//! End-to-end serving driver — the full system on a real small workload.
//!
//! Pipeline exercised (all three layers compose):
//!
//! 1. **Data substrate**: synthetic Zipf-skewed implicit ratings →
//!    implicit ALS → inner-product-preserving lift to the serving
//!    dimension (the Figure-4 "Netflix-like" pipeline).
//! 2. **Coordinator (L3)**: router → dynamic batcher → worker pool,
//!    replaying a Poisson arrival trace of genuine user-factor queries
//!    with mixed per-query (ε, δ) tiers.
//! 3. **Runtime**: if `artifacts/` exists (built by `make artifacts`
//!    from the L2 JAX model calling the L1 Pallas kernel), exact
//!    re-scoring audits run through the PJRT executable; otherwise the
//!    native engine.
//!
//! Reports throughput, latency percentiles, flop savings, and an
//! accuracy audit (precision of served results vs ground truth on a
//! sample). Recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```text
//! cargo run --release --example serving_e2e [-- --items 2000 --dim 512 \
//!     --queries 2000 --rate 500 --workers 2]
//! ```

use bandit_mips::algos::ground_truth;
use bandit_mips::cli::Args;
use bandit_mips::coordinator::{Backend, Coordinator, CoordinatorConfig, QueryRequest};
use bandit_mips::data::{mf, workload};
use bandit_mips::metrics::precision_at_k;
use std::path::Path;
use std::time::{Duration, Instant};

fn main() -> bandit_mips::Result<()> {
    bandit_mips::cli::init_logger();
    let args = Args::parse_with(&["native"]);
    let items = args.get("items", 2000usize);
    let dim = args.get("dim", 512usize);
    let n_queries = args.get("queries", 2000usize);
    let rate = args.get("rate", 500.0f64);
    let workers = args.get("workers", 2usize);

    println!("== serving_e2e: MF recommender serving through the full stack ==");

    // 1. Build the "real small workload": MF embeddings from synthetic
    //    skewed implicit feedback.
    let t0 = Instant::now();
    let mfd = mf::netflix_like(items, dim, 20260710);
    println!(
        "built netflix-like dataset: {} item embeddings in R^{} \
         (ALS rank 32, lifted), {} user queries, in {:?}",
        mfd.dataset.n(),
        mfd.dataset.dim(),
        mfd.user_queries.len(),
        t0.elapsed()
    );

    // 2. Coordinator with PJRT backend when artifacts exist.
    let artifact_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let use_pjrt = !args.has("native")
        && artifact_dir.join(format!("exact_b256_d{dim}.hlo.txt")).exists();
    let backend = if use_pjrt {
        println!("backend: PJRT (AOT artifacts from {})", artifact_dir.display());
        Backend::Pjrt { artifact_dir: artifact_dir.clone() }
    } else {
        println!("backend: native (no exact_b*_d{dim} artifact found or --native)");
        Backend::Native
    };
    let coord = Coordinator::new(
        mfd.dataset.vectors.clone(),
        CoordinatorConfig {
            workers,
            max_batch: 32,
            batch_timeout: Duration::from_millis(2),
            queue_capacity: 8192,
            backend,
            ..Default::default()
        },
    )?;

    // 3. Poisson trace over genuine user factors with mixed (ε, δ) tiers.
    let wl = workload::WorkloadConfig {
        rate,
        count: n_queries,
        k: 10,
        tiers: vec![(0.02, 0.05, 0.2), (0.05, 0.1, 0.5), (0.2, 0.2, 0.3)],
        seed: 99,
    };
    let mut trace = workload::poisson_trace(&mfd.dataset, &wl);
    // Replace synthetic query vectors with genuine user factors.
    for (i, t) in trace.iter_mut().enumerate() {
        t.vector = mfd.user_queries[i % mfd.user_queries.len()].clone();
    }

    println!(
        "replaying {} queries at {:.0} qps (tiers: tight/default/fast ε) …",
        trace.len(),
        rate
    );
    let start = Instant::now();
    let mut pending = Vec::with_capacity(trace.len());
    let mut dropped = 0u64;
    for t in &trace {
        if let Some(sleep) = Duration::from_secs_f64(t.arrival).checked_sub(start.elapsed())
        {
            std::thread::sleep(sleep);
        }
        match coord.submit(QueryRequest::bounded_me(
            t.vector.clone(),
            t.k,
            t.epsilon,
            t.delta,
        )) {
            Ok(rx) => pending.push((t, rx)),
            Err(_) => dropped += 1,
        }
    }
    let mut responses = Vec::with_capacity(pending.len());
    for (t, rx) in pending {
        responses.push((t, rx.recv()?));
    }
    let wall = start.elapsed();

    // 4. Report.
    let m = coord.metrics();
    let naive_flops_per_q = (mfd.dataset.n() * mfd.dataset.dim()) as f64;
    let mean_flops = m.flops as f64 / m.queries.max(1) as f64;
    println!("\n-- serving report --");
    println!(
        "served {}/{} queries ({} dropped by backpressure) in {:.2?} → {:.0} qps",
        m.queries,
        n_queries,
        dropped,
        wall,
        m.queries as f64 / wall.as_secs_f64()
    );
    println!(
        "latency: service p50={:.3} ms p90={:.3} ms p99={:.3} ms; \
         queue p99={:.3} ms; mean batch {:.2}",
        m.service.0 * 1e3,
        m.service.1 * 1e3,
        m.service.2 * 1e3,
        m.queue_wait.2 * 1e3,
        m.mean_batch_size
    );
    println!(
        "flops: mean {:.3e}/query = {:.1}× below naive ({:.3e})",
        mean_flops,
        naive_flops_per_q / mean_flops,
        naive_flops_per_q
    );

    // Accuracy audit on a sample of served queries.
    let audit = 50.min(responses.len());
    let mut prec_sum = 0.0;
    for (t, resp) in responses.iter().take(audit) {
        let truth = ground_truth(&mfd.dataset.vectors, &t.vector, t.k);
        prec_sum += precision_at_k(&truth, &resp.indices);
    }
    println!(
        "accuracy audit: mean precision@10 over {audit} sampled queries = {:.3}",
        prec_sum / audit as f64
    );

    coord.shutdown();
    Ok(())
}
