//! Quickstart: the 60-second tour of the public API.
//!
//! Generates a synthetic dataset, answers one MIPS query exactly, then
//! answers it with BOUNDEDME at three different (ε, δ) settings to show
//! the paper's accuracy/cost knob — no preprocessing, bounded
//! suboptimality, flops always ≤ exhaustive. All queries run through a
//! reusable `QueryContext` (the zero-allocation serving path), and the
//! `QueryPlan` shows which algorithm the planner would route each knob
//! setting to.
//!
//! ```text
//! cargo run --release --example quickstart [-- --n 2000 --dim 4096]
//! ```

use bandit_mips::algos::{ground_truth, BoundedMeIndex, MipsIndex, MipsParams};
use bandit_mips::cli::Args;
use bandit_mips::data::synthetic::gaussian_dataset;
use bandit_mips::exec::{QueryContext, QueryPlan};
use bandit_mips::metrics::precision_at_k;

fn main() {
    let args = Args::parse();
    let n = args.get("n", 2000usize);
    let dim = args.get("dim", 4096usize);
    let k = args.get("k", 5usize);

    println!("== bandit-mips quickstart ==");
    println!("dataset: {n} Gaussian vectors in R^{dim}; top-{k} query\n");

    let ds = gaussian_dataset(n, dim, 42);
    let q = ds.sample_query(7);

    // Ground truth via exhaustive search.
    let t0 = std::time::Instant::now();
    let truth = ground_truth(&ds.vectors, &q, k);
    let naive_time = t0.elapsed();
    let naive_flops = (n * dim) as u64;
    println!("naive:      {truth:?}  ({naive_flops} flops, {naive_time:?})\n");

    // BOUNDEDME: zero preprocessing, per-query knob. One QueryContext
    // serves every query — scratch buffers warm up once, after which
    // the hot path allocates nothing per query.
    let index = BoundedMeIndex::new(ds.vectors.clone());
    let mut ctx = QueryContext::new();
    for (eps, delta) in [(0.3, 0.2), (0.05, 0.1), (0.005, 0.05)] {
        let plan = QueryPlan::pick(k, eps, delta, dim);
        let t0 = std::time::Instant::now();
        let res =
            index.query_with(&q, &MipsParams { k, epsilon: eps, delta, seed: 1 }, &mut ctx);
        let dt = t0.elapsed();
        println!(
            "BoundedME(ε={eps}, δ={delta}): {:?}\n  precision {:.2}, {} flops \
             ({:.1}× fewer than naive), {dt:?}, plan={:?}",
            res.indices,
            precision_at_k(&truth, &res.indices),
            res.flops,
            naive_flops as f64 / res.flops as f64,
            plan.algo,
        );
    }

    println!(
        "\nEvery answer above is guaranteed ε-optimal (relative to the reward \
         range) with probability ≥ 1−δ — Theorem 1 of the paper."
    );
}
