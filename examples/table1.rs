//! Table 1 reproduction (measured): preprocessing time, query time,
//! query flops, precision, and the guarantee column for every method on
//! one common dataset. The paper's table is analytic; this prints the
//! measured counterpart (EXPERIMENTS.md shows them side by side).
//!
//! ```text
//! cargo run --release --example table1 [-- --n 1000 --dim 1024 --full]
//! ```

use bandit_mips::cli::Args;
use bandit_mips::data::synthetic::gaussian_dataset;
use bandit_mips::experiments::table1::{format_rows, run, Table1Config};

fn main() {
    let args = Args::parse_with(&["full"]);
    let (n, dim) = if args.has("full") {
        (10_000, 8192)
    } else {
        (args.get("n", 1000usize), args.get("dim", 1024usize))
    };
    let ds = gaussian_dataset(n, dim, 77);
    println!("== Table 1 (measured): n={n}, N={dim}, K=5, 10 queries ==\n");
    let rows = run(&ds, &Table1Config::default());
    println!("{}", format_rows(&rows));
    std::fs::create_dir_all("results").ok();
    if bandit_mips::experiments::csv::table1_csv("results/table1.csv", &rows).is_ok() {
        println!("(data written to results/table1.csv)");
    }
    println!(
        "paper's analytic columns for reference:\n\
         BOUNDEDME: prep 0, query O(n·√N/ε·√log(1/δ)), ε-optimal w.p. 1−δ\n\
         GREEDY:    prep O(Nn log n), query O(BN), no general guarantee\n\
         LSH:       prep O(Nnab), query O(nN b / 2^a), angle-dependent prob.\n\
         PCA:       prep O(N²n), query O(nN / 2^d), none\n\
         RPT:       prep O(LNn log n), query O(L log n)+rank, not controllable"
    );
}
