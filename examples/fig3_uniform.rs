//! Figure 3 reproduction: precision vs online speedup on the synthetic
//! **uniform** dataset (coordinates i.i.d. U[−1, 1)), K = 5 and 10.
//!
//! ```text
//! cargo run --release --example fig3_uniform [-- --n 2000 --dim 4096 --full]
//! ```

use bandit_mips::cli::Args;
use bandit_mips::data::synthetic::uniform_dataset;
use bandit_mips::experiments::precision_speedup::{format_points, run_sweep, SweepConfig};

fn main() {
    let args = Args::parse_with(&["full"]);
    let (n, dim, queries) = if args.has("full") {
        (10_000, 30_000, 20)
    } else {
        (args.get("n", 2000usize), args.get("dim", 4096usize), args.get("queries", 12usize))
    };
    let ds = uniform_dataset(n, dim, 3033);
    println!("== Figure 3: uniform synthetic, n={n}, N={dim} ==");
    for k in [5usize, 10] {
        let cfg = SweepConfig { k, queries, ..Default::default() };
        println!("\n-- top-{k} --");
        let pts = run_sweep(&ds, &cfg, None);
        println!("{}", format_points(&pts));
        std::fs::create_dir_all("results").ok();
        let path = format!("results/fig3_k{k}.csv");
        if bandit_mips::experiments::csv::sweep_csv(&path, &pts).is_ok() {
            println!("(data written to {path})");
        }
    }
}
