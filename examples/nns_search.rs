//! Nearest Neighbor Search via MAB-BP — the paper's second problem
//! instantiation (`f(i,j) = −(q^(j) − v_i^(j))²`).
//!
//! Runs BOUNDEDME-NNS against the exact scan on Gaussian data and on a
//! clustered dataset, sweeping ε to show the same accuracy/cost knob on
//! a different objective; also demonstrates the Remark-1 extreme-point
//! extension on the MIPS side for contrast.
//!
//! ```text
//! cargo run --release --example nns_search [-- --n 2000 --dim 2048]
//! ```

use bandit_mips::algos::hull::BoundedMeHullIndex;
use bandit_mips::algos::nns::{nns_ground_truth, BoundedMeNnsIndex};
use bandit_mips::algos::{ground_truth, MipsIndex, MipsParams};
use bandit_mips::cli::Args;
use bandit_mips::data::synthetic::{gaussian_dataset, low_rank_dataset};
use bandit_mips::metrics::precision_at_k;

fn main() {
    let args = Args::parse();
    let n = args.get("n", 2000usize);
    let dim = args.get("dim", 2048usize);
    let k = args.get("k", 5usize);

    println!("== NNS via MAB-BP: {n} Gaussian vectors in R^{dim}, {k}-NN ==\n");
    let ds = gaussian_dataset(n, dim, 101);
    let idx = BoundedMeNnsIndex::new(ds.vectors.clone());
    let naive_flops = (n * dim) as f64;

    println!("{:<10} {:>10} {:>14} {:>10}", "ε", "recall", "flops", "speedup");
    for eps in [0.01, 0.05, 0.2, 0.5, 0.9] {
        let mut recall = 0.0;
        let mut flops = 0u64;
        let trials = 8;
        for s in 0..trials {
            let q = ds.sample_query(s);
            let truth = nns_ground_truth(&ds.vectors, &q, k);
            let res = idx.query(&q, &MipsParams { k, epsilon: eps, delta: 0.1, seed: s });
            recall += precision_at_k(&truth, &res.indices);
            flops += res.flops;
        }
        let mean_flops = flops as f64 / trials as f64;
        println!(
            "{eps:<10} {:>10.3} {:>14.0} {:>9.1}x",
            recall / trials as f64,
            mean_flops,
            naive_flops / mean_flops
        );
    }

    println!("\n== Remark-1 extension (MIPS): extreme-point filter on low-rank data ==");
    let lr = low_rank_dataset(n, dim.min(512), 8, 0.02, 7);
    let hull = BoundedMeHullIndex::new(lr.vectors.clone(), 256, 2, 3);
    println!(
        "kept {} / {n} points as extreme ({:.1}%), preprocessing {:.3}s",
        hull.n_extreme(),
        100.0 * hull.n_extreme() as f64 / n as f64,
        hull.preprocessing_seconds()
    );
    let mut prec = 0.0;
    let mut flops = 0u64;
    let trials = 10;
    for s in 0..trials {
        let q = lr.sample_query(s);
        let truth = ground_truth(&lr.vectors, &q, k);
        let res = hull.query(&q, &MipsParams { k, epsilon: 0.05, delta: 0.1, seed: s });
        prec += precision_at_k(&truth, &res.indices);
        flops += res.flops;
    }
    let naive_lr = (n * lr.dim()) as f64;
    println!(
        "hull-restricted BoundedME: precision {:.3}, mean flops {:.0} \
         ({:.1}x below naive) — sublinear in n, at the cost of preprocessing",
        prec / trials as f64,
        flops as f64 / trials as f64,
        naive_lr / (flops as f64 / trials as f64)
    );
}
