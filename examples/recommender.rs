//! Recommender scenario: the use case from the paper's introduction.
//!
//! Matrix-factorization recommenders answer "top-K items for user u" as
//! a MIPS query over item embeddings. This example trains implicit ALS
//! on synthetic skewed feedback, then serves recommendations for a few
//! users comparing BOUNDEDME against the exact scan and GREEDY-MIPS —
//! showing result overlap, flops, and the effect of the ε knob when the
//! catalog changes frequently (zero preprocessing to redo).
//!
//! ```text
//! cargo run --release --example recommender [-- --items 1500 --dim 1024]
//! ```

use bandit_mips::algos::{
    ground_truth, BoundedMeIndex, GreedyMipsIndex, MipsIndex, MipsParams, NaiveIndex,
};
use bandit_mips::cli::Args;
use bandit_mips::data::mf;
use bandit_mips::exec::QueryContext;
use bandit_mips::metrics::precision_at_k;
use std::time::Instant;

fn main() {
    let args = Args::parse();
    let items = args.get("items", 1500usize);
    let dim = args.get("dim", 1024usize);
    let k = args.get("k", 10usize);

    println!("== recommender: ALS + MIPS serving ==");
    let t0 = Instant::now();
    let mfd = mf::yahoo_like(items, dim, 7);
    println!(
        "trained+lifted {} item embeddings (R^{}) in {:?}\n",
        mfd.dataset.n(),
        dim,
        t0.elapsed()
    );

    let naive = NaiveIndex::new(mfd.dataset.vectors.clone());
    let bme = BoundedMeIndex::new(mfd.dataset.vectors.clone());
    let t0 = Instant::now();
    let greedy = GreedyMipsIndex::new(mfd.dataset.vectors.clone(), items / 10);
    let greedy_prep = t0.elapsed();
    println!(
        "GREEDY-MIPS preprocessing took {greedy_prep:?} — repaid only if the \
         catalog stays frozen; BOUNDEDME needs none.\n"
    );

    let naive_flops = (mfd.dataset.n() * mfd.dataset.dim()) as f64;
    // One reusable context for the whole serving loop (the hot-path
    // pattern: scratch warms up once, then queries are allocation-free).
    let mut ctx = QueryContext::new();
    println!(
        "{:<8} {:<12} {:>10} {:>12} {:>10}",
        "user", "algo", "precision", "flops", "speedup"
    );
    for user in 0..5 {
        let q = &mfd.user_queries[user * 11 % mfd.user_queries.len()];
        let truth = ground_truth(&mfd.dataset.vectors, q, k);
        for (algo, res) in [
            ("naive", naive.query_with(q, &MipsParams { k, ..Default::default() }, &mut ctx)),
            (
                "BoundedME",
                bme.query_with(
                    q,
                    &MipsParams { k, epsilon: 0.03, delta: 0.1, seed: user as u64 },
                    &mut ctx,
                ),
            ),
            ("Greedy", greedy.query(q, &MipsParams { k, ..Default::default() })),
        ] {
            println!(
                "{:<8} {:<12} {:>10.2} {:>12} {:>9.1}x",
                format!("u{user}"),
                algo,
                precision_at_k(&truth, &res.indices),
                res.flops,
                naive_flops / res.flops as f64
            );
        }
    }

    // The "catalog churn" scenario (Motivation I): after items change,
    // preprocessing-based methods rebuild; BOUNDEDME just queries.
    println!("\n-- catalog churn: 10 new item versions --");
    let mut rebuild_total = std::time::Duration::ZERO;
    let mut bme_total = std::time::Duration::ZERO;
    for ver in 0..10u64 {
        let fresh = mf::yahoo_like(items, dim, 100 + ver);
        let t0 = Instant::now();
        let _rebuilt = GreedyMipsIndex::new(fresh.dataset.vectors.clone(), items / 10);
        rebuild_total += t0.elapsed();
        let t0 = Instant::now();
        let idx = BoundedMeIndex::new(fresh.dataset.vectors.clone());
        let q = &fresh.user_queries[0];
        let _ = idx.query_with(q, &MipsParams { k, epsilon: 0.03, delta: 0.1, seed: ver }, &mut ctx);
        bme_total += t0.elapsed();
    }
    println!(
        "greedy rebuild time: {rebuild_total:?} | BoundedME (build+query!): {bme_total:?}"
    );
}
