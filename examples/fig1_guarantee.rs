//! Figure 1 reproduction: BOUNDEDME's (ε, δ) guarantee on the
//! adversarial environment.
//!
//! For each ε ∈ {0.05…0.6} and δ ∈ {0.01, 0.05, 0.1, 0.2, 0.3}, run 20
//! trials on fresh adversarial Bernoulli arms (1s served first) and
//! check the (1−δ)-percentile suboptimality stays below ε — every point
//! below the diagonal, as in the paper's plot.
//!
//! ```text
//! cargo run --release --example fig1_guarantee [-- --full]
//! ```
//! `--full` uses the paper's n=10⁴ arms, N=10⁵ rewards.

use bandit_mips::cli::Args;
use bandit_mips::experiments::fig1::{per_epsilon, run, Fig1Config};
use bandit_mips::experiments::markdown_table;

fn main() {
    let args = Args::parse_with(&["full"]);
    let cfg = if args.has("full") {
        Fig1Config { n_arms: 10_000, n_list: 100_000, ..Default::default() }
    } else {
        Fig1Config::default()
    };
    println!(
        "== Figure 1: guarantee validation (n={}, N={}, {} trials/point) ==\n",
        cfg.n_arms, cfg.n_list, cfg.trials
    );
    let points = run(&cfg);
    std::fs::create_dir_all("results").ok();
    if let Err(e) = bandit_mips::experiments::csv::fig1_csv("results/fig1.csv", &points) {
        eprintln!("csv write failed: {e}");
    } else {
        println!("(data written to results/fig1.csv)\n");
    }

    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                format!("{:.2}", p.epsilon),
                format!("{:.2}", p.delta),
                format!("{:.4}", p.quantile_subopt),
                format!("{:.4}", p.mean_subopt),
                format!("{:.2e}", p.mean_pulls),
                if p.holds { "yes".into() } else { "VIOLATED".into() },
            ]
        })
        .collect();
    println!(
        "{}",
        markdown_table(
            &["ε", "δ", "(1-δ)-pct subopt", "mean subopt", "mean pulls", "≤ ε?"],
            &rows
        )
    );

    println!("\nper-ε aggregate (the paper's plotted series):");
    let mut all_hold = true;
    for (e, q, h) in per_epsilon(&points) {
        println!("  ε={e:<5.2} avg quantile subopt = {q:.4}  (below diagonal: {h})");
        all_hold &= h;
    }
    println!(
        "\nTheorem 1 {}",
        if all_hold { "VALIDATED: every point under y = x" } else { "VIOLATED" }
    );
}
