//! Figure 4 reproduction: precision vs online speedup on the two
//! "real-world" matrix-factorization datasets (Netflix-like and
//! Yahoo-Music-like; see DESIGN.md §1 for the substitution — we rebuild
//! the MF pipeline on synthetic skewed ratings since the raw data is
//! unavailable). K = 5, genuine user-factor queries.
//!
//! ```text
//! cargo run --release --example fig4_realworld [-- --items 2000 --dim 4096]
//! ```

use bandit_mips::cli::Args;
use bandit_mips::data::mf;
use bandit_mips::experiments::precision_speedup::{format_points, run_sweep, SweepConfig};

fn main() {
    let args = Args::parse_with(&["full"]);
    let (items, dim, queries) = if args.has("full") {
        (10_000, 30_000, 20)
    } else {
        (
            args.get("items", 2000usize),
            args.get("dim", 4096usize),
            args.get("queries", 12usize),
        )
    };

    for (label, mfd) in [
        ("netflix-like", mf::netflix_like(items, dim, 404)),
        ("yahoo-like", mf::yahoo_like(items, dim, 505)),
    ] {
        println!(
            "\n== Figure 4 ({label}): {} MF item embeddings, R^{dim}, K=5 ==",
            mfd.dataset.n()
        );
        let cfg = SweepConfig { k: 5, queries, ..Default::default() };
        let pts = run_sweep(&mfd.dataset, &cfg, Some(&mfd.user_queries));
        println!("{}", format_points(&pts));
        std::fs::create_dir_all("results").ok();
        let path = format!("results/fig4_{label}.csv");
        if bandit_mips::experiments::csv::sweep_csv(&path, &pts).is_ok() {
            println!("(data written to {path})");
        }
    }
}
