"""L1 Pallas kernel: blocked (partial) inner products.

The compute hot-spot of the whole system is "score a block of vectors
against a (slice of a) query": the exact re-ranking path uses the full
width, and a BOUNDEDME elimination round is the same kernel over a
coordinate slab (one *pull batch* per arm — see DESIGN.md
§Hardware-Adaptation for how the paper's per-coordinate pulls become
dense slabs via a per-query permutation).

TPU thinking (the paper's cost model is scalar MACs; the TPU unit is an
(8,128) VPU lane / MXU pass):

* the grid tiles arms x coords into ``(block_b, block_c)`` VMEM slabs;
* each grid step computes a dense mat-vec on the slab — contiguous HBM
  reads, MXU-friendly;
* the coordinate dimension is the *reduction* (minor) grid axis, so the
  output block stays resident in VMEM while a row of slabs streams
  through (double-buffered by Pallas).

VMEM budget at the default (128, 512) f32 tile: 256 KiB for the slab +
2 KiB for the query slice + 0.5 KiB accumulator, x2 for double
buffering — comfortably inside ~16 MiB VMEM.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the rust
runtime loads. Real-TPU perf is *estimated* in DESIGN.md, not measured.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(v_ref, q_ref, o_ref):
    """One grid step: o[bb] (+)= V[bb, bc] @ q[bc]."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Dense slab mat-vec; f32 accumulate (MXU pass on real TPU).
    o_ref[...] += jnp.dot(
        v_ref[...], q_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block(total: int, want: int) -> int:
    """Largest divisor of ``total`` that is <= ``want`` (>= 1)."""
    b = min(want, total)
    while total % b != 0:
        b -= 1
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("block_b", "block_c"))
def block_scores(v, q, *, block_b: int = 128, block_c: int = 512):
    """Inner products of every row of ``v [B, C]`` with ``q [C]`` -> ``[B]``.

    Used both as the *exact* scorer (C = full dimension) and as the
    *partial* scorer (C = one pull-batch slab). Shapes must tile; the
    block sizes are clamped to divisors so odd shapes still work (tests
    sweep them via hypothesis).
    """
    b, c = v.shape
    assert q.shape == (c,), f"q shape {q.shape} != ({c},)"
    bb = _pick_block(b, block_b)
    bc = _pick_block(c, block_c)
    grid = (b // bb, c // bc)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bc), lambda i, j: (i, j)),
            pl.BlockSpec((bc,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(v, q)
