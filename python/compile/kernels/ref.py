"""Pure-jnp oracle for the Pallas kernels (the correctness ground truth).

Everything here is the obvious one-liner; the pytest suite asserts the
Pallas implementations match these to float tolerance across a
hypothesis-driven shape/dtype sweep.
"""

from __future__ import annotations

import jax.numpy as jnp


def block_scores_ref(v, q):
    """Reference for ``partial_dot.block_scores``: ``V @ q`` in f32."""
    return jnp.dot(v.astype(jnp.float32), q.astype(jnp.float32))


def topk_ref(scores, k: int):
    """Reference top-k (descending) over a 1-D score vector."""
    idx = jnp.argsort(-scores)[:k]
    return scores[idx], idx
