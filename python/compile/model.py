"""L2: the JAX compute graph the rust coordinator executes via PJRT.

Two entry points, both funnelling into the L1 Pallas kernel
(:mod:`compile.kernels.partial_dot`):

* :func:`exact_scores` — full-width inner products of a block of data
  vectors against a query (the exact re-rank / naive backend);
* :func:`partial_scores` — one BOUNDEDME pull batch: partial inner
  products over a coordinate slab.

Both are pure functions of fixed-shape f32 arrays so they AOT-lower
cleanly (see :mod:`compile.aot`). Python never runs at serve time — the
rust runtime loads the lowered HLO text.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.partial_dot import block_scores


def exact_scores(v, q):
    """Exact inner products: ``v [B, D] f32, q [D] f32 -> [B] f32``.

    Returns a 1-tuple so the lowered computation has the tuple root the
    rust loader unwraps with ``to_tuple1``.
    """
    return (block_scores(v, q),)


def exact_scores_flat(v, q):
    """`exact_scores` with a single-step grid (whole array as one tile).

    On the CPU PJRT backend the interpret-mode Pallas grid lowers to a
    sequential slice loop in HLO, which executes far slower than one
    fused dot; artifacts destined for CPU serving use this flat variant
    (grid (1,1) ⇒ a single XLA dot). On a real TPU the tiled
    `exact_scores` is the right lowering (VMEM-sized slabs).
    """
    b, d = v.shape
    return (block_scores(v, q, block_b=b, block_c=d),)


def partial_scores(v_blk, q_blk):
    """Partial sums over a coordinate slab: ``[B, C], [C] -> [B]``.

    One elimination round pulls each surviving arm for a contiguous run
    of (pre-permuted) coordinates; this is that run, batched across
    arms. The caller accumulates across rounds and divides by the pull
    count for the empirical mean.
    """
    return (block_scores(v_blk, q_blk, block_b=128, block_c=256),)


def exact_scores_topk(v, q, k: int):
    """Exact scores fused with a top-k selection (scores + indices).

    Kept for completeness of the L2 surface (the serving path currently
    ranks on the rust side where K is dynamic per request).
    """
    scores = block_scores(v, q)
    top_scores, top_idx = jax.lax.top_k(scores, k)
    return (top_scores, top_idx.astype(jnp.int32))
