"""AOT lowering: JAX (L2 + L1) → HLO *text* artifacts for the rust runtime.

Interchange is HLO text, NOT a serialized ``HloModuleProto``: jax ≥ 0.5
emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifact naming (parsed by ``rust/src/runtime/mod.rs``):

* ``exact_b{B}_d{D}.hlo.txt``   — inputs ``V[B,D] f32, q[D] f32``
* ``partial_b{B}_c{C}.hlo.txt`` — inputs ``V[B,C] f32, q[C] f32``

Usage::

    python -m compile.aot --outdir ../artifacts \
        [--exact 256x512,256x4096] [--partial 128x256]
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_exact(b: int, d: int, flat: bool = True) -> str:
    """Lower exact scoring at shape ``[b, d]``.

    ``flat=True`` (default) emits the single-tile variant, which is what
    the CPU PJRT backend executes efficiently; ``flat=False`` keeps the
    TPU-style (128, 512) tiling (sequential slice loop on CPU).
    """
    spec_v = jax.ShapeDtypeStruct((b, d), jnp.float32)
    spec_q = jax.ShapeDtypeStruct((d,), jnp.float32)
    fn = model.exact_scores_flat if flat else model.exact_scores
    return to_hlo_text(jax.jit(fn).lower(spec_v, spec_q))


def lower_partial(b: int, c: int) -> str:
    spec_v = jax.ShapeDtypeStruct((b, c), jnp.float32)
    spec_q = jax.ShapeDtypeStruct((c,), jnp.float32)
    return to_hlo_text(jax.jit(model.partial_scores).lower(spec_v, spec_q))


def parse_shapes(spec: str) -> list[tuple[int, int]]:
    """``"256x512,128x64"`` → ``[(256, 512), (128, 64)]``."""
    out = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        a, b = part.lower().split("x")
        out.append((int(a), int(b)))
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--exact",
        default="256x512,256x4096,2048x512",
        help="comma-separated BxD shape buckets for exact scoring",
    )
    ap.add_argument(
        "--partial",
        default="128x256",
        help="comma-separated BxC shape buckets for partial scoring",
    )
    args = ap.parse_args(argv)

    os.makedirs(args.outdir, exist_ok=True)
    written = []
    for b, d in parse_shapes(args.exact):
        path = os.path.join(args.outdir, f"exact_b{b}_d{d}.hlo.txt")
        text = lower_exact(b, d)
        with open(path, "w") as f:
            f.write(text)
        written.append((path, len(text)))
    for b, c in parse_shapes(args.partial):
        path = os.path.join(args.outdir, f"partial_b{b}_c{c}.hlo.txt")
        text = lower_partial(b, c)
        with open(path, "w") as f:
            f.write(text)
        written.append((path, len(text)))

    for path, size in written:
        print(f"wrote {size:>8} chars to {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
