"""Analytical TPU resource model for the L1 kernel (DESIGN.md
§Hardware-Adaptation).

Pallas runs in interpret mode on this image's CPU, so real-TPU
performance cannot be *measured*; this module *estimates* it from first
principles: VMEM footprint of the BlockSpec tiling, HBM traffic, and the
roofline-implied bound (bandwidth vs MXU/VPU compute) for the blocked
mat-vec `V[B, C] @ q[C]`.

Numbers default to TPU v4-lite-ish constants; they parameterize so the
DESIGN.md table can show sensitivity. Exercised by
``python/tests/test_estimate.py`` and printable via::

    python -m compile.estimate [--block-b 128 --block-c 512]
"""

from __future__ import annotations

import argparse
from dataclasses import dataclass


@dataclass(frozen=True)
class TpuParams:
    """Hardware constants for the estimate."""

    vmem_bytes: int = 16 * 2**20  # ~16 MiB VMEM per core
    hbm_gbps: float = 1200.0  # HBM bandwidth, GB/s
    vpu_flops: float = 4.0e12  # f32 VPU peak, FLOP/s
    mxu_flops: float = 137.0e12  # bf16 MXU peak, FLOP/s
    clock_ghz: float = 1.05


@dataclass(frozen=True)
class KernelEstimate:
    """Estimated execution profile of one `block_scores` call."""

    block_b: int
    block_c: int
    grid: tuple
    vmem_per_step_bytes: int
    vmem_utilization: float
    hbm_bytes: int
    flops: int
    arithmetic_intensity: float  # FLOP per HBM byte
    bandwidth_bound: bool
    est_seconds: float
    est_flops_per_sec: float
    roofline_fraction: float


def estimate_block_scores(
    b: int,
    c: int,
    *,
    block_b: int = 128,
    block_c: int = 512,
    dtype_bytes: int = 4,
    double_buffer: bool = True,
    tpu: TpuParams = TpuParams(),
) -> KernelEstimate:
    """Estimate the kernel's resource profile at shape ``[b, c]``.

    The kernel is a mat-vec: 2·b·c FLOPs over b·c + c + b words of HBM
    traffic — arithmetic intensity ≈ 2/dtype_bytes FLOP/byte, firmly
    bandwidth-bound on any TPU. The estimate therefore reports the
    bandwidth roofline and the VMEM feasibility of the chosen BlockSpec.
    """
    block_b = min(block_b, b)
    block_c = min(block_c, c)
    grid = (max(b // max(block_b, 1), 1), max(c // max(block_c, 1), 1))

    slab = block_b * block_c * dtype_bytes  # V tile
    qslice = block_c * dtype_bytes
    acc = block_b * 4  # f32 accumulator
    vmem = (slab + qslice) * (2 if double_buffer else 1) + acc

    hbm = (b * c + c * grid[0] + b) * dtype_bytes  # V once, q per row-block, out
    flops = 2 * b * c
    intensity = flops / hbm

    t_bw = hbm / (tpu.hbm_gbps * 1e9)
    t_compute = flops / tpu.vpu_flops  # mat-vec rides the VPU (no MXU reuse)
    est_seconds = max(t_bw, t_compute)

    return KernelEstimate(
        block_b=block_b,
        block_c=block_c,
        grid=grid,
        vmem_per_step_bytes=vmem,
        vmem_utilization=vmem / tpu.vmem_bytes,
        hbm_bytes=hbm,
        flops=flops,
        arithmetic_intensity=intensity,
        bandwidth_bound=t_bw >= t_compute,
        est_seconds=est_seconds,
        est_flops_per_sec=flops / est_seconds,
        roofline_fraction=(flops / est_seconds)
        / min(tpu.vpu_flops, intensity * tpu.hbm_gbps * 1e9),
    )


def sweep_block_sizes(b: int, c: int, tpu: TpuParams = TpuParams()):
    """Feasible (block_b, block_c) settings sorted by estimated time."""
    candidates = []
    for bb in (8, 32, 128, 256, 512):
        for bc in (128, 256, 512, 1024, 2048):
            if bb > b or bc > c:
                continue
            e = estimate_block_scores(b, c, block_b=bb, block_c=bc, tpu=tpu)
            if e.vmem_utilization <= 0.9:
                candidates.append(e)
    return sorted(candidates, key=lambda e: (e.est_seconds, -e.vmem_utilization))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--b", type=int, default=10_000)
    ap.add_argument("--c", type=int, default=100_000)
    ap.add_argument("--block-b", type=int, default=128)
    ap.add_argument("--block-c", type=int, default=512)
    args = ap.parse_args(argv)

    e = estimate_block_scores(args.b, args.c, block_b=args.block_b, block_c=args.block_c)
    print(f"block_scores V[{args.b},{args.c}] @ q  (tile {e.block_b}x{e.block_c})")
    print(f"  grid                {e.grid}")
    print(f"  VMEM/step           {e.vmem_per_step_bytes/2**20:.2f} MiB "
          f"({100*e.vmem_utilization:.1f}% of VMEM)")
    print(f"  HBM traffic         {e.hbm_bytes/2**30:.3f} GiB")
    print(f"  arithmetic intensity {e.arithmetic_intensity:.2f} FLOP/B "
          f"({'bandwidth' if e.bandwidth_bound else 'compute'}-bound)")
    print(f"  est. time           {e.est_seconds*1e3:.3f} ms "
          f"({e.est_flops_per_sec/1e12:.2f} TFLOP/s, "
          f"{100*e.roofline_fraction:.0f}% of roofline)")
    print("\nbest tilings:")
    for cand in sweep_block_sizes(args.b, args.c)[:5]:
        print(f"  {cand.block_b:>4}x{cand.block_c:<5} est {cand.est_seconds*1e3:8.3f} ms"
              f"  vmem {100*cand.vmem_utilization:5.1f}%")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
