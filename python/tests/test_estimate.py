"""Tests for the analytical TPU resource model."""

import pytest

from compile.estimate import (
    KernelEstimate,
    TpuParams,
    estimate_block_scores,
    sweep_block_sizes,
)


class TestEstimate:
    def test_default_tile_fits_vmem(self):
        e = estimate_block_scores(10_000, 100_000)
        assert e.vmem_utilization < 0.1  # (128,512) f32 double-buffered ≈ 0.5 MiB
        assert e.grid[0] >= 1 and e.grid[1] >= 1

    def test_matvec_is_bandwidth_bound(self):
        e = estimate_block_scores(10_000, 100_000)
        assert e.bandwidth_bound
        # intensity of f32 mat-vec ≈ 2 FLOP / 4 B = 0.5
        assert 0.4 < e.arithmetic_intensity < 0.6

    def test_roofline_fraction_near_one(self):
        # The estimate *is* the roofline model, so the fraction is ~1 by
        # construction — this pins the algebra.
        e = estimate_block_scores(4096, 8192)
        assert 0.95 < e.roofline_fraction <= 1.0001

    def test_time_scales_linearly_in_data(self):
        small = estimate_block_scores(1000, 10_000)
        big = estimate_block_scores(2000, 10_000)
        assert big.est_seconds == pytest.approx(2 * small.est_seconds, rel=0.05)

    def test_blocks_clamped_to_shape(self):
        e = estimate_block_scores(16, 64, block_b=128, block_c=512)
        assert e.block_b == 16 and e.block_c == 64

    def test_vmem_grows_with_tile(self):
        a = estimate_block_scores(4096, 4096, block_b=32, block_c=128)
        b = estimate_block_scores(4096, 4096, block_b=256, block_c=1024)
        assert b.vmem_per_step_bytes > a.vmem_per_step_bytes

    def test_sweep_returns_feasible_sorted(self):
        cands = sweep_block_sizes(4096, 16384)
        assert cands, "no feasible tilings?"
        assert all(isinstance(c, KernelEstimate) for c in cands)
        assert all(c.vmem_utilization <= 0.9 for c in cands)
        times = [c.est_seconds for c in cands]
        assert times == sorted(times)

    def test_custom_hardware_params(self):
        slow = TpuParams(hbm_gbps=100.0)
        fast = TpuParams(hbm_gbps=2000.0)
        es = estimate_block_scores(4096, 4096, tpu=slow)
        ef = estimate_block_scores(4096, 4096, tpu=fast)
        assert es.est_seconds > ef.est_seconds
