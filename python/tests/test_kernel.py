"""L1 correctness: Pallas kernel vs pure-jnp oracle.

This is the core correctness signal for everything the rust runtime
executes — the AOT artifacts are lowered from exactly these functions.
Hypothesis sweeps shapes/dtypes; fixed tests pin the shape buckets that
ship as artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.partial_dot import block_scores, _pick_block
from compile.kernels.ref import block_scores_ref, topk_ref


def rand(shape, seed, dtype=np.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(dtype)


class TestPickBlock:
    def test_divisor(self):
        assert _pick_block(100, 8) == 5
        assert _pick_block(128, 128) == 128
        assert _pick_block(7, 4) == 1
        assert _pick_block(12, 6) == 6

    def test_never_zero(self):
        for total in range(1, 40):
            for want in range(1, 40):
                b = _pick_block(total, want)
                assert 1 <= b <= total and total % b == 0


class TestBlockScoresFixed:
    """Pin the artifact shape buckets exactly."""

    @pytest.mark.parametrize("b,d", [(256, 512), (256, 4096), (128, 256)])
    def test_artifact_buckets(self, b, d):
        v = rand((b, d), seed=b + d)
        q = rand((d,), seed=d)
        got = np.asarray(block_scores(jnp.asarray(v), jnp.asarray(q)))
        want = np.asarray(block_scores_ref(jnp.asarray(v), jnp.asarray(q)))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_tiny(self):
        v = jnp.asarray([[1.0, 2.0], [3.0, 4.0]], dtype=jnp.float32)
        q = jnp.asarray([1.0, -1.0], dtype=jnp.float32)
        got = np.asarray(block_scores(v, q))
        np.testing.assert_allclose(got, [-1.0, -1.0], atol=1e-6)

    def test_zero_query(self):
        v = rand((64, 32), seed=1)
        q = np.zeros(32, dtype=np.float32)
        got = np.asarray(block_scores(jnp.asarray(v), jnp.asarray(q)))
        np.testing.assert_allclose(got, np.zeros(64), atol=0)

    def test_block_sizes_do_not_change_result(self):
        v = rand((96, 192), seed=2)
        q = rand((192,), seed=3)
        base = np.asarray(block_scores(jnp.asarray(v), jnp.asarray(q)))
        for bb in (1, 3, 32, 96):
            for bc in (1, 64, 192):
                got = np.asarray(
                    block_scores(jnp.asarray(v), jnp.asarray(q), block_b=bb, block_c=bc)
                )
                np.testing.assert_allclose(got, base, rtol=1e-4, atol=1e-4)

    def test_large_magnitudes(self):
        v = rand((32, 64), seed=4, scale=1e3)
        q = rand((64,), seed=5, scale=1e3)
        got = np.asarray(block_scores(jnp.asarray(v), jnp.asarray(q)))
        want = v.astype(np.float64) @ q.astype(np.float64)
        np.testing.assert_allclose(got, want, rtol=1e-3)


class TestBlockScoresHypothesis:
    @settings(max_examples=40, deadline=None)
    @given(
        b=st.integers(min_value=1, max_value=64),
        d=st.integers(min_value=1, max_value=300),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref_any_shape(self, b, d, seed):
        v = rand((b, d), seed=seed)
        q = rand((d,), seed=seed ^ 0xFFFF)
        got = np.asarray(block_scores(jnp.asarray(v), jnp.asarray(q)))
        want = np.asarray(block_scores_ref(jnp.asarray(v), jnp.asarray(q)))
        assert got.shape == (b,)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(min_value=1, max_value=32),
        d=st.integers(min_value=1, max_value=128),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_bf16_inputs_accumulate_f32(self, b, d, seed):
        v32 = rand((b, d), seed=seed)
        q32 = rand((d,), seed=seed ^ 0xABC)
        v = jnp.asarray(v32, dtype=jnp.bfloat16).astype(jnp.float32)
        q = jnp.asarray(q32, dtype=jnp.bfloat16).astype(jnp.float32)
        got = np.asarray(block_scores(v, q))
        want = np.asarray(block_scores_ref(v, q))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


class TestTopKRef:
    def test_topk_descending(self):
        s = jnp.asarray([0.1, 5.0, -1.0, 3.0], dtype=jnp.float32)
        vals, idx = topk_ref(s, 2)
        np.testing.assert_allclose(np.asarray(vals), [5.0, 3.0])
        np.testing.assert_array_equal(np.asarray(idx), [1, 3])
