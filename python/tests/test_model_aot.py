"""L2 model + AOT pipeline tests: shapes, numerics, and HLO-text output."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)


class TestModel:
    def test_exact_scores_tuple_and_values(self):
        v = rand((16, 32), 1)
        q = rand((32,), 2)
        out = model.exact_scores(jnp.asarray(v), jnp.asarray(q))
        assert isinstance(out, tuple) and len(out) == 1
        np.testing.assert_allclose(np.asarray(out[0]), v @ q, rtol=1e-4, atol=1e-4)

    def test_partial_scores_is_slab_sum(self):
        v = rand((128, 256), 3)
        q = rand((256,), 4)
        out = model.partial_scores(jnp.asarray(v), jnp.asarray(q))[0]
        np.testing.assert_allclose(np.asarray(out), v @ q, rtol=1e-4, atol=1e-4)

    def test_exact_topk_agrees_with_numpy(self):
        v = rand((64, 48), 5)
        q = rand((48,), 6)
        scores, idx = model.exact_scores_topk(jnp.asarray(v), jnp.asarray(q), 5)
        want_idx = np.argsort(-(v @ q))[:5]
        np.testing.assert_array_equal(np.asarray(idx), want_idx)
        np.testing.assert_allclose(np.asarray(scores), (v @ q)[want_idx], rtol=1e-4)


class TestAot:
    def test_parse_shapes(self):
        assert aot.parse_shapes("256x512,128x64") == [(256, 512), (128, 64)]
        assert aot.parse_shapes(" 8X16 ") == [(8, 16)]
        assert aot.parse_shapes("") == []

    def test_lower_exact_produces_hlo_text(self):
        text = aot.lower_exact(8, 16)
        assert "HloModule" in text
        assert "f32[8,16]" in text

    def test_lower_partial_produces_hlo_text(self):
        text = aot.lower_partial(8, 16)
        assert "HloModule" in text

    def test_main_writes_artifacts(self, tmp_path):
        rc = aot.main(
            ["--outdir", str(tmp_path), "--exact", "8x16", "--partial", "4x8"]
        )
        assert rc == 0
        files = sorted(os.listdir(tmp_path))
        assert files == ["exact_b8_d16.hlo.txt", "partial_b4_c8.hlo.txt"]
        for f in files:
            content = (tmp_path / f).read_text()
            assert content.startswith("HloModule")

    def test_lowered_hlo_recompiles_and_matches(self, tmp_path):
        """Round-trip: HLO text → xla_client compile → execute → numerics.

        This is the same path the rust runtime takes (text parse +
        compile on the CPU PJRT client), checked end-to-end in python.
        """
        from jax._src.lib import xla_client as xc

        b, d = 8, 16
        text = aot.lower_exact(b, d)
        # Re-parse the text through the XLA text parser and execute.
        client = xc._xla.get_tfrt_cpu_client()  # type: ignore[attr-defined]
        try:
            comp = xc._xla.hlo_module_from_text(text)  # may not exist
        except AttributeError:
            pytest.skip("hlo text parser not exposed in this jaxlib")
        del client, comp
