//! Ablation A3 (Remark 1): extreme-point filtering — preprocessing cost
//! vs per-query savings vs recall, across data geometry (isotropic
//! Gaussian has ~all points extreme; low-rank data has few).

use bandit_mips::algos::hull::{BoundedMeHullIndex, ExtremePointFilter};
use bandit_mips::algos::{ground_truth, BoundedMeIndex, MipsIndex, MipsParams};
use bandit_mips::benchkit::{Bencher, Reporter};
use bandit_mips::data::synthetic::{gaussian_dataset, low_rank_dataset};
use bandit_mips::metrics::precision_at_k;

fn main() {
    let b = Bencher::quick();
    let mut r = Reporter::new();
    let n = 800;

    for (label, ds) in [
        ("gaussian(iso)", gaussian_dataset(n, 256, 1)),
        ("low_rank(r=4)", low_rank_dataset(n, 256, 4, 0.02, 2)),
        ("low_rank(r=16)", low_rank_dataset(n, 256, 16, 0.02, 3)),
    ] {
        // Filter construction cost + retained fraction.
        let mut kept = 0usize;
        r.bench(&b, &format!("hull/build m=128 t=2 {label}"), || {
            let f = ExtremePointFilter::build(&ds.vectors, 128, 2, 7);
            kept = f.extreme_ids.len();
            kept
        });
        println!("    kept {kept}/{n} ({:.1}%)", 100.0 * kept as f64 / n as f64);

        // Query cost + precision: full vs hull-restricted.
        let full = BoundedMeIndex::new(ds.vectors.clone());
        let hull = BoundedMeHullIndex::new(ds.vectors.clone(), 128, 2, 7);
        let p = MipsParams { k: 5, epsilon: 0.05, delta: 0.1, seed: 0 };
        for (name, idx) in [("full", &full as &dyn MipsIndex), ("hull", &hull)] {
            let mut prec = 0.0;
            let mut flops = 0u64;
            let queries = 6;
            for s in 0..queries {
                let q = ds.sample_query(s);
                let truth = ground_truth(&ds.vectors, &q, 5);
                let res = idx.query(&q, &MipsParams { seed: s, ..p });
                prec += precision_at_k(&truth, &res.indices);
                flops += res.flops;
            }
            let q0 = ds.sample_query(99);
            r.bench(&b, &format!("hull/query {name} {label}"), || {
                idx.query(&q0, &p).flops
            });
            println!(
                "    {name}: precision {:.3}, mean flops {:.0}",
                prec / queries as f64,
                flops as f64 / queries as f64
            );
        }
    }

    r.finish("ablation A3: Remark-1 extreme-point filter");
}
