//! Bench: Figures 2–4 engine — per-algorithm query cost at comparable
//! precision on Gaussian / uniform / MF data, plus the end-to-end sweep.
//!
//! The paper's headline: BOUNDEDME is 5–10× faster (flop-wise) than the
//! baselines at high precision. This bench prints the measured
//! flops/speedups that EXPERIMENTS.md quotes.

use bandit_mips::algos::{
    BoundedMeIndex, GreedyMipsIndex, LshMipsIndex, MipsIndex, MipsParams, NaiveIndex,
    PcaMipsIndex,
};
use bandit_mips::benchkit::{Bencher, Reporter};
use bandit_mips::data::synthetic::{gaussian_dataset, uniform_dataset};
use bandit_mips::experiments::precision_speedup::{run_sweep, SweepConfig};

fn main() {
    let b = Bencher::quick();
    let mut r = Reporter::new();
    let n = 1500;
    let dim = 2048;

    for (label, ds) in [
        ("gaussian", gaussian_dataset(n, dim, 1)),
        ("uniform", uniform_dataset(n, dim, 2)),
    ] {
        let q = ds.sample_query(3);
        let p = MipsParams { k: 5, epsilon: 0.05, delta: 0.1, seed: 0 };

        let naive = NaiveIndex::new(ds.vectors.clone());
        let mut naive_flops = 0;
        r.bench(&b, &format!("{label}/naive query"), || {
            let res = naive.query(&q, &p);
            naive_flops = res.flops;
            res.indices[0]
        });

        let bme = BoundedMeIndex::new(ds.vectors.clone());
        let mut flops = 0;
        r.bench(&b, &format!("{label}/bounded_me query eps=0.05"), || {
            let res = bme.query(&q, &p);
            flops = res.flops;
            res.indices[0]
        });
        println!(
            "    flop speedup vs naive: {:.1}x",
            naive_flops as f64 / flops as f64
        );

        let greedy = GreedyMipsIndex::new(ds.vectors.clone(), n / 5);
        r.bench(&b, &format!("{label}/greedy query B=20%"), || {
            greedy.query(&q, &p).flops
        });

        let lsh = LshMipsIndex::new(ds.vectors.clone(), 8, 16, 4);
        r.bench(&b, &format!("{label}/lsh query a=8 b=16"), || lsh.query(&q, &p).flops);

        let pca = PcaMipsIndex::new(ds.vectors.clone(), 4, 5);
        r.bench(&b, &format!("{label}/pca query d=4"), || pca.query(&q, &p).flops);
    }

    // Whole-sweep cost (the figure generator itself).
    let ds = gaussian_dataset(500, 512, 9);
    let cfg = SweepConfig {
        k: 5,
        queries: 4,
        bme_epsilons: vec![0.05, 0.3],
        greedy_budgets: vec![0.25],
        lsh_settings: vec![(6, 8)],
        pca_depths: vec![3],
        ..Default::default()
    };
    r.bench(&b, "fig2/sweep(500x512, 5 points)", || run_sweep(&ds, &cfg, None).len());

    r.finish("fig2 (precision-vs-speedup engine)");
}
