//! Bench P2: the compute hot paths — native dot kernels, pull-batch
//! gathers, and the PJRT artifact vs the native engine.
//!
//! This is the profile target of the performance pass (EXPERIMENTS.md
//! §Perf): per-layer before/after numbers come from here.

use bandit_mips::benchkit::{Bencher, Reporter};
use bandit_mips::linalg::{dot, Matrix, Rng};
use bandit_mips::runtime::{NativeEngine, PjrtEngine, ScoringEngine};
use std::path::Path;

fn main() {
    let b = Bencher::quick();
    let mut r = Reporter::new();
    let mut rng = Rng::new(3);

    // L0: the scalar dot kernel at serving dims.
    for dim in [512usize, 4096, 32768] {
        let a: Vec<f32> = rng.gaussian_vec(dim);
        let q: Vec<f32> = rng.gaussian_vec(dim);
        let m = b.iter(&format!("dot/{dim}"), || dot(&a, &q));
        let gflops = 2.0 * dim as f64 / m.mean / 1e9;
        println!("bench dot/{dim}: {:.2} GFLOP/s", gflops);
        r.push(m);
    }

    // Gather-based pull batch (the Permuted pull order's inner loop) vs
    // dense slab.
    let dim = 4096;
    let data = Matrix::from_fn(256, dim, |_, _| rng.gaussian() as f32);
    let q: Vec<f32> = rng.gaussian_vec(dim);
    {
        use bandit_mips::bandit::{MatrixArms, PullOrder, RewardSource};
        for (order, label) in [
            (PullOrder::Permuted, "gather"),
            (PullOrder::BlockShuffled(64), "block64"),
            (PullOrder::Sequential, "dense"),
        ] {
            let arms = MatrixArms::new(&data, &q, 4.0, order, 1);
            r.bench(&b, &format!("pull_batch/{label} 256x1024"), || {
                let mut s = 0f64;
                for arm in 0..256 {
                    s += arms.pull_range(arm, 0, 1024);
                }
                s as i64
            });
        }
    }

    // Engines: native vs PJRT artifact (exact 256x512 block).
    let dim = 512;
    let block = Matrix::from_fn(256, dim, |_, _| rng.gaussian() as f32);
    let q: Vec<f32> = rng.gaussian_vec(dim);
    let flat = block.as_slice();
    r.bench(&b, "engine/native 256x512", || {
        NativeEngine.score_block(flat, 256, &q).unwrap().len()
    });
    let artifact_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifact_dir.join("exact_b256_d512.hlo.txt").exists() {
        let engine = PjrtEngine::new(artifact_dir.clone(), dim).expect("pjrt engine");
        r.bench(&b, "engine/pjrt copy 256x512", || {
            engine.score_block(flat, 256, &q).unwrap().len()
        });
        // Device-resident dataset: per-query upload is just q.
        let big = Matrix::from_fn(2048, dim, |r, c| ((r * 31 + c) % 17) as f32 * 0.1);
        let resident =
            PjrtEngine::with_dataset(artifact_dir, &big).expect("resident engine");
        r.bench(&b, "engine/pjrt resident 2048x512 (full dataset)", || {
            resident.score_dataset(&big, &q).unwrap().len()
        });
        r.bench(&b, "engine/native 2048x512 (full dataset)", || {
            NativeEngine.score_dataset(&big, &q).unwrap().len()
        });
    } else {
        println!("bench engine/pjrt 256x512: SKIPPED (run `make artifacts`)");
    }

    r.finish("hotpath");
}
