//! Bench P2: the compute hot paths — native dot kernels, pull-batch
//! gathers, the zero-allocation query execution core, and the PJRT
//! artifact vs the native engine.
//!
//! This is the profile target of the performance pass (EXPERIMENTS.md
//! §Perf): per-layer before/after numbers come from here. Results are
//! also written to `BENCH_hotpath.json` (machine-readable, see
//! `benchkit::Reporter::write_json`) so the perf trajectory is tracked
//! across PRs.
//!
//! The `query/*` section is the acceptance gate of the batched
//! execution core: on a 2000×4096 Gaussian dataset, the context-reuse
//! path (`query_with` / `query_batch` on one long-lived `QueryContext`)
//! must be no slower than the legacy per-query path (`query`, fresh
//! scratch every time) **and** must perform fewer heap allocations —
//! measured exactly via a counting global allocator.

use bandit_mips::algos::{BoundedMeIndex, MipsIndex, MipsParams};
use bandit_mips::bandit::PullOrder;
use bandit_mips::benchkit::{Bencher, Reporter};
use bandit_mips::data::synthetic::gaussian_dataset;
use bandit_mips::exec::QueryContext;
use bandit_mips::jsonlite::Json;
use bandit_mips::linalg::{dot, dot_rows, partial_dot_rows, simd, Matrix, Rng};
use bandit_mips::runtime::{NativeEngine, PjrtEngine, ScoringEngine};
use std::alloc::{GlobalAlloc, Layout, System};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Counts every heap allocation (alloc + realloc) so the bench can
/// report allocations-per-query for each execution path.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocations performed by `f`.
fn count_allocs(mut f: impl FnMut()) -> u64 {
    let before = ALLOCS.load(Ordering::Relaxed);
    f();
    ALLOCS.load(Ordering::Relaxed) - before
}

fn main() {
    let b = Bencher::quick();
    let mut r = Reporter::new();
    let mut rng = Rng::new(3);
    let mut extra: Vec<(&'static str, Json)> = Vec::new();

    println!("simd dispatch: {}", simd::active_isa());
    extra.push(("simd_isa", Json::Str(simd::active_isa().to_string())));

    // L0: the dispatched dot kernel at serving dims.
    for dim in [512usize, 4096, 32768] {
        let a: Vec<f32> = rng.gaussian_vec(dim);
        let q: Vec<f32> = rng.gaussian_vec(dim);
        let m = b.iter(&format!("dot/{dim}"), || dot(&a, &q));
        let gflops = 2.0 * dim as f64 / m.mean / 1e9;
        println!("bench dot/{dim}: {:.2} GFLOP/s", gflops);
        r.push(m);
    }

    // L0b: the blocked kernels on a fused-scan shaped block — 256 rows
    // × 4096 dims scored against one query. `dot_loop` is the per-row
    // baseline; `dot_rows/r{R}` calls the blocked kernel on R-row
    // groups (R=1 measures pure dispatch overhead, R≥4 shares query
    // register loads). The acceptance gate of the SIMD subsystem is
    // dot_rows beating dot_loop here.
    {
        let dim = 4096usize;
        let nrows = 256usize;
        let block = Matrix::from_fn(nrows, dim, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(dim);
        let flat = block.as_slice();
        let mut out = vec![0f32; nrows];
        r.bench(&b, "dot_loop/256x4096 (per-row dot)", || {
            for (i, o) in out.iter_mut().enumerate() {
                *o = dot(&flat[i * dim..(i + 1) * dim], &q);
            }
            out[0].to_bits()
        });
        for rchunk in [1usize, 4, 8] {
            r.bench(&b, &format!("dot_rows/r{rchunk} 256x4096"), || {
                let mut i = 0usize;
                while i < nrows {
                    let take = (nrows - i).min(rchunk);
                    dot_rows(
                        &flat[i * dim..(i + take) * dim],
                        dim,
                        &q,
                        &mut out[i..i + take],
                    );
                    i += take;
                }
                out[0].to_bits()
            });
        }
        // One BOUNDEDME pull batch: 8 scattered survivor rows over one
        // 256-coordinate dense run.
        let refs: Vec<&[f32]> = (0..8).map(|i| &block.row(i * 17)[512..768]).collect();
        let sub_q = &q[512..768];
        let mut pout = vec![0f32; 8];
        r.bench(&b, "partial_dot_rows/8x256", || {
            partial_dot_rows(&refs, sub_q, &mut pout);
            pout[0].to_bits()
        });
    }

    // Gather-based pull batch (the Permuted pull order's inner loop) vs
    // dense slab.
    let dim = 4096;
    let data = Matrix::from_fn(256, dim, |_, _| rng.gaussian() as f32);
    let q: Vec<f32> = rng.gaussian_vec(dim);
    {
        use bandit_mips::bandit::{MatrixArms, RewardSource};
        for (order, label) in [
            (PullOrder::Permuted, "gather"),
            (PullOrder::BlockShuffled(64), "block64"),
            (PullOrder::Sequential, "dense"),
        ] {
            let arms = MatrixArms::new(&data, &q, 4.0, order, 1);
            r.bench(&b, &format!("pull_batch/{label} 256x1024"), || {
                let mut s = 0f64;
                for arm in 0..256 {
                    s += arms.pull_range(arm, 0, 1024);
                }
                s as i64
            });
        }
    }

    // L0c: the survivor-compacting panel layout vs scattered pulls —
    // the BOUNDEDME elimination-core memory-layout decision. One
    // 2000×4096 dataset under the serving block-shuffled order;
    // survivor sets at fractions {1.0, 0.25, 0.05} of the rows (strided
    // ids, so scattered reads walk the whole matrix); each iteration is
    // one elimination round's pull batch over a 512-coordinate range.
    // `pull_panel` measures the steady-state panel scan (the one-time
    // compaction gather is amortized over all subsequent rounds, so it
    // is set up outside the timed loop). Acceptance: panel no slower at
    // fraction ≤ 0.25.
    {
        use bandit_mips::bandit::{MatrixArms, PullPanel, RewardSource};
        let nrows = 2000usize;
        let dim = 4096usize;
        let data = Matrix::from_fn(nrows, dim, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(dim);
        let arms = MatrixArms::new(&data, &q, 8.0, PullOrder::BlockShuffled(128), 7);
        let (from, to) = (1024usize, 1536usize);
        for (frac, label) in [(1.0f64, "1.00"), (0.25, "0.25"), (0.05, "0.05")] {
            let keep = ((nrows as f64 * frac) as usize).max(1);
            let stride = nrows / keep;
            let ids: Vec<usize> = (0..keep).map(|i| i * stride).collect();
            let mut out = vec![0f64; keep];
            r.bench(&b, &format!("pull_scatter/f{label} {keep}x512"), || {
                arms.pull_range_batch(&ids, from, to, &mut out);
                out[0].to_bits()
            });
            let mut panel = PullPanel::new();
            arms.compact_into(&ids, from, &mut panel);
            let mut dense = vec![0f64; keep];
            r.bench(&b, &format!("pull_panel/f{label} {keep}x512"), || {
                arms.pull_range_batch_panel(&panel, from, to, &mut dense);
                dense[0].to_bits()
            });
            // The two layouts must agree bit for bit (spot check; the
            // test batteries pin it exhaustively).
            arms.pull_range_batch(&ids, from, to, &mut out);
            arms.pull_range_batch_panel(&panel, from, to, &mut dense);
            assert!(
                out.iter().zip(&dense).all(|(a, b)| a.to_bits() == b.to_bits()),
                "panel/scatter divergence at fraction {label}"
            );
        }
    }

    // L0d: the mixed-precision Storage axis (see `data::quant` /
    // `linalg::simd::wide`). Two shapes per tier, each tagged with
    // `storage` / `bytes_per_coord` / `simd_isa` in the JSON so
    // `scripts/bench_diff.py` can key on (name, storage):
    //  * fused_scan_*  — one blocked scan of a 256×4096 block (the
    //    widening dot_rows kernel vs the f32 baseline);
    //  * pull_panel_*  — one elimination round's pull batch over a
    //    survivor-compacted panel (500 survivors × 512 coords), the
    //    compressed ping-pong buffers vs the f32 panel.
    // Acceptance (ISSUE 6): f16/int8 ≥ 1.7× over f32 on both shapes on
    // hardware with widening loads (F16C/AVX-512); scalar fallbacks are
    // reported but not gated.
    {
        use bandit_mips::bandit::{MatrixArms, PullPanel, QuantArms, RewardSource};
        use bandit_mips::data::quant::{QuantMatrix, Storage};
        use bandit_mips::linalg::simd::wide;

        extra.push((
            "format_isas",
            Json::obj(
                wide::format_isas().into_iter().map(|(f, i)| (f, Json::Str(i.to_string()))),
            ),
        ));
        let tags = |storage: Storage, isa: &str| {
            [
                ("storage", Json::Str(storage.label().into())),
                ("bytes_per_coord", Json::Num(storage.bytes_per_coord() as f64)),
                ("simd_isa", Json::Str(isa.to_string())),
            ]
        };

        // --- fused scans ---
        let dim = 4096usize;
        let nrows = 256usize;
        let block = Matrix::from_fn(nrows, dim, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(dim);
        let mut out = vec![0f32; nrows];
        r.bench_tagged(
            &b,
            "fused_scan_f32 256x4096",
            &tags(Storage::F32, simd::active_isa()),
            || {
                dot_rows(block.as_slice(), dim, &q, &mut out);
                out[0].to_bits()
            },
        );
        {
            let qm = QuantMatrix::quantize(&block, Storage::F16);
            let k = wide::f16_kernels();
            r.bench_tagged(&b, "fused_scan_f16 256x4096", &tags(Storage::F16, k.isa), || {
                (k.dot_rows)(qm.codes_u16(), dim, &q, &mut out);
                out[0].to_bits()
            });
        }
        {
            let qm = QuantMatrix::quantize(&block, Storage::Bf16);
            let k = wide::bf16_kernels();
            r.bench_tagged(&b, "fused_scan_bf16 256x4096", &tags(Storage::Bf16, k.isa), || {
                (k.dot_rows)(qm.codes_u16(), dim, &q, &mut out);
                out[0].to_bits()
            });
        }
        {
            let qm = QuantMatrix::quantize(&block, Storage::Int8);
            let k = wide::int8_kernels();
            let scales = qm.scales().to_vec();
            r.bench_tagged(&b, "fused_scan_int8 256x4096", &tags(Storage::Int8, k.isa), || {
                (k.dot_rows)(qm.codes_i8(), dim, &q, &mut out);
                // int8 dot_rows yields raw code sums; one multiply per
                // row applies the per-row scale (part of the tier's
                // real cost, so it stays inside the timed loop).
                for (o, &s) in out.iter_mut().zip(&scales) {
                    *o *= s;
                }
                out[0].to_bits()
            });
        }

        // --- survivor-panel pulls ---
        let nrows = 2000usize;
        let data = Matrix::from_fn(nrows, dim, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(dim);
        let order = PullOrder::BlockShuffled(128);
        let (from, to) = (1024usize, 1536usize);
        let keep = 500usize;
        let ids: Vec<usize> = (0..keep).map(|i| i * (nrows / keep)).collect();
        let mut dense = vec![0f64; keep];
        {
            let arms = MatrixArms::new(&data, &q, 8.0, order, 7);
            let mut panel = PullPanel::new();
            arms.compact_into(&ids, from, &mut panel);
            r.bench_tagged(
                &b,
                "pull_panel_f32 500x512",
                &tags(Storage::F32, simd::active_isa()),
                || {
                    arms.pull_range_batch_panel(&panel, from, to, &mut dense);
                    dense[0].to_bits()
                },
            );
        }
        for storage in [Storage::F16, Storage::Int8] {
            let qm = QuantMatrix::quantize(&data, storage);
            let arms = QuantArms::new(&qm, &q, 8.0, order, 7);
            let mut panel = PullPanel::new();
            arms.compact_into(&ids, from, &mut panel);
            let isa = match storage {
                Storage::F16 => wide::f16_kernels().isa,
                _ => wide::int8_kernels().isa,
            };
            let name = format!("pull_panel_{} 500x512", storage.label());
            r.bench_tagged(&b, &name, &tags(storage, isa), || {
                arms.pull_range_batch_panel(&panel, from, to, &mut dense);
                dense[0].to_bits()
            });
        }
    }

    // The query execution core on the acceptance dataset: 2000×4096
    // Gaussian, k=5, serving-default block order. Three paths answer
    // the same queries:
    //  * per-query  — legacy `query`: fresh scratch allocated per call;
    //  * ctx-reuse  — `query_with` on one long-lived QueryContext;
    //  * batch      — `query_batch` over 16 queries sharing one
    //                 permutation.
    {
        let ds = gaussian_dataset(2000, 4096, 42);
        let index =
            BoundedMeIndex::with_order(ds.vectors.clone(), PullOrder::BlockShuffled(128));
        let params = MipsParams { k: 5, epsilon: 0.05, delta: 0.1, seed: 9 };
        let queries: Vec<Vec<f32>> = (0..16).map(|s| ds.sample_query(s)).collect();
        let refs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();

        let mut qi = 0usize;
        r.bench(&b, "query/per_query 2000x4096", || {
            qi = (qi + 1) % queries.len();
            index.query(&refs[qi], &params).flops
        });

        let mut ctx = QueryContext::new();
        // Warm the context so steady state is measured.
        let _ = index.query_with(&refs[0], &params, &mut ctx);
        let mut qi = 0usize;
        r.bench(&b, "query/ctx_reuse 2000x4096", || {
            qi = (qi + 1) % queries.len();
            index.query_with(&refs[qi], &params, &mut ctx).flops
        });

        // The same path with the flight recorder armed (what the
        // coordinator does under `RUST_PALLAS_TRACE=1`): per-round wall
        // clocks plus one QueryExec record per query. This row keeps
        // the tracing tax visible on the bench trajectory
        // (`scripts/bench_diff.py` diffs it against `query/ctx_reuse`).
        let mut qi = 0usize;
        r.bench(&b, "query/ctx_reuse_traced 2000x4096", || {
            qi = (qi + 1) % queries.len();
            ctx.trace.arm();
            let flops = index.query_with(&refs[qi], &params, &mut ctx).flops;
            std::hint::black_box(ctx.trace.finish());
            flops
        });

        // Each iteration runs the whole 16-query batch; scale the
        // measurement down so the row is per-query comparable with the
        // two rows above.
        let mut m = b.iter("query/batch16 2000x4096 (per query)", || {
            let res = index.query_batch(&refs, &params, &mut ctx);
            res.len()
        });
        let nq = refs.len() as f64;
        m.mean /= nq;
        m.std /= nq;
        m.min /= nq;
        m.median /= nq;
        r.push(m);

        // Allocation accounting over a fixed 32-query loop per path.
        const LOOPS: usize = 32;
        let fresh_allocs = count_allocs(|| {
            for i in 0..LOOPS {
                std::hint::black_box(index.query(&refs[i % refs.len()], &params));
            }
        });
        let reuse_allocs = count_allocs(|| {
            for i in 0..LOOPS {
                std::hint::black_box(index.query_with(
                    &refs[i % refs.len()],
                    &params,
                    &mut ctx,
                ));
            }
        });
        let batch_allocs = count_allocs(|| {
            std::hint::black_box(index.query_batch(&refs, &params, &mut ctx));
            std::hint::black_box(index.query_batch(&refs, &params, &mut ctx));
        });
        // Tracing accounting: armed, each query records a QueryExec
        // plus its round vector (reported, not gated); disarmed — the
        // serving default — must add exactly zero allocations over the
        // plain ctx-reuse loop (the ISSUE 8 acceptance gate). The
        // disarmed loop runs *after* the armed one so any lazily grown
        // trace scratch is already warm and can't mask a leak.
        let traced_allocs = count_allocs(|| {
            for i in 0..LOOPS {
                ctx.trace.arm();
                std::hint::black_box(index.query_with(
                    &refs[i % refs.len()],
                    &params,
                    &mut ctx,
                ));
                std::hint::black_box(ctx.trace.finish());
            }
        });
        let disarmed_allocs = count_allocs(|| {
            for i in 0..LOOPS {
                std::hint::black_box(index.query_with(
                    &refs[i % refs.len()],
                    &params,
                    &mut ctx,
                ));
            }
        });
        let per = |a: u64, n: usize| a as f64 / n as f64;
        println!(
            "allocs/query: per_query {:.1}, ctx_reuse {:.1}, batch16 {:.1}, \
             traced {:.1}, trace_disarmed {:.1}",
            per(fresh_allocs, LOOPS),
            per(reuse_allocs, LOOPS),
            per(batch_allocs, 2 * refs.len()),
            per(traced_allocs, LOOPS),
            per(disarmed_allocs, LOOPS),
        );
        assert!(
            reuse_allocs < fresh_allocs,
            "context reuse must allocate less: {reuse_allocs} vs {fresh_allocs}"
        );
        assert_eq!(
            disarmed_allocs, reuse_allocs,
            "disabled tracing must be allocation-free on the hot path"
        );
        extra.push(("allocs_per_query_fresh", Json::Num(per(fresh_allocs, LOOPS))));
        extra.push(("allocs_per_query_ctx_reuse", Json::Num(per(reuse_allocs, LOOPS))));
        extra.push(("allocs_per_query_batch16", Json::Num(per(batch_allocs, 2 * refs.len()))));
        extra.push(("allocs_per_query_traced", Json::Num(per(traced_allocs, LOOPS))));
        extra.push((
            "allocs_per_query_trace_disarmed",
            Json::Num(per(disarmed_allocs, LOOPS)),
        ));
        extra.push(("ctx_grow_events", Json::Num(ctx.grow_events() as f64)));
        extra.push(("ctx_panel_grow_events", Json::Num(ctx.panel_grow_events() as f64)));
    }

    // Engines: native vs PJRT artifact (exact 256x512 block).
    let dim = 512;
    let block = Matrix::from_fn(256, dim, |_, _| rng.gaussian() as f32);
    let q: Vec<f32> = rng.gaussian_vec(dim);
    let flat = block.as_slice();
    r.bench(&b, "engine/native 256x512", || {
        NativeEngine.score_block(flat, 256, &q).unwrap().len()
    });
    // Fused multi-query scoring (the coordinator's one-call-per-batch
    // exact path) vs query-at-a-time.
    {
        let qs: Vec<Vec<f32>> = (0..8).map(|_| rng.gaussian_vec(dim)).collect();
        let qrefs: Vec<&[f32]> = qs.iter().map(|v| v.as_slice()).collect();
        let mut slab = Vec::new();
        r.bench(&b, "engine/native fused 8q x 256x512", || {
            NativeEngine.score_batch_into(flat, 256, dim, &qrefs, &mut slab).unwrap();
            slab.len()
        });
        r.bench(&b, "engine/native looped 8q x 256x512", || {
            let mut n = 0;
            for q in &qrefs {
                n += NativeEngine.score_block(flat, 256, q).unwrap().len();
            }
            n
        });
    }
    let artifact_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if cfg!(feature = "pjrt") && artifact_dir.join("exact_b256_d512.hlo.txt").exists() {
        let engine = PjrtEngine::new(artifact_dir.clone(), dim).expect("pjrt engine");
        r.bench(&b, "engine/pjrt copy 256x512", || {
            engine.score_block(flat, 256, &q).unwrap().len()
        });
        // Device-resident dataset: per-query upload is just q.
        let big = Matrix::from_fn(2048, dim, |r, c| ((r * 31 + c) % 17) as f32 * 0.1);
        let resident =
            PjrtEngine::with_dataset(artifact_dir, &big).expect("resident engine");
        r.bench(&b, "engine/pjrt resident 2048x512 (full dataset)", || {
            resident.score_dataset(&big, &q).unwrap().len()
        });
        r.bench(&b, "engine/native 2048x512 (full dataset)", || {
            NativeEngine.score_dataset(&big, &q).unwrap().len()
        });
    } else {
        println!(
            "bench engine/pjrt 256x512: SKIPPED ({})",
            if cfg!(feature = "pjrt") {
                "run `make artifacts`"
            } else {
                "needs the `pjrt` feature plus a manually added `xla` dependency"
            }
        );
    }

    r.finish("hotpath");
    r.write_json("hotpath", "BENCH_hotpath.json", &extra);
}
