//! Bench: Figure-1 pipeline — BOUNDEDME sample complexity on the
//! adversarial environment across (ε, δ). Regenerates the paper's
//! Figure 1 data and times one full guarantee-validation sweep.

use bandit_mips::bandit::{AdversarialArms, BoundedMe, BoundedMeConfig};
use bandit_mips::benchkit::{Bencher, Reporter};
use bandit_mips::experiments::fig1::{per_epsilon, run, Fig1Config};

fn main() {
    let b = Bencher::quick();
    let mut r = Reporter::new();

    // Per-(ε, δ) single-run cost on the adversarial environment.
    for (eps, delta) in [(0.6, 0.3), (0.3, 0.1), (0.1, 0.05), (0.05, 0.01)] {
        let env = AdversarialArms::generate(1000, 2000, 42);
        let algo = BoundedMe::new(BoundedMeConfig { k: 1, epsilon: eps, delta });
        let mut pulls = 0u64;
        r.bench(&b, &format!("fig1/bounded_me eps={eps} delta={delta}"), || {
            let out = algo.run(&env);
            pulls = out.result.total_pulls;
            out.result.arms[0]
        });
        println!(
            "    pulls = {pulls} ({:.1}% of exhaustive), subopt(best run) recorded in example",
            100.0 * pulls as f64 / (1000.0 * 2000.0)
        );
    }

    // One complete (reduced) Figure-1 sweep, validated.
    let cfg = Fig1Config {
        n_arms: 300,
        n_list: 600,
        epsilons: vec![0.1, 0.3, 0.6],
        deltas: vec![0.05, 0.2],
        trials: 8,
        seed: 1,
    };
    let mut holds = true;
    r.bench(&b, "fig1/full_sweep(300x600, 6 points, 8 trials)", || {
        let pts = run(&cfg);
        holds = per_epsilon(&pts).iter().all(|&(_, _, h)| h);
        pts.len()
    });
    println!("    guarantee holds across sweep: {holds}");
    assert!(holds, "Figure 1 guarantee violated in bench run");

    r.finish("fig1");
}
