//! Bench P1: the serving coordinator under closed-loop load — batcher
//! and queue overhead, worker scaling, exact vs BOUNDEDME modes.

use bandit_mips::benchkit::{Bencher, Reporter};
use bandit_mips::coordinator::{
    Backend, Coordinator, CoordinatorConfig, QueryRequest,
};
use bandit_mips::data::shard::ShardSpec;
use bandit_mips::data::synthetic::gaussian_dataset;
use bandit_mips::jsonlite::Json;
use std::time::Duration;

fn run_load(coord: &Coordinator, queries: usize, q: &[f32]) -> f64 {
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::with_capacity(queries);
    for i in 0..queries {
        let req = QueryRequest {
            vector: q.to_vec(),
            k: 5,
            epsilon: 0.05,
            delta: 0.1,
            mode: bandit_mips::coordinator::QueryMode::BoundedMe,
            seed: i as u64,
            deadline: None,
        };
        rxs.push(coord.submit(req).expect("submit"));
    }
    for rx in rxs {
        rx.recv().expect("recv");
    }
    queries as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let b = Bencher::new(Duration::from_millis(100), Duration::from_secs(1));
    let mut r = Reporter::new();
    let ds = gaussian_dataset(1000, 1024, 31);
    let q = ds.sample_query(1);
    let mut load_points: Vec<Json> = Vec::new();

    for workers in [1usize, 2, 4] {
        let coord = Coordinator::new(
            ds.vectors.clone(),
            CoordinatorConfig {
                workers,
                max_batch: 32,
                batch_timeout: Duration::from_micros(500),
                queue_capacity: 4096,
                backend: Backend::Native,
                ..Default::default()
            },
        )
        .unwrap();
        let mut qps = 0.0;
        r.bench(&b, &format!("serving/closed_loop workers={workers} (100q)"), || {
            qps = run_load(&coord, 100, &q);
            qps as u64
        });
        let m = coord.metrics();
        println!(
            "    ~{qps:.0} qps; mean batch {:.1}; service p50 {:.3} ms; queue p99 {:.3} ms",
            m.mean_batch_size,
            m.service.0 * 1e3,
            m.queue_wait.2 * 1e3
        );
        load_points.push(Json::obj([
            ("workers", Json::Num(workers as f64)),
            ("qps", Json::Num(qps)),
            ("mean_batch_size", Json::Num(m.mean_batch_size)),
            ("service_p50_s", Json::Num(m.service.0)),
            ("queue_p99_s", Json::Num(m.queue_wait.2)),
        ]));
        coord.shutdown();
    }

    // Sharded scenario: the same dataset split S ways across a fixed
    // 4-worker pool — measures fan-out + merge overhead vs the
    // smaller per-shard scans. Shard count is emitted per point.
    let mut shard_points: Vec<Json> = Vec::new();
    for shards in [1usize, 2, 4] {
        let coord = Coordinator::new(
            ds.vectors.clone(),
            CoordinatorConfig {
                workers: 4,
                max_batch: 32,
                batch_timeout: Duration::from_micros(500),
                queue_capacity: 4096,
                backend: Backend::Native,
                shard: ShardSpec::contiguous(shards),
                ..Default::default()
            },
        )
        .unwrap();
        let mut qps = 0.0;
        r.bench(&b, &format!("serving/sharded shards={shards} (100q)"), || {
            qps = run_load(&coord, 100, &q);
            qps as u64
        });
        let m = coord.metrics();
        println!(
            "    ~{qps:.0} qps; mean batch {:.1}; service p50 {:.3} ms",
            m.mean_batch_size,
            m.service.0 * 1e3
        );
        shard_points.push(Json::obj([
            ("shards", Json::Num(shards as f64)),
            ("workers", Json::Num(4.0)),
            ("qps", Json::Num(qps)),
            ("mean_batch_size", Json::Num(m.mean_batch_size)),
            ("service_p50_s", Json::Num(m.service.0)),
            ("queue_p99_s", Json::Num(m.queue_wait.2)),
        ]));
        coord.shutdown();
    }

    // Coordinator overhead: single trivial exact query on a tiny dataset
    // (upper-bounds router+batcher+channel cost per request).
    let tiny = gaussian_dataset(8, 16, 5);
    let coord = Coordinator::new(
        tiny.vectors.clone(),
        CoordinatorConfig {
            workers: 1,
            max_batch: 1,
            batch_timeout: Duration::from_micros(1),
            queue_capacity: 64,
            backend: Backend::Native,
            ..Default::default()
        },
    )
    .unwrap();
    let tq = tiny.sample_query(1);
    r.bench(&b, "serving/per_request_overhead (8x16 exact)", || {
        coord
            .query_blocking(QueryRequest::exact(tq.clone(), 1))
            .unwrap()
            .indices[0]
    });
    coord.shutdown();

    r.finish("serving coordinator");
    r.write_json(
        "serving",
        "BENCH_serving.json",
        &[
            ("closed_loop", Json::Arr(load_points)),
            ("sharded", Json::Arr(shard_points)),
        ],
    );
}
