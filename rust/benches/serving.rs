//! Bench P1: the serving coordinator under closed-loop load — batcher
//! and queue overhead, worker scaling, sharded fan-out, straggler
//! hedging, the S = 1 fast path vs the reactor merge path
//! (`per_request_overhead` vs `per_request_overhead_reactor`), and the
//! wire codecs (`wire_json` vs `wire_binary`: decode-only cost per
//! request plus client-observed end-to-end latency over TCP). Binary
//! decode is additionally gated by a counting global allocator — the
//! steady state must be allocation-free.

use bandit_mips::benchkit::{Bencher, Measurement, Reporter};
use bandit_mips::coordinator::server::{Client, Server};
use bandit_mips::coordinator::{
    Backend, Coordinator, CoordinatorConfig, QueryRequest,
};
use bandit_mips::data::generation::Delta;
use bandit_mips::data::shard::ShardSpec;
use bandit_mips::data::synthetic::gaussian_dataset;
use bandit_mips::exec::DegradePolicy;
use bandit_mips::jsonlite::{parse, Json};
use bandit_mips::linalg::{simd, Rng};
use bandit_mips::wire::frame::FrameDecoder;
use bandit_mips::wire::{binary, QueryOpts};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Counts every heap allocation so the `wire_binary` decode rows can
/// prove their steady state is allocation-free (mirrors the hotpath
/// bench's gate).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocations performed by `f`.
fn count_allocs(mut f: impl FnMut()) -> u64 {
    let before = ALLOCS.load(Ordering::Relaxed);
    f();
    ALLOCS.load(Ordering::Relaxed) - before
}

/// Per-shard counter breakdown (mirrors the `metrics_prom` exposition)
/// as a JSON array, so bench-trajectory diffs can attribute a hedging
/// or churn regression to the shard that caused it.
fn shard_breakdown(m: &bandit_mips::coordinator::MetricsSnapshot) -> Json {
    Json::Arr(
        m.shards
            .iter()
            .map(|s| {
                Json::obj([
                    ("shard", Json::Num(s.shard as f64)),
                    ("dispatches", Json::Num(s.dispatches as f64)),
                    ("hedges_fired", Json::Num(s.hedges_fired as f64)),
                    ("hedges_won", Json::Num(s.hedges_won as f64)),
                    ("merges", Json::Num(s.merges as f64)),
                    ("mean_merge_s", Json::Num(s.mean_merge_s)),
                    ("queue_depth", Json::Num(s.queue_depth as f64)),
                ])
            })
            .collect(),
    )
}

fn run_load(coord: &Coordinator, queries: usize, q: &[f32]) -> f64 {
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::with_capacity(queries);
    for i in 0..queries {
        let req = QueryRequest {
            vector: q.to_vec(),
            k: 5,
            epsilon: 0.05,
            delta: 0.1,
            mode: bandit_mips::coordinator::QueryMode::BoundedMe,
            seed: i as u64,
            deadline: None,
            budget_flops: None,
            storage: None,
            decode_ns: 0,
        };
        rxs.push(coord.submit(req).expect("submit"));
    }
    for rx in rxs {
        rx.recv().expect("recv");
    }
    queries as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let b = Bencher::new(Duration::from_millis(100), Duration::from_secs(1));
    let mut r = Reporter::new();
    let ds = gaussian_dataset(1000, 1024, 31);
    let q = ds.sample_query(1);
    let mut load_points: Vec<Json> = Vec::new();

    for workers in [1usize, 2, 4] {
        let coord = Coordinator::new(
            ds.vectors.clone(),
            CoordinatorConfig {
                workers,
                max_batch: 32,
                batch_timeout: Duration::from_micros(500),
                queue_capacity: 4096,
                backend: Backend::Native,
                ..Default::default()
            },
        )
        .unwrap();
        let mut qps = 0.0;
        r.bench(&b, &format!("serving/closed_loop workers={workers} (100q)"), || {
            qps = run_load(&coord, 100, &q);
            qps as u64
        });
        let m = coord.metrics();
        println!(
            "    ~{qps:.0} qps; mean batch {:.1}; service p50 {:.3} ms; queue p99 {:.3} ms",
            m.mean_batch_size,
            m.service.0 * 1e3,
            m.queue_wait.2 * 1e3
        );
        load_points.push(Json::obj([
            ("workers", Json::Num(workers as f64)),
            ("qps", Json::Num(qps)),
            ("mean_batch_size", Json::Num(m.mean_batch_size)),
            ("service_p50_s", Json::Num(m.service.0)),
            ("queue_p99_s", Json::Num(m.queue_wait.2)),
        ]));
        coord.shutdown();
    }

    // Sharded scenario: the same dataset split S ways across a fixed
    // 4-worker pool — measures fan-out + merge overhead vs the
    // smaller per-shard scans. Shard count is emitted per point.
    let mut shard_points: Vec<Json> = Vec::new();
    for shards in [1usize, 2, 4] {
        let coord = Coordinator::new(
            ds.vectors.clone(),
            CoordinatorConfig {
                workers: 4,
                max_batch: 32,
                batch_timeout: Duration::from_micros(500),
                queue_capacity: 4096,
                backend: Backend::Native,
                shard: ShardSpec::contiguous(shards),
                ..Default::default()
            },
        )
        .unwrap();
        let mut qps = 0.0;
        r.bench(&b, &format!("serving/sharded shards={shards} (100q)"), || {
            qps = run_load(&coord, 100, &q);
            qps as u64
        });
        let m = coord.metrics();
        println!(
            "    ~{qps:.0} qps; mean batch {:.1}; service p50 {:.3} ms",
            m.mean_batch_size,
            m.service.0 * 1e3
        );
        shard_points.push(Json::obj([
            ("shards", Json::Num(shards as f64)),
            ("workers", Json::Num(4.0)),
            ("qps", Json::Num(qps)),
            ("mean_batch_size", Json::Num(m.mean_batch_size)),
            ("service_p50_s", Json::Num(m.service.0)),
            ("queue_p99_s", Json::Num(m.queue_wait.2)),
        ]));
        coord.shutdown();
    }

    // Straggler hedging: shard 0 artificially slow (3ms per primary
    // batch, the debug straggler knob); hedging off vs on. The hedged
    // run's p-worst service should sit near the healthy shard's
    // latency instead of the straggler's.
    let hds = gaussian_dataset(600, 256, 77);
    let hq = hds.sample_query(3);
    let mut hedge_points: Vec<Json> = Vec::new();
    for hedge_us in [0u64, 300] {
        let mut hcfg = CoordinatorConfig {
            workers: 4,
            max_batch: 8,
            batch_timeout: Duration::from_micros(200),
            queue_capacity: 4096,
            backend: Backend::Native,
            shard: ShardSpec::contiguous(2),
            ..Default::default()
        };
        hcfg.debug_slow_shard = Some((0, Duration::from_millis(3)));
        if hedge_us > 0 {
            hcfg.hedge_delay = Some(Duration::from_micros(hedge_us));
        }
        let coord = Coordinator::new(hds.vectors.clone(), hcfg).unwrap();
        let mut qps = 0.0;
        let label = if hedge_us == 0 { "off".to_string() } else { format!("{hedge_us}us") };
        r.bench(&b, &format!("serving/hedging hedge={label} slow_shard=3ms (30q)"), || {
            qps = run_load(&coord, 30, &hq);
            qps as u64
        });
        let m = coord.metrics();
        println!(
            "    ~{qps:.0} qps; service p50 {:.3} ms p99 {:.3} ms; hedges fired {} won {}",
            m.service.0 * 1e3,
            m.service.2 * 1e3,
            m.hedge_fired,
            m.hedge_won
        );
        for s in &m.shards {
            println!(
                "      shard {}: {} dispatches, {} hedges fired / {} won, merge mean {:.3} ms",
                s.shard,
                s.dispatches,
                s.hedges_fired,
                s.hedges_won,
                s.mean_merge_s * 1e3
            );
        }
        hedge_points.push(Json::obj([
            ("hedge_us", Json::Num(hedge_us as f64)),
            ("qps", Json::Num(qps)),
            ("service_p50_s", Json::Num(m.service.0)),
            ("service_p99_s", Json::Num(m.service.2)),
            ("hedge_fired", Json::Num(m.hedge_fired as f64)),
            ("hedge_won", Json::Num(m.hedge_won as f64)),
            ("shard_breakdown", shard_breakdown(&m)),
        ]));
        coord.shutdown();
    }

    // Coordinator overhead: single trivial exact query on a tiny dataset
    // (upper-bounds batcher+channel cost per request). Two rows: the
    // default S = 1 fast path (worker → client directly) and the same
    // traffic forced through the reactor merge path — the difference is
    // the per-request cost the fast path removes.
    let tiny = gaussian_dataset(8, 16, 5);
    let tq = tiny.sample_query(1);
    let mut fast_path_served = 0u64;
    for force_reactor in [false, true] {
        let mut ocfg = CoordinatorConfig {
            workers: 1,
            max_batch: 1,
            batch_timeout: Duration::from_micros(1),
            queue_capacity: 64,
            backend: Backend::Native,
            ..Default::default()
        };
        ocfg.force_reactor = force_reactor;
        let coord = Coordinator::new(tiny.vectors.clone(), ocfg).unwrap();
        let name = if force_reactor {
            "serving/per_request_overhead_reactor (8x16 exact)"
        } else {
            "serving/per_request_overhead (8x16 exact)"
        };
        r.bench(&b, name, || {
            coord
                .query_blocking(QueryRequest::exact(tq.clone(), 1))
                .unwrap()
                .indices[0]
        });
        if !force_reactor {
            fast_path_served = coord.metrics().fast_path;
        }
        coord.shutdown();
    }

    // Live-mutation churn: the same closed-loop load with a writer
    // thread streaming upsert batches at a fixed fraction of the
    // dataset per second (0%, 1%, 10% of rows/s). Each batch builds a
    // COW generation and flips it under the readers, so this row
    // tracks how much query latency the flip protocol costs — the 0%
    // row is the no-churn control, and `generations_alive` at the end
    // proves retired generations were reclaimed, not leaked.
    let mut churn_points: Vec<Json> = Vec::new();
    for shards in [1usize, 4] {
        for churn_pct in [0u64, 1, 10] {
            let coord = Arc::new(
                Coordinator::new(
                    ds.vectors.clone(),
                    CoordinatorConfig {
                        workers: 4,
                        max_batch: 32,
                        batch_timeout: Duration::from_micros(500),
                        queue_capacity: 4096,
                        backend: Backend::Native,
                        shard: ShardSpec::contiguous(shards),
                        ..Default::default()
                    },
                )
                .unwrap(),
            );
            let stop = Arc::new(AtomicBool::new(false));
            let writer = if churn_pct > 0 {
                let wc = Arc::clone(&coord);
                let wstop = Arc::clone(&stop);
                let rows = ds.vectors.rows();
                let dim = ds.vectors.cols();
                Some(std::thread::spawn(move || {
                    // churn_pct% of rows per second, paced in small
                    // batches so 1% still flips several times per
                    // bench window instead of once a second.
                    let rows_per_sec = rows as u64 * churn_pct / 100;
                    let batch = (rows_per_sec as usize / 50).max(1);
                    let interval =
                        Duration::from_secs_f64(batch as f64 / rows_per_sec as f64);
                    let mut rng = Rng::new(0xC0C0_0000 ^ churn_pct);
                    while !wstop.load(Ordering::Relaxed) {
                        let deltas: Vec<Delta> = (0..batch)
                            .map(|_| Delta::Upsert {
                                id: rng.next_below(rows),
                                vector: rng.gaussian_vec(dim),
                            })
                            .collect();
                        if wc.mutate(&deltas).is_err() {
                            break;
                        }
                        std::thread::sleep(interval);
                    }
                }))
            } else {
                None
            };
            let mut qps = 0.0;
            r.bench_tagged(
                &b,
                &format!("serving/churn upsert={churn_pct}%rows/s shards={shards} (100q)"),
                &[
                    ("churn", Json::Str(format!("{churn_pct}%"))),
                    ("shards", Json::Num(shards as f64)),
                ],
                || {
                    qps = run_load(&coord, 100, &q);
                    qps as u64
                },
            );
            stop.store(true, Ordering::Relaxed);
            if let Some(w) = writer {
                w.join().unwrap();
            }
            let m = coord.metrics();
            let alive = coord.generations_alive();
            println!(
                "    ~{qps:.0} qps; service p50 {:.3} ms p99 {:.3} ms; {} flips; {} generations alive",
                m.service.0 * 1e3,
                m.service.2 * 1e3,
                m.mutations,
                alive
            );
            churn_points.push(Json::obj([
                ("shards", Json::Num(shards as f64)),
                ("churn_pct_rows_per_s", Json::Num(churn_pct as f64)),
                ("qps", Json::Num(qps)),
                ("service_p50_s", Json::Num(m.service.0)),
                ("service_p99_s", Json::Num(m.service.2)),
                ("mutations", Json::Num(m.mutations as f64)),
                ("mutation_rows", Json::Num(m.mutation_rows as f64)),
                ("generations_alive", Json::Num(alive as f64)),
                ("shard_breakdown", shard_breakdown(&m)),
            ]));
            if let Ok(c) = Arc::try_unwrap(coord) {
                c.shutdown();
            }
        }
    }

    // Overload sweep (harvest-not-shed): open-loop arrivals at a
    // multiple of the measured closed-loop capacity, every query
    // carrying a soft deadline. The shed-only baseline answers a
    // shrinking fraction within the deadline as load grows; the
    // anytime configuration harvests checkpointed elimination rounds
    // at the deadline instead of shedding or running to completion, so
    // its answered-within-deadline fraction should sit strictly above
    // the baseline at ≥ 2× capacity. A reply counts as answered when
    // it is not shed and its pipeline time (queue wait + service)
    // lands inside 1.5× the deadline — the slack absorbs the one-round
    // overshoot a harvest at a round boundary is allowed.
    let ods = gaussian_dataset(1000, 256, 41);
    let oq = ods.sample_query(5);
    let ocfg = |harvest: bool, degrade| CoordinatorConfig {
        workers: 2,
        max_batch: 16,
        batch_timeout: Duration::from_micros(200),
        queue_capacity: 16384,
        backend: Backend::Native,
        harvest,
        degrade,
        ..Default::default()
    };
    let cap_coord = Coordinator::new(ods.vectors.clone(), ocfg(true, None)).unwrap();
    run_load(&cap_coord, 50, &oq); // warm the pipeline
    let capacity_qps = run_load(&cap_coord, 200, &oq);
    let service_p50 = cap_coord.metrics().service.0;
    cap_coord.shutdown();
    // Deadline: a few median service times, so the mid-run budget has
    // rounds to cut under pressure (floored for scheduler jitter).
    let deadline = Duration::from_secs_f64((service_p50 * 4.0).max(0.002));
    println!(
        "  overload sweep: capacity ~{capacity_qps:.0} qps, deadline {:.2} ms",
        deadline.as_secs_f64() * 1e3
    );
    let mut overload_points: Vec<Json> = Vec::new();
    for (mode_label, harvest, degrade) in [
        ("shed_only", false, None),
        ("harvest", true, None),
        ("harvest_admit", true, Some(DegradePolicy::default())),
    ] {
        for mult in [1.0f64, 2.0, 4.0] {
            let coord = Coordinator::new(ods.vectors.clone(), ocfg(harvest, degrade)).unwrap();
            let rate = capacity_qps * mult;
            let window = Duration::from_millis(600);
            let interval = Duration::from_secs_f64(1.0 / rate);
            let t0 = Instant::now();
            let mut rxs = Vec::with_capacity((rate * 0.7) as usize);
            let mut dropped = 0u64;
            let mut i = 0u64;
            loop {
                let target = t0 + interval.mul_f64(i as f64);
                if target >= t0 + window {
                    break;
                }
                while Instant::now() < target {
                    std::hint::spin_loop();
                }
                let req = QueryRequest {
                    vector: oq.to_vec(),
                    k: 5,
                    epsilon: 0.05,
                    delta: 0.1,
                    mode: bandit_mips::coordinator::QueryMode::BoundedMe,
                    seed: i,
                    deadline: Some(deadline),
                    budget_flops: None,
                    storage: None,
                    decode_ns: 0,
                };
                match coord.submit(req) {
                    Ok(rx) => rxs.push(rx),
                    Err(_) => dropped += 1, // queue full: counts against answered
                }
                i += 1;
            }
            let submitted = (rxs.len() as u64) + dropped;
            let grace = deadline.mul_f64(1.5);
            let (mut answered, mut sheds, mut degraded_ct) = (0u64, 0u64, 0u64);
            let mut eps_hat_sum = 0.0f64;
            for rx in rxs {
                let resp = rx.recv().expect("recv");
                if resp.shed {
                    sheds += 1;
                    continue;
                }
                if resp.queue_wait + resp.service <= grace {
                    answered += 1;
                }
                if resp.degraded {
                    degraded_ct += 1;
                    eps_hat_sum += resp.epsilon_hat;
                }
            }
            let answered_frac = answered as f64 / submitted as f64;
            let mean_eps_hat = if degraded_ct > 0 {
                eps_hat_sum / degraded_ct as f64
            } else {
                0.0
            };
            println!(
                "    overload {mode_label} load={mult}x: answered {:.1}% shed {:.1}% degraded {:.1}% (mean eps_hat {:.4}, {} dropped)",
                answered_frac * 1e2,
                sheds as f64 / submitted as f64 * 1e2,
                degraded_ct as f64 / submitted as f64 * 1e2,
                mean_eps_hat,
                dropped
            );
            // Rows keyed by (name, offered_load) so bench_diff can
            // track answered-within-deadline per load point; `mean` is
            // the answered fraction (higher is better).
            r.push(Measurement {
                name: format!("serving/overload {mode_label} load={mult}x"),
                iters: submitted,
                mean: answered_frac,
                std: 0.0,
                min: answered_frac,
                median: answered_frac,
                tags: vec![
                    ("offered_load", Json::Num(mult)),
                    ("harvest", Json::Str(mode_label.into())),
                    ("answered_within_deadline", Json::Num(answered_frac)),
                ],
            });
            overload_points.push(Json::obj([
                ("mode", Json::Str(mode_label.into())),
                ("offered_load_x", Json::Num(mult)),
                ("capacity_qps", Json::Num(capacity_qps)),
                ("deadline_ms", Json::Num(deadline.as_secs_f64() * 1e3)),
                ("submitted", Json::Num(submitted as f64)),
                ("dropped", Json::Num(dropped as f64)),
                ("answered_within_deadline_frac", Json::Num(answered_frac)),
                ("shed_frac", Json::Num(sheds as f64 / submitted as f64)),
                ("degraded_frac", Json::Num(degraded_ct as f64 / submitted as f64)),
                ("mean_epsilon_hat", Json::Num(mean_eps_hat)),
            ]));
            coord.shutdown();
        }
    }

    // Wire codecs, decode only: what each protocol charges to turn raw
    // socket bytes into a submittable query — line-JSON pays a full
    // parse plus numeric vector extraction, binary pays a frame scan
    // plus one bulk LE-f32 conversion into a reused buffer. The binary
    // path's steady state is asserted allocation-free, and at d = 4096
    // it must beat JSON by at least 5× (the point of the codec).
    let mut wire_decode_points: Vec<Json> = Vec::new();
    for dim in [128usize, 4096] {
        let vec: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.37).sin()).collect();

        let line = Json::obj([
            ("op", Json::Str("query".into())),
            ("vector", Json::f32s(&vec)),
            ("k", Json::Num(5.0)),
            ("epsilon", Json::Num(0.05)),
            ("delta", Json::Num(0.1)),
        ])
        .dump();
        r.bench_tagged(
            &b,
            &format!("wire_json/decode d={dim}"),
            &[("codec", Json::Str("json".into())), ("dim", Json::Num(dim as f64))],
            || {
                let doc = parse(&line).expect("bench line parses");
                doc.get("vector").unwrap().as_f32_vec().unwrap().len()
            },
        );
        let json_mean = r.rows().last().unwrap().mean;

        let mut frame_bytes = Vec::new();
        binary::encode_query_frame(
            &[&vec],
            &QueryOpts { k: 5, epsilon: 0.05, ..Default::default() },
            &mut frame_bytes,
        )
        .unwrap();
        let mut dec = FrameDecoder::new();
        let mut coords: Vec<f32> = Vec::new();
        r.bench_tagged(
            &b,
            &format!("wire_binary/decode d={dim}"),
            &[("codec", Json::Str("binary".into())), ("dim", Json::Num(dim as f64))],
            || {
                dec.feed(&frame_bytes);
                let f = dec.try_frame().unwrap().expect("whole frame fed");
                binary::decode_query_payload(f.body, f.version, &mut coords).unwrap().dim
            },
        );
        let bin_mean = r.rows().last().unwrap().mean;

        // Steady state (decoder + coords warmed by the bench above):
        // zero allocations, gated hard.
        let allocs = count_allocs(|| {
            for _ in 0..100 {
                dec.feed(&frame_bytes);
                let f = dec.try_frame().unwrap().unwrap();
                std::hint::black_box(
                    binary::decode_query_payload(f.body, f.version, &mut coords).unwrap(),
                );
            }
        });
        assert_eq!(
            allocs, 0,
            "binary decode steady state allocated (d={dim}) — zero-copy contract broken"
        );

        let speedup = json_mean / bin_mean;
        println!(
            "    decode d={dim}: json {:.2} µs vs binary {:.2} µs ({speedup:.1}×, 0 allocs)",
            json_mean * 1e6,
            bin_mean * 1e6
        );
        if dim == 4096 {
            assert!(
                speedup >= 5.0,
                "binary decode must be ≥ 5× faster than line-JSON at d=4096, got {speedup:.1}×"
            );
        }
        wire_decode_points.push(Json::obj([
            ("dim", Json::Num(dim as f64)),
            ("json_decode_s", Json::Num(json_mean)),
            ("binary_decode_s", Json::Num(bin_mean)),
            ("speedup", Json::Num(speedup)),
            ("binary_decode_allocs", Json::Num(allocs as f64)),
        ]));
    }

    // Wire codecs, end to end: client-observed round-trip latency per
    // codec against one live TCP server (same coordinator, same
    // query), p50/p99 over a fixed sample count.
    let wds = gaussian_dataset(512, 128, 9);
    let wq = wds.sample_query(2);
    let wcoord = Arc::new(
        Coordinator::new(
            wds.vectors.clone(),
            CoordinatorConfig {
                workers: 2,
                max_batch: 32,
                batch_timeout: Duration::from_micros(500),
                queue_capacity: 4096,
                backend: Backend::Native,
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let server = Server::start(wcoord, "127.0.0.1:0", 8).unwrap();
    let mut wire_e2e_points: Vec<Json> = Vec::new();
    for codec in ["json", "binary"] {
        let mut client = if codec == "json" {
            Client::connect_json(server.addr()).unwrap()
        } else {
            Client::connect_binary(server.addr()).unwrap()
        };
        let warmup = 50usize;
        let mut lat = Vec::with_capacity(300);
        for i in 0..(warmup + 300) {
            let t = Instant::now();
            if codec == "json" {
                let resp = client.query(&wq, 5, 0.05, 0.1).unwrap();
                assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
            } else {
                let replies = client
                    .query_binary(
                        &[&wq],
                        &QueryOpts { k: 5, epsilon: 0.05, delta: 0.1, ..Default::default() },
                    )
                    .unwrap();
                assert!(replies[0].ok);
            }
            if i >= warmup {
                lat.push(t.elapsed().as_secs_f64());
            }
        }
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = lat.iter().sum::<f64>() / lat.len() as f64;
        let var = lat.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / lat.len() as f64;
        let p50 = lat[lat.len() / 2];
        let p99 = lat[lat.len() * 99 / 100];
        println!(
            "    e2e {codec}: p50 {:.3} ms p99 {:.3} ms (d=128, k=5, tcp loopback)",
            p50 * 1e3,
            p99 * 1e3
        );
        r.push(Measurement {
            name: format!("wire_{codec}/e2e d=128 (tcp)"),
            iters: lat.len() as u64,
            mean,
            std: var.sqrt(),
            min: lat[0],
            median: p50,
            tags: vec![("codec", Json::Str(codec.into())), ("dim", Json::Num(128.0))],
        });
        wire_e2e_points.push(Json::obj([
            ("codec", Json::Str(codec.into())),
            ("dim", Json::Num(128.0)),
            ("p50_s", Json::Num(p50)),
            ("p99_s", Json::Num(p99)),
            ("mean_s", Json::Num(mean)),
        ]));
    }
    server.shutdown();

    r.finish("serving coordinator");
    r.write_json(
        "serving",
        "BENCH_serving.json",
        &[
            // Detected ISA, so bench-trajectory diffs across machines
            // are attributable (mirrors BENCH_hotpath.json).
            ("simd_isa", Json::Str(simd::active_isa().to_string())),
            ("closed_loop", Json::Arr(load_points)),
            ("sharded", Json::Arr(shard_points)),
            ("hedging", Json::Arr(hedge_points)),
            ("churn", Json::Arr(churn_points)),
            ("overload", Json::Arr(overload_points)),
            ("wire_decode", Json::Arr(wire_decode_points)),
            ("wire_e2e", Json::Arr(wire_e2e_points)),
            ("fast_path_served", Json::Num(fast_path_served as f64)),
        ],
    );
}
