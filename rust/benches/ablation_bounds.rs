//! Ablation A2: the concentration bound and the pull order — the two
//! design choices DESIGN.md calls out.
//!
//! * bound: the paper's m(u) (Bardenet–Maillard without replacement) vs
//!   classical Hoeffding sample sizes, across ε — quantifying "never
//!   more than N pulls".
//! * pull order: Permuted (paper-faithful gathers) vs BlockShuffled
//!   (TPU/cache-friendly slabs) vs Sequential, measuring wall-clock per
//!   query at equal flop counts.

use bandit_mips::algos::{BoundedMeIndex, MipsIndex, MipsParams};
use bandit_mips::bandit::{hoeffding_sample_size, m_bounded, PullOrder};
use bandit_mips::benchkit::{Bencher, Reporter};
use bandit_mips::data::synthetic::gaussian_dataset;

fn main() {
    let b = Bencher::quick();
    let mut r = Reporter::new();

    // Bound comparison table.
    println!("-- m(u) vs Hoeffding sample sizes (N = 100000, δ = 0.1) --");
    println!("{:<10} {:>12} {:>12} {:>8}", "ε", "m(u)", "Hoeffding", "ratio");
    for eps in [0.3, 0.1, 0.03, 0.01, 0.003, 0.001] {
        let m = m_bounded(eps, 0.1, 100_000, 1.0);
        let h = hoeffding_sample_size(eps, 0.1, 1.0);
        println!("{eps:<10} {m:>12} {h:>12} {:>7.1}x", h as f64 / m as f64);
    }

    // Cost of evaluating the bound itself (it sits in the round loop).
    r.bench(&b, "bounds/m_bounded eval", || m_bounded(0.05, 0.1, 100_000, 1.0));
    r.bench(&b, "bounds/hoeffding eval", || hoeffding_sample_size(0.05, 0.1, 1.0));

    // Pull-order ablation: same algorithm, different memory behaviour.
    let ds = gaussian_dataset(1500, 4096, 21);
    let q = ds.sample_query(2);
    let p = MipsParams { k: 5, epsilon: 0.05, delta: 0.1, seed: 3 };
    for (order, label) in [
        (PullOrder::Permuted, "permuted (paper)"),
        (PullOrder::BlockShuffled(64), "block-shuffled w=64"),
        (PullOrder::BlockShuffled(512), "block-shuffled w=512"),
        (PullOrder::Sequential, "sequential"),
    ] {
        let idx = BoundedMeIndex::with_order(ds.vectors.clone(), order);
        let mut flops = 0;
        r.bench(&b, &format!("pull_order/{label}"), || {
            let res = idx.query(&q, &p);
            flops = res.flops;
            res.indices[0]
        });
        println!("    flops = {flops}");
    }

    r.finish("ablation A2: bounds + pull order");
}
