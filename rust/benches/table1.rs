//! Bench: Table-1 columns — preprocessing cost of every index and the
//! resulting query cost, measured on one dataset.

use bandit_mips::algos::{
    BoundedMeIndex, GreedyMipsIndex, LshMipsIndex, MipsIndex, MipsParams, PcaMipsIndex,
    RptMipsIndex,
};
use bandit_mips::benchkit::{Bencher, Reporter};
use bandit_mips::data::synthetic::gaussian_dataset;

fn main() {
    let b = Bencher::quick();
    let mut r = Reporter::new();
    let n = 1000;
    let dim = 1024;
    let ds = gaussian_dataset(n, dim, 11);
    let q = ds.sample_query(1);
    let p = MipsParams { k: 5, epsilon: 0.05, delta: 0.1, seed: 0 };

    // Preprocessing cost (index construction).
    r.bench(&b, "prep/bounded_me (scan only)", || {
        BoundedMeIndex::new(ds.vectors.clone()).max_abs_coord()
    });
    r.bench(&b, "prep/greedy (sorted columns)", || {
        GreedyMipsIndex::new(ds.vectors.clone(), n / 5).preprocessing_seconds()
    });
    r.bench(&b, "prep/lsh a=8 b=16", || {
        LshMipsIndex::new(ds.vectors.clone(), 8, 16, 1).preprocessing_seconds()
    });
    r.bench(&b, "prep/pca d=4", || {
        PcaMipsIndex::new(ds.vectors.clone(), 4, 1).preprocessing_seconds()
    });
    r.bench(&b, "prep/rpt L=8 leaf=64", || {
        RptMipsIndex::new(ds.vectors.clone(), 8, 64, 1).preprocessing_seconds()
    });

    // Query cost on prebuilt indexes.
    let bme = BoundedMeIndex::new(ds.vectors.clone());
    let greedy = GreedyMipsIndex::new(ds.vectors.clone(), n / 5);
    let lsh = LshMipsIndex::new(ds.vectors.clone(), 8, 16, 1);
    let pca = PcaMipsIndex::new(ds.vectors.clone(), 4, 1);
    let rpt = RptMipsIndex::new(ds.vectors.clone(), 8, 64, 1);
    r.bench(&b, "query/bounded_me", || bme.query(&q, &p).flops);
    r.bench(&b, "query/greedy", || greedy.query(&q, &p).flops);
    r.bench(&b, "query/lsh", || lsh.query(&q, &p).flops);
    r.bench(&b, "query/pca", || pca.query(&q, &p).flops);
    r.bench(&b, "query/rpt", || rpt.query(&q, &p).flops);

    r.finish("table1 (preprocessing vs query cost)");
}
