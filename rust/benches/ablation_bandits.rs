//! Ablation A1: BOUNDEDME vs classic fixed-confidence bandits on the
//! same MAB-BP instances — the paper's core claim that exploiting the
//! finite reward list slashes sample complexity.
//!
//! Compares total pulls and wall-clock of BOUNDEDME, classic Median
//! Elimination (Hoeffding, with replacement), Successive Elimination
//! (both radius flavors), LUCB, and lil'UCB.

use bandit_mips::bandit::lilucb::{lil_ucb, LilUcbConfig};
use bandit_mips::bandit::lucb::{lucb, LucbConfig};
use bandit_mips::bandit::median_elim::{median_elimination, MedianElimConfig};
use bandit_mips::bandit::successive_elim::{
    successive_elimination, RadiusKind, SuccessiveElimConfig,
};
use bandit_mips::bandit::{BoundedMe, BoundedMeConfig, ExplicitArms};
use bandit_mips::benchkit::{Bencher, Reporter};
use bandit_mips::linalg::Rng;

/// Random MAB-BP instance with a planted gap.
fn instance(n: usize, n_list: usize, seed: u64) -> ExplicitArms {
    let mut rng = Rng::new(seed);
    let lists: Vec<Vec<f64>> = (0..n)
        .map(|i| {
            let mean = if i == 0 { 0.8 } else { rng.uniform(0.0, 0.6) };
            (0..n_list).map(|_| (mean + rng.gaussian() * 0.2).clamp(0.0, 1.0)).collect()
        })
        .collect();
    ExplicitArms::new(lists).with_range(0.0, 1.0)
}

fn main() {
    let b = Bencher::quick();
    let mut r = Reporter::new();
    let (n, n_list) = (200, 1000);
    let env = instance(n, n_list, 7);
    let (eps, delta) = (0.1, 0.1);
    let exhaustive = (n * n_list) as u64;

    let report = |name: &str, pulls: u64, correct: bool| {
        println!(
            "    {name}: pulls={pulls} ({:.1}% of exhaustive) best-arm-correct={correct}",
            100.0 * pulls as f64 / exhaustive as f64
        );
    };

    {
        let algo = BoundedMe::new(BoundedMeConfig { k: 1, epsilon: eps, delta });
        let mut out = None;
        r.bench(&b, "bandits/BoundedME", || {
            let o = algo.run(&env);
            let first = o.result.arms[0];
            out = Some(o);
            first
        });
        let o = out.unwrap();
        report("BoundedME", o.result.total_pulls, o.result.arms[0] == 0);
    }
    {
        let cfg = MedianElimConfig { k: 1, epsilon: eps, delta, ..Default::default() };
        let mut pulls = 0;
        let mut best = 0;
        r.bench(&b, "bandits/MedianElim(Hoeffding)", || {
            let mut rng = Rng::new(3);
            let o = median_elimination(&cfg, &env, &mut rng);
            pulls = o.total_pulls;
            best = o.arms[0];
            best
        });
        report("MedianElim", pulls, best == 0);
    }
    for (kind, label) in [
        (RadiusKind::Serfling, "SuccessiveElim(Serfling/BP)"),
        (RadiusKind::Hoeffding, "SuccessiveElim(Hoeffding)"),
    ] {
        let cfg = SuccessiveElimConfig {
            k: 1,
            epsilon: eps,
            delta,
            radius: kind,
            initial_batch: 16,
        };
        let mut pulls = 0;
        let mut best = 0;
        r.bench(&b, &format!("bandits/{label}"), || {
            let mut rng = Rng::new(4);
            let o = successive_elimination(&cfg, &env, &mut rng);
            pulls = o.total_pulls;
            best = o.arms[0];
            best
        });
        report(label, pulls, best == 0);
    }
    {
        let cfg = LucbConfig {
            k: 1,
            epsilon: eps,
            delta,
            batch: 32,
            max_total_pulls: 20 * exhaustive,
        };
        let mut pulls = 0;
        let mut best = 0;
        r.bench(&b, "bandits/LUCB", || {
            let mut rng = Rng::new(5);
            let o = lucb(&cfg, &env, &mut rng);
            pulls = o.total_pulls;
            best = o.arms[0];
            best
        });
        report("LUCB", pulls, best == 0);
    }
    // Fixed-budget baselines at BOUNDEDME's realized budget — the
    // related-work contrast: same pulls, but no (ε, δ) guarantee.
    {
        use bandit_mips::bandit::fixed_budget::{successive_halving, successive_rejects};
        let bme_budget = BoundedMe::new(BoundedMeConfig { k: 1, epsilon: eps, delta })
            .run(&env)
            .result
            .total_pulls;
        let mut pulls = 0;
        let mut best = 0;
        r.bench(&b, "bandits/SuccessiveHalving(fixed-budget)", || {
            let o = successive_halving(&env, 1, bme_budget);
            pulls = o.total_pulls;
            best = o.arms[0];
            best
        });
        report("SuccessiveHalving", pulls, best == 0);
        r.bench(&b, "bandits/SuccessiveRejects(fixed-budget)", || {
            let o = successive_rejects(&env, bme_budget);
            pulls = o.total_pulls;
            best = o.arms[0];
            best
        });
        report("SuccessiveRejects", pulls, best == 0);
    }
    {
        let cfg = LilUcbConfig { delta, batch: 32, max_total_pulls: 20 * exhaustive };
        let mut pulls = 0;
        let mut best = 0;
        r.bench(&b, "bandits/lilUCB", || {
            let mut rng = Rng::new(6);
            let o = lil_ucb(&cfg, &env, &mut rng);
            pulls = o.total_pulls;
            best = o.arms[0];
            best
        });
        report("lilUCB", pulls, best == 0);
    }

    r.finish("ablation A1: bandit algorithms on MAB-BP");
}
