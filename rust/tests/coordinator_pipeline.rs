//! Deterministic end-to-end tests of the serving pipeline:
//! batcher → reactor → shard-pinned worker loop (and the S = 1 direct
//! fast path) — mixed exact/bandit batches, `QueryMode::Auto` routing
//! at batching time, disconnects mid-batch, and drain-on-shutdown
//! without losing queries.
//!
//! Set `RUST_PALLAS_STRESS=1` to elevate burst sizes (the CI stress leg
//! runs this battery in release mode under both SIMD dispatch modes).

use bandit_mips::algos::{ground_truth, MipsIndex, MipsParams, NaiveIndex};
use bandit_mips::bandit::PullOrder;
use bandit_mips::coordinator::{
    Backend, Coordinator, CoordinatorConfig, CoordinatorError, QueryRequest,
};
use bandit_mips::data::generation::Delta;
use bandit_mips::data::shard::ShardSpec;
use bandit_mips::data::synthetic::gaussian_dataset;
use std::time::Duration;

/// Burst multiplier: 1 normally, 8 under `RUST_PALLAS_STRESS=1`.
fn stress() -> u64 {
    match std::env::var("RUST_PALLAS_STRESS") {
        Ok(v) if v == "1" => 8,
        _ => 1,
    }
}

fn cfg(workers: usize, shard: ShardSpec) -> CoordinatorConfig {
    CoordinatorConfig {
        workers,
        max_batch: 16,
        batch_timeout: Duration::from_millis(5),
        queue_capacity: 1024,
        backend: Backend::Native,
        pull_order: PullOrder::BlockShuffled(16),
        shard,
        ..Default::default()
    }
}

/// A burst of mixed exact / BOUNDEDME / Auto requests rides shared
/// dynamic batches through the sharded pipeline; every answer is
/// correct for its mode and reports the shard count.
#[test]
fn mixed_mode_batches_end_to_end() {
    let ds = gaussian_dataset(180, 128, 41);
    let data = ds.vectors.clone();
    let c = Coordinator::new(ds.vectors.clone(), cfg(2, ShardSpec::contiguous(2))).unwrap();
    let mut handles = Vec::new();
    let mut queries = Vec::new();
    for i in 0..24u64 {
        let q = ds.sample_query(i);
        let req = match i % 3 {
            0 => QueryRequest::exact(q.clone(), 4),
            // ε → 0: sharded sample-then-confirm must recover the truth.
            1 => QueryRequest::bounded_me(q.clone(), 4, 1e-9, 0.05),
            // Auto with ε → 0 knobs: the router must plan Exact.
            _ => QueryRequest::auto(q.clone(), 4, 1e-12, 0.05),
        };
        queries.push(q);
        handles.push(c.submit(req).unwrap());
    }
    for (i, (h, q)) in handles.into_iter().zip(&queries).enumerate() {
        let resp = h.recv().unwrap();
        assert_eq!(resp.shards, 2, "req {i}");
        assert!(!resp.shed);
        let truth = ground_truth(&data, q, 4);
        if i % 3 == 1 {
            let mut got = resp.indices.clone();
            got.sort_unstable();
            let mut want = truth;
            want.sort_unstable();
            assert_eq!(got, want, "req {i} (bounded_me)");
        } else {
            assert_eq!(resp.indices, truth, "req {i}");
        }
    }
    let snap = c.metrics();
    assert_eq!(snap.queries, 24, "queries double- or under-counted under sharding");
    c.shutdown();
}

/// Sharded exact answers are byte-identical to the unsharded index —
/// indices and score bits — for both split kinds.
#[test]
fn sharded_exact_byte_identical_through_coordinator() {
    let ds = gaussian_dataset(150, 96, 17);
    let naive = NaiveIndex::new(ds.vectors.clone());
    for spec in [ShardSpec::contiguous(3), ShardSpec::round_robin(3)] {
        let c = Coordinator::new(ds.vectors.clone(), cfg(3, spec)).unwrap();
        for salt in 0..6u64 {
            let q = ds.sample_query(salt);
            let resp = c.query_blocking(QueryRequest::exact(q.clone(), 7)).unwrap();
            let want = naive.query(&q, &MipsParams { k: 7, ..Default::default() });
            assert_eq!(resp.indices, want.indices, "{spec:?} salt={salt}");
            for (a, b) in resp.scores.iter().zip(&want.scores) {
                assert_eq!(a.to_bits(), b.to_bits(), "{spec:?} salt={salt}: score bits");
            }
            assert_eq!(resp.shards, 3);
        }
        c.shutdown();
    }
}

/// Auto routing happens once per query before fan-out: a tight-knob
/// Auto request equals the explicit Exact answer, and the decision is
/// shard-count invariant.
#[test]
fn auto_routing_is_shard_invariant() {
    let ds = gaussian_dataset(120, 64, 5);
    let data = ds.vectors.clone();
    let mut per_shard_answers = Vec::new();
    for s in [1usize, 2, 4] {
        let c = Coordinator::new(ds.vectors.clone(), cfg(s, ShardSpec::contiguous(s))).unwrap();
        let q = ds.sample_query(9);
        let auto = c.query_blocking(QueryRequest::auto(q.clone(), 5, 1e-12, 0.05)).unwrap();
        let exact = c.query_blocking(QueryRequest::exact(q.clone(), 5)).unwrap();
        assert_eq!(auto.indices, exact.indices, "S={s}");
        assert_eq!(auto.indices, ground_truth(&data, &q, 5), "S={s}");
        per_shard_answers.push(auto.indices);
        c.shutdown();
    }
    assert!(per_shard_answers.windows(2).all(|w| w[0] == w[1]));
}

/// Shutdown drains: every query submitted before shutdown gets its
/// answer — nothing is lost in the batcher, the router, or a shard
/// channel.
#[test]
fn shutdown_drains_without_losing_queries() {
    let ds = gaussian_dataset(400, 256, 23);
    let c = Coordinator::new(ds.vectors.clone(), cfg(2, ShardSpec::contiguous(2))).unwrap();
    let mut handles = Vec::new();
    for i in 0..40 * stress() {
        let q = ds.sample_query(i);
        handles.push(c.submit(QueryRequest::bounded_me(q, 3, 0.2, 0.2)).unwrap());
    }
    // Shutdown while (most of) the burst is still queued: the batcher
    // drains its queue, the reactor fans everything out and keeps
    // running until every merge completes, the shard workers drain
    // their channels, then all threads join.
    c.shutdown();
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.recv().unwrap_or_else(|e| panic!("query {i} lost in drain: {e:?}"));
        assert_eq!(resp.indices.len(), 3, "query {i}");
    }
}

/// A client that disconnects mid-batch (drops its receiver) must not
/// wedge the pipeline or steal answers from the other items of the
/// same batch.
#[test]
fn client_disconnect_mid_batch_keeps_pipeline_alive() {
    let ds = gaussian_dataset(200, 64, 29);
    let data = ds.vectors.clone();
    let c = Coordinator::new(ds.vectors.clone(), cfg(2, ShardSpec::contiguous(2))).unwrap();
    let count = 32 * stress();
    let mut kept = Vec::new();
    let mut kept_queries = Vec::new();
    for i in 0..count {
        let q = ds.sample_query(i);
        let rx = c.submit(QueryRequest::exact(q.clone(), 3)).unwrap();
        if i % 2 == 0 {
            kept_queries.push(q);
            kept.push(rx);
        } // odd receivers dropped here, mid-flight
    }
    for (h, q) in kept.into_iter().zip(&kept_queries) {
        let resp = h.recv().unwrap();
        assert_eq!(resp.indices, ground_truth(&data, q, 3));
    }
    // The abandoned queries were still executed and counted (their
    // batches may trail the kept ones briefly — poll with a bound).
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while c.metrics().queries < count && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(c.metrics().queries, count);
    c.shutdown();
}

/// Load shedding composes with sharding: expired items are shed by the
/// router (shards = 0, nothing computed) and everything else completes.
#[test]
fn shedding_on_the_sharded_path() {
    let ds = gaussian_dataset(500, 256, 31);
    let mut config = cfg(2, ShardSpec::contiguous(2));
    config.max_batch = 4;
    config.batch_timeout = Duration::from_millis(1);
    let c = Coordinator::new(ds.vectors.clone(), config).unwrap();
    let mut rxs = Vec::new();
    for i in 0..48u64 {
        let req = QueryRequest::exact(ds.sample_query(i), 3)
            .with_deadline(Duration::from_nanos(1));
        rxs.push(c.submit(req).unwrap());
    }
    let (mut shed, mut served) = (0u64, 0u64);
    for rx in rxs {
        let resp = rx.recv().unwrap();
        if resp.shed {
            assert!(resp.indices.is_empty());
            assert_eq!(resp.shards, 0, "shed reply claims shard work");
            shed += 1;
        } else {
            assert_eq!(resp.indices.len(), 3);
            assert_eq!(resp.shards, 2);
            served += 1;
        }
    }
    assert_eq!(shed + served, 48);
    assert!(shed > 0, "nothing shed under a 1ns deadline");
    assert_eq!(c.metrics().shed, shed);
    c.shutdown();
}

/// The worker-side deadline re-check composes with live mutation:
/// queries dispatched on generation 0 that expire behind a deliberately
/// slow shard are shed at shard pickup, and the ones picked up *after*
/// a flip has started are additionally counted in `shed_superseded` —
/// the stale-and-late subset of `shed`. In-deadline queries still
/// finish on their pinned generation, and post-flip traffic serves on
/// the new one.
#[test]
fn superseded_and_expired_queries_shed_with_counter() {
    let ds = gaussian_dataset(200, 64, 0x51AB);
    let mut config = cfg(2, ShardSpec::contiguous(2));
    config.max_batch = 4;
    config.batch_timeout = Duration::from_millis(1);
    // Shard 0 primaries crawl: a 32-query burst piles ~8 batches
    // (~200ms of queue) behind it while deadlines expire at 5ms.
    config.debug_slow_shard = Some((0, Duration::from_millis(25)));
    let c = Coordinator::new(ds.vectors.clone(), config).unwrap();
    let mut rxs = Vec::new();
    for i in 0..32u64 {
        let req = QueryRequest::exact(ds.sample_query(i), 3)
            .with_deadline(Duration::from_millis(5));
        rxs.push(c.submit(req).unwrap());
    }
    // Let the burst admit and dispatch pinned to generation 0, then
    // flip mid-queue: every later shard-0 pickup sees an expired
    // deadline AND a superseded pin.
    std::thread::sleep(Duration::from_millis(10));
    let out = c
        .mutate(&[Delta::Upsert { id: 0, vector: ds.sample_query(999) }])
        .unwrap();
    assert_eq!(out.generation, 1);

    let (mut shed, mut served) = (0u64, 0u64);
    for rx in rxs {
        let resp = rx.recv().unwrap();
        if resp.shed {
            assert!(resp.indices.is_empty());
            shed += 1;
        } else {
            assert_eq!(resp.indices.len(), 3);
            served += 1;
        }
    }
    assert_eq!(shed + served, 32);
    assert!(shed > 0, "nothing shed behind the slow shard");
    let m = c.metrics();
    assert_eq!(m.shed, shed);
    assert!(
        m.shed_superseded >= 1,
        "no shed was attributed to a superseded generation (shed={shed})"
    );
    assert!(
        m.shed_superseded <= m.shed,
        "shed_superseded must be a subset of shed"
    );

    // The pipeline is healthy on the new generation afterwards.
    let q = ds.sample_query(7);
    let resp = c.query_blocking(QueryRequest::exact(q, 3)).unwrap();
    assert!(!resp.shed);
    assert_eq!(resp.generation, 1);
    c.shutdown();
}

/// Requesting fewer workers than shards is legal: the pool is raised so
/// every shard has a pinned worker.
#[test]
fn worker_pool_raised_to_shard_count() {
    let ds = gaussian_dataset(90, 64, 3);
    let data = ds.vectors.clone();
    let c = Coordinator::new(ds.vectors.clone(), cfg(1, ShardSpec::round_robin(3))).unwrap();
    let q = ds.sample_query(1);
    let resp = c.query_blocking(QueryRequest::exact(q.clone(), 5)).unwrap();
    assert_eq!(resp.shards, 3);
    assert_eq!(resp.indices, ground_truth(&data, &q, 5));
    c.shutdown();
}

/// Unsharded deployments serve on the direct fast path: every answer
/// is produced worker → client (counted in `fast_path`), reports one
/// shard, and is still exact.
#[test]
fn fast_path_serves_unsharded_directly() {
    let ds = gaussian_dataset(100, 64, 81);
    let data = ds.vectors.clone();
    let c = Coordinator::new(ds.vectors.clone(), cfg(2, ShardSpec::single())).unwrap();
    for i in 0..10 {
        let q = ds.sample_query(i);
        let resp = c.query_blocking(QueryRequest::exact(q.clone(), 4)).unwrap();
        assert_eq!(resp.shards, 1);
        assert_eq!(resp.indices, ground_truth(&data, &q, 4));
    }
    let snap = c.metrics();
    assert_eq!(snap.queries, 10);
    assert_eq!(snap.fast_path, 10, "S=1 answers bypassed the fast path");
    assert_eq!(snap.hedge_fired, 0);
    c.shutdown();
}

/// Backpressure still fails fast on the sharded path.
#[test]
fn sharded_backpressure_fires() {
    let ds = gaussian_dataset(2000, 128, 7);
    let mut config = cfg(2, ShardSpec::contiguous(2));
    config.max_batch = 1;
    config.batch_timeout = Duration::from_millis(0);
    config.queue_capacity = 2;
    let c = Coordinator::new(ds.vectors, config).unwrap();
    let mut saw_full = false;
    let mut receivers = Vec::new();
    for _ in 0..2000 {
        match c.submit(QueryRequest::exact(vec![0.1; 128], 1)) {
            Ok(rx) => receivers.push(rx),
            Err(CoordinatorError::QueueFull) => {
                saw_full = true;
                break;
            }
            Err(e) => panic!("unexpected {e}"),
        }
    }
    assert!(saw_full, "backpressure never engaged");
    for rx in receivers {
        let _ = rx.recv();
    }
    c.shutdown();
}
