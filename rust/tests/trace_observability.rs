//! Flight-recorder battery: tracing must be a pure observer.
//!
//! Four groups:
//!
//! 1. **Bit-identity** — a traced coordinator and an untraced one serve
//!    the same deterministic request mix and must return byte-identical
//!    answers (indices, score bits, flops), across shard counts and on
//!    both the direct fast path and the reactor merge path. Tracing
//!    reads clocks and copies metadata; it must never perturb the
//!    arithmetic.
//! 2. **Ring wraparound** — with a tiny per-thread ring, a long query
//!    stream keeps only the newest `ring_capacity` traces and the
//!    published counter still counts every query.
//! 3. **Slow-query retention** — an injected straggler pushes service
//!    time over `slow_threshold`; those traces are retained (and
//!    warn-logged) even when sampling would otherwise discard them.
//! 4. **Span accounting (acceptance)** — for a hedged, sharded
//!    BOUNDEDME run, every span of every trace ends within the
//!    recorded `queue_wait + service` window, and each shard's round
//!    spans tile within its bandit span.
//!
//! Set `RUST_PALLAS_STRESS=1` to elevate stream lengths (the CI trace
//! leg runs tier-1 with `RUST_PALLAS_TRACE=1`, exercising the traced
//! code path under every existing battery as well).

use bandit_mips::bandit::PullOrder;
use bandit_mips::coordinator::{
    Backend, Coordinator, CoordinatorConfig, QueryRequest, QueryResponse,
};
use bandit_mips::data::shard::ShardSpec;
use bandit_mips::data::synthetic::gaussian_dataset;
use bandit_mips::trace::{trace_env_requested, TraceConfig};
use std::time::Duration;

/// Burst multiplier: 1 normally, 8 under `RUST_PALLAS_STRESS=1`.
fn stress() -> u64 {
    match std::env::var("RUST_PALLAS_STRESS") {
        Ok(v) if v == "1" => 8,
        _ => 1,
    }
}

fn cfg(workers: usize, shard: ShardSpec) -> CoordinatorConfig {
    CoordinatorConfig {
        workers,
        max_batch: 8,
        batch_timeout: Duration::from_millis(2),
        queue_capacity: 4096,
        backend: Backend::Native,
        pull_order: PullOrder::BlockShuffled(16),
        shard,
        ..Default::default()
    }
}

/// Deterministic mix of exact and knob-uniform BOUNDEDME queries, all
/// on the default seed so grouping and hedging cannot change bytes.
fn request_mix(ds: &bandit_mips::data::Dataset, n: u64) -> Vec<QueryRequest> {
    (0..n)
        .map(|i| {
            let q = ds.sample_query(i);
            if i % 2 == 0 {
                QueryRequest::exact(q, 5)
            } else {
                QueryRequest::bounded_me(q, 4, 0.15, 0.1)
            }
        })
        .collect()
}

fn run_all(c: &Coordinator, reqs: &[QueryRequest]) -> Vec<QueryResponse> {
    let handles: Vec<_> =
        reqs.iter().map(|r| c.submit(r.clone()).expect("submit")).collect();
    handles.into_iter().map(|h| h.recv().expect("reply")).collect()
}

fn assert_bit_identical(a: &[QueryResponse], b: &[QueryResponse], label: &str) {
    assert_eq!(a.len(), b.len());
    for (i, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.indices, rb.indices, "{label}: query {i} indices");
        assert_eq!(ra.scores.len(), rb.scores.len(), "{label}: query {i}");
        for (sa, sb) in ra.scores.iter().zip(&rb.scores) {
            assert_eq!(sa.to_bits(), sb.to_bits(), "{label}: query {i} score bits");
        }
        assert_eq!(ra.flops, rb.flops, "{label}: query {i} flops");
    }
}

/// Group 1: the flight recorder is a pure observer. Traced and
/// untraced coordinators over the same data and request stream return
/// bit-identical answers on the direct path (S = 1) and the reactor
/// merge path (S = 2, 3).
#[test]
fn tracing_on_vs_off_is_bit_identical() {
    let ds = gaussian_dataset(180, 128, 77);
    let n = 24 * stress();
    let reqs = request_mix(&ds, n);

    for shards in [1usize, 2, 3] {
        let plain =
            Coordinator::new(ds.vectors.clone(), cfg(2 * shards, ShardSpec::contiguous(shards)))
                .unwrap();
        let baseline = run_all(&plain, &reqs);
        plain.shutdown();

        let mut traced_cfg = cfg(2 * shards, ShardSpec::contiguous(shards));
        traced_cfg.trace = TraceConfig { enabled: true, ..Default::default() };
        let traced = Coordinator::new(ds.vectors.clone(), traced_cfg).unwrap();
        let got = run_all(&traced, &reqs);
        assert_bit_identical(&baseline, &got, &format!("S={shards} traced vs plain"));
        assert!(
            !traced.traces(usize::MAX).is_empty(),
            "S={shards}: traced coordinator recorded nothing"
        );
        traced.shutdown();

        // And the untraced coordinator must expose no traces at all —
        // unless the `RUST_PALLAS_TRACE` pin is set (the CI trace leg),
        // which deliberately traces every coordinator in the suite.
        if !trace_env_requested() {
            let plain2 = Coordinator::new(
                ds.vectors.clone(),
                cfg(2 * shards, ShardSpec::contiguous(shards)),
            )
            .unwrap();
            run_all(&plain2, &reqs);
            assert!(
                plain2.traces(usize::MAX).is_empty(),
                "S={shards}: untraced coord has traces"
            );
            plain2.shutdown();
        }
    }
}

/// Group 2: a tiny ring keeps only the newest traces. With
/// `ring_capacity = 4` and a single recording thread, a long stream
/// retains at most 4 traces, they are the most recent ones by `seq`,
/// and `collect` returns them newest-first.
#[test]
fn ring_wraparound_retains_newest() {
    let ds = gaussian_dataset(120, 64, 31);
    let n = 32 * stress();
    let reqs = request_mix(&ds, n);

    let mut config = cfg(2, ShardSpec::contiguous(2));
    config.trace = TraceConfig { enabled: true, ring_capacity: 4, ..Default::default() };
    let coord = Coordinator::new(ds.vectors.clone(), config).unwrap();
    // Sequential submission: each query fully completes (and publishes)
    // before the next, so retained seqs are exactly the last 4.
    for r in &reqs {
        coord.submit(r.clone()).expect("submit").recv().expect("reply");
    }
    let traces = coord.traces(usize::MAX);
    assert_eq!(traces.len(), 4, "ring of 4 retained {} traces", traces.len());
    // The reactor publishes on one thread, so seqs are 0..n and the
    // survivors are the newest 4, returned newest-first.
    let seqs: Vec<u64> = traces.iter().map(|t| t.seq).collect();
    assert_eq!(seqs, vec![n - 1, n - 2, n - 3, n - 4], "wraparound kept stale traces");
    // `limit` truncates from the newest end.
    assert_eq!(coord.traces(2).len(), 2);
    assert_eq!(coord.traces(2)[0].seq, n - 1);
    coord.shutdown();
}

/// Group 3: slow queries beat the sampler. `sample_every` is set high
/// enough to discard everything in a short run, but an injected
/// straggler pushes shard-0 service time over `slow_threshold`, so
/// those traces are retained and flagged `slow`.
#[test]
fn slow_queries_are_always_retained() {
    let ds = gaussian_dataset(120, 64, 43);
    let reqs = request_mix(&ds, 8);

    let mut config = cfg(4, ShardSpec::contiguous(2));
    config.debug_slow_shard = Some((0, Duration::from_millis(5)));
    config.trace = TraceConfig {
        enabled: true,
        sample_every: 1_000_000, // sampler alone would keep nothing
        slow_threshold: Duration::from_millis(1),
        ..Default::default()
    };
    let coord = Coordinator::new(ds.vectors.clone(), config).unwrap();
    for r in &reqs {
        coord.submit(r.clone()).expect("submit").recv().expect("reply");
    }
    let traces = coord.traces(usize::MAX);
    assert!(!traces.is_empty(), "straggler-delayed queries were not retained");
    for t in &traces {
        // seq 0 is also sampler-kept (0 % sample_every == 0); everything
        // else present must be here because it crossed the threshold.
        if t.seq != 0 {
            assert!(t.slow, "retained trace seq={} is not slow", t.seq);
        }
        if t.slow {
            assert!(
                t.service_ns >= 1_000_000,
                "slow trace seq={} has service_ns={} below the 1ms threshold",
                t.seq,
                t.service_ns
            );
        }
    }
    assert!(traces.iter().any(|t| t.slow), "no trace crossed the slow threshold");
    coord.shutdown();
}

/// Group 4 (acceptance): span accounting for a hedged, sharded
/// BOUNDEDME run. Every span of every trace must end within the
/// trace's own `queue_wait + service` window (plus a small slack for
/// the clock reads between span close and publish), and within each
/// shard the round spans tile inside the bandit span.
#[test]
fn acceptance_hedged_sharded_spans_fit_service_window() {
    let ds = gaussian_dataset(200, 128, 91);
    let n = 12 * stress();

    let mut config = cfg(4, ShardSpec::contiguous(2));
    config.hedge_delay = Some(Duration::from_micros(300));
    config.debug_slow_shard = Some((0, Duration::from_millis(3)));
    config.trace = TraceConfig { enabled: true, ..Default::default() };
    let coord = Coordinator::new(ds.vectors.clone(), config).unwrap();
    for i in 0..n {
        let q = ds.sample_query(i);
        coord
            .submit(QueryRequest::bounded_me(q, 4, 0.15, 0.1))
            .expect("submit")
            .recv()
            .expect("reply");
    }
    let traces = coord.traces(usize::MAX);
    assert!(!traces.is_empty(), "no traces recorded");
    assert!(
        traces.iter().any(|t| t.hedge_fired),
        "3ms straggler under a 300µs hedge delay never fired a hedge"
    );

    const SLACK_NS: u64 = 2_000_000; // clock reads between span close and publish
    for t in &traces {
        assert_eq!(t.kind, "bounded_me");
        assert_eq!(t.shards, 2);
        let window = t.queue_wait_ns + t.service_ns + SLACK_NS;
        assert!(!t.spans.is_empty(), "seq={}: empty span tree", t.seq);
        for s in &t.spans {
            assert!(s.end_ns >= s.start_ns, "seq={}: inverted span {}", t.seq, s.label);
            assert!(
                s.end_ns <= window,
                "seq={}: span {} (shard {}) ends at {}ns, outside the {}ns \
                 queue+service window",
                t.seq,
                s.label,
                s.shard,
                s.end_ns,
                window
            );
        }
        // Per-shard: rounds tile front-to-back inside the bandit span.
        for shard in 0..2i64 {
            let bandit: Vec<_> =
                t.spans.iter().filter(|s| s.label == "bandit" && s.shard == shard).collect();
            let round_total: u64 = t
                .spans
                .iter()
                .filter(|s| s.label == "round" && s.shard == shard)
                .map(|s| s.duration_ns())
                .sum();
            for b in &bandit {
                assert!(
                    round_total <= bandit.iter().map(|s| s.duration_ns()).sum::<u64>(),
                    "seq={}: shard {shard} rounds ({round_total}ns) overflow bandit \
                     span ({}ns)",
                    t.seq,
                    b.duration_ns()
                );
            }
        }
        // Query-wide sanity: the queue span matches the recorded wait.
        let queue = t.spans.iter().find(|s| s.label == "queue").expect("queue span");
        assert_eq!(queue.start_ns, 0, "queue span is anchored at submission");
        assert!(
            queue.duration_ns() <= t.queue_wait_ns + SLACK_NS,
            "seq={}: queue span exceeds recorded queue_wait_ns",
            t.seq
        );
    }
    coord.shutdown();
}
