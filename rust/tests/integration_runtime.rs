//! Integration: the rust PJRT runtime executes the python-AOT'd HLO
//! artifacts and matches the native engine bit-for-tolerance.
//!
//! Requires the `pjrt` cargo feature (the `xla` bindings) *and*
//! `make artifacts` to have run (skips politely otherwise so
//! `cargo test` stays green on a fresh checkout).
#![cfg(feature = "pjrt")]

use bandit_mips::linalg::{Matrix, Rng};
use bandit_mips::runtime::{NativeEngine, PjrtEngine, Runtime, ScoringEngine};
use std::path::{Path, PathBuf};

fn artifact_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("exact_b256_d512.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn runtime_loads_all_artifacts() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::cpu().expect("pjrt cpu client");
    let n = rt.load_dir(&dir).expect("load artifacts");
    assert!(n >= 3, "expected ≥3 artifacts, loaded {n}");
    assert!(rt.find_exact(512).is_some());
    assert!(rt.find_exact(4096).is_some());
    assert!(rt.find_partial(256).is_some());
}

#[test]
fn exact_artifact_matches_native_dot() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::cpu().unwrap();
    rt.load_dir(&dir).unwrap();
    // Smallest-block artifact for ad-hoc batches; the largest serves
    // resident whole-dataset scans.
    let (name, shape) = rt.find_exact_min(512).unwrap();
    assert_eq!(shape.block, 256);
    assert!(rt.find_exact(512).unwrap().1.block >= shape.block);

    let mut rng = Rng::new(7);
    let v: Vec<f32> = (0..256 * 512).map(|_| rng.gaussian() as f32).collect();
    let q: Vec<f32> = rng.gaussian_vec(512);
    let got = rt.execute_f32(&name, &[(&v, &[256, 512]), (&q, &[512])]).unwrap();
    assert_eq!(got.len(), 256);
    for i in 0..256 {
        let want = bandit_mips::linalg::dot(&v[i * 512..(i + 1) * 512], &q);
        assert!(
            (got[i] - want).abs() <= 1e-2 + want.abs() * 1e-4,
            "row {i}: pjrt {} vs native {want}",
            got[i]
        );
    }
}

#[test]
fn partial_artifact_matches_native() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::cpu().unwrap();
    rt.load_dir(&dir).unwrap();
    let (name, shape) = rt.find_partial(256).unwrap();
    let (b, c) = (shape.block, shape.width);

    let mut rng = Rng::new(9);
    let v: Vec<f32> = (0..b * c).map(|_| rng.gaussian() as f32).collect();
    let q: Vec<f32> = rng.gaussian_vec(c);
    let got = rt.execute_f32(&name, &[(&v, &[b, c]), (&q, &[c])]).unwrap();
    for i in 0..b {
        let want = bandit_mips::linalg::dot(&v[i * c..(i + 1) * c], &q);
        assert!((got[i] - want).abs() <= 1e-2 + want.abs() * 1e-4, "row {i}");
    }
}

#[test]
fn pjrt_engine_pads_odd_blocks() {
    let Some(dir) = artifact_dir() else { return };
    let engine = PjrtEngine::new(dir, 512).expect("engine");
    let mut rng = Rng::new(11);
    // 300 rows: one full 256-block + padded 44-block.
    let data = Matrix::from_fn(300, 512, |_, _| rng.gaussian() as f32);
    let q: Vec<f32> = rng.gaussian_vec(512);
    let ids: Vec<usize> = (0..300).collect();
    let pjrt = engine.score_rows(&data, &ids, &q).unwrap();
    let native = NativeEngine.score_rows(&data, &ids, &q).unwrap();
    assert_eq!(pjrt.len(), native.len());
    for i in 0..300 {
        assert!(
            (pjrt[i] - native[i]).abs() <= 1e-2 + native[i].abs() * 1e-4,
            "row {i}: {} vs {}",
            pjrt[i],
            native[i]
        );
    }
}

#[test]
fn engine_rejects_wrong_dim() {
    let Some(dir) = artifact_dir() else { return };
    let engine = PjrtEngine::new(dir, 512).unwrap();
    let err = engine.score_block(&[0.0; 100], 1, &[0.0; 100]);
    assert!(err.is_err());
}
