//! Reactor + straggler-hedging battery: duplicate-partial suppression,
//! hedge-as-no-op under healthy shards, byte-identity of the S = 1 fast
//! path against the reactor merge path, drain-on-shutdown with hedges
//! in flight, and client disconnects mid-hedge.
//!
//! Determinism notes: every BOUNDEDME request here uses the default
//! seed and knob-uniform groups, so results are independent of how the
//! batcher happened to group them (batch-vs-single bit-identity of the
//! fused path) and of which copy of a hedged dispatch wins (both copies
//! compute the same bytes from the same shard data and seed). That is
//! what lets these tests compare hedged runs against unhedged runs
//! bit-for-bit.
//!
//! Set `RUST_PALLAS_STRESS=1` to elevate burst sizes (the CI stress leg
//! runs this battery in release mode under both SIMD dispatch modes).

use bandit_mips::algos::ground_truth;
use bandit_mips::bandit::PullOrder;
use bandit_mips::coordinator::{
    Backend, Coordinator, CoordinatorConfig, QueryRequest, QueryResponse,
};
use bandit_mips::data::shard::ShardSpec;
use bandit_mips::data::synthetic::gaussian_dataset;
use std::time::{Duration, Instant};

/// Burst multiplier: 1 normally, 8 under `RUST_PALLAS_STRESS=1`.
fn stress() -> u64 {
    match std::env::var("RUST_PALLAS_STRESS") {
        Ok(v) if v == "1" => 8,
        _ => 1,
    }
}

fn cfg(workers: usize, shard: ShardSpec) -> CoordinatorConfig {
    CoordinatorConfig {
        workers,
        max_batch: 8,
        batch_timeout: Duration::from_millis(2),
        queue_capacity: 4096,
        backend: Backend::Native,
        pull_order: PullOrder::BlockShuffled(16),
        shard,
        ..Default::default()
    }
}

/// The deterministic request mix used by the equivalence tests: exact
/// and knob-uniform BOUNDEDME queries with the default seed.
fn request_mix(ds: &bandit_mips::data::Dataset, n: u64) -> Vec<QueryRequest> {
    (0..n)
        .map(|i| {
            let q = ds.sample_query(i);
            if i % 2 == 0 {
                QueryRequest::exact(q, 5)
            } else {
                QueryRequest::bounded_me(q, 4, 0.15, 0.1)
            }
        })
        .collect()
}

fn run_all(c: &Coordinator, reqs: &[QueryRequest]) -> Vec<QueryResponse> {
    let handles: Vec<_> =
        reqs.iter().map(|r| c.submit(r.clone()).expect("submit")).collect();
    handles.into_iter().map(|h| h.recv().expect("reply")).collect()
}

fn assert_bit_identical(a: &[QueryResponse], b: &[QueryResponse], label: &str) {
    assert_eq!(a.len(), b.len());
    for (i, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.indices, rb.indices, "{label}: query {i} indices");
        assert_eq!(ra.scores.len(), rb.scores.len(), "{label}: query {i}");
        for (sa, sb) in ra.scores.iter().zip(&rb.scores) {
            assert_eq!(sa.to_bits(), sb.to_bits(), "{label}: query {i} score bits");
        }
        assert_eq!(ra.flops, rb.flops, "{label}: query {i} flops");
    }
}

/// A hedge delay of zero hedges *every* dispatch, so most dispatches
/// complete twice. The duplicate partial must be suppressed: merged
/// results are bit-identical to an unhedged run, every query is
/// answered exactly once, and the metrics count each query once.
#[test]
fn hedged_duplicate_partials_are_suppressed() {
    let ds = gaussian_dataset(180, 128, 55);
    let n = 24 * stress();
    let reqs = request_mix(&ds, n);

    let plain = Coordinator::new(ds.vectors.clone(), cfg(6, ShardSpec::contiguous(3))).unwrap();
    let baseline = run_all(&plain, &reqs);
    plain.shutdown();

    let mut hedged_cfg = cfg(6, ShardSpec::contiguous(3));
    hedged_cfg.hedge_delay = Some(Duration::ZERO);
    let hedged = Coordinator::new(ds.vectors.clone(), hedged_cfg).unwrap();
    let handles: Vec<_> =
        reqs.iter().map(|r| hedged.submit(r.clone()).expect("submit")).collect();
    let mut got = Vec::new();
    for (i, h) in handles.into_iter().enumerate() {
        got.push(h.recv().unwrap_or_else(|e| panic!("query {i} lost: {e:?}")));
        // Exactly one answer per query: the reply sender is dropped
        // after the merge replies, so a second recv must error — a
        // duplicate reply would have been buffered and returned here.
        assert!(h.recv().is_err(), "query {i} answered twice");
    }
    assert_bit_identical(&baseline, &got, "hedged vs unhedged");
    let snap = hedged.metrics();
    assert_eq!(snap.queries, n, "duplicate partials double-counted queries");
    assert!(snap.hedge_fired > 0, "zero hedge delay never fired a hedge");
    hedged.shutdown();
}

/// With a generous hedge delay and healthy shards, hedging is a
/// complete no-op: nothing fires, nothing wins, answers are correct.
#[test]
fn hedge_under_no_straggler_is_a_noop() {
    let ds = gaussian_dataset(150, 96, 19);
    let data = ds.vectors.clone();
    let mut config = cfg(2, ShardSpec::contiguous(2));
    config.hedge_delay = Some(Duration::from_secs(30));
    let c = Coordinator::new(ds.vectors.clone(), config).unwrap();
    for i in 0..16u64 {
        let q = ds.sample_query(i);
        let resp = c.query_blocking(QueryRequest::exact(q.clone(), 5)).unwrap();
        assert_eq!(resp.indices, ground_truth(&data, &q, 5));
        assert_eq!(resp.shards, 2);
    }
    let snap = c.metrics();
    assert_eq!(snap.queries, 16);
    assert_eq!(snap.hedge_fired, 0, "hedge fired with no straggler");
    assert_eq!(snap.hedge_won, 0);
    c.shutdown();
}

/// One shard crawls (deterministic straggler injection); the hedge
/// re-dispatch lands on an idle sibling worker and beats it. The
/// answer is still exact, the hedge provably won, and the query
/// returned far sooner than the straggler's delay.
#[test]
fn hedge_rescues_a_slow_shard() {
    let ds = gaussian_dataset(160, 64, 47);
    let data = ds.vectors.clone();
    let slow = Duration::from_millis(500);
    let mut config = cfg(4, ShardSpec::contiguous(2));
    config.hedge_delay = Some(Duration::from_millis(5));
    config.debug_slow_shard = Some((0, slow));
    let c = Coordinator::new(ds.vectors.clone(), config).unwrap();
    for i in 0..3u64 {
        let q = ds.sample_query(i);
        let t0 = Instant::now();
        let resp = c.query_blocking(QueryRequest::exact(q.clone(), 5)).unwrap();
        let wall = t0.elapsed();
        assert_eq!(resp.indices, ground_truth(&data, &q, 5), "query {i}");
        assert_eq!(resp.shards, 2);
        assert!(
            wall < Duration::from_millis(400),
            "query {i} took {wall:?} — hedge did not rescue the {slow:?} straggler"
        );
    }
    let snap = c.metrics();
    assert!(snap.hedge_fired >= 1, "no hedge fired against a {slow:?} straggler");
    assert!(snap.hedge_won >= 1, "hedge never beat the straggler");
    c.shutdown();
}

/// The S = 1 fast path must be bit-identical to the S = 1 reactor merge
/// path on identical traffic — removing the reactor hop and the merge
/// state is pure overhead elimination, not a semantic change.
#[test]
fn fast_path_bit_identical_to_reactor_merge_path() {
    let ds = gaussian_dataset(150, 96, 7);
    // Sequential singles (per-query path) plus a same-knob burst (fused
    // path); default seeds keep the shared permutation identical no
    // matter how the batcher groups the burst.
    let mut reqs = request_mix(&ds, 12);
    for i in 100..108u64 {
        reqs.push(QueryRequest::bounded_me(ds.sample_query(i), 3, 0.2, 0.15));
    }

    let fast = Coordinator::new(ds.vectors.clone(), cfg(2, ShardSpec::single())).unwrap();
    let via_fast = run_all(&fast, &reqs);
    let fast_snap = fast.metrics();
    fast.shutdown();

    let mut reactor_cfg = cfg(2, ShardSpec::single());
    reactor_cfg.force_reactor = true;
    let reactor = Coordinator::new(ds.vectors.clone(), reactor_cfg).unwrap();
    let via_reactor = run_all(&reactor, &reqs);
    let reactor_snap = reactor.metrics();
    reactor.shutdown();

    assert_bit_identical(&via_fast, &via_reactor, "fast path vs reactor merge");
    assert_eq!(fast_snap.fast_path, reqs.len() as u64, "fast path not taken at S=1");
    assert_eq!(reactor_snap.fast_path, 0, "forced reactor still hit the fast path");
    assert_eq!(reactor_snap.queries, reqs.len() as u64);
    for resp in &via_fast {
        assert_eq!(resp.shards, 1);
    }
}

/// Shutdown with hedges in flight: the reactor keeps running until
/// every in-flight (hedged or primary) dispatch has merged — no query
/// is lost, none is answered twice.
#[test]
fn shutdown_drains_inflight_hedged_queries() {
    let ds = gaussian_dataset(200, 128, 61);
    let n = 24 * stress();
    let reqs = request_mix(&ds, n);
    let mut config = cfg(4, ShardSpec::contiguous(2));
    config.hedge_delay = Some(Duration::ZERO);
    config.debug_slow_shard = Some((0, Duration::from_millis(10)));
    let c = Coordinator::new(ds.vectors.clone(), config).unwrap();
    let handles: Vec<_> = reqs.iter().map(|r| c.submit(r.clone()).expect("submit")).collect();
    // Shut down while the burst — and its hedge duplicates — is still
    // in flight.
    c.shutdown();
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.recv().unwrap_or_else(|e| panic!("query {i} lost in drain: {e:?}"));
        assert!(!resp.shed, "query {i} spuriously shed");
        assert!(!resp.indices.is_empty(), "query {i} returned empty");
        assert!(h.recv().is_err(), "query {i} answered twice");
    }
}

/// Clients that vanish mid-hedge (receiver dropped while both copies of
/// their dispatch are in flight) must not wedge the reactor: surviving
/// clients get exact answers and the pipeline keeps serving afterwards.
#[test]
fn client_disconnect_mid_hedge_does_not_wedge_the_reactor() {
    let ds = gaussian_dataset(180, 96, 29);
    let data = ds.vectors.clone();
    let n = 16 * stress();
    let mut config = cfg(3, ShardSpec::contiguous(3));
    config.hedge_delay = Some(Duration::ZERO);
    config.debug_slow_shard = Some((1, Duration::from_millis(5)));
    let c = Coordinator::new(ds.vectors.clone(), config).unwrap();
    let mut kept = Vec::new();
    for i in 0..n {
        let q = ds.sample_query(i);
        let rx = c.submit(QueryRequest::exact(q.clone(), 4)).unwrap();
        if i % 2 == 0 {
            kept.push((q, rx));
        } // odd receivers dropped here, mid-hedge
    }
    for (q, rx) in kept {
        let resp = rx.recv().expect("kept client starved by disconnects");
        assert_eq!(resp.indices, ground_truth(&data, &q, 4));
    }
    // The reactor is still alive and serving: a fresh query round-trips.
    let q = ds.sample_query(9999);
    let resp = c.query_blocking(QueryRequest::exact(q.clone(), 3)).unwrap();
    assert_eq!(resp.indices, ground_truth(&data, &q, 3));
    // Every query (answered or abandoned) executed exactly once.
    let deadline = Instant::now() + Duration::from_secs(10);
    while c.metrics().queries < n + 1 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(c.metrics().queries, n + 1, "abandoned queries lost or double-counted");
    c.shutdown();
}
