//! Wire-protocol battery: the negotiated TCP front-end end-to-end.
//!
//! Proves the [`bandit_mips::wire`] contract against a **live server**,
//! not just the codec units:
//!
//! * partial reads — a frame delivered one byte at a time decodes once,
//!   correctly;
//! * hostile length prefixes (zero / oversized) are rejected without a
//!   single allocation, straight off the 12-byte preamble;
//! * truncated payloads and garbage magic take the reply-once-and-close
//!   path;
//! * mixed JSON and binary clients coexist on one server, and both show
//!   up in the wire metrics;
//! * **codec equivalence**: the same query asked over line-JSON and
//!   over binary frames produces byte-identical answers (indices, score
//!   bits, flops, storage, generation);
//! * per-request storage-tier overrides ride both codecs;
//! * the three-way reply contract (exact-complete / degraded / shed)
//!   rides both codecs: binary via the response-header flag bits +
//!   ε̂, JSON via `degraded`/`epsilon_hat` fields (shed stays the
//!   pre-anytime error shape);
//! * a FLOP budget promotes the query frame to `PLW2` per-frame — a
//!   budget-free frame on the same live connection stays v1;
//! * every line-protocol op works over binary transport (the CI `wire`
//!   leg pins `RUST_PALLAS_WIRE=binary` and replays the TCP batteries
//!   through the binary codec).

use bandit_mips::algos::ground_truth;
use bandit_mips::bandit::force_no_degrade_requested;
use bandit_mips::coordinator::server::{Client, Server};
use bandit_mips::coordinator::{Coordinator, CoordinatorConfig, QueryMode};
use bandit_mips::data::quant::Storage;
use bandit_mips::data::shard::ShardSpec;
use bandit_mips::data::synthetic::gaussian_dataset;
use bandit_mips::jsonlite::{parse, Json};
use bandit_mips::linalg::Matrix;
use bandit_mips::wire::frame::{
    self, FrameDecoder, FrameError, MAGIC, OP_QUERY, PREAMBLE_LEN, RESP_ERROR,
};
use bandit_mips::wire::{binary, BinaryCodec, Codec, QueryOpts};
use std::alloc::{GlobalAlloc, Layout, System};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Counts heap allocations so the hostile-prefix test can prove the
/// reject path never sizes a buffer to the attacker's length.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn count_allocs(mut f: impl FnMut()) -> u64 {
    let before = ALLOCS.load(Ordering::Relaxed);
    f();
    ALLOCS.load(Ordering::Relaxed) - before
}

const DIM: usize = 64;

fn serve(shards: usize, storage: Storage) -> (Server, Matrix) {
    let ds = gaussian_dataset(160, DIM, 77);
    let data = ds.vectors.clone();
    let cfg = CoordinatorConfig {
        workers: shards.max(1),
        shard: ShardSpec::contiguous(shards),
        storage,
        ..Default::default()
    };
    let coord = Arc::new(Coordinator::new(ds.vectors, cfg).unwrap());
    let server = Server::start(coord, "127.0.0.1:0", 16).unwrap();
    (server, data)
}

/// Read exactly one frame off a raw socket.
fn read_raw_frame(stream: &mut TcpStream, dec: &mut FrameDecoder) -> (u8, Vec<u8>) {
    let mut tmp = [0u8; 4096];
    loop {
        match dec.try_frame() {
            Ok(Some(f)) => return (f.op, f.body.to_vec()),
            Ok(None) => {}
            Err(e) => panic!("frame error from server: {e}"),
        }
        let n = stream.read(&mut tmp).unwrap();
        assert!(n > 0, "connection closed mid-frame");
        dec.feed(&tmp[..n]);
    }
}

/// A query frame trickled in one byte per write still decodes exactly
/// once and answers correctly — the server's read loop must tolerate
/// every possible split point, including mid-preamble and mid-f32.
#[test]
fn partial_reads_at_every_frame_boundary() {
    let (server, data) = serve(1, Storage::F32);
    let q = vec![0.25f32; DIM];
    let mut wire = Vec::new();
    binary::encode_query_frame(
        &[&q],
        &QueryOpts { k: 3, epsilon: 1e-9, mode: QueryMode::BoundedMe, ..Default::default() },
        &mut wire,
    )
    .unwrap();

    let mut stream = TcpStream::connect(server.addr()).unwrap();
    for b in &wire {
        stream.write_all(std::slice::from_ref(b)).unwrap();
        stream.flush().unwrap();
    }
    let mut dec = FrameDecoder::new();
    let (op, body) = read_raw_frame(&mut stream, &mut dec);
    assert_eq!(op, frame::RESP_QUERY);
    let reply = binary::decode_reply(&body).unwrap();
    assert!(reply.ok);
    let mut got: Vec<usize> = reply.indices.iter().map(|&i| i as usize).collect();
    got.sort_unstable();
    let mut want = ground_truth(&data, &q, 3);
    want.sort_unstable();
    assert_eq!(got, want);
    server.shutdown();
}

/// Zero and oversized length prefixes are rejected from the preamble
/// alone — decoder-level without any allocation, server-level with one
/// error reply and a closed connection.
#[test]
fn hostile_length_prefixes_rejected_without_allocation() {
    // Decoder level: warm the codec, then prove the reject is
    // allocation-free (nothing is ever sized to the hostile length).
    for (len, is_oversized) in [(0u32, false), (u32::MAX, true)] {
        let mut preamble = Vec::with_capacity(PREAMBLE_LEN);
        preamble.extend_from_slice(&MAGIC);
        preamble.push(OP_QUERY);
        preamble.extend_from_slice(&[0u8; 3]);
        preamble.extend_from_slice(&len.to_le_bytes());
        let mut codec = BinaryCodec::new();
        codec.feed(&preamble);
        let mut err = None;
        let allocs = count_allocs(|| {
            err = Some(codec.try_decode().unwrap_err());
        });
        assert_eq!(allocs, 0, "hostile prefix len={len} allocated on the reject path");
        match err.unwrap() {
            FrameError::EmptyBody => assert!(!is_oversized),
            FrameError::Oversized(n) => {
                assert!(is_oversized);
                assert_eq!(n, u32::MAX as usize);
            }
            other => panic!("unexpected error {other:?}"),
        }
    }

    // Server level: one RESP_ERROR frame, then EOF.
    let (server, _) = serve(1, Storage::F32);
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    let mut preamble = Vec::new();
    preamble.extend_from_slice(&MAGIC);
    preamble.push(OP_QUERY);
    preamble.extend_from_slice(&[0u8; 3]);
    preamble.extend_from_slice(&u32::MAX.to_le_bytes());
    stream.write_all(&preamble).unwrap();
    let mut dec = FrameDecoder::new();
    let (op, body) = read_raw_frame(&mut stream, &mut dec);
    assert_eq!(op, RESP_ERROR);
    let msg = String::from_utf8_lossy(&body);
    assert!(msg.contains("protocol error"), "{msg}");
    // The server closes after a frame-level violation.
    let mut rest = Vec::new();
    assert_eq!(stream.read_to_end(&mut rest).unwrap_or(0), 0);
    server.shutdown();
}

/// A frame whose header claims more payload than the body carries is a
/// protocol error: reply once, close.
#[test]
fn truncated_payload_is_a_protocol_error() {
    let (server, _) = serve(1, Storage::F32);
    let q = vec![1.0f32; DIM];
    let mut wire = Vec::new();
    binary::encode_query_frame(&[&q], &QueryOpts::default(), &mut wire).unwrap();
    // Shrink the frame's body_len and drop the tail: the QueryHeader's
    // count·dim claim no longer matches the payload.
    let cut = 16usize;
    let body_len = (wire.len() - PREAMBLE_LEN - cut) as u32;
    wire[8..12].copy_from_slice(&body_len.to_le_bytes());
    wire.truncate(wire.len() - cut);

    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.write_all(&wire).unwrap();
    let mut dec = FrameDecoder::new();
    let (op, body) = read_raw_frame(&mut stream, &mut dec);
    assert_eq!(op, RESP_ERROR);
    assert!(String::from_utf8_lossy(&body).contains("protocol error"));
    let mut rest = Vec::new();
    assert_eq!(stream.read_to_end(&mut rest).unwrap_or(0), 0);
    server.shutdown();
}

/// Garbage that doesn't start with the magic's `b'P'` negotiates the
/// line codec and fails softly (`bad json`, connection stays open);
/// garbage that *does* start with `b'P'` negotiates binary, fails the
/// magic check, and takes the reply-once-and-close path.
#[test]
fn garbage_negotiates_by_first_byte() {
    let (server, _) = serve(1, Storage::F32);

    // Non-'P' garbage → line codec → bad json reply, connection alive.
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.write_all(b"GET / HTTP/1.1\n").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = parse(line.trim()).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    assert!(resp.get("error").unwrap().as_str().unwrap().contains("bad json"));
    // Still serving: a valid line now gets a real answer.
    stream.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = parse(line.trim()).unwrap();
    assert_eq!(resp.get("pong").unwrap().as_bool(), Some(true));

    // 'P'-led garbage → binary codec → bad magic → error frame + close.
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.write_all(b"PSTL not a frame").unwrap();
    let mut dec = FrameDecoder::new();
    let (op, body) = read_raw_frame(&mut stream, &mut dec);
    assert_eq!(op, RESP_ERROR);
    assert!(String::from_utf8_lossy(&body).contains("bad frame magic"));
    let mut rest = Vec::new();
    assert_eq!(stream.read_to_end(&mut rest).unwrap_or(0), 0);
    server.shutdown();
}

/// The same query over line-JSON and over binary frames must produce
/// **byte-identical** answers: same indices in the same order, same
/// score bits, same flops, same storage tier, same generation. JSON
/// carries f64 shortest-round-trip decimals and jsonlite's parse is
/// bit-exact, so not even the vector differs in flight.
#[test]
fn codec_equivalence_is_byte_identical() {
    let (server, _) = serve(2, Storage::F32);
    let mut json = Client::connect_json(server.addr()).unwrap();
    let mut bin = Client::connect_binary(server.addr()).unwrap();

    for seed in 0..6u64 {
        let q: Vec<f32> =
            (0..DIM).map(|i| ((i as f32 + seed as f32) * 0.37).sin()).collect();
        let mode = if seed % 2 == 0 { "exact" } else { "bounded_me" };
        let jresp = json
            .call(&Json::obj([
                ("op", Json::Str("query".into())),
                ("vector", Json::f32s(&q)),
                ("k", Json::Num(4.0)),
                ("epsilon", Json::Num(0.1)),
                ("delta", Json::Num(0.1)),
                ("seed", Json::Num(seed as f64)),
                ("mode", Json::Str(mode.into())),
            ]))
            .unwrap();
        assert_eq!(jresp.get("ok").unwrap().as_bool(), Some(true), "seed {seed}");

        let breply = bin
            .query_binary(
                &[&q],
                &QueryOpts {
                    k: 4,
                    epsilon: 0.1,
                    delta: 0.1,
                    seed,
                    mode: if seed % 2 == 0 {
                        QueryMode::Exact
                    } else {
                        QueryMode::BoundedMe
                    },
                    ..Default::default()
                },
            )
            .unwrap()
            .remove(0);
        assert!(breply.ok, "seed {seed}: {:?}", breply.error);

        let jindices: Vec<u64> = jresp
            .get("indices")
            .unwrap()
            .as_f32_vec()
            .unwrap()
            .iter()
            .map(|&x| x as u64)
            .collect();
        let jscores = jresp.get("scores").unwrap().as_f32_vec().unwrap();
        assert_eq!(jindices, breply.indices, "seed {seed} ({mode}): index mismatch");
        assert_eq!(jscores.len(), breply.scores.len());
        for (a, b) in jscores.iter().zip(&breply.scores) {
            assert_eq!(a.to_bits(), b.to_bits(), "seed {seed} ({mode}): score bits");
        }
        assert_eq!(
            jresp.get("flops").unwrap().as_usize().unwrap() as u64,
            breply.flops,
            "seed {seed} ({mode}): flops"
        );
        assert_eq!(
            jresp.get("storage").unwrap().as_str(),
            Some(breply.storage.label()),
            "seed {seed} ({mode}): storage"
        );
        assert_eq!(
            jresp.get("generation").unwrap().as_usize().unwrap() as u64,
            breply.generation,
            "seed {seed} ({mode}): generation"
        );
    }
    server.shutdown();
}

/// A multi-vector binary frame is answered by exactly B in-order
/// replies, each correct for its own vector.
#[test]
fn batch_frame_answers_in_request_order() {
    let (server, data) = serve(1, Storage::F32);
    let queries: Vec<Vec<f32>> = (0..8)
        .map(|s| (0..DIM).map(|i| ((i * 7 + s * 13) as f32 * 0.11).cos()).collect())
        .collect();
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();

    let mut bin = Client::connect_binary(server.addr()).unwrap();
    let replies = bin
        .query_binary(
            &qrefs,
            &QueryOpts { k: 3, mode: QueryMode::Exact, ..Default::default() },
        )
        .unwrap();
    assert_eq!(replies.len(), 8);
    for (q, reply) in queries.iter().zip(&replies) {
        assert!(reply.ok);
        let got: Vec<usize> = reply.indices.iter().map(|&i| i as usize).collect();
        assert_eq!(got, ground_truth(&data, q, 3));
    }
    server.shutdown();
}

/// Storage-tier overrides ride both codecs: on an f16 deployment an
/// explicit f32 override answers exactly (and says so), and both codecs
/// agree on the no-override deployment tier.
#[test]
fn storage_override_rides_both_codecs() {
    let (server, data) = serve(1, Storage::F16);
    let q: Vec<f32> = (0..DIM).map(|i| (i as f32 * 0.29).sin()).collect();
    let mut want = ground_truth(&data, &q, 3);
    want.sort_unstable();

    // JSON: explicit f32 override → exact f32 sampling at ε → 0.
    let mut json = Client::connect_json(server.addr()).unwrap();
    let jresp = json
        .call(&Json::obj([
            ("op", Json::Str("query".into())),
            ("vector", Json::f32s(&q)),
            ("k", Json::Num(3.0)),
            ("epsilon", Json::Num(1e-9)),
            ("delta", Json::Num(0.05)),
            ("storage", Json::Str("f32".into())),
        ]))
        .unwrap();
    assert_eq!(jresp.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(jresp.get("storage").unwrap().as_str(), Some("f32"));
    let mut got: Vec<usize> = jresp
        .get("indices")
        .unwrap()
        .as_f32_vec()
        .unwrap()
        .iter()
        .map(|&x| x as usize)
        .collect();
    got.sort_unstable();
    assert_eq!(got, want);

    // Binary: same override through the header byte.
    let mut bin = Client::connect_binary(server.addr()).unwrap();
    let breply = bin
        .query_binary(
            &[&q],
            &QueryOpts {
                k: 3,
                epsilon: 1e-9,
                delta: 0.05,
                storage: Some(Storage::F32),
                ..Default::default()
            },
        )
        .unwrap()
        .remove(0);
    assert!(breply.ok);
    assert_eq!(breply.storage, Storage::F32);
    let mut got: Vec<usize> = breply.indices.iter().map(|&i| i as usize).collect();
    got.sort_unstable();
    assert_eq!(got, want);

    // No override: both codecs land on the same deployment tier (its
    // exact label depends on the RUST_PALLAS_FORCE_F32 leg, so assert
    // agreement rather than a fixed name).
    let jresp = json
        .call(&Json::obj([
            ("op", Json::Str("query".into())),
            ("vector", Json::f32s(&q)),
            ("k", Json::Num(3.0)),
        ]))
        .unwrap();
    let breply = bin
        .query_binary(&[&q], &QueryOpts { k: 3, ..Default::default() })
        .unwrap()
        .remove(0);
    assert_eq!(
        jresp.get("storage").unwrap().as_str(),
        Some(breply.storage.label()),
        "codecs disagree on the deployment tier"
    );
    server.shutdown();
}

/// JSON and binary clients hammer one server concurrently; everyone
/// gets correct answers and both codecs land in the wire counters.
#[test]
fn mixed_codec_clients_share_a_server() {
    let (server, _) = serve(2, Storage::F32);
    let addr = server.addr();
    let mut handles = Vec::new();
    for t in 0..3 {
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect_json(addr).unwrap();
            for i in 0..6 {
                let q = vec![(t * 6 + i) as f32 * 0.01 + 0.1; DIM];
                let r = c.query(&q, 2, 0.3, 0.2).unwrap();
                assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
            }
        }));
    }
    for t in 0..3 {
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect_binary(addr).unwrap();
            for i in 0..3 {
                let a = vec![(t * 3 + i) as f32 * 0.02 + 0.2; DIM];
                let b = vec![(t * 3 + i) as f32 * 0.03 + 0.3; DIM];
                let replies = c
                    .query_binary(
                        &[&a, &b],
                        &QueryOpts { k: 2, epsilon: 0.3, delta: 0.2, ..Default::default() },
                    )
                    .unwrap();
                assert_eq!(replies.len(), 2);
                assert!(replies.iter().all(|r| r.ok));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let mut c = Client::connect_json(addr).unwrap();
    let m = c.call(&Json::obj([("op", Json::Str("metrics".into()))])).unwrap();
    // 3 JSON clients × 6 lines (+ this metrics call) vs 3 binary
    // clients × 3 frames (a batch frame counts once).
    assert!(m.get("wire_json").unwrap().as_usize().unwrap() >= 18);
    assert_eq!(m.get("wire_binary").unwrap().as_usize(), Some(9));
    server.shutdown();
}

/// Pipeline/hedging-style load over the pin-honoring [`Client::connect`]
/// (line-JSON by default, binary on the CI `wire` leg): a sharded
/// deployment with an artificially slow shard and hedging enabled
/// serves concurrent exact queries correctly through whichever codec
/// the `RUST_PALLAS_WIRE` pin negotiates.
#[test]
fn hedged_sharded_load_over_negotiated_codec() {
    let ds = gaussian_dataset(160, DIM, 77);
    let data = ds.vectors.clone();
    let mut cfg = CoordinatorConfig {
        workers: 4,
        shard: ShardSpec::contiguous(2),
        ..Default::default()
    };
    cfg.debug_slow_shard = Some((0, Duration::from_millis(2)));
    cfg.hedge_delay = Some(Duration::from_micros(300));
    let coord = Arc::new(Coordinator::new(ds.vectors, cfg).unwrap());
    let server = Server::start(coord, "127.0.0.1:0", 16).unwrap();
    let addr = server.addr();
    let data = Arc::new(data);

    let mut handles = Vec::new();
    for t in 0..4u64 {
        let data = Arc::clone(&data);
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            for i in 0..6u64 {
                let q: Vec<f32> = (0..DIM)
                    .map(|j| ((j as u64 + t * 31 + i * 7) as f32 * 0.13).sin())
                    .collect();
                let r = c
                    .call(&Json::obj([
                        ("op", Json::Str("query".into())),
                        ("vector", Json::f32s(&q)),
                        ("k", Json::Num(3.0)),
                        ("mode", Json::Str("exact".into())),
                    ]))
                    .unwrap();
                assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "client {t} query {i}");
                let got: Vec<usize> = r
                    .get("indices")
                    .unwrap()
                    .as_f32_vec()
                    .unwrap()
                    .iter()
                    .map(|&x| x as usize)
                    .collect();
                assert_eq!(got, ground_truth(&data, &q, 3), "client {t} query {i}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    server.shutdown();
}

/// The three-way reply contract — exact-complete, degraded, shed —
/// rides both codecs off one live server and the two codecs agree on
/// every fidelity field:
///
/// * exact-complete: plain OK, `degraded == false`, ε̂ == 0, full
///   shard coverage;
/// * degraded: a FLOP budget of 1 forces a round-1 harvest on every
///   BOUNDEDME instance with n − k ≥ 2 (the halving schedule always
///   runs ≥ 2 rounds), so the reply carries `FLAG_DEGRADED` + ε̂ > 0
///   over binary and `degraded:true` + the same ε̂ over JSON;
/// * shed: an already-expired deadline on an unarmed (exact) query
///   sheds whole — `FLAG_SHED` with an empty body over binary, the
///   pre-anytime `"deadline exceeded (shed)"` error shape over JSON.
///
/// On the CI degrade leg (`RUST_PALLAS_FORCE_NO_DEGRADE=1`) harvesting
/// is pinned off, so the budget queries run to completion and must
/// reply clean — same frames, same wire, no degraded bit.
#[test]
fn three_way_reply_flags_ride_both_codecs() {
    let (server, _) = serve(2, Storage::F32);
    let mut json = Client::connect_json(server.addr()).unwrap();
    let mut bin = Client::connect_binary(server.addr()).unwrap();
    let q: Vec<f32> = (0..DIM).map(|i| (i as f32 * 0.23).sin()).collect();

    // --- exact-complete: BOUNDEDME without any budget or deadline.
    let clean = bin
        .query_binary(
            &[&q],
            &QueryOpts {
                k: 3,
                epsilon: 0.1,
                delta: 0.1,
                seed: 7,
                mode: QueryMode::BoundedMe,
                ..Default::default()
            },
        )
        .unwrap()
        .remove(0);
    assert!(clean.ok && !clean.shed && !clean.degraded);
    assert_eq!(clean.epsilon_hat, 0.0);
    assert_eq!((clean.covered, clean.shards_total), (2, 2));

    // --- degraded: FLOP budget of 1 harvests after round 1.
    let b = bin
        .query_binary(
            &[&q],
            &QueryOpts {
                k: 3,
                epsilon: 0.1,
                delta: 0.1,
                seed: 7,
                mode: QueryMode::BoundedMe,
                budget_flops: Some(1),
                ..Default::default()
            },
        )
        .unwrap()
        .remove(0);
    let j = json
        .call(&Json::obj([
            ("op", Json::Str("query".into())),
            ("vector", Json::f32s(&q)),
            ("k", Json::Num(3.0)),
            ("epsilon", Json::Num(0.1)),
            ("delta", Json::Num(0.1)),
            ("seed", Json::Num(7.0)),
            ("mode", Json::Str("bounded_me".into())),
            ("budget_flops", Json::Num(1.0)),
        ]))
        .unwrap();
    assert!(b.ok && !b.shed, "{:?}", b.error);
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(b.indices.len(), 3);
    if force_no_degrade_requested() {
        assert!(!b.degraded);
        assert_eq!(b.epsilon_hat, 0.0);
        assert_eq!(j.get("degraded").unwrap().as_bool(), Some(false));
    } else {
        assert!(b.degraded, "budget_flops=1 must harvest");
        assert!(b.epsilon_hat > 0.0 && b.epsilon_hat <= 0.1 + 1e-6);
        assert_eq!((b.covered, b.shards_total), (2, 2));
        assert_eq!(j.get("degraded").unwrap().as_bool(), Some(true));
        // Same query, same seed, per-item execution on both paths:
        // the codecs must agree on the achieved ε̂ to f32 bit-exactness
        // and on the harvested answer itself.
        let j_eps = j.get("epsilon_hat").unwrap().as_f64().unwrap() as f32;
        assert_eq!(j_eps.to_bits(), b.epsilon_hat.to_bits(), "ε̂ disagrees across codecs");
        let jindices: Vec<u64> = j
            .get("indices")
            .unwrap()
            .as_f32_vec()
            .unwrap()
            .iter()
            .map(|&x| x as u64)
            .collect();
        assert_eq!(jindices, b.indices, "harvested indices disagree across codecs");
    }
    assert_eq!(
        j.get("shards_total").unwrap().as_usize().unwrap() as u8,
        b.shards_total
    );

    // --- shed: an exact query whose deadline expired before admission.
    let s = bin
        .query_binary(
            &[&q],
            &QueryOpts {
                k: 3,
                mode: QueryMode::Exact,
                deadline: Some(Duration::from_nanos(1)),
                ..Default::default()
            },
        )
        .unwrap()
        .remove(0);
    assert!(!s.ok && s.shed && !s.degraded);
    assert!(s.indices.is_empty() && s.scores.is_empty());
    assert_eq!(s.epsilon_hat, 0.0);
    assert_eq!((s.covered, s.shards_total), (0, 2));
    // JSON keeps the pre-anytime contract: shed is an error reply.
    let js = json
        .call(&Json::obj([
            ("op", Json::Str("query".into())),
            ("vector", Json::f32s(&q)),
            ("k", Json::Num(3.0)),
            ("mode", Json::Str("exact".into())),
            ("deadline_ms", Json::Num(1e-6)),
        ]))
        .unwrap();
    assert_eq!(js.get("ok").unwrap().as_bool(), Some(false));
    assert!(js.get("error").unwrap().as_str().unwrap().contains("shed"));

    // The degraded traffic landed in the three-way metrics split
    // (one shed per codec, one harvest per codec).
    let m = json.call(&Json::obj([("op", Json::Str("metrics".into()))])).unwrap();
    assert_eq!(m.get("shed").unwrap().as_usize(), Some(2));
    let degraded = m.get("degraded").unwrap().as_usize().unwrap();
    if force_no_degrade_requested() {
        assert_eq!(degraded, 0);
    } else {
        assert_eq!(degraded, 2, "one budget harvest per codec");
    }
    server.shutdown();
}

/// The wire revision is negotiated **per frame**, not per connection: a
/// FLOP budget promotes its own query frame to `PLW2` (the v2 header
/// carries the extra `budget_flops` word), while a budget-free frame on
/// the very same socket stays byte-compatible v1 `PLW1` — and both are
/// answered correctly in order.
#[test]
fn plw2_negotiates_per_frame_over_tcp() {
    let (server, data) = serve(1, Storage::F32);
    let q: Vec<f32> = (0..DIM).map(|i| (i as f32 * 0.31).cos()).collect();

    let mut v2_wire = Vec::new();
    binary::encode_query_frame(
        &[&q],
        &QueryOpts {
            k: 3,
            epsilon: 0.1,
            delta: 0.1,
            mode: QueryMode::BoundedMe,
            budget_flops: Some(1),
            ..Default::default()
        },
        &mut v2_wire,
    )
    .unwrap();
    assert_eq!(&v2_wire[..4], &frame::MAGIC_V2, "budgeted frame must lead with PLW2");

    let mut v1_wire = Vec::new();
    binary::encode_query_frame(
        &[&q],
        &QueryOpts { k: 3, epsilon: 1e-9, mode: QueryMode::BoundedMe, ..Default::default() },
        &mut v1_wire,
    )
    .unwrap();
    assert_eq!(&v1_wire[..4], &MAGIC, "budget-free frame must stay v1");

    let mut stream = TcpStream::connect(server.addr()).unwrap();
    let mut dec = FrameDecoder::new();

    // v2 first (it also negotiates binary via the leading 'P').
    stream.write_all(&v2_wire).unwrap();
    let (op, body) = read_raw_frame(&mut stream, &mut dec);
    assert_eq!(op, frame::RESP_QUERY);
    let r2 = binary::decode_reply(&body).unwrap();
    assert!(r2.ok, "{:?}", r2.error);
    assert_eq!(r2.indices.len(), 3);
    if !force_no_degrade_requested() {
        assert!(r2.degraded && r2.epsilon_hat > 0.0);
    }

    // v1 on the same connection still decodes and answers exactly.
    stream.write_all(&v1_wire).unwrap();
    let (op, body) = read_raw_frame(&mut stream, &mut dec);
    assert_eq!(op, frame::RESP_QUERY);
    let r1 = binary::decode_reply(&body).unwrap();
    assert!(r1.ok && !r1.degraded && !r1.shed);
    let mut got: Vec<usize> = r1.indices.iter().map(|&i| i as usize).collect();
    got.sort_unstable();
    let mut want = ground_truth(&data, &q, 3);
    want.sort_unstable();
    assert_eq!(got, want);
    server.shutdown();
}

/// Every line-protocol op — mutate, trace, metrics_prom included —
/// works over binary transport, which is what lets the CI `wire` leg
/// replay the TCP batteries through the binary codec wholesale.
#[test]
fn all_ops_work_over_binary_transport() {
    let ds = gaussian_dataset(120, DIM, 5);
    let cfg = CoordinatorConfig {
        trace: bandit_mips::trace::TraceConfig { enabled: true, ..Default::default() },
        ..Default::default()
    };
    let coord = Arc::new(Coordinator::new(ds.vectors, cfg).unwrap());
    let server = Server::start(coord, "127.0.0.1:0", 4).unwrap();
    let mut c = Client::connect_binary(server.addr()).unwrap();

    // mutate: plant a spike, then find it with a binary query frame.
    let ones: Vec<f32> = vec![1.0; DIM];
    let m = c
        .call(&Json::obj([
            ("op", Json::Str("mutate".into())),
            ("appends", Json::Arr(vec![Json::f32s(&ones)])),
        ]))
        .unwrap();
    assert_eq!(m.get("ok").unwrap().as_bool(), Some(true), "{m:?}");
    assert_eq!(m.get("generation").unwrap().as_usize(), Some(1));
    let reply = c
        .query_binary(
            &[&ones],
            &QueryOpts { k: 1, mode: QueryMode::Exact, ..Default::default() },
        )
        .unwrap()
        .remove(0);
    assert!(reply.ok);
    assert_eq!(reply.generation, 1);
    assert_eq!(reply.indices, vec![120u64]);

    // trace: the flight recorder saw the query and carries its decode
    // span (stamped by the binary codec before submission).
    std::thread::sleep(Duration::from_millis(50));
    let t = c
        .call(&Json::obj([
            ("op", Json::Str("trace".into())),
            ("limit", Json::Num(8.0)),
        ]))
        .unwrap();
    assert_eq!(t.get("ok").unwrap().as_bool(), Some(true));
    let Json::Arr(traces) = t.get("traces").unwrap() else { panic!() };
    assert!(!traces.is_empty());
    let mut saw_decode = false;
    for tr in traces {
        if let Some(Json::Arr(spans)) = tr.get("spans") {
            saw_decode |= spans
                .iter()
                .any(|s| s.get("label").and_then(Json::as_str) == Some("decode"));
        }
    }
    assert!(saw_decode, "no decode span in binary-transport traces");

    // metrics_prom: exposition renders, wire counters included.
    let p = c.call(&Json::obj([("op", Json::Str("metrics_prom".into()))])).unwrap();
    assert_eq!(p.get("ok").unwrap().as_bool(), Some(true));
    let body = p.get("body").unwrap().as_str().unwrap();
    assert!(body.contains("pallas_wire_requests_total{codec=\"binary\"}"));
    server.shutdown();
}
