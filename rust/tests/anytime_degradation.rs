//! Acceptance battery for anytime BOUNDEDME and deadline-aware graceful
//! degradation (harvest-not-shed):
//!
//! 1. **Harvested answers honor the reported ε̂** — a budget-cut query
//!    returns a checkpointed top-k whose arms are ε̂-optimal against the
//!    TRUE f32 scores with per-query failure probability ≤ δ, judged
//!    with the same Binomial(Q, δ) + 3σ budget as `quant_tier.rs`, on
//!    every storage tier.
//! 2. **Off-path bit-identity** — queries with no deadline and no FLOP
//!    budget answer bit-for-bit the same whether harvesting is enabled
//!    or not, across storage tiers and S ∈ {1, 2, 4}; and unarmed
//!    queries stay bit-identical even when budget-armed queries ride
//!    the same batches (the armed gating must not perturb them).
//! 3. **Exact harvest-vs-shed accounting under stragglers** — with an
//!    injected slow shard, every reply is exactly one of shed /
//!    degraded / clean and the metrics three-way split matches the
//!    replies one for one.
//!
//! Under the CI `degrade` leg (`RUST_PALLAS_FORCE_NO_DEGRADE=1`) the
//! budgets are dead switches: the same battery then proves harvests
//! never fire and budget-armed runs are bit-identical to plain ones.

use bandit_mips::algos::{BoundedMeIndex, MipsParams};
use bandit_mips::bandit::{force_no_degrade_requested, AnytimeBudget};
use bandit_mips::coordinator::{Coordinator, CoordinatorConfig, QueryRequest};
use bandit_mips::data::quant::Storage;
use bandit_mips::data::shard::ShardSpec;
use bandit_mips::data::synthetic::gaussian_dataset;
use bandit_mips::exec::QueryContext;
use bandit_mips::linalg::{dot, Matrix, Rng};
use std::time::Duration;

const TIERS: [Storage; 4] = [Storage::F32, Storage::F16, Storage::Bf16, Storage::Int8];

/// Binomial(Q, δ) upper bound with 3σ of slack (+1 so tiny Q·δ never
/// rounds to an impossible zero-tolerance) — same budget as the
/// quant-tier battery.
fn violation_budget(n_queries: usize, delta: f64) -> usize {
    let q = n_queries as f64;
    (q * delta + 3.0 * (q * delta * (1.0 - delta)).sqrt() + 1.0).ceil() as usize
}

/// k-th best TRUE inner product of `data` against `q`.
fn kth_true_score(data: &Matrix, q: &[f32], k: usize) -> f64 {
    let mut truth: Vec<f32> = (0..data.rows()).map(|i| dot(data.row(i), q)).collect();
    truth.sort_by(|a, b| b.partial_cmp(a).unwrap());
    truth[k - 1] as f64
}

/// A 1-pull FLOP budget: exhausts at the first round boundary, so any
/// instance with more than k+1 arms (≥ 2 elimination rounds) harvests
/// its round-1 checkpoint.
const TINY: AnytimeBudget = AnytimeBudget { deadline: None, budget_flops: Some(1) };

#[test]
fn harvested_answers_satisfy_reported_epsilon_hat() {
    let data = gaussian_dataset(150, 64, 0xA17E).vectors;
    let mut rng = Rng::new(0xA17F);
    let queries: Vec<Vec<f32>> = (0..40).map(|_| rng.gaussian_vec(64)).collect();
    let params = MipsParams { k: 3, epsilon: 0.15, delta: 0.1, seed: 0 };
    let budget = violation_budget(queries.len(), params.delta);
    for storage in TIERS {
        let idx = BoundedMeIndex::new(data.clone()).with_storage(storage);
        let tier = idx.storage();
        let mut ctx = QueryContext::new();
        let mut violations = 0usize;
        for (qi, q) in queries.iter().enumerate() {
            let p = MipsParams { seed: qi as u64, ..params };
            let (res, harvest) = idx.query_with_tier_budget(q, &p, &mut ctx, tier, TINY);
            if force_no_degrade_requested() {
                // Degrade pin live (CI `degrade` leg): the budget must be
                // inert — no harvest, bit-identical to the plain run.
                assert!(harvest.is_none(), "{} q{qi}: pinned run harvested", tier.label());
                let mut ctx2 = QueryContext::new();
                let plain = idx.query_with_tier(q, &p, &mut ctx2, tier);
                assert_eq!(res.indices, plain.indices, "{} q{qi}", tier.label());
                assert_eq!(res.flops, plain.flops, "{} q{qi}", tier.label());
                continue;
            }
            let h = harvest.unwrap_or_else(|| {
                panic!("{} q{qi}: 1-flop budget must harvest", tier.label())
            });
            assert!(h.rounds >= 1, "{} q{qi}", tier.label());
            assert_eq!(res.indices.len(), params.k, "{} q{qi}", tier.label());
            // ε̂ is request-relative: strictly tighter than the asked ε
            // (a harvest degrades *achieved* width, never past ε) and
            // strictly positive (a partial run can't claim full width).
            assert!(
                h.epsilon_hat > 0.0 && h.epsilon_hat <= params.epsilon + 1e-12,
                "{} q{qi}: eps_hat {} outside (0, {}]",
                tier.label(),
                h.epsilon_hat,
                params.epsilon
            );
            // The harvested arms must be ε̂-optimal against TRUE scores
            // (same range normalization as the quant battery: ε̂ is a
            // fraction of the ±reward_bound range, scores are N·mean).
            let slack = h.epsilon_hat
                * 2.0
                * idx.reward_bound(q).max(f32::MIN_POSITIVE) as f64
                * data.cols() as f64;
            let kth = kth_true_score(&data, q, params.k);
            let ok = res
                .indices
                .iter()
                .all(|&arm| dot(data.row(arm), q) as f64 >= kth - slack - 1e-3);
            if !ok {
                violations += 1;
            }
        }
        assert!(
            violations <= budget,
            "{}: {violations} ε̂-violations over {} harvested queries (budget {budget})",
            tier.label(),
            queries.len()
        );
    }
}

/// Submit the same BOUNDEDME queries (distinct seeds, no deadline, no
/// budget) and collect the responses in submission order.
fn run_unarmed(
    c: &Coordinator,
    queries: &[Vec<f32>],
) -> Vec<bandit_mips::coordinator::QueryResponse> {
    let rxs: Vec<_> = queries
        .iter()
        .enumerate()
        .map(|(i, q)| {
            let mut req = QueryRequest::bounded_me(q.clone(), 3, 0.15, 0.1);
            req.seed = i as u64;
            c.submit(req).unwrap()
        })
        .collect();
    rxs.into_iter().map(|rx| rx.recv().unwrap()).collect()
}

#[test]
fn no_deadline_queries_bit_identical_with_harvest_on_and_off() {
    let ds = gaussian_dataset(400, 64, 0xB3D1);
    let mut rng = Rng::new(0xB3D2);
    let queries: Vec<Vec<f32>> = (0..12).map(|_| rng.gaussian_vec(64)).collect();
    for shards in [1usize, 2, 4] {
        for storage in TIERS {
            let cfg = |harvest: bool| CoordinatorConfig {
                workers: 2,
                shard: ShardSpec::contiguous(shards),
                storage,
                harvest,
                ..Default::default()
            };
            let on = Coordinator::new(ds.vectors.clone(), cfg(true)).unwrap();
            let off = Coordinator::new(ds.vectors.clone(), cfg(false)).unwrap();
            let ra = run_unarmed(&on, &queries);
            let rb = run_unarmed(&off, &queries);
            for (qi, (a, b)) in ra.iter().zip(&rb).enumerate() {
                let tag = format!("S={shards} {} q{qi}", storage.label());
                assert!(!a.shed && !a.degraded, "{tag}: spurious shed/degrade");
                assert_eq!(a.epsilon_hat, 0.0, "{tag}");
                assert_eq!(a.indices, b.indices, "{tag}");
                assert_eq!(a.flops, b.flops, "{tag}");
                for (x, y) in a.scores.iter().zip(&b.scores) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{tag}: score bits");
                }
            }
            assert_eq!(on.metrics().degraded, 0);
            on.shutdown();
            off.shutdown();
        }
    }
}

#[test]
fn armed_neighbors_do_not_perturb_unarmed_queries() {
    // Budget-armed queries force their batches onto the per-item
    // serving path; the unarmed queries sharing those batches must
    // still answer bit-identically to a coordinator that never saw an
    // armed query (per-item ≡ fused is the contract that makes the
    // gating safe).
    let ds = gaussian_dataset(400, 64, 0xC4D1);
    let mut rng = Rng::new(0xC4D2);
    let queries: Vec<Vec<f32>> = (0..10).map(|_| rng.gaussian_vec(64)).collect();
    for shards in [1usize, 2] {
        let cfg = CoordinatorConfig {
            workers: 2,
            shard: ShardSpec::contiguous(shards),
            max_batch: 8,
            batch_timeout: Duration::from_millis(2),
            ..Default::default()
        };
        let pure = Coordinator::new(ds.vectors.clone(), cfg.clone()).unwrap();
        let mixed = Coordinator::new(ds.vectors.clone(), cfg).unwrap();
        let want = run_unarmed(&pure, &queries);

        // Interleave: every unarmed query is chased by an armed twin
        // with a generous deadline (same knobs, so the batcher fuses
        // them into the same groups).
        let rxs: Vec<_> = queries
            .iter()
            .enumerate()
            .map(|(i, q)| {
                let mut req = QueryRequest::bounded_me(q.clone(), 3, 0.15, 0.1);
                req.seed = i as u64;
                let rx = mixed.submit(req).unwrap();
                let mut armed = QueryRequest::bounded_me(q.clone(), 3, 0.15, 0.1)
                    .with_deadline(Duration::from_secs(30));
                armed.seed = 1000 + i as u64;
                let _armed_rx = mixed.submit(armed).unwrap();
                rx
            })
            .collect();
        let got: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        for (qi, (a, b)) in got.iter().zip(&want).enumerate() {
            let tag = format!("S={shards} q{qi}");
            assert!(!a.shed && !a.degraded, "{tag}");
            assert_eq!(a.indices, b.indices, "{tag}");
            assert_eq!(a.flops, b.flops, "{tag}");
            for (x, y) in a.scores.iter().zip(&b.scores) {
                assert_eq!(x.to_bits(), y.to_bits(), "{tag}: score bits");
            }
        }
        pure.shutdown();
        mixed.shutdown();
    }
}

#[test]
fn straggler_split_accounting_is_exact() {
    // Two shards, shard 1 artificially slow past the deadline. Armed
    // queries harvest the fast shard (degraded, coverage 1/2) — or, on
    // the degrade-pinned CI leg, shed whole. Either way every reply is
    // exactly one of shed / degraded / clean, and the metrics split
    // matches the replies one for one.
    let ds = gaussian_dataset(600, 64, 0xD5E1);
    let cfg = CoordinatorConfig {
        workers: 2,
        shard: ShardSpec::contiguous(2),
        debug_slow_shard: Some((1, Duration::from_millis(150))),
        ..Default::default()
    };
    let c = Coordinator::new(ds.vectors.clone(), cfg).unwrap();
    let mut rxs = Vec::new();
    for i in 0..12u64 {
        let mut req = QueryRequest::bounded_me(ds.vectors.row(i as usize).to_vec(), 3, 0.2, 0.1)
            .with_deadline(Duration::from_millis(40));
        req.seed = i;
        rxs.push(c.submit(req).unwrap());
    }
    // No-deadline traffic rides along and must stay clean (it waits the
    // straggler out).
    for i in 0..6u64 {
        let mut req =
            QueryRequest::bounded_me(ds.vectors.row(100 + i as usize).to_vec(), 3, 0.2, 0.1);
        req.seed = 100 + i;
        rxs.push(c.submit(req).unwrap());
    }
    let (mut sheds, mut degradeds, mut clean) = (0u64, 0u64, 0u64);
    for rx in rxs {
        let resp = rx.recv().unwrap();
        match (resp.shed, resp.degraded) {
            (true, true) => panic!("reply is both shed and degraded"),
            (true, false) => {
                assert!(resp.indices.is_empty(), "shed reply carries results");
                assert_eq!(resp.shards, 0);
                assert_eq!(resp.epsilon_hat, 0.0);
                sheds += 1;
            }
            (false, true) => {
                assert!(!resp.indices.is_empty(), "degraded reply carries no results");
                assert!(
                    resp.shards < resp.shards_total || resp.epsilon_hat > 0.0,
                    "degraded reply shows neither partial coverage nor a harvest"
                );
                degradeds += 1;
            }
            (false, false) => {
                assert_eq!(resp.indices.len(), 3);
                assert_eq!(resp.shards, resp.shards_total);
                assert_eq!(resp.epsilon_hat, 0.0);
                clean += 1;
            }
        }
    }
    assert_eq!(sheds + degradeds + clean, 18);
    assert!(clean >= 6, "no-deadline queries must never shed or degrade");
    if force_no_degrade_requested() {
        assert_eq!(degradeds, 0, "pinned run produced degraded replies");
    } else {
        assert!(
            degradeds > 0,
            "the fast shard's partials should harvest into degraded replies"
        );
    }
    let m = c.metrics();
    assert_eq!(m.shed, sheds);
    assert_eq!(m.degraded, degradeds);
    assert_eq!(m.queries, degradeds + clean);
    assert_eq!(m.submitted, 18);
    c.shutdown();
}

#[test]
fn budget_flops_harvests_on_every_tier() {
    // Deployment-tier sweep of the FLOP budget at the coordinator
    // level: a 1-pull budget degrades (with a usable ε̂) on the live
    // path and is provably inert on the degrade-pinned CI leg.
    let ds = gaussian_dataset(500, 64, 0xE6F1);
    for storage in TIERS {
        let cfg = CoordinatorConfig { workers: 2, storage, ..Default::default() };
        let c = Coordinator::new(ds.vectors.clone(), cfg).unwrap();
        for i in 0..4u64 {
            let mut req = QueryRequest::bounded_me(ds.vectors.row(i as usize).to_vec(), 3, 0.15, 0.1)
                .with_budget_flops(1);
            req.seed = i;
            let resp = c.query_blocking(req).unwrap();
            assert!(!resp.shed, "{} q{i}: budget must harvest, not shed", storage.label());
            assert_eq!(resp.indices.len(), 3, "{} q{i}", storage.label());
            if force_no_degrade_requested() {
                assert!(!resp.degraded, "{} q{i}: pinned run degraded", storage.label());
                assert_eq!(resp.epsilon_hat, 0.0, "{} q{i}", storage.label());
            } else {
                assert!(resp.degraded, "{} q{i}: budget did not degrade", storage.label());
                assert!(
                    resp.epsilon_hat > 0.0 && resp.epsilon_hat <= 0.15 + 1e-12,
                    "{} q{i}: eps_hat {}",
                    storage.label(),
                    resp.epsilon_hat
                );
            }
        }
        c.shutdown();
    }
}
