//! Concurrent-equivalence battery for generation-swapped live mutation:
//! writer threads stream upsert/delete/append batches through
//! [`Coordinator::mutate`] while query threads keep traffic in flight,
//! and **every** answer must be correct for *some* generation snapshot
//! whose lifetime overlapped the query — a linearizability-style
//! witness, not a "mostly fresh" smoke test.
//!
//! The witness works because the coordinator exposes both ends of the
//! overlap window:
//!
//! * `generation()` — highest id every serving thread had acked before
//!   the query was submitted (no answer may be older), and
//! * `latest_generation()` — highest id any `mutate` call had started
//!   flipping to by the time the reply arrived (no answer may be newer).
//!
//! A shadow catalog maps generation id → materialized snapshot (the
//! writer records the snapshot *before* calling `mutate`, so any id a
//! reply can carry is already resolvable). Exact answers must match the
//! snapshot's ground truth in order; BOUNDEDME answers use ε → 0, where
//! elimination is provably exact, and must match as a set (concurrent
//! batches may fuse under the first item's pull-order seed, so score
//! bits are checked single-threadedly in `prop_invariants`, not here).
//!
//! The battery runs the S = 1 direct fast path, the forced-reactor
//! S = 1 path, and sharded S ∈ {2, 4} (both split kinds), exact and
//! BOUNDEDME interleaved. After the churn quiesces, the epoch gauge
//! must report exactly one generation alive — the reclamation leak
//! check.
//!
//! Set `RUST_PALLAS_STRESS=1` to multiply batch and query counts (the
//! CI `churn` stress leg runs this battery in release mode).

use bandit_mips::algos::ground_truth;
use bandit_mips::bandit::PullOrder;
use bandit_mips::coordinator::{Backend, Coordinator, CoordinatorConfig, QueryRequest};
use bandit_mips::data::generation::{Delta, Generation, GenerationBuilder};
use bandit_mips::data::shard::ShardSpec;
use bandit_mips::data::synthetic::gaussian_dataset;
use bandit_mips::linalg::{Matrix, Rng};
use bandit_mips::sync::EpochGauge;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Burst multiplier: 1 normally, 8 under `RUST_PALLAS_STRESS=1`.
fn stress() -> u64 {
    match std::env::var("RUST_PALLAS_STRESS") {
        Ok(v) if v == "1" => 8,
        _ => 1,
    }
}

fn cfg(workers: usize, shard: ShardSpec, force_reactor: bool) -> CoordinatorConfig {
    CoordinatorConfig {
        workers,
        max_batch: 8,
        batch_timeout: Duration::from_millis(1),
        queue_capacity: 4096,
        backend: Backend::Native,
        pull_order: PullOrder::BlockShuffled(16),
        shard,
        force_reactor,
        ..Default::default()
    }
}

/// One deterministic delta batch. Batches rotate through pure upserts
/// (COW shard reuse), mixed upsert/delete/append (full rebalance), and
/// growth-only appends; ids are arranged to never upsert and delete the
/// same row in one batch.
fn delta_batch(b: u64, rows: usize, dim: usize) -> Vec<Delta> {
    let vec_for = |salt: u64| {
        Rng::new(0xD00D_5EED ^ (b << 20) ^ salt.wrapping_mul(0x9E37_79B9)).gaussian_vec(dim)
    };
    let bu = b as usize;
    match b % 3 {
        0 => {
            let a = (bu * 7 + 3) % rows;
            let mut c = (bu * 13 + 11) % rows;
            if c == a {
                c = (c + 1) % rows;
            }
            vec![
                Delta::Upsert { id: a, vector: vec_for(1) },
                Delta::Upsert { id: c, vector: vec_for(2) },
            ]
        }
        1 => {
            let up = (bu * 5) % rows;
            let mut del = (bu * 17 + 1) % rows;
            if del == up {
                del = (del + 1) % rows;
            }
            vec![
                Delta::Upsert { id: up, vector: vec_for(3) },
                Delta::Delete { id: del },
                Delta::Append { vector: vec_for(4) },
            ]
        }
        _ => vec![
            Delta::Append { vector: vec_for(5) },
            Delta::Append { vector: vec_for(6) },
        ],
    }
}

/// Run the concurrent battery against one deployment shape. Returns the
/// number of queries answered (for the caller's metrics assertions).
fn run_battery(spec: ShardSpec, workers: usize, force_reactor: bool, seed: u64) {
    let n = 120;
    let dim = 48;
    let k = 4;
    let batches = 6 * stress();
    let min_queries = 24 * stress();
    let query_threads = 2usize;

    let ds = gaussian_dataset(n, dim, seed);
    let shards = spec.shards();
    let c = Arc::new(Coordinator::new(ds.vectors.clone(), cfg(workers, spec, force_reactor)).unwrap());

    // Shadow catalog: generation id → materialized snapshot. Written by
    // the mutator *before* the coordinator flips, so every id a reply
    // can legally carry resolves here.
    let snaps: Arc<Mutex<HashMap<u64, Matrix>>> = Arc::new(Mutex::new(HashMap::new()));
    snaps.lock().unwrap().insert(0, ds.vectors.clone());

    let done = Arc::new(AtomicBool::new(false));

    // Writer: stream delta batches through a shadow GenerationBuilder
    // (content is spec-independent: surviving rows in base order, then
    // appends) and then through the live coordinator.
    let mutator = {
        let c = c.clone();
        let snaps = snaps.clone();
        let mut shadow = Generation::initial(ds.vectors.clone(), ShardSpec::single(), EpochGauge::new());
        std::thread::spawn(move || {
            for b in 0..batches {
                let deltas = delta_batch(b, shadow.rows(), shadow.dim());
                let mut bld = GenerationBuilder::new(&shadow);
                for d in &deltas {
                    bld.apply(d).unwrap();
                }
                let built = bld.build().unwrap();
                snaps
                    .lock()
                    .unwrap()
                    .insert(built.generation.id(), built.generation.materialize());
                shadow = built.generation.clone();
                let out = c.mutate(&deltas).unwrap();
                assert_eq!(out.generation, shadow.id(), "coordinator/shadow ids diverged");
                assert_eq!(out.rows, shadow.rows(), "coordinator/shadow rows diverged");
                // Let queries land on this generation before the next flip.
                std::thread::sleep(Duration::from_millis(2));
            }
        })
    };

    let mut readers = Vec::new();
    for t in 0..query_threads {
        let c = c.clone();
        let snaps = snaps.clone();
        let done = done.clone();
        let ds = ds.clone();
        readers.push(std::thread::spawn(move || {
            let mut i = 0u64;
            while !done.load(Ordering::Relaxed) || i < min_queries {
                let salt = t as u64 * 1_000_003 + i;
                let q = ds.sample_query(salt);
                let exact = (t as u64 + i) % 2 == 0;
                let req = if exact {
                    QueryRequest::exact(q.clone(), k)
                } else {
                    // ε → 0: elimination recovers the exact top-k set.
                    QueryRequest::bounded_me(q.clone(), k, 1e-9, 0.05)
                };
                let g_lo = c.generation();
                let resp = c.query_blocking(req).unwrap();
                let g_hi = c.latest_generation();
                assert!(!resp.shed, "no deadline set, nothing may shed");
                assert!(
                    g_lo <= resp.generation && resp.generation <= g_hi,
                    "witness violated: answer generation {} outside [{g_lo}, {g_hi}]",
                    resp.generation
                );
                assert_eq!(resp.shards, shards, "wrong fan-out width");
                let snap = snaps
                    .lock()
                    .unwrap()
                    .get(&resp.generation)
                    .unwrap_or_else(|| panic!("reply carries unknown generation {}", resp.generation))
                    .clone();
                let truth = ground_truth(&snap, &q, k);
                if exact {
                    assert_eq!(
                        resp.indices, truth,
                        "exact answer wrong for generation {} (thread {t}, query {i})",
                        resp.generation
                    );
                } else {
                    let mut got = resp.indices.clone();
                    got.sort_unstable();
                    let mut want = truth;
                    want.sort_unstable();
                    assert_eq!(
                        got, want,
                        "ε→0 BOUNDEDME set wrong for generation {} (thread {t}, query {i})",
                        resp.generation
                    );
                }
                i += 1;
            }
            i
        }));
    }

    mutator.join().unwrap();
    done.store(true, Ordering::Relaxed);
    let mut total = 0u64;
    for r in readers {
        total += r.join().unwrap();
    }

    // Epoch-reclamation leak check: once churn quiesces, only the live
    // generation may hold a guard (superseded sets are reclaimed when
    // their last pin drops — poll briefly for trailing batches).
    let deadline = Instant::now() + Duration::from_secs(10);
    while c.generations_alive() > 1 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(
        c.generations_alive(),
        1,
        "epoch leak: superseded generations still pinned after quiesce"
    );
    assert_eq!(c.generation(), batches, "not every flip was acked");
    assert_eq!(c.latest_generation(), batches);

    let m = c.metrics();
    assert_eq!(m.queries, total, "lost or double-counted queries under churn");
    assert_eq!(m.mutations, batches);
    assert_eq!(m.shed, 0);

    if let Ok(c) = Arc::try_unwrap(c) {
        c.shutdown();
    }
}

#[test]
fn battery_s1_direct_fast_path() {
    run_battery(ShardSpec::single(), 2, false, 0xA11CE);
}

#[test]
fn battery_s1_forced_reactor() {
    run_battery(ShardSpec::single(), 2, true, 0xB0B);
}

#[test]
fn battery_s2_contiguous() {
    run_battery(ShardSpec::contiguous(2), 2, false, 0xCAFE);
}

#[test]
fn battery_s4_round_robin() {
    run_battery(ShardSpec::round_robin(4), 4, false, 0xD1CE);
}

/// Deterministic (single-threaded) flip sequence: after every batch the
/// coordinator's answers equal ground truth on the shadow snapshot, the
/// reported generation is exactly the flip count, and the superseded
/// generation is reclaimed immediately (no traffic holds it).
#[test]
fn serial_flips_track_snapshots_exactly() {
    let ds = gaussian_dataset(90, 32, 0x5E7);
    let c = Coordinator::new(ds.vectors.clone(), cfg(2, ShardSpec::contiguous(2), false)).unwrap();
    let mut shadow = Generation::initial(ds.vectors.clone(), ShardSpec::single(), EpochGauge::new());
    for b in 0..9 * stress() {
        let deltas = delta_batch(b, shadow.rows(), shadow.dim());
        let mut bld = GenerationBuilder::new(&shadow);
        for d in &deltas {
            bld.apply(d).unwrap();
        }
        shadow = bld.build().unwrap().generation.clone();
        let out = c.mutate(&deltas).unwrap();
        assert_eq!(out.generation, b + 1);
        let snap = shadow.materialize();
        for salt in 0..3u64 {
            let q = ds.sample_query(b * 100 + salt);
            let resp = c.query_blocking(QueryRequest::exact(q.clone(), 5)).unwrap();
            assert_eq!(resp.generation, b + 1);
            assert_eq!(resp.indices, ground_truth(&snap, &q, 5), "batch {b} salt {salt}");
            let resp =
                c.query_blocking(QueryRequest::bounded_me(q.clone(), 5, 1e-9, 0.05)).unwrap();
            assert_eq!(resp.generation, b + 1);
            let mut got = resp.indices.clone();
            got.sort_unstable();
            let mut want = ground_truth(&snap, &q, 5);
            want.sort_unstable();
            assert_eq!(got, want, "batch {b} salt {salt} (bounded_me)");
        }
        assert_eq!(c.generations_alive(), 1, "batch {b}: superseded generation leaked");
    }
    c.shutdown();
}

/// A batch the builder rejects (bad row id) must leave the serving
/// generation untouched and not poison the writer lock.
#[test]
fn rejected_batch_leaves_generation_live() {
    let ds = gaussian_dataset(60, 32, 0xBAD);
    let c = Coordinator::new(ds.vectors.clone(), cfg(2, ShardSpec::single(), false)).unwrap();
    let err = c.mutate(&[Delta::Delete { id: 999 }]).unwrap_err();
    assert!(err.to_string().contains("mutation rejected"), "{err}");
    assert_eq!(c.generation(), 0);
    assert_eq!(c.generations_alive(), 1);
    let q = ds.sample_query(1);
    let resp = c.query_blocking(QueryRequest::exact(q.clone(), 3)).unwrap();
    assert_eq!(resp.generation, 0);
    assert_eq!(resp.indices, ground_truth(&ds.vectors, &q, 3));
    // The next well-formed batch still flips.
    let out = c.mutate(&[Delta::Append { vector: ds.sample_query(2) }]).unwrap();
    assert_eq!(out.generation, 1);
    c.shutdown();
}
