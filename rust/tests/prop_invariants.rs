//! Randomized property tests over the DESIGN.md §5 invariants.
//!
//! proptest is unavailable offline, so these drive the crate's own
//! deterministic RNG through many random instances per property —
//! failures print the offending seed for replay.

use bandit_mips::algos::{BoundedMeIndex, MipsIndex, MipsParams, NaiveIndex};
use bandit_mips::bandit::{
    hoeffding_sample_size, m_bounded, serfling_radius, AdversarialArms, BanditScratch,
    BoundedMe, BoundedMeConfig, Compaction, ExplicitArms, MatrixArms, PullOrder, RewardSource,
};
use bandit_mips::data::shard::ShardSpec;
use bandit_mips::exec::shard::ShardedIndex;
use bandit_mips::exec::{QueryContext, QueryPlan};
use bandit_mips::linalg::{topk::arg_top_k, Matrix, Rng};

const CASES: usize = 60;

/// m(u) ∈ [1, N], monotone: smaller ε / δ ⇒ more pulls; → N as ε → 0.
#[test]
fn prop_m_bounded_within_list_and_monotone() {
    let mut rng = Rng::new(0xA11CE);
    for case in 0..CASES {
        let n_list = 2 + rng.next_below(1_000_000);
        let eps = rng.uniform(1e-4, 0.9);
        let delta = rng.uniform(1e-4, 0.9);
        let range = rng.uniform(0.1, 50.0);
        let m = m_bounded(eps, delta, n_list, range);
        assert!((1..=n_list).contains(&m), "case {case}: m={m} N={n_list}");
        let m_tighter_eps = m_bounded(eps * 0.5, delta, n_list, range);
        assert!(m_tighter_eps >= m, "case {case}: ε-monotonicity");
        let m_tighter_delta = m_bounded(eps, delta * 0.5, n_list, range);
        assert!(m_tighter_delta >= m, "case {case}: δ-monotonicity");
        assert_eq!(m_bounded(0.0, delta, n_list, range), n_list, "case {case}");
        // Never worse than Hoeffding.
        assert!(
            m <= hoeffding_sample_size(eps, delta, range).max(1),
            "case {case}: m exceeds Hoeffding"
        );
    }
}

/// Serfling radius ∈ [0, ∞), 0 at m=N, decreasing in m.
#[test]
fn prop_serfling_radius_shrinks_to_zero() {
    let mut rng = Rng::new(0xBEE5);
    for case in 0..CASES {
        let n_list = 10 + rng.next_below(10_000);
        let delta = rng.uniform(1e-3, 0.5);
        let range = rng.uniform(0.1, 10.0);
        let mut prev = f64::INFINITY;
        let steps = 8;
        for s in 1..=steps {
            let m = (n_list * s) / steps;
            let r = serfling_radius(m.max(1), n_list, delta, range);
            assert!(r >= 0.0 && r <= prev + 1e-12, "case {case} step {s}: {r} > {prev}");
            prev = r;
        }
        assert_eq!(serfling_radius(n_list, n_list, delta, range), 0.0);
    }
}

/// BOUNDEDME structural invariants on random instances: exactly K
/// distinct arms, per-arm pulls ≤ N, total ≤ n·N, and exact recovery as
/// ε → 0.
#[test]
fn prop_bounded_me_structure() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..30 {
        let n = 2 + rng.next_below(80);
        let n_list = 2 + rng.next_below(200);
        let k = 1 + rng.next_below(n.min(8));
        let lists: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..n_list).map(|_| rng.next_f64()).collect())
            .collect();
        let env = ExplicitArms::new(lists).with_range(0.0, 1.0);
        let eps = rng.uniform(1e-9, 0.5);
        let delta = rng.uniform(0.01, 0.4);
        let out = BoundedMe::new(BoundedMeConfig { k, epsilon: eps, delta }).run(&env);

        assert_eq!(out.result.arms.len(), k.min(n), "case {case}");
        let mut sorted = out.result.arms.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), k.min(n), "case {case}: duplicates");
        assert!(out.result.total_pulls <= (n * n_list) as u64, "case {case}");
        for t in &out.trace {
            assert!(t.t_l <= n_list, "case {case}: t_l > N");
        }
    }
}

/// ε → 0 forces exact top-K on any instance (elimination on true means).
#[test]
fn prop_bounded_me_exact_at_zero_epsilon() {
    let mut rng = Rng::new(0xDEAD);
    for case in 0..20 {
        let n = 5 + rng.next_below(60);
        let n_list = 5 + rng.next_below(100);
        let k = 1 + rng.next_below(4.min(n));
        let lists: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..n_list).map(|_| rng.next_f64()).collect())
            .collect();
        let env = ExplicitArms::new(lists).with_range(0.0, 1.0);
        let out =
            BoundedMe::new(BoundedMeConfig { k, epsilon: 1e-12, delta: 0.05 }).run(&env);
        let mut truth: Vec<usize> = (0..n).collect();
        truth.sort_by(|&a, &b| {
            env.true_mean(b).partial_cmp(&env.true_mean(a)).unwrap()
        });
        truth.truncate(k);
        let mut got = out.result.arms.clone();
        got.sort_unstable();
        truth.sort_unstable();
        assert_eq!(got, truth, "case {case}");
    }
}

/// Sampling without replacement: the full pull equals the exact sum for
/// every pull order, and disjoint ranges compose.
#[test]
fn prop_matrix_arms_pull_composition() {
    let mut rng = Rng::new(0xFEED);
    for case in 0..CASES {
        let n = 1 + rng.next_below(20);
        let d = 2 + rng.next_below(100);
        let data = Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(d);
        let (lo, hi) = data.min_max();
        let max_abs = lo.abs().max(hi.abs()).max(1e-9);
        let order = match case % 3 {
            0 => PullOrder::Permuted,
            1 => PullOrder::Sequential,
            _ => PullOrder::BlockShuffled(1 + rng.next_below(16)),
        };
        let arms = MatrixArms::new(&data, &q, max_abs, order, case as u64);
        let arm = rng.next_below(n);
        let full = arms.pull_range(arm, 0, d);
        let exact = bandit_mips::linalg::dot(data.row(arm), &q) as f64;
        assert!(
            (full - exact).abs() < 1e-3 * (1.0 + exact.abs()),
            "case {case}: {full} vs {exact}"
        );
        let cut = rng.next_below(d);
        let split = arms.pull_range(arm, 0, cut) + arms.pull_range(arm, cut, d);
        assert!((split - full).abs() < 1e-3 * (1.0 + full.abs()), "case {case}");
    }
}

/// Adversarial arms: empirical mean after m pulls over-estimates the true
/// mean (1s first), and equals it exactly at m = N.
#[test]
fn prop_adversarial_prefix_bias() {
    let mut rng = Rng::new(0x5EED);
    for case in 0..CASES {
        let n_list = 10 + rng.next_below(500);
        let env = AdversarialArms::generate(5, n_list, case as u64);
        for arm in 0..5 {
            let m = 1 + rng.next_below(n_list);
            let emp = env.pull_range(arm, 0, m) / m as f64;
            let truth = env.true_mean(arm);
            assert!(emp >= truth - 1e-12, "case {case}: prefix under-estimates");
            let full = env.pull_range(arm, 0, n_list) / n_list as f64;
            assert!((full - truth).abs() < 1e-12, "case {case}");
        }
    }
}

/// TopK matches a full sort for random scores (ties included).
#[test]
fn prop_topk_matches_sort() {
    let mut rng = Rng::new(0x70D0);
    for case in 0..CASES {
        let n = 1 + rng.next_below(500);
        let k = 1 + rng.next_below(32);
        // Quantized scores to force ties.
        let scores: Vec<f32> =
            (0..n).map(|_| (rng.next_f64() * 8.0).floor() as f32).collect();
        let got = arg_top_k(&scores, k);
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| {
            scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b))
        });
        idx.truncate(k.min(n));
        assert_eq!(got, idx, "case {case}");
    }
}

/// Context reuse is invisible: `query_with` on one long-lived
/// `QueryContext` returns bit-identical results to a fresh context (and
/// to plain `query`) across random instances, orders, and knobs.
#[test]
fn prop_query_with_context_reuse_bit_identical() {
    let mut rng = Rng::new(0xCC7E);
    let mut ctx = QueryContext::new();
    for case in 0..25 {
        let n = 10 + rng.next_below(80);
        let d = 16 + rng.next_below(200);
        let data = Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32);
        let order = match case % 3 {
            0 => PullOrder::Permuted,
            1 => PullOrder::Sequential,
            _ => PullOrder::BlockShuffled(1 + rng.next_below(32)),
        };
        let idx = BoundedMeIndex::with_order(data, order);
        let q: Vec<f32> = rng.gaussian_vec(d);
        let params = MipsParams {
            k: 1 + rng.next_below(5),
            epsilon: rng.uniform(1e-6, 0.5),
            delta: rng.uniform(0.01, 0.4),
            seed: case as u64,
        };
        let fresh = idx.query_with(&q, &params, &mut QueryContext::new());
        let reused = idx.query_with(&q, &params, &mut ctx);
        let plain = idx.query(&q, &params);
        assert_eq!(fresh.indices, reused.indices, "case {case}");
        assert_eq!(fresh.flops, reused.flops, "case {case}");
        assert_eq!(plain.indices, reused.indices, "case {case}");
        for (a, b) in fresh.scores.iter().zip(&reused.scores) {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case}: score bits differ");
        }
    }
}

/// `query_batch` agrees with per-query `query` (same shared params) on
/// Gaussian data across seeds, for both BOUNDEDME and the fused naive
/// scan.
#[test]
fn prop_query_batch_agrees_with_single_queries() {
    let mut rng = Rng::new(0xBA7C);
    for case in 0..12 {
        let n = 20 + rng.next_below(100);
        let d = 32 + rng.next_below(128);
        let data = Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32);
        let nq = 2 + rng.next_below(6);
        let queries: Vec<Vec<f32>> = (0..nq).map(|_| rng.gaussian_vec(d)).collect();
        let refs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let params = MipsParams {
            k: 1 + rng.next_below(4),
            epsilon: rng.uniform(1e-9, 0.3),
            delta: 0.1,
            seed: 1000 + case as u64,
        };
        let mut ctx = QueryContext::new();

        let bme = BoundedMeIndex::with_order(
            data.clone(),
            PullOrder::BlockShuffled(1 + rng.next_below(48)),
        );
        let batch = bme.query_batch(&refs, &params, &mut ctx);
        for (i, q) in queries.iter().enumerate() {
            let single = bme.query(q, &params);
            assert_eq!(batch[i].indices, single.indices, "case {case} bme q{i}");
            assert_eq!(batch[i].flops, single.flops, "case {case} bme q{i}");
        }

        let naive = NaiveIndex::new(data);
        let batch = naive.query_batch(&refs, &params, &mut ctx);
        for (i, q) in queries.iter().enumerate() {
            let single = naive.query(q, &params);
            assert_eq!(batch[i].indices, single.indices, "case {case} naive q{i}");
            assert_eq!(batch[i].scores, single.scores, "case {case} naive q{i}");
        }
    }
}

/// BOUNDEDME with a reused `BanditScratch` equals the allocating `run`
/// on ExplicitArms instances.
#[test]
fn prop_run_in_scratch_reuse_matches_run() {
    let mut rng = Rng::new(0x5C7A);
    let mut scratch = BanditScratch::new();
    for case in 0..20 {
        let n = 3 + rng.next_below(60);
        let n_list = 4 + rng.next_below(120);
        let lists: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..n_list).map(|_| rng.next_f64()).collect())
            .collect();
        let env = ExplicitArms::new(lists).with_range(0.0, 1.0);
        let cfg = BoundedMeConfig {
            k: 1 + rng.next_below(n.min(6)),
            epsilon: rng.uniform(1e-9, 0.5),
            delta: rng.uniform(0.01, 0.4),
        };
        let algo = BoundedMe::new(cfg);
        let fresh = algo.run(&env).result;
        let reused = algo.run_in(&env, &mut scratch);
        assert_eq!(fresh.arms, reused.arms, "case {case}");
        assert_eq!(fresh.total_pulls, reused.total_pulls, "case {case}");
        assert_eq!(fresh.rounds, reused.rounds, "case {case}");
        for (a, b) in fresh.means.iter().zip(&reused.means) {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case}: mean bits differ");
        }
    }
}

/// `QueryPlan` decisions are shard-count invariant: sharding splits
/// rows, never coordinates, so the plan (picked once before fan-out)
/// must match the direct `QueryPlan::pick` for every shard count and
/// split kind — algo, pull order, and pull estimate alike.
#[test]
fn prop_queryplan_shard_count_invariant() {
    let mut rng = Rng::new(0x51AD);
    for case in 0..CASES {
        let n = 10 + rng.next_below(60);
        let d = 8 + rng.next_below(600);
        let data = Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32);
        let k = 1 + rng.next_below(8);
        let eps = rng.uniform(1e-9, 0.9);
        let delta = rng.uniform(1e-3, 0.5);
        let direct = QueryPlan::pick(k, eps, delta, d);
        for s in [1usize, 2, 3, 7] {
            for spec in [ShardSpec::contiguous(s), ShardSpec::round_robin(s)] {
                let sx = ShardedIndex::new(data.clone(), spec);
                let plan = sx.plan(k, eps, delta);
                assert_eq!(plan.algo, direct.algo, "case {case} {spec:?}");
                assert_eq!(plan.order, direct.order, "case {case} {spec:?}");
                assert_eq!(
                    plan.first_round_pulls, direct.first_round_pulls,
                    "case {case} {spec:?}"
                );
            }
        }
    }
}

/// `PullScratch` reuse across shard-pinned contexts is invisible: a
/// `ShardedIndex` whose per-shard contexts have served many prior
/// batches returns bit-identical results (indices, score bits, flops)
/// to a freshly-built one, for both exact and BOUNDEDME paths.
#[test]
fn prop_shard_pinned_context_reuse_bit_identical() {
    let mut rng = Rng::new(0x5C0D);
    for case in 0..10 {
        let n = 30 + rng.next_below(80);
        let d = 32 + rng.next_below(160);
        let data = Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32);
        let spec = if case % 2 == 0 {
            ShardSpec::contiguous(2 + rng.next_below(3))
        } else {
            ShardSpec::round_robin(2 + rng.next_below(3))
        };
        let queries: Vec<Vec<f32>> = (0..4).map(|_| rng.gaussian_vec(d)).collect();
        let refs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let params = MipsParams {
            k: 1 + rng.next_below(5),
            epsilon: rng.uniform(1e-6, 0.4),
            delta: rng.uniform(0.01, 0.4),
            seed: case as u64,
        };
        let mut warm = ShardedIndex::new(data.clone(), spec);
        // Warm the shard-pinned contexts with unrelated traffic.
        for s in 0..3u64 {
            let _ = warm.query_batch_bounded_me(
                &refs,
                &MipsParams { seed: 100 + s, ..params },
            );
            let _ = warm.query_batch_exact(&refs, params.k);
        }
        let mut fresh = ShardedIndex::new(data.clone(), spec);
        let a = warm.query_batch_bounded_me(&refs, &params);
        let b = fresh.query_batch_bounded_me(&refs, &params);
        for (qi, (ra, rb)) in a.iter().zip(&b).enumerate() {
            assert_eq!(ra.indices, rb.indices, "case {case} q{qi}");
            assert_eq!(ra.flops, rb.flops, "case {case} q{qi}");
            for (x, y) in ra.scores.iter().zip(&rb.scores) {
                assert_eq!(x.to_bits(), y.to_bits(), "case {case} q{qi}: score bits");
            }
        }
        let a = warm.query_batch_exact(&refs, params.k);
        let b = fresh.query_batch_exact(&refs, params.k);
        for (qi, (ra, rb)) in a.iter().zip(&b).enumerate() {
            assert_eq!(ra.indices, rb.indices, "case {case} exact q{qi}");
            for (x, y) in ra.scores.iter().zip(&rb.scores) {
                assert_eq!(x.to_bits(), y.to_bits(), "case {case} exact q{qi}");
            }
        }
    }
}

/// Channel conservation under random producer/consumer interleavings.
#[test]
fn prop_channel_conservation() {
    use bandit_mips::sync::bounded;
    let mut rng = Rng::new(0xCAB);
    for case in 0..10 {
        let cap = 1 + rng.next_below(8);
        let producers = 1 + rng.next_below(4);
        let consumers = 1 + rng.next_below(4);
        let per = 50 + rng.next_below(100);
        let (tx, rx) = bounded::<usize>(cap);
        let mut ps = Vec::new();
        for p in 0..producers {
            let tx = tx.clone();
            ps.push(std::thread::spawn(move || {
                for i in 0..per {
                    tx.send(p * 10_000 + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut cs = Vec::new();
        for _ in 0..consumers {
            let rx = rx.clone();
            cs.push(std::thread::spawn(move || {
                let mut v = Vec::new();
                while let Ok(x) = rx.recv() {
                    v.push(x);
                }
                v
            }));
        }
        drop(rx);
        for p in ps {
            p.join().unwrap();
        }
        let mut all: Vec<usize> = Vec::new();
        for c in cs {
            all.extend(c.join().unwrap());
        }
        assert_eq!(all.len(), producers * per, "case {case}: loss or duplication");
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), producers * per, "case {case}: duplicates");
    }
}

/// Exact `query_batch` through the dispatched SIMD kernel table returns
/// the same argmax ids as a forced-scalar recomputation (what
/// `RUST_PALLAS_FORCE_SCALAR=1` executes), with scores inside the
/// `linalg::simd` tolerance contract — on random instances. Near-ties
/// at a rank boundary (where argmax identity across ISAs is genuinely
/// undefined) are skipped; Gaussian draws essentially never produce
/// them.
#[test]
fn prop_query_batch_argmax_simd_scalar_invariant() {
    use bandit_mips::linalg::simd;
    let scalar = simd::scalar_kernels();
    let mut rng = Rng::new(0x51AD2);
    for case in 0..20 {
        let n = 20 + rng.next_below(200);
        let d = 8 + rng.next_below(300);
        let k = 1 + rng.next_below(6);
        let data = Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32);
        let queries: Vec<Vec<f32>> = (0..4).map(|_| rng.gaussian_vec(d)).collect();
        let refs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let index = NaiveIndex::new(data.clone());
        let mut ctx = QueryContext::new();
        let batch =
            index.query_batch(&refs, &MipsParams { k, ..Default::default() }, &mut ctx);
        for (qi, q) in queries.iter().enumerate() {
            let mut ranked: Vec<(f32, usize)> =
                (0..n).map(|i| ((scalar.dot)(data.row(i), q), i)).collect();
            ranked.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
            let kk = k.min(n);
            // Skip draws with a near-tie anywhere in (or just past) the
            // returned prefix. "Near" is relative to the score scale:
            // the simd contract lets each score move by 1e-4·(1+|s|)
            // across ISAs, so a pair is only safely ordered when its
            // gap exceeds both scores' combined allowance.
            let boundary = (kk + 1).min(n);
            let degenerate = ranked[..boundary].windows(2).any(|w| {
                let scale = 1.0 + w[0].0.abs().max(w[1].0.abs());
                (w[0].0 - w[1].0).abs() < 4e-4 * scale
            });
            if degenerate {
                continue;
            }
            let want: Vec<usize> = ranked[..kk].iter().map(|&(_, i)| i).collect();
            assert_eq!(
                batch[qi].indices, want,
                "case {case} q{qi} (n={n} d={d} k={k}): dispatched argmax != scalar"
            );
            for (got, &(w, _)) in batch[qi].scores.iter().zip(&ranked[..kk]) {
                assert!(
                    (got - w).abs() <= 1e-4 * (1.0 + w.abs()),
                    "case {case} q{qi}: score {got} vs scalar {w}"
                );
            }
        }
    }
}

/// Generation-pinned queries are bit-identical to a from-scratch index
/// on the materialized snapshot: a `ShardSet` advanced copy-on-write
/// through a chain of random delta batches (pure upserts, mixed
/// upsert/delete/append, growth-only) must return the same indices,
/// score bits, and flop counts as a `ShardSet` built fresh on
/// `Generation::materialize()` — for both split kinds, S ∈ {1, 2, 3},
/// and every storage tier. This is the single-threaded bit-level half
/// of the live-mutation contract (the concurrent set-level half lives
/// in `generation_equivalence`): rebuild-free mutation may not perturb
/// answers by even one ULP, including re-quantized delta rows on
/// compressed tiers.
#[test]
fn prop_generation_cow_bit_identical_to_from_scratch() {
    use bandit_mips::data::generation::{Generation, GenerationBuilder};
    use bandit_mips::data::quant::Storage;
    use bandit_mips::exec::shard::ShardSet;
    use bandit_mips::sync::EpochGauge;

    let tiers = [Storage::F32, Storage::F16, Storage::Bf16, Storage::Int8];
    let mut rng = Rng::new(0x6E6E);
    for case in 0..12 {
        let n = 40 + rng.next_below(60);
        let d = 16 + rng.next_below(64);
        let data = Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32);
        let s = 1 + case % 3;
        let spec = if case % 2 == 0 {
            ShardSpec::contiguous(s)
        } else {
            ShardSpec::round_robin(s)
        };
        let storage = tiers[case % tiers.len()];
        let mut gen = Generation::initial(data, spec, EpochGauge::new());
        let mut set = ShardSet::build(gen.clone(), storage);
        for step in 0..4u64 {
            // One random delta batch; upsert ids come from the lower
            // half and delete ids from the upper half of the id space so
            // a batch never upserts and deletes the same row.
            let rows = gen.rows();
            let mut bld = GenerationBuilder::new(&gen);
            match (case as u64 + step) % 3 {
                0 => {
                    for _ in 0..1 + rng.next_below(3) {
                        bld.upsert(rng.next_below(rows), rng.gaussian_vec(d)).unwrap();
                    }
                }
                1 => {
                    bld.upsert(rng.next_below(rows / 2), rng.gaussian_vec(d)).unwrap();
                    bld.delete(rows / 2 + rng.next_below(rows / 2)).unwrap();
                    bld.append(rng.gaussian_vec(d)).unwrap();
                }
                _ => {
                    for _ in 0..1 + rng.next_below(2) {
                        bld.append(rng.gaussian_vec(d)).unwrap();
                    }
                }
            }
            let built = bld.build().unwrap();
            gen = built.generation.clone();
            set = ShardSet::advance(&set, &built);

            // Reference: same snapshot, same spec and tier, no history.
            let fresh = ShardSet::build(
                Generation::initial(gen.materialize(), spec, EpochGauge::new()),
                storage,
            );

            let queries: Vec<Vec<f32>> = (0..3).map(|_| rng.gaussian_vec(d)).collect();
            let refs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            let params = MipsParams {
                k: 1 + rng.next_below(5),
                epsilon: rng.uniform(1e-6, 0.4),
                delta: rng.uniform(0.01, 0.3),
                seed: 9000 + case as u64 * 31 + step,
            };
            let mut ctx_a: Vec<QueryContext> =
                (0..set.num_shards()).map(|_| QueryContext::new()).collect();
            let mut ctx_b: Vec<QueryContext> =
                (0..set.num_shards()).map(|_| QueryContext::new()).collect();

            let a = set.query_batch_bounded_me(&refs, &params, &mut ctx_a);
            let b = fresh.query_batch_bounded_me(&refs, &params, &mut ctx_b);
            for (qi, (ra, rb)) in a.iter().zip(&b).enumerate() {
                assert_eq!(ra.indices, rb.indices, "case {case} step {step} q{qi} {spec:?}");
                assert_eq!(ra.flops, rb.flops, "case {case} step {step} q{qi} {spec:?}");
                for (x, y) in ra.scores.iter().zip(&rb.scores) {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "case {case} step {step} q{qi} {spec:?} {storage:?}: score bits"
                    );
                }
            }
            let a = set.query_batch_exact(&refs, params.k, &mut ctx_a);
            let b = fresh.query_batch_exact(&refs, params.k, &mut ctx_b);
            for (qi, (ra, rb)) in a.iter().zip(&b).enumerate() {
                assert_eq!(ra.indices, rb.indices, "case {case} step {step} exact q{qi}");
                for (x, y) in ra.scores.iter().zip(&rb.scores) {
                    assert_eq!(x.to_bits(), y.to_bits(), "case {case} step {step} exact q{qi}");
                }
            }
        }
    }
}

/// The survivor-compaction policy is pure memory layout: for any random
/// instance, pull order, and knob set, every `Compaction` choice —
/// never, always, or any threshold fraction — produces bit-identical
/// `BoundedMe::run` output through the index hot path (same arms, same
/// score bits, same flop accounting).
#[test]
fn prop_compaction_threshold_never_changes_output() {
    let mut rng = Rng::new(0xC0137);
    for case in 0..25 {
        let n = 10 + rng.next_below(90);
        let d = 64 + rng.next_below(300);
        let data = Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32);
        let order = match case % 3 {
            0 => PullOrder::Permuted,
            1 => PullOrder::Sequential,
            _ => PullOrder::BlockShuffled(1 + rng.next_below(48)),
        };
        let q: Vec<f32> = rng.gaussian_vec(d);
        let params = MipsParams {
            k: 1 + rng.next_below(5),
            epsilon: rng.uniform(1e-6, 0.5),
            delta: rng.uniform(0.01, 0.4),
            seed: 7000 + case as u64,
        };
        let run = |policy: Compaction| {
            let idx =
                BoundedMeIndex::with_order(data.clone(), order).with_compaction(policy);
            idx.query_with(&q, &params, &mut QueryContext::new())
        };
        let base = run(Compaction::Never);
        let frac = rng.uniform(0.0, 1.0);
        for policy in [Compaction::Always, Compaction::AtFraction(frac)] {
            let got = run(policy);
            assert_eq!(got.indices, base.indices, "case {case} {order:?} {policy:?}");
            assert_eq!(got.flops, base.flops, "case {case} {order:?} {policy:?}");
            for (a, b) in got.scores.iter().zip(&base.scores) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "case {case} {order:?} {policy:?}: score bits differ"
                );
            }
        }
    }
}
