//! Integration: dataset → algorithms → metrics → coordinator, composed
//! the way the examples use them.

use bandit_mips::algos::{
    ground_truth, BoundedMeIndex, GreedyMipsIndex, LshMipsIndex, MipsIndex, MipsParams,
    NaiveIndex, PcaMipsIndex, RptMipsIndex,
};
use bandit_mips::coordinator::{
    Backend, Coordinator, CoordinatorConfig, QueryRequest,
};
use bandit_mips::data::{io as dio, mf, synthetic, workload};
use bandit_mips::metrics::{precision_at_k, suboptimality};
use std::time::Duration;

#[test]
fn all_indexes_agree_at_full_accuracy() {
    let ds = synthetic::gaussian_dataset(300, 128, 1);
    let q = ds.sample_query(5);
    let truth = ground_truth(&ds.vectors, &q, 5);

    // Exact-configured variants of every index must return the truth.
    let naive = NaiveIndex::new(ds.vectors.clone());
    let bme = BoundedMeIndex::new(ds.vectors.clone());
    let greedy = GreedyMipsIndex::new(ds.vectors.clone(), 300);

    let p = MipsParams { k: 5, epsilon: 1e-12, delta: 0.05, seed: 3 };
    assert_eq!(naive.query(&q, &p).indices, truth);
    assert_eq!(greedy.query(&q, &p).indices, truth);
    let mut got = bme.query(&q, &p).indices;
    got.sort_unstable();
    let mut want = truth.clone();
    want.sort_unstable();
    assert_eq!(got, want);
}

#[test]
fn precision_improves_with_budget_for_every_algorithm() {
    let ds = synthetic::gaussian_dataset(400, 96, 2);
    let queries = ds.sample_queries(8, 7);
    let truths: Vec<Vec<usize>> =
        queries.iter().map(|q| ground_truth(&ds.vectors, q, 5)).collect();

    let mean_precision = |idx: &dyn MipsIndex, eps: f64| -> f64 {
        queries
            .iter()
            .zip(&truths)
            .map(|(q, t)| {
                let p = MipsParams { k: 5, epsilon: eps, delta: 0.1, seed: 1 };
                precision_at_k(t, &idx.query(q, &p).indices)
            })
            .sum::<f64>()
            / queries.len() as f64
    };

    let bme = BoundedMeIndex::new(ds.vectors.clone());
    assert!(mean_precision(&bme, 0.001) >= mean_precision(&bme, 0.8) - 1e-9);

    let g_small = GreedyMipsIndex::new(ds.vectors.clone(), 20);
    let g_big = GreedyMipsIndex::new(ds.vectors.clone(), 400);
    assert!(mean_precision(&g_big, 0.0) >= mean_precision(&g_small, 0.0) - 1e-9);

    let lsh_coarse = LshMipsIndex::new(ds.vectors.clone(), 14, 2, 3);
    let lsh_fine = LshMipsIndex::new(ds.vectors.clone(), 4, 16, 3);
    assert!(mean_precision(&lsh_fine, 0.0) >= mean_precision(&lsh_coarse, 0.0) - 1e-9);

    let pca_deep = PcaMipsIndex::new(ds.vectors.clone(), 6, 4);
    let pca_shallow = PcaMipsIndex::new(ds.vectors.clone(), 1, 4);
    assert!(mean_precision(&pca_shallow, 0.0) >= mean_precision(&pca_deep, 0.0) - 1e-9);

    let rpt_many = RptMipsIndex::new(ds.vectors.clone(), 10, 40, 5);
    let rpt_one = RptMipsIndex::new(ds.vectors.clone(), 1, 40, 5);
    assert!(mean_precision(&rpt_many, 0.0) >= mean_precision(&rpt_one, 0.0) - 1e-9);
}

#[test]
fn suboptimality_respects_epsilon_statistically() {
    // Over several queries, BOUNDEDME's observed suboptimality (relative
    // to reward range) must be ≤ ε at well above 1−δ rate.
    let ds = synthetic::uniform_dataset(300, 256, 4);
    let idx = BoundedMeIndex::new(ds.vectors.clone());
    let (eps, delta) = (0.05, 0.1);
    let mut failures = 0;
    let trials = 20;
    for s in 0..trials {
        let q = ds.sample_query(s as u64);
        let truth = ground_truth(&ds.vectors, &q, 1);
        let res =
            idx.query(&q, &MipsParams { k: 1, epsilon: eps, delta, seed: s as u64 });
        let sub = suboptimality(&ds.vectors, &q, &truth, &res.indices);
        // Range-relative comparison (same bound the index uses).
        let range = 2.0 * idx.reward_bound(&q) as f64;
        if sub > eps * range {
            failures += 1;
        }
    }
    assert!(failures <= 2, "{failures}/{trials} exceeded ε");
}

#[test]
fn mf_dataset_through_full_stack() {
    let mfd = mf::netflix_like(120, 256, 9);
    let ds = &mfd.dataset;
    let idx = BoundedMeIndex::new(ds.vectors.clone());
    let q = &mfd.user_queries[3];
    let res = idx.query(q, &MipsParams { k: 5, epsilon: 1e-12, delta: 0.05, seed: 0 });
    let mut got = res.indices.clone();
    got.sort_unstable();
    let mut want = ground_truth(&ds.vectors, q, 5);
    want.sort_unstable();
    assert_eq!(got, want);
}

#[test]
fn dataset_io_roundtrip_through_index() {
    let ds = synthetic::gaussian_dataset(64, 32, 11);
    let path = std::env::temp_dir().join("bm_pipeline_io.bin");
    dio::save(&ds, &path).unwrap();
    let loaded = dio::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let q = ds.sample_query(1);
    let a = NaiveIndex::new(ds.vectors.clone())
        .query(&q, &MipsParams { k: 3, ..Default::default() });
    let b = NaiveIndex::new(loaded.vectors.clone())
        .query(&q, &MipsParams { k: 3, ..Default::default() });
    assert_eq!(a.indices, b.indices);
}

#[test]
fn coordinator_replays_poisson_trace() {
    let ds = synthetic::gaussian_dataset(256, 64, 13);
    let coord = Coordinator::new(
        ds.vectors.clone(),
        CoordinatorConfig {
            workers: 2,
            max_batch: 16,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: 4096,
            backend: Backend::Native,
            ..Default::default()
        },
    )
    .unwrap();
    let trace = workload::poisson_trace(
        &ds,
        &workload::WorkloadConfig { count: 200, rate: 1e6, ..Default::default() },
    );
    let mut rxs = Vec::new();
    for t in &trace {
        rxs.push(
            coord
                .submit(QueryRequest::bounded_me(t.vector.clone(), t.k, t.epsilon, t.delta))
                .unwrap(),
        );
    }
    let mut served = 0;
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.indices.len(), 10);
        served += 1;
    }
    assert_eq!(served, 200);
    let m = coord.metrics();
    assert_eq!(m.queries, 200);
    assert!(m.flops > 0);
    coord.shutdown();
}
