//! Battery for the survivor-compacting BOUNDEDME pull layout: the
//! panel-compacted elimination core must be **bit-identical** to the
//! scattered one — same arms, same scores to the bit, same flop
//! accounting — across pull orders, survivor fractions, ragged
//! dimensions, and the sharded confirm path; and the panel must reach a
//! zero-allocation steady state inside a reused `QueryContext`.

use bandit_mips::algos::{BoundedMeIndex, MipsIndex, MipsParams};
use bandit_mips::bandit::{
    force_no_compact_requested, Compaction, MatrixArms, PullOrder, PullPanel, RewardSource,
};
use bandit_mips::data::shard::{ShardSpec, ShardedMatrix};
use bandit_mips::exec::QueryContext;
use bandit_mips::linalg::{Matrix, Rng};

fn gaussian(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32)
}

/// Run one query under a compaction policy and return the full result.
fn query_with_policy(
    data: &Matrix,
    order: PullOrder,
    policy: Compaction,
    q: &[f32],
    params: &MipsParams,
) -> bandit_mips::algos::MipsResult {
    let idx = BoundedMeIndex::with_order(data.clone(), order).with_compaction(policy);
    let mut ctx = QueryContext::new();
    idx.query_with(q, params, &mut ctx)
}

#[test]
fn panel_and_scatter_elimination_are_bit_identical() {
    // Ragged dims straddle the kernels' chunk widths and the block
    // shuffle's run tails; ε spread drives shallow and deep
    // elimination schedules (different survivor fractions at
    // compaction time).
    for (n, dim) in [(60usize, 257usize), (90, 384), (40, 97)] {
        let data = gaussian(n, dim, 7 + n as u64);
        let mut rng = Rng::new(1000 + dim as u64);
        let q: Vec<f32> = rng.gaussian_vec(dim);
        for order in [
            PullOrder::Sequential,
            PullOrder::Permuted,
            PullOrder::BlockShuffled(19),
        ] {
            for eps in [1e-9, 0.05, 0.3] {
                let params = MipsParams { k: 3, epsilon: eps, delta: 0.1, seed: 5 };
                let base = query_with_policy(&data, order, Compaction::Never, &q, &params);
                for policy in [
                    Compaction::Always,
                    Compaction::AtFraction(0.05),
                    Compaction::AtFraction(0.25),
                    Compaction::AtFraction(0.5),
                    Compaction::AtFraction(0.9),
                    Compaction::AtFraction(1.0),
                ] {
                    let got = query_with_policy(&data, order, policy, &q, &params);
                    assert_eq!(
                        got.indices, base.indices,
                        "{n}x{dim} {order:?} eps={eps} {policy:?}: indices"
                    );
                    assert_eq!(
                        got.flops, base.flops,
                        "{n}x{dim} {order:?} eps={eps} {policy:?}: flops"
                    );
                    for (a, b) in got.scores.iter().zip(&base.scores) {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{n}x{dim} {order:?} eps={eps} {policy:?}: scores"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn panel_pull_matches_scatter_on_ragged_tails() {
    // Raw reward-source level: every (survivor count mod chunk width)
    // remainder and a ragged final coordinate run.
    let dim = 211usize;
    let data = gaussian(37, dim, 21);
    let mut rng = Rng::new(77);
    let q: Vec<f32> = rng.gaussian_vec(dim);
    for order in [PullOrder::Permuted, PullOrder::BlockShuffled(23)] {
        let arms = MatrixArms::new(&data, &q, 16.0, order, 13);
        for keep in [1usize, 2, 7, 8, 9, 16, 17, 37] {
            let ids: Vec<usize> = (0..keep).map(|i| (i * 5) % 37).collect();
            for (from, to) in [(0usize, dim), (3, 200), (100, 101), (dim - 1, dim)] {
                let mut panel = PullPanel::new();
                arms.compact_into(&ids, from, &mut panel);
                let mut scatter = vec![0f64; keep];
                arms.pull_range_batch(&ids, from, to, &mut scatter);
                let mut dense = vec![0f64; keep];
                arms.pull_range_batch_panel(&panel, from, to, &mut dense);
                for (i, (a, b)) in scatter.iter().zip(&dense).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{order:?} keep={keep} [{from},{to}) row {i}"
                    );
                }
            }
        }
    }
}

#[test]
fn shard_confirm_path_is_compaction_invariant() {
    // The sharded sample-then-confirm entry point: entries (exact
    // confirm scores under global ids) must not depend on the pull
    // layout of the sample step.
    let data = gaussian(80, 256, 31);
    let sm = ShardedMatrix::new(data.clone(), ShardSpec::contiguous(2));
    let mut rng = Rng::new(55);
    let qs: Vec<Vec<f32>> = (0..4).map(|_| rng.gaussian_vec(256)).collect();
    let refs: Vec<&[f32]> = qs.iter().map(|v| v.as_slice()).collect();
    let params = MipsParams { k: 4, epsilon: 0.1, delta: 0.1, seed: 9 };
    for shard_id in 0..2 {
        let shard = sm.shard(shard_id);
        let mk = |policy: Compaction| {
            let idx =
                BoundedMeIndex::with_order(shard.matrix().clone(), PullOrder::BlockShuffled(32))
                    .with_compaction(policy);
            let mut ctx = QueryContext::new();
            idx.query_batch_shard(&refs, &params, &mut ctx, shard)
        };
        let scattered = mk(Compaction::Never);
        let compacted = mk(Compaction::Always);
        assert_eq!(scattered.len(), compacted.len());
        for (a, b) in scattered.iter().zip(&compacted) {
            assert_eq!(a.flops, b.flops, "shard {shard_id}");
            assert_eq!(a.scanned, b.scanned, "shard {shard_id}");
            assert_eq!(a.entries.len(), b.entries.len(), "shard {shard_id}");
            for ((sa, ia), (sb, ib)) in a.entries.iter().zip(&b.entries) {
                assert_eq!(ia, ib, "shard {shard_id}");
                assert_eq!(sa.to_bits(), sb.to_bits(), "shard {shard_id}");
            }
        }
    }
}

#[test]
fn reused_context_panel_reaches_steady_state() {
    // After one pass over a query set, a second pass must not grow the
    // panel buffers (the high-water capacity is established).
    let data = gaussian(300, 512, 3);
    let idx = BoundedMeIndex::with_order(data, PullOrder::BlockShuffled(64))
        .with_compaction(Compaction::AtFraction(0.5));
    let params = MipsParams { k: 5, epsilon: 0.05, delta: 0.1, seed: 2 };
    let qs: Vec<Vec<f32>> = (0..6).map(|i| Rng::new(400 + i).gaussian_vec(512)).collect();
    let mut ctx = QueryContext::new();
    // Two warm passes: the panel's ping-pong buffers need both parities
    // of the compact/recompact sequence before capacities stabilize.
    for _ in 0..2 {
        for q in &qs {
            let _ = idx.query_with(q, &params, &mut ctx);
        }
    }
    let warm_grows = ctx.panel_grow_events();
    for q in &qs {
        let _ = idx.query_with(q, &params, &mut ctx);
    }
    assert_eq!(ctx.panel_grow_events(), warm_grows, "panel reallocated in steady state");
}

#[test]
fn forced_no_compact_env_pins_scattered_default() {
    // Only assertable when the harness set the variable (the CI
    // `scatter` matrix leg does); otherwise this is vacuous.
    if force_no_compact_requested() {
        assert_eq!(Compaction::default(), Compaction::Never);
    } else {
        assert_eq!(
            Compaction::default(),
            Compaction::AtFraction(Compaction::DEFAULT_FRACTION)
        );
    }
}
