//! Statistical acceptance battery for the mixed-precision storage tier:
//! BOUNDEDME queries sampling a compressed f16 / bf16 / int8 copy of
//! the dataset (and confirm-rescoring survivors on f32) must preserve
//! the paper's (ε, δ) guarantee **stated against the true f32 means**,
//! on both synthetic Gaussian data and matrix-factorization embeddings.
//! The ε → 0 limit must stay exact (the tier silently falls back to
//! the f32 path when the quantization-bias budget would exceed ε), and
//! the `RUST_PALLAS_FORCE_F32` escape hatch must make a
//! storage-configured index behave bit-for-bit like a plain one.

use bandit_mips::algos::{ground_truth, BoundedMeIndex, MipsIndex, MipsParams};
use bandit_mips::data::mf::netflix_like;
use bandit_mips::data::quant::{force_f32_requested, Storage};
use bandit_mips::data::synthetic::gaussian_dataset;
use bandit_mips::exec::QueryContext;
use bandit_mips::linalg::{dot, Matrix, Rng};

const TIERS: [Storage; 3] = [Storage::F16, Storage::Bf16, Storage::Int8];

/// Exact score of every row against `q`, plus the k-th best (the
/// ε-optimality reference point μ_[k] in score units).
fn exact_scores(data: &Matrix, q: &[f32]) -> Vec<f32> {
    (0..data.rows()).map(|i| dot(data.row(i), q)).collect()
}

/// Run `queries` against a `storage`-tier index and count queries where
/// ANY returned arm is worse than ε-optimal w.r.t. the TRUE f32 scores.
/// The guarantee is per-query failure probability ≤ δ, so the count is
/// stochastically dominated by Binomial(Q, δ); the caller asserts a
/// 3σ-slack bound on it.
fn count_epsilon_violations(
    data: &Matrix,
    queries: &[Vec<f32>],
    storage: Storage,
    params: &MipsParams,
) -> usize {
    let idx = BoundedMeIndex::new(data.clone()).with_storage(storage);
    let mut ctx = QueryContext::new();
    let mut violations = 0usize;
    for (qi, q) in queries.iter().enumerate() {
        let res = idx.query_with(q, &MipsParams { seed: qi as u64, ..*params }, &mut ctx);
        assert_eq!(res.indices.len(), params.k, "{} q{qi}", storage.label());
        // ε is stated in mean units over a per-query range of width
        // 2·reward_bound(q); scores are N·mean.
        let slack = params.epsilon
            * 2.0
            * idx.reward_bound(q).max(f32::MIN_POSITIVE) as f64
            * data.cols() as f64;
        let mut truth = exact_scores(data, q);
        truth.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let kth = truth[params.k - 1] as f64;
        // Judge the returned ARMS by their exact scores (don't trust
        // the reported ones here — that contract has its own tests).
        let ok = res
            .indices
            .iter()
            .all(|&arm| dot(data.row(arm), q) as f64 >= kth - slack - 1e-3);
        if !ok {
            violations += 1;
        }
    }
    violations
}

/// Binomial(Q, δ) upper bound with 3σ of slack (+1 so tiny Q·δ never
/// rounds to an impossible zero-tolerance).
fn violation_budget(n_queries: usize, delta: f64) -> usize {
    let q = n_queries as f64;
    (q * delta + 3.0 * (q * delta * (1.0 - delta)).sqrt() + 1.0).ceil() as usize
}

#[test]
fn compressed_tiers_preserve_epsilon_delta_on_gaussian() {
    let data = gaussian_dataset(150, 64, 0xE9D1).vectors;
    let mut rng = Rng::new(0x9A55);
    let queries: Vec<Vec<f32>> = (0..40).map(|_| rng.gaussian_vec(64)).collect();
    let params = MipsParams { k: 3, epsilon: 0.15, delta: 0.1, seed: 0 };
    let budget = violation_budget(queries.len(), params.delta);
    for storage in TIERS {
        let violations = count_epsilon_violations(&data, &queries, storage, &params);
        assert!(
            violations <= budget,
            "{}: {violations} ε-violations over {} queries (budget {budget})",
            storage.label(),
            queries.len()
        );
    }
}

#[test]
fn compressed_tiers_preserve_epsilon_delta_on_mf_embeddings() {
    // MF embeddings are the adversarial case for per-row int8 scales:
    // popularity skew gives rows wildly different norms, and the
    // user-factor queries are correlated with the item space instead of
    // isotropic.
    let mf = netflix_like(240, 48, 0x4EF1);
    let data = mf.dataset.vectors;
    let queries: Vec<Vec<f32>> = mf.user_queries.into_iter().take(40).collect();
    assert!(queries.len() >= 30, "MF pipeline produced too few user queries");
    let params = MipsParams { k: 5, epsilon: 0.15, delta: 0.1, seed: 0 };
    let budget = violation_budget(queries.len(), params.delta);
    for storage in TIERS {
        let violations = count_epsilon_violations(&data, &queries, storage, &params);
        assert!(
            violations <= budget,
            "{}: {violations} ε-violations over {} MF queries (budget {budget})",
            storage.label(),
            queries.len()
        );
    }
}

#[test]
fn zero_epsilon_with_compressed_tier_stays_exact() {
    // The quantization-bias budget 2b always exceeds an ε → 0 target,
    // so the tier must silently fall back to the exact-capable f32
    // path — compressed storage never costs correctness.
    let data = gaussian_dataset(100, 48, 0x0EA7).vectors;
    let mut rng = Rng::new(0x5EED);
    let params = MipsParams { k: 4, epsilon: 1e-9, delta: 0.05, seed: 3 };
    for storage in TIERS {
        let idx = BoundedMeIndex::new(data.clone()).with_storage(storage);
        let mut ctx = QueryContext::new();
        for case in 0..10 {
            let q: Vec<f32> = rng.gaussian_vec(48);
            let res = idx.query_with(&q, &params, &mut ctx);
            let mut got = res.indices.clone();
            got.sort_unstable();
            let mut want = ground_truth(&data, &q, params.k);
            want.sort_unstable();
            assert_eq!(got, want, "{} case {case}", storage.label());
        }
    }
}

#[test]
fn compressed_tier_recall_tracks_f32_at_equal_params() {
    // Same (ε, δ), same queries: the two-tier path's ground-truth
    // recall must stay in the same regime as the f32 path's. Not an
    // equality (different sampling noise), but compression must not
    // collapse answer quality.
    let data = gaussian_dataset(150, 64, 0x7EC0).vectors;
    let mut rng = Rng::new(0xCA11);
    let queries: Vec<Vec<f32>> = (0..30).map(|_| rng.gaussian_vec(64)).collect();
    let params = MipsParams { k: 5, epsilon: 0.15, delta: 0.1, seed: 0 };
    let recall = |storage: Storage| -> f64 {
        let idx = BoundedMeIndex::new(data.clone()).with_storage(storage);
        let mut ctx = QueryContext::new();
        let mut hits = 0usize;
        for (qi, q) in queries.iter().enumerate() {
            let res =
                idx.query_with(q, &MipsParams { seed: qi as u64, ..params }, &mut ctx);
            let truth = ground_truth(&data, q, params.k);
            hits += res.indices.iter().filter(|i| truth.contains(i)).count();
        }
        hits as f64 / (queries.len() * params.k) as f64
    };
    let f32_recall = recall(Storage::F32);
    for storage in TIERS {
        let tier_recall = recall(storage);
        assert!(
            tier_recall >= f32_recall - 0.25 && tier_recall >= 0.5,
            "{}: recall {tier_recall:.3} vs f32 {f32_recall:.3}",
            storage.label()
        );
    }
}

/// Compressed tiers survive generation flips. Delta rows land in
/// rebuilt shards whose `QuantMatrix` (codes, per-row scales, per-row
/// error bounds) is recomputed from the new bytes — a stale int8 scale
/// on a rescaled row would clip its codes and silently break the (ε, δ)
/// guarantee. Three checks per tier, all on a flip whose upserts rescale
/// rows by 8× (the adversarial case for per-row scales):
///
/// 1. ε = 0.15 violation counting straddling the flip — pre-flip
///    queries judged against the base snapshot, post-flip queries
///    against the mutated one — stays within the Binomial(Q, δ) budget;
/// 2. ε → 0 stays exact on the flipped set (bias fallback intact);
/// 3. the advanced set is bit-identical to a from-scratch tiered build
///    on the materialized snapshot, including a COW-reused shard (S=2).
#[test]
fn compressed_tiers_survive_generation_flips() {
    use bandit_mips::data::generation::{Generation, GenerationBuilder};
    use bandit_mips::data::shard::ShardSpec;
    use bandit_mips::exec::shard::ShardSet;
    use bandit_mips::sync::EpochGauge;

    fn count_violations_on_set(
        set: &ShardSet,
        snap: &Matrix,
        queries: &[Vec<f32>],
        params: &MipsParams,
        salt: u64,
    ) -> usize {
        let mut ctxs = vec![QueryContext::new()];
        let mut violations = 0usize;
        for (qi, q) in queries.iter().enumerate() {
            let res = &set.query_batch_bounded_me(
                &[q.as_slice()],
                &MipsParams { seed: salt + qi as u64, ..*params },
                &mut ctxs,
            )[0];
            assert_eq!(res.indices.len(), params.k);
            let slack = params.epsilon
                * 2.0
                * set.index(0).reward_bound(q).max(f32::MIN_POSITIVE) as f64
                * snap.cols() as f64;
            let mut truth = exact_scores(snap, q);
            truth.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let kth = truth[params.k - 1] as f64;
            let ok = res
                .indices
                .iter()
                .all(|&arm| dot(snap.row(arm), q) as f64 >= kth - slack - 1e-3);
            if !ok {
                violations += 1;
            }
        }
        violations
    }

    let data = gaussian_dataset(140, 48, 0xF11B).vectors;
    let mut rng = Rng::new(0xF11C);
    let pre: Vec<Vec<f32>> = (0..20).map(|_| rng.gaussian_vec(48)).collect();
    let post: Vec<Vec<f32>> = (0..20).map(|_| rng.gaussian_vec(48)).collect();
    let params = MipsParams { k: 3, epsilon: 0.15, delta: 0.1, seed: 0 };
    let budget = violation_budget(pre.len() + post.len(), params.delta);

    // The flip: rescale two rows by 8×, delete one, append a tiny-norm
    // row — every delta row needs fresh quantization state.
    let flip = |gen: &Generation| {
        let mut bld = GenerationBuilder::new(gen);
        for id in [5usize, 70] {
            let v: Vec<f32> = (0..gen.dim())
                .map(|j| gen.row(id)[j] * 8.0)
                .collect();
            bld.upsert(id, v).unwrap();
        }
        bld.delete(100).unwrap();
        let tiny: Vec<f32> = (0..gen.dim()).map(|j| gen.row(3)[j] * 0.05).collect();
        bld.append(tiny).unwrap();
        bld.build().unwrap()
    };

    for storage in TIERS {
        // (1) + (2): S = 1, violation counting across the flip.
        let gen0 = Generation::initial(data.clone(), ShardSpec::single(), EpochGauge::new());
        let set = ShardSet::build(gen0.clone(), storage);
        let mut violations = count_violations_on_set(&set, &data, &pre, &params, 0);
        let built = flip(&gen0);
        let set = ShardSet::advance(&set, &built);
        let snap = built.generation.materialize();
        violations += count_violations_on_set(&set, &snap, &post, &params, 10_000);
        assert!(
            violations <= budget,
            "{}: {violations} ε-violations across the flip (budget {budget})",
            storage.label()
        );

        // ε → 0 on the flipped set: the bias fallback must still see the
        // *new* per-row error bounds and stay exact.
        let tight = MipsParams { k: 4, epsilon: 1e-9, delta: 0.05, seed: 7 };
        let mut ctxs = vec![QueryContext::new()];
        for (case, q) in post.iter().take(6).enumerate() {
            let res = &set.query_batch_bounded_me(&[q.as_slice()], &tight, &mut ctxs)[0];
            let mut got = res.indices.clone();
            got.sort_unstable();
            let mut want = ground_truth(&snap, q, tight.k);
            want.sort_unstable();
            assert_eq!(got, want, "{} post-flip case {case}", storage.label());
        }

        // (3): S = 2 pure-upsert flip — shard 0 rebuilds (and
        // re-quantizes), shard 1 is COW-reused — must be bit-identical
        // to a from-scratch tiered build on the snapshot.
        let gen0 =
            Generation::initial(data.clone(), ShardSpec::contiguous(2), EpochGauge::new());
        let cow = ShardSet::build(gen0.clone(), storage);
        let mut bld = GenerationBuilder::new(&gen0);
        let v: Vec<f32> = (0..gen0.dim()).map(|j| gen0.row(9)[j] * 8.0).collect();
        bld.upsert(9, v).unwrap();
        let built = bld.build().unwrap();
        assert!(
            built.reuse.iter().any(|r| r.is_some()),
            "pure upsert should reuse the untouched shard"
        );
        let cow = ShardSet::advance(&cow, &built);
        let fresh = ShardSet::build(
            Generation::initial(built.generation.materialize(), ShardSpec::contiguous(2), EpochGauge::new()),
            storage,
        );
        let refs: Vec<&[f32]> = post.iter().take(4).map(|q| q.as_slice()).collect();
        let p = MipsParams { k: 3, epsilon: 0.2, delta: 0.1, seed: 42 };
        let mut ctx_a = vec![QueryContext::new(), QueryContext::new()];
        let mut ctx_b = vec![QueryContext::new(), QueryContext::new()];
        let a = cow.query_batch_bounded_me(&refs, &p, &mut ctx_a);
        let b = fresh.query_batch_bounded_me(&refs, &p, &mut ctx_b);
        for (qi, (ra, rb)) in a.iter().zip(&b).enumerate() {
            assert_eq!(ra.indices, rb.indices, "{} q{qi}", storage.label());
            assert_eq!(ra.flops, rb.flops, "{} q{qi}", storage.label());
            for (x, y) in ra.scores.iter().zip(&rb.scores) {
                assert_eq!(x.to_bits(), y.to_bits(), "{} q{qi}: score bits", storage.label());
            }
        }
    }
}

#[test]
fn force_f32_pin_collapses_every_tier() {
    for storage in TIERS {
        assert_eq!(storage.effective_with(true), Storage::F32);
        assert_eq!(storage.effective_with(false), storage);
    }
    assert_eq!(Storage::F32.effective_with(true), Storage::F32);
    // Under the CI f32 leg the pin is process-wide: a storage-configured
    // index must report F32…
    if force_f32_requested() {
        for storage in TIERS {
            let data = gaussian_dataset(40, 16, 1).vectors;
            let idx = BoundedMeIndex::new(data).with_storage(storage);
            assert_eq!(idx.storage(), Storage::F32);
        }
    }
}

#[test]
fn force_f32_leg_is_bit_identical_to_plain_index() {
    // …and answer bit-for-bit like an index that never heard of the
    // mixed-precision subsystem (indices, score bits, AND flops — the
    // whole observable surface). Runs its real assertion only on the
    // RUST_PALLAS_FORCE_F32 CI leg; elsewhere the compressed tier is
    // live and legitimately diverges.
    let data = gaussian_dataset(120, 64, 0xB17F).vectors;
    let plain = BoundedMeIndex::new(data.clone());
    let tiered = BoundedMeIndex::new(data).with_storage(Storage::Int8);
    if tiered.storage() != Storage::F32 {
        return;
    }
    let mut rng = Rng::new(0xFACE);
    let mut ctx_a = QueryContext::new();
    let mut ctx_b = QueryContext::new();
    for case in 0..8u64 {
        let q: Vec<f32> = rng.gaussian_vec(64);
        let params = MipsParams { k: 3, epsilon: 0.1, delta: 0.1, seed: case };
        let a = plain.query_with(&q, &params, &mut ctx_a);
        let b = tiered.query_with(&q, &params, &mut ctx_b);
        assert_eq!(a.indices, b.indices, "case {case}");
        assert_eq!(a.flops, b.flops, "case {case}");
        assert_eq!(a.candidates, b.candidates, "case {case}");
        for (x, y) in a.scores.iter().zip(&b.scores) {
            assert_eq!(x.to_bits(), y.to_bits(), "case {case}: score bits");
        }
    }
}
