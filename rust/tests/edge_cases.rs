//! Edge-case and failure-injection tests across the public API: zero
//! queries, degenerate datasets, extreme K, and pathological knob
//! settings must never panic and must return well-formed results.

use bandit_mips::algos::{
    BoundedMeIndex, GreedyMipsIndex, LshMipsIndex, MipsIndex, MipsParams, NaiveIndex,
    PcaMipsIndex, RptMipsIndex,
};
use bandit_mips::bandit::{BoundedMe, BoundedMeConfig, ExplicitArms};
use bandit_mips::data::synthetic::{gaussian_dataset, spiky_dataset};
use bandit_mips::linalg::{Matrix, Rng};

fn indexes(data: &Matrix) -> Vec<Box<dyn MipsIndex>> {
    vec![
        Box::new(NaiveIndex::new(data.clone())),
        Box::new(BoundedMeIndex::new(data.clone())),
        Box::new(GreedyMipsIndex::new(data.clone(), data.rows() / 2 + 1)),
        Box::new(LshMipsIndex::new(data.clone(), 4, 4, 1)),
        Box::new(PcaMipsIndex::new(data.clone(), 2, 1)),
        Box::new(RptMipsIndex::new(data.clone(), 2, 8, 1)),
    ]
}

#[test]
fn zero_query_never_panics() {
    let ds = gaussian_dataset(50, 32, 1);
    let q = vec![0.0f32; 32];
    for idx in indexes(&ds.vectors) {
        let res = idx.query(&q, &MipsParams { k: 3, ..Default::default() });
        assert!(res.indices.len() <= 3, "{}", idx.name());
        assert_eq!(res.indices.len(), res.scores.len(), "{}", idx.name());
    }
}

#[test]
fn k_larger_than_n_is_safe() {
    let ds = gaussian_dataset(6, 16, 2);
    let q = ds.sample_query(1);
    for idx in indexes(&ds.vectors) {
        let res = idx.query(&q, &MipsParams { k: 100, ..Default::default() });
        assert!(res.indices.len() <= 6, "{}", idx.name());
        // No duplicates.
        let mut s = res.indices.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), res.indices.len(), "{}", idx.name());
    }
}

#[test]
fn single_vector_dataset() {
    let data = Matrix::from_rows(&[vec![1.0f32, -2.0, 3.0]]);
    let q = [1.0f32, 1.0, 1.0];
    for idx in indexes(&data) {
        let res = idx.query(&q, &MipsParams { k: 1, ..Default::default() });
        if !res.indices.is_empty() {
            assert_eq!(res.indices, vec![0], "{}", idx.name());
        }
    }
}

#[test]
fn k_zero_clamped() {
    let ds = gaussian_dataset(20, 8, 3);
    let q = ds.sample_query(0);
    let bme = BoundedMeIndex::new(ds.vectors.clone());
    let res = bme.query(&q, &MipsParams { k: 0, epsilon: 0.2, delta: 0.2, seed: 0 });
    assert_eq!(res.indices.len(), 1); // clamped to K=1
    let naive = NaiveIndex::new(ds.vectors.clone());
    let res = naive.query(&q, &MipsParams { k: 0, ..Default::default() });
    assert!(res.indices.is_empty());
}

#[test]
fn constant_dataset_all_algorithms() {
    // All vectors identical: any returned set is "correct"; nothing may
    // panic (PCA rank-deficiency, LSH single bucket, ties everywhere).
    let data = Matrix::from_rows(&vec![vec![0.5f32; 12]; 40]);
    let q = [1.0f32; 12];
    for idx in indexes(&data) {
        let res = idx.query(&q, &MipsParams { k: 4, epsilon: 0.3, delta: 0.2, seed: 0 });
        assert!(res.indices.len() <= 4, "{}", idx.name());
        for &s in &res.scores {
            assert!(s.is_finite(), "{}", idx.name());
        }
    }
}

#[test]
fn spiky_dataset_greedy_note() {
    // The Table-1 note: when the largest coordinate of q^T v is identical
    // for all v, GREEDY's screening is uninformative at tiny budgets,
    // while BoundedME's guarantee is distribution-free.
    let ds = spiky_dataset(200, 32, 10, 5);
    let q = ds.sample_query(3);
    let truth = bandit_mips::algos::ground_truth(&ds.vectors, &q, 5);

    let bme = BoundedMeIndex::new(ds.vectors.clone());
    let res = bme.query(&q, &MipsParams { k: 5, epsilon: 1e-9, delta: 0.05, seed: 1 });
    let mut got = res.indices.clone();
    got.sort_unstable();
    let mut want = truth.clone();
    want.sort_unstable();
    assert_eq!(got, want, "BoundedME exact mode must recover truth on spiky data");
}

#[test]
fn extreme_epsilon_delta_values() {
    let ds = gaussian_dataset(30, 64, 7);
    let idx = BoundedMeIndex::new(ds.vectors.clone());
    let q = ds.sample_query(2);
    for (eps, delta) in [(1e-300, 0.5), (0.999, 1e-300), (0.999, 0.999), (1e-300, 1e-300)]
    {
        let res = idx.query(&q, &MipsParams { k: 2, epsilon: eps, delta, seed: 0 });
        assert_eq!(res.indices.len(), 2, "eps={eps} delta={delta}");
        assert!(res.flops <= 30 * 64);
    }
}

#[test]
fn bounded_me_k_equals_n_minus_one() {
    // drop = ⌈1/2⌉ = 1 arm per round: the slowest elimination schedule.
    let lists: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64 / 10.0; 20]).collect();
    let env = ExplicitArms::new(lists).with_range(0.0, 1.0);
    let out = BoundedMe::new(BoundedMeConfig { k: 9, epsilon: 0.1, delta: 0.1 }).run(&env);
    assert_eq!(out.result.arms.len(), 9);
    assert_eq!(out.result.rounds, 1);
    assert!(!out.result.arms.contains(&0), "worst arm must be eliminated");
}

#[test]
fn huge_value_dataset_no_overflow() {
    let mut rng = Rng::new(9);
    let data = Matrix::from_fn(20, 16, |_, _| rng.gaussian() as f32 * 1e18);
    let q: Vec<f32> = (0..16).map(|_| rng.gaussian() as f32 * 1e18).collect();
    let idx = BoundedMeIndex::new(data.clone());
    let res = idx.query(&q, &MipsParams { k: 2, epsilon: 0.3, delta: 0.2, seed: 0 });
    assert_eq!(res.indices.len(), 2);
    // Scores may be ±inf in f32 after N·(1e36) sums, but must not be NaN
    // in the *selection* path (ordering stays total).
    let naive = NaiveIndex::new(data);
    let res2 = naive.query(&q, &MipsParams { k: 2, ..Default::default() });
    assert_eq!(res2.indices.len(), 2);
}

#[test]
fn greedy_budget_one() {
    let ds = gaussian_dataset(100, 16, 11);
    let idx = GreedyMipsIndex::new(ds.vectors.clone(), 1);
    let q = ds.sample_query(4);
    let res = idx.query(&q, &MipsParams { k: 5, ..Default::default() });
    assert_eq!(res.candidates, 1);
    assert_eq!(res.indices.len(), 1);
}

#[test]
fn query_determinism_given_seed() {
    let ds = gaussian_dataset(120, 64, 13);
    let idx = BoundedMeIndex::new(ds.vectors.clone());
    let q = ds.sample_query(5);
    let p = MipsParams { k: 3, epsilon: 0.3, delta: 0.2, seed: 77 };
    let a = idx.query(&q, &p);
    let b = idx.query(&q, &p);
    assert_eq!(a.indices, b.indices);
    assert_eq!(a.flops, b.flops);
    let c = idx.query(&q, &MipsParams { seed: 78, ..p });
    // Different pull order may change flops; result set should usually
    // match but is not guaranteed — only check well-formedness.
    assert_eq!(c.indices.len(), 3);
}
