//! Property battery for the `linalg::simd` runtime-dispatched kernel
//! subsystem: every available ISA table (scalar, AVX2, AVX-512, NEON —
//! whatever the runner detects) must agree with the portable scalar
//! reference within the module's 1e-4 tolerance contract, the blocked
//! kernels must stay bit-identical per row to their table's `dot`, the
//! gather kernel must be exact on every backend, and the dispatched
//! funnel (`linalg::dot` & co.) must match a forced-scalar
//! recomputation on the exact query path.
//!
//! The same batteries run over the `simd::wide` widening tables that
//! score the compressed f16/bf16/int8 storage tiers: every available
//! hardware table agrees with its format's scalar reference within the
//! 1e-4 contract, blocked ≡ dot bitwise, and gather is exact on the
//! compressed element types.

use bandit_mips::algos::{MipsIndex, MipsParams, NaiveIndex};
use bandit_mips::exec::QueryContext;
use bandit_mips::linalg::simd::wide;
use bandit_mips::linalg::{
    axpy, dist_sq, dot, dot_rows, norm_sq, partial_dot, partial_dot_rows, simd, Matrix,
    Rng,
};

/// Relative agreement within the subsystem's tolerance contract.
fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

/// f64 reference dot (more accurate than any f32 kernel).
fn ref_dot(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Every length 0..=64 plus ragged tails around the kernels' chunk
/// widths (8/16-float main loops) and a long streaming case.
fn probe_lengths() -> Vec<usize> {
    let mut lens: Vec<usize> = (0..=64).collect();
    lens.extend([65, 71, 127, 128, 129, 255, 257, 1000, 1023, 1025, 4096, 4099]);
    lens
}

#[test]
fn all_tables_agree_with_scalar_on_dot_within_1e4() {
    let scalar = simd::scalar_kernels();
    let mut rng = Rng::new(0x51AD);
    for table in simd::available_tables() {
        for n in probe_lengths() {
            let a: Vec<f32> = rng.gaussian_vec(n);
            let b: Vec<f32> = rng.gaussian_vec(n);
            let want = (scalar.dot)(&a, &b) as f64;
            let got = (table.dot)(&a, &b) as f64;
            assert!(
                close(got, want, 1e-4),
                "{} vs scalar dot n={n}: {got} vs {want}",
                table.isa
            );
            // Both within tolerance of the f64 truth too.
            assert!(close(got, ref_dot(&a, &b), 1e-4), "{} dot n={n}", table.isa);
            assert!(
                close((table.norm_sq)(&a) as f64, (scalar.norm_sq)(&a) as f64, 1e-4),
                "{} norm_sq n={n}",
                table.isa
            );
            assert!(
                close((table.dist_sq)(&a, &b) as f64, (scalar.dist_sq)(&a, &b) as f64, 1e-4),
                "{} dist_sq n={n}",
                table.isa
            );
            let alpha = rng.gaussian() as f32;
            let mut y_t = b.clone();
            let mut y_s = b.clone();
            (table.axpy)(alpha, &a, &mut y_t);
            (scalar.axpy)(alpha, &a, &mut y_s);
            for i in 0..n {
                assert!(
                    close(y_t[i] as f64, y_s[i] as f64, 1e-4),
                    "{} axpy n={n} i={i}",
                    table.isa
                );
            }
        }
    }
}

#[test]
fn all_tables_blocked_kernels_bit_identical_to_their_dot() {
    // The invariant exact-path equivalence stands on: within one table,
    // dot_rows / partial_dot_rows ≡ dot per row, bit for bit — for
    // every row-count remainder shape of each backend's block size.
    let mut rng = Rng::new(0xB10C);
    for table in simd::available_tables() {
        for rows in 0..=9usize {
            for dim in [0usize, 1, 7, 15, 16, 17, 33, 130] {
                let block: Vec<f32> = rng.gaussian_vec(rows * dim);
                let q: Vec<f32> = rng.gaussian_vec(dim);
                let mut out = vec![0f32; rows];
                (table.dot_rows)(&block, dim, &q, &mut out);
                let refs: Vec<&[f32]> =
                    (0..rows).map(|r| &block[r * dim..(r + 1) * dim]).collect();
                let mut pout = vec![0f32; rows];
                (table.partial_dot_rows)(&refs, &q, &mut pout);
                for r in 0..rows {
                    let single = (table.dot)(&block[r * dim..(r + 1) * dim], &q);
                    assert_eq!(
                        out[r].to_bits(),
                        single.to_bits(),
                        "{} dot_rows {rows}x{dim} row {r}",
                        table.isa
                    );
                    assert_eq!(
                        pout[r].to_bits(),
                        single.to_bits(),
                        "{} partial_dot_rows {rows}x{dim} row {r}",
                        table.isa
                    );
                }
            }
        }
    }
}

#[test]
fn all_tables_gather_is_exact() {
    // Gather is pure data movement, so unlike the dot kernels it must
    // be EXACT on every backend — including the AVX-512 and AVX2
    // hardware `vgatherdps` paths (exercised whenever the runner
    // detects them, independent of the forced-scalar dispatch pin).
    let mut rng = Rng::new(0x6A77);
    for table in simd::available_tables() {
        for src_len in [1usize, 7, 64, 300] {
            let src: Vec<f32> = rng.gaussian_vec(src_len);
            for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 64, 129] {
                // Duplicates, reversals, and full coverage mixed in.
                let idx: Vec<u32> =
                    (0..n).map(|t| ((t * 31 + 3) % src_len) as u32).collect();
                let mut out = vec![0f32; n];
                (table.gather)(&src, &idx, &mut out);
                for t in 0..n {
                    assert_eq!(
                        out[t].to_bits(),
                        src[idx[t] as usize].to_bits(),
                        "{} gather src_len={src_len} n={n} t={t}",
                        table.isa
                    );
                }
            }
        }
    }
}

#[test]
fn avx512_listed_exactly_when_detected() {
    // The AVX-512 table must appear in available_tables() iff the CPU
    // has avx512f AND avx2+fma (its gather kernel runs the AVX2
    // vgatherdps) — the agreement tests above then cover it; on
    // machines without it the table is silently absent (runtime
    // gating, not compile-time).
    let listed = simd::available_tables().iter().any(|t| t.isa == "avx512");
    #[cfg(target_arch = "x86_64")]
    assert_eq!(
        listed,
        std::arch::is_x86_feature_detected!("avx512f")
            && std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
    );
    #[cfg(not(target_arch = "x86_64"))]
    assert!(!listed);
}

#[test]
fn partial_dot_range_edges() {
    let mut rng = Rng::new(0xED6E);
    let n = 197usize;
    let a: Vec<f32> = rng.gaussian_vec(n);
    let b: Vec<f32> = rng.gaussian_vec(n);
    // lo == hi (empty range, incl. both ends), full range, unaligned lo.
    for (lo, hi) in [(0usize, 0usize), (n, n), (97, 97), (0, n), (1, n), (13, 14), (3, 187)] {
        let got = partial_dot(&a, &b, lo, hi) as f64;
        let want = ref_dot(&a[lo..hi], &b[lo..hi]);
        assert!(close(got, want, 1e-4), "partial_dot [{lo},{hi}): {got} vs {want}");
        // And bitwise: partial_dot is dot on the sub-slices.
        assert_eq!(
            partial_dot(&a, &b, lo, hi).to_bits(),
            dot(&a[lo..hi], &b[lo..hi]).to_bits()
        );
    }
}

#[test]
fn dispatched_funnel_matches_active_table() {
    // The free functions in `linalg` must route to the dispatched
    // table — no private scalar copies left behind (PCA/solve/stats
    // callers all go through these).
    let active = simd::kernels();
    let mut rng = Rng::new(0xF0);
    let a: Vec<f32> = rng.gaussian_vec(300);
    let b: Vec<f32> = rng.gaussian_vec(300);
    assert_eq!(dot(&a, &b).to_bits(), (active.dot)(&a, &b).to_bits());
    assert_eq!(norm_sq(&a).to_bits(), (active.norm_sq)(&a).to_bits());
    assert_eq!(dist_sq(&a, &b).to_bits(), (active.dist_sq)(&a, &b).to_bits());
    let mut y1 = b.clone();
    let mut y2 = b.clone();
    axpy(0.5, &a, &mut y1);
    (active.axpy)(0.5, &a, &mut y2);
    assert_eq!(y1, y2);
    let mut o1 = vec![0f32; 3];
    let mut o2 = vec![0f32; 3];
    dot_rows(&a[..300], 100, &b[..100], &mut o1);
    (active.dot_rows)(&a[..300], 100, &b[..100], &mut o2);
    assert_eq!(o1, o2);
    let refs: Vec<&[f32]> = (0..3).map(|r| &a[r * 100..(r + 1) * 100]).collect();
    partial_dot_rows(&refs, &b[..100], &mut o1);
    (active.partial_dot_rows)(&refs, &b[..100], &mut o2);
    assert_eq!(o1, o2);
}

#[test]
fn force_scalar_escape_hatch_pins_scalar_table() {
    // Selection policy: forcing always lands on the scalar table…
    assert_eq!(simd::select(true).isa, "scalar");
    // …and when the harness actually set the env var (the CI matrix
    // leg), the process-wide dispatch must have honored it.
    if simd::force_scalar_requested() {
        assert_eq!(simd::active_isa(), "scalar");
        assert_eq!(
            dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]).to_bits(),
            (simd::scalar_kernels().dot)(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]).to_bits()
        );
    }
}

#[test]
fn dispatched_query_batch_argmax_matches_forced_scalar_recompute() {
    // The acceptance invariant: the exact path returns identical argmax
    // ids whether it runs on the dispatched table or the scalar one.
    // Recompute every score with the scalar table's `dot` (exactly what
    // RUST_PALLAS_FORCE_SCALAR executes) and compare full top-k id
    // lists; scores agree within the tolerance contract.
    let scalar = simd::scalar_kernels();
    let n = 300usize;
    let d = 256usize;
    let k = 5usize;
    let mut rng = Rng::new(0xA26);
    let data = Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32);
    let queries: Vec<Vec<f32>> = (0..12).map(|_| rng.gaussian_vec(d)).collect();
    let refs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
    let index = NaiveIndex::new(data.clone());
    let mut ctx = QueryContext::new();
    let batch = index.query_batch(&refs, &MipsParams { k, ..Default::default() }, &mut ctx);
    for (qi, q) in queries.iter().enumerate() {
        // Scalar-recomputed exact ranking (score desc, id asc — the
        // TopK total order).
        let mut ranked: Vec<(f32, usize)> = (0..n)
            .map(|i| ((scalar.dot)(data.row(i), q), i))
            .collect();
        ranked.sort_by(|a, b| {
            b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1))
        });
        // Gaussian scores: adjacent margins in the returned prefix dwarf
        // cross-ISA float noise. Skip the (essentially impossible)
        // degenerate draw rather than flake — argmax identity across
        // ISAs is genuinely undefined when a gap is inside the
        // contract's per-score allowance of 1e-4·(1+|s|).
        let degenerate = ranked[..k + 1].windows(2).any(|w| {
            let scale = 1.0 + w[0].0.abs().max(w[1].0.abs());
            (w[0].0 - w[1].0).abs() < 4e-4 * scale
        });
        if degenerate {
            continue;
        }
        let want_ids: Vec<usize> = ranked[..k].iter().map(|&(_, i)| i).collect();
        assert_eq!(batch[qi].indices, want_ids, "q{qi} argmax ids diverged");
        for (got, &(want, _)) in batch[qi].scores.iter().zip(&ranked[..k]) {
            assert!(
                close(*got as f64, want as f64, 1e-4),
                "q{qi}: score {got} vs scalar {want}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Widening (compressed-tier) kernel batteries — same invariants, run
// per format over `wide::available_*_tables()`.
// ---------------------------------------------------------------------------

/// Dot agreement battery for one compressed format: every available
/// table within 1e-4 of the format's scalar reference AND of the f64
/// dot over the *decoded* codes, across the probe lengths.
fn wide_dot_agreement<E: Copy>(
    format: &str,
    tables: Vec<&'static wide::WideKernels<E>>,
    scalar: &wide::WideKernels<E>,
    encode: impl Fn(f32) -> E,
    decode: impl Fn(E) -> f32,
) {
    let mut rng = Rng::new(0x31DE);
    for table in tables {
        for n in probe_lengths() {
            let codes: Vec<E> = rng.gaussian_vec(n).into_iter().map(&encode).collect();
            let q: Vec<f32> = rng.gaussian_vec(n);
            let want = (scalar.dot)(&codes, &q) as f64;
            let got = (table.dot)(&codes, &q) as f64;
            assert!(
                close(got, want, 1e-4),
                "{format}/{} vs scalar dot n={n}: {got} vs {want}",
                table.isa
            );
            // And against the f64 truth on the decoded values — the
            // codes are whatever they are; the kernels must only agree
            // on what they decode to.
            let decoded: Vec<f32> = codes.iter().map(|&c| decode(c)).collect();
            assert!(
                close(got, ref_dot(&decoded, &q), 1e-4),
                "{format}/{} dot n={n} vs decoded f64 reference",
                table.isa
            );
        }
    }
}

/// Blocked ≡ dot bit-identity battery for one compressed format: the
/// quant-tier panel equivalence (blocked panel scoring ≡ scattered
/// pulls) stands on dot_rows / partial_dot_rows being per-row
/// bit-identical to the same table's `dot`.
fn wide_blocked_bit_identity<E: Copy>(
    format: &str,
    tables: Vec<&'static wide::WideKernels<E>>,
    encode: impl Fn(f32) -> E,
) {
    let mut rng = Rng::new(0xB17E);
    for table in tables {
        for rows in 0..=9usize {
            for dim in [0usize, 1, 7, 15, 16, 17, 33, 130] {
                let block: Vec<E> =
                    rng.gaussian_vec(rows * dim).into_iter().map(&encode).collect();
                let q: Vec<f32> = rng.gaussian_vec(dim);
                let mut out = vec![0f32; rows];
                (table.dot_rows)(&block, dim, &q, &mut out);
                let refs: Vec<&[E]> =
                    (0..rows).map(|r| &block[r * dim..(r + 1) * dim]).collect();
                let mut pout = vec![0f32; rows];
                (table.partial_dot_rows)(&refs, &q, &mut pout);
                for r in 0..rows {
                    let single = (table.dot)(&block[r * dim..(r + 1) * dim], &q);
                    assert_eq!(
                        out[r].to_bits(),
                        single.to_bits(),
                        "{format}/{} dot_rows {rows}x{dim} row {r}",
                        table.isa
                    );
                    assert_eq!(
                        pout[r].to_bits(),
                        single.to_bits(),
                        "{format}/{} partial_dot_rows {rows}x{dim} row {r}",
                        table.isa
                    );
                }
            }
        }
    }
}

/// Gather exactness battery for one compressed format — pure data
/// movement over the code type, so code-for-code equality everywhere.
fn wide_gather_exact<E: Copy + PartialEq + std::fmt::Debug>(
    format: &str,
    tables: Vec<&'static wide::WideKernels<E>>,
    encode: impl Fn(f32) -> E,
) {
    let mut rng = Rng::new(0x6A78);
    for table in tables {
        for src_len in [1usize, 7, 64, 300] {
            let src: Vec<E> =
                rng.gaussian_vec(src_len).into_iter().map(&encode).collect();
            for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 64, 129] {
                let idx: Vec<u32> =
                    (0..n).map(|t| ((t * 31 + 3) % src_len) as u32).collect();
                let mut out = vec![src[0]; n];
                (table.gather)(&src, &idx, &mut out);
                for t in 0..n {
                    assert_eq!(
                        out[t],
                        src[idx[t] as usize],
                        "{format}/{} gather src_len={src_len} n={n} t={t}",
                        table.isa
                    );
                }
            }
        }
    }
}

/// Representative int8 code for a gaussian draw (encode proper needs a
/// per-row scale — see `QuantMatrix::quantize` — but the kernels only
/// see raw codes, so any spread over the i8 range exercises them).
fn i8_code(x: f32) -> i8 {
    (x * 40.0).clamp(-127.0, 127.0) as i8
}

#[test]
fn wide_f16_tables_agree_with_scalar_reference() {
    wide_dot_agreement(
        "f16",
        wide::available_f16_tables(),
        wide::f16_scalar_kernels(),
        wide::f16_from_f32,
        wide::f16_to_f32,
    );
}

#[test]
fn wide_bf16_tables_agree_with_scalar_reference() {
    wide_dot_agreement(
        "bf16",
        wide::available_bf16_tables(),
        wide::bf16_scalar_kernels(),
        wide::bf16_from_f32,
        wide::bf16_to_f32,
    );
}

#[test]
fn wide_int8_tables_agree_with_scalar_reference() {
    // int8 dots are RAW code·query sums — the per-row scale lives with
    // the caller — so the decoded reference is just `c as f32`.
    wide_dot_agreement(
        "int8",
        wide::available_int8_tables(),
        wide::int8_scalar_kernels(),
        i8_code,
        wide::i8_to_f32,
    );
}

#[test]
fn wide_blocked_kernels_bit_identical_to_their_dot() {
    wide_blocked_bit_identity("f16", wide::available_f16_tables(), wide::f16_from_f32);
    wide_blocked_bit_identity("bf16", wide::available_bf16_tables(), wide::bf16_from_f32);
    wide_blocked_bit_identity("int8", wide::available_int8_tables(), i8_code);
}

#[test]
fn wide_gather_is_exact_on_compressed_elements() {
    wide_gather_exact("f16", wide::available_f16_tables(), wide::f16_from_f32);
    wide_gather_exact("bf16", wide::available_bf16_tables(), wide::bf16_from_f32);
    wide_gather_exact("int8", wide::available_int8_tables(), i8_code);
}

#[test]
fn format_isas_reports_every_format_and_matches_dispatch() {
    // The capability listing benches/servers emit must cover all four
    // storage formats and mirror the actually-dispatched tables.
    let listing = wide::format_isas();
    let get = |f: &str| {
        listing
            .iter()
            .find(|(name, _)| *name == f)
            .map(|&(_, isa)| isa)
            .unwrap_or_else(|| panic!("format {f} missing from format_isas()"))
    };
    assert_eq!(listing.len(), 4);
    assert_eq!(get("f32"), simd::active_isa());
    assert_eq!(get("f16"), wide::f16_kernels().isa);
    assert_eq!(get("bf16"), wide::bf16_kernels().isa);
    assert_eq!(get("int8"), wide::int8_kernels().isa);
    // The forced-scalar escape hatch pins every widening table too.
    if simd::force_scalar_requested() {
        for (format, isa) in &listing {
            assert_eq!(*isa, "scalar", "{format} not pinned under FORCE_SCALAR");
        }
    }
}
