//! Sharded execution equivalence properties: for S ∈ {1, 2, 3, 7}
//! shards (contiguous and round-robin, ragged splits included), sharded
//! exact top-K is *identical* — indices and score bits — to unsharded,
//! and sharded BOUNDEDME keeps the paper's (ε, δ) guarantee under the
//! per-shard δ/S split + exact-confirm merge of `exec::shard`.

use bandit_mips::algos::{ground_truth, BoundedMeIndex, MipsIndex, MipsParams, NaiveIndex};
use bandit_mips::data::shard::{ShardSpec, ShardedMatrix};
use bandit_mips::data::synthetic::gaussian_dataset;
use bandit_mips::exec::shard::{merge_partials, shard_params, ShardedIndex};
use bandit_mips::linalg::{Matrix, Rng};
use bandit_mips::metrics::suboptimality;

const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 7];

fn specs(s: usize) -> [ShardSpec; 2] {
    [ShardSpec::contiguous(s), ShardSpec::round_robin(s)]
}

/// Exact sharded top-K is identical to the unsharded scan on random
/// instances — including ragged splits (rows chosen so `rows % S != 0`
/// for every S > 1 in the sweep) and k ≥ rows.
#[test]
fn exact_sharded_identical_to_unsharded() {
    let mut rng = Rng::new(0x5A4D);
    for case in 0..12 {
        // Odd row counts: the S = 2 split is always ragged, and the
        // S ∈ {3, 7} splits are ragged for most draws.
        let n = 23 + 2 * rng.next_below(40);
        let d = 8 + rng.next_below(96);
        let data = Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32);
        let naive = NaiveIndex::new(data.clone());
        let nq = 1 + rng.next_below(4);
        let queries: Vec<Vec<f32>> = (0..nq).map(|_| rng.gaussian_vec(d)).collect();
        let refs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        for k in [1, 5, n + 10] {
            for s in SHARD_COUNTS {
                for spec in specs(s) {
                    let mut sx = ShardedIndex::new(data.clone(), spec);
                    let got = sx.query_batch_exact(&refs, k);
                    for (qi, q) in queries.iter().enumerate() {
                        let want =
                            naive.query(q, &MipsParams { k, ..Default::default() });
                        assert_eq!(
                            got[qi].indices, want.indices,
                            "case {case} {spec:?} k={k} q{qi}"
                        );
                        assert_eq!(got[qi].scores.len(), want.scores.len());
                        for (a, b) in got[qi].scores.iter().zip(&want.scores) {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "case {case} {spec:?} k={k} q{qi}: score bits"
                            );
                        }
                    }
                }
            }
        }
    }
}

/// The acceptance gate: on a 2000×4096 seeded Gaussian dataset, a
/// sharded exact query (S ≥ 2) returns byte-identical top-K to the
/// unsharded path.
#[test]
fn acceptance_2000x4096_sharded_exact_byte_identical() {
    let ds = gaussian_dataset(2000, 4096, 20260729);
    let naive = NaiveIndex::new(ds.vectors.clone());
    let queries: Vec<Vec<f32>> = (0..2).map(|s| ds.sample_query(s)).collect();
    let refs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
    for spec in [ShardSpec::contiguous(2), ShardSpec::contiguous(3)] {
        let mut sx = ShardedIndex::new(ds.vectors.clone(), spec);
        let got = sx.query_batch_exact(&refs, 10);
        for (qi, q) in queries.iter().enumerate() {
            let want = naive.query(q, &MipsParams { k: 10, ..Default::default() });
            assert_eq!(got[qi].indices, want.indices, "{spec:?} q{qi}");
            for (a, b) in got[qi].scores.iter().zip(&want.scores) {
                assert_eq!(a.to_bits(), b.to_bits(), "{spec:?} q{qi}: score bytes differ");
            }
        }
    }
}

/// Sharded BOUNDEDME at ε → 0 recovers the exact top-K for every shard
/// count (per-shard exact elimination + exact confirm ⇒ exact merge).
#[test]
fn bounded_me_sharded_exact_at_tiny_epsilon() {
    let ds = gaussian_dataset(150, 128, 7);
    for s in SHARD_COUNTS {
        for spec in specs(s) {
            let mut sx = ShardedIndex::new(ds.vectors.clone(), spec);
            for salt in 0..3u64 {
                let q = ds.sample_query(salt);
                let truth = ground_truth(&ds.vectors, &q, 5);
                let params =
                    MipsParams { k: 5, epsilon: 1e-9, delta: 0.05, seed: salt };
                let results = sx.query_batch_bounded_me(&[&q[..]], &params);
                let res = &results[0];
                let mut got = res.indices.clone();
                got.sort_unstable();
                let mut want = truth.clone();
                want.sort_unstable();
                assert_eq!(got, want, "{spec:?} salt={salt}");
                // Never more work than S sharded exhaustive scans +
                // confirm overhead.
                let confirm = (s * 5 * 128) as u64;
                assert!(
                    res.flops <= (150 * 128) as u64 + confirm,
                    "{spec:?}: flops {}",
                    res.flops
                );
            }
        }
    }
}

/// Sharded BOUNDEDME satisfies the (ε, δ) suboptimality bound on seeded
/// Gaussian data: over many queries, the fraction exceeding ε (range-
/// relative, same normalization the index uses) stays within the δ
/// budget — for every shard count.
#[test]
fn bounded_me_sharded_meets_eps_delta_bound() {
    let ds = gaussian_dataset(220, 256, 11);
    let bound_idx = BoundedMeIndex::new(ds.vectors.clone());
    let (eps, delta) = (0.05, 0.1);
    let trials = 20;
    for s in SHARD_COUNTS {
        let mut sx = ShardedIndex::new(ds.vectors.clone(), ShardSpec::contiguous(s));
        let mut failures = 0;
        for t in 0..trials {
            let q = ds.sample_query(t as u64);
            let truth = ground_truth(&ds.vectors, &q, 1);
            let params =
                MipsParams { k: 1, epsilon: eps, delta, seed: t as u64 };
            let results = sx.query_batch_bounded_me(&[&q[..]], &params);
            let res = &results[0];
            let sub = suboptimality(&ds.vectors, &q, &truth, &res.indices);
            // Range-relative, against the *global* reward bound (each
            // shard's bound is ≤ it, so this is the honest comparison).
            let range = 2.0 * bound_idx.reward_bound(&q) as f64;
            if sub > eps * range {
                failures += 1;
            }
        }
        // δ = 0.1 over 20 trials ⇒ ~2 expected failures; 4 is > 3σ out.
        assert!(failures <= 4, "S={s}: {failures}/{trials} exceeded ε");
    }
}

/// Ragged + extreme splits: single-row shards (S = rows) and S > rows
/// behave exactly like the unsharded scan for exact queries, and the
/// per-shard param split stays well-formed (k ≥ 1, δ > 0).
#[test]
fn single_row_shards_and_overcommitted_shard_counts() {
    let mut rng = Rng::new(0xD1CE);
    let n = 9;
    let data = Matrix::from_fn(n, 24, |_, _| rng.gaussian() as f32);
    let naive = NaiveIndex::new(data.clone());
    let q: Vec<f32> = rng.gaussian_vec(24);
    for requested in [n, n * 3] {
        for spec in specs(requested) {
            let sm = ShardedMatrix::new(data.clone(), spec);
            assert_eq!(sm.num_shards(), n, "{spec:?} should clamp to {n}");
            assert!(sm.shards().iter().all(|sh| sh.rows() == 1));
            let split = shard_params(
                &MipsParams { k: 4, epsilon: 0.1, delta: 0.2, seed: 0 },
                sm.num_shards(),
                1,
            );
            assert_eq!(split.k, 1);
            assert!(split.delta > 0.0);
            let mut sx = ShardedIndex::new(data.clone(), spec);
            let exact = sx.query_batch_exact(&[&q[..]], 4);
            let want = naive.query(&q, &MipsParams { k: 4, ..Default::default() });
            assert_eq!(exact[0].indices, want.indices, "{spec:?}");
            // BOUNDEDME across single-row shards: each shard's only row
            // is confirmed exactly, so the merge is the exact top-4.
            let bme = sx.query_batch_bounded_me(
                &[&q[..]],
                &MipsParams { k: 4, epsilon: 0.3, delta: 0.2, seed: 1 },
            );
            assert_eq!(bme[0].indices, want.indices, "{spec:?} bme");
        }
    }
}

/// Duplicate scores across shards merge deterministically: identical
/// rows living on different shards tie-break by global id, no matter
/// how partials are ordered.
#[test]
fn duplicate_scores_across_shards_merge_deterministically() {
    // Four copies of the same row interleaved with distinct rows: the
    // duplicates land on different shards for every split.
    let proto = vec![1.0f32, 2.0, -1.0, 0.5];
    let mut rows: Vec<Vec<f32>> = Vec::new();
    let mut rng = Rng::new(3);
    for i in 0..12 {
        if i % 3 == 0 {
            rows.push(proto.clone());
        } else {
            rows.push(rng.gaussian_vec(4));
        }
    }
    let data = Matrix::from_rows(&rows);
    let naive = NaiveIndex::new(data.clone());
    let q = vec![0.3f32, 0.1, -0.2, 0.9];
    for s in SHARD_COUNTS {
        for spec in specs(s) {
            let mut sx = ShardedIndex::new(data.clone(), spec);
            let got = sx.query_batch_exact(&[&q[..]], 6);
            let want = naive.query(&q, &MipsParams { k: 6, ..Default::default() });
            assert_eq!(got[0].indices, want.indices, "{spec:?}");
            assert_eq!(got[0].scores, want.scores, "{spec:?}");
        }
    }
    // The duplicate rows 0, 3, 6, 9 must appear in ascending-id order
    // wherever they rank.
    let full = ShardedIndex::new(data, ShardSpec::round_robin(3))
        .query_batch_exact(&[&q[..]], 12);
    let dup_positions: Vec<usize> = full[0]
        .indices
        .iter()
        .copied()
        .filter(|i| i % 3 == 0)
        .collect();
    assert_eq!(dup_positions, vec![0, 3, 6, 9], "id tie-break violated");
}

/// merge_partials edge cases: k = 0 keeps nothing, k larger than the
/// union returns everything ranked, empty partial lists are fine.
#[test]
fn merge_edge_cases() {
    use bandit_mips::exec::shard::ShardPartial;
    let partial = |entries: Vec<(f32, usize)>| ShardPartial {
        entries,
        flops: 1,
        scanned: 1,
    };
    let r = merge_partials(0, [partial(vec![(2.0, 1)]), partial(vec![(3.0, 0)])]);
    assert!(r.indices.is_empty() && r.scores.is_empty());
    assert_eq!(r.flops, 2);

    let r = merge_partials(
        10,
        [partial(vec![(2.0, 1)]), partial(vec![]), partial(vec![(3.0, 0)])],
    );
    assert_eq!(r.indices, vec![0, 1]);

    let r = merge_partials(2, std::iter::empty());
    assert!(r.indices.is_empty());
}
