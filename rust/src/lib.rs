//! # bandit-mips
//!
//! A production-grade reproduction of *"A Bandit Approach to Maximum Inner
//! Product Search"* (Liu, Wu, Mozafari — AAAI 2019).
//!
//! The paper casts Maximum Inner Product Search (MIPS) as a Best Arm
//! Identification problem in a new bandit setting — **Multi-Armed Bandit
//! with Bounded Pulls (MAB-BP)** — where each arm's rewards are drawn
//! *without replacement* from a finite list of size `N` (the vector
//! dimension). Its algorithm, **BOUNDEDME**, is a median-elimination
//! variant using a concentration bound for sampling without replacement
//! (Bardenet & Maillard 2015), which gives:
//!
//! * zero preprocessing,
//! * a user-controlled (ε, δ) suboptimality knob per query,
//! * per-arm pull counts bounded by `N`, and
//! * `O(n√N/ε · √log(1/δ))` sample complexity.
//!
//! Because there is no preprocessing, the *per-query hot path* is the
//! entire product. The [`exec`] module is the allocation-free execution
//! core threaded through every layer: a reusable [`exec::QueryContext`]
//! scratch arena (pull-order permutation, gathered-query buffer,
//! per-arm bandit state, exact-scoring slab) plus a [`exec::QueryPlan`]
//! that picks algorithm and pull order from `(k, ε, δ, dim)`. Indexes
//! execute through [`algos::MipsIndex::query_with`] (one query, borrowed
//! scratch) and [`algos::MipsIndex::query_batch`] (a fused batch sharing
//! one coordinate permutation); the serving coordinator gives each
//! worker a long-lived context so dynamic batching fuses compute instead
//! of just queueing.
//!
//! ## Crate layout
//!
//! | module | contents |
//! |---|---|
//! | [`linalg`] | dense matrix/vector substrate (incl. zero-copy row views), RNG, PCA, top-K utilities; [`linalg::simd`] runtime-dispatched SIMD kernels (AVX-512/AVX2/NEON/scalar incl. hardware gather + software prefetch); [`linalg::simd::wide`] widening kernels over compressed f16/bf16/int8 codes |
//! | [`bandit`] | MAB-BP framework, BOUNDEDME with the survivor-compacting panel layout ([`bandit::PullPanel`] + [`bandit::Compaction`] policy), compressed-tier arms ([`bandit::QuantArms`]), bandit baselines, pull-order scratch |
//! | [`algos`]  | MIPS indexes: naive, BoundedME (incl. the two-tier sample-then-confirm compressed path), Greedy-, LSH-, PCA-, RPT-MIPS — with shard-aware batch entry points |
//! | [`exec`]   | zero-allocation execution core: `QueryContext` arena + `QueryPlan` (incl. the [`data::quant::Storage`] axis); [`exec::shard`] fan-out/merge layer |
//! | [`data`]   | dataset substrate: synthetic, adversarial, ALS matrix factorization; [`data::shard`] row sharding; [`data::quant`] mixed-precision compressed dataset tiers; [`data::generation`] copy-on-write dataset generations for live mutation |
//! | [`metrics`] | precision@K, flop accounting, latency sketches; [`metrics::prom`] Prometheus text-exposition writer |
//! | [`trace`]  | flight recorder: per-query [`trace::QueryTrace`] span trees, sampling + slow-query retention, lossy lock-free rings |
//! | [`runtime`] | scoring engines; PJRT/XLA artifact execution behind the `pjrt` feature |
//! | [`coordinator`] | serving layer: plan-aware dynamic batcher, event-driven reactor (shard fan-out, completion-event merge, straggler hedging), S = 1 fast path, shard-pinned worker pool |
//! | [`wire`] | pluggable TCP wire codecs: newline-delimited JSON (default) and length-prefixed binary frames with raw f32 query payloads, negotiated per connection from the first byte |
//! | [`experiments`] | harness regenerating every paper table/figure |
//! | [`errors`], [`logkit`], [`jsonlite`], [`sync`], [`benchkit`], [`cli`] | offline substrates (no external deps); [`sync`] adds `try_recv`/`Waker`/`Selector` polling primitives for the reactor and the [`sync::EpochGauge`] generation-reclamation gauge |
//!
//! ## SIMD kernel funnel
//!
//! Every flop — exact scans, BOUNDEDME pull batches, sharded confirm
//! rescores — funnels through [`linalg::dot`] and its siblings, which
//! dispatch once per process to a [`linalg::simd`] kernel table
//! (AVX-512 on x86-64 with `avx512f` detected, else AVX2 with
//! `avx2+fma`, NEON on aarch64, portable scalar otherwise;
//! `RUST_PALLAS_FORCE_SCALAR=1` pins scalar). Two *blocked* kernels
//! feed the batch paths: [`linalg::dot_rows`] scores several contiguous
//! dataset rows per query register load (the Naive fused scan, engine
//! batch scoring, confirm rescore; 8 rows per pass on AVX-512) and
//! [`linalg::partial_dot_rows`] runs one pull batch across a BOUNDEDME
//! survivor set. [`linalg::gather_idx`] (hardware `vgatherdps` on x86)
//! stages query gathers and panel compaction. Blocked results are
//! bit-identical per row to `dot`, so fused and per-query paths agree
//! exactly; see [`linalg::simd`] for the cross-ISA tolerance contract.
//!
//! ## Survivor-compacting elimination core
//!
//! BOUNDEDME pulls the same positional range from every surviving arm
//! each round, so once elimination thins the survivor set the
//! scattered row-major reads waste most of each cache line. Per the
//! [`bandit::Compaction`] policy (default: at survivor fraction ≤ 1/2;
//! `RUST_PALLAS_FORCE_NO_COMPACT=1` pins the scattered layout), the
//! elimination core compacts the survivors' not-yet-pulled coordinates
//! into a dense [`bandit::PullPanel`] owned by the query context — one
//! batched gather, then dense ping-pong re-compaction per round — so
//! every later pull batch is a streaming scan with software prefetch.
//! Panel pulls are **bit-identical** to scattered ones (same f64
//! accumulation order per arm), so results, flop accounting, and every
//! fused/sharded/hedged byte-identity battery are layout-independent;
//! the `hotpath` bench's `pull_scatter` vs `pull_panel` rows track the
//! win at survivor fractions 1.0 / 0.25 / 0.05.
//!
//! ## Mixed-precision storage tier
//!
//! The hot paths are memory-bandwidth-bound, so the biggest raw-speed
//! lever left is bytes per coordinate. [`data::quant`] adds a
//! [`data::quant::Storage`] axis — `f32 | f16 | bf16 | int8` (int8 with
//! a per-row scale) — building a compressed copy of the dataset with
//! the **per-row max quantization error recorded**, and
//! [`linalg::simd::wide`] supplies widening kernel tables per format
//! (F16C / AVX-512 on x86-64, NEON widening on aarch64, scalar always)
//! that keep the blocked ≡ `dot` per-row bit contract on the compressed
//! codes. A storage-configured [`algos::BoundedMeIndex`]
//! (`with_storage`) answers in **two tiers**: BOUNDEDME *samples* the
//! compressed codes with its ε budget shrunk by the worst-case
//! quantization bias `2·max_row_err·‖q‖₁/N` — so the (ε, δ) guarantee
//! stays stated against the **true f32 means** — then *confirms* the
//! ≤ k survivors with an exact f32 rescore and re-ranks on exact
//! scores. When the bias would exhaust the ε budget (e.g. ε → 0) the
//! query silently falls back to the f32 tier: compression never costs
//! correctness, only the bandwidth win. `RUST_PALLAS_FORCE_F32=1`
//! collapses every tier back to f32 (a CI leg runs the whole suite
//! under it — storage-configured deployments must be bit-identical to
//! ones without the subsystem). The serving layer takes its tier from
//! [`coordinator::CoordinatorConfig::storage`], batches by it, and
//! reports the answering tier in each [`coordinator::QueryResponse`].
//!
//! ## Sharded execution
//!
//! Datasets larger than one worker's cache-friendly slice split by
//! rows: [`data::shard::ShardedMatrix`] holds contiguous zero-copy
//! views (or round-robin gathers) over one backing matrix, and
//! [`exec::shard`] fans a `query_batch` out per shard — one
//! [`exec::QueryContext`] per shard, per-shard `(ε, δ/S)` budgets with
//! an exact *confirm* rescore so the union keeps the paper's (ε, δ)
//! guarantee — and merges partials through [`linalg::TopK`] (stable
//! global-id tie-break, so merges are deterministic). Exact sharded
//! queries are byte-identical to the unsharded scan. In-process callers
//! use [`exec::shard::ShardedIndex`].
//!
//! ## Serving
//!
//! The [`coordinator`] runs the sharded protocol in parallel behind an
//! **event-driven reactor**: batcher → reactor → shard-pinned workers →
//! completion events → merge-and-reply. The batcher is *plan-aware*
//! (it resolves [`coordinator::QueryMode::Auto`] once per query and
//! groups arrivals by exact-vs-bandit decision and `(k, ε, δ)` knobs,
//! so batches hit the fused paths), the reactor dispatches shard
//! batches without ever blocking on a channel and folds per-shard
//! partials into per-query merges as events arrive (no locks), slow
//! shards can be **hedged** onto idle sibling workers
//! ([`coordinator::CoordinatorConfig::hedge_delay`]; first completion
//! wins, duplicates are suppressed), and unsharded (`S = 1`)
//! deployments skip the reactor entirely — workers answer clients
//! directly. All of it rides the [`sync`] substrate's non-blocking
//! primitives (`try_recv`, `Waker`, `Selector`).
//!
//! ## Live mutation
//!
//! Datasets mutate under traffic without pausing queries.
//! [`data::generation`] models the dataset as a chain of immutable
//! **generations**: [`data::generation::GenerationBuilder`] applies a
//! batch of [`data::generation::Delta`]s (upsert / delete / append) to
//! generation *N* and builds *N + 1*, reusing every untouched shard's
//! rows by copy and rebuilding only dirty shards (pure-upsert batches;
//! size-changing batches renumber, so they rebuild all). Writers go
//! through [`coordinator::Coordinator::mutate`] — serialized by a
//! writer lock that queries never touch — which builds the new
//! [`exec::shard::ShardSet`] off the hot path, then flips it into the
//! reactor and every S = 1 worker **between batches**: in-flight
//! queries finish on the generation they started on, and every
//! [`coordinator::QueryResponse`] reports the generation that answered
//! it. Retired generations are reclaimed by the [`sync::EpochGauge`] —
//! each live `ShardSet` holds an epoch guard, so the moment the last
//! pinned query drops, the old generation's memory goes with it (the
//! `generations_alive` metric watches for leaks). The concurrent
//! equivalence battery (`tests/generation_equivalence.rs`) proves the
//! protocol: mutator and query threads race while every response is
//! checked bit-for-bit against a from-scratch index on the matching
//! generation's materialized snapshot, bracketed by a
//! generation-witness bound.
//!
//! ## Observability
//!
//! Process-wide aggregates can't explain one slow query of an
//! *adaptive* algorithm, so the serving layer carries a flight
//! recorder ([`trace`]). Enabled via
//! [`coordinator::CoordinatorConfig::trace`] or the `RUST_PALLAS_TRACE`
//! env pin (mirroring the forced-scalar/no-compact hatches), it
//! records a [`trace::QueryTrace`] span tree per query — queue wait,
//! resolved plan (kind / k / ε / δ / storage tier / generation pin),
//! per-shard dispatch→merge windows with hedge fire/win attribution,
//! and the BOUNDEDME per-round schedule
//! ([`bandit::RoundTrace`], incl. wall time, survivors, pull targets,
//! panel compaction, and the quant ε-bias fallback) — into lossy
//! lock-free per-thread rings ([`sync::SlotRing`]). Completed traces
//! are **sampled** (`sample_every`) and any query at or above
//! [`trace::TraceConfig::slow_threshold`] is retained unconditionally
//! plus warn-logged with its span breakdown. When disabled (the
//! default), the hot path spends zero allocations and zero atomics on
//! tracing — the decision is one bool resolved at coordinator
//! construction. Exposition: the server `trace` op returns the last N
//! traces as JSON; the `metrics` op carries the global counters
//! (now incl. `batch_items`, `hedge_lost`, `generations_alive`); and
//! the `metrics_prom` op renders Prometheus text exposition with a
//! **per-shard** breakdown (queue depth, dispatches, hedges, merge
//! latency) next to the global snapshot. Tracing on vs off is
//! bit-identity-tested (`tests/trace_observability.rs`) and a CI leg
//! runs the whole suite under `RUST_PALLAS_TRACE=1`; the hotpath
//! bench's `query/ctx_reuse_traced` row keeps the tracing tax on the
//! bench trajectory.
//!
//! ## Graceful degradation
//!
//! BOUNDEDME is an *anytime* algorithm: every elimination round ends
//! with a well-defined best-so-far answer and an achieved confidence
//! width ε̂ that halves per round. The serving layer exploits that to
//! **harvest instead of shed** under overload. The elimination core
//! checkpoints its round-end top-k + ε̂ into the query context's
//! [`bandit::BanditScratch`] whenever an [`bandit::AnytimeBudget`]
//! (soft deadline and/or FLOP cap) is armed — zero extra steady-state
//! allocations, and bit-identical results when the budget never fires.
//! [`coordinator::QueryRequest`] carries the budget over both wire
//! codecs (`deadline_ms`/`budget_flops` JSON fields; the binary frame
//! promotes itself to the `PLW2` revision per frame when a FLOP cap
//! rides the header, and the decode span's cost counts against the
//! deadline). A deadline crosses three checks: expired at admission →
//! shed (nothing was computed); expired at shard pickup → armed
//! queries fold whichever shard partials arrived and reply with
//! partial coverage, unarmed (exact-mode) queries keep the pre-anytime
//! shed-whole contract; mid-run → the bandit harvests its checkpoint.
//! Replies are a **three-way contract** — exact-complete, `degraded`
//! (results + ε̂ + shard coverage), or shed (empty) — visible in both
//! codecs, the `shed`/`degraded` metrics split, Prometheus, and a
//! `harvest` trace span. Under sustained backlog an optional
//! [`exec::DegradePolicy`] widens ε / clamps k at admission (reported
//! via `applied_epsilon`/`applied_k`, *not* marked degraded).
//! `RUST_PALLAS_FORCE_NO_DEGRADE=1` pins the whole subsystem off (a CI
//! leg runs the full suite under it — budget-armed deployments must be
//! bit-identical to a build without the subsystem), and the
//! `tests/anytime_degradation.rs` battery proves harvested answers
//! honor their reported ε̂ statistically. The serving bench's overload
//! sweep tracks the payoff: at ≥ 2× capacity the harvest path answers
//! a strictly higher fraction of queries within deadline than the
//! shed-only baseline.
//!
//! ## Wire protocol
//!
//! The TCP front-end's protocol is a pluggable [`wire::Codec`] axis,
//! negotiated **per connection from the first byte**: anything that can
//! start a JSON document keeps the original newline-delimited JSON
//! protocol bit-for-bit ([`wire::LineJsonCodec`]), while the frame
//! magic's leading `b'P'` — which no JSON document can start with —
//! selects [`wire::BinaryCodec`]. Binary transport exists because at
//! d = 4096 a query vector costs ~13 ASCII bytes per coordinate as
//! decimal JSON but exactly 4 as raw little-endian f32, and parsing the
//! text costs more than answering the query. Every frame is
//!
//! ```text
//! ┌──────────┬─────┬────┬──────────┬─────────────┬──────────────────┐
//! │ "PLW1"   │ op  │ 0  │ body_len │ QueryHeader │ B·d raw LE f32   │
//! │ magic ×4 │ u8  │ ×3 │ u32 LE   │ 48 bytes    │ coordinates      │
//! └──────────┴─────┴────┴──────────┴─────────────┴──────────────────┘
//! ```
//!
//! where `OP_QUERY` bodies carry one [`wire::frame::QueryHeader`]
//! (k, ε, δ, seed, deadline, mode, storage-tier override, count, dim)
//! followed by B vectors of contiguous raw coordinates — decoded
//! straight off the frame buffer into [`coordinator::QueryRequest`]s
//! with no intermediate JSON values, and submitted together so the
//! batcher admits the frame as **one group**. `OP_JSON` frames embed a
//! line-protocol document verbatim, so every op (metrics, mutate,
//! trace, …) works identically over either codec. Hostile length
//! prefixes (zero or > 64 MiB) are rejected from the 12-byte preamble
//! alone, before any allocation. Queries may override the sampling
//! tier per request (`"storage"` field / header byte); resolution
//! against the deployed tier is [`coordinator::resolve_storage`]'s.
//! The codec split is observable end-to-end: wire requests count into
//! `pallas_wire_requests_total{codec=…}` and the flight recorder's
//! `decode` span carries the protocol tax per query. The codec
//! equivalence battery (`tests/wire_protocol.rs`) proves both codecs
//! produce byte-identical answers; `benches/serving.rs` tracks
//! decode-only and end-to-end rows per codec.
//!
//! ## Quick start
//!
//! ```no_run
//! use bandit_mips::algos::{BoundedMeIndex, MipsIndex, MipsParams};
//! use bandit_mips::data::synthetic::gaussian_dataset;
//! use bandit_mips::exec::QueryContext;
//!
//! let ds = gaussian_dataset(1000, 512, 42);
//! let index = BoundedMeIndex::new(ds.vectors.clone());
//! let params = MipsParams { k: 5, epsilon: 0.1, delta: 0.1, ..Default::default() };
//!
//! // One-shot (allocates its own scratch):
//! let res = index.query(&ds.sample_query(7), &params);
//! println!("top-5 = {:?}", res.indices);
//!
//! // Hot path: reuse one QueryContext across queries — no per-query
//! // permutation/buffer allocations, and a whole batch shares one
//! // block-shuffled coordinate permutation.
//! let mut ctx = QueryContext::new();
//! for seed in 0..100 {
//!     let q = ds.sample_query(seed);
//!     let res = index.query_with(&q, &params, &mut ctx);
//!     assert_eq!(res.indices.len(), 5);
//! }
//! let queries: Vec<Vec<f32>> = (0..32).map(|s| ds.sample_query(s)).collect();
//! let refs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
//! let batch = index.query_batch(&refs, &params, &mut ctx);
//! assert_eq!(batch.len(), 32);
//!
//! // Mixed-precision: sample int8 codes (4× less memory traffic),
//! // confirm survivors exactly on f32 — same (ε, δ) guarantee.
//! use bandit_mips::data::quant::Storage;
//! let compressed =
//!     BoundedMeIndex::new(ds.vectors.clone()).with_storage(Storage::Int8);
//! let res = compressed.query_with(&ds.sample_query(7), &params, &mut ctx);
//! assert_eq!(res.indices.len(), 5);
//! ```

pub mod algos;
pub mod bandit;
pub mod benchkit;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod errors;
pub mod exec;
pub mod experiments;
pub mod jsonlite;
pub mod linalg;
pub mod logkit;
pub mod metrics;
pub mod runtime;
pub mod sync;
pub mod trace;
pub mod wire;

/// Crate-wide result alias.
pub type Result<T> = errors::Result<T>;
