//! # bandit-mips
//!
//! A production-grade reproduction of *"A Bandit Approach to Maximum Inner
//! Product Search"* (Liu, Wu, Mozafari — AAAI 2019).
//!
//! The paper casts Maximum Inner Product Search (MIPS) as a Best Arm
//! Identification problem in a new bandit setting — **Multi-Armed Bandit
//! with Bounded Pulls (MAB-BP)** — where each arm's rewards are drawn
//! *without replacement* from a finite list of size `N` (the vector
//! dimension). Its algorithm, **BOUNDEDME**, is a median-elimination
//! variant using a concentration bound for sampling without replacement
//! (Bardenet & Maillard 2015), which gives:
//!
//! * zero preprocessing,
//! * a user-controlled (ε, δ) suboptimality knob per query,
//! * per-arm pull counts bounded by `N`, and
//! * `O(n√N/ε · √log(1/δ))` sample complexity.
//!
//! ## Crate layout
//!
//! | module | contents |
//! |---|---|
//! | [`linalg`] | dense matrix/vector substrate, RNG, PCA, top-K utilities |
//! | [`bandit`] | MAB-BP framework, BOUNDEDME, bandit baselines |
//! | [`algos`]  | MIPS indexes: naive, BoundedME, Greedy-, LSH-, PCA-, RPT-MIPS |
//! | [`data`]   | dataset substrate: synthetic, adversarial, ALS matrix factorization |
//! | [`metrics`] | precision@K, flop accounting, latency sketches |
//! | [`runtime`] | PJRT bridge: load AOT HLO artifacts, execute on the hot path |
//! | [`coordinator`] | serving layer: router, dynamic batcher, worker pool |
//! | [`experiments`] | harness regenerating every paper table/figure |
//!
//! ## Quick start
//!
//! ```no_run
//! use bandit_mips::algos::{BoundedMeIndex, MipsIndex, MipsParams};
//! use bandit_mips::data::synthetic::gaussian_dataset;
//!
//! let ds = gaussian_dataset(1000, 512, 42);
//! let index = BoundedMeIndex::new(ds.vectors.clone());
//! let q = ds.sample_query(7);
//! let res = index.query(&q, &MipsParams { k: 5, epsilon: 0.1, delta: 0.1, ..Default::default() });
//! println!("top-5 = {:?}", res.indices);
//! ```

pub mod algos;
pub mod bandit;
pub mod benchkit;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod jsonlite;
pub mod linalg;
pub mod metrics;
pub mod runtime;
pub mod sync;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
