//! Minimal error substrate (anyhow is unavailable offline).
//!
//! Implements the subset of `anyhow`'s surface the crate uses: an opaque
//! [`Error`] holding a message chain, the [`anyhow!`]/[`bail!`] macros,
//! a crate-wide [`Result`] alias, and the [`Context`] extension trait
//! for decorating fallible calls. Any `std::error::Error` converts into
//! [`Error`] via `?`, so `io::Error` & friends propagate unchanged.
//!
//! Like `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error` itself — that is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::fmt;

/// Opaque application error: a human-readable message plus an optional
/// source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build from a plain message.
    pub fn msg(msg: impl Into<String>) -> Self {
        Self { msg: msg.into(), source: None }
    }

    /// Wrap an underlying error with a higher-level message.
    pub fn wrap(
        msg: impl Into<String>,
        source: impl std::error::Error + Send + Sync + 'static,
    ) -> Self {
        Self { msg: msg.into(), source: Some(Box::new(source)) }
    }

    /// Prepend a context message (keeps the existing chain).
    pub fn context(self, msg: impl Into<String>) -> Self {
        let msg = msg.into();
        Self { msg: format!("{msg}: {}", self.msg), source: self.source }
    }

    /// The deepest underlying error, if any.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn std::error::Error + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.source();
        while let Some(e) = src {
            write!(f, ": {e}")?;
            src = e.source();
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> Result<()>` prints Debug on error; make it read
        // like the Display chain instead of a struct dump.
        fmt::Display::fmt(self, f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Format an ad-hoc [`Error`] (drop-in for `anyhow::anyhow!`).
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::errors::Error::msg(format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`] (drop-in for `anyhow::bail!`).
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::errors::Error::msg(format!($($arg)*)))
    };
}

pub use {anyhow, bail};

/// Extension trait adding context to fallible results (drop-in for
/// `anyhow::Context`).
pub trait Context<T> {
    /// Attach a fixed context message.
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::wrap(msg.to_string(), e))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.map_err(|e| Error::wrap(f().to_string(), e))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn message_and_chain_display() {
        let e = anyhow!("top {}", 7);
        assert_eq!(e.to_string(), "top 7");
        let wrapped: Result<()> = Err(io_err()).context("loading file");
        let msg = wrapped.unwrap_err().to_string();
        assert!(msg.starts_with("loading file"), "{msg}");
        assert!(msg.contains("gone"), "{msg}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative -1");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }
}
