//! Minimal JSON substrate (serde is unavailable offline).
//!
//! Implements the subset the wire protocol needs: parsing and
//! serializing objects, arrays, strings (with escapes), numbers, bools
//! and null. Numbers are `f64`; no streaming; inputs are single
//! documents (the server frames messages by line).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object constructor from pairs.
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array of f32s.
    pub fn f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Array of usizes.
    pub fn usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Field lookup on objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Number accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Usize accessor (rejects negatives / non-integers beyond 2^53).
    pub fn as_usize(&self) -> Option<usize> {
        let x = self.as_f64()?;
        if x >= 0.0 && x.fract() == 0.0 {
            Some(x as usize)
        } else {
            None
        }
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array-of-f32 accessor.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        match self {
            Json::Arr(xs) => xs.iter().map(|x| x.as_f64().map(|v| v as f32)).collect(),
            _ => None,
        }
    }

    /// Serialize to a compact string.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.is_finite() {
                    // `-0.0` must keep its sign bit, so it takes the
                    // float branch (the i64 cast would print "0").
                    if x.fract() == 0.0 && x.abs() < 9e15 && (*x != 0.0 || x.is_sign_positive())
                    {
                        let _ = write!(out, "{}", *x as i64);
                    } else if x.abs() >= 1e17 || (*x != 0.0 && x.abs() < 1e-5) {
                        // Positional `{}` never uses an exponent, so
                        // extreme magnitudes would print hundreds of
                        // digits. `{:e}` is shortest scientific
                        // notation and still round-trips bit-exactly.
                        let _ = write!(out, "{x:e}");
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    /// Byte offset of the error.
    pub at: usize,
    /// Description.
    pub msg: &'static str,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Exact decimal powers of ten for the number fast path. Every entry
/// is exactly representable in f64 (10¹⁵ < 2⁵³), which is what makes
/// the fast path's single division correctly rounded — `10f64.powi`
/// carries no such guarantee.
const POW10: [f64; 16] = [
    1e0, 1e1, 1e2, 1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9, 1e10, 1e11, 1e12, 1e13, 1e14, 1e15,
];

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &'static str) -> Result<T, ParseError> {
        Err(ParseError { at: self.i, msg })
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err("unexpected character")
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            self.err("bad literal")
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected value"),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let Some(c) = self.peek() else { return self.err("unterminated string") };
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let Some(e) = self.peek() else { return self.err("bad escape") };
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return self.err("short \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .ok()
                                    .and_then(|h| u32::from_str_radix(h, 16).ok());
                            self.i += 4;
                            match hex.and_then(char::from_u32) {
                                Some(ch) => s.push(ch),
                                None => return self.err("bad \\u escape"),
                            }
                        }
                        _ => return self.err("bad escape"),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        if start + len > self.b.len() {
                            return self.err("bad utf8");
                        }
                        match std::str::from_utf8(&self.b[start..start + len]) {
                            Ok(chunk) => {
                                s.push_str(chunk);
                                self.i = start + len;
                            }
                            Err(_) => return self.err("bad utf8"),
                        }
                    }
                }
            }
        }
    }

    /// Single-pass fast path for the common wire-format number shape:
    /// optional sign, digits, optional fraction, **no exponent**, and
    /// at most 15 total digits. The accumulated mantissa (< 2⁵³) and
    /// the divisor (10^frac ≤ 10¹⁵, from the exact [`POW10`] table)
    /// are both exactly representable, so the single IEEE division is
    /// correctly rounded — bit-identical to `str::parse::<f64>` on the
    /// same text (Clinger's strtod fast path). Returns `None` without
    /// consuming input on any shape it cannot prove exact; the caller
    /// falls back to the general parse.
    fn number_fast(&mut self) -> Option<f64> {
        let b = self.b;
        let mut j = self.i;
        let neg = b.get(j) == Some(&b'-');
        if neg {
            j += 1;
        }
        let mut mant: u64 = 0;
        let mut digits = 0usize;
        let int_start = j;
        while let Some(c) = b.get(j) {
            if !c.is_ascii_digit() {
                break;
            }
            mant = mant.wrapping_mul(10).wrapping_add((c - b'0') as u64);
            digits += 1;
            j += 1;
        }
        if j == int_start {
            return None; // no integer digits — not a shape we handle
        }
        let mut frac = 0usize;
        if b.get(j) == Some(&b'.') {
            j += 1;
            let frac_start = j;
            while let Some(c) = b.get(j) {
                if !c.is_ascii_digit() {
                    break;
                }
                mant = mant.wrapping_mul(10).wrapping_add((c - b'0') as u64);
                digits += 1;
                frac += 1;
                j += 1;
            }
            if j == frac_start {
                return None; // "1." — defer to the general path
            }
        }
        if matches!(b.get(j), Some(b'e') | Some(b'E')) {
            return None; // exponent: general path
        }
        if digits > 15 {
            return None; // mantissa may no longer be exact
        }
        self.i = j;
        let v = mant as f64 / POW10[frac];
        Some(if neg { -v } else { v })
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        if let Some(v) = self.number_fast() {
            return Ok(Json::Num(v));
        }
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(ParseError { at: start, msg: "bad number" })
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return self.err("expected , or ]"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return self.err("expected , or }"),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return p.err("trailing data");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for (text, want) in [
            ("null", Json::Null),
            ("true", Json::Bool(true)),
            ("false", Json::Bool(false)),
            ("42", Json::Num(42.0)),
            ("-3.5e2", Json::Num(-350.0)),
            ("\"hi\"", Json::Str("hi".into())),
        ] {
            assert_eq!(parse(text).unwrap(), want, "{text}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a":[1,2,{"b":null}],"c":"x\ny","d":true}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let dumped = v.dump();
        assert_eq!(parse(&dumped).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("quote\" slash\\ nl\n tab\t ctl\u{1}".into());
        let d = v.dump();
        assert_eq!(parse(&d).unwrap(), v);
    }

    #[test]
    fn unicode_strings() {
        let v = parse(r#""héllo ☃ ☃""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃ ☃");
        assert_eq!(parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "\"", "{\"a\"}", "tru", "1 2", "{'a':1}"] {
            assert!(parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn accessors() {
        let v = Json::obj([
            ("k", Json::Num(5.0)),
            ("xs", Json::f32s(&[1.0, 2.5])),
            ("ids", Json::usizes(&[3, 4])),
            ("flag", Json::Bool(true)),
        ]);
        assert_eq!(v.get("k").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("xs").unwrap().as_f32_vec(), Some(vec![1.0, 2.5]));
        assert_eq!(v.get("flag").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("missing"), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(-2.0).as_usize(), None);
    }

    #[test]
    fn number_fast_path_is_bit_identical_to_std_parse() {
        // Shapes the single-pass accumulator handles directly.
        for text in [
            "0",
            "-0",
            "1",
            "42",
            "-3.5",
            "0.1",
            "123.456",
            "999999999999999",
            "-0.0",
            "0.000123",
            "7.25",
        ] {
            let want: f64 = text.parse().unwrap();
            match parse(text).unwrap() {
                Json::Num(x) => {
                    assert_eq!(x.to_bits(), want.to_bits(), "fast path diverged on {text}")
                }
                other => panic!("{text} parsed to {other:?}"),
            }
        }
    }

    #[test]
    fn number_slow_path_covers_exponents_and_long_mantissas() {
        // Exponents and > 15-digit mantissas must fall back to the
        // general parse and still agree with `str::parse` bit for bit.
        for text in [
            "1e3",
            "-2.5E-4",
            "1.7976931348623157e308",
            "5e-324",
            "0.1234567890123456789",
            "3.141592653589793",
        ] {
            let want: f64 = text.parse().unwrap();
            match parse(text).unwrap() {
                Json::Num(x) => {
                    assert_eq!(x.to_bits(), want.to_bits(), "slow path diverged on {text}")
                }
                other => panic!("{text} parsed to {other:?}"),
            }
        }
    }

    #[test]
    fn extreme_magnitudes_dump_scientific_and_roundtrip() {
        for v in
            [1e300f64, -1e300, 1e-300, 5e-324, 1.5e18, f64::MAX, f64::MIN_POSITIVE, 2.5e-7]
        {
            let d = Json::Num(v).dump();
            assert!(d.len() < 32, "{v} should dump compactly, got {d:?}");
            match parse(&d).unwrap() {
                Json::Num(x) => assert_eq!(x.to_bits(), v.to_bits(), "{v} via {d}"),
                other => panic!("{d} parsed to {other:?}"),
            }
        }
    }

    #[test]
    fn negative_zero_keeps_its_sign_bit() {
        let d = Json::Num(-0.0).dump();
        assert_eq!(d, "-0");
        match parse(&d).unwrap() {
            Json::Num(x) => assert_eq!(x.to_bits(), (-0.0f64).to_bits()),
            other => panic!("-0 parsed to {other:?}"),
        }
    }

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
    }

    #[test]
    fn whitespace_tolerated() {
        let v = parse(" { \"a\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap(), &Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]));
    }
}
