//! Flight recorder: per-query span traces from admission to reply.
//!
//! The paper's whole point is that per-query work is *adaptive* —
//! elimination rounds, pulls, the achieved round schedule, and
//! quantization fallbacks vary query by query — so process-wide
//! aggregates ([`crate::coordinator::MetricsSnapshot`]) cannot explain
//! where one slow query's time went. This module records a
//! [`QueryTrace`] span tree per query and keeps the most recent ones in
//! lossy lock-free rings ([`crate::sync::SlotRing`], one per recording
//! thread), following the all-atomic discipline of
//! `coordinator/stats.rs`.
//!
//! # Lifecycle
//!
//! * The coordinator decides **once at construction** whether tracing
//!   is on: [`TraceConfig::enabled`] or the [`TRACE_ENV`] pin
//!   (mirroring the forced-scalar / no-compact hatches). The decision
//!   is carried as a plain bool through every thread and batch, so a
//!   disabled hot path performs **zero allocations and zero atomic
//!   operations** for tracing — cheaper than the one-relaxed-load
//!   budget the subsystem is allowed.
//! * When enabled, every query accumulates spans: queue wait, plan
//!   resolution (kind / k / ε / δ / storage tier / generation pin),
//!   per-shard dispatch → merge windows with hedge fire/win
//!   attribution, and the BOUNDEDME per-round schedule
//!   ([`crate::bandit::RoundTrace`], now with wall time) staged by the
//!   worker through [`TraceStage`].
//! * At reply time the trace is published if it is **sampled**
//!   (`seq % sample_every == 0`) or **slow** (service time ≥
//!   [`TraceConfig::slow_threshold`] — slow queries are always
//!   retained and also emit one `logkit` warn line with the span
//!   breakdown).
//!
//! # Exposition
//!
//! Three ways out: the server `trace` op returns the last N retained
//! traces as JSON span trees ([`trace_to_json`]); slow queries log
//! themselves; and the `metrics_prom` op renders the per-shard counter
//! breakdown next to the global snapshot in Prometheus text format
//! (see `coordinator/stats.rs` / `metrics::prom`).

use crate::bandit::RoundTrace;
use crate::jsonlite::Json;
use crate::sync::SlotRing;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// Environment pin: any non-empty value other than `0` enables the
/// flight recorder with default knobs, regardless of
/// [`TraceConfig::enabled`]. Mirrors `RUST_PALLAS_FORCE_SCALAR` /
/// `RUST_PALLAS_FORCE_NO_COMPACT`.
pub const TRACE_ENV: &str = "RUST_PALLAS_TRACE";

/// True when [`TRACE_ENV`] requests tracing (read once, cached).
pub fn trace_env_requested() -> bool {
    static FORCED: OnceLock<bool> = OnceLock::new();
    *FORCED.get_or_init(|| match std::env::var(TRACE_ENV) {
        Ok(v) => !(v.is_empty() || v == "0"),
        Err(_) => false,
    })
}

/// Flight-recorder knobs (part of
/// [`crate::coordinator::CoordinatorConfig`]).
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Master switch; `false` still yields to the [`TRACE_ENV`] pin.
    pub enabled: bool,
    /// Keep every `sample_every`-th completed trace (1 = all). Slow
    /// queries are always kept.
    pub sample_every: u64,
    /// Service time at or above which a query is considered slow:
    /// always retained, and logged at warn level with its breakdown.
    pub slow_threshold: Duration,
    /// Slots per recording thread's ring.
    pub ring_capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            enabled: false,
            sample_every: 1,
            slow_threshold: Duration::from_millis(100),
            ring_capacity: 64,
        }
    }
}

/// One timed interval of a query's lifetime. Offsets are nanoseconds
/// from the query's submission instant, so sibling spans are directly
/// comparable.
#[derive(Clone, Debug)]
pub struct Span {
    /// What the interval covers (`"decode"`, `"queue"`, `"shard"`,
    /// `"bandit"`, `"round"`, `"confirm"`, `"compute"`).
    pub label: &'static str,
    /// Shard the span is scoped to, `-1` for query-wide spans.
    pub shard: i64,
    /// Start offset from submission, ns.
    pub start_ns: u64,
    /// End offset from submission, ns (≥ `start_ns`).
    pub end_ns: u64,
    /// Free-form numeric attributes (worker id, hedge flags, survivor
    /// counts, pull targets…), flattened into the JSON object.
    pub detail: Vec<(&'static str, f64)>,
}

impl Span {
    /// Span length in nanoseconds.
    pub fn duration_ns(&self) -> u64 {
        self.end_ns - self.start_ns
    }
}

/// A completed query's trace: identity, plan resolution, timing roll-up
/// and the span tree (flat list; `shard` scopes the per-shard subtree,
/// `"round"` spans nest inside their shard's `"bandit"` span by
/// construction).
#[derive(Clone, Debug)]
pub struct QueryTrace {
    /// Global publication order (monotone across all recording threads).
    pub seq: u64,
    /// Reactor query id, or the submission counter on the S = 1 path.
    pub query_id: u64,
    /// Resolved plan: `"exact"`, `"bounded_me"`, `"shed"`, or
    /// `"degraded"` (a deadline-harvested partial answer).
    pub kind: &'static str,
    /// Requested top-K.
    pub k: usize,
    /// Requested ε (0 for exact).
    pub epsilon: f64,
    /// Requested δ (0 for exact).
    pub delta: f64,
    /// Storage tier label the plan resolved to (`"f32"`, `"f16"`, …).
    pub storage: &'static str,
    /// Generation the query was pinned to.
    pub generation: u64,
    /// Items in the batch this query rode in.
    pub batch_size: usize,
    /// Shards fanned out to.
    pub shards: usize,
    /// Whether a straggler hedge fired for any of this query's shards.
    pub hedge_fired: bool,
    /// Whether a hedge dispatch delivered the winning partial.
    pub hedge_won: bool,
    /// Wire-decode wall time, ns (0 for in-process submissions). The
    /// codec pays this *before* submission, so the matching `"decode"`
    /// span is re-anchored at `[0, decode_ns]` — the protocol tax shows
    /// up ahead of the queue wait instead of vanishing off-trace.
    pub decode_ns: u64,
    /// Submission → pickup, ns.
    pub queue_wait_ns: u64,
    /// Pickup → reply, ns.
    pub service_ns: u64,
    /// Deadline-shed (no result was produced).
    pub shed: bool,
    /// Deadline-degraded: a harvested partial answer was returned
    /// instead of shedding (see the coordinator's deadline lifecycle).
    pub degraded: bool,
    /// Achieved confidence width ε̂ of a degraded reply
    /// (request-relative units; 0 when not degraded).
    pub epsilon_hat: f64,
    /// Service time reached [`TraceConfig::slow_threshold`].
    pub slow: bool,
    /// The span tree.
    pub spans: Vec<Span>,
}

/// Accumulates one query's spans against its submission instant.
pub struct TraceBuilder {
    t0: Instant,
    /// The trace under construction (seq/slow are filled at publish).
    pub trace: QueryTrace,
}

impl TraceBuilder {
    /// Builder anchored at the query's submission instant.
    pub fn new(t0: Instant, query_id: u64, kind: &'static str) -> Self {
        TraceBuilder {
            t0,
            trace: QueryTrace {
                seq: 0,
                query_id,
                kind,
                k: 0,
                epsilon: 0.0,
                delta: 0.0,
                storage: "f32",
                generation: 0,
                batch_size: 0,
                shards: 1,
                hedge_fired: false,
                hedge_won: false,
                decode_ns: 0,
                queue_wait_ns: 0,
                service_ns: 0,
                shed: false,
                degraded: false,
                epsilon_hat: 0.0,
                slow: false,
                spans: Vec::new(),
            },
        }
    }

    /// Nanosecond offset of `t` from submission (0 if `t` precedes it).
    pub fn offset_ns(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.t0).map(|d| d.as_nanos() as u64).unwrap_or(0)
    }

    /// Add a span from two instants.
    pub fn span(
        &mut self,
        label: &'static str,
        shard: i64,
        start: Instant,
        end: Instant,
        detail: Vec<(&'static str, f64)>,
    ) {
        let start_ns = self.offset_ns(start);
        let end_ns = self.offset_ns(end).max(start_ns);
        self.span_ns(label, shard, start_ns, end_ns, detail);
    }

    /// Add a span from precomputed offsets.
    pub fn span_ns(
        &mut self,
        label: &'static str,
        shard: i64,
        start_ns: u64,
        end_ns: u64,
        detail: Vec<(&'static str, f64)>,
    ) {
        self.trace.spans.push(Span { label, shard, start_ns, end_ns: end_ns.max(start_ns), detail });
    }
}

/// Counters and sampling knobs shared by every recorder of one
/// coordinator. All-atomic, relaxed everywhere.
pub struct TraceShared {
    seq: AtomicU64,
    sample_every: u64,
    slow_ns: u64,
    published: AtomicU64,
    slow_seen: AtomicU64,
}

/// One recording thread's handle: its ring plus the shared sampler.
pub struct TraceRecorder {
    ring: Arc<SlotRing<QueryTrace>>,
    shared: Arc<TraceShared>,
}

impl TraceRecorder {
    /// Finalize and (maybe) retain a completed trace: stamps the global
    /// sequence number, always warn-logs slow queries, and pushes into
    /// the ring when sampled or slow.
    pub fn publish(&self, mut builder: TraceBuilder) {
        let seq = self.shared.seq.fetch_add(1, Ordering::Relaxed);
        let t = &mut builder.trace;
        t.seq = seq;
        t.slow = self.shared.slow_ns > 0 && t.service_ns >= self.shared.slow_ns;
        if t.slow {
            self.shared.slow_seen.fetch_add(1, Ordering::Relaxed);
            crate::logkit::warn!(
                "slow query {} ({}): queue {:.3}ms service {:.3}ms gen {} [{}]",
                t.query_id,
                t.kind,
                t.queue_wait_ns as f64 / 1e6,
                t.service_ns as f64 / 1e6,
                t.generation,
                span_breakdown(t)
            );
        }
        if t.slow || seq % self.shared.sample_every == 0 {
            self.shared.published.fetch_add(1, Ordering::Relaxed);
            self.ring.push(builder.trace);
        }
    }
}

/// One-line span breakdown for the slow-query log record.
fn span_breakdown(t: &QueryTrace) -> String {
    let mut s = String::new();
    for sp in &t.spans {
        if !s.is_empty() {
            s.push_str(", ");
        }
        if sp.shard >= 0 {
            s.push_str(&format!("{}/s{} {:.3}ms", sp.label, sp.shard, sp.duration_ns() as f64 / 1e6));
        } else {
            s.push_str(&format!("{} {:.3}ms", sp.label, sp.duration_ns() as f64 / 1e6));
        }
    }
    s
}

/// All recording rings of one coordinator plus the shared sampler: the
/// reader side hands out [`TraceRecorder`]s at construction and merges
/// ring snapshots for the server `trace` op.
pub struct TraceSink {
    rings: Vec<Arc<SlotRing<QueryTrace>>>,
    shared: Arc<TraceShared>,
}

impl TraceSink {
    /// Sink with one ring per recording thread.
    pub fn new(cfg: &TraceConfig, threads: usize) -> Self {
        let shared = Arc::new(TraceShared {
            seq: AtomicU64::new(0),
            sample_every: cfg.sample_every.max(1),
            slow_ns: cfg.slow_threshold.as_nanos() as u64,
            published: AtomicU64::new(0),
            slow_seen: AtomicU64::new(0),
        });
        let rings = (0..threads.max(1))
            .map(|_| Arc::new(SlotRing::new(cfg.ring_capacity.max(1))))
            .collect();
        TraceSink { rings, shared }
    }

    /// Recorder for recording thread `thread` (threads beyond the ring
    /// count share by modulo — still lock-free, only lossier).
    pub fn recorder(&self, thread: usize) -> TraceRecorder {
        TraceRecorder {
            ring: Arc::clone(&self.rings[thread % self.rings.len()]),
            shared: Arc::clone(&self.shared),
        }
    }

    /// The most recent `limit` retained traces, newest first.
    pub fn collect(&self, limit: usize) -> Vec<QueryTrace> {
        let mut out = Vec::new();
        for ring in &self.rings {
            ring.snapshot_into(&mut out);
        }
        out.sort_by(|a, b| b.seq.cmp(&a.seq));
        out.truncate(limit);
        out
    }

    /// Traces retained (sampled or slow) since construction.
    pub fn published(&self) -> u64 {
        self.shared.published.load(Ordering::Relaxed)
    }

    /// Slow queries seen since construction.
    pub fn slow_seen(&self) -> u64 {
        self.shared.slow_seen.load(Ordering::Relaxed)
    }
}

/// Worker-side staging area, embedded in
/// [`crate::exec::QueryContext`]: the BOUNDEDME index pushes one
/// [`QueryExec`] per executed query while `armed`, and the serving
/// layer drains them into spans. Default (disarmed) state is inert —
/// one bool check per query, no clock reads, no allocation.
#[derive(Default)]
pub struct TraceStage {
    /// Whether executions should be staged.
    pub armed: bool,
    /// Set by the quantized two-tier path when the ε-bias fallback
    /// forced an f32 run; folded into the next staged [`QueryExec`].
    pub quant_fallback: bool,
    /// Staged executions, in query order.
    pub queries: Vec<QueryExec>,
}

impl TraceStage {
    /// Start staging a traced batch (clears leftovers).
    pub fn arm(&mut self) {
        self.armed = true;
        self.quant_fallback = false;
        self.queries.clear();
    }

    /// Stop staging and take the staged executions.
    pub fn finish(&mut self) -> Vec<QueryExec> {
        self.armed = false;
        self.quant_fallback = false;
        std::mem::take(&mut self.queries)
    }
}

/// One query's execution telemetry as staged by
/// [`crate::algos::BoundedMeIndex`]: the bandit window, the confirm
/// rescoring window, and the per-round schedule.
#[derive(Clone, Debug)]
pub struct QueryExec {
    /// Execution start (sampling phase entry).
    pub started: Instant,
    /// Execution end (after confirm, before ranking the reply).
    pub ended: Instant,
    /// Time inside the elimination core, ns.
    pub bandit_ns: u64,
    /// Time confirming survivors on exact f32 scores, ns.
    pub confirm_ns: u64,
    /// Total arm pulls the run spent.
    pub total_pulls: u64,
    /// Whether sampling ran on a compressed tier.
    pub quant: bool,
    /// Whether a present compressed tier fell back to f32 because the
    /// quantization bias exhausted ε.
    pub quant_fallback: bool,
    /// Set when an armed [`crate::bandit::AnytimeBudget`] expired
    /// mid-run and the round checkpoint was harvested: the achieved
    /// confidence width ε̂ in request-relative units.
    pub harvest: Option<f64>,
    /// Per-round schedule (with wall time) from the elimination core.
    pub rounds: Vec<RoundTrace>,
}

impl QueryExec {
    /// Fresh record starting now.
    pub fn begin() -> Self {
        let now = Instant::now();
        QueryExec {
            started: now,
            ended: now,
            bandit_ns: 0,
            confirm_ns: 0,
            total_pulls: 0,
            quant: false,
            quant_fallback: false,
            harvest: None,
            rounds: Vec::new(),
        }
    }
}

/// Render one trace as a JSON span tree for the server `trace` op.
pub fn trace_to_json(t: &QueryTrace) -> Json {
    Json::obj([
        ("seq", Json::Num(t.seq as f64)),
        ("query_id", Json::Num(t.query_id as f64)),
        ("kind", Json::Str(t.kind.to_string())),
        ("k", Json::Num(t.k as f64)),
        ("epsilon", Json::Num(t.epsilon)),
        ("delta", Json::Num(t.delta)),
        ("storage", Json::Str(t.storage.to_string())),
        ("generation", Json::Num(t.generation as f64)),
        ("batch_size", Json::Num(t.batch_size as f64)),
        ("shards", Json::Num(t.shards as f64)),
        ("hedge_fired", Json::Bool(t.hedge_fired)),
        ("hedge_won", Json::Bool(t.hedge_won)),
        ("decode_us", Json::Num(t.decode_ns as f64 / 1e3)),
        ("queue_wait_us", Json::Num(t.queue_wait_ns as f64 / 1e3)),
        ("service_us", Json::Num(t.service_ns as f64 / 1e3)),
        ("shed", Json::Bool(t.shed)),
        ("degraded", Json::Bool(t.degraded)),
        ("epsilon_hat", Json::Num(t.epsilon_hat)),
        ("slow", Json::Bool(t.slow)),
        ("spans", Json::Arr(t.spans.iter().map(span_to_json).collect())),
    ])
}

fn span_to_json(s: &Span) -> Json {
    let mut pairs: Vec<(&'static str, Json)> = vec![
        ("label", Json::Str(s.label.to_string())),
        ("shard", Json::Num(s.shard as f64)),
        ("start_us", Json::Num(s.start_ns as f64 / 1e3)),
        ("end_us", Json::Num(s.end_ns as f64 / 1e3)),
    ];
    for (k, v) in &s.detail {
        pairs.push((k, Json::Num(*v)));
    }
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_builder(kind: &'static str) -> TraceBuilder {
        TraceBuilder::new(Instant::now(), 7, kind)
    }

    #[test]
    fn builder_offsets_are_monotone_and_clamped() {
        let t0 = Instant::now();
        let mut b = TraceBuilder::new(t0, 1, "bounded_me");
        // An instant before t0 clamps to 0 instead of underflowing.
        if let Some(before) = t0.checked_sub(Duration::from_millis(5)) {
            assert_eq!(b.offset_ns(before), 0);
        }
        let later = t0 + Duration::from_micros(50);
        b.span("queue", -1, t0, later, vec![]);
        assert_eq!(b.trace.spans.len(), 1);
        assert!(b.trace.spans[0].end_ns >= b.trace.spans[0].start_ns);
    }

    #[test]
    fn sampling_and_slow_retention() {
        let cfg = TraceConfig {
            enabled: true,
            sample_every: 1000,
            slow_threshold: Duration::from_millis(1),
            ring_capacity: 8,
        };
        let sink = TraceSink::new(&cfg, 1);
        let rec = sink.recorder(0);
        // seq 0 is sampled; seqs 1.. are not, and stay below threshold.
        for _ in 0..5 {
            let mut b = mk_builder("exact");
            b.trace.service_ns = 10_000; // 10µs, fast
            rec.publish(b);
        }
        assert_eq!(sink.published(), 1);
        // A slow query is retained regardless of the sample gate.
        let mut b = mk_builder("bounded_me");
        b.trace.service_ns = 5_000_000; // 5ms ≥ 1ms threshold
        rec.publish(b);
        assert_eq!(sink.published(), 2);
        assert_eq!(sink.slow_seen(), 1);
        let got = sink.collect(16);
        assert_eq!(got.len(), 2);
        // Newest first, and the slow one is the newest.
        assert!(got[0].seq > got[1].seq);
        assert!(got[0].slow);
        assert!(!got[1].slow);
    }

    #[test]
    fn collect_merges_rings_and_truncates() {
        let cfg = TraceConfig { enabled: true, ..Default::default() };
        let sink = TraceSink::new(&cfg, 3);
        for t in 0..3 {
            let rec = sink.recorder(t);
            for _ in 0..4 {
                rec.publish(mk_builder("exact"));
            }
        }
        let all = sink.collect(usize::MAX);
        assert_eq!(all.len(), 12);
        // Globally ordered newest-first despite per-thread rings.
        assert!(all.windows(2).all(|w| w[0].seq > w[1].seq));
        assert_eq!(sink.collect(5).len(), 5);
    }

    #[test]
    fn stage_arm_and_finish_roundtrip() {
        let mut stage = TraceStage::default();
        assert!(!stage.armed);
        stage.arm();
        assert!(stage.armed);
        let mut e = QueryExec::begin();
        e.total_pulls = 42;
        stage.queries.push(e);
        let drained = stage.finish();
        assert!(!stage.armed);
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].total_pulls, 42);
        assert!(stage.queries.is_empty());
    }

    #[test]
    fn json_rendering_roundtrips_through_jsonlite() {
        let mut b = mk_builder("bounded_me");
        b.trace.k = 5;
        b.trace.epsilon = 0.05;
        b.trace.storage = "f16";
        b.trace.batch_size = 3;
        let t0 = Instant::now();
        b.span("shard", 1, t0, t0 + Duration::from_micros(10), vec![("worker", 2.0)]);
        let json = trace_to_json(&b.trace);
        let parsed = crate::jsonlite::parse(&json.dump()).unwrap();
        assert_eq!(parsed.get("kind").unwrap().as_str().unwrap(), "bounded_me");
        assert_eq!(parsed.get("k").unwrap().as_usize().unwrap(), 5);
        assert_eq!(parsed.get("storage").unwrap().as_str().unwrap(), "f16");
        let spans = match parsed.get("spans").unwrap() {
            Json::Arr(xs) => xs,
            other => panic!("spans not an array: {other:?}"),
        };
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].get("label").unwrap().as_str().unwrap(), "shard");
        assert_eq!(spans[0].get("worker").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn env_pin_parse_contract() {
        // The OnceLock caches the ambient value; just pin the parse
        // contract on the cached result being a bool (the CI `trace`
        // leg exercises the enabled path end to end).
        let _ = trace_env_requested();
    }
}
