//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! [`Bencher::iter`] warms up, runs timed batches until a wall-clock
//! budget is spent, and reports mean / σ / min / p50 per iteration. The
//! bench binaries print a summary table at the end via [`Reporter`],
//! and can persist machine-readable results with
//! [`Reporter::write_json`] (`BENCH_<name>.json`) so the perf
//! trajectory is tracked across PRs.

use crate::jsonlite::Json;
use std::time::{Duration, Instant};

/// Result of one measured benchmark.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Benchmark label.
    pub name: String,
    /// Iterations measured.
    pub iters: u64,
    /// Mean seconds per iteration.
    pub mean: f64,
    /// Standard deviation per iteration.
    pub std: f64,
    /// Fastest iteration.
    pub min: f64,
    /// Median iteration.
    pub median: f64,
    /// Extra per-row fields serialized alongside the timing columns in
    /// the JSON output (e.g. `storage`, `bytes_per_coord`, `simd_isa`
    /// on mixed-precision rows). Diff tooling keys on `(name, storage)`
    /// — see `scripts/bench_diff.py`.
    pub tags: Vec<(&'static str, Json)>,
}

impl Measurement {
    /// Attach a per-row JSON field (builder-style).
    pub fn with_tag(mut self, key: &'static str, value: Json) -> Self {
        self.tags.push((key, value));
        self
    }
    /// `value ± σ` with adaptive units.
    pub fn human(&self) -> String {
        fn fmt(s: f64) -> String {
            if s >= 1.0 {
                format!("{s:.3} s")
            } else if s >= 1e-3 {
                format!("{:.3} ms", s * 1e3)
            } else if s >= 1e-6 {
                format!("{:.3} µs", s * 1e6)
            } else {
                format!("{:.1} ns", s * 1e9)
            }
        }
        format!("{} ± {} (n={})", fmt(self.mean), fmt(self.std), self.iters)
    }
}

/// Benchmark runner with a fixed time budget per benchmark.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    max_iters: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            max_iters: 1_000_000,
        }
    }
}

impl Bencher {
    /// Runner with explicit budgets.
    pub fn new(warmup: Duration, budget: Duration) -> Self {
        Self { warmup, budget, max_iters: 1_000_000 }
    }

    /// Quick runner for CI-ish runs.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            max_iters: 100_000,
        }
    }

    /// Measure a closure. The closure's return value is consumed via
    /// `std::hint::black_box` to keep the optimizer honest.
    pub fn iter<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Timed iterations.
        let mut samples = Vec::new();
        let b0 = Instant::now();
        while b0.elapsed() < self.budget && (samples.len() as u64) < self.max_iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        let n = samples.len().max(1) as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Measurement {
            name: name.to_string(),
            iters: samples.len() as u64,
            mean,
            std: var.sqrt(),
            min: sorted.first().copied().unwrap_or(0.0),
            median: sorted.get(sorted.len() / 2).copied().unwrap_or(0.0),
            tags: Vec::new(),
        }
    }
}

/// Collects measurements and prints an aligned summary.
#[derive(Default)]
pub struct Reporter {
    rows: Vec<Measurement>,
}

impl Reporter {
    /// Empty reporter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record (and echo) a measurement.
    pub fn push(&mut self, m: Measurement) {
        println!("bench {:<44} {}", m.name, m.human());
        self.rows.push(m);
    }

    /// Measure + record in one call.
    pub fn bench<T>(&mut self, b: &Bencher, name: &str, f: impl FnMut() -> T) {
        let m = b.iter(name, f);
        self.push(m);
    }

    /// Measure + record with per-row JSON tags (see
    /// [`Measurement::tags`]).
    pub fn bench_tagged<T>(
        &mut self,
        b: &Bencher,
        name: &str,
        tags: &[(&'static str, Json)],
        f: impl FnMut() -> T,
    ) {
        let mut m = b.iter(name, f);
        m.tags.extend(tags.iter().cloned());
        self.push(m);
    }

    /// Recorded measurements.
    pub fn rows(&self) -> &[Measurement] {
        &self.rows
    }

    /// All measurements as a JSON document, with optional extra
    /// top-level fields (e.g. allocation counters, qps figures).
    pub fn to_json(&self, title: &str, extra: &[(&'static str, Json)]) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|m| {
                let mut fields = vec![
                    ("name", Json::Str(m.name.clone())),
                    ("iters", Json::Num(m.iters as f64)),
                    ("mean_s", Json::Num(m.mean)),
                    ("std_s", Json::Num(m.std)),
                    ("min_s", Json::Num(m.min)),
                    ("median_s", Json::Num(m.median)),
                ];
                fields.extend(m.tags.iter().cloned());
                Json::obj(fields)
            })
            .collect();
        let mut fields = vec![
            ("bench", Json::Str(title.to_string())),
            ("results", Json::Arr(rows)),
        ];
        fields.extend(extra.iter().cloned());
        Json::obj(fields)
    }

    /// Write [`Reporter::to_json`] to `path` (best-effort: benches must
    /// not fail on a read-only filesystem; errors go to stderr).
    pub fn write_json(&self, title: &str, path: &str, extra: &[(&'static str, Json)]) {
        let doc = self.to_json(title, extra).dump();
        match std::fs::write(path, &doc) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }

    /// Final summary block.
    pub fn finish(&self, title: &str) {
        println!("\n== {title} ==");
        for m in &self.rows {
            println!(
                "{:<44} mean {:>12.6} ms  min {:>12.6} ms  n={}",
                m.name,
                m.mean * 1e3,
                m.min * 1e3,
                m.iters
            );
        }
    }
}

/// True when a quick bench run is requested (`BENCH_QUICK=1`, or always
/// under `cargo test`).
pub fn quick_requested() -> bool {
    std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::new(Duration::from_millis(1), Duration::from_millis(20));
        let m = b.iter("spin", || {
            let mut s = 0u64;
            for i in 0..100 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(m.iters > 10);
        assert!(m.mean > 0.0);
        assert!(m.min <= m.mean);
        assert!(!m.human().is_empty());
    }

    #[test]
    fn reporter_accumulates() {
        let b = Bencher::new(Duration::from_millis(1), Duration::from_millis(5));
        let mut r = Reporter::new();
        r.bench(&b, "noop", || 1);
        assert_eq!(r.rows().len(), 1);
        r.finish("test");
    }

    #[test]
    fn tags_serialize_per_row() {
        let b = Bencher::new(Duration::from_millis(1), Duration::from_millis(5));
        let mut r = Reporter::new();
        r.bench_tagged(
            &b,
            "fused_scan_f16",
            &[
                ("storage", Json::Str("f16".into())),
                ("bytes_per_coord", Json::Num(2.0)),
            ],
            || 1,
        );
        let doc = r.to_json("unit", &[]);
        let parsed = crate::jsonlite::parse(&doc.dump()).unwrap();
        let rows = match parsed.get("results").unwrap() {
            Json::Arr(v) => v,
            other => panic!("results not an array: {other:?}"),
        };
        assert_eq!(rows[0].get("storage").unwrap().as_str(), Some("f16"));
        assert_eq!(rows[0].get("bytes_per_coord").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn json_round_trips() {
        let b = Bencher::new(Duration::from_millis(1), Duration::from_millis(5));
        let mut r = Reporter::new();
        r.bench(&b, "noop", || 1);
        let doc = r.to_json("unit", &[("allocs", Json::Num(3.0))]);
        let text = doc.dump();
        let parsed = crate::jsonlite::parse(&text).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("unit"));
        assert_eq!(parsed.get("allocs").unwrap().as_f64(), Some(3.0));
        let rows = match parsed.get("results").unwrap() {
            Json::Arr(v) => v,
            other => panic!("results not an array: {other:?}"),
        };
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").unwrap().as_str(), Some("noop"));
        assert!(rows[0].get("mean_s").unwrap().as_f64().unwrap() > 0.0);
    }
}
