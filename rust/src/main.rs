//! `bandit-mips` CLI: dataset generation, one-shot queries, a serving
//! loop, and quick experiment runs.
//!
//! ```text
//! bandit-mips gen      --kind gaussian --n 2000 --dim 4096 --out data.bin
//! bandit-mips query    --data data.bin --k 5 --epsilon 0.1 --delta 0.1
//! bandit-mips serve    --data data.bin --workers 2 --queries 500 [--artifacts artifacts/]
//! bandit-mips fig1     [--full]
//! bandit-mips table1   [--full]
//! ```

use bandit_mips::algos::{BoundedMeIndex, MipsIndex, MipsParams};
use bandit_mips::cli::{init_logger, Args};
use bandit_mips::coordinator::{Backend, Coordinator, CoordinatorConfig, QueryRequest};
use bandit_mips::data::{io as dio, synthetic, workload};
use bandit_mips::errors::bail;
use bandit_mips::exec::QueryContext;
use bandit_mips::experiments::{fig1, table1};
use bandit_mips::logkit;
use std::path::PathBuf;
use std::time::Duration;

const USAGE: &str = "\
bandit-mips <command> [flags]

commands:
  gen     --kind gaussian|uniform|netflix|yahoo --n <int> --dim <int> \
--seed <int> --out <path>
  query   --data <path> [--k 5] [--epsilon 0.1] [--delta 0.1] [--seed 0]
  serve   --data <path> [--workers 2] [--queries 500] [--rate 200] \
[--artifacts <dir>] [--tcp host:port [--max-conns 64]]
  fig1    [--full]
  table1  [--full]
";

fn main() -> bandit_mips::Result<()> {
    init_logger();
    let args = Args::parse_with(&["full"]);
    match args.command() {
        Some("gen") => cmd_gen(&args),
        Some("query") => cmd_query(&args),
        Some("serve") => cmd_serve(&args),
        Some("fig1") => cmd_fig1(&args),
        Some("table1") => cmd_table1(&args),
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn cmd_gen(args: &Args) -> bandit_mips::Result<()> {
    let kind = args.get_str("kind").unwrap_or("gaussian").to_string();
    let n = args.get("n", 2000usize);
    let dim = args.get("dim", 4096usize);
    let seed = args.get("seed", 42u64);
    let out: PathBuf = args.require::<PathBuf>("out")?;
    let ds = match kind.as_str() {
        "gaussian" => synthetic::gaussian_dataset(n, dim, seed),
        "uniform" => synthetic::uniform_dataset(n, dim, seed),
        "netflix" => bandit_mips::data::mf::netflix_like(n, dim, seed).dataset,
        "yahoo" => bandit_mips::data::mf::yahoo_like(n, dim, seed).dataset,
        other => bail!("unknown kind {other}"),
    };
    dio::save(&ds, &out)?;
    println!("wrote {} ({}x{}) to {}", ds.name, ds.n(), ds.dim(), out.display());
    Ok(())
}

fn cmd_query(args: &Args) -> bandit_mips::Result<()> {
    let ds = dio::load(args.require::<PathBuf>("data")?)?;
    let k = args.get("k", 5usize);
    let epsilon = args.get("epsilon", 0.1f64);
    let delta = args.get("delta", 0.1f64);
    let seed = args.get("seed", 0u64);
    let idx = BoundedMeIndex::new(ds.vectors.clone());
    let q = ds.sample_query(seed);
    let mut ctx = QueryContext::new();
    let t = std::time::Instant::now();
    let res = idx.query_with(&q, &MipsParams { k, epsilon, delta, seed }, &mut ctx);
    let dt = t.elapsed();
    println!(
        "top-{k} (ε={epsilon}, δ={delta}) in {dt:?}, {} flops ({:.1}% of naive):",
        res.flops,
        100.0 * res.flops as f64 / (ds.n() * ds.dim()) as f64
    );
    for (i, (&id, &s)) in res.indices.iter().zip(&res.scores).enumerate() {
        println!("  #{:<2} id={id:<8} score≈{s:.4}", i + 1);
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> bandit_mips::Result<()> {
    let ds = dio::load(args.require::<PathBuf>("data")?)?;
    let workers = args.get("workers", 2usize);
    let queries = args.get("queries", 500usize);
    let rate = args.get("rate", 200.0f64);
    let backend = match args.get_str("artifacts") {
        Some(dir) => Backend::Pjrt { artifact_dir: PathBuf::from(dir) },
        None => Backend::Native,
    };
    let cfg = CoordinatorConfig { workers, backend, ..Default::default() };

    // TCP mode: expose the wire server and block forever.
    if let Some(bind) = args.get_str("tcp") {
        let coord = std::sync::Arc::new(Coordinator::new(ds.vectors.clone(), cfg)?);
        let server = bandit_mips::coordinator::server::Server::start(
            coord,
            bind,
            args.get("max-conns", 64usize),
        )?;
        println!("serving {} ({}x{}) on {}", ds.name, ds.n(), ds.dim(), server.addr());
        println!(
            "protocol: negotiated per connection — newline-delimited JSON (default) \
             or PLW1 binary frames; ops: query | mutate | metrics | metrics_prom | trace | ping"
        );
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }

    let coord = Coordinator::new(ds.vectors.clone(), cfg)?;
    let trace = workload::poisson_trace(
        &ds,
        &workload::WorkloadConfig { count: queries, rate, ..Default::default() },
    );
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for q in &trace {
        let due = Duration::from_secs_f64(q.arrival);
        if let Some(sleep) = due.checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        match coord.submit(QueryRequest::bounded_me(q.vector.clone(), q.k, q.epsilon, q.delta))
        {
            Ok(rx) => pending.push(rx),
            Err(e) => logkit::warn!("dropped: {e}"),
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    let wall = t0.elapsed();
    let m = coord.metrics();
    println!(
        "served {} queries in {wall:?} ({:.0} qps)",
        m.queries,
        m.queries as f64 / wall.as_secs_f64()
    );
    println!(
        "batch size mean {:.2}; service p50/p99 = {:.3}/{:.3} ms; queue p99 = {:.3} ms; \
         total flops {:.2e}",
        m.mean_batch_size,
        m.service.0 * 1e3,
        m.service.2 * 1e3,
        m.queue_wait.2 * 1e3,
        m.flops as f64
    );
    coord.shutdown();
    Ok(())
}

fn cmd_fig1(args: &Args) -> bandit_mips::Result<()> {
    let cfg = if args.has("full") {
        fig1::Fig1Config { n_arms: 10_000, n_list: 100_000, trials: 20, ..Default::default() }
    } else {
        fig1::Fig1Config::default()
    };
    let pts = fig1::run(&cfg);
    println!("epsilon  (1-δ)-quantile subopt  holds");
    for (e, q, h) in fig1::per_epsilon(&pts) {
        println!("{e:<8.2} {q:<22.4} {h}");
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> bandit_mips::Result<()> {
    let ds = if args.has("full") {
        synthetic::gaussian_dataset(10_000, 8192, 7)
    } else {
        synthetic::gaussian_dataset(1000, 1024, 7)
    };
    let rows = table1::run(&ds, &table1::Table1Config::default());
    println!("{}", table1::format_rows(&rows));
    Ok(())
}
