//! Multi-Armed Bandit with Bounded Pulls (MAB-BP) — the paper's setting —
//! plus the BOUNDEDME algorithm and classic bandit baselines.
//!
//! In MAB-BP every arm `a_i` carries a *finite* reward list
//! `R_i = {R_i^(1), …, R_i^(N)}`; a pull samples **without replacement**
//! from the list, so after `N` pulls the empirical mean equals the true
//! mean `p_i` exactly. The goal is fixed-confidence top-K identification:
//! return a K-set that is ε-optimal with probability ≥ 1 − δ, minimizing
//! the number of pulls.
//!
//! MIPS reduces to MAB-BP by setting `R_i^(j) = v_i^(j) q^(j)`; a pull is
//! one floating-point multiply, so *sample complexity = flop count*.
//!
//! # The Storage axis
//!
//! The reduction also works over a *compressed* dataset tier:
//! [`QuantArms`] serves rewards `deq(c_i^(π(j))) · q^(π(j))` from
//! f16/bf16/int8 codes (see [`crate::data::quant`]), streaming 2–4×
//! fewer bytes per pull, with [`PullPanel`] staging compressed codes so
//! survivor compaction shrinks proportionally. The bandit's confidence
//! argument is untouched — [`QuantArms`] is a bounded-reward
//! environment whose guarantee is stated against the *dequantized*
//! means; the index layer (see [`crate::algos::BoundedMeIndex`])
//! bridges to the true f32 means by shrinking ε by the recorded
//! quantization bias and confirm-rescoring survivors on f32.
//!
//! | item | file |
//! |---|---|
//! | concentration bounds (`m(u)`, Hoeffding, Serfling) | [`bounds`] |
//! | [`RewardSource`] trait + matrix / quantized / adversarial / explicit arms, pull-order scratch + survivor-compacted [`PullPanel`] | [`arms`] |
//! | BOUNDEDME (Algorithm 1) + [`Compaction`] pull-layout policy | [`bounded_me`] |
//! | classic Median Elimination (Even-Dar et al. 2002) | [`median_elim`] |
//! | Successive Elimination | [`successive_elim`] |
//! | LUCB (Kalyanakrishnan et al. 2012) | [`lucb`] |
//! | lil'UCB (Jamieson et al. 2014) | [`lilucb`] |

pub mod arms;
pub mod bounded_me;
pub mod bounds;
pub mod fixed_budget;
pub mod lilucb;
pub mod lucb;
pub mod median_elim;
pub mod successive_elim;

pub use arms::{
    AdversarialArms, ExplicitArms, MatrixArms, PullOrder, PullPanel, PullScratch, QuantArms,
    RewardSource,
};
pub use bounded_me::{
    force_no_compact_requested, force_no_degrade_requested, AnytimeBudget, BanditScratch,
    BoundedMe, BoundedMeConfig, BoundedMeOutput, Compaction, Harvest, RoundTrace,
    FORCE_NO_COMPACT_ENV, FORCE_NO_DEGRADE_ENV,
};
pub use bounds::{hoeffding_sample_size, m_bounded, serfling_radius};

/// Outcome of a fixed-confidence bandit run.
#[derive(Clone, Debug)]
pub struct BanditResult {
    /// Selected arm indices, best-first by final empirical mean.
    pub arms: Vec<usize>,
    /// Final empirical mean of each selected arm (same order as `arms`).
    pub means: Vec<f64>,
    /// Total pulls across all arms (for MIPS: multiplications performed).
    pub total_pulls: u64,
    /// Number of elimination / sampling rounds executed.
    pub rounds: u32,
}

impl BanditResult {
    /// Pulls as a fraction of the exhaustive `n·N` budget.
    pub fn budget_fraction(&self, n_arms: usize, list_len: usize) -> f64 {
        self.total_pulls as f64 / (n_arms as f64 * list_len as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_fraction() {
        let r = BanditResult { arms: vec![0], means: vec![1.0], total_pulls: 50, rounds: 2 };
        assert!((r.budget_fraction(10, 10) - 0.5).abs() < 1e-12);
    }
}
