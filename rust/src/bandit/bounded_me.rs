//! BOUNDEDME (Algorithm 1 of the paper): median elimination for MAB-BP.
//!
//! Round `l` keeps a survivor set `S_l` (initially all `n` arms) and a
//! cumulative pull target `t_l` derived from the without-replacement
//! bound ([`crate::bandit::bounds::m_bounded`]) at the round's error/
//! confidence budget `ε_l = ε/4·(3/4)^{l-1}`, `δ_l = δ/2^l`. Each round:
//!
//! 1. pull every surviving arm up to `t_l` cumulative pulls,
//! 2. drop the `⌈(|S_l|−K)/2⌉` arms with the lowest empirical means,
//!
//! until `K` arms remain. Theorem 1: the returned set is ε-optimal with
//! probability ≥ 1 − δ. Corollary 2: per-arm pulls ≤ `N`, so BOUNDEDME
//! is never asymptotically worse than exhaustive search.
//!
//! # Survivor-compacting pull layout
//!
//! The pull phase has two physical layouts. The *scattered* layout
//! reads each survivor's coordinate window straight out of the
//! row-major dataset — fine while most arms survive (the scan still
//! streams), cache-hostile once elimination thins the set. The
//! *panel* layout ([`crate::bandit::PullPanel`]) kicks in per the
//! [`Compaction`] policy: the survivors' not-yet-pulled rewards are
//! compacted into a dense scratch panel (one batched gather, then
//! dense ping-pong copies each round), so every later pull batch is a
//! streaming scan of exactly the bytes it needs. Both layouts produce
//! **bit-identical** pull sums (tested), so elimination order, output
//! arms, and flop accounting never depend on the layout. The serving
//! default compacts once the survivor fraction drops to
//! [`Compaction::DEFAULT_FRACTION`]; [`FORCE_NO_COMPACT_ENV`] pins the
//! scattered layout process-wide (the CI leg that keeps it tested).

use super::arms::{PullPanel, RewardSource};
use super::bounds::m_bounded;
use super::BanditResult;
use std::sync::OnceLock;
use std::time::Instant;

/// Environment variable pinning the scattered pull layout (debug/CI
/// escape hatch, mirroring `RUST_PALLAS_FORCE_SCALAR`): any value other
/// than empty or `"0"` makes [`Compaction::default`] resolve to
/// [`Compaction::Never`]. Read once, at first use.
pub const FORCE_NO_COMPACT_ENV: &str = "RUST_PALLAS_FORCE_NO_COMPACT";

/// True when [`FORCE_NO_COMPACT_ENV`] requests the scattered layout.
pub fn force_no_compact_requested() -> bool {
    static FORCED: OnceLock<bool> = OnceLock::new();
    *FORCED.get_or_init(|| match std::env::var(FORCE_NO_COMPACT_ENV) {
        Ok(v) => !(v.is_empty() || v == "0"),
        Err(_) => false,
    })
}

/// Environment variable disabling every anytime/degradation path (the
/// CI leg proving the feature's off-path is bit-identical): any value
/// other than empty or `"0"` makes [`BoundedMe`] ignore any
/// [`AnytimeBudget`] and the coordinator skip budget arming and
/// [`crate::exec::DegradePolicy`] application. Read once, at first use.
pub const FORCE_NO_DEGRADE_ENV: &str = "RUST_PALLAS_FORCE_NO_DEGRADE";

/// True when [`FORCE_NO_DEGRADE_ENV`] pins degradation off.
pub fn force_no_degrade_requested() -> bool {
    static FORCED: OnceLock<bool> = OnceLock::new();
    *FORCED.get_or_init(|| match std::env::var(FORCE_NO_DEGRADE_ENV) {
        Ok(v) => !(v.is_empty() || v == "0"),
        Err(_) => false,
    })
}

/// An anytime stopping budget for one BOUNDEDME run. When armed, the
/// run checks it at the top of every elimination round *after the
/// first*: round 1 always completes (without one completed round there
/// is no checkpoint to harvest — the caller sheds instead), and an
/// exhausted budget returns the latest round's checkpointed top-k with
/// its achieved width ε̂ (see [`Harvest`]). Unarmed (the default), the
/// run is byte-for-byte the plain Algorithm 1: no clock reads, no
/// checkpoint writes.
#[derive(Clone, Copy, Debug, Default)]
pub struct AnytimeBudget {
    /// Soft wall-clock deadline: harvest at the first round boundary at
    /// or past this instant.
    pub deadline: Option<Instant>,
    /// FLOP budget (bandit pulls): harvest at the first round boundary
    /// where `total_pulls` has reached it.
    pub budget_flops: Option<u64>,
}

impl AnytimeBudget {
    /// The unarmed budget (plain Algorithm 1).
    pub const NONE: Self = Self { deadline: None, budget_flops: None };

    /// Whether any limit is set.
    pub fn armed(&self) -> bool {
        self.deadline.is_some() || self.budget_flops.is_some()
    }

    /// Whether the budget is spent at `total_pulls` pulls. Reads the
    /// clock only when a deadline is set.
    fn exhausted(&self, total_pulls: u64) -> bool {
        if let Some(b) = self.budget_flops {
            if total_pulls >= b {
                return true;
            }
        }
        if let Some(d) = self.deadline {
            if Instant::now() >= d {
                return true;
            }
        }
        false
    }
}

/// Outcome record of an anytime harvest, left in [`BanditScratch`] by a
/// budget-exhausted run (and `None` after any run that completed all
/// rounds). `epsilon_hat` is the *achieved* suboptimality width in the
/// same units as [`BoundedMeConfig::epsilon`] — always `< ε`: after
/// completing round `l` the elimination debt is `Σ_{j≤l} ε_j = ε −
/// 3ε_l`, and ranking survivors by means estimated at radius `ε_l/2`
/// adds `ε_l`, so ε̂ = ε − 2ε_l. The degradation is *coverage*, not
/// width: a harvested run answered from a partially-eliminated survivor
/// pool with δ budget already spent, at fewer pulls than the full run.
#[derive(Clone, Copy, Debug)]
pub struct Harvest {
    /// Achieved width ε̂ (units of [`BoundedMeConfig::epsilon`]).
    pub epsilon_hat: f64,
    /// Completed elimination rounds at harvest time (≥ 1).
    pub rounds: u32,
}

/// When BOUNDEDME compacts the survivors' remaining coordinates into
/// the scratch panel. Pure layout policy: every choice produces
/// bit-identical [`BoundedMe::run`] output (the `prop_invariants`
/// battery pins this), only the memory traffic differs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Compaction {
    /// Never compact — every pull uses the scattered dataset layout.
    Never,
    /// Compact once the survivor count drops to the given fraction of
    /// `n` (and every round after) — skip while the survivor set is
    /// dense enough that scattered reads still stream well.
    AtFraction(f64),
    /// Compact from the first round regardless of fraction (benches /
    /// tests; pays the full-set gather up front).
    Always,
}

impl Default for Compaction {
    /// The serving policy: [`Compaction::AtFraction`] of
    /// [`Compaction::DEFAULT_FRACTION`], unless [`FORCE_NO_COMPACT_ENV`]
    /// pins [`Compaction::Never`].
    fn default() -> Self {
        Self::policy(force_no_compact_requested())
    }
}

impl Compaction {
    /// Default survivor fraction at which compaction starts: below
    /// half, the panel's dense rows beat re-walking the scattered
    /// dataset every round (see the `pull_scatter` vs `pull_panel`
    /// rows of the `hotpath` bench).
    pub const DEFAULT_FRACTION: f64 = 0.5;

    /// Policy selection, exposed for tests: `force_no_compact` bypasses
    /// the heuristic exactly like the env var does (the env var is
    /// consulted by [`Compaction::default`], not here, so tests can
    /// exercise both branches in-process).
    pub fn policy(force_no_compact: bool) -> Self {
        if force_no_compact {
            Self::Never
        } else {
            Self::AtFraction(Self::DEFAULT_FRACTION)
        }
    }

    /// Panic on out-of-range fractions. Every builder accepting a
    /// policy funnels through this, so a misconfigured policy fails at
    /// construction time — never on the first serving request.
    pub fn validated(self) -> Self {
        if let Self::AtFraction(f) = self {
            assert!((0.0..=1.0).contains(&f), "compaction fraction must be in [0,1]");
        }
        self
    }

    /// Whether a run with this policy may compact at all.
    fn enabled(self) -> bool {
        !matches!(self, Self::Never)
    }

    /// Whether to *start* compacting at `survivors` of `n` arms (once
    /// started, a run keeps its panel compacted every round).
    fn fires(self, survivors: usize, n: usize) -> bool {
        match self {
            Self::Never => false,
            Self::Always => true,
            Self::AtFraction(f) => (survivors as f64) <= f * (n as f64),
        }
    }
}

/// Parameters of a BOUNDEDME run.
#[derive(Clone, Copy, Debug)]
pub struct BoundedMeConfig {
    /// Size of the returned arm set (`K ≥ 1`).
    pub k: usize,
    /// Suboptimality budget ε (on *mean* rewards, i.e. inner products
    /// scaled by `1/N`). Must be > 0; smaller ⇒ more pulls (capped at N).
    pub epsilon: f64,
    /// Failure probability δ ∈ (0, 1).
    pub delta: f64,
}

impl Default for BoundedMeConfig {
    fn default() -> Self {
        Self { k: 1, epsilon: 0.1, delta: 0.1 }
    }
}

/// Per-round trace entry (for the figure-1 harness and debugging).
#[derive(Clone, Copy, Debug)]
pub struct RoundTrace {
    /// Round index `l` (1-based).
    pub round: u32,
    /// Survivor count at the start of the round.
    pub survivors: usize,
    /// Cumulative pull target `t_l` for this round.
    pub t_l: usize,
    /// Round error budget `ε_l`.
    pub epsilon_l: f64,
    /// Round confidence budget `δ_l`.
    pub delta_l: f64,
    /// Width ε̂ an anytime harvest at the *end* of this round would
    /// report: elimination debt through this round plus the round's
    /// estimation radius, `Σ_{j≤l} ε_j + ε_l = ε − 2ε_l`.
    pub epsilon_hat: f64,
    /// Whether this round's pulls ran on the compacted survivor panel.
    pub compacted: bool,
    /// Wall time of the round (batched pull + elimination), in
    /// nanoseconds. Only measured when a trace is being collected —
    /// the traceless [`BoundedMe::run_in`] hot path never reads the
    /// clock — so it is `0` exactly when nobody is looking.
    pub nanos: u64,
}

/// Full output of [`BoundedMe::run`]: the [`BanditResult`] plus the
/// per-round schedule actually executed.
#[derive(Clone, Debug)]
pub struct BoundedMeOutput {
    /// Selected arms / means / pull accounting.
    pub result: BanditResult,
    /// One entry per elimination round.
    pub trace: Vec<RoundTrace>,
}

/// Reusable per-run survivor arena for [`BoundedMe::run_in`]: the
/// `O(n)` arm-state vector (plus the id/sum staging buffers of the
/// batched pull) are the only non-constant allocations of a BOUNDEDME
/// run, and a long-lived scratch (one per serving worker, inside
/// [`crate::exec::QueryContext`]) amortizes them to zero across
/// queries.
#[derive(Default)]
pub struct BanditScratch {
    survivors: Vec<ArmState>,
    /// Survivor ids staged for [`RewardSource::pull_range_batch`] (and,
    /// between pulls, panel slots staged for [`PullPanel::recompact`]).
    pull_ids: Vec<usize>,
    /// Per-survivor range sums returned by the batched pull.
    pull_sums: Vec<f64>,
    /// Survivor-compacted pull panel (see the module docs); sized by
    /// the first compacting queries, then reused allocation-free.
    panel: PullPanel,
    /// Anytime checkpoint: the best-so-far top-k `(mean, id)` set,
    /// rewritten at the end of every completed round **only while an
    /// [`AnytimeBudget`] is armed** — unarmed runs never touch it (the
    /// bit-identity contract costs nothing on the common path). Sized
    /// by the first armed query, then reused allocation-free.
    checkpoint: Vec<(f64, u32)>,
    /// Harvest record of the most recent run: `Some` iff the run was
    /// cut short by its budget (see [`Harvest`]).
    harvest: Option<Harvest>,
}

impl BanditScratch {
    /// Empty arena; the survivor buffer grows to `n` on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Panel buffer-growth (reallocation) events since construction —
    /// constant in steady state, like
    /// [`crate::bandit::PullScratch::grow_events`].
    pub fn panel_grow_events(&self) -> u64 {
        self.panel.grow_events()
    }

    /// Harvest record of the most recent run through this scratch:
    /// `Some` iff that run returned an anytime checkpoint instead of
    /// completing its elimination schedule.
    pub fn last_harvest(&self) -> Option<Harvest> {
        self.harvest
    }
}

/// The BOUNDEDME algorithm. Stateless; construct with a config and call
/// [`BoundedMe::run`] per query.
#[derive(Clone, Copy, Debug)]
pub struct BoundedMe {
    cfg: BoundedMeConfig,
    compaction: Compaction,
}

/// Internal survivor record.
#[derive(Clone, Copy, Debug)]
struct ArmState {
    id: u32,
    sum: f64,
    pulls: u32,
    /// Panel row holding this arm's remaining rewards, valid only while
    /// the run's panel is active (rewritten at every compaction).
    slot: u32,
}

impl ArmState {
    #[inline]
    fn mean(&self) -> f64 {
        if self.pulls == 0 {
            0.0
        } else {
            self.sum / self.pulls as f64
        }
    }
}

impl BoundedMe {
    /// New instance with the default [`Compaction`] policy; panics on
    /// invalid config.
    pub fn new(cfg: BoundedMeConfig) -> Self {
        assert!(cfg.k >= 1, "K must be ≥ 1");
        assert!(cfg.epsilon > 0.0, "ε must be > 0");
        assert!(cfg.delta > 0.0 && cfg.delta < 1.0, "δ must be in (0,1)");
        Self { cfg, compaction: Compaction::default() }
    }

    /// Override the survivor-compaction policy (layout only — results
    /// are bit-identical across policies).
    pub fn with_compaction(mut self, compaction: Compaction) -> Self {
        self.compaction = compaction.validated();
        self
    }

    /// Run Algorithm 1 against the environment, collecting the per-round
    /// trace (allocates a fresh survivor vector; the hot path uses
    /// [`BoundedMe::run_in`]).
    pub fn run<R: RewardSource>(&self, env: &R) -> BoundedMeOutput {
        let mut scratch = BanditScratch::new();
        let mut trace = Vec::new();
        let result =
            self.run_core(env, &mut scratch, Some(&mut trace), AnytimeBudget::NONE);
        BoundedMeOutput { result, trace }
    }

    /// Run Algorithm 1 reusing a caller-owned survivor arena and
    /// skipping trace collection. Results are bit-identical to
    /// [`BoundedMe::run`] (same pulls, same elimination order) — only
    /// the allocations differ.
    pub fn run_in<R: RewardSource>(
        &self,
        env: &R,
        scratch: &mut BanditScratch,
    ) -> BanditResult {
        self.run_core(env, scratch, None, AnytimeBudget::NONE)
    }

    /// [`BoundedMe::run_in`] under an [`AnytimeBudget`]: identical
    /// (bit-for-bit) while the budget is not exhausted; once it is, the
    /// run returns the latest round's checkpointed top-k and records a
    /// [`Harvest`] in the scratch ([`BanditScratch::last_harvest`]).
    /// [`FORCE_NO_DEGRADE_ENV`] disarms any budget process-wide.
    pub fn run_in_budget<R: RewardSource>(
        &self,
        env: &R,
        scratch: &mut BanditScratch,
        budget: AnytimeBudget,
    ) -> BanditResult {
        self.run_core(env, scratch, None, budget)
    }

    /// [`BoundedMe::run_in_traced`] under an [`AnytimeBudget`] (see
    /// [`BoundedMe::run_in_budget`]).
    pub fn run_in_traced_budget<R: RewardSource>(
        &self,
        env: &R,
        scratch: &mut BanditScratch,
        trace: Option<&mut Vec<RoundTrace>>,
        budget: AnytimeBudget,
    ) -> BanditResult {
        self.run_core(env, scratch, trace, budget)
    }

    /// [`BoundedMe::run_in`] with optional per-round trace collection
    /// into a caller-owned buffer (the flight recorder's entry point:
    /// scratch reuse *and* a round schedule, without the allocation of
    /// [`BoundedMe::run`]). `None` is exactly `run_in` — same pulls,
    /// same elimination order, no clock reads.
    pub fn run_in_traced<R: RewardSource>(
        &self,
        env: &R,
        scratch: &mut BanditScratch,
        trace: Option<&mut Vec<RoundTrace>>,
    ) -> BanditResult {
        self.run_core(env, scratch, trace, AnytimeBudget::NONE)
    }

    fn run_core<R: RewardSource>(
        &self,
        env: &R,
        scratch: &mut BanditScratch,
        mut trace: Option<&mut Vec<RoundTrace>>,
        budget: AnytimeBudget,
    ) -> BanditResult {
        let BanditScratch { survivors, pull_ids, pull_sums, panel, checkpoint, harvest } =
            scratch;
        *harvest = None;
        // The global kill switch: with the pin set, an armed budget is
        // indistinguishable from no budget at all (the CI `degrade` leg
        // proves the off-path bit-identical this way).
        let armed = budget.armed() && !force_no_degrade_requested();
        let n = env.n_arms();
        let n_list = env.list_len();
        let k = self.cfg.k;
        let range = env.range_width();

        survivors.clear();
        survivors
            .extend((0..n).map(|i| ArmState { id: i as u32, sum: 0.0, pulls: 0, slot: 0 }));
        let mut total_pulls: u64 = 0;

        let mut eps_l = self.cfg.epsilon / 4.0;
        let mut delta_l = self.cfg.delta / 2.0;
        // Elimination debt Σ_{j≤l} ε_j of the completed rounds.
        let mut eps_debt = 0.0f64;
        // ε̂ a harvest would report right now (valid once a round has
        // completed and written a checkpoint).
        let mut eps_hat = 0.0f64;
        let mut t_prev = 0usize;
        let mut round: u32 = 0;
        let compactable = self.compaction.enabled() && env.supports_compaction();
        let mut panel_on = false;

        while survivors.len() > k {
            // Anytime stop: only at a round boundary with ≥ 1 completed
            // round (round 1 always runs — before it there is nothing
            // to harvest, and the caller sheds instead).
            if armed && round >= 1 && budget.exhausted(total_pulls) {
                *harvest = Some(Harvest { epsilon_hat: eps_hat, rounds: round });
                let arms = checkpoint.iter().map(|&(_, id)| id as usize).collect();
                let means = checkpoint.iter().map(|&(m, _)| m).collect();
                return BanditResult { arms, means, total_pulls, rounds: round };
            }
            round += 1;
            let s = survivors.len();
            let gap = s - k; // |S_l| − K ≥ 1 here
            let drop = gap.div_ceil(2); // ⌈(|S_l|−K)/2⌉ arms to remove
            let keep_half = gap / 2; // ⌊(|S_l|−K)/2⌋

            // Per-arm failure budget from the Lemma-4 union bound:
            // δ' = δ_l(⌊gap/2⌋+1) / (2·gap), tested at radius ε_l/2.
            let delta_arm = delta_l * (keep_half as f64 + 1.0) / (2.0 * gap as f64);
            let t_l = if delta_arm >= 1.0 {
                // Degenerate (tiny instance, generous δ): one pull suffices
                // for the union bound to hold vacuously.
                t_prev.max(1)
            } else {
                m_bounded(eps_l / 2.0, delta_arm, n_list, range).max(t_prev)
            };

            // Survivor compaction: once the policy fires (and on every
            // round after — fractions only shrink), stage the survivors'
            // not-yet-pulled rewards [t_prev, N) as dense panel rows in
            // survivor order. First activation is one batched gather
            // from the environment; later rounds are dense ping-pong
            // copies that drop eliminated rows and the pulled prefix.
            // Panel sums are bit-identical to scattered ones, so this is
            // purely a memory-layout decision.
            if compactable && t_prev < n_list && (panel_on || self.compaction.fires(s, n)) {
                pull_ids.clear();
                if panel_on {
                    pull_ids.extend(survivors.iter().map(|a| a.slot as usize));
                    panel.recompact(pull_ids, t_prev);
                } else {
                    pull_ids.extend(survivors.iter().map(|a| a.id as usize));
                    env.compact_into(pull_ids, t_prev, panel);
                    panel_on = true;
                }
                for (i, a) in survivors.iter_mut().enumerate() {
                    a.slot = i as u32;
                }
            }

            if let Some(trace) = trace.as_mut() {
                trace.push(RoundTrace {
                    round,
                    survivors: s,
                    t_l,
                    epsilon_l: eps_l,
                    delta_l,
                    // Debt through this round (eps_debt + ε_l) plus the
                    // round's estimation radius allowance ε_l.
                    epsilon_hat: eps_debt + 2.0 * eps_l,
                    compacted: panel_on,
                    nanos: 0,
                });
            }
            let round_t0 = if trace.is_some() { Some(Instant::now()) } else { None };

            // Pull every survivor up to t_l cumulative pulls. Every
            // survivor sits at exactly t_prev pulls (each round tops all
            // of them up to the same t_l), so the whole round is one
            // batched pull over the uniform range [t_prev, t_l) — dense
            // environments run it as blocked SIMD kernels across the
            // survivor set, either over scattered dataset rows or over
            // the compacted panel (panel row i ↔ survivors[i], by the
            // compaction above).
            let delta_pulls = t_l - t_prev;
            if delta_pulls > 0 {
                pull_sums.clear();
                pull_sums.resize(s, 0.0);
                if panel_on {
                    debug_assert!(survivors
                        .iter()
                        .enumerate()
                        .all(|(i, a)| a.pulls as usize == t_prev && a.slot as usize == i));
                    env.pull_range_batch_panel(panel, t_prev, t_l, pull_sums);
                } else {
                    pull_ids.clear();
                    pull_ids.extend(survivors.iter().map(|a| {
                        debug_assert_eq!(a.pulls as usize, t_prev);
                        a.id as usize
                    }));
                    env.pull_range_batch(pull_ids, t_prev, t_l, pull_sums);
                }
                for (a, &sum) in survivors.iter_mut().zip(pull_sums.iter()) {
                    a.sum += sum;
                    a.pulls = t_l as u32;
                }
                total_pulls += (delta_pulls * s) as u64;
            }

            // Drop the `drop` arms with the lowest empirical means.
            // `select_nth_unstable` partitions in O(s).
            let pivot = drop - 1;
            survivors.select_nth_unstable_by(pivot, |a, b| {
                a.mean().partial_cmp(&b.mean()).unwrap_or(std::cmp::Ordering::Equal)
            });
            survivors.drain(..drop);

            if let (Some(trace), Some(t0)) = (trace.as_mut(), round_t0) {
                if let Some(entry) = trace.last_mut() {
                    entry.nanos = t0.elapsed().as_nanos() as u64;
                }
            }

            // Round complete: checkpoint the best-so-far top-k for a
            // possible harvest next round. Armed runs only — the plain
            // path never writes (or reads) the checkpoint. The partial
            // selection works on a copy, so survivor order (and thus
            // every later pull and elimination) is untouched.
            eps_debt += eps_l;
            if armed {
                eps_hat = eps_debt + eps_l;
                let by_best = |a: &(f64, u32), b: &(f64, u32)| {
                    b.0.partial_cmp(&a.0)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.1.cmp(&b.1))
                };
                checkpoint.clear();
                checkpoint.extend(survivors.iter().map(|a| (a.mean(), a.id)));
                if checkpoint.len() > k {
                    checkpoint.select_nth_unstable_by(k - 1, by_best);
                    checkpoint.truncate(k);
                }
                checkpoint.sort_by(by_best);
            }

            eps_l *= 0.75;
            delta_l *= 0.5;
            t_prev = t_l;
        }

        // Rank the final K arms by empirical mean, best first.
        survivors.sort_by(|a, b| {
            b.mean()
                .partial_cmp(&a.mean())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.id.cmp(&b.id))
        });
        let arms = survivors.iter().map(|a| a.id as usize).collect();
        let means = survivors.iter().map(|a| a.mean()).collect();

        BanditResult { arms, means, total_pulls, rounds: round }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::arms::{AdversarialArms, ExplicitArms, MatrixArms, PullOrder};
    use crate::linalg::{Matrix, Rng};

    fn constant_arms(means: &[f64], n_list: usize) -> ExplicitArms {
        ExplicitArms::new(
            means.iter().map(|&m| vec![m; n_list]).collect::<Vec<_>>(),
        )
        .with_range(0.0, 1.0)
    }

    #[test]
    fn finds_best_constant_arm() {
        let env = constant_arms(&[0.1, 0.9, 0.5, 0.2, 0.3], 100);
        let out = BoundedMe::new(BoundedMeConfig { k: 1, epsilon: 0.05, delta: 0.05 }).run(&env);
        assert_eq!(out.result.arms, vec![1]);
        assert!((out.result.means[0] - 0.9).abs() < 1e-9);
    }

    #[test]
    fn top_k_of_constant_arms() {
        let means: Vec<f64> = (0..50).map(|i| i as f64 / 50.0).collect();
        let env = constant_arms(&means, 64);
        let out = BoundedMe::new(BoundedMeConfig { k: 5, epsilon: 0.001, delta: 0.05 }).run(&env);
        let mut got = out.result.arms.clone();
        got.sort_unstable();
        assert_eq!(got, vec![45, 46, 47, 48, 49]);
    }

    #[test]
    fn pulls_bounded_by_n_per_arm() {
        // Corollary 2: pull count per arm ≤ N even for tiny ε.
        let n = 64;
        let n_list = 50;
        let mut rng = Rng::new(5);
        let lists: Vec<Vec<f64>> =
            (0..n).map(|_| (0..n_list).map(|_| rng.next_f64()).collect()).collect();
        let env = ExplicitArms::new(lists).with_range(0.0, 1.0);
        let out =
            BoundedMe::new(BoundedMeConfig { k: 1, epsilon: 1e-9, delta: 0.01 }).run(&env);
        for t in &out.trace {
            assert!(t.t_l <= n_list, "round {} wants t_l={} > N", t.round, t.t_l);
        }
        // With t_l = N from round 1, elimination is on exact means ⇒
        // correct best arm.
        let mut best = 0usize;
        for i in 1..n {
            if env.true_mean(i) > env.true_mean(best) {
                best = i;
            }
        }
        assert_eq!(out.result.arms[0], best);
        // Total pulls ≤ exhaustive n·N.
        assert!(out.result.total_pulls <= (n * n_list) as u64);
    }

    #[test]
    fn returns_exactly_k_arms() {
        let env = constant_arms(&[0.5; 33], 32);
        for k in [1usize, 2, 7, 32] {
            let out =
                BoundedMe::new(BoundedMeConfig { k, epsilon: 0.2, delta: 0.2 }).run(&env);
            assert_eq!(out.result.arms.len(), k, "k={k}");
            // No duplicates.
            let mut s = out.result.arms.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), k);
        }
    }

    #[test]
    fn n_leq_k_returns_all_without_pulls() {
        let env = constant_arms(&[0.3, 0.7], 16);
        let out = BoundedMe::new(BoundedMeConfig { k: 5, epsilon: 0.1, delta: 0.1 }).run(&env);
        assert_eq!(out.result.arms.len(), 2);
        assert_eq!(out.result.total_pulls, 0);
        assert_eq!(out.result.rounds, 0);
    }

    #[test]
    fn epsilon_schedule_sums_below_epsilon() {
        // Σ ε_l = ε/4 · Σ (3/4)^i ≤ ε; verify the executed schedule.
        let env = constant_arms(&[0.5; 1000], 64);
        let out = BoundedMe::new(BoundedMeConfig { k: 1, epsilon: 0.4, delta: 0.1 }).run(&env);
        let eps_sum: f64 = out.trace.iter().map(|t| t.epsilon_l).sum();
        let delta_sum: f64 = out.trace.iter().map(|t| t.delta_l).sum();
        assert!(eps_sum <= 0.4 + 1e-12, "Σε_l = {eps_sum}");
        assert!(delta_sum <= 0.1 + 1e-12, "Σδ_l = {delta_sum}");
    }

    #[test]
    fn survivor_counts_shrink_correctly() {
        let env = constant_arms(&[0.5; 100], 64);
        let out = BoundedMe::new(BoundedMeConfig { k: 3, epsilon: 0.3, delta: 0.2 }).run(&env);
        let mut prev = 100usize;
        for t in &out.trace {
            assert_eq!(t.survivors, prev);
            let drop = (t.survivors - 3).div_ceil(2);
            prev = t.survivors - drop;
        }
        assert_eq!(prev, 3);
    }

    #[test]
    fn adversarial_guarantee_holds_statistically() {
        // On the paper's adversarial environment, the (1−δ)-quantile of
        // suboptimality must stay below ε. 30 trials, ε=0.3, δ=0.2.
        let (eps, delta) = (0.3, 0.2);
        let mut subopts = Vec::new();
        for seed in 0..30u64 {
            let env = AdversarialArms::generate(200, 500, seed);
            let out = BoundedMe::new(BoundedMeConfig { k: 1, epsilon: eps, delta }).run(&env);
            let best = env.true_mean(env.best_arm());
            let got = env.true_mean(out.result.arms[0]);
            subopts.push(best - got);
        }
        subopts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q_idx = ((1.0 - delta) * subopts.len() as f64).ceil() as usize - 1;
        let q = subopts[q_idx];
        assert!(q < eps, "(1-δ)-quantile suboptimality {q} ≥ ε {eps}");
    }

    #[test]
    fn cumulative_pull_targets_monotone() {
        let env = constant_arms(&[0.5; 512], 1000);
        let out = BoundedMe::new(BoundedMeConfig { k: 1, epsilon: 0.05, delta: 0.05 }).run(&env);
        let mut prev = 0usize;
        for t in &out.trace {
            assert!(t.t_l >= prev);
            prev = t.t_l;
        }
    }

    #[test]
    fn run_in_matches_run_with_reused_scratch() {
        let mut rng = Rng::new(77);
        let lists: Vec<Vec<f64>> =
            (0..40).map(|_| (0..64).map(|_| rng.next_f64()).collect()).collect();
        let env = ExplicitArms::new(lists).with_range(0.0, 1.0);
        let algo = BoundedMe::new(BoundedMeConfig { k: 3, epsilon: 0.05, delta: 0.1 });
        let mut scratch = BanditScratch::new();
        for _ in 0..5 {
            let fresh = algo.run(&env).result;
            let reused = algo.run_in(&env, &mut scratch);
            assert_eq!(fresh.arms, reused.arms);
            assert_eq!(fresh.total_pulls, reused.total_pulls);
            assert_eq!(fresh.rounds, reused.rounds);
            for (a, b) in fresh.means.iter().zip(&reused.means) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn compaction_policy_never_changes_output() {
        // The layout invariant: every compaction policy yields the same
        // arms, the same means bit-for-bit, the same pull/round counts.
        let mut rng = Rng::new(0xC0DE);
        let m = Matrix::from_fn(60, 230, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(230);
        for order in [
            PullOrder::Sequential,
            PullOrder::Permuted,
            PullOrder::BlockShuffled(17),
        ] {
            let env = MatrixArms::new(&m, &q, 16.0, order, 3);
            let algo = BoundedMe::new(BoundedMeConfig { k: 4, epsilon: 0.08, delta: 0.1 });
            let base = algo.with_compaction(Compaction::Never).run(&env);
            for policy in [
                Compaction::Always,
                Compaction::AtFraction(0.05),
                Compaction::AtFraction(0.5),
                Compaction::AtFraction(1.0),
            ] {
                let got = algo.with_compaction(policy).run(&env);
                assert_eq!(got.result.arms, base.result.arms, "{order:?} {policy:?}");
                assert_eq!(
                    got.result.total_pulls, base.result.total_pulls,
                    "{order:?} {policy:?}"
                );
                assert_eq!(got.result.rounds, base.result.rounds, "{order:?} {policy:?}");
                for (a, b) in got.result.means.iter().zip(&base.result.means) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{order:?} {policy:?}");
                }
            }
        }
    }

    #[test]
    fn compaction_trace_flags_match_policy() {
        let mut rng = Rng::new(0x9A);
        let m = Matrix::from_fn(48, 180, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(180);
        let env = MatrixArms::new(&m, &q, 16.0, PullOrder::BlockShuffled(16), 1);
        let algo = BoundedMe::new(BoundedMeConfig { k: 2, epsilon: 0.1, delta: 0.1 });
        let never = algo.with_compaction(Compaction::Never).run(&env);
        assert!(never.trace.iter().all(|t| !t.compacted));
        let always = algo.with_compaction(Compaction::Always).run(&env);
        assert!(always.trace.iter().all(|t| t.compacted));
        // AtFraction: scattered while dense, compacted from the first
        // round at or below the threshold on.
        let half = algo.with_compaction(Compaction::AtFraction(0.5)).run(&env);
        let mut seen_compact = false;
        for t in &half.trace {
            if seen_compact {
                assert!(t.compacted, "panel must stay on once activated");
            } else if t.compacted {
                assert!(t.survivors as f64 <= 0.5 * 48.0, "compacted too early");
                seen_compact = true;
            }
        }
    }

    #[test]
    fn non_compacting_env_ignores_policy() {
        // ExplicitArms reports supports_compaction() == false, so even
        // Always must run (identically) on the scattered path.
        let mut rng = Rng::new(31);
        let lists: Vec<Vec<f64>> =
            (0..30).map(|_| (0..40).map(|_| rng.next_f64()).collect()).collect();
        let env = ExplicitArms::new(lists).with_range(0.0, 1.0);
        let algo = BoundedMe::new(BoundedMeConfig { k: 2, epsilon: 0.05, delta: 0.1 });
        let base = algo.with_compaction(Compaction::Never).run(&env);
        let forced = algo.with_compaction(Compaction::Always).run(&env);
        assert_eq!(base.result.arms, forced.result.arms);
        assert_eq!(base.result.total_pulls, forced.result.total_pulls);
        assert!(forced.trace.iter().all(|t| !t.compacted));
    }

    #[test]
    fn compaction_policy_selection() {
        assert_eq!(Compaction::policy(true), Compaction::Never);
        assert_eq!(
            Compaction::policy(false),
            Compaction::AtFraction(Compaction::DEFAULT_FRACTION)
        );
        // When the harness actually set the env var (the CI scatter
        // leg), the process-wide default must have honored it.
        if force_no_compact_requested() {
            assert_eq!(Compaction::default(), Compaction::Never);
        }
    }

    #[test]
    fn generous_budget_is_bit_identical_to_unbudgeted() {
        // Armed-but-never-exhausted budgets must not perturb the run:
        // same arms, same means bit-for-bit, same pull accounting, and
        // no harvest record.
        let mut rng = Rng::new(0xAB);
        let m = Matrix::from_fn(50, 200, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(200);
        let env = MatrixArms::new(&m, &q, 16.0, PullOrder::BlockShuffled(16), 7);
        let algo = BoundedMe::new(BoundedMeConfig { k: 3, epsilon: 0.05, delta: 0.1 });
        let mut s1 = BanditScratch::new();
        let mut s2 = BanditScratch::new();
        let plain = algo.run_in(&env, &mut s1);
        let generous = AnytimeBudget {
            deadline: Some(Instant::now() + std::time::Duration::from_secs(3600)),
            budget_flops: Some(u64::MAX),
        };
        let armed = algo.run_in_budget(&env, &mut s2, generous);
        assert_eq!(plain.arms, armed.arms);
        assert_eq!(plain.total_pulls, armed.total_pulls);
        assert_eq!(plain.rounds, armed.rounds);
        for (a, b) in plain.means.iter().zip(&armed.means) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(s2.last_harvest().is_none());
    }

    #[test]
    fn flop_budget_harvests_a_checkpoint() {
        let mut rng = Rng::new(0xCD);
        let m = Matrix::from_fn(80, 400, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(400);
        let env = MatrixArms::new(&m, &q, 16.0, PullOrder::BlockShuffled(16), 9);
        let algo = BoundedMe::new(BoundedMeConfig { k: 4, epsilon: 0.02, delta: 0.1 });
        let mut scratch = BanditScratch::new();
        let full = algo.run_in(&env, &mut scratch);
        assert!(full.rounds >= 2, "instance too easy to exercise a harvest");
        // A 1-flop budget exhausts right after round 1.
        let budget = AnytimeBudget { deadline: None, budget_flops: Some(1) };
        let cut = algo.run_in_budget(&env, &mut scratch, budget);
        if force_no_degrade_requested() {
            // Degrade pin live (CI `degrade` leg): the budget must have
            // been ignored entirely.
            assert_eq!(cut.arms, full.arms);
            assert!(scratch.last_harvest().is_none());
            return;
        }
        let h = scratch.last_harvest().expect("tiny budget must harvest");
        assert_eq!(h.rounds, 1);
        assert_eq!(cut.rounds, 1);
        assert_eq!(cut.arms.len(), 4);
        assert!(cut.total_pulls < full.total_pulls);
        // ε̂ = ε − 2ε_1 = ε − 2·(ε/4) = ε/2 after round 1.
        assert!((h.epsilon_hat - 0.01).abs() < 1e-12, "ε̂ = {}", h.epsilon_hat);
        assert!(h.epsilon_hat < 0.02);
        // Means come sorted best-first with the run's tie-break.
        for w in cut.means.windows(2) {
            assert!(w[0] >= w[1]);
        }
        // A later plain run through the same scratch clears the record.
        let again = algo.run_in(&env, &mut scratch);
        assert_eq!(again.arms, full.arms);
        assert!(scratch.last_harvest().is_none());
    }

    #[test]
    fn round_trace_epsilon_hat_schedule() {
        // ε̂ after round l is ε − 2ε_l: strictly increasing toward ε,
        // starting at ε/2.
        let env = constant_arms(&[0.5; 300], 256);
        let algo = BoundedMe::new(BoundedMeConfig { k: 1, epsilon: 0.2, delta: 0.1 });
        let out = algo.run(&env);
        let mut prev = 0.0;
        for t in &out.trace {
            assert!((t.epsilon_hat - (0.2 - 2.0 * t.epsilon_l)).abs() < 1e-12);
            assert!(t.epsilon_hat > prev && t.epsilon_hat < 0.2);
            prev = t.epsilon_hat;
        }
        assert!((out.trace[0].epsilon_hat - 0.1).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_compaction_fraction() {
        let algo = BoundedMe::new(BoundedMeConfig::default());
        let _ = algo.with_compaction(Compaction::AtFraction(1.5));
    }

    #[test]
    #[should_panic]
    fn rejects_zero_epsilon() {
        BoundedMe::new(BoundedMeConfig { k: 1, epsilon: 0.0, delta: 0.1 });
    }

    #[test]
    #[should_panic]
    fn rejects_bad_delta() {
        BoundedMe::new(BoundedMeConfig { k: 1, epsilon: 0.1, delta: 1.0 });
    }
}
