//! BOUNDEDME (Algorithm 1 of the paper): median elimination for MAB-BP.
//!
//! Round `l` keeps a survivor set `S_l` (initially all `n` arms) and a
//! cumulative pull target `t_l` derived from the without-replacement
//! bound ([`crate::bandit::bounds::m_bounded`]) at the round's error/
//! confidence budget `ε_l = ε/4·(3/4)^{l-1}`, `δ_l = δ/2^l`. Each round:
//!
//! 1. pull every surviving arm up to `t_l` cumulative pulls,
//! 2. drop the `⌈(|S_l|−K)/2⌉` arms with the lowest empirical means,
//!
//! until `K` arms remain. Theorem 1: the returned set is ε-optimal with
//! probability ≥ 1 − δ. Corollary 2: per-arm pulls ≤ `N`, so BOUNDEDME
//! is never asymptotically worse than exhaustive search.

use super::arms::RewardSource;
use super::bounds::m_bounded;
use super::BanditResult;

/// Parameters of a BOUNDEDME run.
#[derive(Clone, Copy, Debug)]
pub struct BoundedMeConfig {
    /// Size of the returned arm set (`K ≥ 1`).
    pub k: usize,
    /// Suboptimality budget ε (on *mean* rewards, i.e. inner products
    /// scaled by `1/N`). Must be > 0; smaller ⇒ more pulls (capped at N).
    pub epsilon: f64,
    /// Failure probability δ ∈ (0, 1).
    pub delta: f64,
}

impl Default for BoundedMeConfig {
    fn default() -> Self {
        Self { k: 1, epsilon: 0.1, delta: 0.1 }
    }
}

/// Per-round trace entry (for the figure-1 harness and debugging).
#[derive(Clone, Copy, Debug)]
pub struct RoundTrace {
    /// Round index `l` (1-based).
    pub round: u32,
    /// Survivor count at the start of the round.
    pub survivors: usize,
    /// Cumulative pull target `t_l` for this round.
    pub t_l: usize,
    /// Round error budget `ε_l`.
    pub epsilon_l: f64,
    /// Round confidence budget `δ_l`.
    pub delta_l: f64,
}

/// Full output of [`BoundedMe::run`]: the [`BanditResult`] plus the
/// per-round schedule actually executed.
#[derive(Clone, Debug)]
pub struct BoundedMeOutput {
    /// Selected arms / means / pull accounting.
    pub result: BanditResult,
    /// One entry per elimination round.
    pub trace: Vec<RoundTrace>,
}

/// Reusable per-run survivor arena for [`BoundedMe::run_in`]: the
/// `O(n)` arm-state vector (plus the id/sum staging buffers of the
/// batched pull) are the only non-constant allocations of a BOUNDEDME
/// run, and a long-lived scratch (one per serving worker, inside
/// [`crate::exec::QueryContext`]) amortizes them to zero across
/// queries.
#[derive(Default)]
pub struct BanditScratch {
    survivors: Vec<ArmState>,
    /// Survivor ids staged for [`RewardSource::pull_range_batch`].
    pull_ids: Vec<usize>,
    /// Per-survivor range sums returned by the batched pull.
    pull_sums: Vec<f64>,
}

impl BanditScratch {
    /// Empty arena; the survivor buffer grows to `n` on first use.
    pub fn new() -> Self {
        Self::default()
    }
}

/// The BOUNDEDME algorithm. Stateless; construct with a config and call
/// [`BoundedMe::run`] per query.
#[derive(Clone, Copy, Debug)]
pub struct BoundedMe {
    cfg: BoundedMeConfig,
}

/// Internal survivor record.
#[derive(Clone, Copy, Debug)]
struct ArmState {
    id: u32,
    sum: f64,
    pulls: u32,
}

impl ArmState {
    #[inline]
    fn mean(&self) -> f64 {
        if self.pulls == 0 {
            0.0
        } else {
            self.sum / self.pulls as f64
        }
    }
}

impl BoundedMe {
    /// New instance; panics on invalid config.
    pub fn new(cfg: BoundedMeConfig) -> Self {
        assert!(cfg.k >= 1, "K must be ≥ 1");
        assert!(cfg.epsilon > 0.0, "ε must be > 0");
        assert!(cfg.delta > 0.0 && cfg.delta < 1.0, "δ must be in (0,1)");
        Self { cfg }
    }

    /// Run Algorithm 1 against the environment, collecting the per-round
    /// trace (allocates a fresh survivor vector; the hot path uses
    /// [`BoundedMe::run_in`]).
    pub fn run<R: RewardSource>(&self, env: &R) -> BoundedMeOutput {
        let mut scratch = BanditScratch::new();
        let mut trace = Vec::new();
        let result = self.run_core(env, &mut scratch, Some(&mut trace));
        BoundedMeOutput { result, trace }
    }

    /// Run Algorithm 1 reusing a caller-owned survivor arena and
    /// skipping trace collection. Results are bit-identical to
    /// [`BoundedMe::run`] (same pulls, same elimination order) — only
    /// the allocations differ.
    pub fn run_in<R: RewardSource>(
        &self,
        env: &R,
        scratch: &mut BanditScratch,
    ) -> BanditResult {
        self.run_core(env, scratch, None)
    }

    fn run_core<R: RewardSource>(
        &self,
        env: &R,
        scratch: &mut BanditScratch,
        mut trace: Option<&mut Vec<RoundTrace>>,
    ) -> BanditResult {
        let BanditScratch { survivors, pull_ids, pull_sums } = scratch;
        let n = env.n_arms();
        let n_list = env.list_len();
        let k = self.cfg.k;
        let range = env.range_width();

        survivors.clear();
        survivors.extend((0..n).map(|i| ArmState { id: i as u32, sum: 0.0, pulls: 0 }));
        let mut total_pulls: u64 = 0;

        let mut eps_l = self.cfg.epsilon / 4.0;
        let mut delta_l = self.cfg.delta / 2.0;
        let mut t_prev = 0usize;
        let mut round: u32 = 0;

        while survivors.len() > k {
            round += 1;
            let s = survivors.len();
            let gap = s - k; // |S_l| − K ≥ 1 here
            let drop = gap.div_ceil(2); // ⌈(|S_l|−K)/2⌉ arms to remove
            let keep_half = gap / 2; // ⌊(|S_l|−K)/2⌋

            // Per-arm failure budget from the Lemma-4 union bound:
            // δ' = δ_l(⌊gap/2⌋+1) / (2·gap), tested at radius ε_l/2.
            let delta_arm = delta_l * (keep_half as f64 + 1.0) / (2.0 * gap as f64);
            let t_l = if delta_arm >= 1.0 {
                // Degenerate (tiny instance, generous δ): one pull suffices
                // for the union bound to hold vacuously.
                t_prev.max(1)
            } else {
                m_bounded(eps_l / 2.0, delta_arm, n_list, range).max(t_prev)
            };

            if let Some(trace) = trace.as_mut() {
                trace.push(RoundTrace {
                    round,
                    survivors: s,
                    t_l,
                    epsilon_l: eps_l,
                    delta_l,
                });
            }

            // Pull every survivor up to t_l cumulative pulls. Every
            // survivor sits at exactly t_prev pulls (each round tops all
            // of them up to the same t_l), so the whole round is one
            // batched pull over the uniform range [t_prev, t_l) — dense
            // environments run it as blocked SIMD kernels across the
            // survivor set.
            let delta_pulls = t_l - t_prev;
            if delta_pulls > 0 {
                pull_ids.clear();
                pull_ids.extend(survivors.iter().map(|a| {
                    debug_assert_eq!(a.pulls as usize, t_prev);
                    a.id as usize
                }));
                pull_sums.clear();
                pull_sums.resize(pull_ids.len(), 0.0);
                env.pull_range_batch(pull_ids, t_prev, t_l, pull_sums);
                for (a, &sum) in survivors.iter_mut().zip(pull_sums.iter()) {
                    a.sum += sum;
                    a.pulls = t_l as u32;
                }
                total_pulls += (delta_pulls * s) as u64;
            }

            // Drop the `drop` arms with the lowest empirical means.
            // `select_nth_unstable` partitions in O(s).
            let pivot = drop - 1;
            survivors.select_nth_unstable_by(pivot, |a, b| {
                a.mean().partial_cmp(&b.mean()).unwrap_or(std::cmp::Ordering::Equal)
            });
            survivors.drain(..drop);

            eps_l *= 0.75;
            delta_l *= 0.5;
            t_prev = t_l;
        }

        // Rank the final K arms by empirical mean, best first.
        survivors.sort_by(|a, b| {
            b.mean()
                .partial_cmp(&a.mean())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.id.cmp(&b.id))
        });
        let arms = survivors.iter().map(|a| a.id as usize).collect();
        let means = survivors.iter().map(|a| a.mean()).collect();

        BanditResult { arms, means, total_pulls, rounds: round }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::arms::{AdversarialArms, ExplicitArms};
    use crate::linalg::Rng;

    fn constant_arms(means: &[f64], n_list: usize) -> ExplicitArms {
        ExplicitArms::new(
            means.iter().map(|&m| vec![m; n_list]).collect::<Vec<_>>(),
        )
        .with_range(0.0, 1.0)
    }

    #[test]
    fn finds_best_constant_arm() {
        let env = constant_arms(&[0.1, 0.9, 0.5, 0.2, 0.3], 100);
        let out = BoundedMe::new(BoundedMeConfig { k: 1, epsilon: 0.05, delta: 0.05 }).run(&env);
        assert_eq!(out.result.arms, vec![1]);
        assert!((out.result.means[0] - 0.9).abs() < 1e-9);
    }

    #[test]
    fn top_k_of_constant_arms() {
        let means: Vec<f64> = (0..50).map(|i| i as f64 / 50.0).collect();
        let env = constant_arms(&means, 64);
        let out = BoundedMe::new(BoundedMeConfig { k: 5, epsilon: 0.001, delta: 0.05 }).run(&env);
        let mut got = out.result.arms.clone();
        got.sort_unstable();
        assert_eq!(got, vec![45, 46, 47, 48, 49]);
    }

    #[test]
    fn pulls_bounded_by_n_per_arm() {
        // Corollary 2: pull count per arm ≤ N even for tiny ε.
        let n = 64;
        let n_list = 50;
        let mut rng = Rng::new(5);
        let lists: Vec<Vec<f64>> =
            (0..n).map(|_| (0..n_list).map(|_| rng.next_f64()).collect()).collect();
        let env = ExplicitArms::new(lists).with_range(0.0, 1.0);
        let out =
            BoundedMe::new(BoundedMeConfig { k: 1, epsilon: 1e-9, delta: 0.01 }).run(&env);
        for t in &out.trace {
            assert!(t.t_l <= n_list, "round {} wants t_l={} > N", t.round, t.t_l);
        }
        // With t_l = N from round 1, elimination is on exact means ⇒
        // correct best arm.
        let mut best = 0usize;
        for i in 1..n {
            if env.true_mean(i) > env.true_mean(best) {
                best = i;
            }
        }
        assert_eq!(out.result.arms[0], best);
        // Total pulls ≤ exhaustive n·N.
        assert!(out.result.total_pulls <= (n * n_list) as u64);
    }

    #[test]
    fn returns_exactly_k_arms() {
        let env = constant_arms(&[0.5; 33], 32);
        for k in [1usize, 2, 7, 32] {
            let out =
                BoundedMe::new(BoundedMeConfig { k, epsilon: 0.2, delta: 0.2 }).run(&env);
            assert_eq!(out.result.arms.len(), k, "k={k}");
            // No duplicates.
            let mut s = out.result.arms.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), k);
        }
    }

    #[test]
    fn n_leq_k_returns_all_without_pulls() {
        let env = constant_arms(&[0.3, 0.7], 16);
        let out = BoundedMe::new(BoundedMeConfig { k: 5, epsilon: 0.1, delta: 0.1 }).run(&env);
        assert_eq!(out.result.arms.len(), 2);
        assert_eq!(out.result.total_pulls, 0);
        assert_eq!(out.result.rounds, 0);
    }

    #[test]
    fn epsilon_schedule_sums_below_epsilon() {
        // Σ ε_l = ε/4 · Σ (3/4)^i ≤ ε; verify the executed schedule.
        let env = constant_arms(&[0.5; 1000], 64);
        let out = BoundedMe::new(BoundedMeConfig { k: 1, epsilon: 0.4, delta: 0.1 }).run(&env);
        let eps_sum: f64 = out.trace.iter().map(|t| t.epsilon_l).sum();
        let delta_sum: f64 = out.trace.iter().map(|t| t.delta_l).sum();
        assert!(eps_sum <= 0.4 + 1e-12, "Σε_l = {eps_sum}");
        assert!(delta_sum <= 0.1 + 1e-12, "Σδ_l = {delta_sum}");
    }

    #[test]
    fn survivor_counts_shrink_correctly() {
        let env = constant_arms(&[0.5; 100], 64);
        let out = BoundedMe::new(BoundedMeConfig { k: 3, epsilon: 0.3, delta: 0.2 }).run(&env);
        let mut prev = 100usize;
        for t in &out.trace {
            assert_eq!(t.survivors, prev);
            let drop = (t.survivors - 3).div_ceil(2);
            prev = t.survivors - drop;
        }
        assert_eq!(prev, 3);
    }

    #[test]
    fn adversarial_guarantee_holds_statistically() {
        // On the paper's adversarial environment, the (1−δ)-quantile of
        // suboptimality must stay below ε. 30 trials, ε=0.3, δ=0.2.
        let (eps, delta) = (0.3, 0.2);
        let mut subopts = Vec::new();
        for seed in 0..30u64 {
            let env = AdversarialArms::generate(200, 500, seed);
            let out = BoundedMe::new(BoundedMeConfig { k: 1, epsilon: eps, delta }).run(&env);
            let best = env.true_mean(env.best_arm());
            let got = env.true_mean(out.result.arms[0]);
            subopts.push(best - got);
        }
        subopts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q_idx = ((1.0 - delta) * subopts.len() as f64).ceil() as usize - 1;
        let q = subopts[q_idx];
        assert!(q < eps, "(1-δ)-quantile suboptimality {q} ≥ ε {eps}");
    }

    #[test]
    fn cumulative_pull_targets_monotone() {
        let env = constant_arms(&[0.5; 512], 1000);
        let out = BoundedMe::new(BoundedMeConfig { k: 1, epsilon: 0.05, delta: 0.05 }).run(&env);
        let mut prev = 0usize;
        for t in &out.trace {
            assert!(t.t_l >= prev);
            prev = t.t_l;
        }
    }

    #[test]
    fn run_in_matches_run_with_reused_scratch() {
        let mut rng = Rng::new(77);
        let lists: Vec<Vec<f64>> =
            (0..40).map(|_| (0..64).map(|_| rng.next_f64()).collect()).collect();
        let env = ExplicitArms::new(lists).with_range(0.0, 1.0);
        let algo = BoundedMe::new(BoundedMeConfig { k: 3, epsilon: 0.05, delta: 0.1 });
        let mut scratch = BanditScratch::new();
        for _ in 0..5 {
            let fresh = algo.run(&env).result;
            let reused = algo.run_in(&env, &mut scratch);
            assert_eq!(fresh.arms, reused.arms);
            assert_eq!(fresh.total_pulls, reused.total_pulls);
            assert_eq!(fresh.rounds, reused.rounds);
            for (a, b) in fresh.means.iter().zip(&reused.means) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    #[should_panic]
    fn rejects_zero_epsilon() {
        BoundedMe::new(BoundedMeConfig { k: 1, epsilon: 0.0, delta: 0.1 });
    }

    #[test]
    #[should_panic]
    fn rejects_bad_delta() {
        BoundedMe::new(BoundedMeConfig { k: 1, epsilon: 0.1, delta: 1.0 });
    }
}
