//! Successive Elimination (Even-Dar, Mannor & Mansour 2006) with two
//! confidence-radius flavors:
//!
//! * [`RadiusKind::Hoeffding`] — the classic i.i.d. radius (baseline),
//! * [`RadiusKind::Serfling`] — the without-replacement radius, which
//!   hits exactly 0 at `t = N`; an alternative way (vs BOUNDEDME's
//!   round schedule) to exploit the MAB-BP structure, included for the
//!   `ablation_bounds` bench.
//!
//! Pulls happen in geometrically growing batches so the radius
//! recomputation cost is `O(log N)` per arm.

use super::arms::RewardSource;
use super::bounds::{hoeffding_radius, serfling_radius};
use super::BanditResult;
use crate::linalg::Rng;

/// Which concentration radius drives elimination.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RadiusKind {
    /// Classic i.i.d. Hoeffding radius; samples with replacement.
    Hoeffding,
    /// Hoeffding–Serfling without-replacement radius; samples without
    /// replacement (positional pulls), radius = 0 at `t = N`.
    Serfling,
}

/// Configuration for Successive Elimination.
#[derive(Clone, Copy, Debug)]
pub struct SuccessiveElimConfig {
    /// Returned set size.
    pub k: usize,
    /// Stop once every surviving pair is resolved to within ε.
    pub epsilon: f64,
    /// Failure probability δ.
    pub delta: f64,
    /// Radius flavor (see [`RadiusKind`]).
    pub radius: RadiusKind,
    /// First batch size (doubles every round).
    pub initial_batch: usize,
}

impl Default for SuccessiveElimConfig {
    fn default() -> Self {
        Self {
            k: 1,
            epsilon: 0.1,
            delta: 0.1,
            radius: RadiusKind::Serfling,
            initial_batch: 16,
        }
    }
}

struct SeArm {
    id: u32,
    sum: f64,
    pulls: usize,
}

impl SeArm {
    fn mean(&self) -> f64 {
        if self.pulls == 0 {
            0.0
        } else {
            self.sum / self.pulls as f64
        }
    }
}

/// Run Successive Elimination for ε-optimal top-K identification.
pub fn successive_elimination<R: RewardSource>(
    cfg: &SuccessiveElimConfig,
    env: &R,
    rng: &mut Rng,
) -> BanditResult {
    assert!(cfg.k >= 1 && cfg.epsilon > 0.0 && cfg.delta > 0.0 && cfg.delta < 1.0);
    let n = env.n_arms();
    let n_list = env.list_len();
    let range = env.range_width();
    // Union bound over arms and (geometric) rounds: log2(N)+1 rounds max
    // for Serfling; allow a generous 64 for Hoeffding.
    let delta_per_test = cfg.delta / (n as f64 * 64.0);

    let mut survivors: Vec<SeArm> =
        (0..n).map(|i| SeArm { id: i as u32, sum: 0.0, pulls: 0 }).collect();
    let mut total_pulls = 0u64;
    let mut rounds = 0u32;
    let mut batch = cfg.initial_batch.max(1);

    loop {
        rounds += 1;
        // Pull each survivor `batch` more times.
        for a in survivors.iter_mut() {
            match cfg.radius {
                RadiusKind::Serfling => {
                    let from = a.pulls;
                    let to = (from + batch).min(n_list);
                    if to > from {
                        a.sum += env.pull_range(a.id as usize, from, to);
                        total_pulls += (to - from) as u64;
                        a.pulls = to;
                    }
                }
                RadiusKind::Hoeffding => {
                    for _ in 0..batch {
                        a.sum += env.pull_iid(a.id as usize, rng);
                    }
                    a.pulls += batch;
                    total_pulls += batch as u64;
                }
            }
        }

        // Confidence radius (same pull count for all survivors).
        let t = survivors[0].pulls;
        let beta = match cfg.radius {
            RadiusKind::Hoeffding => hoeffding_radius(t, delta_per_test, range),
            RadiusKind::Serfling => serfling_radius(t, n_list, delta_per_test, range),
        };

        // K-th best empirical mean among survivors.
        let mut means: Vec<f64> = survivors.iter().map(|a| a.mean()).collect();
        means.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
        let kth = means[cfg.k - 1];

        // Eliminate arms confidently below the K-th best.
        if survivors.len() > cfg.k {
            survivors.retain(|a| a.mean() + beta >= kth - beta);
        }

        let done = survivors.len() <= cfg.k // resolved the set
            || 2.0 * beta <= cfg.epsilon // every comparison is ε-resolved
            || (cfg.radius == RadiusKind::Serfling && t >= n_list); // exact
        if done {
            break;
        }
        batch *= 2;
    }

    survivors.sort_by(|a, b| {
        b.mean()
            .partial_cmp(&a.mean())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
    survivors.truncate(cfg.k);
    BanditResult {
        arms: survivors.iter().map(|a| a.id as usize).collect(),
        means: survivors.iter().map(|a| a.mean()).collect(),
        total_pulls,
        rounds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::arms::ExplicitArms;

    fn staircase(n: usize, n_list: usize) -> ExplicitArms {
        ExplicitArms::new(
            (0..n).map(|i| vec![i as f64 / n as f64; n_list]).collect::<Vec<_>>(),
        )
        .with_range(0.0, 1.0)
    }

    #[test]
    fn serfling_finds_top_k_exactly() {
        let env = staircase(32, 128);
        let mut rng = Rng::new(1);
        let cfg = SuccessiveElimConfig { k: 3, epsilon: 0.001, ..Default::default() };
        let res = successive_elimination(&cfg, &env, &mut rng);
        let mut got = res.arms.clone();
        got.sort_unstable();
        assert_eq!(got, vec![29, 30, 31]);
        // Serfling caps pulls at n·N.
        assert!(res.total_pulls <= (32 * 128) as u64);
    }

    #[test]
    fn hoeffding_variant_runs_and_selects_reasonably() {
        let env = ExplicitArms::new(vec![vec![0.05; 64], vec![0.95; 64]]).with_range(0.0, 1.0);
        let mut rng = Rng::new(2);
        let cfg = SuccessiveElimConfig {
            k: 1,
            epsilon: 0.2,
            delta: 0.1,
            radius: RadiusKind::Hoeffding,
            initial_batch: 8,
        };
        let res = successive_elimination(&cfg, &env, &mut rng);
        assert_eq!(res.arms, vec![1]);
    }

    #[test]
    fn serfling_never_exceeds_n_per_arm() {
        let env = staircase(8, 40);
        let mut rng = Rng::new(3);
        let cfg = SuccessiveElimConfig {
            k: 1,
            epsilon: 1e-12,
            delta: 0.01,
            radius: RadiusKind::Serfling,
            initial_batch: 16,
        };
        let res = successive_elimination(&cfg, &env, &mut rng);
        assert!(res.total_pulls <= (8 * 40) as u64);
        assert_eq!(res.arms, vec![7]);
    }
}
