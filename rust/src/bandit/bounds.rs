//! Concentration bounds for MAB-BP.
//!
//! The paper's key statistical tool is Lemma 1: for a finite list of size
//! `N` with values in `[a, b]`, sampling `m` values **without
//! replacement** gives `P[mean_est − µ ≤ ε] ≥ 1 − δ` whenever
//!
//! ```text
//! m ≥ m(u) = min{ (u+1)/(1+u/N),  (u + u/N)/(1+u/N) },
//! u   = log(1/δ)/2 · (b−a)²/ε².
//! ```
//!
//! `m(u)` is derived from the Bardenet–Maillard (2015) Corollary 2.5
//! Hoeffding–Serfling bound and satisfies `m(u) ≤ N` for every `u ≥ 0` —
//! the formal statement of "never pull an arm more than N times".
//!
//! For the ablation benches we also expose the classical Hoeffding sample
//! size (infinite population, with replacement) and the Serfling
//! confidence *radius* used by the Successive-Elimination baseline.

/// The paper's `u` quantity: `log(1/δ)/2 · (b−a)²/ε²`.
#[inline]
pub fn u_of(epsilon: f64, delta: f64, range: f64) -> f64 {
    debug_assert!(epsilon > 0.0 && delta > 0.0 && delta < 1.0 && range > 0.0);
    (1.0 / delta).ln() / 2.0 * (range / epsilon).powi(2)
}

/// `m(u)` for list size `N` (Eq. 6 of the paper): the number of
/// without-replacement samples sufficient for an (ε, δ) one-sided mean
/// estimate. Always in `(0, N]` for `u > 0`.
#[inline]
pub fn m_of_u(u: f64, n_list: usize) -> f64 {
    let n = n_list as f64;
    let denom = 1.0 + u / n;
    let m1 = (u + 1.0) / denom;
    let m2 = (u + u / n) / denom;
    m1.min(m2)
}

/// Sample size (integer pulls, ≥ 1, ≤ N) for an (ε, δ) estimate of the
/// mean of a finite list of `n_list` values spanning `range = b − a`.
///
/// This is the paper's Lemma 1 rounded up for implementation: we take
/// `⌈m(u)⌉` clamped to `[1, N]`. (Rounding up only tightens the
/// guarantee.)
pub fn m_bounded(epsilon: f64, delta: f64, n_list: usize, range: f64) -> usize {
    if epsilon <= 0.0 {
        return n_list; // ε → 0 ⇒ exact computation
    }
    let u = u_of(epsilon, delta, range);
    let m = m_of_u(u, n_list).ceil();
    (m.max(1.0) as usize).min(n_list)
}

/// Same, but parameterized directly by `u` (used by BOUNDEDME's round
/// schedule where `u` already folds in the per-round union bound).
pub fn m_bounded_from_u(u: f64, n_list: usize) -> usize {
    if !u.is_finite() || u < 0.0 {
        return n_list;
    }
    let m = m_of_u(u, n_list).ceil();
    (m.max(1.0) as usize).min(n_list)
}

/// Classical Hoeffding sample size for an i.i.d. (with-replacement)
/// (ε, δ) mean estimate of a `[a,b]`-bounded variable:
/// `m = (b−a)²/(2ε²) · log(1/δ)`. Unbounded in `N` — this is what the
/// classic Median-Elimination baseline uses.
pub fn hoeffding_sample_size(epsilon: f64, delta: f64, range: f64) -> usize {
    if epsilon <= 0.0 {
        return usize::MAX;
    }
    let m = (range / epsilon).powi(2) / 2.0 * (1.0 / delta).ln();
    m.ceil().max(1.0) as usize
}

/// Hoeffding confidence radius after `m` i.i.d. samples at confidence δ:
/// `ε = (b−a) √(log(1/δ) / (2m))`.
pub fn hoeffding_radius(m: usize, delta: f64, range: f64) -> f64 {
    if m == 0 {
        return f64::INFINITY;
    }
    range * ((1.0 / delta).ln() / (2.0 * m as f64)).sqrt()
}

/// The `ρ_m` factor of Bardenet–Maillard Cor. 2.5 (Eq. 3 of the paper):
/// `ρ_m = min{ 1 − (m−1)/N, (1 − m/N)(1 + 1/m) }`.
#[inline]
pub fn rho_m(m: usize, n_list: usize) -> f64 {
    if m == 0 {
        return 1.0;
    }
    let m_f = m as f64;
    let n = n_list as f64;
    let r1 = 1.0 - (m_f - 1.0) / n;
    let r2 = (1.0 - m_f / n) * (1.0 + 1.0 / m_f);
    r1.min(r2).max(0.0)
}

/// Without-replacement (Hoeffding–Serfling) confidence radius after `m`
/// of `N` pulls at confidence δ: `ε = (b−a) √(ρ_m log(1/δ) / (2m))`.
///
/// Shrinks to exactly 0 at `m = N` — the "bounded pulls" advantage in
/// radius form; used by the Successive-Elimination-BP baseline.
pub fn serfling_radius(m: usize, n_list: usize, delta: f64, range: f64) -> f64 {
    if m == 0 {
        return f64::INFINITY;
    }
    if m >= n_list {
        return 0.0;
    }
    range * (rho_m(m, n_list) * (1.0 / delta).ln() / (2.0 * m as f64)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    const N: usize = 100_000;

    #[test]
    fn m_never_exceeds_n() {
        for &eps in &[1e-6, 1e-3, 0.01, 0.1, 0.5, 0.99] {
            for &delta in &[1e-6, 0.01, 0.3, 0.9] {
                let m = m_bounded(eps, delta, N, 1.0);
                assert!(m >= 1 && m <= N, "eps={eps} delta={delta} m={m}");
            }
        }
    }

    #[test]
    fn m_monotone_decreasing_in_epsilon() {
        let mut prev = usize::MAX;
        for &eps in &[0.001, 0.01, 0.05, 0.1, 0.3, 0.6] {
            let m = m_bounded(eps, 0.05, N, 1.0);
            assert!(m <= prev, "eps={eps}: m={m} > prev={prev}");
            prev = m;
        }
    }

    #[test]
    fn m_monotone_decreasing_in_delta() {
        let mut prev = usize::MAX;
        for &delta in &[0.001, 0.01, 0.1, 0.3, 0.6] {
            let m = m_bounded(0.05, delta, N, 1.0);
            assert!(m <= prev);
            prev = m;
        }
    }

    #[test]
    fn m_approaches_n_as_eps_to_zero() {
        assert_eq!(m_bounded(1e-9, 0.1, N, 1.0), N);
        assert_eq!(m_bounded(0.0, 0.1, N, 1.0), N);
    }

    #[test]
    fn m_far_below_hoeffding_when_eps_small() {
        // The whole point of the paper: for small ε the without-replacement
        // sample size caps at N while Hoeffding explodes.
        let eps = 0.001;
        let delta = 0.05;
        let h = hoeffding_sample_size(eps, delta, 1.0);
        let m = m_bounded(eps, delta, N, 1.0);
        assert!(h > 10 * m, "hoeffding {h} vs bounded {m}");
    }

    #[test]
    fn m_matches_hoeffding_when_n_large() {
        // As N → ∞, m(u) → u + 1 ≈ Hoeffding's u.
        let eps = 0.2;
        let delta = 0.1;
        let h = hoeffding_sample_size(eps, delta, 1.0);
        let m = m_bounded(eps, delta, 1_000_000_000, 1.0);
        let ratio = m as f64 / h as f64;
        assert!((ratio - 1.0).abs() < 0.1, "ratio={ratio}");
    }

    #[test]
    fn rho_bounds() {
        for &m in &[1usize, 2, 100, 50_000, 99_999] {
            let r = rho_m(m, N);
            assert!((0.0..=1.0 + 1e-12).contains(&r), "m={m} rho={r}");
        }
        assert!(rho_m(0, N) == 1.0);
    }

    #[test]
    fn serfling_radius_zero_at_full_list() {
        assert_eq!(serfling_radius(N, N, 0.1, 1.0), 0.0);
        assert!(serfling_radius(N / 2, N, 0.1, 1.0) > 0.0);
        assert_eq!(serfling_radius(0, N, 0.1, 1.0), f64::INFINITY);
    }

    #[test]
    fn serfling_tighter_than_hoeffding() {
        for &m in &[100usize, 1000, 50_000, 90_000] {
            let s = serfling_radius(m, N, 0.05, 1.0);
            let h = hoeffding_radius(m, 0.05, 1.0);
            assert!(s <= h + 1e-12, "m={m}: serfling {s} > hoeffding {h}");
        }
    }

    #[test]
    fn range_scales_quadratically_in_m() {
        let m1 = m_bounded(0.1, 0.1, usize::MAX >> 16, 1.0);
        let m2 = m_bounded(0.1, 0.1, usize::MAX >> 16, 2.0);
        let ratio = m2 as f64 / m1 as f64;
        assert!((ratio - 4.0).abs() < 0.1, "ratio={ratio}");
    }

    #[test]
    fn hoeffding_radius_matches_sample_size_inverse() {
        let eps = 0.07;
        let delta = 0.03;
        let m = hoeffding_sample_size(eps, delta, 1.0);
        let r = hoeffding_radius(m, delta, 1.0);
        assert!(r <= eps && r > eps * 0.9, "r={r} eps={eps}");
    }
}
