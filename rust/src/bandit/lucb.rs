//! LUCB (Kalyanakrishnan, Tewari, Auer & Stone 2012): fixed-confidence
//! top-K identification by sampling the two *critical* arms each round —
//! the weakest of the empirical top-K (by LCB) and the strongest of the
//! rest (by UCB) — until their intervals separate to within ε.
//!
//! Classic i.i.d. baseline for the `ablation_bandits` bench; pulls are
//! with replacement and the radius uses the standard `k₁ n t⁴/δ`
//! exploration rate. Pull batching keeps wall-clock reasonable.

use super::arms::RewardSource;
use super::BanditResult;
use crate::linalg::Rng;

/// LUCB configuration.
#[derive(Clone, Copy, Debug)]
pub struct LucbConfig {
    /// Returned set size.
    pub k: usize,
    /// Stop when `UCB(best challenger) − LCB(weakest incumbent) < ε`.
    pub epsilon: f64,
    /// Failure probability δ.
    pub delta: f64,
    /// Pulls per selected arm per round (batching; 1 = faithful LUCB1).
    pub batch: usize,
    /// Safety cap on total pulls (`u64::MAX` = none).
    pub max_total_pulls: u64,
}

impl Default for LucbConfig {
    fn default() -> Self {
        Self { k: 1, epsilon: 0.1, delta: 0.1, batch: 16, max_total_pulls: u64::MAX }
    }
}

struct LucbArm {
    sum: f64,
    pulls: u64,
}

impl LucbArm {
    fn mean(&self) -> f64 {
        if self.pulls == 0 {
            0.0
        } else {
            self.sum / self.pulls as f64
        }
    }
}

/// LUCB exploration radius: `β(t, δ) = (b−a)·√(ln(k₁ n t⁴ / δ) / (2t))`
/// with `k₁ = 5/4`.
fn beta(t: u64, n: usize, delta: f64, range: f64) -> f64 {
    if t == 0 {
        return f64::INFINITY;
    }
    let t_f = t as f64;
    let arg = (1.25 * n as f64 * t_f.powi(4) / delta).ln().max(0.0);
    range * (arg / (2.0 * t_f)).sqrt()
}

/// Run LUCB for ε-optimal top-K identification.
pub fn lucb<R: RewardSource>(cfg: &LucbConfig, env: &R, rng: &mut Rng) -> BanditResult {
    assert!(cfg.k >= 1 && cfg.epsilon > 0.0 && cfg.delta > 0.0 && cfg.delta < 1.0);
    let n = env.n_arms();
    assert!(n > cfg.k, "LUCB needs n > K");
    let range = env.range_width();
    let mut arms: Vec<LucbArm> = (0..n).map(|_| LucbArm { sum: 0.0, pulls: 0 }).collect();
    let mut total_pulls = 0u64;
    let mut rounds = 0u32;

    let pull = |arm: &mut LucbArm, id: usize, count: usize, rng: &mut Rng| {
        for _ in 0..count {
            arm.sum += env.pull_iid(id, rng);
        }
        arm.pulls += count as u64;
    };

    // Initialize: one batch per arm.
    for (i, a) in arms.iter_mut().enumerate() {
        pull(a, i, cfg.batch, rng);
        total_pulls += cfg.batch as u64;
    }

    loop {
        rounds += 1;
        // Partition indices into empirical top-K and the rest.
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| {
            arms[b]
                .mean()
                .partial_cmp(&arms[a].mean())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let (top, rest) = idx.split_at(cfg.k);

        // h = weakest incumbent by LCB; l = strongest challenger by UCB.
        let h = *top
            .iter()
            .min_by(|&&a, &&b| {
                let la = arms[a].mean() - beta(arms[a].pulls, n, cfg.delta, range);
                let lb = arms[b].mean() - beta(arms[b].pulls, n, cfg.delta, range);
                la.partial_cmp(&lb).unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap();
        let l = *rest
            .iter()
            .max_by(|&&a, &&b| {
                let ua = arms[a].mean() + beta(arms[a].pulls, n, cfg.delta, range);
                let ub = arms[b].mean() + beta(arms[b].pulls, n, cfg.delta, range);
                ua.partial_cmp(&ub).unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap();

        let gap = (arms[l].mean() + beta(arms[l].pulls, n, cfg.delta, range))
            - (arms[h].mean() - beta(arms[h].pulls, n, cfg.delta, range));
        if gap < cfg.epsilon || total_pulls >= cfg.max_total_pulls {
            let means = top.iter().map(|&i| arms[i].mean()).collect();
            return BanditResult { arms: top.to_vec(), means, total_pulls, rounds };
        }

        pull(&mut arms[h], h, cfg.batch, rng);
        pull(&mut arms[l], l, cfg.batch, rng);
        total_pulls += 2 * cfg.batch as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::arms::ExplicitArms;

    #[test]
    fn separated_arms_resolved() {
        let env = ExplicitArms::new(vec![vec![0.1; 32], vec![0.9; 32], vec![0.2; 32]])
            .with_range(0.0, 1.0);
        let mut rng = Rng::new(1);
        let res = lucb(&LucbConfig { k: 1, epsilon: 0.3, ..Default::default() }, &env, &mut rng);
        assert_eq!(res.arms, vec![1]);
    }

    #[test]
    fn top_2_of_staircase() {
        let env = ExplicitArms::new(
            (0..6).map(|i| vec![i as f64 * 0.15; 32]).collect::<Vec<_>>(),
        )
        .with_range(0.0, 1.0);
        let mut rng = Rng::new(2);
        let res =
            lucb(&LucbConfig { k: 2, epsilon: 0.1, ..Default::default() }, &env, &mut rng);
        let mut got = res.arms.clone();
        got.sort_unstable();
        assert_eq!(got, vec![4, 5]);
    }

    #[test]
    fn pull_cap_respected() {
        // Two identical arms can never separate; the cap must fire.
        let env = ExplicitArms::new(vec![vec![0.5; 16], vec![0.5; 16]]).with_range(0.0, 1.0);
        let mut rng = Rng::new(3);
        let cfg = LucbConfig {
            k: 1,
            epsilon: 1e-9,
            delta: 0.05,
            batch: 8,
            max_total_pulls: 10_000,
        };
        let res = lucb(&cfg, &env, &mut rng);
        assert!(res.total_pulls >= 10_000);
        assert!(res.total_pulls < 10_000 + 32);
    }

    #[test]
    fn beta_decreasing_in_t() {
        let b1 = beta(10, 100, 0.1, 1.0);
        let b2 = beta(1000, 100, 0.1, 1.0);
        assert!(b2 < b1);
        assert_eq!(beta(0, 100, 0.1, 1.0), f64::INFINITY);
    }
}
