//! Reward sources: the MAB-BP environments.
//!
//! [`RewardSource`] abstracts "pull arm *i*": the algorithms only see
//! positional pulls into each arm's (already randomly ordered) reward
//! list, which is exactly sampling without replacement. Three
//! environments are provided:
//!
//! * [`MatrixArms`] — the MIPS reduction: arm `i` = vector `v_i`, reward
//!   `j` = `v_i^(π(j)) · q^(π(j))` under a per-query coordinate
//!   permutation `π`.
//! * [`AdversarialArms`] — the paper's Figure-1 worst case: Bernoulli
//!   reward lists served 1s-first so empirical means stay maximally
//!   uninformative.
//! * [`ExplicitArms`] — arbitrary lists, for unit tests.
//!
//! The permutation, run table, and gathered-query buffer behind
//! [`MatrixArms`] live in a [`PullScratch`] arena so the serving hot
//! path re-uses them across queries (and shares one permutation across
//! a whole batch) instead of allocating per query — see
//! [`crate::exec::QueryContext`]. [`MatrixArms::new`] still owns a
//! private scratch for one-shot callers.
//!
//! # Survivor compaction
//!
//! Every BOUNDEDME elimination round pulls the *same* positional range
//! from every surviving arm, so once elimination has thinned the
//! survivor set the scattered pull walks most of the dataset's cache
//! lines to touch a few floats per line. [`PullPanel`] is the fix: a
//! dense scratch panel holding the survivors' *not-yet-pulled* rewards
//! in pull order, one contiguous row per survivor, built by one batched
//! gather ([`RewardSource::compact_into`]) and re-compacted by dense
//! copies as elimination proceeds ([`PullPanel::recompact`], ping-pong
//! buffers — no re-gathering). Panel pulls
//! ([`RewardSource::pull_range_batch_panel`]) replicate the scattered
//! paths' per-coordinate f64 accumulation order **bit for bit**, so
//! elimination decisions never depend on the layout; the panel scan
//! also issues [`crate::linalg::simd::prefetch_read`] one row ahead.
//! The panel lives in [`crate::bandit::BanditScratch`], so steady-state
//! serving stays allocation-free.

use crate::data::quant::{QuantMatrix, Storage};
use crate::linalg::simd::wide;
use crate::linalg::{dot, gather_idx, partial_dot_rows_chunked, simd, Matrix, Rng};

/// How [`MatrixArms`] orders coordinates for without-replacement pulls.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PullOrder {
    /// Full uniform random permutation of the `N` coordinates — the
    /// paper's sampling model. Pulls are gathers (cache-unfriendly).
    Permuted,
    /// Coordinates shuffled in contiguous blocks of the given width:
    /// near-uniform statistically, but every pull batch reads dense
    /// runs. This is the TPU-friendly schedule from DESIGN.md
    /// §Hardware-Adaptation and the default on the serving path.
    BlockShuffled(usize),
    /// No shuffling (identity order). Only sound when coordinates are
    /// a-priori exchangeable (e.g. i.i.d. synthetic data); exposed for
    /// the ablation benches.
    Sequential,
}

/// A MAB-BP environment: `n` arms, each with a finite reward list of
/// length `N`, pulled without replacement in a fixed (random) order.
pub trait RewardSource {
    /// Number of arms `n`.
    fn n_arms(&self) -> usize;
    /// Reward-list length `N` (max useful pulls per arm).
    fn list_len(&self) -> usize;
    /// Known bounds `[a, b]` on individual rewards.
    fn reward_range(&self) -> (f64, f64);
    /// Sum of rewards at positions `[from, to)` of arm `arm`'s pull
    /// sequence. Positions beyond `list_len()` are a contract violation.
    fn pull_range(&self, arm: usize, from: usize, to: usize) -> f64;
    /// Batched [`RewardSource::pull_range`]:
    /// `out[i] = pull_range(arms[i], from, to)`.
    ///
    /// One BOUNDEDME elimination round pulls the *same* positional range
    /// from every surviving arm, so the whole round is one call here.
    /// Environments with dense storage override this to run the blocked
    /// [`crate::linalg::partial_dot_rows`] kernel across the survivor
    /// set per coordinate run (see [`MatrixArms`]); the default loops.
    /// Overrides must produce bit-identical sums to the per-arm method
    /// — the elimination order of a run must not depend on whether the
    /// caller batched.
    fn pull_range_batch(&self, arms: &[usize], from: usize, to: usize, out: &mut [f64]) {
        debug_assert_eq!(arms.len(), out.len());
        for (&arm, o) in arms.iter().zip(out.iter_mut()) {
            *o = self.pull_range(arm, from, to);
        }
    }
    /// True when the environment can stage remaining rewards into a
    /// [`PullPanel`] (see [`RewardSource::compact_into`]). Dense f32
    /// matrix environments say yes; list environments (whose rewards
    /// are f64 and already contiguous) keep the default `false`, and
    /// BOUNDEDME then never compacts them.
    fn supports_compaction(&self) -> bool {
        false
    }

    /// Stage the not-yet-pulled rewards of `arms` — pull positions
    /// `[from, list_len())` — into `panel`, one dense row per arm in the
    /// given order (one batched gather). Only called when
    /// [`RewardSource::supports_compaction`] is true.
    fn compact_into(&self, arms: &[usize], from: usize, panel: &mut PullPanel) {
        let _ = (arms, from, panel);
        unreachable!("compact_into called on a non-compacting environment");
    }

    /// Batched pull served from a compacted panel:
    /// `out[i]` = sum of panel row `i`'s rewards at pull positions
    /// `[from, to)`. MUST be bit-identical to
    /// [`RewardSource::pull_range_batch`] over the arms the panel was
    /// compacted from (same per-coordinate f64 accumulation order) —
    /// the elimination outcome of a run must not depend on the pull
    /// layout. Only called when [`RewardSource::supports_compaction`]
    /// is true and a panel covering `[from, to)` exists.
    fn pull_range_batch_panel(&self, panel: &PullPanel, from: usize, to: usize, out: &mut [f64]) {
        let _ = (panel, from, to, out);
        unreachable!("pull_range_batch_panel called on a non-compacting environment");
    }

    /// One i.i.d. *with-replacement* sample from arm `arm`'s list (what a
    /// classic bandit algorithm would observe).
    fn pull_iid(&self, arm: usize, rng: &mut Rng) -> f64;
    /// Exact true mean `p_i` (oracle — equals the mean after `N` pulls).
    fn true_mean(&self, arm: usize) -> f64;

    /// Width of the reward range `b − a`.
    fn range_width(&self) -> f64 {
        let (a, b) = self.reward_range();
        (b - a).max(f64::MIN_POSITIVE)
    }
}

/// Which pull-order representation a [`PullScratch`] currently holds.
///
/// Block-shuffled orders are stored as contiguous *runs* so pull batches
/// stay dense (vectorizable dots) instead of scalar gathers — the
/// difference is ~8× wall-clock on the pull hot path (see the `hotpath`
/// bench).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum OrderKind {
    /// Identity (sequential) order.
    Identity,
    /// Arbitrary permutation: positional gathers via `perm`.
    Gather,
    /// Blockwise-contiguous permutation: run `r` covers pull positions
    /// `[offsets[r], offsets[r+1])` and coordinates starting at
    /// `starts[r]`.
    Runs,
}

/// Reusable pull-order arena: the coordinate permutation / run table
/// and the gathered-query buffer of [`MatrixArms`], hoisted out so a
/// long-lived context can amortize them across queries.
///
/// [`PullScratch::prepare`] is keyed on `(order, dim, seed)` and is a
/// no-op when called again with the same key — that is how every query
/// of a dynamic batch shares one block-shuffled permutation while only
/// re-gathering its own query values.
pub struct PullScratch {
    kind: OrderKind,
    /// `Gather`: the permutation. `Runs`: scratch for block ids.
    perm: Vec<u32>,
    /// `Runs`: first coordinate of each run.
    starts: Vec<u32>,
    /// `Runs`: prefix pull positions; `offsets.len() == starts.len() + 1`.
    offsets: Vec<u32>,
    /// Query values pre-gathered in pull order: `qp[j] = q[π(j)]`.
    qp: Vec<f32>,
    /// Cache key of the prepared order.
    key: Option<(PullOrder, usize, u64)>,
    /// Dimension of the prepared order.
    dim: usize,
    /// Buffer-growth events (capacity reallocations) since construction —
    /// the observable the `hotpath` bench uses to prove steady-state
    /// zero-allocation behavior.
    grows: u64,
}

impl Default for PullScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl PullScratch {
    /// Empty arena; buffers grow on first use, then stay.
    pub fn new() -> Self {
        Self {
            kind: OrderKind::Identity,
            perm: Vec::new(),
            starts: Vec::new(),
            offsets: Vec::new(),
            qp: Vec::new(),
            key: None,
            dim: 0,
            grows: 0,
        }
    }

    /// Build the pull order for `(order, dim, seed)`, reusing the cached
    /// one when the key matches (shared permutation across a batch).
    pub fn prepare(&mut self, order: PullOrder, dim: usize, seed: u64) {
        if self.key == Some((order, dim, seed)) {
            return;
        }
        let caps = (self.perm.capacity(), self.starts.capacity(), self.offsets.capacity());
        self.dim = dim;
        let mut rng = Rng::new(seed);
        match order {
            PullOrder::Sequential => {
                self.kind = OrderKind::Identity;
            }
            PullOrder::Permuted => {
                self.kind = OrderKind::Gather;
                self.perm.clear();
                self.perm.extend(0..dim as u32);
                rng.shuffle(&mut self.perm);
            }
            PullOrder::BlockShuffled(w) => {
                self.kind = OrderKind::Runs;
                let w = w.max(1).min(dim.max(1));
                let nblocks = dim.div_ceil(w);
                self.perm.clear();
                self.perm.extend(0..nblocks as u32);
                rng.shuffle(&mut self.perm);
                self.starts.clear();
                self.offsets.clear();
                let mut pos = 0u32;
                for &blk in &self.perm {
                    let lo = blk as usize * w;
                    let hi = (lo + w).min(dim);
                    self.starts.push(lo as u32);
                    self.offsets.push(pos);
                    pos += (hi - lo) as u32;
                }
                self.offsets.push(pos);
            }
        }
        if (self.perm.capacity(), self.starts.capacity(), self.offsets.capacity()) != caps {
            self.grows += 1;
        }
        self.key = Some((order, dim, seed));
    }

    /// Gather `q` into the pull-order buffer (`qp[j] = q[π(j)]`). Must be
    /// called after [`PullScratch::prepare`], once per query.
    pub fn gather(&mut self, q: &[f32]) {
        assert_eq!(q.len(), self.dim, "gather: query dim mismatch");
        let cap = self.qp.capacity();
        self.qp.clear();
        match self.kind {
            OrderKind::Identity => self.qp.extend_from_slice(q),
            OrderKind::Gather => {
                // Through the dispatched gather kernel (hardware
                // vgatherdps on x86): pure data movement, identical
                // values on every ISA.
                self.qp.resize(self.dim, 0.0);
                gather_idx(q, &self.perm, &mut self.qp);
            }
            OrderKind::Runs => {
                for r in 0..self.starts.len() {
                    let lo = self.starts[r] as usize;
                    let len = (self.offsets[r + 1] - self.offsets[r]) as usize;
                    self.qp.extend_from_slice(&q[lo..lo + len]);
                }
            }
        }
        if self.qp.capacity() != cap {
            self.grows += 1;
        }
    }

    /// Dimension of the prepared order (0 before first prepare).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Buffer-growth (reallocation) events since construction. A
    /// steady-state hot loop holds this constant.
    pub fn grow_events(&self) -> u64 {
        self.grows
    }

    /// Coordinate index served at pull position `pos`.
    #[inline]
    fn coord_at(&self, pos: usize) -> usize {
        match self.kind {
            OrderKind::Identity => pos,
            OrderKind::Gather => self.perm[pos] as usize,
            OrderKind::Runs => {
                // Last run whose offset ≤ pos.
                let r = self.offsets.partition_point(|&o| o as usize <= pos) - 1;
                self.starts[r] as usize + (pos - self.offsets[r] as usize)
            }
        }
    }

    /// The dense segments of the run table covering pull positions
    /// `[from, to)`: yields `(pos, stop, coord)` meaning pull positions
    /// `[pos, stop)` read coordinates `[coord, coord + (stop − pos))`.
    /// This is the ONE run-walk every Runs consumer iterates — per-run
    /// pulls, batched pulls, panel compaction, and panel scans — so the
    /// partition-point seeding and ragged-tail bookkeeping live in
    /// exactly one place (a divergence here would silently break the
    /// panel/scatter bit-identity contract). Only meaningful for the
    /// `Runs` order kind.
    fn run_segments(&self, from: usize, to: usize) -> RunSegments<'_> {
        debug_assert_eq!(self.kind, OrderKind::Runs);
        let r = if from < to {
            // Last run whose first pull position is ≤ from.
            self.offsets.partition_point(|&o| (o as usize) <= from) - 1
        } else {
            0 // never dereferenced: the iterator is immediately empty
        };
        RunSegments { starts: &self.starts, offsets: &self.offsets, pos: from, to, r }
    }
}

/// Iterator behind [`PullScratch::run_segments`].
struct RunSegments<'a> {
    starts: &'a [u32],
    offsets: &'a [u32],
    pos: usize,
    to: usize,
    r: usize,
}

impl Iterator for RunSegments<'_> {
    /// `(pos, stop, coord)`: pull positions `[pos, stop)` ↔ coordinates
    /// `[coord, coord + (stop − pos))`.
    type Item = (usize, usize, usize);

    fn next(&mut self) -> Option<(usize, usize, usize)> {
        if self.pos >= self.to {
            return None;
        }
        let run_end = self.offsets[self.r + 1] as usize;
        let stop = run_end.min(self.to);
        let coord = self.starts[self.r] as usize + (self.pos - self.offsets[self.r] as usize);
        let seg = (self.pos, stop, coord);
        self.pos = stop;
        self.r += 1;
        Some(seg)
    }
}

/// Dense survivor panel for compacted BOUNDEDME pulls: row `i` holds
/// one arm's rewards at pull positions `[base, base + stride)` (its
/// whole not-yet-pulled suffix), contiguously and in pull order.
///
/// The panel is double-buffered: [`PullPanel::recompact`] copies the
/// surviving rows' remaining windows into the spare buffer and swaps,
/// so re-compaction after an elimination round is pure dense `memcpy`
/// traffic (no gathers, no aliasing hazards) and both buffers reach a
/// steady-state capacity after the first few queries — the panel is
/// part of [`crate::bandit::BanditScratch`]'s zero-allocation contract,
/// observable via [`PullPanel::grow_events`].
///
/// # Memory high-water
///
/// Like every scratch arena in the crate, the buffers never shrink:
/// each long-lived context retains the largest panel it ever staged —
/// bounded by `survivor-fraction × rows × remaining-coords × 4 B`,
/// ×2 for the ping-pong pair (on a 2000×4096 f32 dataset at the
/// default 0.5 threshold, up to ~2×16 MB per context). Deployments
/// that would rather re-walk the scattered dataset than hold a
/// resident panel set [`crate::bandit::Compaction::Never`] (or the
/// `RUST_PALLAS_FORCE_NO_COMPACT` hatch), or lower the fraction to
/// shrink the bound; NUMA-aware panels are tracked in the ROADMAP.
///
/// # Compressed panels (the Storage axis)
///
/// When the environment samples a compressed tier
/// (see [`QuantArms`] / [`crate::data::quant`]), the panel stages the
/// *compressed codes* instead of f32 — [`PullPanel::begin_u16`] /
/// [`PullPanel::begin_i8`] fill typed ping-pong pairs (f16/bf16 share
/// the `u16` pair; int8 additionally carries one f32 scale per row,
/// permuted alongside rows on re-compaction) — so the resident
/// high-water shrinks by the same 2–4× as the streaming reads. One
/// element-kind tag selects which pair [`PullPanel::recompact`]
/// operates on; the f32 pair and its code path are byte-identical to
/// the pre-Storage behavior.
pub struct PullPanel {
    /// Active panel, `rows × stride`, row-major (f32 tier).
    cur: Vec<f32>,
    /// Spare buffer for the next ping-pong re-compaction (f32 tier).
    alt: Vec<f32>,
    /// Active/spare pair for f16/bf16 codes.
    cur16: Vec<u16>,
    alt16: Vec<u16>,
    /// Active/spare pair for int8 codes.
    cur8: Vec<i8>,
    alt8: Vec<i8>,
    /// Per-row int8 scales (aligned with `cur8` rows) + spare.
    scales: Vec<f32>,
    alt_scales: Vec<f32>,
    /// Which buffer pair the current staging lives in.
    elem: PanelElem,
    rows: usize,
    stride: usize,
    /// Pull position of panel column 0.
    base: usize,
    /// Buffer-growth (capacity reallocation) events since construction.
    grows: u64,
}

/// Element kind of the currently staged panel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum PanelElem {
    F32,
    U16,
    I8,
}

impl Default for PullPanel {
    fn default() -> Self {
        Self::new()
    }
}

impl PullPanel {
    /// Empty panel; buffers grow to steady state on first use.
    pub fn new() -> Self {
        Self {
            cur: Vec::new(),
            alt: Vec::new(),
            cur16: Vec::new(),
            alt16: Vec::new(),
            cur8: Vec::new(),
            alt8: Vec::new(),
            scales: Vec::new(),
            alt_scales: Vec::new(),
            elem: PanelElem::F32,
            rows: 0,
            stride: 0,
            base: 0,
            grows: 0,
        }
    }

    /// Capacities of every buffer, for growth-event accounting.
    #[inline]
    fn caps(&self) -> [usize; 8] {
        [
            self.cur.capacity(),
            self.alt.capacity(),
            self.cur16.capacity(),
            self.alt16.capacity(),
            self.cur8.capacity(),
            self.alt8.capacity(),
            self.scales.capacity(),
            self.alt_scales.capacity(),
        ]
    }

    /// Reset to `rows × stride` at pull base `base` and expose the
    /// staging buffer for an environment's gather
    /// ([`RewardSource::compact_into`] fills row `i` with arm `i`'s
    /// rewards at pull positions `base..base + stride`).
    pub fn begin(&mut self, rows: usize, stride: usize, base: usize) -> &mut [f32] {
        let caps = self.caps();
        self.elem = PanelElem::F32;
        self.cur.clear();
        self.cur.resize(rows * stride, 0.0);
        self.rows = rows;
        self.stride = stride;
        self.base = base;
        if self.caps() != caps {
            self.grows += 1;
        }
        &mut self.cur
    }

    /// [`PullPanel::begin`] for the f16/bf16 tiers: the staging buffer
    /// holds raw 16-bit codes (the format is whatever the filling
    /// environment stores — the panel only moves bytes).
    pub fn begin_u16(&mut self, rows: usize, stride: usize, base: usize) -> &mut [u16] {
        let caps = self.caps();
        self.elem = PanelElem::U16;
        self.cur16.clear();
        self.cur16.resize(rows * stride, 0);
        self.rows = rows;
        self.stride = stride;
        self.base = base;
        if self.caps() != caps {
            self.grows += 1;
        }
        &mut self.cur16
    }

    /// [`PullPanel::begin`] for the int8 tier: returns the code staging
    /// buffer plus the per-row scale buffer (`rows` entries) the filler
    /// must populate; scales ride along through every re-compaction.
    pub fn begin_i8(&mut self, rows: usize, stride: usize, base: usize) -> (&mut [i8], &mut [f32]) {
        let caps = self.caps();
        self.elem = PanelElem::I8;
        self.cur8.clear();
        self.cur8.resize(rows * stride, 0);
        self.scales.clear();
        self.scales.resize(rows, 0.0);
        self.rows = rows;
        self.stride = stride;
        self.base = base;
        if self.caps() != caps {
            self.grows += 1;
        }
        (&mut self.cur8, &mut self.scales)
    }

    /// Ping-pong copy of one buffer pair (shared by every element
    /// kind — the f32 tier's copies are exactly the pre-Storage ones).
    fn recompact_pair<T: Copy + Default>(
        cur: &mut Vec<T>,
        alt: &mut Vec<T>,
        slots: &[usize],
        rows: usize,
        stride: usize,
        delta: usize,
        ns: usize,
    ) {
        alt.clear();
        alt.resize(slots.len() * ns, T::default());
        for (i, &slot) in slots.iter().enumerate() {
            debug_assert!(slot < rows);
            let src = slot * stride + delta;
            alt[i * ns..(i + 1) * ns].copy_from_slice(&cur[src..src + ns]);
        }
        std::mem::swap(cur, alt);
    }

    /// Drop eliminated rows and the freshly pulled prefix: new row `i`
    /// is old row `slots[i]`'s window from pull position `new_base` on.
    /// Dense copies into the spare buffer, then swap — on whichever
    /// buffer pair the current tier staged (int8 scales are permuted
    /// alongside their rows).
    pub fn recompact(&mut self, slots: &[usize], new_base: usize) {
        debug_assert!(new_base >= self.base);
        let delta = new_base - self.base;
        debug_assert!(delta <= self.stride);
        let ns = self.stride - delta;
        let caps = self.caps();
        match self.elem {
            PanelElem::F32 => {
                Self::recompact_pair(
                    &mut self.cur, &mut self.alt, slots, self.rows, self.stride, delta, ns,
                );
            }
            PanelElem::U16 => {
                Self::recompact_pair(
                    &mut self.cur16, &mut self.alt16, slots, self.rows, self.stride, delta, ns,
                );
            }
            PanelElem::I8 => {
                Self::recompact_pair(
                    &mut self.cur8, &mut self.alt8, slots, self.rows, self.stride, delta, ns,
                );
                let scales = &self.scales;
                self.alt_scales.clear();
                self.alt_scales.extend(slots.iter().map(|&s| scales[s]));
                std::mem::swap(&mut self.scales, &mut self.alt_scales);
            }
        }
        self.rows = slots.len();
        self.stride = ns;
        self.base = new_base;
        if self.caps() != caps {
            self.grows += 1;
        }
    }

    /// Number of survivor rows currently staged.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Pull position of panel column 0 (pulls must start at or after
    /// this).
    pub fn base(&self) -> usize {
        self.base
    }

    /// Pull positions covered per row: `[base, base + stride)`.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Row `i`'s rewards at pull positions `[from, to)`.
    #[inline]
    pub fn window(&self, i: usize, from: usize, to: usize) -> &[f32] {
        debug_assert!(self.base <= from && from <= to && to <= self.base + self.stride);
        let o = i * self.stride;
        &self.cur[o + (from - self.base)..o + (to - self.base)]
    }

    /// Pointer to row `i`'s reward at pull position `from` (prefetch
    /// target for the next row while scanning the current one).
    #[inline]
    fn window_ptr(&self, i: usize, from: usize) -> *const f32 {
        // In-bounds by the same contract as `window`; raw pointer only
        // because prefetch wants an address, not a borrow.
        unsafe { self.cur.as_ptr().add(i * self.stride + (from - self.base)) }
    }

    /// Row `i`'s f16/bf16 codes at pull positions `[from, to)`.
    #[inline]
    pub fn window16(&self, i: usize, from: usize, to: usize) -> &[u16] {
        debug_assert_eq!(self.elem, PanelElem::U16);
        debug_assert!(self.base <= from && from <= to && to <= self.base + self.stride);
        let o = i * self.stride;
        &self.cur16[o + (from - self.base)..o + (to - self.base)]
    }

    /// Row `i`'s int8 codes at pull positions `[from, to)`.
    #[inline]
    pub fn window8(&self, i: usize, from: usize, to: usize) -> &[i8] {
        debug_assert_eq!(self.elem, PanelElem::I8);
        debug_assert!(self.base <= from && from <= to && to <= self.base + self.stride);
        let o = i * self.stride;
        &self.cur8[o + (from - self.base)..o + (to - self.base)]
    }

    /// Row `i`'s int8 scale (`value ≈ code · scale`).
    #[inline]
    pub fn row_scale(&self, i: usize) -> f32 {
        debug_assert_eq!(self.elem, PanelElem::I8);
        self.scales[i]
    }

    /// Prefetch address for compressed rows (the cast is only for the
    /// address-taking prefetch hint, never dereferenced as f32).
    #[inline]
    fn window_ptr16(&self, i: usize, from: usize) -> *const f32 {
        unsafe { self.cur16.as_ptr().add(i * self.stride + (from - self.base)) as *const f32 }
    }

    /// Prefetch address for int8 rows (cast as above).
    #[inline]
    fn window_ptr8(&self, i: usize, from: usize) -> *const f32 {
        unsafe { self.cur8.as_ptr().add(i * self.stride + (from - self.base)) as *const f32 }
    }

    /// Buffer-growth (reallocation) events since construction. A
    /// steady-state hot loop holds this constant.
    pub fn grow_events(&self) -> u64 {
        self.grows
    }
}

/// MIPS as MAB-BP: arm `i` ↔ data vector `v_i`, reward `j` ↔ one
/// coordinate product with the query.
pub struct MatrixArms<'a> {
    data: &'a Matrix,
    scratch: ScratchRef<'a>,
    range: (f64, f64),
}

/// Owned (one-shot convenience) or borrowed (hot path) scratch.
enum ScratchRef<'a> {
    Owned(Box<PullScratch>),
    Borrowed(&'a PullScratch),
}

impl<'a> MatrixArms<'a> {
    /// Build the MIPS environment for one query, allocating a private
    /// scratch (one-shot convenience; the serving path uses
    /// [`MatrixArms::with_scratch`]).
    ///
    /// `reward_bound` is a valid almost-sure bound `b` on every reward:
    /// `|v_i^(j) q^(j)| ≤ b` for all `i, j`. Callers derive it from
    /// query-independent dataset metadata — coarsest: `max|v|·max|q|`;
    /// tighter (what [`crate::algos::BoundedMeIndex`] uses):
    /// `max_j colmax[j]·|q_j|` with `colmax[j] = max_i |v_i^(j)|`.
    pub fn new(
        data: &'a Matrix,
        query: &[f32],
        reward_bound: f32,
        order: PullOrder,
        seed: u64,
    ) -> Self {
        assert_eq!(query.len(), data.cols(), "query dim mismatch");
        let mut scratch = Box::new(PullScratch::new());
        scratch.prepare(order, data.cols(), seed);
        scratch.gather(query);
        Self {
            data,
            scratch: ScratchRef::Owned(scratch),
            range: Self::range_from_bound(reward_bound),
        }
    }

    /// Build over an externally-prepared [`PullScratch`] (the caller has
    /// already run [`PullScratch::prepare`] and [`PullScratch::gather`]).
    /// No allocation happens here — this is the zero-allocation serving
    /// path.
    pub fn with_scratch(data: &'a Matrix, reward_bound: f32, scratch: &'a PullScratch) -> Self {
        assert_eq!(scratch.dim(), data.cols(), "scratch dim mismatch");
        assert_eq!(scratch.qp.len(), data.cols(), "scratch not gathered");
        Self {
            data,
            scratch: ScratchRef::Borrowed(scratch),
            range: Self::range_from_bound(reward_bound),
        }
    }

    fn range_from_bound(reward_bound: f32) -> (f64, f64) {
        let b = reward_bound.max(f32::MIN_POSITIVE) as f64;
        (-b, b)
    }

    #[inline]
    fn scratch(&self) -> &PullScratch {
        match &self.scratch {
            ScratchRef::Owned(s) => s,
            ScratchRef::Borrowed(s) => s,
        }
    }
}

impl RewardSource for MatrixArms<'_> {
    fn n_arms(&self) -> usize {
        self.data.rows()
    }

    fn list_len(&self) -> usize {
        self.data.cols()
    }

    fn reward_range(&self) -> (f64, f64) {
        self.range
    }

    fn pull_range(&self, arm: usize, from: usize, to: usize) -> f64 {
        debug_assert!(to <= self.list_len());
        let row = self.data.row(arm);
        let s = self.scratch();
        match s.kind {
            OrderKind::Identity => dot(&row[from..to], &s.qp[from..to]) as f64,
            OrderKind::Gather => {
                // Gather-multiply; consecutive j share cache lines in qp,
                // row accesses are indirect. Unrolled 4-wide.
                let p = &s.perm;
                let qp = &s.qp;
                let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
                let mut j = from;
                while j + 4 <= to {
                    s0 += row[p[j] as usize] * qp[j];
                    s1 += row[p[j + 1] as usize] * qp[j + 1];
                    s2 += row[p[j + 2] as usize] * qp[j + 2];
                    s3 += row[p[j + 3] as usize] * qp[j + 3];
                    j += 4;
                }
                let mut tail = 0f32;
                while j < to {
                    tail += row[p[j] as usize] * qp[j];
                    j += 1;
                }
                ((s0 + s1) + (s2 + s3) + tail) as f64
            }
            OrderKind::Runs => {
                // Dense partial dots run-by-run (vectorizable).
                let mut acc = 0f64;
                for (pos, stop, coord) in s.run_segments(from, to) {
                    let len = stop - pos;
                    acc += dot(&row[coord..coord + len], &s.qp[pos..stop]) as f64;
                }
                acc
            }
        }
    }

    /// One pull batch across an arm set through the blocked
    /// [`partial_dot_rows`] kernel: for each dense coordinate run the
    /// gathered query window is loaded once and FMA'd against up to 8
    /// survivor rows at a time. Bit-identical per arm to
    /// [`RewardSource::pull_range`] (same runs, same per-row kernel,
    /// same f64 accumulation order) — BOUNDEDME's elimination decisions
    /// do not depend on batching.
    fn pull_range_batch(&self, arms: &[usize], from: usize, to: usize, out: &mut [f64]) {
        debug_assert_eq!(arms.len(), out.len());
        debug_assert!(to <= self.list_len());
        let s = self.scratch();
        match s.kind {
            OrderKind::Gather => {
                // Positional gathers have no dense runs to block over.
                for (&arm, o) in arms.iter().zip(out.iter_mut()) {
                    *o = self.pull_range(arm, from, to);
                }
            }
            OrderKind::Identity => {
                partial_dot_rows_chunked(
                    arms.iter().map(|&arm| &self.data.row(arm)[from..to]),
                    &s.qp[from..to],
                    |i, score| out[i] = score as f64,
                );
            }
            OrderKind::Runs => {
                // Run-by-run across the whole arm set: each dense run's
                // query window is loaded once and swept over every arm
                // (in the shared staging loop), accumulating per-arm in
                // f64 in run order — the exact accumulation order of
                // the per-arm `pull_range`, so sums stay bit-identical.
                for o in out.iter_mut() {
                    *o = 0.0;
                }
                for (pos, stop, coord) in s.run_segments(from, to) {
                    let len = stop - pos;
                    partial_dot_rows_chunked(
                        arms.iter().map(|&arm| &self.data.row(arm)[coord..coord + len]),
                        &s.qp[pos..stop],
                        |i, score| out[i] += score as f64,
                    );
                }
            }
        }
    }

    fn supports_compaction(&self) -> bool {
        true
    }

    /// One batched gather of every arm's not-yet-pulled coordinates
    /// into the panel, in pull order: dense per-row copies for
    /// `Sequential`, run-segment copies for `BlockShuffled`, and the
    /// dispatched [`gather_idx`] kernel (hardware `vgatherdps` on x86)
    /// for `Permuted`. Amortized over every subsequent pull of these
    /// arms, which all become dense streaming scans.
    fn compact_into(&self, arms: &[usize], from: usize, panel: &mut PullPanel) {
        let s = self.scratch();
        let n_list = self.list_len();
        debug_assert!(from < n_list);
        let stride = n_list - from;
        let buf = panel.begin(arms.len(), stride, from);
        match s.kind {
            OrderKind::Identity => {
                for (i, &arm) in arms.iter().enumerate() {
                    buf[i * stride..(i + 1) * stride]
                        .copy_from_slice(&self.data.row(arm)[from..]);
                }
            }
            OrderKind::Gather => {
                let idx = &s.perm[from..];
                for (i, &arm) in arms.iter().enumerate() {
                    gather_idx(self.data.row(arm), idx, &mut buf[i * stride..(i + 1) * stride]);
                }
            }
            OrderKind::Runs => {
                for (i, &arm) in arms.iter().enumerate() {
                    let row = self.data.row(arm);
                    let dst = &mut buf[i * stride..(i + 1) * stride];
                    for (pos, stop, coord) in s.run_segments(from, n_list) {
                        let len = stop - pos;
                        dst[pos - from..pos - from + len]
                            .copy_from_slice(&row[coord..coord + len]);
                    }
                }
            }
        }
    }

    /// One pull batch over the compacted panel: per-order, the exact
    /// f64 accumulation order of the scattered
    /// [`RewardSource::pull_range_batch`] replayed over dense panel
    /// rows (`Sequential`/`BlockShuffled`: the shared
    /// [`partial_dot_rows_chunked`] staging loop over contiguous
    /// windows; `Permuted`: the 4-wide gather unroll on now-contiguous
    /// values) — bit-identical sums, streaming memory access, with a
    /// software prefetch one row ahead.
    fn pull_range_batch_panel(&self, panel: &PullPanel, from: usize, to: usize, out: &mut [f64]) {
        debug_assert_eq!(panel.rows(), out.len());
        debug_assert!(panel.base() <= from && from <= to && to <= self.list_len());
        let s = self.scratch();
        let nrows = panel.rows();
        match s.kind {
            OrderKind::Identity => {
                partial_dot_rows_chunked(
                    (0..nrows).map(|i| {
                        if i + 1 < nrows {
                            simd::prefetch_read(panel.window_ptr(i + 1, from));
                        }
                        panel.window(i, from, to)
                    }),
                    &s.qp[from..to],
                    |i, score| out[i] = score as f64,
                );
            }
            OrderKind::Gather => {
                let qw = &s.qp[from..to];
                for (i, o) in out.iter_mut().enumerate() {
                    if i + 1 < nrows {
                        simd::prefetch_read(panel.window_ptr(i + 1, from));
                    }
                    *o = gather_order_dot(panel.window(i, from, to), qw);
                }
            }
            OrderKind::Runs => {
                for o in out.iter_mut() {
                    *o = 0.0;
                }
                for (pos, stop, _) in s.run_segments(from, to) {
                    partial_dot_rows_chunked(
                        (0..nrows).map(|i| {
                            if i + 1 < nrows {
                                simd::prefetch_read(panel.window_ptr(i + 1, pos));
                            }
                            panel.window(i, pos, stop)
                        }),
                        &s.qp[pos..stop],
                        |i, score| out[i] += score as f64,
                    );
                }
            }
        }
    }

    fn pull_iid(&self, arm: usize, rng: &mut Rng) -> f64 {
        let j = rng.next_below(self.list_len());
        let s = self.scratch();
        (self.data.row(arm)[s.coord_at(j)] * s.qp[j]) as f64
    }

    fn true_mean(&self, arm: usize) -> f64 {
        self.pull_range(arm, 0, self.list_len()) / self.list_len() as f64
    }
}

/// Dot over two contiguous slices in the *exact* arithmetic order of
/// the `Permuted` scattered pull's 4-wide gather-multiply unroll (four
/// independent f32 lane sums, sequential tail, `((s0+s1)+(s2+s3)+tail)`
/// widened to f64 once). The panel's `Permuted` pulls go through this
/// so compacted sums stay bit-identical to scattered ones — and unlike
/// the scattered loop, the four lanes now read consecutive memory, so
/// LLVM vectorizes them.
#[inline]
fn gather_order_dot(v: &[f32], q: &[f32]) -> f64 {
    debug_assert_eq!(v.len(), q.len());
    let n = v.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let mut j = 0usize;
    while j + 4 <= n {
        s0 += v[j] * q[j];
        s1 += v[j + 1] * q[j + 1];
        s2 += v[j + 2] * q[j + 2];
        s3 += v[j + 3] * q[j + 3];
        j += 4;
    }
    let mut tail = 0f32;
    while j < n {
        tail += v[j] * q[j];
        j += 1;
    }
    ((s0 + s1) + (s2 + s3) + tail) as f64
}

/// [`gather_order_dot`]'s coded twin for the *scattered* `Permuted`
/// pull over compressed rows: identical 4-lane structure, with each
/// indexed element decoded before the multiply. `dec` must be exact and
/// deterministic (f16/bf16 decode, int8 code→f32) so the panel replay
/// below stays bit-identical.
#[inline]
fn gather_order_dot_coded<E: Copy>(
    row: &[E],
    p: &[u32],
    qp: &[f32],
    from: usize,
    to: usize,
    dec: impl Fn(E) -> f32,
) -> f64 {
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let mut j = from;
    while j + 4 <= to {
        s0 += dec(row[p[j] as usize]) * qp[j];
        s1 += dec(row[p[j + 1] as usize]) * qp[j + 1];
        s2 += dec(row[p[j + 2] as usize]) * qp[j + 2];
        s3 += dec(row[p[j + 3] as usize]) * qp[j + 3];
        j += 4;
    }
    let mut tail = 0f32;
    while j < to {
        tail += dec(row[p[j] as usize]) * qp[j];
        j += 1;
    }
    ((s0 + s1) + (s2 + s3) + tail) as f64
}

/// [`gather_order_dot`]'s coded twin for *panel* `Permuted` pulls: the
/// codes were already gathered into pull order, so the lanes read
/// consecutive memory; same decode, same lane sums, same widening —
/// bit-identical to [`gather_order_dot_coded`] over the source row.
#[inline]
fn gather_order_dot_decoded<E: Copy>(v: &[E], q: &[f32], dec: impl Fn(E) -> f32) -> f64 {
    debug_assert_eq!(v.len(), q.len());
    let n = v.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let mut j = 0usize;
    while j + 4 <= n {
        s0 += dec(v[j]) * q[j];
        s1 += dec(v[j + 1]) * q[j + 1];
        s2 += dec(v[j + 2]) * q[j + 2];
        s3 += dec(v[j + 3]) * q[j + 3];
        j += 4;
    }
    let mut tail = 0f32;
    while j < n {
        tail += dec(v[j]) * q[j];
        j += 1;
    }
    ((s0 + s1) + (s2 + s3) + tail) as f64
}

/// MIPS as MAB-BP over a *compressed* dataset tier: arm `i` ↔ the
/// dequantized row `deq(c_i)`, reward `j` ↔ one dequantized coordinate
/// product. The sampling half of the two-tier query path
/// (see [`crate::algos::BoundedMeIndex`]): the bandit eliminates on
/// rewards read from f16/bf16/int8 codes (widened in registers by
/// [`crate::linalg::simd::wide`], 2–4× less memory traffic), and the
/// caller confirm-rescores the returned arms on f32.
///
/// This is a *legitimate* bounded-reward environment in its own right —
/// `reward_bound` must bound the **dequantized** products (derive it
/// from [`QuantMatrix::colmax`]), so the (ε, δ) guarantee holds exactly
/// with respect to the dequantized means; the caller accounts for the
/// quantization bias separately via [`QuantMatrix::row_err`].
///
/// Every layout contract of [`MatrixArms`] carries over per order:
/// batched ≡ per-arm, panel ≡ scattered, bit for bit (the panel stages
/// compressed codes — [`PullPanel::begin_u16`] / [`PullPanel::begin_i8`]
/// — and replays the same decode + accumulation order; int8 raw code
/// sums are widened to f64 and multiplied by the row scale identically
/// on both paths).
pub struct QuantArms<'a> {
    data: &'a QuantMatrix,
    scratch: ScratchRef<'a>,
    range: (f64, f64),
}

impl<'a> QuantArms<'a> {
    /// Build the compressed-tier environment for one query, allocating
    /// a private scratch (one-shot convenience; the serving path uses
    /// [`QuantArms::with_scratch`]).
    ///
    /// `reward_bound` must bound every *dequantized* reward:
    /// `max_j colmax[j]·|q_j|` over [`QuantMatrix::colmax`].
    pub fn new(
        data: &'a QuantMatrix,
        query: &[f32],
        reward_bound: f32,
        order: PullOrder,
        seed: u64,
    ) -> Self {
        assert_eq!(query.len(), data.cols(), "query dim mismatch");
        let mut scratch = Box::new(PullScratch::new());
        scratch.prepare(order, data.cols(), seed);
        scratch.gather(query);
        Self {
            data,
            scratch: ScratchRef::Owned(scratch),
            range: MatrixArms::range_from_bound(reward_bound),
        }
    }

    /// Build over an externally-prepared [`PullScratch`] (the
    /// zero-allocation serving path — the same prepared+gathered
    /// scratch the f32 tier would use).
    pub fn with_scratch(data: &'a QuantMatrix, reward_bound: f32, scratch: &'a PullScratch) -> Self {
        assert_eq!(scratch.dim(), data.cols(), "scratch dim mismatch");
        assert_eq!(scratch.qp.len(), data.cols(), "scratch not gathered");
        Self {
            data,
            scratch: ScratchRef::Borrowed(scratch),
            range: MatrixArms::range_from_bound(reward_bound),
        }
    }

    /// The tier this environment samples from.
    pub fn storage(&self) -> Storage {
        self.data.storage()
    }

    #[inline]
    fn scratch(&self) -> &PullScratch {
        match &self.scratch {
            ScratchRef::Owned(s) => s,
            ScratchRef::Borrowed(s) => s,
        }
    }
}

impl RewardSource for QuantArms<'_> {
    fn n_arms(&self) -> usize {
        self.data.rows()
    }

    fn list_len(&self) -> usize {
        self.data.cols()
    }

    fn reward_range(&self) -> (f64, f64) {
        self.range
    }

    /// Per order, the compressed mirror of [`MatrixArms::pull_range`]:
    /// `Sequential` / `BlockShuffled` run the dispatched widening dot
    /// over code windows (per dense run for the latter, accumulating in
    /// f64 in run order); `Permuted` runs the coded 4-wide gather
    /// unroll. int8 dots are raw code sums widened to f64 then scaled
    /// once per dot — the same scale application the panel path replays.
    fn pull_range(&self, arm: usize, from: usize, to: usize) -> f64 {
        debug_assert!(to <= self.list_len());
        let s = self.scratch();
        match (s.kind, self.data.storage()) {
            (OrderKind::Identity, Storage::F16) => {
                (wide::f16_kernels().dot)(&self.data.row_u16(arm)[from..to], &s.qp[from..to])
                    as f64
            }
            (OrderKind::Identity, Storage::Bf16) => {
                (wide::bf16_kernels().dot)(&self.data.row_u16(arm)[from..to], &s.qp[from..to])
                    as f64
            }
            (OrderKind::Identity, Storage::Int8) => {
                (wide::int8_kernels().dot)(&self.data.row_i8(arm)[from..to], &s.qp[from..to])
                    as f64
                    * self.data.scale(arm) as f64
            }
            (OrderKind::Gather, Storage::F16) => gather_order_dot_coded(
                self.data.row_u16(arm),
                &s.perm,
                &s.qp,
                from,
                to,
                wide::f16_to_f32,
            ),
            (OrderKind::Gather, Storage::Bf16) => gather_order_dot_coded(
                self.data.row_u16(arm),
                &s.perm,
                &s.qp,
                from,
                to,
                wide::bf16_to_f32,
            ),
            (OrderKind::Gather, Storage::Int8) => {
                gather_order_dot_coded(
                    self.data.row_i8(arm),
                    &s.perm,
                    &s.qp,
                    from,
                    to,
                    |c: i8| c as f32,
                ) * self.data.scale(arm) as f64
            }
            (OrderKind::Runs, storage) => {
                let mut acc = 0f64;
                match storage {
                    Storage::F16 | Storage::Bf16 => {
                        let k = if storage == Storage::F16 {
                            wide::f16_kernels()
                        } else {
                            wide::bf16_kernels()
                        };
                        let row = self.data.row_u16(arm);
                        for (pos, stop, coord) in s.run_segments(from, to) {
                            let len = stop - pos;
                            acc += (k.dot)(&row[coord..coord + len], &s.qp[pos..stop]) as f64;
                        }
                    }
                    Storage::Int8 => {
                        let k = wide::int8_kernels();
                        let row = self.data.row_i8(arm);
                        let scale = self.data.scale(arm) as f64;
                        for (pos, stop, coord) in s.run_segments(from, to) {
                            let len = stop - pos;
                            acc += (k.dot)(&row[coord..coord + len], &s.qp[pos..stop]) as f64
                                * scale;
                        }
                    }
                    Storage::F32 => unreachable!("QuantMatrix never stores f32"),
                }
                acc
            }
            (_, Storage::F32) => unreachable!("QuantMatrix never stores f32"),
        }
    }

    fn supports_compaction(&self) -> bool {
        true
    }

    /// Stage *compressed codes* into the panel (2–4× smaller resident
    /// panel than the f32 tier): dense row copies for `Sequential`,
    /// run-segment copies for `BlockShuffled`, and the wide tables'
    /// exact element gather for `Permuted`; int8 rows carry their scale
    /// into the panel's per-row scale lane.
    fn compact_into(&self, arms: &[usize], from: usize, panel: &mut PullPanel) {
        let s = self.scratch();
        let n_list = self.list_len();
        debug_assert!(from < n_list);
        let stride = n_list - from;
        match self.data.storage() {
            Storage::F16 | Storage::Bf16 => {
                let gather = wide::f16_kernels().gather; // element move, format-agnostic
                let buf = panel.begin_u16(arms.len(), stride, from);
                for (i, &arm) in arms.iter().enumerate() {
                    let row = self.data.row_u16(arm);
                    let dst = &mut buf[i * stride..(i + 1) * stride];
                    match s.kind {
                        OrderKind::Identity => dst.copy_from_slice(&row[from..]),
                        OrderKind::Gather => gather(row, &s.perm[from..], dst),
                        OrderKind::Runs => {
                            for (pos, stop, coord) in s.run_segments(from, n_list) {
                                let len = stop - pos;
                                dst[pos - from..pos - from + len]
                                    .copy_from_slice(&row[coord..coord + len]);
                            }
                        }
                    }
                }
            }
            Storage::Int8 => {
                let gather = wide::int8_kernels().gather;
                let (buf, scales) = panel.begin_i8(arms.len(), stride, from);
                for (i, &arm) in arms.iter().enumerate() {
                    scales[i] = self.data.scale(arm);
                    let row = self.data.row_i8(arm);
                    let dst = &mut buf[i * stride..(i + 1) * stride];
                    match s.kind {
                        OrderKind::Identity => dst.copy_from_slice(&row[from..]),
                        OrderKind::Gather => gather(row, &s.perm[from..], dst),
                        OrderKind::Runs => {
                            for (pos, stop, coord) in s.run_segments(from, n_list) {
                                let len = stop - pos;
                                dst[pos - from..pos - from + len]
                                    .copy_from_slice(&row[coord..coord + len]);
                            }
                        }
                    }
                }
            }
            Storage::F32 => unreachable!("QuantMatrix never stores f32"),
        }
    }

    /// Panel pulls replaying [`QuantArms::pull_range`]'s exact decode +
    /// accumulation order over dense code rows (per-row widening dots;
    /// the coded 4-wide unroll for `Permuted`), with a software
    /// prefetch one row ahead — bit-identical sums to the scattered
    /// batch, streaming compressed bytes.
    fn pull_range_batch_panel(&self, panel: &PullPanel, from: usize, to: usize, out: &mut [f64]) {
        debug_assert_eq!(panel.rows(), out.len());
        debug_assert!(panel.base() <= from && from <= to && to <= self.list_len());
        let s = self.scratch();
        let nrows = panel.rows();
        let storage = self.data.storage();
        // One dense-window dot per panel row, in the scattered path's
        // arithmetic order for the active (order, storage) pair.
        let dot_row = |i: usize, wfrom: usize, wto: usize| -> f64 {
            match (s.kind, storage) {
                (OrderKind::Gather, Storage::F16) => gather_order_dot_decoded(
                    panel.window16(i, wfrom, wto),
                    &s.qp[wfrom..wto],
                    wide::f16_to_f32,
                ),
                (OrderKind::Gather, Storage::Bf16) => gather_order_dot_decoded(
                    panel.window16(i, wfrom, wto),
                    &s.qp[wfrom..wto],
                    wide::bf16_to_f32,
                ),
                (OrderKind::Gather, Storage::Int8) => {
                    gather_order_dot_decoded(
                        panel.window8(i, wfrom, wto),
                        &s.qp[wfrom..wto],
                        |c: i8| c as f32,
                    ) * panel.row_scale(i) as f64
                }
                (_, Storage::F16) => (wide::f16_kernels().dot)(
                    panel.window16(i, wfrom, wto),
                    &s.qp[wfrom..wto],
                ) as f64,
                (_, Storage::Bf16) => (wide::bf16_kernels().dot)(
                    panel.window16(i, wfrom, wto),
                    &s.qp[wfrom..wto],
                ) as f64,
                (_, Storage::Int8) => {
                    (wide::int8_kernels().dot)(
                        panel.window8(i, wfrom, wto),
                        &s.qp[wfrom..wto],
                    ) as f64
                        * panel.row_scale(i) as f64
                }
                (_, Storage::F32) => unreachable!("QuantMatrix never stores f32"),
            }
        };
        let prefetch = |i: usize, at: usize| {
            if i + 1 < nrows {
                match storage {
                    Storage::Int8 => simd::prefetch_read(panel.window_ptr8(i + 1, at)),
                    _ => simd::prefetch_read(panel.window_ptr16(i + 1, at)),
                }
            }
        };
        match s.kind {
            OrderKind::Identity | OrderKind::Gather => {
                for (i, o) in out.iter_mut().enumerate() {
                    prefetch(i, from);
                    *o = dot_row(i, from, to);
                }
            }
            OrderKind::Runs => {
                for o in out.iter_mut() {
                    *o = 0.0;
                }
                for (pos, stop, _) in s.run_segments(from, to) {
                    for (i, o) in out.iter_mut().enumerate() {
                        prefetch(i, pos);
                        *o += dot_row(i, pos, stop);
                    }
                }
            }
        }
    }

    fn pull_iid(&self, arm: usize, rng: &mut Rng) -> f64 {
        let j = rng.next_below(self.list_len());
        let s = self.scratch();
        (self.data.dequantize(arm, s.coord_at(j)) * s.qp[j]) as f64
    }

    fn true_mean(&self, arm: usize) -> f64 {
        self.pull_range(arm, 0, self.list_len()) / self.list_len() as f64
    }
}

/// The paper's adversarial environment (Figure 1): arm `a` has true mean
/// `r_a ~ U[0,1]`; its reward list holds `⌊r_a·N⌉` ones then zeros, and
/// pulls are served **1s-first**, making prefixes maximally misleading.
pub struct AdversarialArms {
    ones: Vec<u32>,
    n_list: usize,
}

impl AdversarialArms {
    /// Generate `n` arms with lists of length `n_list`, seeded.
    pub fn generate(n: usize, n_list: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let ones = (0..n)
            .map(|_| (rng.next_f64() * n_list as f64).round() as u32)
            .map(|o| o.min(n_list as u32))
            .collect();
        Self { ones, n_list }
    }

    /// Construct with explicit per-arm one-counts (tests).
    pub fn from_ones(ones: Vec<u32>, n_list: usize) -> Self {
        assert!(ones.iter().all(|&o| o as usize <= n_list));
        Self { ones, n_list }
    }

    /// Index of the best arm (ties → lowest index).
    pub fn best_arm(&self) -> usize {
        let mut best = 0usize;
        for i in 1..self.ones.len() {
            if self.ones[i] > self.ones[best] {
                best = i;
            }
        }
        best
    }
}

impl RewardSource for AdversarialArms {
    fn n_arms(&self) -> usize {
        self.ones.len()
    }

    fn list_len(&self) -> usize {
        self.n_list
    }

    fn reward_range(&self) -> (f64, f64) {
        (0.0, 1.0)
    }

    fn pull_range(&self, arm: usize, from: usize, to: usize) -> f64 {
        debug_assert!(to <= self.n_list);
        let ones = self.ones[arm] as usize;
        // Rewards are 1 at positions [0, ones), 0 afterwards.
        let hi = to.min(ones);
        let lo = from.min(ones);
        (hi - lo) as f64
    }

    fn pull_iid(&self, arm: usize, rng: &mut Rng) -> f64 {
        let p = self.ones[arm] as f64 / self.n_list as f64;
        if rng.bernoulli(p) {
            1.0
        } else {
            0.0
        }
    }

    fn true_mean(&self, arm: usize) -> f64 {
        self.ones[arm] as f64 / self.n_list as f64
    }
}

/// Arbitrary in-memory reward lists (unit-test environment). Lists are
/// used in the order given — shuffle beforehand if random order is
/// desired.
pub struct ExplicitArms {
    lists: Vec<Vec<f64>>,
    range: (f64, f64),
}

impl ExplicitArms {
    /// Build from per-arm lists; all must share one length ≥ 1.
    pub fn new(lists: Vec<Vec<f64>>) -> Self {
        assert!(!lists.is_empty(), "no arms");
        let n = lists[0].len();
        assert!(n > 0, "empty reward lists");
        assert!(lists.iter().all(|l| l.len() == n), "ragged reward lists");
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for l in &lists {
            for &x in l {
                lo = lo.min(x);
                hi = hi.max(x);
            }
        }
        if lo >= hi {
            hi = lo + 1.0;
        }
        Self { lists, range: (lo, hi) }
    }

    /// Override the advertised reward range.
    pub fn with_range(mut self, a: f64, b: f64) -> Self {
        assert!(b > a);
        self.range = (a, b);
        self
    }
}

impl RewardSource for ExplicitArms {
    fn n_arms(&self) -> usize {
        self.lists.len()
    }

    fn list_len(&self) -> usize {
        self.lists[0].len()
    }

    fn reward_range(&self) -> (f64, f64) {
        self.range
    }

    fn pull_range(&self, arm: usize, from: usize, to: usize) -> f64 {
        self.lists[arm][from..to].iter().sum()
    }

    fn pull_iid(&self, arm: usize, rng: &mut Rng) -> f64 {
        self.lists[arm][rng.next_below(self.list_len())]
    }

    fn true_mean(&self, arm: usize) -> f64 {
        self.lists[arm].iter().sum::<f64>() / self.list_len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_matrix() -> Matrix {
        Matrix::from_rows(&[
            vec![1.0, 2.0, 3.0, 4.0],
            vec![-1.0, 0.5, 2.0, -2.0],
            vec![0.0, 0.0, 0.0, 0.0],
        ])
    }

    #[test]
    fn matrix_arms_true_mean_matches_dot() {
        let m = toy_matrix();
        let q = [1.0f32, -1.0, 0.5, 2.0];
        for order in [PullOrder::Sequential, PullOrder::Permuted, PullOrder::BlockShuffled(2)] {
            let arms = MatrixArms::new(&m, &q, 8.0, order, 7);
            for i in 0..3 {
                let expect = dot(m.row(i), &q) as f64 / 4.0;
                assert!(
                    (arms.true_mean(i) - expect).abs() < 1e-6,
                    "order={order:?} arm={i}"
                );
            }
        }
    }

    #[test]
    fn matrix_arms_full_pull_equals_exact_product() {
        let m = toy_matrix();
        let q = [1.0f32, -1.0, 0.5, 2.0];
        for order in [PullOrder::Sequential, PullOrder::Permuted, PullOrder::BlockShuffled(3)] {
            let arms = MatrixArms::new(&m, &q, 8.0, order, 3);
            for i in 0..3 {
                let full = arms.pull_range(i, 0, 4);
                let expect = dot(m.row(i), &q) as f64;
                assert!((full - expect).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn matrix_arms_pulls_compose() {
        let m = toy_matrix();
        let q = [0.5f32, 1.5, -0.5, 1.0];
        let arms = MatrixArms::new(&m, &q, 8.0, PullOrder::Permuted, 11);
        for i in 0..3 {
            let split = arms.pull_range(i, 0, 2) + arms.pull_range(i, 2, 4);
            let full = arms.pull_range(i, 0, 4);
            assert!((split - full).abs() < 1e-6);
        }
    }

    #[test]
    fn matrix_arms_range_bounds_rewards() {
        let m = toy_matrix();
        let q = [1.0f32, -1.0, 0.5, 2.0];
        let arms = MatrixArms::new(&m, &q, 8.0, PullOrder::Permuted, 5);
        let (a, b) = arms.reward_range();
        for i in 0..3 {
            for j in 0..4 {
                let r = arms.pull_range(i, j, j + 1);
                assert!(r >= a - 1e-9 && r <= b + 1e-9, "reward {r} outside [{a},{b}]");
            }
        }
    }

    #[test]
    fn borrowed_scratch_matches_owned() {
        let m = toy_matrix();
        let q = [1.0f32, -1.0, 0.5, 2.0];
        for order in [PullOrder::Sequential, PullOrder::Permuted, PullOrder::BlockShuffled(2)] {
            let owned = MatrixArms::new(&m, &q, 8.0, order, 13);
            let mut scratch = PullScratch::new();
            scratch.prepare(order, 4, 13);
            scratch.gather(&q);
            let borrowed = MatrixArms::with_scratch(&m, 8.0, &scratch);
            for i in 0..3 {
                for (from, to) in [(0, 4), (1, 3), (0, 2), (2, 4)] {
                    let a = owned.pull_range(i, from, to);
                    let b = borrowed.pull_range(i, from, to);
                    assert_eq!(a.to_bits(), b.to_bits(), "order={order:?} arm={i}");
                }
            }
        }
    }

    #[test]
    fn scratch_prepare_is_cached_and_regather_is_growth_free() {
        let mut scratch = PullScratch::new();
        scratch.prepare(PullOrder::BlockShuffled(2), 64, 9);
        let q1 = vec![1.0f32; 64];
        scratch.gather(&q1);
        let grows = scratch.grow_events();
        // Same key: prepare is a no-op; new queries only re-gather, and
        // the warm buffers never grow again.
        for i in 0..50 {
            scratch.prepare(PullOrder::BlockShuffled(2), 64, 9);
            let q = vec![i as f32; 64];
            scratch.gather(&q);
        }
        assert_eq!(scratch.grow_events(), grows, "steady state reallocated");
    }

    #[test]
    fn scratch_rekey_rebuilds_order() {
        let m = toy_matrix();
        let q = [1.0f32, 2.0, 3.0, 4.0];
        let mut scratch = PullScratch::new();
        scratch.prepare(PullOrder::Permuted, 4, 1);
        scratch.gather(&q);
        let first: Vec<f32> = scratch.qp.clone();
        scratch.prepare(PullOrder::Permuted, 4, 2);
        scratch.gather(&q);
        // Different seed ⇒ (almost surely) different permutation of a
        // 4-element distinct query; either way the full sum is invariant.
        let arms = MatrixArms::with_scratch(&m, 8.0, &scratch);
        assert!((arms.pull_range(0, 0, 4) - dot(m.row(0), &q) as f64).abs() < 1e-6);
        let _ = first;
    }

    #[test]
    fn pull_range_batch_is_bit_identical_to_per_arm() {
        // A wider instance than the toy so every CHUNK remainder shape
        // (full 8-blocks + ragged tail) is exercised.
        let mut rng = Rng::new(21);
        let m = Matrix::from_fn(19, 96, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(96);
        let arm_ids: Vec<usize> = (0..19).rev().collect(); // scattered order
        for order in [
            PullOrder::Sequential,
            PullOrder::Permuted,
            PullOrder::BlockShuffled(13),
        ] {
            let arms = MatrixArms::new(&m, &q, 16.0, order, 5);
            for (from, to) in [(0usize, 96usize), (0, 1), (7, 61), (33, 33), (95, 96)] {
                let mut batch = vec![0f64; arm_ids.len()];
                arms.pull_range_batch(&arm_ids, from, to, &mut batch);
                for (i, &arm) in arm_ids.iter().enumerate() {
                    let single = arms.pull_range(arm, from, to);
                    assert_eq!(
                        batch[i].to_bits(),
                        single.to_bits(),
                        "order={order:?} arm={arm} range=[{from},{to})"
                    );
                }
            }
        }
    }

    #[test]
    fn panel_pull_is_bit_identical_to_scatter() {
        // Ragged dim (103) so run tails, chunk remainders, and the
        // 4-wide gather tail are all exercised; scattered arm order.
        let mut rng = Rng::new(0x7A11);
        let m = Matrix::from_fn(21, 103, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(103);
        let ids: Vec<usize> = (0..21).rev().step_by(2).collect();
        for order in [
            PullOrder::Sequential,
            PullOrder::Permuted,
            PullOrder::BlockShuffled(13),
        ] {
            let arms = MatrixArms::new(&m, &q, 16.0, order, 9);
            for base in [0usize, 7, 41, 102] {
                let mut panel = PullPanel::new();
                arms.compact_into(&ids, base, &mut panel);
                assert_eq!(panel.rows(), ids.len());
                assert_eq!(panel.base(), base);
                assert_eq!(panel.stride(), 103 - base);
                for (from, to) in
                    [(base, 103), (base, base), (base, base + 1), (base + 1, 103)]
                {
                    if to > 103 {
                        continue;
                    }
                    let mut scatter = vec![0f64; ids.len()];
                    arms.pull_range_batch(&ids, from, to, &mut scatter);
                    let mut dense = vec![0f64; ids.len()];
                    arms.pull_range_batch_panel(&panel, from, to, &mut dense);
                    for (i, (a, b)) in scatter.iter().zip(&dense).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "order={order:?} base={base} range=[{from},{to}) row {i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn panel_recompact_matches_fresh_compaction() {
        let mut rng = Rng::new(0xF00D);
        let m = Matrix::from_fn(17, 96, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(96);
        for order in [PullOrder::Permuted, PullOrder::BlockShuffled(11)] {
            let arms = MatrixArms::new(&m, &q, 16.0, order, 4);
            let ids: Vec<usize> = (0..17).collect();
            let mut panel = PullPanel::new();
            arms.compact_into(&ids, 5, &mut panel);
            // Survive rows {14, 2, 9, 0} (arbitrary order), advance to 23.
            let slots = vec![14usize, 2, 9, 0];
            panel.recompact(&slots, 23);
            let kept: Vec<usize> = slots.iter().map(|&s| ids[s]).collect();
            let mut fresh = PullPanel::new();
            arms.compact_into(&kept, 23, &mut fresh);
            assert_eq!(panel.rows(), fresh.rows());
            assert_eq!(panel.base(), fresh.base());
            assert_eq!(panel.stride(), fresh.stride());
            for i in 0..panel.rows() {
                let a = panel.window(i, 23, 96);
                let b = fresh.window(i, 23, 96);
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "order={order:?} row {i}");
                }
            }
            // And pulls off the recompacted panel still match scatter.
            let mut scatter = vec![0f64; kept.len()];
            arms.pull_range_batch(&kept, 23, 96, &mut scatter);
            let mut dense = vec![0f64; kept.len()];
            arms.pull_range_batch_panel(&panel, 23, 96, &mut dense);
            for (a, b) in scatter.iter().zip(&dense) {
                assert_eq!(a.to_bits(), b.to_bits(), "order={order:?}");
            }
        }
    }

    #[test]
    fn panel_steady_state_is_growth_free() {
        let mut rng = Rng::new(0x60);
        let m = Matrix::from_fn(12, 64, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(64);
        let arms = MatrixArms::new(&m, &q, 8.0, PullOrder::BlockShuffled(8), 2);
        let ids: Vec<usize> = (0..12).collect();
        let mut panel = PullPanel::new();
        // Two warm passes: the ping-pong swap means both buffers must
        // reach the high-water capacity before growth stops.
        for _ in 0..2 {
            arms.compact_into(&ids, 0, &mut panel);
            panel.recompact(&[0, 3, 7, 9], 16);
            panel.recompact(&[1, 2], 40);
        }
        let warm = panel.grow_events();
        for _ in 0..20 {
            arms.compact_into(&ids, 0, &mut panel);
            panel.recompact(&[0, 3, 7, 9], 16);
            panel.recompact(&[1, 2], 40);
        }
        assert_eq!(panel.grow_events(), warm, "steady-state panel reallocated");
    }

    #[test]
    fn default_pull_range_batch_matches_loop() {
        let arms = AdversarialArms::from_ones(vec![3, 0, 5, 2], 5);
        let ids = [2usize, 0, 3];
        let mut out = vec![0f64; 3];
        arms.pull_range_batch(&ids, 1, 4, &mut out);
        assert_eq!(out, vec![3.0, 2.0, 1.0]);
    }

    #[test]
    fn adversarial_serves_ones_first() {
        let arms = AdversarialArms::from_ones(vec![3, 0, 5], 5);
        assert_eq!(arms.pull_range(0, 0, 3), 3.0);
        assert_eq!(arms.pull_range(0, 3, 5), 0.0);
        assert_eq!(arms.pull_range(1, 0, 5), 0.0);
        assert_eq!(arms.pull_range(2, 0, 5), 5.0);
        assert_eq!(arms.best_arm(), 2);
        assert_eq!(arms.true_mean(0), 0.6);
    }

    #[test]
    fn adversarial_generate_means_in_unit() {
        let arms = AdversarialArms::generate(100, 1000, 3);
        for i in 0..100 {
            let p = arms.true_mean(i);
            assert!((0.0..=1.0).contains(&p));
            // full pull equals true mean * N
            assert!((arms.pull_range(i, 0, 1000) / 1000.0 - p).abs() < 1e-12);
        }
    }

    #[test]
    fn adversarial_iid_matches_mean() {
        let arms = AdversarialArms::from_ones(vec![700], 1000);
        let mut rng = Rng::new(9);
        let m: f64 = (0..20_000).map(|_| arms.pull_iid(0, &mut rng)).sum::<f64>() / 20_000.0;
        assert!((m - 0.7).abs() < 0.02, "mean={m}");
    }

    #[test]
    fn explicit_arms_basics() {
        let arms = ExplicitArms::new(vec![vec![1.0, 2.0, 3.0], vec![0.0, 0.0, 3.0]]);
        assert_eq!(arms.n_arms(), 2);
        assert_eq!(arms.list_len(), 3);
        assert_eq!(arms.true_mean(0), 2.0);
        assert_eq!(arms.pull_range(1, 1, 3), 3.0);
        assert_eq!(arms.reward_range(), (0.0, 3.0));
        let ranged = ExplicitArms::new(vec![vec![1.0]]).with_range(-5.0, 5.0);
        assert_eq!(ranged.reward_range(), (-5.0, 5.0));
    }

    #[test]
    #[should_panic]
    fn explicit_arms_rejects_ragged() {
        ExplicitArms::new(vec![vec![1.0], vec![1.0, 2.0]]);
    }

    #[test]
    fn block_shuffle_covers_all_coords() {
        let m = toy_matrix();
        let q = [1.0f32, 1.0, 1.0, 1.0];
        let arms = MatrixArms::new(&m, &q, 8.0, PullOrder::BlockShuffled(3), 17);
        // Sum over full range must equal plain sum regardless of order.
        let full = arms.pull_range(0, 0, 4);
        assert!((full - 10.0).abs() < 1e-6);
    }

    /// Dequantized reward bound for a quant environment (what the index
    /// layer computes from colmax).
    fn quant_bound(qm: &QuantMatrix, q: &[f32]) -> f32 {
        qm.colmax()
            .iter()
            .zip(q)
            .fold(f32::MIN_POSITIVE, |b, (&c, &x)| b.max(c * x.abs()))
    }

    #[test]
    fn quant_pull_paths_are_bit_identical_across_layouts() {
        // The Storage-axis mirror of panel_pull_is_bit_identical_to_scatter
        // + pull_range_batch_is_bit_identical_to_per_arm: for every
        // (order, tier), batched ≡ per-arm and panel ≡ scattered, bit
        // for bit. Ragged dim 103 exercises run tails, wide-kernel chunk
        // remainders, and the 4-wide gather tail.
        let mut rng = Rng::new(0x9A27);
        let m = Matrix::from_fn(21, 103, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(103);
        let ids: Vec<usize> = (0..21).rev().step_by(2).collect();
        for storage in [Storage::F16, Storage::Bf16, Storage::Int8] {
            let qm = QuantMatrix::quantize(&m, storage);
            for order in [
                PullOrder::Sequential,
                PullOrder::Permuted,
                PullOrder::BlockShuffled(13),
            ] {
                let arms = QuantArms::new(&qm, &q, quant_bound(&qm, &q), order, 9);
                // Batched ≡ per-arm.
                for (from, to) in [(0usize, 103usize), (0, 1), (7, 61), (33, 33)] {
                    let mut batch = vec![0f64; ids.len()];
                    arms.pull_range_batch(&ids, from, to, &mut batch);
                    for (i, &arm) in ids.iter().enumerate() {
                        assert_eq!(
                            batch[i].to_bits(),
                            arms.pull_range(arm, from, to).to_bits(),
                            "{storage:?} {order:?} arm={arm} [{from},{to})"
                        );
                    }
                }
                // Panel ≡ scattered, across bases and windows.
                for base in [0usize, 7, 41, 102] {
                    let mut panel = PullPanel::new();
                    arms.compact_into(&ids, base, &mut panel);
                    assert_eq!(panel.rows(), ids.len());
                    assert_eq!(panel.base(), base);
                    for (from, to) in
                        [(base, 103), (base, base), (base, base + 1), (base + 1, 103)]
                    {
                        if to > 103 {
                            continue;
                        }
                        let mut scatter = vec![0f64; ids.len()];
                        arms.pull_range_batch(&ids, from, to, &mut scatter);
                        let mut dense = vec![0f64; ids.len()];
                        arms.pull_range_batch_panel(&panel, from, to, &mut dense);
                        for (i, (a, b)) in scatter.iter().zip(&dense).enumerate() {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "{storage:?} {order:?} base={base} [{from},{to}) row {i}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn quant_panel_recompact_matches_fresh_compaction() {
        // The compressed ping-pong pairs must re-compact exactly like
        // the f32 pair (including the int8 scale lane riding along).
        let mut rng = Rng::new(0x5CA1_F00D);
        let m = Matrix::from_fn(17, 96, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(96);
        for storage in [Storage::F16, Storage::Int8] {
            let qm = QuantMatrix::quantize(&m, storage);
            for order in [PullOrder::Permuted, PullOrder::BlockShuffled(11)] {
                let arms = QuantArms::new(&qm, &q, quant_bound(&qm, &q), order, 4);
                let ids: Vec<usize> = (0..17).collect();
                let mut panel = PullPanel::new();
                arms.compact_into(&ids, 5, &mut panel);
                let slots = vec![14usize, 2, 9, 0];
                panel.recompact(&slots, 23);
                let kept: Vec<usize> = slots.iter().map(|&s| ids[s]).collect();
                let mut fresh = PullPanel::new();
                arms.compact_into(&kept, 23, &mut fresh);
                assert_eq!(panel.rows(), fresh.rows());
                assert_eq!(panel.base(), fresh.base());
                assert_eq!(panel.stride(), fresh.stride());
                // Pulls off the recompacted panel still match scatter.
                let mut scatter = vec![0f64; kept.len()];
                arms.pull_range_batch(&kept, 23, 96, &mut scatter);
                let mut dense = vec![0f64; kept.len()];
                arms.pull_range_batch_panel(&panel, 23, 96, &mut dense);
                for (a, b) in scatter.iter().zip(&dense) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{storage:?} {order:?}");
                }
            }
        }
    }

    #[test]
    fn quant_means_are_within_recorded_error_of_f32_means() {
        // |lossy mean − true mean| ≤ row_err·‖q‖₁/N (+ float-eval slack):
        // the bias bound the two-tier index inflates its ε by.
        let mut rng = Rng::new(0xB1A5);
        let m = Matrix::from_fn(15, 128, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(128);
        let l1: f32 = q.iter().map(|x| x.abs()).sum();
        let f32_arms = MatrixArms::new(&m, &q, 16.0, PullOrder::Sequential, 3);
        for storage in [Storage::F16, Storage::Bf16, Storage::Int8] {
            let qm = QuantMatrix::quantize(&m, storage);
            let arms =
                QuantArms::new(&qm, &q, quant_bound(&qm, &q), PullOrder::Sequential, 3);
            for i in 0..15 {
                let bias = (qm.row_err(i) * l1) as f64 / 128.0;
                let gap = (arms.true_mean(i) - f32_arms.true_mean(i)).abs();
                assert!(
                    gap <= bias + 1e-6,
                    "{storage:?} arm {i}: gap {gap} > bias {bias}"
                );
            }
        }
    }

    #[test]
    fn quant_range_bounds_dequantized_rewards() {
        let mut rng = Rng::new(0x0B0E);
        let m = Matrix::from_fn(9, 40, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(40);
        for storage in [Storage::F16, Storage::Bf16, Storage::Int8] {
            let qm = QuantMatrix::quantize(&m, storage);
            let arms = QuantArms::new(&qm, &q, quant_bound(&qm, &q), PullOrder::Permuted, 1);
            let (a, b) = arms.reward_range();
            // quant_bound is tight (no manual slack like the f32 toy
            // test's 8.0), so allow one f32 product rounding of noise.
            let tol = b * 1e-6 + 1e-9;
            for i in 0..9 {
                for j in 0..40 {
                    let r = arms.pull_range(i, j, j + 1);
                    assert!(
                        r >= a - tol && r <= b + tol,
                        "{storage:?} reward {r} outside [{a},{b}]"
                    );
                }
            }
        }
    }
}
