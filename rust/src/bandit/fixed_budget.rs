//! Fixed-*budget* Best Arm Identification baselines: Successive Halving
//! (Karnin et al. 2013) and Successive Rejects (Audibert & Bubeck 2010).
//!
//! The paper's related-work section argues the fixed-budget setting
//! does not fit MIPS Motivation II (no (ε, δ) guarantee — the algorithm
//! spends a fixed pull budget and returns its best guess). These
//! implementations exist to *measure* that argument: the
//! `ablation_bandits` bench compares their suboptimality at the budget
//! BOUNDEDME chose for a given (ε, δ) against BOUNDEDME's guaranteed
//! result. Pulls are positional (without replacement, capped at `N`),
//! giving the fixed-budget algorithms the same MAB-BP advantage.

use super::arms::RewardSource;
use super::BanditResult;

/// Successive Halving with total pull budget `budget`.
///
/// `⌈log₂ n⌉` rounds; each round spends `budget / rounds` pulls spread
/// evenly over the surviving arms (cumulative per-arm pulls capped at
/// `N`), then keeps the better half (at least K).
pub fn successive_halving<R: RewardSource>(env: &R, k: usize, budget: u64) -> BanditResult {
    assert!(k >= 1);
    let n = env.n_arms();
    let n_list = env.list_len();
    let mut survivors: Vec<(u32, f64, usize)> =
        (0..n).map(|i| (i as u32, 0.0, 0usize)).collect(); // (id, sum, pulls)
    if n <= k {
        return BanditResult {
            arms: survivors.iter().map(|&(i, _, _)| i as usize).collect(),
            means: vec![0.0; n],
            total_pulls: 0,
            rounds: 0,
        };
    }
    let rounds = (n as f64 / k as f64).log2().ceil().max(1.0) as u32;
    let per_round = (budget / rounds as u64).max(1);
    let mut total_pulls = 0u64;
    let mut round = 0;

    while survivors.len() > k && round < rounds * 2 {
        round += 1;
        let per_arm = (per_round / survivors.len() as u64).max(1) as usize;
        for (id, sum, pulls) in survivors.iter_mut() {
            let from = *pulls;
            let to = (from + per_arm).min(n_list);
            if to > from {
                *sum += env.pull_range(*id as usize, from, to);
                total_pulls += (to - from) as u64;
                *pulls = to;
            }
        }
        // Keep the best half (>= k).
        let keep = (survivors.len() / 2).max(k);
        survivors.sort_by(|a, b| {
            let ma = a.1 / a.2.max(1) as f64;
            let mb = b.1 / b.2.max(1) as f64;
            mb.partial_cmp(&ma).unwrap_or(std::cmp::Ordering::Equal)
        });
        survivors.truncate(keep);
        // All arms exhausted: means are exact, finish.
        if survivors.iter().all(|&(_, _, p)| p >= n_list) {
            survivors.truncate(k);
            break;
        }
    }
    survivors.sort_by(|a, b| {
        let ma = a.1 / a.2.max(1) as f64;
        let mb = b.1 / b.2.max(1) as f64;
        mb.partial_cmp(&ma).unwrap_or(std::cmp::Ordering::Equal)
    });
    survivors.truncate(k);
    BanditResult {
        arms: survivors.iter().map(|&(i, _, _)| i as usize).collect(),
        means: survivors.iter().map(|&(_, s, p)| s / p.max(1) as f64).collect(),
        total_pulls,
        rounds: round,
    }
}

/// Successive Rejects (best-arm, K = 1) with total budget `budget`.
///
/// The classic phase schedule: `n − 1` phases; in phase `j` every
/// surviving arm is pulled up to `n_j = ⌈(budget − n)/ (loḡ(n)·(n+1−j))⌉`
/// cumulative pulls, then the worst arm is rejected.
pub fn successive_rejects<R: RewardSource>(env: &R, budget: u64) -> BanditResult {
    let n = env.n_arms();
    let n_list = env.list_len();
    if n == 1 {
        return BanditResult { arms: vec![0], means: vec![0.0], total_pulls: 0, rounds: 0 };
    }
    // log-bar(n) = 1/2 + Σ_{i=2..n} 1/i
    let logbar: f64 = 0.5 + (2..=n).map(|i| 1.0 / i as f64).sum::<f64>();
    let mut survivors: Vec<(u32, f64, usize)> =
        (0..n).map(|i| (i as u32, 0.0, 0usize)).collect();
    let mut total_pulls = 0u64;
    let mut prev_target = 0usize;

    for phase in 1..n {
        let target = (((budget.saturating_sub(n as u64)) as f64
            / (logbar * (n + 1 - phase) as f64))
            .ceil() as usize)
            .max(prev_target)
            .min(n_list);
        for (id, sum, pulls) in survivors.iter_mut() {
            let from = *pulls;
            let to = target.max(1).min(n_list);
            if to > from {
                *sum += env.pull_range(*id as usize, from, to);
                total_pulls += (to - from) as u64;
                *pulls = to;
            }
        }
        // Reject the worst arm.
        let worst = survivors
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                let ma = a.1 / a.2.max(1) as f64;
                let mb = b.1 / b.2.max(1) as f64;
                ma.partial_cmp(&mb).unwrap_or(std::cmp::Ordering::Equal)
            })
            .map(|(i, _)| i)
            .unwrap();
        survivors.swap_remove(worst);
        prev_target = target;
    }
    let (id, sum, pulls) = survivors[0];
    BanditResult {
        arms: vec![id as usize],
        means: vec![sum / pulls.max(1) as f64],
        total_pulls,
        rounds: (n - 1) as u32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::arms::ExplicitArms;

    fn staircase(n: usize, n_list: usize) -> ExplicitArms {
        ExplicitArms::new(
            (0..n).map(|i| vec![i as f64 / n as f64; n_list]).collect::<Vec<_>>(),
        )
        .with_range(0.0, 1.0)
    }

    #[test]
    fn halving_finds_best_with_ample_budget() {
        let env = staircase(64, 100);
        let res = successive_halving(&env, 1, 64 * 100);
        assert_eq!(res.arms, vec![63]);
        assert!(res.total_pulls <= 64 * 100);
    }

    #[test]
    fn halving_top_k() {
        let env = staircase(32, 50);
        let res = successive_halving(&env, 4, 32 * 50);
        let mut got = res.arms.clone();
        got.sort_unstable();
        assert_eq!(got, vec![28, 29, 30, 31]);
    }

    #[test]
    fn halving_respects_budget_roughly() {
        let env = staircase(100, 1000);
        let budget = 5000;
        let res = successive_halving(&env, 1, budget);
        // Per-round floors allow slight overshoot; stays within 2x.
        assert!(res.total_pulls <= 2 * budget, "{}", res.total_pulls);
    }

    #[test]
    fn rejects_finds_best() {
        let env = staircase(16, 200);
        let res = successive_rejects(&env, 16 * 200);
        assert_eq!(res.arms, vec![15]);
    }

    #[test]
    fn rejects_single_arm() {
        let env = staircase(1, 10);
        let res = successive_rejects(&env, 100);
        assert_eq!(res.arms, vec![0]);
    }

    #[test]
    fn smaller_budget_worse_or_equal() {
        // With a tiny budget the result may be wrong; with a huge budget
        // it must be right. (Statistical smoke check on one instance.)
        let env = staircase(64, 400);
        let rich = successive_halving(&env, 1, 64 * 400);
        assert_eq!(rich.arms, vec![63]);
        let poor = successive_halving(&env, 1, 64);
        assert!(poor.total_pulls < rich.total_pulls);
    }
}
