//! Classic Median Elimination (Even-Dar, Mannor & Mansour 2002).
//!
//! The algorithm BOUNDEDME extends: identical round structure, but
//! designed for i.i.d. rewards over an *infinite* population, so each
//! round samples **with replacement** and sizes the round with the
//! Hoeffding bound `t_l = ⌈(2/ε_l²)·log(3/δ_l)⌉` — which is unbounded in
//! `N` and explodes as ε → 0. Kept as the head-to-head ablation baseline
//! (bench `ablation_bandits`).

use super::arms::RewardSource;
use super::bounds::hoeffding_sample_size;
use super::BanditResult;
use crate::linalg::Rng;

/// Configuration for classic Median Elimination (top-K generalization,
/// mirroring BOUNDEDME's round schedule for a fair comparison).
#[derive(Clone, Copy, Debug)]
pub struct MedianElimConfig {
    /// Returned set size.
    pub k: usize,
    /// Suboptimality budget ε on mean rewards.
    pub epsilon: f64,
    /// Failure probability δ.
    pub delta: f64,
    /// Hard cap on per-arm pulls per round (guards wall-clock on small ε;
    /// `usize::MAX` = faithful algorithm).
    pub max_pulls_per_round: usize,
}

impl Default for MedianElimConfig {
    fn default() -> Self {
        Self { k: 1, epsilon: 0.1, delta: 0.1, max_pulls_per_round: usize::MAX }
    }
}

/// Run classic Median Elimination. Rewards are drawn i.i.d. (with
/// replacement) via [`RewardSource::pull_iid`]; each round uses fresh
/// samples, per the original algorithm.
pub fn median_elimination<R: RewardSource>(
    cfg: &MedianElimConfig,
    env: &R,
    rng: &mut Rng,
) -> BanditResult {
    assert!(cfg.k >= 1 && cfg.epsilon > 0.0 && cfg.delta > 0.0 && cfg.delta < 1.0);
    let range = env.range_width();
    let mut survivors: Vec<(u32, f64)> =
        (0..env.n_arms()).map(|i| (i as u32, 0.0)).collect();
    let mut eps_l = cfg.epsilon / 4.0;
    let mut delta_l = cfg.delta / 2.0;
    let mut total_pulls = 0u64;
    let mut rounds = 0u32;

    while survivors.len() > cfg.k {
        rounds += 1;
        // Hoeffding at radius ε_l/2, confidence δ_l/3 (the classic "3" of
        // Even-Dar et al.).
        let t_l = hoeffding_sample_size(eps_l / 2.0, delta_l / 3.0, range)
            .min(cfg.max_pulls_per_round);

        for (id, mean) in survivors.iter_mut() {
            let mut sum = 0.0;
            for _ in 0..t_l {
                sum += env.pull_iid(*id as usize, rng);
            }
            *mean = sum / t_l as f64;
        }
        total_pulls += (t_l * survivors.len()) as u64;

        let drop = (survivors.len() - cfg.k).div_ceil(2);
        survivors.select_nth_unstable_by(drop - 1, |a, b| {
            a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal)
        });
        survivors.drain(..drop);

        eps_l *= 0.75;
        delta_l *= 0.5;
    }

    survivors.sort_by(|a, b| {
        b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
    });
    BanditResult {
        arms: survivors.iter().map(|&(i, _)| i as usize).collect(),
        means: survivors.iter().map(|&(_, m)| m).collect(),
        total_pulls,
        rounds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::arms::ExplicitArms;

    #[test]
    fn identifies_clearly_best_arm() {
        let env = ExplicitArms::new(vec![vec![0.1; 50], vec![0.9; 50], vec![0.5; 50]])
            .with_range(0.0, 1.0);
        let mut rng = Rng::new(1);
        let cfg = MedianElimConfig { k: 1, epsilon: 0.2, delta: 0.1, ..Default::default() };
        let res = median_elimination(&cfg, &env, &mut rng);
        assert_eq!(res.arms, vec![1]);
        assert!(res.total_pulls > 0);
    }

    #[test]
    fn uses_far_more_pulls_than_bounded_me_for_small_eps() {
        // The paper's headline comparison: with-replacement Hoeffding
        // ignores the finite list, so its pull count dwarfs BOUNDEDME's
        // N-cap.
        let n_list = 200;
        let env = ExplicitArms::new(
            (0..16).map(|i| vec![i as f64 / 16.0; n_list]).collect::<Vec<_>>(),
        )
        .with_range(0.0, 1.0);
        let mut rng = Rng::new(2);
        let cfg =
            MedianElimConfig { k: 1, epsilon: 0.05, delta: 0.1, ..Default::default() };
        let me = median_elimination(&cfg, &env, &mut rng);
        let bme = crate::bandit::BoundedMe::new(crate::bandit::BoundedMeConfig {
            k: 1,
            epsilon: 0.05,
            delta: 0.1,
        })
        .run(&env);
        assert!(
            me.total_pulls > 5 * bme.result.total_pulls,
            "ME {} vs BoundedME {}",
            me.total_pulls,
            bme.result.total_pulls
        );
    }

    #[test]
    fn respects_round_cap() {
        let env = ExplicitArms::new(vec![vec![0.2; 10], vec![0.8; 10]]).with_range(0.0, 1.0);
        let mut rng = Rng::new(3);
        let cfg = MedianElimConfig {
            k: 1,
            epsilon: 0.01,
            delta: 0.05,
            max_pulls_per_round: 100,
        };
        let res = median_elimination(&cfg, &env, &mut rng);
        assert!(res.total_pulls <= 200);
    }
}
