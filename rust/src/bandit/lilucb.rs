//! lil'UCB (Jamieson, Malloy, Nowak & Bubeck 2014): best-arm (K = 1)
//! identification with a law-of-the-iterated-logarithm confidence bound.
//! Samples the highest-UCB arm until one arm has collected a constant
//! fraction of all pulls. i.i.d. baseline for `ablation_bandits`.

use super::arms::RewardSource;
use super::BanditResult;
use crate::linalg::Rng;

/// lil'UCB configuration (the paper's "lil'UCB heuristic" parameters:
/// ε = 0.01, β = 0.5, λ = 1 + 2/β).
#[derive(Clone, Copy, Debug)]
pub struct LilUcbConfig {
    /// Failure probability δ.
    pub delta: f64,
    /// Pulls per selection (batching).
    pub batch: usize,
    /// Safety cap on total pulls.
    pub max_total_pulls: u64,
}

impl Default for LilUcbConfig {
    fn default() -> Self {
        Self { delta: 0.1, batch: 16, max_total_pulls: u64::MAX }
    }
}

/// LIL exploration bonus with the heuristic constants.
fn lil_bonus(t: u64, delta: f64, range: f64) -> f64 {
    if t == 0 {
        return f64::INFINITY;
    }
    let eps = 0.01f64;
    let t_f = t as f64;
    let inner = ((1.0 + eps) * t_f).ln().max(1.0) / delta;
    let num = 2.0 * (1.0 + eps) * inner.ln().max(0.0);
    range * (num / t_f).sqrt()
}

/// Run lil'UCB; returns the single best arm.
pub fn lil_ucb<R: RewardSource>(cfg: &LilUcbConfig, env: &R, rng: &mut Rng) -> BanditResult {
    assert!(cfg.delta > 0.0 && cfg.delta < 1.0);
    let n = env.n_arms();
    let range = env.range_width();
    let lambda = 1.0 + 2.0 / 0.5; // λ = 1 + 2/β, β = 0.5
    let mut sums = vec![0.0f64; n];
    let mut pulls = vec![0u64; n];
    let mut total = 0u64;
    let mut rounds = 0u32;

    // One initial batch each.
    for i in 0..n {
        for _ in 0..cfg.batch {
            sums[i] += env.pull_iid(i, rng);
        }
        pulls[i] += cfg.batch as u64;
        total += cfg.batch as u64;
    }

    loop {
        rounds += 1;
        // Stopping: some arm holds ≥ λ/(1+λ) … classic form:
        // T_i(t) ≥ 1 + λ Σ_{j≠i} T_j(t).
        let argmax_pulled = (0..n).max_by_key(|&i| pulls[i]).unwrap();
        let others: u64 = total - pulls[argmax_pulled];
        if pulls[argmax_pulled] as f64 >= 1.0 + lambda * others as f64
            || total >= cfg.max_total_pulls
        {
            let best = (0..n)
                .max_by(|&a, &b| {
                    let ma = sums[a] / pulls[a].max(1) as f64;
                    let mb = sums[b] / pulls[b].max(1) as f64;
                    ma.partial_cmp(&mb).unwrap_or(std::cmp::Ordering::Equal)
                })
                .unwrap();
            return BanditResult {
                arms: vec![best],
                means: vec![sums[best] / pulls[best].max(1) as f64],
                total_pulls: total,
                rounds,
            };
        }

        // Pull the highest-UCB arm.
        let pick = (0..n)
            .max_by(|&a, &b| {
                let ua = sums[a] / pulls[a] as f64 + lil_bonus(pulls[a], cfg.delta, range);
                let ub = sums[b] / pulls[b] as f64 + lil_bonus(pulls[b], cfg.delta, range);
                ua.partial_cmp(&ub).unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap();
        for _ in 0..cfg.batch {
            sums[pick] += env.pull_iid(pick, rng);
        }
        pulls[pick] += cfg.batch as u64;
        total += cfg.batch as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::arms::ExplicitArms;

    #[test]
    fn finds_separated_best() {
        let env = ExplicitArms::new(vec![vec![0.2; 32], vec![0.8; 32], vec![0.3; 32]])
            .with_range(0.0, 1.0);
        let mut rng = Rng::new(1);
        let res = lil_ucb(&LilUcbConfig::default(), &env, &mut rng);
        assert_eq!(res.arms, vec![1]);
    }

    #[test]
    fn cap_fires_on_identical_arms() {
        let env = ExplicitArms::new(vec![vec![0.5; 8], vec![0.5; 8]]).with_range(0.0, 1.0);
        let mut rng = Rng::new(2);
        let cfg = LilUcbConfig { delta: 0.05, batch: 8, max_total_pulls: 5000 };
        let res = lil_ucb(&cfg, &env, &mut rng);
        assert!(res.total_pulls >= 5000 && res.total_pulls < 5100);
        assert_eq!(res.arms.len(), 1);
    }

    #[test]
    fn bonus_shrinks() {
        assert!(lil_bonus(10_000, 0.1, 1.0) < lil_bonus(10, 0.1, 1.0));
    }
}
