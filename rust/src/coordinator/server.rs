//! TCP front-end for the coordinator, speaking a **negotiated** wire
//! protocol (see [`crate::wire`]): every connection's first byte picks
//! its codec and the choice sticks for the connection's lifetime.
//!
//! * Anything that can start a JSON document (`{`, whitespace, …)
//!   selects the line-JSON codec — the original protocol, bit-for-bit,
//!   so existing clients need no changes.
//! * The frame magic's leading `b'P'` selects the binary codec:
//!   length-prefixed frames carrying either an embedded JSON document
//!   (every op below works unchanged over binary transport) or a raw
//!   little-endian f32 query batch that skips JSON entirely — at
//!   d = 4096 the decimal text of one vector costs more to parse than
//!   the SIMD scan that answers it.
//!
//! Line protocol (one JSON document per line; the same documents ride
//! `OP_JSON`/`RESP_JSON` frames over binary transport):
//!
//! ```text
//! → {"op":"query","vector":[…],"k":5,"epsilon":0.1,"delta":0.1,
//!    "mode":"bounded_me","deadline_ms":50,"budget_flops":100000,
//!    "storage":"f32"}
//! ← {"ok":true,"indices":[…],"scores":[…],"flops":123,"service_ms":0.8,"batch":4,
//!    "degraded":false,"epsilon_hat":0.0,"shards":1,"shards_total":1}
//! → {"op":"metrics"}
//! ← {"ok":true,"queries":10,"batches":4,"flops":…, "wire_binary":…, …}
//! → {"op":"mutate","upserts":[{"id":3,"vector":[…]}],"deletes":[7],
//!    "appends":[[…]]}
//! ← {"ok":true,"generation":1,"rows":200,"shards_rebuilt":1,
//!    "shards_reused":2,"delta_rows":3}
//! → {"op":"ping"}
//! ← {"ok":true,"pong":true}
//! → {"op":"trace","limit":8}
//! ← {"ok":true,"traces":[{"seq":…,"kind":"bounded_me","spans":[…]},…]}
//! → {"op":"metrics_prom"}
//! ← {"ok":true,"content_type":"text/plain; version=0.0.4","body":"# HELP …"}
//! ```
//!
//! The optional query `storage` field (`"f32"`/`"f16"`/`"bf16"`/
//! `"int8"`) requests a per-query sampling tier; resolution against the
//! deployment is [`super::resolve_storage`]'s. Binary query frames
//! carry the same override as a header byte.
//!
//! A binary `OP_QUERY` frame with B vectors is submitted as one group —
//! the batcher admits it whole — and answered by B `RESP_QUERY` frames
//! in request order.
//!
//! Errors come back as `{"ok":false,"error":"…"}` (or a `RESP_ERROR`
//! frame); malformed *documents* do not kill the connection, but
//! frame-level violations (bad magic, hostile length prefix) do — the
//! server replies once and closes, since resync inside a corrupt byte
//! stream is guesswork. One thread per connection (bounded by
//! `max_conns`).

use super::{Coordinator, CoordinatorError, QueryMode, QueryRequest};
use crate::data::generation::Delta;
use crate::data::quant::Storage;
use crate::jsonlite::{parse, Json};
use crate::wire::{
    self, binary, frame, Codec, FrameDecoder, QueryOpts, QueryReply, WireRequest,
};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Handle to a running TCP server.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving the coordinator on `bind_addr` (use port 0 for an
    /// ephemeral port; the actual address is [`Server::addr`]).
    pub fn start(
        coordinator: Arc<Coordinator>,
        bind_addr: &str,
        max_conns: usize,
    ) -> std::io::Result<Server> {
        let listener = TcpListener::bind(bind_addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let live = Arc::new(AtomicUsize::new(0));
        listener.set_nonblocking(true)?;
        let accept_thread = std::thread::Builder::new().name("mips-server".into()).spawn(
            move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if live.load(Ordering::Relaxed) >= max_conns {
                                let _ = reject(stream);
                                continue;
                            }
                            live.fetch_add(1, Ordering::Relaxed);
                            let coord = coordinator.clone();
                            let live2 = live.clone();
                            let stop3 = stop2.clone();
                            let _ = std::thread::Builder::new()
                                .name("mips-conn".into())
                                .spawn(move || {
                                    let _ = handle_conn(stream, &coord, &stop3);
                                    live2.fetch_sub(1, Ordering::Relaxed);
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            },
        )?;
        Ok(Server { addr, stop, accept_thread: Some(accept_thread) })
    }

    /// Address the server is listening on.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept loop (open connections finish
    /// their current request and close on next read).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Over-capacity rejection happens before negotiation, so it speaks the
/// line protocol (a binary client sees a failed magic and closes —
/// which is the point either way).
fn reject(mut stream: TcpStream) -> std::io::Result<()> {
    stream.write_all(b"{\"ok\":false,\"error\":\"too many connections\"}\n")
}

fn handle_conn(
    mut stream: TcpStream,
    coord: &Coordinator,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    // The codec is chosen lazily from the first byte received; until
    // then the connection has no protocol.
    let mut codec: Option<Box<dyn Codec + Send>> = None;
    let mut rbuf = vec![0u8; 16 * 1024];
    let mut out = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let n = match stream.read(&mut rbuf) {
            Ok(0) => return Ok(()), // client closed
            Ok(n) => n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue; // poll the stop flag
            }
            Err(_) => return Ok(()),
        };
        if codec.is_none() {
            codec = Some(wire::negotiate(rbuf[0]));
        }
        let c = codec.as_mut().expect("codec negotiated above");
        c.feed(&rbuf[..n]);
        loop {
            match c.try_decode() {
                Ok(Some(req)) => {
                    out.clear();
                    process_request(req, coord, c.as_mut(), &mut out);
                    writer.write_all(&out)?;
                }
                Ok(None) => break, // need more bytes
                Err(e) => {
                    // Frame-level violation: reply once, close.
                    out.clear();
                    c.encode_error(&format!("protocol error: {e}"), &mut out);
                    let _ = writer.write_all(&out);
                    return Ok(());
                }
            }
        }
    }
}

/// Serve one decoded wire request, appending the encoded replies.
fn process_request(
    req: WireRequest,
    coord: &Coordinator,
    codec: &mut dyn Codec,
    out: &mut Vec<u8>,
) {
    coord.record_wire(codec.name() == "binary");
    match req {
        WireRequest::Line(line) => {
            if line.is_empty() {
                return;
            }
            let resp = handle_line(&line, coord);
            codec.encode_json(&resp, out);
        }
        WireRequest::Query(requests) => {
            // Submit the whole batch before reaping any reply, so the
            // coordinator's batcher sees the frame as one group instead
            // of B lockstep singletons.
            let handles: Vec<_> =
                requests.into_iter().map(|r| coord.submit(r)).collect();
            for h in handles {
                match h {
                    Ok(rx) => match rx.recv() {
                        Ok(resp) => codec.encode_reply(&resp, out),
                        Err(_) => codec.encode_error("shutdown", out),
                    },
                    Err(CoordinatorError::QueueFull) => codec.encode_error("overloaded", out),
                    Err(e) => codec.encode_error(&e.to_string(), out),
                }
            }
        }
    }
}

fn err_response(msg: &str) -> Json {
    wire::error_json(msg)
}

/// Dispatch one request document (exposed for unit tests and reused by
/// both codecs' JSON paths).
pub fn handle_line(line: &str, coord: &Coordinator) -> Json {
    // Decode clock: parse + vector extraction are the protocol tax the
    // flight recorder's `decode` span reports for JSON-borne queries.
    let decode_t0 = Instant::now();
    let req = match parse(line) {
        Ok(v) => v,
        Err(e) => return err_response(&format!("bad json: {e}")),
    };
    match req.get("op").and_then(Json::as_str) {
        Some("ping") => Json::obj([("ok", Json::Bool(true)), ("pong", Json::Bool(true))]),
        Some("metrics") => {
            let m = coord.metrics();
            Json::obj([
                ("ok", Json::Bool(true)),
                ("queries", Json::Num(m.queries as f64)),
                ("batches", Json::Num(m.batches as f64)),
                ("flops", Json::Num(m.flops as f64)),
                ("mean_batch", Json::Num(m.mean_batch_size)),
                ("service_p50_ms", Json::Num(m.service.0 * 1e3)),
                ("service_p99_ms", Json::Num(m.service.2 * 1e3)),
                ("queue_p99_ms", Json::Num(m.queue_wait.2 * 1e3)),
                ("shed", Json::Num(m.shed as f64)),
                ("submitted", Json::Num(m.submitted as f64)),
                ("degraded", Json::Num(m.degraded as f64)),
                ("degraded_admitted", Json::Num(m.degraded_admitted as f64)),
                ("batch_items", Json::Num(m.batch_items as f64)),
                ("hedge_fired", Json::Num(m.hedge_fired as f64)),
                ("hedge_won", Json::Num(m.hedge_won as f64)),
                ("hedge_lost", Json::Num(m.hedge_lost as f64)),
                ("fast_path", Json::Num(m.fast_path as f64)),
                ("mutations", Json::Num(m.mutations as f64)),
                ("mutation_rows", Json::Num(m.mutation_rows as f64)),
                ("shed_superseded", Json::Num(m.shed_superseded as f64)),
                ("wire_json", Json::Num(m.wire_json as f64)),
                ("wire_binary", Json::Num(m.wire_binary as f64)),
                ("generation", Json::Num(coord.generation() as f64)),
                ("generations_alive", Json::Num(coord.generations_alive() as f64)),
            ])
        }
        Some("metrics_prom") => {
            let body = coord
                .metrics()
                .to_prometheus(coord.generation(), coord.generations_alive());
            Json::obj([
                ("ok", Json::Bool(true)),
                ("content_type", Json::Str("text/plain; version=0.0.4".into())),
                ("body", Json::Str(body)),
            ])
        }
        Some("trace") => {
            let limit = req.get("limit").and_then(Json::as_usize).unwrap_or(32);
            let traces: Vec<Json> =
                coord.traces(limit).iter().map(crate::trace::trace_to_json).collect();
            Json::obj([("ok", Json::Bool(true)), ("traces", Json::Arr(traces))])
        }
        Some("mutate") => {
            let mut deltas = Vec::new();
            if let Some(ups) = req.get("upserts") {
                let Json::Arr(items) = ups else {
                    return err_response("'upserts' must be an array");
                };
                for item in items {
                    let Some(id) = item.get("id").and_then(Json::as_usize) else {
                        return err_response("upsert needs an integer 'id'");
                    };
                    let Some(vector) = item.get("vector").and_then(Json::as_f32_vec) else {
                        return err_response("upsert needs a numeric 'vector'");
                    };
                    deltas.push(Delta::Upsert { id, vector });
                }
            }
            if let Some(dels) = req.get("deletes") {
                let Json::Arr(items) = dels else {
                    return err_response("'deletes' must be an array");
                };
                for item in items {
                    let Some(id) = item.as_usize() else {
                        return err_response("delete ids must be integers");
                    };
                    deltas.push(Delta::Delete { id });
                }
            }
            if let Some(apps) = req.get("appends") {
                let Json::Arr(items) = apps else {
                    return err_response("'appends' must be an array");
                };
                for item in items {
                    let Some(vector) = item.as_f32_vec() else {
                        return err_response("appends must be numeric vectors");
                    };
                    deltas.push(Delta::Append { vector });
                }
            }
            match coord.mutate(&deltas) {
                Ok(out) => Json::obj([
                    ("ok", Json::Bool(true)),
                    ("generation", Json::Num(out.generation as f64)),
                    ("rows", Json::Num(out.rows as f64)),
                    ("shards_rebuilt", Json::Num(out.shards_rebuilt as f64)),
                    ("shards_reused", Json::Num(out.shards_reused as f64)),
                    ("delta_rows", Json::Num(out.delta_rows as f64)),
                ]),
                Err(e) => err_response(&e.to_string()),
            }
        }
        Some("query") => {
            let Some(vector) = req.get("vector").and_then(Json::as_f32_vec) else {
                return err_response("missing or bad 'vector'");
            };
            let k = req.get("k").and_then(Json::as_usize).unwrap_or(10);
            let epsilon = req.get("epsilon").and_then(Json::as_f64).unwrap_or(0.1);
            let delta = req.get("delta").and_then(Json::as_f64).unwrap_or(0.1);
            let seed = req.get("seed").and_then(Json::as_usize).unwrap_or(0) as u64;
            let mode = match req.get("mode").and_then(Json::as_str) {
                None | Some("bounded_me") => QueryMode::BoundedMe,
                Some("exact") => QueryMode::Exact,
                Some("auto") => QueryMode::Auto,
                Some(other) => return err_response(&format!("unknown mode {other:?}")),
            };
            let storage = match req.get("storage").and_then(Json::as_str) {
                None => None,
                Some(label) => match Storage::from_label(label) {
                    Some(s) => Some(s),
                    None => return err_response(&format!("unknown storage {label:?}")),
                },
            };
            let deadline = req
                .get("deadline_ms")
                .and_then(Json::as_f64)
                .map(std::time::Duration::from_secs_f64)
                .map(|d| d / 1000);
            let budget_flops = req
                .get("budget_flops")
                .and_then(Json::as_usize)
                .filter(|&b| b > 0)
                .map(|b| b as u64);
            let decode_ns = decode_t0.elapsed().as_nanos() as u64;
            let qr = QueryRequest {
                vector,
                k,
                epsilon,
                delta,
                mode,
                seed,
                deadline,
                budget_flops,
                storage,
                decode_ns,
            };
            match coord.query_blocking(qr) {
                Ok(resp) if resp.shed => err_response("deadline exceeded (shed)"),
                Ok(resp) => wire::json::query_response_json(&resp),
                Err(CoordinatorError::QueueFull) => err_response("overloaded"),
                Err(e) => err_response(&e.to_string()),
            }
        }
        Some(other) => err_response(&format!("unknown op {other:?}")),
        None => err_response("missing 'op'"),
    }
}

/// Minimal blocking client for either wire codec (used by tests and the
/// serving example). [`Client::connect`] honors the
/// [`wire::WIRE_ENV`] pin (`RUST_PALLAS_WIRE=binary`), so the whole TCP
/// test battery runs over binary framing on the CI `wire` leg without a
/// single call-site change.
pub struct Client {
    transport: Transport,
}

enum Transport {
    Json { reader: BufReader<TcpStream>, writer: TcpStream },
    Binary { stream: TcpStream, dec: FrameDecoder },
}

impl Client {
    /// Connect with the codec the [`wire::WIRE_ENV`] pin selects
    /// (line-JSON unless pinned to binary).
    pub fn connect(addr: SocketAddr) -> std::io::Result<Client> {
        if wire::binary_env_requested() {
            Self::connect_binary(addr)
        } else {
            Self::connect_json(addr)
        }
    }

    /// Connect speaking newline-delimited JSON (the default protocol).
    pub fn connect_json(addr: SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client {
            transport: Transport::Json { reader: BufReader::new(stream), writer },
        })
    }

    /// Connect speaking the binary frame protocol (negotiated by the
    /// first frame's magic; nothing is sent until the first call).
    pub fn connect_binary(addr: SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { transport: Transport::Binary { stream, dec: FrameDecoder::new() } })
    }

    /// Whether this client speaks the binary codec.
    pub fn is_binary(&self) -> bool {
        matches!(self.transport, Transport::Binary { .. })
    }

    /// Send one request object, wait for the response document. Over
    /// binary transport the document rides an `OP_JSON` frame — every
    /// op works identically on either codec.
    pub fn call(&mut self, req: &Json) -> std::io::Result<Json> {
        match &mut self.transport {
            Transport::Json { reader, writer } => {
                writer.write_all(req.dump().as_bytes())?;
                writer.write_all(b"\n")?;
                let mut line = String::new();
                reader.read_line(&mut line)?;
                parse(line.trim()).map_err(|e| {
                    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
                })
            }
            Transport::Binary { stream, dec } => {
                let mut out = Vec::new();
                frame::encode_frame(frame::OP_JSON, req.dump().as_bytes(), &mut out);
                stream.write_all(&out)?;
                let (op, body) = read_frame(stream, dec)?;
                match op {
                    frame::RESP_JSON => {
                        parse(String::from_utf8_lossy(&body).trim()).map_err(|e| {
                            std::io::Error::new(
                                std::io::ErrorKind::InvalidData,
                                e.to_string(),
                            )
                        })
                    }
                    frame::RESP_ERROR => {
                        Ok(wire::error_json(&String::from_utf8_lossy(&body)))
                    }
                    _ => Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        "unexpected response op",
                    )),
                }
            }
        }
    }

    /// Convenience: a BOUNDEDME query (as a JSON document, on either
    /// codec).
    pub fn query(
        &mut self,
        vector: &[f32],
        k: usize,
        epsilon: f64,
        delta: f64,
    ) -> std::io::Result<Json> {
        self.call(&Json::obj([
            ("op", Json::Str("query".into())),
            ("vector", Json::f32s(vector)),
            ("k", Json::Num(k as f64)),
            ("epsilon", Json::Num(epsilon)),
            ("delta", Json::Num(delta)),
        ]))
    }

    /// Send `vectors` as **one** binary `OP_QUERY` frame (admitted by
    /// the coordinator as one batch group) and collect the per-vector
    /// replies, in request order. Requires a binary connection.
    pub fn query_binary(
        &mut self,
        vectors: &[&[f32]],
        opts: &QueryOpts,
    ) -> std::io::Result<Vec<QueryReply>> {
        let Transport::Binary { stream, dec } = &mut self.transport else {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "query_binary requires a binary connection (Client::connect_binary)",
            ));
        };
        let mut out = Vec::new();
        binary::encode_query_frame(vectors, opts, &mut out).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidInput, e.to_string())
        })?;
        stream.write_all(&out)?;
        let mut replies = Vec::with_capacity(vectors.len());
        for _ in 0..vectors.len() {
            let (op, body) = read_frame(stream, dec)?;
            replies.push(match op {
                frame::RESP_QUERY => binary::decode_reply(&body).map_err(|e| {
                    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
                })?,
                frame::RESP_ERROR => {
                    QueryReply::from_error(String::from_utf8_lossy(&body).into_owned())
                }
                _ => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        "unexpected response op",
                    ))
                }
            });
        }
        Ok(replies)
    }
}

/// Block until one complete frame arrives, returning it owned.
fn read_frame(
    stream: &mut TcpStream,
    dec: &mut FrameDecoder,
) -> std::io::Result<(u8, Vec<u8>)> {
    let mut tmp = [0u8; 16 * 1024];
    loop {
        match dec.try_frame() {
            Ok(Some(f)) => return Ok((f.op, f.body.to_vec())),
            Ok(None) => {}
            Err(e) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    e.to_string(),
                ))
            }
        }
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed mid-frame",
            ));
        }
        dec.feed(&tmp[..n]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::data::synthetic::gaussian_dataset;

    fn coordinator() -> Arc<Coordinator> {
        let ds = gaussian_dataset(100, 32, 1);
        Arc::new(
            Coordinator::new(ds.vectors, CoordinatorConfig::default()).unwrap(),
        )
    }

    #[test]
    fn handle_line_query_and_errors() {
        let coord = coordinator();
        let resp = handle_line(r#"{"op":"ping"}"#, &coord);
        assert_eq!(resp.get("pong").unwrap().as_bool(), Some(true));

        let q: Vec<String> = (0..32).map(|i| format!("{}", i as f32 * 0.1)).collect();
        let line = format!(
            r#"{{"op":"query","vector":[{}],"k":3,"epsilon":0.2,"delta":0.2}}"#,
            q.join(",")
        );
        let resp = handle_line(&line, &coord);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("indices").unwrap().as_f32_vec().unwrap().len(), 3);

        for bad in [
            "not json",
            r#"{"op":"nope"}"#,
            r#"{"op":"query"}"#,
            r#"{"op":"query","vector":[1,2]}"#, // dim mismatch
            r#"{}"#,
        ] {
            let resp = handle_line(bad, &coord);
            assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{bad}");
        }

        // Storage overrides: a known tier is accepted, junk is not.
        let line = format!(
            r#"{{"op":"query","vector":[{}],"k":3,"epsilon":0.2,"delta":0.2,"storage":"f32"}}"#,
            q.join(",")
        );
        let resp = handle_line(&line, &coord);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("storage").unwrap().as_str(), Some("f32"));
        let line = format!(
            r#"{{"op":"query","vector":[{}],"storage":"f8"}}"#,
            q.join(",")
        );
        let resp = handle_line(&line, &coord);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn handle_line_mutate_flips_and_serves() {
        let coord = coordinator();
        // Upsert row 0 and append one row, both to the all-ones spike;
        // delete row 5. The dataset has 100 rows, so afterwards the
        // appended row is id 99 (ids above the deletion shift down).
        let v: Vec<String> = (0..32).map(|_| "1".to_string()).collect();
        let v = v.join(",");
        let line = format!(
            r#"{{"op":"mutate","upserts":[{{"id":0,"vector":[{v}]}}],"deletes":[5],"appends":[[{v}]]}}"#
        );
        let resp = handle_line(&line, &coord);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
        assert_eq!(resp.get("generation").unwrap().as_usize(), Some(1));
        assert_eq!(resp.get("rows").unwrap().as_usize(), Some(100));
        assert_eq!(resp.get("delta_rows").unwrap().as_usize(), Some(3));

        // An exact query along the spike must surface both planted rows
        // on the new generation.
        let line = format!(r#"{{"op":"query","vector":[{v}],"k":2,"mode":"exact"}}"#);
        let resp = handle_line(&line, &coord);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("generation").unwrap().as_usize(), Some(1));
        let mut got: Vec<f32> = resp.get("indices").unwrap().as_f32_vec().unwrap();
        got.sort_by(f32::total_cmp);
        assert_eq!(got, vec![0.0, 99.0]);

        let m = handle_line(r#"{"op":"metrics"}"#, &coord);
        assert_eq!(m.get("generation").unwrap().as_usize(), Some(1));
        assert_eq!(m.get("mutations").unwrap().as_usize(), Some(1));
        assert_eq!(m.get("mutation_rows").unwrap().as_usize(), Some(3));
        assert_eq!(m.get("generations_alive").unwrap().as_usize(), Some(1));

        // Malformed or rejected batches answer ok:false and leave the
        // serving generation untouched.
        for bad in [
            r#"{"op":"mutate","upserts":[{"id":0}]}"#.to_string(),
            r#"{"op":"mutate","upserts":{"id":0}}"#.to_string(),
            r#"{"op":"mutate","deletes":["x"]}"#.to_string(),
            r#"{"op":"mutate","appends":[3]}"#.to_string(),
            format!(r#"{{"op":"mutate","upserts":[{{"id":5000,"vector":[{v}]}}]}}"#),
            r#"{"op":"mutate","appends":[[1,2]]}"#.to_string(), // dim mismatch
        ] {
            let resp = handle_line(&bad, &coord);
            assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{bad}");
        }
        let m = handle_line(r#"{"op":"metrics"}"#, &coord);
        assert_eq!(m.get("generation").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn metrics_op_exposes_full_field_set() {
        let coord = coordinator();
        let q: Vec<String> = (0..32).map(|i| format!("{}", i as f32 * 0.1)).collect();
        let line = format!(
            r#"{{"op":"query","vector":[{}],"k":3,"epsilon":0.2,"delta":0.2}}"#,
            q.join(",")
        );
        assert_eq!(handle_line(&line, &coord).get("ok").unwrap().as_bool(), Some(true));
        let m = handle_line(r#"{"op":"metrics"}"#, &coord);
        assert_eq!(m.get("ok").unwrap().as_bool(), Some(true));
        // The op's complete contract: every exported field present (a
        // missing field silently breaks downstream scrapers).
        for field in [
            "queries",
            "batches",
            "flops",
            "mean_batch",
            "service_p50_ms",
            "service_p99_ms",
            "queue_p99_ms",
            "shed",
            "submitted",
            "degraded",
            "degraded_admitted",
            "batch_items",
            "hedge_fired",
            "hedge_won",
            "hedge_lost",
            "fast_path",
            "mutations",
            "mutation_rows",
            "shed_superseded",
            "wire_json",
            "wire_binary",
            "generation",
            "generations_alive",
        ] {
            assert!(m.get(field).is_some(), "metrics op missing field {field:?}");
        }
        assert_eq!(m.get("queries").unwrap().as_usize(), Some(1));
        assert_eq!(m.get("batch_items").unwrap().as_usize(), Some(1));
        // No hedging configured: fired = won = lost = 0.
        assert_eq!(m.get("hedge_lost").unwrap().as_usize(), Some(0));
        assert_eq!(m.get("generations_alive").unwrap().as_usize(), Some(1));
        // handle_line was called in-process: no wire requests recorded.
        assert_eq!(m.get("wire_json").unwrap().as_usize(), Some(0));
        assert_eq!(m.get("wire_binary").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn metrics_prom_op_renders_exposition() {
        let coord = coordinator();
        let resp = handle_line(r#"{"op":"metrics_prom"}"#, &coord);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(
            resp.get("content_type").unwrap().as_str(),
            Some("text/plain; version=0.0.4")
        );
        let body = resp.get("body").unwrap().as_str().unwrap();
        assert!(body.contains("# TYPE pallas_queries_total counter"));
        assert!(body.contains("pallas_shard_dispatches_total{shard=\"0\"}"));
        assert!(body.contains("pallas_generation "));
        assert!(body.contains("pallas_wire_requests_total{codec=\"json\"}"));
    }

    #[test]
    fn trace_op_returns_empty_without_recorder_and_traces_with() {
        // Tracing off: the op answers ok with an empty list.
        let coord = coordinator();
        let resp = handle_line(r#"{"op":"trace"}"#, &coord);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let Json::Arr(traces) = resp.get("traces").unwrap() else {
            panic!("traces not an array");
        };
        assert!(traces.is_empty());

        // Tracing on (config switch): a served query shows up.
        let ds = gaussian_dataset(100, 32, 1);
        let cfg = CoordinatorConfig {
            trace: crate::trace::TraceConfig { enabled: true, ..Default::default() },
            ..Default::default()
        };
        let coord = Arc::new(Coordinator::new(ds.vectors, cfg).unwrap());
        let q: Vec<String> = (0..32).map(|i| format!("{}", i as f32 * 0.1)).collect();
        let line = format!(
            r#"{{"op":"query","vector":[{}],"k":3,"epsilon":0.2,"delta":0.2}}"#,
            q.join(",")
        );
        assert_eq!(handle_line(&line, &coord).get("ok").unwrap().as_bool(), Some(true));
        let resp = handle_line(r#"{"op":"trace","limit":4}"#, &coord);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let Json::Arr(traces) = resp.get("traces").unwrap() else {
            panic!("traces not an array");
        };
        assert_eq!(traces.len(), 1);
        let t = &traces[0];
        assert_eq!(t.get("kind").unwrap().as_str(), Some("bounded_me"));
        assert_eq!(t.get("k").unwrap().as_usize(), Some(3));
        let Json::Arr(spans) = t.get("spans").unwrap() else {
            panic!("spans not an array");
        };
        assert!(!spans.is_empty());
        // handle_line stamps a decode time, so the trace carries a
        // decode span ahead of the queue wait.
        assert!(
            spans.iter().any(|s| s.get("label").unwrap().as_str() == Some("decode")),
            "no decode span in {spans:?}"
        );
    }

    #[test]
    fn tcp_roundtrip() {
        let coord = coordinator();
        let server = Server::start(coord, "127.0.0.1:0", 4).unwrap();
        let addr = server.addr();

        let mut client = Client::connect(addr).unwrap();
        let pong = client.call(&Json::obj([("op", Json::Str("ping".into()))])).unwrap();
        assert_eq!(pong.get("pong").unwrap().as_bool(), Some(true));

        let v = vec![0.5f32; 32];
        let resp = client.query(&v, 5, 0.1, 0.1).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("indices").unwrap().as_f32_vec().unwrap().len(), 5);

        let metrics =
            client.call(&Json::obj([("op", Json::Str("metrics".into()))])).unwrap();
        assert!(metrics.get("queries").unwrap().as_usize().unwrap() >= 1);

        server.shutdown();
    }

    #[test]
    fn binary_transport_serves_json_ops_and_query_frames() {
        let coord = coordinator();
        let server = Server::start(coord, "127.0.0.1:0", 4).unwrap();
        let addr = server.addr();

        let mut bin = Client::connect_binary(addr).unwrap();
        assert!(bin.is_binary());
        // JSON ops ride OP_JSON frames transparently.
        let pong = bin.call(&Json::obj([("op", Json::Str("ping".into()))])).unwrap();
        assert_eq!(pong.get("pong").unwrap().as_bool(), Some(true));

        // A binary query frame answers with typed replies.
        let v = vec![0.5f32; 32];
        let replies = bin
            .query_binary(
                &[&v],
                &QueryOpts { k: 5, epsilon: 0.1, delta: 0.1, ..Default::default() },
            )
            .unwrap();
        assert_eq!(replies.len(), 1);
        assert!(replies[0].ok, "{:?}", replies[0].error);
        assert_eq!(replies[0].indices.len(), 5);

        // Both codecs were recorded against the wire counters.
        let m = bin.call(&Json::obj([("op", Json::Str("metrics".into()))])).unwrap();
        assert!(m.get("wire_binary").unwrap().as_usize().unwrap() >= 2);

        // A JSON client coexists on the same server.
        let mut js = Client::connect_json(addr).unwrap();
        let resp = js.query(&v, 5, 0.1, 0.1).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));

        server.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let coord = coordinator();
        let server = Server::start(coord, "127.0.0.1:0", 8).unwrap();
        let addr = server.addr();
        let mut handles = Vec::new();
        for t in 0..4 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for i in 0..5 {
                    let v = vec![(t * 5 + i) as f32 * 0.01; 32];
                    let r = c.query(&v, 2, 0.3, 0.2).unwrap();
                    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        server.shutdown();
    }
}
