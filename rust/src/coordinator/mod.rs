//! The serving coordinator: router → dynamic batcher → worker pool.
//!
//! The paper's Motivation II — a per-query (ε, δ) accuracy knob — is a
//! *serving* feature: different requests on one index want different
//! points on the accuracy/latency curve. This module provides that as a
//! production-shaped service:
//!
//! ```text
//!  submit() ──► bounded queue ──► batcher (size/deadline policy)
//!                                    │ batches
//!                                    ▼
//!                              shard router (shed + Auto planning,
//!                              once per query, before fan-out)
//!                               │ fan-out: one ShardBatch per shard
//!                  ┌────────────┼────────────┐
//!                  ▼            ▼            ▼
//!             shard-0 workers   …       shard-S−1 workers
//!             (ScoringEngine + BoundedME over their shard)
//!                  └──── partial top-K ──────┘
//!                               ▼
//!               last-shard-completes merge (TopK, stable
//!               id tie-break) ─► per-request channels + metrics
//! ```
//!
//! * **Backpressure**: the submit queue is bounded; `submit` fails fast
//!   with [`CoordinatorError::QueueFull`] instead of buffering unbounded.
//! * **Dynamic batching**: a batch closes when it reaches
//!   `max_batch` or when the oldest request has waited `batch_timeout` —
//!   and workers *execute* it as a batch, not just receive it as one:
//!   each worker owns a long-lived [`QueryContext`] plus an Arc-backed
//!   [`BoundedMeIndex`], exact queries of a batch go through **one**
//!   [`ScoringEngine::score_dataset_batch`] call (fused row-major scan /
//!   device-resident scoring), and BOUNDEDME queries of a batch share
//!   one block-shuffled coordinate permutation via
//!   [`crate::algos::MipsIndex::query_batch`].
//! * **Sharding**: with [`CoordinatorConfig::shard`] set to `S ≥ 2`
//!   shards, workers are *shard-pinned* (worker `w` serves shard `w mod
//!   S`) and the router fans every batch out to all shards. Exact items
//!   run one per-shard [`ScoringEngine::score_dataset_batch`]; BOUNDEDME
//!   items run per-shard at the `(ε, δ/S)` split from
//!   [`crate::exec::shard::shard_params`] and are exactly rescored
//!   before the merge (sample-then-confirm — see [`crate::exec::shard`]
//!   for why the union keeps the (ε, δ) guarantee). The last shard to
//!   finish a query merges and replies.
//! * **Backends**: workers score through a [`ScoringEngine`] — pure-Rust
//!   or the PJRT AOT artifact (see [`crate::runtime`]).
//! * **Planning**: [`QueryMode::Auto`] requests are resolved by the
//!   router, **once per query before fan-out** — knobs too tight for
//!   sampling to win go straight to the exact engine, and every shard
//!   sees the same decision (plans depend on `dim`, which sharding
//!   never splits).

pub mod server;
pub mod stats;

pub use stats::{MetricsRegistry, MetricsSnapshot};

use crate::algos::{BoundedMeIndex, MipsIndex, MipsParams, MipsResult};
use crate::bandit::PullOrder;
use crate::data::shard::{Shard, ShardSpec, ShardedMatrix};
use crate::exec::shard::{shard_params, ShardPartial};
use crate::exec::{PlanAlgo, QueryContext, QueryPlan};
use crate::linalg::{Matrix, TopK};
use crate::runtime::{NativeEngine, PjrtEngine, ScoringEngine};
use crate::sync::{bounded, Receiver, RecvError, SendError, Sender};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Which compute backend workers use for exact scoring.
#[derive(Clone, Debug)]
pub enum Backend {
    /// Pure-Rust dot products.
    Native,
    /// AOT-compiled XLA artifacts loaded from this directory.
    Pjrt {
        /// Directory containing `*.hlo.txt` artifacts.
        artifact_dir: PathBuf,
    },
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads.
    pub workers: usize,
    /// Maximum queries per batch.
    pub max_batch: usize,
    /// Maximum time the oldest request waits before its batch closes.
    pub batch_timeout: Duration,
    /// Router queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Exact-scoring backend.
    pub backend: Backend,
    /// Pull order for BOUNDEDME queries. `BlockShuffled(0)` (the
    /// default) means "planner-chosen": the coordinator substitutes
    /// [`QueryPlan::block_width`] for the dataset's dimension at
    /// startup.
    pub pull_order: PullOrder,
    /// Dataset sharding across the worker pool (see
    /// [`crate::data::shard`]). The default is a single shard —
    /// identical behavior to the unsharded coordinator. With `S ≥ 2`
    /// shards the worker count is raised to at least `S` so every shard
    /// has a pinned worker.
    pub shard: ShardSpec,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 32,
            batch_timeout: Duration::from_millis(2),
            queue_capacity: 1024,
            backend: Backend::Native,
            pull_order: PullOrder::BlockShuffled(0),
            shard: ShardSpec::single(),
        }
    }
}

/// How a request wants to be answered.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryMode {
    /// BOUNDEDME with the request's (ε, δ).
    BoundedMe,
    /// Exhaustive exact scoring through the backend engine.
    Exact,
    /// Let [`QueryPlan`] decide per query from `(k, ε, δ, dim)`: knobs
    /// tight enough that sampling cannot beat a scan run exact.
    Auto,
}

/// One MIPS request.
#[derive(Clone, Debug)]
pub struct QueryRequest {
    /// The query vector (must match the dataset dimension).
    pub vector: Vec<f32>,
    /// Result count.
    pub k: usize,
    /// BOUNDEDME suboptimality budget.
    pub epsilon: f64,
    /// BOUNDEDME failure probability.
    pub delta: f64,
    /// Answer mode.
    pub mode: QueryMode,
    /// Pull-order seed. When a dynamic batch of BOUNDEDME requests has
    /// uniform (k, ε, δ), the batch is *fused*: the first request's
    /// seed keys one shared coordinate permutation for the whole batch
    /// (that sharing is what makes batching fuse compute). Requests
    /// with heterogeneous knobs are served individually with their own
    /// seeds.
    pub seed: u64,
    /// Optional service-level deadline, measured from submission. A
    /// request whose queue wait already exceeds it is *shed* (answered
    /// with `shed = true` and no results) instead of wasting worker
    /// time — classic load-shedding under overload.
    pub deadline: Option<Duration>,
}

impl QueryRequest {
    /// A BOUNDEDME request with the given knobs.
    pub fn bounded_me(vector: Vec<f32>, k: usize, epsilon: f64, delta: f64) -> Self {
        Self { vector, k, epsilon, delta, mode: QueryMode::BoundedMe, seed: 0, deadline: None }
    }

    /// Attach a deadline (see [`QueryRequest::deadline`]).
    pub fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// A planner-routed request: [`QueryPlan`] picks exact vs BOUNDEDME
    /// from the knobs at execution time.
    pub fn auto(vector: Vec<f32>, k: usize, epsilon: f64, delta: f64) -> Self {
        Self { vector, k, epsilon, delta, mode: QueryMode::Auto, seed: 0, deadline: None }
    }

    /// An exact request.
    pub fn exact(vector: Vec<f32>, k: usize) -> Self {
        Self {
            vector,
            k,
            epsilon: 0.0,
            delta: 0.5,
            mode: QueryMode::Exact,
            seed: 0,
            deadline: None,
        }
    }
}

/// A completed response.
#[derive(Clone, Debug)]
pub struct QueryResponse {
    /// Result indices, best first.
    pub indices: Vec<usize>,
    /// Scores, best first. Exact-mode answers always carry exact inner
    /// products. BOUNDEDME answers carry the bandit's estimates
    /// (`N·p̂`) on an unsharded coordinator, but **exact rescored**
    /// inner products on a sharded one (`S ≥ 2`) — the
    /// sample-then-confirm merge ranks on true products (see
    /// [`crate::exec::shard`]). Don't compare raw BOUNDEDME score
    /// values across deployments with different shard counts.
    pub scores: Vec<f32>,
    /// Flops spent.
    pub flops: u64,
    /// Queue wait from submission to *router* pickup. Time spent
    /// waiting in a backed-up per-shard channel after fan-out is
    /// accounted in `service`, not here.
    pub queue_wait: Duration,
    /// Time from shard fan-out to the merged reply (includes any
    /// shard-channel wait plus the slowest shard's compute).
    pub service: Duration,
    /// Size of the batch this query rode in.
    pub batch_size: usize,
    /// Worker id that served it (under sharding: the worker whose shard
    /// finished last and performed the merge). `usize::MAX` when no
    /// worker touched the request (shed by the router).
    pub worker: usize,
    /// True when the request was shed (deadline exceeded in queue): no
    /// results were computed.
    pub shed: bool,
    /// Shard partials merged into this answer (1 when unsharded, 0 for
    /// shed requests — they never reached a shard).
    pub shards: usize,
}

/// Submission failures.
#[derive(Debug)]
pub enum CoordinatorError {
    /// The bounded router queue is full (backpressure).
    QueueFull,
    /// The coordinator is shutting down.
    Shutdown,
    /// The query vector dimension does not match the dataset.
    DimMismatch {
        /// Dimension received.
        got: usize,
        /// Dimension expected.
        want: usize,
    },
}

impl std::fmt::Display for CoordinatorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::QueueFull => write!(f, "router queue full"),
            Self::Shutdown => write!(f, "coordinator shut down"),
            Self::DimMismatch { got, want } => {
                write!(f, "query dim {got} != dataset dim {want}")
            }
        }
    }
}

impl std::error::Error for CoordinatorError {}

struct Pending {
    req: QueryRequest,
    submitted: Instant,
    reply: Sender<QueryResponse>,
}

struct Batch {
    items: Vec<Pending>,
}

/// The serving coordinator. See module docs.
pub struct Coordinator {
    submit_tx: Sender<Pending>,
    metrics: Arc<MetricsRegistry>,
    dim: usize,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the coordinator over a vector set, split per
    /// [`CoordinatorConfig::shard`].
    pub fn new(data: Matrix, cfg: CoordinatorConfig) -> crate::Result<Self> {
        assert!(cfg.workers >= 1 && cfg.max_batch >= 1);
        let dim = data.cols();
        let sharded = Arc::new(ShardedMatrix::new(data, cfg.shard));
        let n_shards = sharded.num_shards();
        // Every shard needs at least one pinned worker; extra workers
        // round-robin across shards.
        let workers = cfg.workers.max(n_shards);
        let metrics = Arc::new(MetricsRegistry::new());
        let (submit_tx, submit_rx) = bounded::<Pending>(cfg.queue_capacity);
        let (batch_tx, batch_rx) = bounded::<Batch>(workers * 2);

        let mut threads = Vec::new();

        // Batcher thread.
        {
            let cfg2 = cfg.clone();
            let metrics = metrics.clone();
            threads.push(
                std::thread::Builder::new().name("batcher".into()).spawn(move || {
                    run_batcher(submit_rx, batch_tx, &cfg2, &metrics)
                })?,
            );
        }

        // Shard router thread: sheds, resolves Auto plans once per
        // query, and fans each batch out to every shard's channel.
        let mut shard_txs = Vec::with_capacity(n_shards);
        let mut shard_rxs = Vec::with_capacity(n_shards);
        let per_shard_cap = (workers / n_shards).max(1) * 2;
        for _ in 0..n_shards {
            let (tx, rx) = bounded::<ShardBatch>(per_shard_cap);
            shard_txs.push(tx);
            shard_rxs.push(rx);
        }
        {
            let metrics = metrics.clone();
            threads.push(
                std::thread::Builder::new().name("shard-router".into()).spawn(move || {
                    run_router(batch_rx, shard_txs, dim, &metrics)
                })?,
            );
        }

        // Shard-pinned worker threads: worker `w` serves shard `w mod
        // S`. The per-shard colmax scan is shared across that shard's
        // workers; shard matrices share storage with the backing data
        // (contiguous) so per-worker state stays one O(dim) colmax copy
        // plus the long-lived QueryContext.
        let colmaxes: Vec<Arc<Vec<f32>>> = sharded
            .shards()
            .iter()
            .map(|s| Arc::new(crate::algos::bounded_me_index::column_maxima(s.matrix())))
            .collect();
        // `BlockShuffled(0)` = planner-chosen width for this dimension.
        let order = match cfg.pull_order {
            PullOrder::BlockShuffled(0) => PullOrder::BlockShuffled(QueryPlan::block_width(dim)),
            o => o,
        };
        for w in 0..workers {
            let shard_id = w % n_shards;
            let rx = shard_rxs[shard_id].clone();
            let sharded = sharded.clone();
            let colmax = colmaxes[shard_id].clone();
            let metrics = metrics.clone();
            let backend = cfg.backend.clone();
            threads.push(std::thread::Builder::new().name(format!("worker-{w}")).spawn(
                move || {
                    let shard = sharded.shard(shard_id);
                    let engine: Box<dyn ScoringEngine> = match &backend {
                        Backend::Native => Box::new(NativeEngine),
                        Backend::Pjrt { artifact_dir } => {
                            // Preload this worker's shard to the device so
                            // exact queries only move the query vector.
                            match PjrtEngine::with_dataset(artifact_dir.clone(), shard.matrix())
                            {
                                Ok(e) => Box::new(e),
                                Err(err) => {
                                    crate::logkit::error!(
                                        "worker-{w}: pjrt init failed ({err}); \
                                         falling back to native"
                                    );
                                    Box::new(NativeEngine)
                                }
                            }
                        }
                    };
                    let index = BoundedMeIndex::from_parts(
                        shard.matrix().clone(),
                        colmax.as_ref().clone(),
                        order,
                    );
                    run_shard_worker(
                        w,
                        n_shards,
                        rx,
                        &index,
                        shard,
                        engine.as_ref(),
                        &metrics,
                    );
                },
            )?);
        }

        Ok(Self { submit_tx, metrics, dim, threads })
    }

    /// Submit a request; returns the response channel. Fails fast under
    /// backpressure.
    pub fn submit(
        &self,
        req: QueryRequest,
    ) -> Result<Receiver<QueryResponse>, CoordinatorError> {
        if req.vector.len() != self.dim {
            return Err(CoordinatorError::DimMismatch { got: req.vector.len(), want: self.dim });
        }
        let (reply, rx) = bounded(1);
        let pending = Pending { req, submitted: Instant::now(), reply };
        self.submit_tx.try_send(pending).map_err(|e| match e {
            SendError::Full(_) => CoordinatorError::QueueFull,
            SendError::Disconnected(_) => CoordinatorError::Shutdown,
        })?;
        Ok(rx)
    }

    /// Submit and wait for the answer.
    pub fn query_blocking(&self, req: QueryRequest) -> Result<QueryResponse, CoordinatorError> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| CoordinatorError::Shutdown)
    }

    /// Current metrics snapshot.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Dataset dimension served.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Drain and stop all threads.
    pub fn shutdown(mut self) {
        drop(self.submit_tx);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Batcher loop: close a batch on size or oldest-waiter deadline.
fn run_batcher(
    submit_rx: Receiver<Pending>,
    batch_tx: Sender<Batch>,
    cfg: &CoordinatorConfig,
    metrics: &MetricsRegistry,
) {
    loop {
        // Block for the batch's first element.
        let first = match submit_rx.recv() {
            Ok(p) => p,
            Err(_) => return, // all senders gone: shutdown
        };
        let deadline = first.submitted + cfg.batch_timeout;
        let mut items = vec![first];
        while items.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match submit_rx.recv_timeout(deadline - now) {
                Ok(p) => items.push(p),
                Err(RecvError::Timeout) => break,
                Err(RecvError::Disconnected) => {
                    // Flush what we have, then exit on next loop.
                    break;
                }
            }
        }
        metrics.record_batch(items.len());
        if batch_tx.send(Batch { items }).is_err() {
            return;
        }
    }
}

/// A query in flight across the shard fan-out: the resolved request,
/// the merge accumulator, and the reply route. Shared by `Arc` between
/// the router and every shard's workers.
struct InFlight {
    vector: Vec<f32>,
    k: usize,
    epsilon: f64,
    delta: f64,
    seed: u64,
    /// Post-plan mode: `Exact` or `BoundedMe`, never `Auto` (the router
    /// resolved it before fan-out).
    mode: QueryMode,
    queue_wait: Duration,
    batch_size: usize,
    /// Original submission instant — workers re-check `deadline`
    /// against it at shard pickup (a query can expire while sitting in
    /// a backed-up shard channel after passing the router's check).
    submitted: Instant,
    /// Service-level deadline, measured from submission.
    deadline: Option<Duration>,
    /// Fan-out instant; the merging worker measures service from it.
    started: Instant,
    reply: Sender<QueryResponse>,
    merge: Mutex<Merge>,
}

/// Cross-shard merge accumulator: partial top-K entries from each shard
/// fold into one [`TopK`] (stable global-id tie-break, so the result is
/// independent of which shard finishes first). The worker that drops
/// `remaining` to zero builds and sends the reply.
struct Merge {
    top: TopK,
    flops: u64,
    remaining: usize,
    /// Set when any shard saw the item's deadline expired at pickup;
    /// the finisher then replies `shed = true` (empty results) instead
    /// of a merged answer.
    shed: bool,
}

/// One dynamic batch, routed to one shard (every shard receives its own
/// `ShardBatch` holding the same `Arc`'d items).
struct ShardBatch {
    items: Vec<Arc<InFlight>>,
}

/// Router loop: for each dynamic batch, shed expired items, resolve
/// [`QueryMode::Auto`] through [`QueryPlan`] **once per query**, then
/// fan the batch out to every shard's channel.
fn run_router(
    batch_rx: Receiver<Batch>,
    shard_txs: Vec<Sender<ShardBatch>>,
    dim: usize,
    metrics: &MetricsRegistry,
) {
    let n_shards = shard_txs.len();
    while let Ok(batch) = batch_rx.recv() {
        let picked_up = Instant::now();
        let batch_size = batch.items.len();
        let mut items: Vec<Arc<InFlight>> = Vec::with_capacity(batch_size);
        for pending in batch.items {
            let queue_wait = picked_up - pending.submitted;
            // Load shedding: don't fan out answers nobody is waiting for.
            if let Some(deadline) = pending.req.deadline {
                if queue_wait > deadline {
                    metrics.record_shed();
                    let _ = pending.reply.send(QueryResponse {
                        indices: Vec::new(),
                        scores: Vec::new(),
                        flops: 0,
                        queue_wait,
                        service: Duration::ZERO,
                        batch_size,
                        worker: usize::MAX, // shed by the router, no worker involved
                        shed: true,
                        shards: 0,
                    });
                    continue;
                }
            }
            let req = pending.req;
            let mode = match req.mode {
                QueryMode::Auto => {
                    match QueryPlan::pick(req.k, req.epsilon, req.delta, dim).algo {
                        PlanAlgo::Exact => QueryMode::Exact,
                        PlanAlgo::BoundedMe => QueryMode::BoundedMe,
                    }
                }
                m => m,
            };
            // BOUNDEDME always returns ≥ 1 result (the index clamps k);
            // the merge cap must match or it would drop that result.
            let top_k = match mode {
                QueryMode::Exact => req.k,
                _ => req.k.max(1),
            };
            items.push(Arc::new(InFlight {
                vector: req.vector,
                k: req.k,
                epsilon: req.epsilon,
                delta: req.delta,
                seed: req.seed,
                mode,
                queue_wait,
                batch_size,
                submitted: pending.submitted,
                deadline: req.deadline,
                started: Instant::now(),
                reply: pending.reply,
                merge: Mutex::new(Merge {
                    top: TopK::new(top_k),
                    flops: 0,
                    remaining: n_shards,
                    shed: false,
                }),
            }));
        }
        if items.is_empty() {
            continue;
        }
        for tx in &shard_txs {
            if tx.send(ShardBatch { items: items.clone() }).is_err() {
                return;
            }
        }
    }
}

/// Fold one shard's partial into an item's merge; the worker whose
/// partial completes the fan-out builds and sends the reply. `expired`
/// marks this shard's contribution as a deadline-expiry observation
/// (flags the whole merge as shed).
fn complete(
    item: &Arc<InFlight>,
    partial: ShardPartial,
    n_shards: usize,
    worker_id: usize,
    metrics: &MetricsRegistry,
    expired: bool,
) {
    let finished = {
        let mut m = item.merge.lock().unwrap();
        m.shed |= expired;
        m.flops += partial.flops;
        for (score, id) in partial.entries {
            m.top.push(score, id);
        }
        m.remaining -= 1;
        if m.remaining == 0 {
            let top = std::mem::replace(&mut m.top, TopK::new(0));
            Some((top.into_sorted(), m.flops, m.shed))
        } else {
            None
        }
    };
    if let Some((ranked, flops, was_shed)) = finished {
        let service = item.started.elapsed();
        if was_shed {
            // Some shard saw the deadline expired at pickup: the client
            // has timed out, reply shed (no results; `flops` reports
            // whatever work other shards had already sunk).
            metrics.record_shed();
            let _ = item.reply.send(QueryResponse {
                indices: Vec::new(),
                scores: Vec::new(),
                flops,
                queue_wait: item.queue_wait,
                service,
                batch_size: item.batch_size,
                worker: worker_id,
                shed: true,
                shards: 0,
            });
            return;
        }
        metrics.record_query(item.queue_wait, service, flops);
        let _ = item.reply.send(QueryResponse {
            indices: ranked.iter().map(|&(_, i)| i).collect(),
            scores: ranked.iter().map(|&(s, _)| s).collect(),
            flops,
            queue_wait: item.queue_wait,
            service,
            batch_size: item.batch_size,
            worker: worker_id,
            shed: false,
            shards: n_shards,
        });
    }
}

/// A shard worker noticed the item's deadline expired while it waited
/// in the shard channel: contribute an empty partial flagged as shed
/// (keeping the `remaining` countdown correct so exactly one worker
/// replies).
fn complete_shed(
    item: &Arc<InFlight>,
    n_shards: usize,
    worker_id: usize,
    metrics: &MetricsRegistry,
) {
    let empty = ShardPartial { entries: Vec::new(), flops: 0, scanned: 0 };
    complete(item, empty, n_shards, worker_id, metrics, true);
}

/// Send a fully-formed single-shard result directly (the `S = 1`
/// BOUNDEDME path, bit-identical to the pre-sharding coordinator: the
/// bandit's own ranking and estimate scores pass through untouched).
fn respond_direct(
    item: &Arc<InFlight>,
    result: MipsResult,
    worker_id: usize,
    metrics: &MetricsRegistry,
) {
    let service = item.started.elapsed();
    metrics.record_query(item.queue_wait, service, result.flops);
    let _ = item.reply.send(QueryResponse {
        indices: result.indices,
        scores: result.scores,
        flops: result.flops,
        queue_wait: item.queue_wait,
        service,
        batch_size: item.batch_size,
        worker: worker_id,
        shed: false,
        shards: 1,
    });
}

/// Shard-pinned worker loop: one long-lived [`QueryContext`], batches
/// executed through the fused execution core against this shard only.
fn run_shard_worker(
    worker_id: usize,
    n_shards: usize,
    rx: Receiver<ShardBatch>,
    index: &BoundedMeIndex,
    shard: &Shard,
    engine: &dyn ScoringEngine,
    metrics: &MetricsRegistry,
) {
    let mut ctx = QueryContext::new();
    while let Ok(batch) = rx.recv() {
        serve_shard_batch(worker_id, n_shards, batch, index, shard, engine, &mut ctx, metrics);
    }
}

/// Execute one shard's slice of a dynamic batch:
///
/// 1. exact items: **one** [`ScoringEngine::score_dataset_batch`] call
///    over the shard for the whole group (fused scan / device-resident),
///    then per-query top-K partials from the shared score slab under
///    dataset-global ids;
/// 2. BOUNDEDME items: with `S = 1`, the legacy fused paths
///    ([`MipsIndex::query_batch`] when knobs are uniform, else
///    [`MipsIndex::query_with`]) replying directly; with `S ≥ 2`, the
///    sample-then-confirm entry point
///    [`BoundedMeIndex::query_batch_shard`] at the per-shard
///    `(ε, δ/S)` split — either way the context's cached pull order
///    means the batch shares one coordinate permutation (keyed by the
///    first item's seed).
#[allow(clippy::too_many_arguments)]
fn serve_shard_batch(
    worker_id: usize,
    n_shards: usize,
    batch: ShardBatch,
    index: &BoundedMeIndex,
    shard: &Shard,
    engine: &dyn ScoringEngine,
    ctx: &mut QueryContext,
    metrics: &MetricsRegistry,
) {
    let data = index.data();
    let (rows, dim) = (data.rows(), data.cols());

    let mut exact: Vec<&Arc<InFlight>> = Vec::new();
    let mut bme: Vec<&Arc<InFlight>> = Vec::new();
    for item in &batch.items {
        // Re-check the deadline at shard pickup: the router's check can
        // be long past by the time a backed-up shard channel drains,
        // and computing an answer the client timed out on wastes a full
        // shard scan (× S shards).
        if let Some(deadline) = item.deadline {
            if item.submitted.elapsed() > deadline {
                complete_shed(item, n_shards, worker_id, metrics);
                continue;
            }
        }
        match item.mode {
            QueryMode::Exact => exact.push(item),
            _ => bme.push(item),
        }
    }

    // --- Exact group: one engine call for the whole group. ---
    if !exact.is_empty() {
        let queries: Vec<&[f32]> = exact.iter().map(|it| it.vector.as_slice()).collect();
        let fused_ok = engine.score_dataset_batch(data, &queries, &mut ctx.rank.scores).is_ok();
        for (gi, item) in exact.iter().enumerate() {
            let mut top = TopK::new(item.k);
            if fused_ok {
                let slab = &ctx.rank.scores[gi * rows..(gi + 1) * rows];
                for (i, &s) in slab.iter().enumerate() {
                    top.push(s, shard.global_id(i));
                }
            } else {
                // Engine failure (e.g. backend died): pure-Rust fallback.
                let scores = data.matvec(&item.vector);
                for (i, &s) in scores.iter().enumerate() {
                    top.push(s, shard.global_id(i));
                }
            }
            let partial = ShardPartial {
                entries: top.into_sorted(),
                flops: (rows * dim) as u64,
                scanned: rows,
            };
            complete(item, partial, n_shards, worker_id, metrics, false);
        }
    }

    // --- BOUNDEDME group: shared permutation, fused when uniform. ---
    if bme.is_empty() {
        return;
    }
    let knobs = |it: &Arc<InFlight>| (it.k, it.epsilon.to_bits(), it.delta.to_bits());
    let uniform = bme.windows(2).all(|w| knobs(w[0]) == knobs(w[1]));
    if n_shards == 1 {
        // Unsharded: legacy semantics (estimate scores, no confirm).
        if uniform && bme.len() > 1 {
            // The first item's seed keys the batch's shared pull order.
            let first = bme[0];
            let params = MipsParams {
                k: first.k,
                epsilon: first.epsilon,
                delta: first.delta,
                seed: first.seed,
            };
            let queries: Vec<&[f32]> = bme.iter().map(|it| it.vector.as_slice()).collect();
            let results = index.query_batch(&queries, &params, ctx);
            for (item, result) in bme.iter().zip(results) {
                respond_direct(item, result, worker_id, metrics);
            }
        } else {
            for item in &bme {
                let params = MipsParams {
                    k: item.k,
                    epsilon: item.epsilon,
                    delta: item.delta,
                    seed: item.seed,
                };
                let result = index.query_with(&item.vector, &params, ctx);
                respond_direct(item, result, worker_id, metrics);
            }
        }
        return;
    }
    // Sharded: per-shard (ε, δ/S) sample + exact confirm, merged by the
    // last shard to finish.
    if uniform && bme.len() > 1 {
        let first = bme[0];
        let params = MipsParams {
            k: first.k,
            epsilon: first.epsilon,
            delta: first.delta,
            seed: first.seed,
        };
        let split = shard_params(&params, n_shards, shard.rows());
        let queries: Vec<&[f32]> = bme.iter().map(|it| it.vector.as_slice()).collect();
        let partials = index.query_batch_shard(&queries, &split, ctx, shard);
        for (item, partial) in bme.iter().zip(partials) {
            complete(item, partial, n_shards, worker_id, metrics, false);
        }
    } else {
        for item in &bme {
            let params = MipsParams {
                k: item.k,
                epsilon: item.epsilon,
                delta: item.delta,
                seed: item.seed,
            };
            let split = shard_params(&params, n_shards, shard.rows());
            let partial = index
                .query_batch_shard(&[item.vector.as_slice()], &split, ctx, shard)
                .pop()
                .expect("one partial per query");
            complete(item, partial, n_shards, worker_id, metrics, false);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::gaussian_dataset;

    fn small_coordinator(workers: usize, queue: usize) -> (Coordinator, Matrix) {
        let ds = gaussian_dataset(200, 64, 42);
        let cfg = CoordinatorConfig {
            workers,
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: queue,
            backend: Backend::Native,
            pull_order: PullOrder::BlockShuffled(16),
            shard: ShardSpec::single(),
        };
        let data = ds.vectors.clone();
        (Coordinator::new(ds.vectors, cfg).unwrap(), data)
    }

    #[test]
    fn exact_query_round_trips() {
        let (c, data) = small_coordinator(2, 64);
        let q = vec![0.5f32; 64];
        let resp = c.query_blocking(QueryRequest::exact(q.clone(), 5)).unwrap();
        assert_eq!(resp.indices.len(), 5);
        let truth = crate::algos::ground_truth(&data, &q, 5);
        assert_eq!(resp.indices, truth);
        c.shutdown();
    }

    #[test]
    fn bounded_me_query_served() {
        let (c, data) = small_coordinator(1, 64);
        let q = vec![0.25f32; 64];
        let resp = c
            .query_blocking(QueryRequest::bounded_me(q.clone(), 3, 1e-9, 0.05))
            .unwrap();
        // ε→0 ⇒ exact elimination.
        let mut got = resp.indices.clone();
        got.sort_unstable();
        let mut want = crate::algos::ground_truth(&data, &q, 3);
        want.sort_unstable();
        assert_eq!(got, want);
        assert!(resp.flops <= (200 * 64) as u64);
        c.shutdown();
    }

    #[test]
    fn dim_mismatch_rejected() {
        let (c, _) = small_coordinator(1, 8);
        let Err(err) = c.submit(QueryRequest::exact(vec![0.0; 3], 1)) else {
            panic!("expected DimMismatch");
        };
        assert!(matches!(err, CoordinatorError::DimMismatch { got: 3, want: 64 }));
        c.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let (c, _) = small_coordinator(4, 256);
        let mut handles = Vec::new();
        for i in 0..64u64 {
            let q = vec![(i as f32 % 7.0) - 3.0; 64];
            handles.push(c.submit(QueryRequest::bounded_me(q, 2, 0.3, 0.2)).unwrap());
        }
        for h in handles {
            let resp = h.recv().unwrap();
            assert_eq!(resp.indices.len(), 2);
        }
        let snap = c.metrics();
        assert_eq!(snap.queries, 64);
        assert!(snap.mean_batch_size >= 1.0);
        c.shutdown();
    }

    #[test]
    fn auto_mode_routes_and_answers() {
        let (c, data) = small_coordinator(2, 128);
        // Tight knobs on a 64-dim dataset: the plan routes to Exact, so
        // the answer must be the exact top-k.
        let q = vec![0.4f32; 64];
        let resp = c.query_blocking(QueryRequest::auto(q.clone(), 4, 1e-12, 0.05)).unwrap();
        assert_eq!(resp.indices, crate::algos::ground_truth(&data, &q, 4));
        // Loose knobs: still a valid 4-set (BOUNDEDME path).
        let resp = c.query_blocking(QueryRequest::auto(q, 4, 0.5, 0.3)).unwrap();
        assert_eq!(resp.indices.len(), 4);
        c.shutdown();
    }

    #[test]
    fn batched_exact_queries_stay_exact() {
        // Force real batches of mixed exact queries and check every
        // answer against ground truth — the fused score_dataset_batch
        // path must be indistinguishable from per-query scoring.
        let ds = gaussian_dataset(150, 48, 12);
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 16,
            batch_timeout: Duration::from_millis(20),
            queue_capacity: 256,
            backend: Backend::Native,
            pull_order: PullOrder::Sequential,
            shard: ShardSpec::single(),
        };
        let data = ds.vectors.clone();
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        let mut handles = Vec::new();
        let mut queries = Vec::new();
        for i in 0..24u64 {
            let mut q = vec![0.0f32; 48];
            q[(i as usize) % 48] = 1.0;
            q[(i as usize * 7) % 48] = -0.5;
            queries.push(q.clone());
            handles.push(c.submit(QueryRequest::exact(q, 3)).unwrap());
        }
        let mut max_batch_seen = 0;
        for (h, q) in handles.into_iter().zip(&queries) {
            let resp = h.recv().unwrap();
            max_batch_seen = max_batch_seen.max(resp.batch_size);
            assert_eq!(resp.indices, crate::algos::ground_truth(&data, q, 3));
        }
        assert!(max_batch_seen > 1, "no batching under burst load");
        c.shutdown();
    }

    #[test]
    fn batched_bounded_me_matches_index_results() {
        // Uniform knobs + burst ⇒ the worker takes the query_batch path
        // with the first item's seed; with ε→0 every answer must still
        // be the exact top-k set.
        let ds = gaussian_dataset(120, 64, 13);
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 8,
            batch_timeout: Duration::from_millis(20),
            queue_capacity: 256,
            backend: Backend::Native,
            pull_order: PullOrder::BlockShuffled(16),
            shard: ShardSpec::single(),
        };
        let data = ds.vectors.clone();
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        let mut handles = Vec::new();
        let mut queries = Vec::new();
        for i in 0..16u64 {
            let q: Vec<f32> = (0..64).map(|j| ((i + j) % 5) as f32 - 2.0).collect();
            queries.push(q.clone());
            handles.push(c.submit(QueryRequest::bounded_me(q, 3, 1e-9, 0.05)).unwrap());
        }
        for (h, q) in handles.into_iter().zip(&queries) {
            let resp = h.recv().unwrap();
            let mut got = resp.indices.clone();
            got.sort_unstable();
            let mut want = crate::algos::ground_truth(&data, q, 3);
            want.sort_unstable();
            assert_eq!(got, want);
        }
        c.shutdown();
    }

    #[test]
    fn backpressure_fires_when_queue_full() {
        // Queue of 1, zero workers draining fast: spam submissions until
        // QueueFull appears.
        let ds = gaussian_dataset(2000, 128, 7);
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 1,
            batch_timeout: Duration::from_millis(0),
            queue_capacity: 2,
            backend: Backend::Native,
            pull_order: PullOrder::Sequential,
            shard: ShardSpec::single(),
        };
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        let mut saw_full = false;
        let mut receivers = Vec::new();
        for _ in 0..2000 {
            match c.submit(QueryRequest::exact(vec![0.1; 128], 1)) {
                Ok(rx) => receivers.push(rx),
                Err(CoordinatorError::QueueFull) => {
                    saw_full = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(saw_full, "backpressure never engaged");
        for rx in receivers {
            let _ = rx.recv();
        }
        c.shutdown();
    }

    #[test]
    fn sharded_coordinator_matches_ground_truth() {
        let ds = gaussian_dataset(101, 64, 33);
        let cfg = CoordinatorConfig {
            workers: 3,
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: 128,
            backend: Backend::Native,
            pull_order: PullOrder::BlockShuffled(16),
            shard: ShardSpec::contiguous(3),
        };
        let data = ds.vectors.clone();
        let q = ds.sample_query(2);
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        let resp = c.query_blocking(QueryRequest::exact(q.clone(), 5)).unwrap();
        assert_eq!(resp.shards, 3);
        assert_eq!(resp.indices, crate::algos::ground_truth(&data, &q, 5));
        // BOUNDEDME ε→0 through sample-then-confirm: per-shard exact
        // elimination + exact rescore ⇒ the merged answer is the exact
        // top-k in exact order.
        let resp =
            c.query_blocking(QueryRequest::bounded_me(q.clone(), 4, 1e-9, 0.1)).unwrap();
        assert_eq!(resp.indices, crate::algos::ground_truth(&data, &q, 4));
        assert_eq!(resp.shards, 3);
        c.shutdown();
    }

    #[test]
    fn batches_form_under_load() {
        let ds = gaussian_dataset(100, 32, 9);
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 16,
            batch_timeout: Duration::from_millis(20),
            queue_capacity: 512,
            backend: Backend::Native,
            pull_order: PullOrder::Sequential,
            shard: ShardSpec::single(),
        };
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        let mut handles = Vec::new();
        for _ in 0..32 {
            handles.push(c.submit(QueryRequest::exact(vec![0.2; 32], 1)).unwrap());
        }
        let mut max_batch_seen = 0;
        for h in handles {
            max_batch_seen = max_batch_seen.max(h.recv().unwrap().batch_size);
        }
        assert!(max_batch_seen > 1, "no batching under burst load");
        c.shutdown();
    }
}

#[cfg(test)]
mod deadline_tests {
    use super::*;
    use crate::data::synthetic::gaussian_dataset;

    #[test]
    fn expired_deadline_sheds() {
        // One slow worker, queue fills, deadlines of 0ns: everything past
        // the first batch is shed.
        let ds = gaussian_dataset(500, 256, 21);
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 4,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: 512,
            backend: Backend::Native,
            pull_order: PullOrder::Sequential,
            shard: ShardSpec::single(),
        };
        let c = Coordinator::new(ds.vectors.clone(), cfg).unwrap();
        let mut rxs = Vec::new();
        for _ in 0..64 {
            let req = QueryRequest::exact(vec![0.3; 256], 3)
                .with_deadline(Duration::from_nanos(1));
            rxs.push(c.submit(req).unwrap());
        }
        let mut shed = 0;
        let mut served = 0;
        for rx in rxs {
            let resp = rx.recv().unwrap();
            if resp.shed {
                assert!(resp.indices.is_empty());
                shed += 1;
            } else {
                assert_eq!(resp.indices.len(), 3);
                served += 1;
            }
        }
        assert_eq!(shed + served, 64);
        assert!(shed > 0, "nothing shed under a 1ns deadline");
        assert_eq!(c.metrics().shed, shed);
        c.shutdown();
    }

    #[test]
    fn generous_deadline_never_sheds() {
        let ds = gaussian_dataset(50, 32, 22);
        let c = Coordinator::new(ds.vectors.clone(), CoordinatorConfig::default()).unwrap();
        for _ in 0..10 {
            let req = QueryRequest::bounded_me(vec![0.1; 32], 2, 0.2, 0.2)
                .with_deadline(Duration::from_secs(30));
            let resp = c.query_blocking(req).unwrap();
            assert!(!resp.shed);
            assert_eq!(resp.indices.len(), 2);
        }
        assert_eq!(c.metrics().shed, 0);
        c.shutdown();
    }
}
