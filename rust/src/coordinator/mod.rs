//! The serving coordinator: router → dynamic batcher → worker pool.
//!
//! The paper's Motivation II — a per-query (ε, δ) accuracy knob — is a
//! *serving* feature: different requests on one index want different
//! points on the accuracy/latency curve. This module provides that as a
//! production-shaped service:
//!
//! ```text
//!  submit() ──► bounded router queue ──► batcher (size/deadline policy)
//!                                          │ batches
//!                                          ▼
//!                                   worker pool (each owns a
//!                                   ScoringEngine + BoundedME state)
//!                                          │ responses
//!                                          ▼
//!                                   per-request channels + metrics
//! ```
//!
//! * **Backpressure**: the router queue is bounded; `submit` fails fast
//!   with [`CoordinatorError::QueueFull`] instead of buffering unbounded.
//! * **Dynamic batching**: a batch closes when it reaches
//!   `max_batch` or when the oldest request has waited `batch_timeout`.
//! * **Backends**: workers score through a [`ScoringEngine`] — pure-Rust
//!   or the PJRT AOT artifact (see [`crate::runtime`]).

pub mod server;
pub mod stats;

pub use stats::{MetricsRegistry, MetricsSnapshot};

use crate::algos::MipsResult;
use crate::bandit::{BoundedMe, BoundedMeConfig, MatrixArms, PullOrder, RewardSource};
use crate::linalg::{Matrix, TopK};
use crate::runtime::{NativeEngine, PjrtEngine, ScoringEngine};
use crate::sync::{bounded, Receiver, RecvError, SendError, Sender};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Which compute backend workers use for exact scoring.
#[derive(Clone, Debug)]
pub enum Backend {
    /// Pure-Rust dot products.
    Native,
    /// AOT-compiled XLA artifacts loaded from this directory.
    Pjrt {
        /// Directory containing `*.hlo.txt` artifacts.
        artifact_dir: PathBuf,
    },
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads.
    pub workers: usize,
    /// Maximum queries per batch.
    pub max_batch: usize,
    /// Maximum time the oldest request waits before its batch closes.
    pub batch_timeout: Duration,
    /// Router queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Exact-scoring backend.
    pub backend: Backend,
    /// Pull order for BOUNDEDME queries.
    pub pull_order: PullOrder,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 32,
            batch_timeout: Duration::from_millis(2),
            queue_capacity: 1024,
            backend: Backend::Native,
            pull_order: PullOrder::BlockShuffled(64),
        }
    }
}

/// How a request wants to be answered.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryMode {
    /// BOUNDEDME with the request's (ε, δ).
    BoundedMe,
    /// Exhaustive exact scoring through the backend engine.
    Exact,
}

/// One MIPS request.
#[derive(Clone, Debug)]
pub struct QueryRequest {
    /// The query vector (must match the dataset dimension).
    pub vector: Vec<f32>,
    /// Result count.
    pub k: usize,
    /// BOUNDEDME suboptimality budget.
    pub epsilon: f64,
    /// BOUNDEDME failure probability.
    pub delta: f64,
    /// Answer mode.
    pub mode: QueryMode,
    /// Per-query seed (pull-order randomness).
    pub seed: u64,
    /// Optional service-level deadline, measured from submission. A
    /// request whose queue wait already exceeds it is *shed* (answered
    /// with `shed = true` and no results) instead of wasting worker
    /// time — classic load-shedding under overload.
    pub deadline: Option<Duration>,
}

impl QueryRequest {
    /// A BOUNDEDME request with the given knobs.
    pub fn bounded_me(vector: Vec<f32>, k: usize, epsilon: f64, delta: f64) -> Self {
        Self { vector, k, epsilon, delta, mode: QueryMode::BoundedMe, seed: 0, deadline: None }
    }

    /// Attach a deadline (see [`QueryRequest::deadline`]).
    pub fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// An exact request.
    pub fn exact(vector: Vec<f32>, k: usize) -> Self {
        Self {
            vector,
            k,
            epsilon: 0.0,
            delta: 0.5,
            mode: QueryMode::Exact,
            seed: 0,
            deadline: None,
        }
    }
}

/// A completed response.
#[derive(Clone, Debug)]
pub struct QueryResponse {
    /// Result indices, best first.
    pub indices: Vec<usize>,
    /// Score estimates.
    pub scores: Vec<f32>,
    /// Flops spent.
    pub flops: u64,
    /// Queue wait before a worker picked the batch up.
    pub queue_wait: Duration,
    /// Service time inside the worker.
    pub service: Duration,
    /// Size of the batch this query rode in.
    pub batch_size: usize,
    /// Worker id that served it.
    pub worker: usize,
    /// True when the request was shed (deadline exceeded in queue): no
    /// results were computed.
    pub shed: bool,
}

/// Submission failures.
#[derive(Debug)]
pub enum CoordinatorError {
    /// The bounded router queue is full (backpressure).
    QueueFull,
    /// The coordinator is shutting down.
    Shutdown,
    /// The query vector dimension does not match the dataset.
    DimMismatch {
        /// Dimension received.
        got: usize,
        /// Dimension expected.
        want: usize,
    },
}

impl std::fmt::Display for CoordinatorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::QueueFull => write!(f, "router queue full"),
            Self::Shutdown => write!(f, "coordinator shut down"),
            Self::DimMismatch { got, want } => {
                write!(f, "query dim {got} != dataset dim {want}")
            }
        }
    }
}

impl std::error::Error for CoordinatorError {}

struct Pending {
    req: QueryRequest,
    submitted: Instant,
    reply: Sender<QueryResponse>,
}

struct Batch {
    items: Vec<Pending>,
}

/// The serving coordinator. See module docs.
pub struct Coordinator {
    submit_tx: Sender<Pending>,
    metrics: Arc<MetricsRegistry>,
    dim: usize,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the coordinator over a vector set.
    pub fn new(data: Matrix, cfg: CoordinatorConfig) -> crate::Result<Self> {
        assert!(cfg.workers >= 1 && cfg.max_batch >= 1);
        let dim = data.cols();
        let data = Arc::new(data);
        let metrics = Arc::new(MetricsRegistry::new());
        let (submit_tx, submit_rx) = bounded::<Pending>(cfg.queue_capacity);
        let (batch_tx, batch_rx) = bounded::<Batch>(cfg.workers * 2);

        let mut threads = Vec::new();

        // Batcher thread.
        {
            let cfg2 = cfg.clone();
            let metrics = metrics.clone();
            threads.push(
                std::thread::Builder::new().name("batcher".into()).spawn(move || {
                    run_batcher(submit_rx, batch_tx, &cfg2, &metrics)
                })?,
            );
        }

        // Worker threads.
        let colmax = Arc::new(crate::algos::bounded_me_index::column_maxima(&data));
        for w in 0..cfg.workers {
            let rx = batch_rx.clone();
            let data = data.clone();
            let colmax = colmax.clone();
            let metrics = metrics.clone();
            let backend = cfg.backend.clone();
            let order = cfg.pull_order;
            threads.push(std::thread::Builder::new().name(format!("worker-{w}")).spawn(
                move || {
                    let engine: Box<dyn ScoringEngine> = match &backend {
                        Backend::Native => Box::new(NativeEngine),
                        Backend::Pjrt { artifact_dir } => {
                            // Preload the dataset to the device so exact
                            // queries only move the query vector.
                            match PjrtEngine::with_dataset(artifact_dir.clone(), &data) {
                                Ok(e) => Box::new(e),
                                Err(err) => {
                                    log::error!(
                                        "worker-{w}: pjrt init failed ({err}); \
                                         falling back to native"
                                    );
                                    Box::new(NativeEngine)
                                }
                            }
                        }
                    };
                    run_worker(w, rx, &data, &colmax, order, engine.as_ref(), &metrics);
                },
            )?);
        }

        Ok(Self { submit_tx, metrics, dim, threads })
    }

    /// Submit a request; returns the response channel. Fails fast under
    /// backpressure.
    pub fn submit(
        &self,
        req: QueryRequest,
    ) -> Result<Receiver<QueryResponse>, CoordinatorError> {
        if req.vector.len() != self.dim {
            return Err(CoordinatorError::DimMismatch { got: req.vector.len(), want: self.dim });
        }
        let (reply, rx) = bounded(1);
        let pending = Pending { req, submitted: Instant::now(), reply };
        self.submit_tx.try_send(pending).map_err(|e| match e {
            SendError::Full(_) => CoordinatorError::QueueFull,
            SendError::Disconnected(_) => CoordinatorError::Shutdown,
        })?;
        Ok(rx)
    }

    /// Submit and wait for the answer.
    pub fn query_blocking(&self, req: QueryRequest) -> Result<QueryResponse, CoordinatorError> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| CoordinatorError::Shutdown)
    }

    /// Current metrics snapshot.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Dataset dimension served.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Drain and stop all threads.
    pub fn shutdown(mut self) {
        drop(self.submit_tx);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Batcher loop: close a batch on size or oldest-waiter deadline.
fn run_batcher(
    submit_rx: Receiver<Pending>,
    batch_tx: Sender<Batch>,
    cfg: &CoordinatorConfig,
    metrics: &MetricsRegistry,
) {
    loop {
        // Block for the batch's first element.
        let first = match submit_rx.recv() {
            Ok(p) => p,
            Err(_) => return, // all senders gone: shutdown
        };
        let deadline = first.submitted + cfg.batch_timeout;
        let mut items = vec![first];
        while items.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match submit_rx.recv_timeout(deadline - now) {
                Ok(p) => items.push(p),
                Err(RecvError::Timeout) => break,
                Err(RecvError::Disconnected) => {
                    // Flush what we have, then exit on next loop.
                    break;
                }
            }
        }
        metrics.record_batch(items.len());
        if batch_tx.send(Batch { items }).is_err() {
            return;
        }
    }
}

/// Worker loop: serve every query of every batch.
fn run_worker(
    worker_id: usize,
    rx: Receiver<Batch>,
    data: &Matrix,
    colmax: &[f32],
    order: PullOrder,
    engine: &dyn ScoringEngine,
    metrics: &MetricsRegistry,
) {
    let all_ids: Vec<usize> = (0..data.rows()).collect();
    while let Ok(batch) = rx.recv() {
        let batch_size = batch.items.len();
        for p in batch.items {
            let picked_up = Instant::now();
            let queue_wait = picked_up - p.submitted;
            // Load shedding: don't compute answers nobody is waiting for.
            if let Some(deadline) = p.req.deadline {
                if queue_wait > deadline {
                    metrics.record_shed();
                    let _ = p.reply.send(QueryResponse {
                        indices: Vec::new(),
                        scores: Vec::new(),
                        flops: 0,
                        queue_wait,
                        service: Duration::ZERO,
                        batch_size,
                        worker: worker_id,
                        shed: true,
                    });
                    continue;
                }
            }
            let result = serve_one(&p.req, data, colmax, order, engine, &all_ids);
            let service = picked_up.elapsed();
            metrics.record_query(queue_wait, service, result.flops);
            let _ = p.reply.send(QueryResponse {
                indices: result.indices,
                scores: result.scores,
                flops: result.flops,
                queue_wait,
                service,
                batch_size,
                worker: worker_id,
                shed: false,
            });
        }
    }
}

/// Serve a single query on a worker.
fn serve_one(
    req: &QueryRequest,
    data: &Matrix,
    colmax: &[f32],
    order: PullOrder,
    engine: &dyn ScoringEngine,
    all_ids: &[usize],
) -> MipsResult {
    match req.mode {
        QueryMode::Exact => {
            let _ = all_ids;
            let scores = engine
                .score_dataset(data, &req.vector)
                .unwrap_or_else(|_| data.matvec(&req.vector));
            let mut top = TopK::new(req.k);
            for (i, &s) in scores.iter().enumerate() {
                top.push(s, i);
            }
            let ranked = top.into_sorted();
            MipsResult {
                indices: ranked.iter().map(|&(_, i)| i).collect(),
                scores: ranked.iter().map(|&(s, _)| s).collect(),
                flops: (data.rows() * data.cols()) as u64,
                candidates: data.rows(),
            }
        }
        QueryMode::BoundedMe => {
            // Tight per-query reward bound from column maxima.
            let bound = colmax
                .iter()
                .zip(&req.vector)
                .fold(f32::MIN_POSITIVE, |m, (&c, &qj)| m.max(c * qj.abs()));
            let arms = MatrixArms::new(data, &req.vector, bound, order, req.seed);
            let n_list = arms.list_len() as f64;
            // ε is range-relative (see `BoundedMeIndex::query`).
            let eff_epsilon = req.epsilon * arms.range_width();
            let algo = BoundedMe::new(BoundedMeConfig {
                k: req.k.max(1),
                epsilon: eff_epsilon.max(1e-12),
                delta: req.delta.clamp(1e-12, 1.0 - 1e-12),
            });
            let out = algo.run(&arms);
            MipsResult {
                indices: out.result.arms,
                scores: out.result.means.iter().map(|&m| (m * n_list) as f32).collect(),
                flops: out.result.total_pulls,
                candidates: 0,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::gaussian_dataset;

    fn small_coordinator(workers: usize, queue: usize) -> (Coordinator, Matrix) {
        let ds = gaussian_dataset(200, 64, 42);
        let cfg = CoordinatorConfig {
            workers,
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: queue,
            backend: Backend::Native,
            pull_order: PullOrder::BlockShuffled(16),
        };
        let data = ds.vectors.clone();
        (Coordinator::new(ds.vectors, cfg).unwrap(), data)
    }

    #[test]
    fn exact_query_round_trips() {
        let (c, data) = small_coordinator(2, 64);
        let q = vec![0.5f32; 64];
        let resp = c.query_blocking(QueryRequest::exact(q.clone(), 5)).unwrap();
        assert_eq!(resp.indices.len(), 5);
        let truth = crate::algos::ground_truth(&data, &q, 5);
        assert_eq!(resp.indices, truth);
        c.shutdown();
    }

    #[test]
    fn bounded_me_query_served() {
        let (c, data) = small_coordinator(1, 64);
        let q = vec![0.25f32; 64];
        let resp = c
            .query_blocking(QueryRequest::bounded_me(q.clone(), 3, 1e-9, 0.05))
            .unwrap();
        // ε→0 ⇒ exact elimination.
        let mut got = resp.indices.clone();
        got.sort_unstable();
        let mut want = crate::algos::ground_truth(&data, &q, 3);
        want.sort_unstable();
        assert_eq!(got, want);
        assert!(resp.flops <= (200 * 64) as u64);
        c.shutdown();
    }

    #[test]
    fn dim_mismatch_rejected() {
        let (c, _) = small_coordinator(1, 8);
        let Err(err) = c.submit(QueryRequest::exact(vec![0.0; 3], 1)) else {
            panic!("expected DimMismatch");
        };
        assert!(matches!(err, CoordinatorError::DimMismatch { got: 3, want: 64 }));
        c.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let (c, _) = small_coordinator(4, 256);
        let mut handles = Vec::new();
        for i in 0..64u64 {
            let q = vec![(i as f32 % 7.0) - 3.0; 64];
            handles.push(c.submit(QueryRequest::bounded_me(q, 2, 0.3, 0.2)).unwrap());
        }
        for h in handles {
            let resp = h.recv().unwrap();
            assert_eq!(resp.indices.len(), 2);
        }
        let snap = c.metrics();
        assert_eq!(snap.queries, 64);
        assert!(snap.mean_batch_size >= 1.0);
        c.shutdown();
    }

    #[test]
    fn backpressure_fires_when_queue_full() {
        // Queue of 1, zero workers draining fast: spam submissions until
        // QueueFull appears.
        let ds = gaussian_dataset(2000, 128, 7);
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 1,
            batch_timeout: Duration::from_millis(0),
            queue_capacity: 2,
            backend: Backend::Native,
            pull_order: PullOrder::Sequential,
        };
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        let mut saw_full = false;
        let mut receivers = Vec::new();
        for _ in 0..2000 {
            match c.submit(QueryRequest::exact(vec![0.1; 128], 1)) {
                Ok(rx) => receivers.push(rx),
                Err(CoordinatorError::QueueFull) => {
                    saw_full = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(saw_full, "backpressure never engaged");
        for rx in receivers {
            let _ = rx.recv();
        }
        c.shutdown();
    }

    #[test]
    fn batches_form_under_load() {
        let ds = gaussian_dataset(100, 32, 9);
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 16,
            batch_timeout: Duration::from_millis(20),
            queue_capacity: 512,
            backend: Backend::Native,
            pull_order: PullOrder::Sequential,
        };
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        let mut handles = Vec::new();
        for _ in 0..32 {
            handles.push(c.submit(QueryRequest::exact(vec![0.2; 32], 1)).unwrap());
        }
        let mut max_batch_seen = 0;
        for h in handles {
            max_batch_seen = max_batch_seen.max(h.recv().unwrap().batch_size);
        }
        assert!(max_batch_seen > 1, "no batching under burst load");
        c.shutdown();
    }
}

#[cfg(test)]
mod deadline_tests {
    use super::*;
    use crate::data::synthetic::gaussian_dataset;

    #[test]
    fn expired_deadline_sheds() {
        // One slow worker, queue fills, deadlines of 0ns: everything past
        // the first batch is shed.
        let ds = gaussian_dataset(500, 256, 21);
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 4,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: 512,
            backend: Backend::Native,
            pull_order: PullOrder::Sequential,
        };
        let c = Coordinator::new(ds.vectors.clone(), cfg).unwrap();
        let mut rxs = Vec::new();
        for _ in 0..64 {
            let req = QueryRequest::exact(vec![0.3; 256], 3)
                .with_deadline(Duration::from_nanos(1));
            rxs.push(c.submit(req).unwrap());
        }
        let mut shed = 0;
        let mut served = 0;
        for rx in rxs {
            let resp = rx.recv().unwrap();
            if resp.shed {
                assert!(resp.indices.is_empty());
                shed += 1;
            } else {
                assert_eq!(resp.indices.len(), 3);
                served += 1;
            }
        }
        assert_eq!(shed + served, 64);
        assert!(shed > 0, "nothing shed under a 1ns deadline");
        assert_eq!(c.metrics().shed, shed);
        c.shutdown();
    }

    #[test]
    fn generous_deadline_never_sheds() {
        let ds = gaussian_dataset(50, 32, 22);
        let c = Coordinator::new(ds.vectors.clone(), CoordinatorConfig::default()).unwrap();
        for _ in 0..10 {
            let req = QueryRequest::bounded_me(vec![0.1; 32], 2, 0.2, 0.2)
                .with_deadline(Duration::from_secs(30));
            let resp = c.query_blocking(req).unwrap();
            assert!(!resp.shed);
            assert_eq!(resp.indices.len(), 2);
        }
        assert_eq!(c.metrics().shed, 0);
        c.shutdown();
    }
}
