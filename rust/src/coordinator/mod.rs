//! The serving coordinator: plan-aware batcher → event-driven reactor →
//! shard workers — with an S = 1 fast path that skips the reactor hop
//! entirely.
//!
//! The paper's Motivation II — a per-query (ε, δ) accuracy knob — is a
//! *serving* feature: different requests on one index want different
//! points on the accuracy/latency curve. This module provides that as a
//! production-shaped service. Event flow, stage by stage:
//!
//! 1. **Submit.** [`Coordinator::submit`] pushes into a bounded queue
//!    and fails fast with [`CoordinatorError::QueueFull`] under
//!    backpressure — no unbounded buffering anywhere in the pipeline.
//! 2. **Batch (plan-aware).** The batcher resolves [`QueryMode::Auto`]
//!    through [`QueryPlan`] **once per query at arrival**, then groups
//!    queries by *execution shape* — exact scans together, BOUNDEDME
//!    queries together per `(k, ε, δ)` knob triple and storage tier —
//!    instead of by raw arrival order. A group closes when it reaches `max_batch` or its
//!    oldest member has waited `batch_timeout`. Because a flushed group
//!    is already knob-uniform, it hits the fused
//!    [`crate::algos::MipsIndex::query_batch`] path (one shared
//!    coordinate permutation, one scoring slab) instead of degrading to
//!    per-query serving.
//! 3. **Fast path (S = 1).** Unsharded deployments skip the reactor
//!    thread entirely: workers consume batches straight from the
//!    batcher, check deadlines at pickup, execute through their
//!    long-lived [`QueryContext`], and reply **worker → client** — no
//!    per-query `Arc` wrapper, no merge lock, no extra thread hop.
//!    `serving/per_request_overhead` in `BENCH_serving.json` tracks
//!    exactly this path.
//! 4. **Reactor (S ≥ 2).** A single event-loop thread owns all
//!    cross-shard state. It *never blocks on a full channel*: batches
//!    are admitted from the batcher with
//!    [`try_recv`](crate::sync::Receiver::try_recv), fanned out to
//!    per-shard channels with `try_send`
//!    (spilling to a bounded per-shard backlog under backpressure —
//!    admission pauses while a backlog is full, so the end-to-end
//!    backpressure chain submit → batcher → reactor stays intact), and
//!    merge completion is driven by **shard-partial events** coming
//!    back from workers rather than by a last-shard-takes-the-lock
//!    [`std::sync::Mutex`]. All merge state lives in the reactor
//!    thread: no locks on the serving path.
//! 5. **Shard workers.** Worker `w` is pinned to shard `w mod S` and
//!    polls two channels through one [`crate::sync::Selector`]: its
//!    shard's primary channel and the shared hedge channel. Exact
//!    items of a batch run **one**
//!    [`ScoringEngine::score_dataset_batch`] over the shard; BOUNDEDME
//!    items run the sample-then-confirm entry point
//!    [`BoundedMeIndex::query_batch_shard`] at the `(ε, δ/S)` split
//!    from [`crate::exec::shard::shard_params`]. Each completed shard
//!    batch returns to the reactor as one completion event carrying
//!    per-query [`ShardPartial`]s.
//! 6. **Merge & reply.** The reactor folds each partial into the
//!    query's [`TopK`] accumulator (stable global-id tie-break — merge
//!    results are independent of shard arrival order) and replies the
//!    moment the last shard's partial lands. Sharded results are
//!    byte-identical to the blocking implementation this replaced:
//!    per-worker contexts and [`crate::exec::shard::merge_partials`]
//!    semantics carried over unchanged.
//!
//! **Straggler hedging** ([`CoordinatorConfig::hedge_delay`]): when a
//! dispatched shard batch has produced no completion event after the
//! hedge delay, the reactor re-dispatches the same batch — flagged as a
//! hedge — onto the shared hedge channel, where any idle worker (for
//! contiguous shards, every worker can score every shard: shard
//! matrices are zero-copy views) picks it up. First completion wins;
//! the loser's event finds its dispatch entry already retired and is
//! dropped wholesale, so the merge never double-counts a shard.
//! Duplicate execution is byte-deterministic (same shard data, same
//! knobs, same seed), which keeps hedged results identical to unhedged
//! runs — with one deliberate exception: under per-request deadlines,
//! a hedge copy picked up *after* the deadline sheds the query even if
//! the straggling primary would eventually have answered late; either
//! outcome is within the deadline contract (the client had already
//! timed out). `hedge_fired` / `hedge_won` in [`MetricsSnapshot`]
//! track how often hedges launch and how often they beat the
//! straggler.
//!
//! **Live mutation** ([`Coordinator::mutate`]): the dataset is served
//! as a lineage of immutable [`Generation`]s (see
//! [`crate::data::generation`]) wrapped in `Arc`-shared
//! [`ShardSet`]s. A writer builds generation `N+1` copy-on-write from
//! `N` under a mutex that only writers touch, then delivers the new
//! set to every serving thread over dedicated flip channels; the
//! reactor (and each S = 1 direct worker) swaps its local `Arc`
//! **between batches** and acks. `mutate` blocks until every consumer
//! acked, so once it returns, every subsequently submitted query is
//! answered at or above the new generation — the witness window the
//! `generation_equivalence` battery asserts. Queries already in
//! flight finish on the generation their batch captured at admission
//! (pinning is an `Arc` clone per batch, not per query, and never
//! mid-batch), and the superseded generation is reclaimed when its
//! last pinned batch drops — epoch-observed via
//! [`crate::sync::EpochGauge`]. **The query hot path takes no lock
//! anywhere in this protocol**; only writers serialize.
//!
//! # Deadline lifecycle: harvest, not shed
//!
//! A [`QueryRequest::deadline`] (and/or [`QueryRequest::budget_flops`])
//! starts a budget clock at **submit**, and the wire decode time the
//! front-end stamps into [`QueryRequest::decode_ns`] counts against it
//! — a query that burned its whole deadline being parsed sheds without
//! computing. The clock is then checked at three points, each with a
//! different outcome:
//!
//! 1. **Admission** (reactor admit / direct-worker pickup): already
//!    expired ⇒ reply `shed = true` immediately, no compute. This is
//!    the only *pure* shed left for BOUNDEDME queries — nothing ran, so
//!    there is nothing to harvest.
//! 2. **Shard pickup** (reactor path): a query expiring inside a
//!    backed-up shard channel produces an empty `expired` partial for
//!    that shard. For budget-armed queries — BOUNDEDME with a deadline
//!    or FLOP cap, under [`CoordinatorConfig::harvest`] (the default)
//!    — the merge *degrades* instead of shedding: it folds whatever
//!    non-expired shards delivered and replies `degraded = true` with
//!    `shards` < `shards_total` coverage. The merge still sheds when
//!    **no** shard produced a usable partial, and unarmed queries
//!    (exact mode, or harvesting disabled) keep the pre-anytime
//!    contract: any expired shard sheds whole.
//! 3. **Mid-run** (inside BOUNDEDME): budget-armed queries run under an
//!    [`AnytimeBudget`]; each elimination round checkpoints a
//!    best-so-far top-k into the bandit scratch, and when the budget
//!    fires the round loop stops and returns the checkpoint — the
//!    achieved confidence width ε̂ rides the reply as
//!    [`QueryResponse::epsilon_hat`], with `degraded = true`. Round 1
//!    always runs; a budget too small for even one round is a shed at
//!    the caller.
//!
//! Every reply is therefore exactly one of **shed** (empty, `shed`),
//! **degraded** (results present at reduced fidelity, `degraded`, ε̂ /
//! coverage reported), or **exact-complete** (neither flag). The
//! [`MetricsSnapshot`] splits terminal outcomes the same three ways
//! (`shed` / `degraded` / the remainder of `queries`).
//!
//! Separately, sustained backlog can trigger **admission degradation**:
//! with a [`DegradePolicy`] configured, the batcher widens ε / clamps k
//! on arriving non-exact queries while [`MetricsRegistry::backlog`]
//! exceeds the policy threshold, reporting the applied knobs via
//! [`QueryResponse::applied_epsilon`] / [`QueryResponse::applied_k`].
//! This is load-aware *planning*, not harvesting — such replies are not
//! marked `degraded` unless their budget also fired.
//!
//! With no deadline and no budget set (or under
//! `RUST_PALLAS_FORCE_NO_DEGRADE=1`), none of this machinery runs and
//! answers are bit-identical to the pre-anytime coordinator.
//!
//! * **Backpressure**: bounded everywhere — submit queue, batch
//!   channel, per-shard channels, reactor backlog, hedge channel.
//! * **Backends**: workers score through a [`ScoringEngine`] —
//!   pure-Rust or the PJRT AOT artifact (see [`crate::runtime`]).
//!   Hedged batches for a *different* shard score through the native
//!   blocked kernels (bit-identical under the Native backend; a PJRT
//!   worker's device holds only its pinned shard).

pub mod server;
pub mod stats;

pub use stats::{MetricsRegistry, MetricsSnapshot};

use crate::algos::{BoundedMeIndex, MipsIndex, MipsParams, MipsResult};
use crate::bandit::{force_no_degrade_requested, AnytimeBudget, Harvest, PullOrder};
use crate::data::generation::{Delta, Generation, GenerationBuilder};
use crate::data::quant::Storage;
use crate::data::shard::ShardSpec;
use crate::exec::shard::{shard_params, ShardPartial, ShardSet};
use crate::exec::{DegradePolicy, PlanAlgo, QueryContext, QueryPlan};
use crate::linalg::{Matrix, TopK};
use crate::runtime::{NativeEngine, PjrtEngine, ScoringEngine};
use crate::sync::{
    bounded, EpochGauge, Receiver, RecvError, Selector, SendError, Sender, TryRecvError,
};
use crate::trace::{
    trace_env_requested, QueryExec, QueryTrace, TraceBuilder, TraceConfig, TraceRecorder,
    TraceSink,
};
use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Which compute backend workers use for exact scoring.
#[derive(Clone, Debug)]
pub enum Backend {
    /// Pure-Rust dot products.
    Native,
    /// AOT-compiled XLA artifacts loaded from this directory.
    Pjrt {
        /// Directory containing `*.hlo.txt` artifacts.
        artifact_dir: PathBuf,
    },
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads.
    pub workers: usize,
    /// Maximum queries per batch (per plan/knob group — see the module
    /// docs on plan-aware batching).
    pub max_batch: usize,
    /// Maximum time the oldest request of a group waits before its
    /// batch closes.
    pub batch_timeout: Duration,
    /// Router queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Exact-scoring backend.
    pub backend: Backend,
    /// Pull order for BOUNDEDME queries. `BlockShuffled(0)` (the
    /// default) means "planner-chosen": the coordinator substitutes
    /// [`QueryPlan::block_width`] for the dataset's dimension at
    /// startup.
    pub pull_order: PullOrder,
    /// Dataset sharding across the worker pool (see
    /// [`crate::data::shard`]). The default is a single shard — served
    /// on the direct fast path. With `S ≥ 2` shards the worker count is
    /// raised to at least `S` so every shard has a pinned worker.
    pub shard: ShardSpec,
    /// Storage tier BOUNDEDME queries sample from (see
    /// [`crate::data::quant::Storage`] and the two-tier path on
    /// [`BoundedMeIndex::with_storage`]). Each shard index quantizes its
    /// rows once at startup; exact scans always score on f32. The
    /// batcher keys BOUNDEDME groups on the effective tier, and every
    /// [`QueryResponse`] reports the tier it actually sampled from.
    /// `RUST_PALLAS_FORCE_F32` collapses this to [`Storage::F32`]
    /// process-wide. Default: [`Storage::F32`] (no compressed tier).
    pub storage: Storage,
    /// Shard-level straggler hedging (reactor path only): after a
    /// dispatched shard batch has gone this long without completing,
    /// re-dispatch it to the shared hedge queue where any idle worker
    /// can serve it; first completion wins and the duplicate partial is
    /// dropped. `None` (the default) disables hedging.
    ///
    /// Under the Native backend (and under PJRT's native fallback, the
    /// only thing the stubbed `pjrt` feature can produce today), both
    /// copies compute bit-identical partials, so hedged results equal
    /// unhedged ones exactly. With a real PJRT device backend, hedged
    /// *exact* partials are computed by the host's native kernels while
    /// primaries score on-device — low-order float accumulation bits
    /// may differ, and whichever copy completes first wins. Both are
    /// correct exact scans; don't enable hedging there if bit-stable
    /// replies across runs matter.
    pub hedge_delay: Option<Duration>,
    /// Route `S = 1` through the reactor merge path instead of the
    /// direct fast path. Exists so tests and benches can compare the
    /// two paths on identical traffic; answers are bit-identical either
    /// way, the fast path just skips the reactor hop and merge state.
    #[doc(hidden)]
    pub force_reactor: bool,
    /// Deterministic straggler injection for tests/benches: primary
    /// (non-hedged) batches for shard `.0` sleep `.1` before serving.
    /// Hedge copies run full speed. Reactor path only.
    #[doc(hidden)]
    pub debug_slow_shard: Option<(usize, Duration)>,
    /// Flight-recorder knobs (see [`crate::trace`]). Whether tracing is
    /// on is decided **once at construction** — `trace.enabled` or the
    /// `RUST_PALLAS_TRACE` env pin — and carried as a plain bool
    /// through every thread, so a disabled deployment pays zero
    /// allocations and zero atomics for the subsystem.
    pub trace: TraceConfig,
    /// Harvest-not-shed switch (default `true`): BOUNDEDME queries
    /// carrying a deadline or a [`QueryRequest::budget_flops`] cap run
    /// the anytime elimination core and, when the budget expires
    /// mid-run, answer from the best-so-far round checkpoint with
    /// `degraded = true` and the achieved ε̂ — instead of shedding
    /// whole. Partial shard coverage is likewise merged instead of
    /// shed (shedding remains only for queries that expired before any
    /// round / any shard completed). `false` restores pure shed-only
    /// deadline handling (the pre-anytime contract); the
    /// `RUST_PALLAS_FORCE_NO_DEGRADE` env pin forces that process-wide
    /// regardless of this flag.
    pub harvest: bool,
    /// Load-aware admission degradation (default `None` = off): under
    /// sustained queue backlog, admit BOUNDEDME queries with widened ε
    /// / clamped k per the policy, reporting the applied knobs in
    /// [`QueryResponse::applied_epsilon`] /
    /// [`QueryResponse::applied_k`]. Exact queries are never degraded
    /// at admission.
    pub degrade: Option<DegradePolicy>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 32,
            batch_timeout: Duration::from_millis(2),
            queue_capacity: 1024,
            backend: Backend::Native,
            pull_order: PullOrder::BlockShuffled(0),
            shard: ShardSpec::single(),
            storage: Storage::F32,
            hedge_delay: None,
            force_reactor: false,
            debug_slow_shard: None,
            trace: TraceConfig::default(),
            harvest: true,
            degrade: None,
        }
    }
}

/// How a request wants to be answered.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryMode {
    /// BOUNDEDME with the request's (ε, δ).
    BoundedMe,
    /// Exhaustive exact scoring through the backend engine.
    Exact,
    /// Let [`QueryPlan`] decide per query from `(k, ε, δ, dim)`: knobs
    /// tight enough that sampling cannot beat a scan run exact.
    Auto,
}

/// One MIPS request.
#[derive(Clone, Debug)]
pub struct QueryRequest {
    /// The query vector (must match the dataset dimension).
    pub vector: Vec<f32>,
    /// Result count.
    pub k: usize,
    /// BOUNDEDME suboptimality budget.
    pub epsilon: f64,
    /// BOUNDEDME failure probability.
    pub delta: f64,
    /// Answer mode.
    pub mode: QueryMode,
    /// Pull-order seed. When a dynamic batch of BOUNDEDME requests has
    /// uniform (k, ε, δ), the batch is *fused*: the first request's
    /// seed keys one shared coordinate permutation for the whole batch
    /// (that sharing is what makes batching fuse compute). Requests
    /// with heterogeneous knobs land in different batch groups and are
    /// served with their own seeds.
    pub seed: u64,
    /// Optional service-level deadline, measured from submission —
    /// wire decode time ([`QueryRequest::decode_ns`]) counts against
    /// it. A request that expires before any work could start is *shed*
    /// (answered with `shed = true` and no results); a BOUNDEDME
    /// request that expires mid-elimination is **harvested** instead
    /// (answered from the best-so-far round checkpoint with
    /// `degraded = true` and the achieved ε̂) unless
    /// [`CoordinatorConfig::harvest`] is off. Exact-mode requests never
    /// degrade: they either complete or shed.
    pub deadline: Option<Duration>,
    /// Optional FLOP budget for BOUNDEDME sampling (pulls ≈ multiplies,
    /// the paper's cost model): the elimination core checks it at every
    /// round boundary and harvests the checkpoint once the spend
    /// crosses it — a deadline in deterministic compute units, immune
    /// to wall-clock noise. `None` (the default) leaves the spend
    /// bounded only by (ε, δ). Rides both wire codecs (PLW2 frames /
    /// `budget_flops` on the JSON line codec).
    pub budget_flops: Option<u64>,
    /// Optional per-request storage-tier override for BOUNDEDME
    /// sampling (see [`resolve_storage`]). `None` (the default) samples
    /// from the deployment tier ([`CoordinatorConfig::storage`]).
    /// `Some(tier)` requests that tier: granted when it is the one the
    /// shard indexes actually hold, otherwise the request is served on
    /// the always-present exact f32 tier — a *conservative* downgrade,
    /// never a silently different compression. The batcher keys
    /// BOUNDEDME groups on the resolved tier, so mixed-override traffic
    /// still fuses per tier. Exact-mode requests ignore this (exact
    /// scans always score f32).
    pub storage: Option<Storage>,
    /// Wire-decode wall time in nanoseconds, stamped by the server's
    /// codec before submission (0 = unmeasured / in-process caller).
    /// Purely observability: the flight recorder turns it into a
    /// `decode` span so the protocol tax is visible per query.
    pub decode_ns: u64,
}

impl QueryRequest {
    /// A BOUNDEDME request with the given knobs.
    pub fn bounded_me(vector: Vec<f32>, k: usize, epsilon: f64, delta: f64) -> Self {
        Self {
            vector,
            k,
            epsilon,
            delta,
            mode: QueryMode::BoundedMe,
            seed: 0,
            deadline: None,
            budget_flops: None,
            storage: None,
            decode_ns: 0,
        }
    }

    /// Attach a deadline (see [`QueryRequest::deadline`]).
    pub fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Cap the BOUNDEDME sampling spend (see
    /// [`QueryRequest::budget_flops`]).
    pub fn with_budget_flops(mut self, flops: u64) -> Self {
        self.budget_flops = Some(flops);
        self
    }

    /// Request a specific sampling tier (see [`QueryRequest::storage`]).
    pub fn with_storage(mut self, storage: Storage) -> Self {
        self.storage = Some(storage);
        self
    }

    /// A planner-routed request: [`QueryPlan`] picks exact vs BOUNDEDME
    /// from the knobs at batching time.
    pub fn auto(vector: Vec<f32>, k: usize, epsilon: f64, delta: f64) -> Self {
        Self {
            vector,
            k,
            epsilon,
            delta,
            mode: QueryMode::Auto,
            seed: 0,
            deadline: None,
            budget_flops: None,
            storage: None,
            decode_ns: 0,
        }
    }

    /// An exact request.
    pub fn exact(vector: Vec<f32>, k: usize) -> Self {
        Self {
            vector,
            k,
            epsilon: 0.0,
            delta: 0.5,
            mode: QueryMode::Exact,
            seed: 0,
            deadline: None,
            budget_flops: None,
            storage: None,
            decode_ns: 0,
        }
    }
}

/// Resolve a request's effective BOUNDEDME sampling tier against the
/// deployment's. `None` takes the deployed tier. A `Some(tier)` request
/// is granted only when its effective tier (the `RUST_PALLAS_FORCE_F32`
/// hatch applied) is exactly what the shard indexes hold; any other
/// request downgrades to [`Storage::F32`] — the exact tier every index
/// carries — rather than approximating with a different compression
/// than the client asked for.
pub fn resolve_storage(requested: Option<Storage>, deployed: Storage) -> Storage {
    match requested {
        None => deployed,
        Some(s) if s.effective() == deployed => deployed,
        Some(_) => Storage::F32,
    }
}

/// A completed response.
#[derive(Clone, Debug)]
pub struct QueryResponse {
    /// Result indices, best first.
    pub indices: Vec<usize>,
    /// Scores, best first. Exact-mode answers always carry exact inner
    /// products. BOUNDEDME answers carry the bandit's estimates
    /// (`N·p̂`) on an unsharded f32-tier coordinator, but **exact
    /// rescored** inner products on a sharded one (`S ≥ 2`) or whenever
    /// a compressed storage tier served the query (its confirm step
    /// rescores survivors on f32; see
    /// [`BoundedMeIndex::with_storage`]). Don't compare raw BOUNDEDME
    /// score values across deployments with different shard counts or
    /// storage tiers.
    pub scores: Vec<f32>,
    /// Flops spent.
    pub flops: u64,
    /// Queue wait from submission to pipeline pickup — reactor
    /// admission on the sharded path, worker pickup on the S = 1 fast
    /// path. Time spent waiting in a backed-up per-shard channel after
    /// fan-out is accounted in `service`, not here.
    pub queue_wait: Duration,
    /// Sharded path: time from reactor fan-out to the merged reply
    /// (includes any shard-channel wait plus the slowest shard's
    /// compute, minus whatever a winning hedge saved). Fast path: the
    /// worker's compute time for the batch.
    pub service: Duration,
    /// Size of the batch group this query rode in.
    pub batch_size: usize,
    /// Worker id that served it (under sharding: the worker whose
    /// completion event closed the merge). `usize::MAX` when no worker
    /// computed anything (shed).
    pub worker: usize,
    /// True when the request was shed (deadline exceeded before any
    /// round of work completed): no results were computed.
    pub shed: bool,
    /// True when the answer was **harvested** rather than served to the
    /// full (ε, δ) contract: the deadline / FLOP budget expired
    /// mid-elimination and the best-so-far round checkpoint answered
    /// (ε̂ in [`QueryResponse::epsilon_hat`]), and/or some shards
    /// expired and the reply merges only the covering subset
    /// ([`QueryResponse::shards`] < [`QueryResponse::shards_total`]).
    /// Exactly one of `shed` / `degraded` / neither (exact-complete)
    /// holds.
    pub degraded: bool,
    /// Achieved confidence width ε̂ of a degraded answer, in the same
    /// request-relative units as [`QueryRequest::epsilon`] (the max
    /// over harvested shards; 0 when not degraded or when degradation
    /// was coverage-only). Always < the requested ε: the checkpoint
    /// after round *l* is ε − 2ε_l optimal **over the surviving pool**
    /// — degradation is reduced elimination depth and (under sharding)
    /// reduced coverage, not a widened guarantee against the full set.
    pub epsilon_hat: f64,
    /// Shard partials merged into this answer (1 when unsharded, 0 for
    /// shed requests — they never produced shard work; < `shards_total`
    /// for a coverage-degraded reply).
    pub shards: usize,
    /// Shards the deployment serves (the fan-out this query was meant
    /// to cover). `shards / shards_total` is a degraded reply's
    /// coverage fraction.
    pub shards_total: usize,
    /// ε actually admitted under load-aware degradation
    /// ([`CoordinatorConfig::degrade`]): `Some(widened)` when the
    /// admission policy widened the requested ε, `None` when the
    /// request ran at its own knobs.
    pub applied_epsilon: Option<f64>,
    /// k actually admitted under load-aware degradation (`Some(clamped)`
    /// when the policy clamped it).
    pub applied_k: Option<usize>,
    /// Storage tier the sampling step ran on: the deployment's
    /// effective [`CoordinatorConfig::storage`] for BOUNDEDME answers,
    /// [`Storage::F32`] for exact scans and shed replies. Compressed
    /// answers were still *confirmed* on f32 (sample-then-confirm).
    pub storage: Storage,
    /// Dataset generation this answer (or shed decision) was pinned to.
    /// Result indices refer to this generation's row numbering; with
    /// live mutation ([`Coordinator::mutate`]) the id identifies *which*
    /// snapshot the answer is exact for. Always some generation whose
    /// lifetime overlapped the request: at least the highest generation
    /// acked before submission, at most the highest started before the
    /// reply.
    pub generation: u64,
}

/// Submission failures.
#[derive(Debug)]
pub enum CoordinatorError {
    /// The bounded router queue is full (backpressure).
    QueueFull,
    /// The coordinator is shutting down.
    Shutdown,
    /// The query vector dimension does not match the dataset.
    DimMismatch {
        /// Dimension received.
        got: usize,
        /// Dimension expected.
        want: usize,
    },
    /// A [`Coordinator::mutate`] delta batch was rejected (bad row id,
    /// wrong dimension, upsert/delete conflict, or shrinking below one
    /// row per shard); the serving generation is unchanged.
    Mutation(String),
}

impl std::fmt::Display for CoordinatorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::QueueFull => write!(f, "router queue full"),
            Self::Shutdown => write!(f, "coordinator shut down"),
            Self::DimMismatch { got, want } => {
                write!(f, "query dim {got} != dataset dim {want}")
            }
            Self::Mutation(msg) => write!(f, "mutation rejected: {msg}"),
        }
    }
}

impl std::error::Error for CoordinatorError {}

struct Pending {
    /// The request; `mode` is resolved (never `Auto`) once the batcher
    /// has planned it, and `(epsilon, k)` may have been rewritten by
    /// the admission [`DegradePolicy`] (recorded in `applied_*`).
    req: QueryRequest,
    submitted: Instant,
    /// ε the admission policy widened to (`None` = admitted as asked).
    applied_epsilon: Option<f64>,
    /// k the admission policy clamped to (`None` = admitted as asked).
    applied_k: Option<usize>,
    reply: Sender<QueryResponse>,
}

struct Batch {
    items: Vec<Pending>,
}

/// A generation flip delivered to one serving thread (the reactor, or
/// one S = 1 direct worker). The consumer swaps its local `Arc` between
/// batches and acks; [`Coordinator::mutate`] blocks on every ack so the
/// post-return visibility guarantee holds (see the module docs).
struct Flip {
    set: Arc<ShardSet>,
    ack: Sender<()>,
}

/// Writer-side state: the newest fully-acked shard set. Only
/// [`Coordinator::mutate`] locks this — the query path never does.
struct MutationState {
    current: Arc<ShardSet>,
}

/// What one applied [`Coordinator::mutate`] batch did.
#[derive(Clone, Debug)]
pub struct MutationOutcome {
    /// Id of the generation now serving (every consumer acked it).
    pub generation: u64,
    /// Row count of that generation.
    pub rows: usize,
    /// Shards re-materialized and re-indexed (delta rows re-quantized
    /// with fresh per-row error bounds).
    pub shards_rebuilt: usize,
    /// Shards carried over as zero-copy `Arc` clones, derived state
    /// (colmax, quantized codes) included.
    pub shards_reused: usize,
    /// Deltas the batch carried (upserts + deletes + appends).
    pub delta_rows: usize,
}

/// The serving coordinator. See module docs.
pub struct Coordinator {
    submit_tx: Sender<Pending>,
    metrics: Arc<MetricsRegistry>,
    dim: usize,
    /// Observes generation lifetimes (every [`Generation`] of this
    /// coordinator's lineage registers here).
    gauge: EpochGauge,
    /// Writer-only lock; see [`MutationState`].
    mutator: Mutex<MutationState>,
    /// One flip channel per consumer: `[reactor]`, or one per direct
    /// worker at S = 1.
    flip_txs: Vec<Sender<Flip>>,
    /// Highest generation id *started* (stored before flips are sent).
    /// Workers read it (Relaxed) for the superseded-shed check; it is
    /// also the sound upper witness bound — a reply can only carry a
    /// generation already recorded here.
    latest_gen: Arc<AtomicU64>,
    /// Highest generation id every consumer has acked (stored after
    /// [`Coordinator::mutate`] collected all acks) — the sound lower
    /// witness bound for queries submitted afterwards.
    acked_gen: AtomicU64,
    /// Flight-recorder rings (`None` when tracing is off — the common
    /// case; the absence is what makes tracing free when disabled).
    trace_sink: Option<Arc<TraceSink>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

/// Build a worker's scoring engine (PJRT preloads the worker's pinned
/// shard to the device so exact queries only move the query vector).
fn build_engine(backend: &Backend, shard_data: &Matrix, worker: usize) -> Box<dyn ScoringEngine> {
    match backend {
        Backend::Native => Box::new(NativeEngine),
        Backend::Pjrt { artifact_dir } => {
            match PjrtEngine::with_dataset(artifact_dir.clone(), shard_data) {
                Ok(e) => Box::new(e),
                Err(err) => {
                    crate::logkit::error!(
                        "worker-{worker}: pjrt init failed ({err}); falling back to native"
                    );
                    Box::new(NativeEngine)
                }
            }
        }
    }
}

impl Coordinator {
    /// Start the coordinator over a vector set, split per
    /// [`CoordinatorConfig::shard`].
    pub fn new(data: Matrix, cfg: CoordinatorConfig) -> crate::Result<Self> {
        assert!(cfg.workers >= 1 && cfg.max_batch >= 1);
        let dim = data.cols();
        let gauge = EpochGauge::new();
        // Generation 0: identical shard layout to a plain ShardedMatrix
        // build (contiguous shards are zero-copy views).
        let gen0 = Generation::initial(data, cfg.shard, gauge.clone());
        let n_shards = gen0.num_shards();
        let use_reactor = n_shards > 1 || cfg.force_reactor;
        // Harvest-not-shed is resolved once, here: the config switch
        // gated by the process-wide kill pin. Off means every deadline
        // path behaves exactly as the pre-anytime coordinator.
        let harvest_on = cfg.harvest && !force_no_degrade_requested();
        // Every shard needs at least one pinned worker; extra workers
        // round-robin across shards.
        let workers = cfg.workers.max(n_shards);
        let metrics = Arc::new(MetricsRegistry::with_shards(n_shards));
        // Tracing is resolved exactly once, here: config switch or the
        // `RUST_PALLAS_TRACE` pin. Recording threads are the reactor
        // (S ≥ 2) or each direct worker (S = 1) — one ring each.
        let trace_on = cfg.trace.enabled || trace_env_requested();
        let trace_sink: Option<Arc<TraceSink>> = if trace_on {
            let rings = if use_reactor { 1 } else { workers };
            Some(Arc::new(TraceSink::new(&cfg.trace, rings)))
        } else {
            None
        };
        let (submit_tx, submit_rx) = bounded::<Pending>(cfg.queue_capacity);
        let (batch_tx, batch_rx) = bounded::<Batch>(workers * 2);

        let mut threads = Vec::new();

        // Batcher thread: resolves Auto plans and groups by execution
        // shape (see run_batcher).
        {
            let cfg2 = cfg.clone();
            let metrics = metrics.clone();
            threads.push(
                std::thread::Builder::new().name("batcher".into()).spawn(move || {
                    run_batcher(submit_rx, batch_tx, &cfg2, dim, &metrics)
                })?,
            );
        }

        // `BlockShuffled(0)` = planner-chosen width for this dimension.
        let order = match cfg.pull_order {
            PullOrder::BlockShuffled(0) => PullOrder::BlockShuffled(QueryPlan::block_width(dim)),
            o => o,
        };
        // Generation 0's shard set: one BoundedMeIndex per shard (the
        // colmax scan and, for compressed tiers, the one-time
        // quantization run once per shard; `Matrix` clones share
        // storage). Batches pin the set they were admitted under; a
        // `mutate` flip swaps the serving `Arc` without touching this
        // one. Workers serve *any* shard's hedge batches through the
        // indexes the batch itself carries.
        let set0 = ShardSet::with_order(gen0, order, cfg.storage);
        let latest_gen = Arc::new(AtomicU64::new(0));
        let mut flip_txs: Vec<Sender<Flip>> = Vec::new();

        if use_reactor {
            let per_shard_cap = (workers / n_shards).max(1) * 2;
            let mut shard_txs = Vec::with_capacity(n_shards);
            let mut shard_rxs = Vec::with_capacity(n_shards);
            for _ in 0..n_shards {
                let (tx, rx) = bounded::<ShardBatch>(per_shard_cap);
                shard_txs.push(tx);
                shard_rxs.push(rx);
            }
            let (hedge_tx, hedge_rx) = bounded::<ShardBatch>(workers * 2);
            let (done_tx, done_rx) = bounded::<ShardDone>(workers * 4);
            let (flip_tx, flip_rx) = bounded::<Flip>(4);
            flip_txs.push(flip_tx);

            // Reactor thread: owns all cross-shard state, never blocks
            // on a channel. The only flip consumer at S ≥ 2: it swaps
            // its `current` set between admits.
            {
                let metrics = metrics.clone();
                let hedge_delay = cfg.hedge_delay;
                let storage = set0.index(0).storage();
                let current = set0.clone();
                let recorder = trace_sink.as_ref().map(|s| s.recorder(0));
                threads.push(std::thread::Builder::new().name("reactor".into()).spawn(
                    move || {
                        Reactor {
                            n_shards,
                            dim,
                            storage,
                            hedge_delay,
                            harvest: harvest_on,
                            max_backlog: per_shard_cap,
                            batch_rx,
                            done_rx,
                            flip_rx,
                            shard_txs,
                            hedge_tx,
                            selector: Selector::new(),
                            merges: HashMap::new(),
                            dispatches: HashMap::new(),
                            backlog: (0..n_shards).map(|_| VecDeque::new()).collect(),
                            next_query: 0,
                            next_dispatch: 0,
                            draining: false,
                            current,
                            metrics,
                            recorder,
                        }
                        .run()
                    },
                )?);
            }

            for w in 0..workers {
                let shard_id = w % n_shards;
                let rx = shard_rxs[shard_id].clone();
                let hedge_rx = hedge_rx.clone();
                let done_tx = done_tx.clone();
                // The generation-0 shard the engine preloads; later
                // generations' batches carry their own data and are
                // pointer-checked against this at serve time.
                let resident = set0.shard(shard_id).matrix().clone();
                let backend = cfg.backend.clone();
                let slow = cfg.debug_slow_shard;
                let latest = latest_gen.clone();
                threads.push(std::thread::Builder::new().name(format!("worker-{w}")).spawn(
                    move || {
                        let engine = build_engine(&backend, &resident, w);
                        run_reactor_worker(
                            w,
                            shard_id,
                            rx,
                            hedge_rx,
                            done_tx,
                            &resident,
                            engine.as_ref(),
                            &latest,
                            slow,
                        );
                    },
                )?);
            }
        } else {
            // S = 1 fast path: workers consume batches straight from
            // the batcher (MPMC) and reply directly — no reactor
            // thread, no per-query Arc, no merge state. Every worker
            // is a flip consumer (it swaps its local set between
            // batches), so mutate() acks cover the whole pool.
            for w in 0..workers {
                let (flip_tx, flip_rx) = bounded::<Flip>(4);
                flip_txs.push(flip_tx);
                let rx = batch_rx.clone();
                let set = set0.clone();
                let metrics = metrics.clone();
                let backend = cfg.backend.clone();
                let recorder = trace_sink.as_ref().map(|s| s.recorder(w));
                threads.push(std::thread::Builder::new().name(format!("worker-{w}")).spawn(
                    move || {
                        let resident = set.shard(0).matrix().clone();
                        let engine = build_engine(&backend, &resident, w);
                        run_direct_worker(
                            w,
                            rx,
                            flip_rx,
                            set,
                            &resident,
                            engine.as_ref(),
                            &metrics,
                            recorder,
                            harvest_on,
                        );
                    },
                )?);
            }
        }

        Ok(Self {
            submit_tx,
            metrics,
            dim,
            gauge,
            mutator: Mutex::new(MutationState { current: set0 }),
            flip_txs,
            latest_gen,
            acked_gen: AtomicU64::new(0),
            trace_sink,
            threads,
        })
    }

    /// Submit a request; returns the response channel. Fails fast under
    /// backpressure.
    pub fn submit(
        &self,
        req: QueryRequest,
    ) -> Result<Receiver<QueryResponse>, CoordinatorError> {
        if req.vector.len() != self.dim {
            return Err(CoordinatorError::DimMismatch { got: req.vector.len(), want: self.dim });
        }
        let (reply, rx) = bounded(1);
        let pending = Pending {
            req,
            submitted: Instant::now(),
            applied_epsilon: None,
            applied_k: None,
            reply,
        };
        self.submit_tx.try_send(pending).map_err(|e| match e {
            SendError::Full(_) => CoordinatorError::QueueFull,
            SendError::Disconnected(_) => CoordinatorError::Shutdown,
        })?;
        // Submission counter feeds the batcher's backlog signal
        // (submitted − completed) for admission degradation.
        self.metrics.record_submit();
        Ok(rx)
    }

    /// Submit and wait for the answer.
    pub fn query_blocking(&self, req: QueryRequest) -> Result<QueryResponse, CoordinatorError> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| CoordinatorError::Shutdown)
    }

    /// Current metrics snapshot.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Count one wire request decoded by the TCP front-end against its
    /// codec (see [`crate::wire`]); the server calls this per decoded
    /// line or frame so the protocol mix is visible in `metrics` /
    /// `metrics_prom`.
    pub fn record_wire(&self, binary: bool) {
        self.metrics.record_wire(binary);
    }

    /// The most recent `limit` retained query traces, newest first.
    /// Empty unless the flight recorder is on
    /// ([`CoordinatorConfig::trace`] or `RUST_PALLAS_TRACE`). Reading
    /// is non-destructive — a trace stays in its ring until overwritten.
    pub fn traces(&self, limit: usize) -> Vec<QueryTrace> {
        self.trace_sink.as_ref().map(|s| s.collect(limit)).unwrap_or_default()
    }

    /// Dataset dimension served.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Apply one delta batch atomically and flip the serving generation.
    ///
    /// Builds generation `N+1` copy-on-write from the current `N`
    /// (untouched shards carried as zero-copy `Arc` clones, dirty ones
    /// re-indexed — and re-quantized, on compressed tiers — from
    /// scratch), delivers the new [`ShardSet`] to every serving thread,
    /// and **blocks until all of them acked the swap**. On return,
    /// every query submitted afterwards is answered at generation ≥ the
    /// returned id; queries already in flight finish on the snapshot
    /// they pinned at admission. Writers serialize on an internal mutex
    /// the query path never touches. An empty batch is a no-op (no
    /// flip, current generation reported). A rejected batch
    /// ([`CoordinatorError::Mutation`]) leaves the serving generation
    /// unchanged.
    pub fn mutate(&self, deltas: &[Delta]) -> Result<MutationOutcome, CoordinatorError> {
        let mut st = self.mutator.lock().expect("mutator lock poisoned");
        if deltas.is_empty() {
            return Ok(MutationOutcome {
                generation: st.current.generation().id(),
                rows: st.current.generation().rows(),
                shards_rebuilt: 0,
                shards_reused: st.current.num_shards(),
                delta_rows: 0,
            });
        }
        let mut builder = GenerationBuilder::new(st.current.generation());
        for d in deltas {
            builder.apply(d).map_err(|e| CoordinatorError::Mutation(e.to_string()))?;
        }
        let delta_rows = builder.delta_rows();
        let built =
            builder.build().map_err(|e| CoordinatorError::Mutation(e.to_string()))?;
        let next = ShardSet::advance(&st.current, &built);
        let shards_reused = built.reuse.iter().filter(|r| r.is_some()).count();
        let shards_rebuilt = built.reuse.len() - shards_reused;
        let generation = next.generation().id();
        let rows = next.generation().rows();
        // Publish the started id *before* any consumer can hold the
        // set: a reply carrying `generation` therefore implies
        // `latest_generation() ≥ generation` — the upper witness bound.
        self.latest_gen.store(generation, Ordering::Release);
        let mut acks = Vec::with_capacity(self.flip_txs.len());
        for tx in &self.flip_txs {
            let (ack_tx, ack_rx) = bounded(1);
            if tx.send(Flip { set: next.clone(), ack: ack_tx }).is_err() {
                return Err(CoordinatorError::Shutdown);
            }
            acks.push(ack_rx);
        }
        for rx in acks {
            rx.recv().map_err(|_| CoordinatorError::Shutdown)?;
        }
        self.acked_gen.store(generation, Ordering::Release);
        st.current = next;
        self.metrics.record_mutation(delta_rows);
        Ok(MutationOutcome { generation, rows, shards_rebuilt, shards_reused, delta_rows })
    }

    /// Highest generation id every serving thread has acked: queries
    /// submitted after this read are answered at a generation ≥ it (the
    /// lower witness bound of the equivalence battery).
    pub fn generation(&self) -> u64 {
        self.acked_gen.load(Ordering::Acquire)
    }

    /// Highest generation id a [`Coordinator::mutate`] call has started
    /// flipping to (≥ [`Coordinator::generation`]): no reply can carry
    /// a generation above this (the upper witness bound).
    pub fn latest_generation(&self) -> u64 {
        self.latest_gen.load(Ordering::Acquire)
    }

    /// Generations currently alive (pinned by serving state or
    /// in-flight batches). Returns to 1 after churn quiesces — the
    /// epoch-reclamation leak check.
    pub fn generations_alive(&self) -> usize {
        self.gauge.alive()
    }

    /// Drain and stop all threads: the batcher flushes its open groups,
    /// the reactor keeps running until every in-flight query (hedged or
    /// not) has replied, then the worker pool drains its channels.
    pub fn shutdown(mut self) {
        drop(self.submit_tx);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Group key for plan-aware batching: exact scans fuse regardless of
/// `k` (one shared scoring slab, per-query top-K after), BOUNDEDME
/// fuses only under equal `(k, ε, δ)` *and* storage tier (one shared
/// pull budget, permutation, and panel element type — a batch never
/// mixes compressed and f32 sampling).
#[derive(Clone, Copy, PartialEq, Eq)]
enum GroupKey {
    Exact,
    BoundedMe { k: usize, eps_bits: u64, delta_bits: u64, storage: Storage },
}

/// Resolve a request's execution mode: `Auto` goes through
/// [`QueryPlan::pick`] exactly once, here at batching time, so every
/// downstream stage (fast path, reactor, every shard) sees the same
/// decision. Plans depend on `dim`, which sharding never splits, so the
/// decision is shard-count invariant.
fn plan_mode(req: &QueryRequest, dim: usize) -> QueryMode {
    match req.mode {
        QueryMode::Auto => match QueryPlan::pick(req.k, req.epsilon, req.delta, dim).algo {
            PlanAlgo::Exact => QueryMode::Exact,
            PlanAlgo::BoundedMe => QueryMode::BoundedMe,
        },
        m => m,
    }
}

/// Batcher loop — **plan-aware**: arrivals are planned (`Auto`
/// resolved), then grouped by [`GroupKey`] so every flushed batch is
/// uniform in execution shape and hits the fused `query_batch` /
/// `score_dataset_batch` paths. A group closes when it reaches
/// `max_batch` or when its oldest member has waited `batch_timeout`.
fn run_batcher(
    submit_rx: Receiver<Pending>,
    batch_tx: Sender<Batch>,
    cfg: &CoordinatorConfig,
    dim: usize,
    metrics: &MetricsRegistry,
) {
    struct Group {
        key: GroupKey,
        items: Vec<Pending>,
        deadline: Instant,
    }
    let mut groups: Vec<Group> = Vec::new();
    let flush = |items: Vec<Pending>| -> bool {
        metrics.record_batch(items.len());
        batch_tx.send(Batch { items }).is_ok()
    };
    loop {
        // Wait for the next arrival — indefinitely when no group is
        // open, else until the earliest group deadline.
        let next = if groups.is_empty() {
            match submit_rx.recv() {
                Ok(p) => Some(p),
                Err(_) => return, // all senders gone, nothing buffered: shutdown
            }
        } else {
            let earliest = groups.iter().map(|g| g.deadline).min().unwrap();
            let now = Instant::now();
            if now >= earliest {
                None
            } else {
                match submit_rx.recv_timeout(earliest - now) {
                    Ok(p) => Some(p),
                    Err(RecvError::Timeout) => None,
                    Err(RecvError::Disconnected) => {
                        // Shutdown drain: flush every open group.
                        for g in groups.drain(..) {
                            if !flush(g.items) {
                                return;
                            }
                        }
                        return;
                    }
                }
            }
        };
        match next {
            Some(mut p) => {
                p.req.mode = plan_mode(&p.req, dim);
                // Load-aware admission degradation: under sustained
                // backlog, admit BOUNDEDME queries with widened ε /
                // clamped k (exact queries keep their contract). The
                // applied knobs ride the Pending into the reply.
                if let Some(policy) = cfg.degrade {
                    if p.req.mode != QueryMode::Exact
                        && metrics.backlog() >= policy.backlog_threshold as u64
                    {
                        if let Some((eps, k)) = policy.apply(p.req.epsilon, p.req.k) {
                            if eps > p.req.epsilon {
                                p.applied_epsilon = Some(eps);
                                p.req.epsilon = eps;
                            }
                            if k < p.req.k {
                                p.applied_k = Some(k);
                                p.req.k = k;
                            }
                            metrics.record_degraded_admit();
                        }
                    }
                }
                let key = match p.req.mode {
                    QueryMode::Exact => GroupKey::Exact,
                    _ => GroupKey::BoundedMe {
                        k: p.req.k,
                        eps_bits: p.req.epsilon.to_bits(),
                        delta_bits: p.req.delta.to_bits(),
                        // The tier this request will actually sample
                        // from: its override resolved against the
                        // deployment tier (force-f32 hatch applied), so
                        // groups stay tier-uniform under mixed
                        // per-request overrides.
                        storage: resolve_storage(p.req.storage, cfg.storage.effective()),
                    },
                };
                let deadline = p.submitted + cfg.batch_timeout;
                match groups.iter_mut().find(|g| g.key == key) {
                    Some(g) => g.items.push(p),
                    None => groups.push(Group { key, items: vec![p], deadline }),
                }
                let mut i = 0;
                while i < groups.len() {
                    if groups[i].items.len() >= cfg.max_batch {
                        let g = groups.swap_remove(i);
                        if !flush(g.items) {
                            return;
                        }
                    } else {
                        i += 1;
                    }
                }
            }
            None => {
                // Deadline flush: close every group whose oldest member
                // has waited out the batch window.
                let now = Instant::now();
                let mut i = 0;
                while i < groups.len() {
                    if now >= groups[i].deadline {
                        let g = groups.swap_remove(i);
                        if !flush(g.items) {
                            return;
                        }
                    } else {
                        i += 1;
                    }
                }
            }
        }
    }
}

/// A query admitted by the reactor, shared read-only across its `S`
/// shard dispatches (and any hedge re-dispatches).
struct QueryJob {
    id: u64,
    vector: Vec<f32>,
    k: usize,
    epsilon: f64,
    delta: f64,
    seed: u64,
    /// Resolved mode: `Exact` or `BoundedMe`, never `Auto`.
    mode: QueryMode,
    /// Resolved sampling tier (see [`resolve_storage`]): the
    /// deployment's for exact jobs (they score f32 regardless), the
    /// request's resolved override for BOUNDEDME ones. Workers pass it
    /// to the `_tier` query entry points.
    storage: Storage,
    /// Original submission instant — workers re-check `deadline`
    /// against it at shard pickup.
    submitted: Instant,
    deadline: Option<Duration>,
    /// FLOP cap for the anytime elimination core (see
    /// [`QueryRequest::budget_flops`]).
    budget_flops: Option<u64>,
    /// Wire-decode time, counted against the deadline at every check
    /// site (a query that burned its whole deadline in decode sheds).
    decode_ns: u64,
    /// Arm the anytime budget for this job: resolved at admission to
    /// `cfg.harvest && BOUNDEDME && (deadline or FLOP budget present)`.
    /// Unarmed jobs take exactly the pre-anytime code path.
    harvest: bool,
}

/// The instant a request's budget clock runs out: submission plus the
/// deadline *minus the wire-decode time already spent* — decode is not
/// free ([`QueryRequest::decode_ns`]).
fn deadline_instant(submitted: Instant, deadline: Duration, decode_ns: u64) -> Instant {
    submitted + deadline.saturating_sub(Duration::from_nanos(decode_ns))
}

/// One shard's slice of a dispatched batch. `dispatch` identifies the
/// (batch × shard) dispatch for duplicate suppression; a hedge
/// re-dispatch carries the *same* dispatch id with `hedged = true`.
struct ShardBatch {
    dispatch: u64,
    shard: usize,
    hedged: bool,
    /// Cleared by the reactor when the dispatch completes: a copy
    /// (hedge *or* straggling primary) that is picked up after its
    /// sibling already won checks this once and skips the whole scan
    /// instead of computing a partial nobody will fold. Purely an
    /// optimization — suppression itself happens at the reactor's
    /// dispatch table, and the first copy always sees `true`.
    live: Arc<AtomicBool>,
    /// The generation-pinned shard set captured at reactor admission.
    /// Every copy of the dispatch (hedges included) serves from this
    /// set, however many flips happen while the batch is in flight —
    /// that pin is what makes answers exact for one specific snapshot.
    set: Arc<ShardSet>,
    /// Whether the flight recorder wants this batch's executions
    /// staged: a plain bool resolved once at coordinator construction,
    /// so the disabled hot path never touches the trace subsystem.
    traced: bool,
    items: Vec<Arc<QueryJob>>,
}

/// One query's outcome within a completed shard batch.
struct QueryDone {
    query: u64,
    partial: ShardPartial,
    /// The worker observed the query's deadline expired at pickup; the
    /// partial is empty and the merge will reply `shed`.
    expired: bool,
    /// `expired` *and* the batch's pinned generation had already been
    /// superseded by a flip at pickup — the stale-and-late shed the
    /// `shed_superseded` counter tracks.
    superseded: bool,
    /// Set when this shard's bandit run harvested its round checkpoint
    /// (anytime budget expired mid-run): the achieved ε̂ in
    /// request-relative units plus completed rounds. The partial still
    /// carries real (confirm-rescored) entries.
    harvest: Option<Harvest>,
    /// Execution telemetry staged by the BOUNDEDME index for this
    /// query (traced batches only; boxed so the untraced `QueryDone`
    /// stays one pointer wider, not a struct wider).
    exec: Option<Box<QueryExec>>,
}

/// Completion event: one executed [`ShardBatch`], reported back to the
/// reactor.
struct ShardDone {
    dispatch: u64,
    worker: usize,
    hedged: bool,
    /// When the worker picked the batch up (traced batches only) —
    /// lets the reactor split the shard window into channel wait vs
    /// compute. Taken *before* the `debug_slow_shard` sleep, so an
    /// injected straggler shows up as compute, like a real one would.
    picked: Option<Instant>,
    results: Vec<QueryDone>,
}

/// Per-query merge accumulator, owned by the reactor thread (no lock).
struct MergeState {
    top: TopK,
    /// `S = 1` BOUNDEDME under `force_reactor`: the single shard's
    /// entries pass through in the bandit's own ranking (estimate
    /// scores), bit-identical to the fast path / the pre-reactor
    /// unsharded coordinator — re-ranking estimates through `TopK`
    /// could reorder ties.
    passthrough: bool,
    entries_direct: Vec<(f32, usize)>,
    /// Tier the sampling step ran on (reported in the reply):
    /// `Storage::F32` for exact queries, the deployment tier for
    /// BOUNDEDME ones.
    storage: Storage,
    /// Generation id the query's batch pinned at admission (reported in
    /// the reply).
    generation: u64,
    flops: u64,
    remaining: usize,
    shed: bool,
    /// Shards that contributed a real (non-expired) partial. For
    /// harvest-armed queries, `shed && covered > 0` replies degraded
    /// over the covering subset instead of shedding whole.
    covered: usize,
    /// Whether this query was admitted with the anytime budget armed
    /// (BOUNDEDME with a deadline or FLOP cap, harvesting enabled).
    /// Unarmed queries — exact ones included — keep the pre-anytime
    /// shed contract even when some shards delivered.
    harvest: bool,
    /// Any folded partial came from a harvested (budget-expired)
    /// bandit run.
    harvested: bool,
    /// Worst (max) achieved ε̂ across harvested shards,
    /// request-relative units.
    epsilon_hat: f64,
    /// Some shard shed this query while its pinned generation was
    /// already superseded (see [`QueryDone::superseded`]).
    superseded: bool,
    /// Admission-degradation knobs carried from the [`Pending`]
    /// (reported in the reply).
    applied_epsilon: Option<f64>,
    applied_k: Option<usize>,
    queue_wait: Duration,
    batch_size: usize,
    started: Instant,
    /// Span accumulator for the flight recorder (traced queries only;
    /// boxed to keep the untraced merge state small).
    trace: Option<Box<TraceBuilder>>,
    reply: Sender<QueryResponse>,
}

/// Bookkeeping for one in-flight (batch × shard) dispatch.
struct Dispatch {
    shard: usize,
    /// Kept so a hedge can re-dispatch the identical batch. Populated
    /// only when hedging is enabled — the default (`hedge_delay:
    /// None`) path pays no per-dispatch clone for it.
    items: Vec<Arc<QueryJob>>,
    /// Set when the primary actually entered the shard channel. The
    /// reactor-side backlog does not count toward the hedge delay, but
    /// shard-channel wait deliberately does: to the waiting client a
    /// backed-up shard channel is indistinguishable from a slow shard,
    /// and an idle sibling should steal the work either way. A hedge
    /// fired against a merely-queued batch is cheap — once the primary
    /// completes, the queued hedge copy fails its `live` check at
    /// pickup and skips the scan.
    sent_at: Option<Instant>,
    hedge_sent: bool,
    /// Shared with every queued copy of this dispatch; cleared on
    /// completion so stale copies skip their scan at pickup.
    live: Arc<AtomicBool>,
    /// The pinned set, so a hedge re-dispatch serves the *same*
    /// generation as the primary (an `Arc` bump, kept regardless of
    /// whether hedging is enabled).
    set: Arc<ShardSet>,
}

/// The event-driven shard coordinator core. Single-threaded event loop:
/// poll completions → admit batches (bounded by backlog depth) → flush
/// backlogs → drive hedges → park on the selector. See module docs.
struct Reactor {
    n_shards: usize,
    dim: usize,
    /// Effective storage tier of the shard indexes (what BOUNDEDME
    /// replies report).
    storage: Storage,
    hedge_delay: Option<Duration>,
    /// Harvest-not-shed (config switch × env kill pin, resolved at
    /// construction): arm anytime budgets on deadline/FLOP-capped
    /// BOUNDEDME jobs and merge partial shard coverage instead of
    /// shedding it.
    harvest: bool,
    /// Per-shard backlog bound; admission pauses while any shard's
    /// backlog is at the bound, preserving end-to-end backpressure.
    max_backlog: usize,
    batch_rx: Receiver<Batch>,
    done_rx: Receiver<ShardDone>,
    flip_rx: Receiver<Flip>,
    shard_txs: Vec<Sender<ShardBatch>>,
    hedge_tx: Sender<ShardBatch>,
    selector: Selector,
    merges: HashMap<u64, MergeState>,
    dispatches: HashMap<u64, Dispatch>,
    backlog: Vec<VecDeque<ShardBatch>>,
    next_query: u64,
    next_dispatch: u64,
    draining: bool,
    /// The shard set new admissions pin — swapped by generation flips,
    /// always between batches (admission happens after the flip drain).
    current: Arc<ShardSet>,
    metrics: Arc<MetricsRegistry>,
    /// Flight-recorder handle (`None` when tracing is off). Its
    /// presence is the per-batch `traced` bit workers see.
    recorder: Option<TraceRecorder>,
}

impl Reactor {
    fn run(mut self) {
        self.selector.watch(&self.batch_rx);
        self.selector.watch(&self.done_rx);
        self.selector.watch(&self.flip_rx);
        for tx in &self.shard_txs {
            self.selector.watch_sender(tx); // wake on pop: backlog can flush
        }
        self.selector.watch_sender(&self.hedge_tx);
        loop {
            // 0. Generation flips, before any admission this iteration:
            //    a batch never straddles a flip, and acking here (after
            //    the swap) upholds mutate()'s post-return guarantee for
            //    every batch admitted afterwards. In-flight dispatches
            //    keep serving the set they pinned.
            while let Ok(flip) = self.flip_rx.try_recv() {
                self.current = flip.set;
                let _ = flip.ack.send(());
            }
            // 1. Completions first: they retire merge/dispatch state and
            //    free backlog headroom.
            loop {
                match self.done_rx.try_recv() {
                    Ok(done) => self.on_done(done),
                    Err(TryRecvError::Empty) => break,
                    // All workers gone mid-flight (panic) — in-flight
                    // queries can never complete; bail rather than hang.
                    Err(TryRecvError::Disconnected) => return,
                }
            }
            // 2. Admit new batches while the backlog has headroom (a
            //    full backlog pushes back through the batch channel to
            //    the batcher and on to submit()).
            while !self.draining && self.backlog_has_headroom() {
                match self.batch_rx.try_recv() {
                    Ok(batch) => self.admit(batch),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => self.draining = true,
                }
            }
            // 3. Dispatch without blocking.
            self.flush_backlogs();
            // 4. Straggler hedging.
            let next_hedge = self.drive_hedges();
            // 5. Drained?
            if self.draining && self.merges.is_empty() {
                return;
            }
            // 6. Park until a channel changes state or a hedge is due.
            match next_hedge {
                Some(deadline) => {
                    self.selector.wait_deadline(deadline);
                }
                None => self.selector.wait(),
            }
        }
    }

    fn backlog_has_headroom(&self) -> bool {
        self.backlog.iter().all(|b| b.len() < self.max_backlog)
    }

    /// Shed check + fan-out: every admitted query becomes one
    /// [`MergeState`] and `S` dispatch entries (one per shard) queued on
    /// the per-shard backlogs.
    fn admit(&mut self, batch: Batch) {
        let picked_up = Instant::now();
        let generation = self.current.generation().id();
        let batch_size = batch.items.len();
        let mut jobs: Vec<Arc<QueryJob>> = Vec::with_capacity(batch_size);
        for pending in batch.items {
            let queue_wait = picked_up - pending.submitted;
            // Load shedding: don't fan out answers nobody is waiting
            // for. Wire decode happened before submission and counts
            // against the deadline — a query that burned its whole
            // deadline in decode sheds here, not after computing.
            if let Some(deadline) = pending.req.deadline {
                if queue_wait + Duration::from_nanos(pending.req.decode_ns) > deadline {
                    self.metrics.record_shed();
                    let _ = pending.reply.send(QueryResponse {
                        indices: Vec::new(),
                        scores: Vec::new(),
                        flops: 0,
                        queue_wait,
                        service: Duration::ZERO,
                        batch_size,
                        worker: usize::MAX, // shed before any worker touched it
                        shed: true,
                        degraded: false,
                        epsilon_hat: 0.0,
                        shards: 0,
                        shards_total: self.n_shards,
                        applied_epsilon: pending.applied_epsilon,
                        applied_k: pending.applied_k,
                        storage: Storage::F32,
                        generation,
                    });
                    continue;
                }
            }
            let req = pending.req;
            // The batcher resolved Auto; re-resolve defensively so a
            // future direct producer can't leak Auto into the workers.
            let mode = plan_mode(&req, self.dim);
            // BOUNDEDME always returns ≥ 1 result (the index clamps k);
            // the merge cap must match or it would drop that result.
            let top_k = match mode {
                QueryMode::Exact => req.k,
                _ => req.k.max(1),
            };
            let id = self.next_query;
            self.next_query += 1;
            let storage = match mode {
                QueryMode::Exact => Storage::F32,
                _ => resolve_storage(req.storage, self.storage),
            };
            // Flight recorder: anchor the builder at submission, record
            // the queue span and the plan resolution. (Sheds decided
            // above, before any fan-out, are deliberately not traced —
            // no worker ever touches them.)
            let trace = self.recorder.as_ref().map(|_| {
                let kind = match mode {
                    QueryMode::Exact => "exact",
                    _ => "bounded_me",
                };
                let mut b = Box::new(TraceBuilder::new(pending.submitted, id, kind));
                b.trace.k = req.k;
                b.trace.epsilon = req.epsilon;
                b.trace.delta = req.delta;
                b.trace.storage = storage.label();
                b.trace.generation = generation;
                b.trace.batch_size = batch_size;
                b.trace.shards = self.n_shards;
                b.trace.queue_wait_ns = queue_wait.as_nanos() as u64;
                if req.decode_ns > 0 {
                    // Wire decode happened *before* submission (the
                    // trace origin), so the span is re-anchored at
                    // [0, decode_ns] — it reads as the protocol tax
                    // paid ahead of the queue wait.
                    b.trace.decode_ns = req.decode_ns;
                    b.span_ns("decode", -1, 0, req.decode_ns, Vec::new());
                }
                b.span(
                    "queue",
                    -1,
                    pending.submitted,
                    picked_up,
                    Vec::new(),
                );
                b
            });
            let harvest = self.harvest
                && mode == QueryMode::BoundedMe
                && (req.deadline.is_some() || req.budget_flops.is_some());
            self.merges.insert(
                id,
                MergeState {
                    top: TopK::new(top_k),
                    passthrough: self.n_shards == 1 && mode == QueryMode::BoundedMe,
                    entries_direct: Vec::new(),
                    storage,
                    generation,
                    flops: 0,
                    remaining: self.n_shards,
                    shed: false,
                    covered: 0,
                    harvest,
                    harvested: false,
                    epsilon_hat: 0.0,
                    superseded: false,
                    applied_epsilon: pending.applied_epsilon,
                    applied_k: pending.applied_k,
                    queue_wait,
                    batch_size,
                    started: Instant::now(),
                    trace,
                    reply: pending.reply,
                },
            );
            jobs.push(Arc::new(QueryJob {
                id,
                vector: req.vector,
                k: req.k,
                epsilon: req.epsilon,
                delta: req.delta,
                seed: req.seed,
                mode,
                storage,
                submitted: pending.submitted,
                deadline: req.deadline,
                budget_flops: req.budget_flops,
                decode_ns: req.decode_ns,
                harvest,
            }));
        }
        if jobs.is_empty() {
            return;
        }
        for shard in 0..self.n_shards {
            let dispatch = self.next_dispatch;
            self.next_dispatch += 1;
            let live = Arc::new(AtomicBool::new(true));
            // `items` feeds hedge re-dispatch only; skip the clone when
            // hedging is off (`Vec::new()` does not allocate).
            let hedge_items =
                if self.hedge_delay.is_some() { jobs.clone() } else { Vec::new() };
            self.dispatches.insert(
                dispatch,
                Dispatch {
                    shard,
                    items: hedge_items,
                    sent_at: None,
                    hedge_sent: false,
                    live: live.clone(),
                    set: self.current.clone(),
                },
            );
            self.backlog[shard].push_back(ShardBatch {
                dispatch,
                shard,
                hedged: false,
                live,
                set: self.current.clone(),
                traced: self.recorder.is_some(),
                items: jobs.clone(),
            });
        }
    }

    /// Non-blocking dispatch: drain each shard's backlog into its
    /// channel until the channel is full.
    fn flush_backlogs(&mut self) {
        for s in 0..self.n_shards {
            while let Some(sb) = self.backlog[s].pop_front() {
                let dispatch = sb.dispatch;
                match self.shard_txs[s].try_send(sb) {
                    Ok(()) => {
                        self.metrics.record_dispatch(s);
                        if let Some(d) = self.dispatches.get_mut(&dispatch) {
                            if d.sent_at.is_none() {
                                d.sent_at = Some(Instant::now());
                            }
                        }
                    }
                    Err(SendError::Full(sb)) => {
                        self.backlog[s].push_front(sb);
                        break;
                    }
                    // Worker pool died (panic); nothing to do with the
                    // batch. `run` exits via the done_rx disconnect.
                    Err(SendError::Disconnected(_)) => break,
                }
            }
            // Backlog depth after the flush = what's still queued on
            // the reactor side for this shard (a gauge, not a counter).
            self.metrics.set_queue_depth(s, self.backlog[s].len());
        }
    }

    /// Fire hedges for overdue dispatches; return the next instant a
    /// hedge decision is due (the reactor's park deadline). The scan is
    /// linear in outstanding dispatches, which admission control bounds
    /// at roughly `(backlog cap + channel cap + in-compute) × S` — a
    /// small constant independent of throughput, so no heap of due
    /// times is warranted.
    fn drive_hedges(&mut self) -> Option<Instant> {
        let delay = self.hedge_delay?;
        let now = Instant::now();
        let mut next: Option<Instant> = None;
        let refresh = |next: &mut Option<Instant>, t: Instant| {
            *next = Some(next.map_or(t, |n| n.min(t)));
        };
        for (&id, disp) in self.dispatches.iter_mut() {
            if disp.hedge_sent {
                continue;
            }
            let Some(sent) = disp.sent_at else { continue };
            let due = sent + delay;
            if due <= now {
                let sb = ShardBatch {
                    dispatch: id,
                    shard: disp.shard,
                    hedged: true,
                    live: disp.live.clone(),
                    set: disp.set.clone(),
                    traced: self.recorder.is_some(),
                    items: disp.items.clone(),
                };
                if self.hedge_tx.try_send(sb).is_ok() {
                    disp.hedge_sent = true;
                    self.metrics.record_hedge_fired(disp.shard);
                } else {
                    // Hedge queue full: the pool is saturated and a
                    // duplicate would only add load. Back off one delay
                    // (floored so a zero delay cannot busy-spin here).
                    refresh(&mut next, now + delay.max(Duration::from_micros(100)));
                }
            } else {
                refresh(&mut next, due);
            }
        }
        next
    }

    /// Fold one completion event. Duplicate suppression happens here:
    /// the first event for a dispatch retires its entry; the losing
    /// copy of a hedged dispatch finds no entry and is dropped whole,
    /// so no shard ever contributes twice to a merge.
    fn on_done(&mut self, done: ShardDone) {
        let now = Instant::now();
        let (shard, sent_at, hedge_sent) = match self.dispatches.remove(&done.dispatch) {
            // Retire the dispatch: any still-queued sibling copy sees
            // the cleared flag at pickup and skips its scan.
            Some(d) => {
                d.live.store(false, Ordering::Relaxed);
                (d.shard, d.sent_at, d.hedge_sent)
            }
            None => return, // losing copy of a hedged dispatch
        };
        if done.hedged {
            self.metrics.record_hedge_won(shard);
        }
        if let Some(sent) = sent_at {
            // Fan-out → fold window of this shard's slice: the
            // per-shard merge latency an adaptive hedge delay would
            // consume.
            self.metrics.record_merge(shard, now.saturating_duration_since(sent));
        }
        for QueryDone { query, partial, expired, superseded, harvest, exec } in done.results {
            let Some(m) = self.merges.get_mut(&query) else { continue };
            m.shed |= expired;
            if !expired {
                m.covered += 1;
            }
            if let Some(h) = harvest {
                m.harvested = true;
                m.epsilon_hat = m.epsilon_hat.max(h.epsilon_hat);
            }
            m.superseded |= superseded;
            m.flops += partial.flops;
            if let Some(tb) = m.trace.as_deref_mut() {
                tb.trace.hedge_fired |= hedge_sent;
                tb.trace.hedge_won |= done.hedged;
                let sid = shard as i64;
                let start = sent_at.unwrap_or(now);
                tb.span(
                    "shard",
                    sid,
                    start,
                    now,
                    vec![
                        ("worker", done.worker as f64),
                        ("hedged", if done.hedged { 1.0 } else { 0.0 }),
                        ("hedge_fired", if hedge_sent { 1.0 } else { 0.0 }),
                    ],
                );
                if let Some(picked) = done.picked {
                    // Channel wait vs compute split of the shard window.
                    tb.span("shard_wait", sid, start, picked, Vec::new());
                    tb.span("shard_compute", sid, picked, now, Vec::new());
                }
                if let Some(exec) = exec.as_deref() {
                    push_exec_spans(tb, sid, exec);
                }
            }
            if m.passthrough {
                m.entries_direct = partial.entries;
            } else {
                for (score, id) in partial.entries {
                    m.top.push(score, id);
                }
            }
            m.remaining -= 1;
            if m.remaining == 0 {
                let m = self.merges.remove(&query).expect("merge state present");
                self.send_reply(m, done.worker);
            }
        }
    }

    fn send_reply(&self, m: MergeState, worker: usize) {
        let service = m.started.elapsed();
        // Harvest-not-shed: a budget-armed merge sheds only when *no*
        // shard produced a usable partial. Any expired shard otherwise
        // degrades the reply — partial coverage — and a mid-flight
        // harvest (checkpointed rounds) degrades it too. Unarmed
        // queries (exact mode, or harvesting disabled) keep the
        // pre-anytime contract: any expired shard sheds whole.
        let shed = m.shed && (m.covered == 0 || !m.harvest);
        let degraded = !shed && (m.harvested || (m.shed && m.covered < self.n_shards));
        // Flight recorder: stamp the roll-up and publish (sampling and
        // the slow-query warn line both happen inside `publish`).
        if let (Some(rec), Some(mut tb)) = (self.recorder.as_ref(), m.trace) {
            tb.trace.service_ns = service.as_nanos() as u64;
            tb.trace.shed = shed;
            tb.trace.degraded = degraded;
            tb.trace.epsilon_hat = m.epsilon_hat;
            if shed {
                tb.trace.kind = "shed";
            } else if degraded {
                tb.trace.kind = "degraded";
            }
            rec.publish(*tb);
        }
        if shed {
            // Every shard saw the deadline expired at pickup (or
            // harvesting is off): the client has timed out with nothing
            // usable, reply shed (no results; `flops` reports whatever
            // work other shards had already sunk).
            self.metrics.record_shed();
            if m.superseded {
                self.metrics.record_shed_superseded();
            }
            let _ = m.reply.send(QueryResponse {
                indices: Vec::new(),
                scores: Vec::new(),
                flops: m.flops,
                queue_wait: m.queue_wait,
                service,
                batch_size: m.batch_size,
                worker,
                shed: true,
                degraded: false,
                epsilon_hat: 0.0,
                shards: 0,
                shards_total: self.n_shards,
                storage: Storage::F32,
                generation: m.generation,
                applied_epsilon: m.applied_epsilon,
                applied_k: m.applied_k,
            });
            return;
        }
        self.metrics.record_query(m.queue_wait, service, m.flops);
        if degraded {
            self.metrics.record_degraded();
        }
        let ranked =
            if m.passthrough { m.entries_direct } else { m.top.into_sorted() };
        let _ = m.reply.send(QueryResponse {
            indices: ranked.iter().map(|&(_, i)| i).collect(),
            scores: ranked.iter().map(|&(s, _)| s).collect(),
            flops: m.flops,
            queue_wait: m.queue_wait,
            service,
            batch_size: m.batch_size,
            worker,
            shed: false,
            degraded,
            epsilon_hat: m.epsilon_hat,
            shards: if m.shed { m.covered } else { self.n_shards },
            shards_total: self.n_shards,
            storage: m.storage,
            generation: m.generation,
            applied_epsilon: m.applied_epsilon,
            applied_k: m.applied_k,
        });
    }
}

/// Reactor-path worker loop: poll the pinned shard's primary channel,
/// then the shared hedge channel (primary work first — hedges are
/// other shards' stragglers), park on the selector when both are
/// empty. Exits when the primary channel disconnects (reactor done).
#[allow(clippy::too_many_arguments)]
fn run_reactor_worker(
    worker_id: usize,
    pinned: usize,
    primary: Receiver<ShardBatch>,
    hedge_rx: Receiver<ShardBatch>,
    done_tx: Sender<ShardDone>,
    resident: &Matrix,
    engine: &dyn ScoringEngine,
    latest_gen: &AtomicU64,
    slow: Option<(usize, Duration)>,
) {
    let mut ctx = QueryContext::new();
    let selector = Selector::new();
    selector.watch(&primary);
    selector.watch(&hedge_rx);
    loop {
        let sb = match primary.try_recv() {
            Ok(sb) => Some(sb),
            Err(TryRecvError::Disconnected) => return,
            Err(TryRecvError::Empty) => match hedge_rx.try_recv() {
                Ok(sb) => Some(sb),
                Err(_) => None,
            },
        };
        match sb {
            Some(sb) => {
                // A copy whose dispatch already completed (its sibling
                // won) is dead weight: skip the scan, send nothing —
                // the reactor retired the dispatch and expects no
                // further event for it.
                if !sb.live.load(Ordering::Relaxed) {
                    continue;
                }
                let done = serve_reactor_batch(
                    sb, worker_id, pinned, resident, engine, &mut ctx, latest_gen, slow,
                );
                if done_tx.send(done).is_err() {
                    return; // reactor gone (shutdown): stop serving
                }
            }
            None => selector.wait(),
        }
    }
}

/// Execute one shard's slice of a dispatched batch and report it as a
/// completion event:
///
/// 1. deadline re-check at pickup (expired items produce empty,
///    `expired`-flagged outcomes — the merge replies shed);
/// 2. exact items: **one** [`ScoringEngine::score_dataset_batch`] call
///    over the shard for the whole group, then per-query top-K partials
///    under dataset-global ids;
/// 3. BOUNDEDME items: with real sharding, the sample-then-confirm
///    entry point [`BoundedMeIndex::query_batch_shard`] at the
///    `(ε, δ/S)` split; with `S = 1` (forced reactor), the legacy fused
///    paths whose ranked results pass through the merge untouched.
///
/// Hedged copies compute the identical partials (same shard data, same
/// knobs, same seed) — whichever copy wins, the merge sees the same
/// bytes.
#[allow(clippy::too_many_arguments)]
fn serve_reactor_batch(
    sb: ShardBatch,
    worker_id: usize,
    pinned: usize,
    resident: &Matrix,
    engine: &dyn ScoringEngine,
    ctx: &mut QueryContext,
    latest_gen: &AtomicU64,
    slow: Option<(usize, Duration)>,
) -> ShardDone {
    // Pickup timestamp before the straggler injection so an injected
    // slow shard is attributed to compute, like a genuinely slow one.
    let picked = if sb.traced { Some(Instant::now()) } else { None };
    if sb.traced {
        ctx.trace.arm();
    }
    if let Some((slow_shard, delay)) = slow {
        // Deterministic straggler injection: primaries on the slow
        // shard crawl, hedge copies run full speed.
        if !sb.hedged && sb.shard == slow_shard {
            std::thread::sleep(delay);
        }
    }
    let set = &sb.set;
    let n_shards = set.num_shards();
    let shard = set.shard(sb.shard);
    let index = set.index(sb.shard).as_ref();
    let data = index.data();
    let (rows, dim) = (data.rows(), data.cols());
    // Stale-generation marker for the shed path: a flip has started
    // past this batch's pin (Relaxed is enough — the flag only
    // annotates sheds, it never gates correctness).
    let superseded_gen = set.generation().id() < latest_gen.load(Ordering::Relaxed);
    let mut results: Vec<QueryDone> = Vec::with_capacity(sb.items.len());

    let mut exact: Vec<&Arc<QueryJob>> = Vec::new();
    let mut bme: Vec<&Arc<QueryJob>> = Vec::new();
    for item in &sb.items {
        // Re-check the deadline at shard pickup: the reactor's check can
        // be long past by the time a backed-up shard channel drains, and
        // computing an answer the client timed out on wastes a full
        // shard scan (× S shards). A query that is late *and* pinned to
        // a superseded generation is the churn-specific shed —
        // `shed_superseded` makes that visible; in-deadline queries
        // always finish on their pin, superseded or not.
        if let Some(deadline) = item.deadline {
            // Decode time already spent on the wire thread counts
            // against the budget clock (see `deadline_instant`).
            if Instant::now() > deadline_instant(item.submitted, deadline, item.decode_ns) {
                results.push(QueryDone {
                    query: item.id,
                    partial: ShardPartial { entries: Vec::new(), flops: 0, scanned: 0 },
                    expired: true,
                    superseded: superseded_gen,
                    harvest: None,
                    exec: None,
                });
                continue;
            }
        }
        match item.mode {
            QueryMode::Exact => exact.push(item),
            _ => bme.push(item),
        }
    }

    // --- Exact group: one engine call for the whole group. ---
    if !exact.is_empty() {
        let queries: Vec<&[f32]> = exact.iter().map(|it| it.vector.as_slice()).collect();
        // The worker's engine may hold a *different* shard, or a
        // *previous generation* of its own shard, device-resident (PJRT
        // preloads generation 0 of the pinned shard). A flipped shard
        // can alias different bytes at an equal row count, so the
        // device-path gate is pointer identity with the preloaded
        // matrix; everything else scores through the native blocked
        // kernels — bit-identical to the engine path under the Native
        // backend.
        let resident_ok = sb.shard == pinned
            && data.rows() == resident.rows()
            && std::ptr::eq(data.as_slice().as_ptr(), resident.as_slice().as_ptr());
        let fused_ok = if resident_ok {
            engine.score_dataset_batch(data, &queries, &mut ctx.rank.scores).is_ok()
        } else {
            NativeEngine.score_dataset_batch(data, &queries, &mut ctx.rank.scores).is_ok()
        };
        for (gi, item) in exact.iter().enumerate() {
            let mut top = TopK::new(item.k);
            if fused_ok {
                let slab = &ctx.rank.scores[gi * rows..(gi + 1) * rows];
                for (i, &s) in slab.iter().enumerate() {
                    top.push(s, shard.global_id(i));
                }
            } else {
                // Engine failure (e.g. backend died): pure-Rust fallback.
                let scores = data.matvec(&item.vector);
                for (i, &s) in scores.iter().enumerate() {
                    top.push(s, shard.global_id(i));
                }
            }
            results.push(QueryDone {
                query: item.id,
                partial: ShardPartial {
                    entries: top.into_sorted(),
                    flops: (rows * dim) as u64,
                    scanned: rows,
                },
                expired: false,
                superseded: false,
                harvest: None,
                exec: None,
            });
        }
    }

    // --- BOUNDEDME group: shared permutation; the batcher's knob
    // grouping makes whole groups uniform, so the fused path is the
    // common case. ---
    if !bme.is_empty() {
        let knobs =
            |it: &Arc<QueryJob>| (it.k, it.epsilon.to_bits(), it.delta.to_bits(), it.storage);
        let uniform = bme.windows(2).all(|w| knobs(w[0]) == knobs(w[1]));
        // Anytime budget per item: only budget-armed items (harvest
        // resolved at admission) carry a live deadline / flop cap into
        // the bandit; everything else runs under `NONE`, which is
        // bit-identical to the plain entry points.
        let any_armed = bme.iter().any(|it| it.harvest);
        let item_budget = |it: &Arc<QueryJob>| {
            if it.harvest {
                AnytimeBudget {
                    deadline: it
                        .deadline
                        .map(|d| deadline_instant(it.submitted, d, it.decode_ns)),
                    budget_flops: it.budget_flops,
                }
            } else {
                AnytimeBudget::NONE
            }
        };
        if n_shards == 1 {
            // Forced reactor over a single shard: legacy unsharded
            // semantics (estimate scores, no confirm). The merge passes
            // these entries through in the bandit's ranking
            // (`passthrough`), bit-identical to the fast path.
            let mut push_direct = |id: u64, res: MipsResult, harvest: Option<Harvest>| {
                let entries: Vec<(f32, usize)> = res
                    .scores
                    .iter()
                    .copied()
                    .zip(res.indices.iter().copied())
                    .collect();
                results.push(QueryDone {
                    query: id,
                    partial: ShardPartial {
                        entries,
                        flops: res.flops,
                        scanned: res.candidates,
                    },
                    expired: false,
                    superseded: false,
                    harvest,
                    exec: None,
                });
            };
            if uniform && bme.len() > 1 && !any_armed {
                // The first item's seed keys the batch's shared pull order.
                let first = bme[0];
                let params = MipsParams {
                    k: first.k,
                    epsilon: first.epsilon,
                    delta: first.delta,
                    seed: first.seed,
                };
                let queries: Vec<&[f32]> = bme.iter().map(|it| it.vector.as_slice()).collect();
                for (item, res) in
                    bme.iter().zip(index.query_batch_tier(&queries, &params, ctx, first.storage))
                {
                    push_direct(item.id, res, None);
                }
            } else {
                // Per-item path (mixed knobs, singleton batches, or any
                // budget-armed item). `query_batch_tier` is itself a
                // per-query loop, so this split changes no bits for
                // unarmed items.
                for item in &bme {
                    let params = MipsParams {
                        k: item.k,
                        epsilon: item.epsilon,
                        delta: item.delta,
                        seed: item.seed,
                    };
                    let (res, harvest) = index.query_with_tier_budget(
                        &item.vector,
                        &params,
                        ctx,
                        item.storage,
                        item_budget(item),
                    );
                    push_direct(item.id, res, harvest);
                }
            }
        } else if uniform && bme.len() > 1 && !any_armed {
            let first = bme[0];
            let params = MipsParams {
                k: first.k,
                epsilon: first.epsilon,
                delta: first.delta,
                seed: first.seed,
            };
            let split = shard_params(&params, n_shards, shard.rows());
            let queries: Vec<&[f32]> = bme.iter().map(|it| it.vector.as_slice()).collect();
            for (item, partial) in bme
                .iter()
                .zip(index.query_batch_shard_tier(&queries, &split, ctx, shard, first.storage))
            {
                results.push(QueryDone {
                    query: item.id,
                    partial,
                    expired: false,
                    superseded: false,
                    harvest: None,
                    exec: None,
                });
            }
        } else {
            for item in &bme {
                let params = MipsParams {
                    k: item.k,
                    epsilon: item.epsilon,
                    delta: item.delta,
                    seed: item.seed,
                };
                let split = shard_params(&params, n_shards, shard.rows());
                let (partial, harvest) = index.query_shard_tier_budget(
                    &item.vector,
                    &split,
                    ctx,
                    shard,
                    item.storage,
                    item_budget(item),
                );
                results.push(QueryDone {
                    query: item.id,
                    partial,
                    expired: false,
                    superseded: false,
                    harvest,
                    exec: None,
                });
            }
        }
    }

    // Traced batches: the BOUNDEDME paths above staged exactly one
    // QueryExec per *served* (non-expired) bme query, in query order —
    // and those results are the tail of `results` in the same order.
    if sb.traced {
        let execs = ctx.trace.finish();
        let base = results.len() - execs.len();
        for (i, exec) in execs.into_iter().enumerate() {
            results[base + i].exec = Some(Box::new(exec));
        }
    }

    ShardDone {
        dispatch: sb.dispatch,
        worker: worker_id,
        hedged: sb.hedged,
        picked,
        results,
    }
}

/// Append a staged execution's bandit / per-round / confirm spans to a
/// trace. The round spans tile the bandit window front-to-back
/// (cumulative [`crate::bandit::RoundTrace::nanos`] offsets), so their
/// sum never exceeds `bandit_ns`. Shared by the reactor merge and the
/// S = 1 direct path.
fn push_exec_spans(tb: &mut TraceBuilder, shard: i64, exec: &QueryExec) {
    let b0 = tb.offset_ns(exec.started);
    tb.span_ns(
        "bandit",
        shard,
        b0,
        b0 + exec.bandit_ns,
        vec![
            ("pulls", exec.total_pulls as f64),
            ("rounds", exec.rounds.len() as f64),
            ("quant", if exec.quant { 1.0 } else { 0.0 }),
            ("quant_fallback", if exec.quant_fallback { 1.0 } else { 0.0 }),
        ],
    );
    let mut off = b0;
    for r in &exec.rounds {
        tb.span_ns(
            "round",
            shard,
            off,
            off + r.nanos,
            vec![
                ("round", r.round as f64),
                ("survivors", r.survivors as f64),
                ("t_l", r.t_l as f64),
                ("epsilon_l", r.epsilon_l),
                ("delta_l", r.delta_l),
                ("epsilon_hat", r.epsilon_hat),
                ("compacted", if r.compacted { 1.0 } else { 0.0 }),
            ],
        );
        off += r.nanos;
    }
    if exec.confirm_ns > 0 {
        let c0 = b0 + exec.bandit_ns;
        tb.span_ns("confirm", shard, c0, c0 + exec.confirm_ns, Vec::new());
    }
    if let Some(eps_hat) = exec.harvest {
        // Budget fired mid-run: a zero-width marker span carrying the
        // achieved width of the checkpointed answer.
        let h0 = b0 + exec.bandit_ns;
        tb.span_ns("harvest", shard, h0, h0, vec![("epsilon_hat", eps_hat)]);
    }
}

/// S = 1 fast-path worker loop: batches arrive straight from the
/// batcher, answers go straight to the client. One long-lived
/// [`QueryContext`]; no reactor state anywhere on this path. Each
/// worker is its own generation-flip consumer: flips drain (and ack)
/// between batches, so the serving set swap is a local `Arc` move —
/// still no lock anywhere on the fast path.
#[allow(clippy::too_many_arguments)]
fn run_direct_worker(
    worker_id: usize,
    rx: Receiver<Batch>,
    flip_rx: Receiver<Flip>,
    mut set: Arc<ShardSet>,
    resident: &Matrix,
    engine: &dyn ScoringEngine,
    metrics: &MetricsRegistry,
    recorder: Option<TraceRecorder>,
    harvest_enabled: bool,
) {
    let mut ctx = QueryContext::new();
    // Direct-path trace ids: worker-local submission counter (there is
    // no reactor to hand out global ids; the published seq orders
    // traces globally).
    let mut next_trace_id: u64 = 0;
    let selector = Selector::new();
    selector.watch(&rx);
    selector.watch(&flip_rx);
    loop {
        // Flips apply between batches only; the ack (sent after the
        // swap) is what lets mutate() promise post-return visibility.
        while let Ok(flip) = flip_rx.try_recv() {
            set = flip.set;
            let _ = flip.ack.send(());
        }
        match rx.try_recv() {
            Ok(batch) => {
                serve_direct_batch(
                    worker_id,
                    batch,
                    &set,
                    resident,
                    engine,
                    &mut ctx,
                    metrics,
                    recorder.as_ref(),
                    &mut next_trace_id,
                    harvest_enabled,
                );
            }
            Err(TryRecvError::Empty) => selector.wait(),
            Err(TryRecvError::Disconnected) => return,
        }
    }
}

/// Execute one fast-path batch and reply per query. Identical compute
/// to the reactor path at `S = 1` — same fused engine call for exact
/// groups, same fused/per-query BOUNDEDME paths — so answers are
/// bit-identical to the merge path; the saving is pure overhead (no
/// `Arc`-wrapped merge state, no completion event, no reactor hop).
#[allow(clippy::too_many_arguments)]
fn serve_direct_batch(
    worker_id: usize,
    batch: Batch,
    set: &ShardSet,
    resident: &Matrix,
    engine: &dyn ScoringEngine,
    ctx: &mut QueryContext,
    metrics: &MetricsRegistry,
    recorder: Option<&TraceRecorder>,
    next_trace_id: &mut u64,
    harvest_enabled: bool,
) {
    let picked_up = Instant::now();
    if recorder.is_some() {
        ctx.trace.arm();
    }
    let index = set.index(0).as_ref();
    let shard = set.shard(0);
    let generation = set.generation().id();
    let data = index.data();
    let (rows, dim) = (data.rows(), data.cols());
    let batch_size = batch.items.len();

    let mut exact: Vec<&Pending> = Vec::new();
    let mut bme: Vec<&Pending> = Vec::new();
    for pending in &batch.items {
        let queue_wait = picked_up - pending.submitted;
        if let Some(deadline) = pending.req.deadline {
            // Decode time counts against the budget clock: a query
            // that burned its whole deadline in the wire decoder sheds
            // here even if it reached the worker instantly.
            if queue_wait + Duration::from_nanos(pending.req.decode_ns) > deadline {
                metrics.record_shed();
                let _ = pending.reply.send(QueryResponse {
                    indices: Vec::new(),
                    scores: Vec::new(),
                    flops: 0,
                    queue_wait,
                    service: Duration::ZERO,
                    batch_size,
                    worker: usize::MAX, // shed: no worker computed anything
                    shed: true,
                    degraded: false,
                    epsilon_hat: 0.0,
                    shards: 0,
                    shards_total: 1,
                    storage: Storage::F32,
                    generation,
                    applied_epsilon: pending.applied_epsilon,
                    applied_k: pending.applied_k,
                });
                continue;
            }
        }
        match pending.req.mode {
            QueryMode::Exact => exact.push(pending),
            _ => bme.push(pending),
        }
    }

    let mut respond = |pending: &Pending,
                       indices: Vec<usize>,
                       scores: Vec<f32>,
                       flops: u64,
                       storage: Storage,
                       harvest: Option<f64>,
                       exec: Option<&QueryExec>| {
        let queue_wait = picked_up - pending.submitted;
        let service = picked_up.elapsed();
        let degraded = harvest.is_some();
        let epsilon_hat = harvest.unwrap_or(0.0);
        metrics.record_query(queue_wait, service, flops);
        metrics.record_fast_path();
        if degraded {
            metrics.record_degraded();
        }
        if let Some(rec) = recorder {
            let kind = if degraded {
                "degraded"
            } else {
                match pending.req.mode {
                    QueryMode::Exact => "exact",
                    _ => "bounded_me",
                }
            };
            let id = *next_trace_id;
            *next_trace_id += 1;
            let mut tb = TraceBuilder::new(pending.submitted, id, kind);
            tb.trace.k = pending.req.k;
            tb.trace.epsilon = pending.req.epsilon;
            tb.trace.delta = pending.req.delta;
            tb.trace.storage = storage.label();
            tb.trace.generation = generation;
            tb.trace.batch_size = batch_size;
            tb.trace.shards = 1;
            tb.trace.queue_wait_ns = queue_wait.as_nanos() as u64;
            tb.trace.service_ns = service.as_nanos() as u64;
            tb.trace.degraded = degraded;
            tb.trace.epsilon_hat = epsilon_hat;
            if pending.req.decode_ns > 0 {
                // Decode precedes submission (the trace origin); the
                // span is re-anchored at [0, decode_ns].
                tb.trace.decode_ns = pending.req.decode_ns;
                tb.span_ns("decode", -1, 0, pending.req.decode_ns, Vec::new());
            }
            tb.span("queue", -1, pending.submitted, picked_up, Vec::new());
            tb.span(
                "compute",
                0,
                picked_up,
                Instant::now(),
                vec![("worker", worker_id as f64)],
            );
            if let Some(exec) = exec {
                push_exec_spans(&mut tb, 0, exec);
            }
            rec.publish(tb);
        }
        let _ = pending.reply.send(QueryResponse {
            indices,
            scores,
            flops,
            queue_wait,
            service,
            batch_size,
            worker: worker_id,
            shed: false,
            degraded,
            epsilon_hat,
            shards: 1,
            shards_total: 1,
            storage,
            generation,
            applied_epsilon: pending.applied_epsilon,
            applied_k: pending.applied_k,
        });
    };

    // --- Exact group: one engine call for the whole group. ---
    if !exact.is_empty() {
        let queries: Vec<&[f32]> = exact.iter().map(|p| p.req.vector.as_slice()).collect();
        // The engine preloaded generation 0 (PJRT device residency);
        // after a flip this set's rows are different bytes — pointer
        // identity gates the device path, native kernels otherwise.
        let resident_ok = data.rows() == resident.rows()
            && std::ptr::eq(data.as_slice().as_ptr(), resident.as_slice().as_ptr());
        let fused_ok = if resident_ok {
            engine.score_dataset_batch(data, &queries, &mut ctx.rank.scores).is_ok()
        } else {
            NativeEngine.score_dataset_batch(data, &queries, &mut ctx.rank.scores).is_ok()
        };
        for (gi, pending) in exact.iter().enumerate() {
            let mut top = TopK::new(pending.req.k);
            if fused_ok {
                let slab = &ctx.rank.scores[gi * rows..(gi + 1) * rows];
                for (i, &s) in slab.iter().enumerate() {
                    top.push(s, shard.global_id(i));
                }
            } else {
                let scores = data.matvec(&pending.req.vector);
                for (i, &s) in scores.iter().enumerate() {
                    top.push(s, shard.global_id(i));
                }
            }
            let ranked = top.into_sorted();
            respond(
                pending,
                ranked.iter().map(|&(_, i)| i).collect(),
                ranked.iter().map(|&(s, _)| s).collect(),
                (rows * dim) as u64,
                Storage::F32,
                None,
                None,
            );
        }
    }

    // --- BOUNDEDME group (estimate scores, legacy unsharded semantics). ---
    if bme.is_empty() {
        return;
    }
    // Per-request tier overrides resolve against the deployment tier
    // the shard index holds; the batcher already grouped by the
    // resolved tier, so `uniform` batches hit the fused path per tier.
    let tier = |p: &Pending| resolve_storage(p.req.storage, index.storage());
    let knobs = |p: &Pending| (p.req.k, p.req.epsilon.to_bits(), p.req.delta.to_bits(), tier(p));
    let uniform = bme.windows(2).all(|w| knobs(w[0]) == knobs(w[1]));
    // Anytime budget arming mirrors the reactor's admission logic: only
    // BOUNDEDME queries that actually set a deadline or flop budget run
    // under a live `AnytimeBudget`; everything else stays on the plain
    // (bit-identical) entry points.
    let armed = |p: &Pending| {
        harvest_enabled
            && p.req.mode == QueryMode::BoundedMe
            && (p.req.deadline.is_some() || p.req.budget_flops.is_some())
    };
    let item_budget = |p: &Pending| {
        if armed(p) {
            AnytimeBudget {
                deadline: p
                    .req
                    .deadline
                    .map(|d| deadline_instant(p.submitted, d, p.req.decode_ns)),
                budget_flops: p.req.budget_flops,
            }
        } else {
            AnytimeBudget::NONE
        }
    };
    let any_armed = bme.iter().any(|p| armed(p));
    if uniform && bme.len() > 1 && !any_armed {
        let first = &bme[0].req;
        let storage = tier(bme[0]);
        let params =
            MipsParams { k: first.k, epsilon: first.epsilon, delta: first.delta, seed: first.seed };
        let queries: Vec<&[f32]> = bme.iter().map(|p| p.req.vector.as_slice()).collect();
        let batch_res = index.query_batch_tier(&queries, &params, ctx, storage);
        // One staged QueryExec per bme query, in order (empty when the
        // stage is disarmed — `get` then yields None throughout).
        let execs = ctx.trace.finish();
        for (i, (pending, res)) in bme.iter().zip(batch_res).enumerate() {
            respond(pending, res.indices, res.scores, res.flops, storage, None, execs.get(i));
        }
    } else {
        // Per-item path (mixed knobs, singletons, or budget-armed
        // items). `query_batch_tier` is itself a per-query loop, so
        // this split changes no bits for unarmed items.
        for pending in &bme {
            let storage = tier(pending);
            let params = MipsParams {
                k: pending.req.k,
                epsilon: pending.req.epsilon,
                delta: pending.req.delta,
                seed: pending.req.seed,
            };
            let (res, harvest) = index.query_with_tier_budget(
                &pending.req.vector,
                &params,
                ctx,
                storage,
                item_budget(pending),
            );
            let exec = ctx.trace.queries.pop();
            respond(
                pending,
                res.indices,
                res.scores,
                res.flops,
                storage,
                harvest.map(|h| h.epsilon_hat),
                exec.as_ref(),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::gaussian_dataset;

    fn small_coordinator(workers: usize, queue: usize) -> (Coordinator, Matrix) {
        let ds = gaussian_dataset(200, 64, 42);
        let cfg = CoordinatorConfig {
            workers,
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: queue,
            backend: Backend::Native,
            pull_order: PullOrder::BlockShuffled(16),
            shard: ShardSpec::single(),
            ..Default::default()
        };
        let data = ds.vectors.clone();
        (Coordinator::new(ds.vectors, cfg).unwrap(), data)
    }

    #[test]
    fn exact_query_round_trips() {
        let (c, data) = small_coordinator(2, 64);
        let q = vec![0.5f32; 64];
        let resp = c.query_blocking(QueryRequest::exact(q.clone(), 5)).unwrap();
        assert_eq!(resp.indices.len(), 5);
        let truth = crate::algos::ground_truth(&data, &q, 5);
        assert_eq!(resp.indices, truth);
        c.shutdown();
    }

    #[test]
    fn bounded_me_query_served() {
        let (c, data) = small_coordinator(1, 64);
        let q = vec![0.25f32; 64];
        let resp = c
            .query_blocking(QueryRequest::bounded_me(q.clone(), 3, 1e-9, 0.05))
            .unwrap();
        // ε→0 ⇒ exact elimination.
        let mut got = resp.indices.clone();
        got.sort_unstable();
        let mut want = crate::algos::ground_truth(&data, &q, 3);
        want.sort_unstable();
        assert_eq!(got, want);
        assert!(resp.flops <= (200 * 64) as u64);
        c.shutdown();
    }

    #[test]
    fn dim_mismatch_rejected() {
        let (c, _) = small_coordinator(1, 8);
        let Err(err) = c.submit(QueryRequest::exact(vec![0.0; 3], 1)) else {
            panic!("expected DimMismatch");
        };
        assert!(matches!(err, CoordinatorError::DimMismatch { got: 3, want: 64 }));
        c.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let (c, _) = small_coordinator(4, 256);
        let mut handles = Vec::new();
        for i in 0..64u64 {
            let q = vec![(i as f32 % 7.0) - 3.0; 64];
            handles.push(c.submit(QueryRequest::bounded_me(q, 2, 0.3, 0.2)).unwrap());
        }
        for h in handles {
            let resp = h.recv().unwrap();
            assert_eq!(resp.indices.len(), 2);
        }
        let snap = c.metrics();
        assert_eq!(snap.queries, 64);
        assert!(snap.mean_batch_size >= 1.0);
        // S = 1: every answer went worker → client directly.
        assert_eq!(snap.fast_path, 64);
        c.shutdown();
    }

    #[test]
    fn auto_mode_routes_and_answers() {
        let (c, data) = small_coordinator(2, 128);
        // Tight knobs on a 64-dim dataset: the plan routes to Exact, so
        // the answer must be the exact top-k.
        let q = vec![0.4f32; 64];
        let resp = c.query_blocking(QueryRequest::auto(q.clone(), 4, 1e-12, 0.05)).unwrap();
        assert_eq!(resp.indices, crate::algos::ground_truth(&data, &q, 4));
        // Loose knobs: still a valid 4-set (BOUNDEDME path).
        let resp = c.query_blocking(QueryRequest::auto(q, 4, 0.5, 0.3)).unwrap();
        assert_eq!(resp.indices.len(), 4);
        c.shutdown();
    }

    #[test]
    fn batched_exact_queries_stay_exact() {
        // Force real batches of mixed exact queries and check every
        // answer against ground truth — the fused score_dataset_batch
        // path must be indistinguishable from per-query scoring.
        let ds = gaussian_dataset(150, 48, 12);
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 16,
            batch_timeout: Duration::from_millis(20),
            queue_capacity: 256,
            backend: Backend::Native,
            pull_order: PullOrder::Sequential,
            shard: ShardSpec::single(),
            ..Default::default()
        };
        let data = ds.vectors.clone();
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        let mut handles = Vec::new();
        let mut queries = Vec::new();
        for i in 0..24u64 {
            let mut q = vec![0.0f32; 48];
            q[(i as usize) % 48] = 1.0;
            q[(i as usize * 7) % 48] = -0.5;
            queries.push(q.clone());
            handles.push(c.submit(QueryRequest::exact(q, 3)).unwrap());
        }
        let mut max_batch_seen = 0;
        for (h, q) in handles.into_iter().zip(&queries) {
            let resp = h.recv().unwrap();
            max_batch_seen = max_batch_seen.max(resp.batch_size);
            assert_eq!(resp.indices, crate::algos::ground_truth(&data, q, 3));
        }
        assert!(max_batch_seen > 1, "no batching under burst load");
        c.shutdown();
    }

    #[test]
    fn batched_bounded_me_matches_index_results() {
        // Uniform knobs + burst ⇒ the worker takes the query_batch path
        // with the first item's seed; with ε→0 every answer must still
        // be the exact top-k set.
        let ds = gaussian_dataset(120, 64, 13);
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 8,
            batch_timeout: Duration::from_millis(20),
            queue_capacity: 256,
            backend: Backend::Native,
            pull_order: PullOrder::BlockShuffled(16),
            shard: ShardSpec::single(),
            ..Default::default()
        };
        let data = ds.vectors.clone();
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        let mut handles = Vec::new();
        let mut queries = Vec::new();
        for i in 0..16u64 {
            let q: Vec<f32> = (0..64).map(|j| ((i + j) % 5) as f32 - 2.0).collect();
            queries.push(q.clone());
            handles.push(c.submit(QueryRequest::bounded_me(q, 3, 1e-9, 0.05)).unwrap());
        }
        for (h, q) in handles.into_iter().zip(&queries) {
            let resp = h.recv().unwrap();
            let mut got = resp.indices.clone();
            got.sort_unstable();
            let mut want = crate::algos::ground_truth(&data, q, 3);
            want.sort_unstable();
            assert_eq!(got, want);
        }
        c.shutdown();
    }

    #[test]
    fn backpressure_fires_when_queue_full() {
        // Queue of 1, zero workers draining fast: spam submissions until
        // QueueFull appears.
        let ds = gaussian_dataset(2000, 128, 7);
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 1,
            batch_timeout: Duration::from_millis(0),
            queue_capacity: 2,
            backend: Backend::Native,
            pull_order: PullOrder::Sequential,
            shard: ShardSpec::single(),
            ..Default::default()
        };
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        let mut saw_full = false;
        let mut receivers = Vec::new();
        for _ in 0..2000 {
            match c.submit(QueryRequest::exact(vec![0.1; 128], 1)) {
                Ok(rx) => receivers.push(rx),
                Err(CoordinatorError::QueueFull) => {
                    saw_full = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(saw_full, "backpressure never engaged");
        for rx in receivers {
            let _ = rx.recv();
        }
        c.shutdown();
    }

    #[test]
    fn sharded_coordinator_matches_ground_truth() {
        let ds = gaussian_dataset(101, 64, 33);
        let cfg = CoordinatorConfig {
            workers: 3,
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: 128,
            backend: Backend::Native,
            pull_order: PullOrder::BlockShuffled(16),
            shard: ShardSpec::contiguous(3),
            ..Default::default()
        };
        let data = ds.vectors.clone();
        let q = ds.sample_query(2);
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        let resp = c.query_blocking(QueryRequest::exact(q.clone(), 5)).unwrap();
        assert_eq!(resp.shards, 3);
        assert_eq!(resp.indices, crate::algos::ground_truth(&data, &q, 5));
        // BOUNDEDME ε→0 through sample-then-confirm: per-shard exact
        // elimination + exact rescore ⇒ the merged answer is the exact
        // top-k in exact order.
        let resp =
            c.query_blocking(QueryRequest::bounded_me(q.clone(), 4, 1e-9, 0.1)).unwrap();
        assert_eq!(resp.indices, crate::algos::ground_truth(&data, &q, 4));
        assert_eq!(resp.shards, 3);
        c.shutdown();
    }

    #[test]
    fn compressed_tier_round_trips_and_reports_storage() {
        let ds = gaussian_dataset(150, 128, 55);
        let cfg = CoordinatorConfig {
            workers: 2,
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: 128,
            backend: Backend::Native,
            pull_order: PullOrder::BlockShuffled(16),
            shard: ShardSpec::single(),
            storage: Storage::F16,
            ..Default::default()
        };
        let data = ds.vectors.clone();
        let q = ds.sample_query(4);
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        // Exact scans never touch the compressed tier.
        let resp = c.query_blocking(QueryRequest::exact(q.clone(), 5)).unwrap();
        assert_eq!(resp.storage, Storage::F32);
        assert_eq!(resp.indices, crate::algos::ground_truth(&data, &q, 5));
        // BOUNDEDME reports the deployment tier (F32 under the
        // RUST_PALLAS_FORCE_F32 leg) and ε→0 stays exact — the index
        // falls back to the f32 tier when the budget can't absorb the
        // quantization bias.
        let resp =
            c.query_blocking(QueryRequest::bounded_me(q.clone(), 3, 1e-9, 0.05)).unwrap();
        assert_eq!(resp.storage, Storage::F16.effective());
        let mut got = resp.indices.clone();
        got.sort_unstable();
        let mut want = crate::algos::ground_truth(&data, &q, 3);
        want.sort_unstable();
        assert_eq!(got, want);
        // A loose-ε query actually samples compressed; the answer is
        // still a full k-set.
        let resp = c.query_blocking(QueryRequest::bounded_me(q, 3, 0.3, 0.2)).unwrap();
        assert_eq!(resp.storage, Storage::F16.effective());
        assert_eq!(resp.indices.len(), 3);
        c.shutdown();
    }

    #[test]
    fn batches_form_under_load() {
        let ds = gaussian_dataset(100, 32, 9);
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 16,
            batch_timeout: Duration::from_millis(20),
            queue_capacity: 512,
            backend: Backend::Native,
            pull_order: PullOrder::Sequential,
            shard: ShardSpec::single(),
            ..Default::default()
        };
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        let mut handles = Vec::new();
        for _ in 0..32 {
            handles.push(c.submit(QueryRequest::exact(vec![0.2; 32], 1)).unwrap());
        }
        let mut max_batch_seen = 0;
        for h in handles {
            max_batch_seen = max_batch_seen.max(h.recv().unwrap().batch_size);
        }
        assert!(max_batch_seen > 1, "no batching under burst load");
        c.shutdown();
    }

    #[test]
    fn plan_aware_batcher_groups_by_knobs() {
        // Interleave two BOUNDEDME knob classes and an exact class under
        // one burst: groups must never mix — each response's batch only
        // contains its own class, so batch_size never exceeds the class
        // population even though max_batch would allow it.
        let ds = gaussian_dataset(120, 64, 91);
        let data = ds.vectors.clone();
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 32,
            batch_timeout: Duration::from_millis(30),
            queue_capacity: 512,
            backend: Backend::Native,
            pull_order: PullOrder::BlockShuffled(16),
            shard: ShardSpec::single(),
            ..Default::default()
        };
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        let mut handles = Vec::new();
        for i in 0..24u64 {
            let q = ds.sample_query(i);
            let req = match i % 3 {
                0 => QueryRequest::exact(q, 3),
                1 => QueryRequest::bounded_me(q, 3, 1e-9, 0.05),
                _ => QueryRequest::bounded_me(q, 3, 0.3, 0.2),
            };
            handles.push((i, c.submit(req).unwrap()));
        }
        for (i, h) in handles {
            let resp = h.recv().unwrap();
            assert!(
                resp.batch_size <= 8,
                "req {i}: batch_size {} crosses plan/knob groups",
                resp.batch_size
            );
            if i % 3 != 2 {
                // Exact and ε→0 classes: exact answers.
                let q = ds.sample_query(i);
                let mut got = resp.indices.clone();
                got.sort_unstable();
                let mut want = crate::algos::ground_truth(&data, &q, 3);
                want.sort_unstable();
                assert_eq!(got, want, "req {i}");
            } else {
                assert_eq!(resp.indices.len(), 3, "req {i}");
            }
        }
        assert_eq!(c.metrics().queries, 24);
        c.shutdown();
    }

    #[test]
    fn resolve_storage_semantics() {
        // No override: deployment tier.
        assert_eq!(resolve_storage(None, Storage::F16), Storage::F16);
        assert_eq!(resolve_storage(None, Storage::F32), Storage::F32);
        // Matching override: granted.
        assert_eq!(
            resolve_storage(Some(Storage::F16), Storage::F16.effective()),
            Storage::F16.effective()
        );
        // F32 is always available (exact tier) — requesting it on a
        // compressed deployment opts the query out of sampling codes.
        assert_eq!(resolve_storage(Some(Storage::F32), Storage::F32), Storage::F32);
        // A tier the deployment does not hold downgrades conservatively
        // to f32 — never to a different compression. (Skip under the
        // force-f32 leg, where every tier is "held": it collapses to
        // f32 anyway.)
        if Storage::Int8.effective() == Storage::Int8 {
            assert_eq!(resolve_storage(Some(Storage::Int8), Storage::F16), Storage::F32);
            assert_eq!(resolve_storage(Some(Storage::F32), Storage::F16), Storage::F32);
        }
    }

    #[test]
    fn per_request_storage_override_round_trips() {
        // F16 deployment; the assertions below hold on every CI leg
        // (under RUST_PALLAS_FORCE_F32 all tiers collapse to f32 and
        // every expected value below collapses with them).
        let ds = gaussian_dataset(150, 128, 56);
        let cfg = CoordinatorConfig {
            workers: 2,
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: 128,
            backend: Backend::Native,
            pull_order: PullOrder::BlockShuffled(16),
            shard: ShardSpec::single(),
            storage: Storage::F16,
            ..Default::default()
        };
        let data = ds.vectors.clone();
        let q = ds.sample_query(4);
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        let deployed = Storage::F16.effective();

        // No override: the deployment tier answers.
        let resp = c.query_blocking(QueryRequest::bounded_me(q.clone(), 3, 0.3, 0.2)).unwrap();
        assert_eq!(resp.storage, deployed);

        // Explicit f32: opts out of compressed sampling per request.
        let resp = c
            .query_blocking(
                QueryRequest::bounded_me(q.clone(), 3, 1e-9, 0.05).with_storage(Storage::F32),
            )
            .unwrap();
        assert_eq!(resp.storage, Storage::F32);
        let mut got = resp.indices.clone();
        got.sort_unstable();
        let mut want = crate::algos::ground_truth(&data, &q, 3);
        want.sort_unstable();
        assert_eq!(got, want);

        // Matching override: granted the deployed tier.
        let resp = c
            .query_blocking(
                QueryRequest::bounded_me(q.clone(), 3, 0.3, 0.2).with_storage(Storage::F16),
            )
            .unwrap();
        assert_eq!(resp.storage, deployed);

        // Unavailable tier: conservative f32, still a correct answer.
        let resp = c
            .query_blocking(
                QueryRequest::bounded_me(q.clone(), 3, 1e-9, 0.05).with_storage(Storage::Int8),
            )
            .unwrap();
        assert_eq!(resp.storage, Storage::F32);
        let mut got = resp.indices.clone();
        got.sort_unstable();
        assert_eq!(got, want);
        c.shutdown();
    }

    #[test]
    fn per_request_storage_override_sharded() {
        // Same resolution through the reactor path (S = 3): the
        // override rides GroupKey → QueryJob → query_batch_shard_tier.
        let ds = gaussian_dataset(101, 64, 34);
        let cfg = CoordinatorConfig {
            workers: 3,
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: 128,
            backend: Backend::Native,
            pull_order: PullOrder::BlockShuffled(16),
            shard: ShardSpec::contiguous(3),
            storage: Storage::F16,
            ..Default::default()
        };
        let data = ds.vectors.clone();
        let q = ds.sample_query(2);
        let c = Coordinator::new(ds.vectors, cfg).unwrap();
        let resp = c
            .query_blocking(
                QueryRequest::bounded_me(q.clone(), 4, 1e-9, 0.1).with_storage(Storage::F32),
            )
            .unwrap();
        assert_eq!(resp.storage, Storage::F32);
        assert_eq!(resp.shards, 3);
        // ε→0 through sample-then-confirm on the f32 tier: exact top-k
        // in exact order.
        assert_eq!(resp.indices, crate::algos::ground_truth(&data, &q, 4));
        c.shutdown();
    }

    /// Shadow a delta batch through [`GenerationBuilder`] on the side and
    /// check the coordinator's post-flip answers against ground truth on
    /// the materialized snapshot.
    fn mutated_truth(data: &Matrix, deltas: &[Delta], q: &[f32], k: usize) -> Vec<usize> {
        let g0 = Generation::initial(data.clone(), ShardSpec::single(), EpochGauge::new());
        let mut b = GenerationBuilder::new(&g0);
        for d in deltas {
            b.apply(d).unwrap();
        }
        let snap = b.build().unwrap().generation.materialize();
        crate::algos::ground_truth(&snap, q, k)
    }

    #[test]
    fn mutate_flips_generation_and_answers() {
        // S = 1 direct path: queries before the flip answer on generation
        // 0, queries after answer on generation 1 against the mutated
        // rows, and the superseded generation is reclaimed.
        let (c, data) = small_coordinator(2, 64);
        let q = vec![0.5f32; 64];
        let resp = c.query_blocking(QueryRequest::exact(q.clone(), 5)).unwrap();
        assert_eq!(resp.generation, 0);
        assert_eq!(resp.indices, crate::algos::ground_truth(&data, &q, 5));
        assert_eq!(c.generation(), 0);
        assert_eq!(c.generations_alive(), 1);

        let deltas = vec![
            Delta::Upsert { id: 3, vector: vec![1.0; 64] },
            Delta::Delete { id: 7 },
            Delta::Append { vector: vec![-1.0; 64] },
        ];
        let out = c.mutate(&deltas).unwrap();
        assert_eq!(out.generation, 1);
        assert_eq!(out.rows, 200);
        assert_eq!(out.delta_rows, 3);
        assert_eq!(c.generation(), 1);
        assert_eq!(c.latest_generation(), 1);

        let resp = c.query_blocking(QueryRequest::exact(q.clone(), 5)).unwrap();
        assert_eq!(resp.generation, 1);
        assert_eq!(resp.indices, mutated_truth(&data, &deltas, &q, 5));
        // ε→0 BOUNDEDME agrees on the new generation too.
        let resp = c.query_blocking(QueryRequest::bounded_me(q.clone(), 5, 1e-9, 0.05)).unwrap();
        assert_eq!(resp.generation, 1);
        let mut got = resp.indices.clone();
        got.sort_unstable();
        let mut want = mutated_truth(&data, &deltas, &q, 5);
        want.sort_unstable();
        assert_eq!(got, want);

        // Generation 0 has no pins left once the flip is acked.
        assert_eq!(c.generations_alive(), 1);
        let snap = c.metrics();
        assert_eq!(snap.mutations, 1);
        assert_eq!(snap.mutation_rows, 3);
        c.shutdown();
    }

    #[test]
    fn mutate_under_reactor_serves_new_generation() {
        // S = 3 reactor path: the flip lands at the admission point, so a
        // query submitted after mutate() returns must answer on the new
        // generation with exact sharded answers.
        let ds = gaussian_dataset(101, 64, 33);
        let cfg = CoordinatorConfig {
            workers: 3,
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: 128,
            backend: Backend::Native,
            pull_order: PullOrder::BlockShuffled(16),
            shard: ShardSpec::contiguous(3),
            ..Default::default()
        };
        let data = ds.vectors.clone();
        let q = ds.sample_query(2);
        let c = Coordinator::new(ds.vectors, cfg).unwrap();

        let mut deltas = Vec::new();
        for id in [0usize, 50, 100] {
            let mut v = ds.sample_query(900 + id as u64);
            v[0] += 2.0;
            deltas.push(Delta::Upsert { id, vector: v });
        }
        let out = c.mutate(&deltas).unwrap();
        assert_eq!(out.generation, 1);
        // Pure upserts keep the shard layout: only dirty shards rebuild.
        assert_eq!(out.shards_rebuilt + out.shards_reused, 3);
        assert!(out.shards_rebuilt >= 1);

        let resp = c.query_blocking(QueryRequest::exact(q.clone(), 5)).unwrap();
        assert_eq!(resp.generation, 1);
        assert_eq!(resp.shards, 3);
        assert_eq!(resp.indices, mutated_truth(&data, &deltas, &q, 5));
        let resp = c.query_blocking(QueryRequest::bounded_me(q.clone(), 4, 1e-9, 0.1)).unwrap();
        assert_eq!(resp.generation, 1);
        assert_eq!(resp.indices, mutated_truth(&data, &deltas, &q, 4));
        assert_eq!(c.generations_alive(), 1);

        // An empty batch is a no-op, not a flip.
        let out = c.mutate(&[]).unwrap();
        assert_eq!(out.generation, 1);
        assert_eq!(out.delta_rows, 0);
        assert_eq!(c.metrics().mutations, 1);
        c.shutdown();
    }
}

#[cfg(test)]
mod deadline_tests {
    use super::*;
    use crate::data::synthetic::gaussian_dataset;

    #[test]
    fn expired_deadline_sheds() {
        // One slow worker, queue fills, deadlines of 0ns: everything past
        // the first batch is shed.
        let ds = gaussian_dataset(500, 256, 21);
        let cfg = CoordinatorConfig {
            workers: 1,
            max_batch: 4,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: 512,
            backend: Backend::Native,
            pull_order: PullOrder::Sequential,
            shard: ShardSpec::single(),
            ..Default::default()
        };
        let c = Coordinator::new(ds.vectors.clone(), cfg).unwrap();
        let mut rxs = Vec::new();
        for _ in 0..64 {
            let req = QueryRequest::exact(vec![0.3; 256], 3)
                .with_deadline(Duration::from_nanos(1));
            rxs.push(c.submit(req).unwrap());
        }
        let mut shed = 0;
        let mut served = 0;
        for rx in rxs {
            let resp = rx.recv().unwrap();
            if resp.shed {
                assert!(resp.indices.is_empty());
                shed += 1;
            } else {
                assert_eq!(resp.indices.len(), 3);
                served += 1;
            }
        }
        assert_eq!(shed + served, 64);
        assert!(shed > 0, "nothing shed under a 1ns deadline");
        assert_eq!(c.metrics().shed, shed);
        c.shutdown();
    }

    #[test]
    fn generous_deadline_never_sheds() {
        let ds = gaussian_dataset(50, 32, 22);
        let c = Coordinator::new(ds.vectors.clone(), CoordinatorConfig::default()).unwrap();
        for _ in 0..10 {
            let req = QueryRequest::bounded_me(vec![0.1; 32], 2, 0.2, 0.2)
                .with_deadline(Duration::from_secs(30));
            let resp = c.query_blocking(req).unwrap();
            assert!(!resp.shed);
            assert!(!resp.degraded, "a 30s deadline must never fire the budget");
            assert_eq!(resp.epsilon_hat, 0.0);
            assert_eq!(resp.indices.len(), 2);
        }
        let m = c.metrics();
        assert_eq!(m.shed, 0);
        assert_eq!(m.degraded, 0);
        c.shutdown();
    }

    #[test]
    fn flop_budget_harvests_instead_of_shedding() {
        // A 1-pull FLOP budget exhausts after round 1 on any instance
        // that needs ≥ 2 rounds: the reply must carry the checkpointed
        // top-k (`degraded = true`, ε̂ ∈ (0, ε)), never shed.
        let ds = gaussian_dataset(2000, 64, 23);
        let c = Coordinator::new(ds.vectors.clone(), CoordinatorConfig::default()).unwrap();
        let mut degraded = 0u64;
        for i in 0..8 {
            let req =
                QueryRequest::bounded_me(ds.vectors.row(i).to_vec(), 5, 0.05, 0.05)
                    .with_budget_flops(1);
            let resp = c.query_blocking(req).unwrap();
            assert!(!resp.shed, "budget exhaustion must harvest, not shed");
            assert_eq!(resp.indices.len(), 5);
            if resp.degraded {
                assert!(
                    resp.epsilon_hat > 0.0 && resp.epsilon_hat < 0.05,
                    "harvested ε̂ must lie strictly inside (0, ε), got {}",
                    resp.epsilon_hat
                );
                degraded += 1;
            } else {
                assert_eq!(resp.epsilon_hat, 0.0);
            }
        }
        assert!(degraded > 0, "ε = 0.05 on n = 2000 should need ≥ 2 rounds");
        let m = c.metrics();
        assert_eq!(m.shed, 0);
        assert_eq!(m.degraded, degraded);
        c.shutdown();
    }

    #[test]
    fn harvest_disabled_runs_budgets_to_completion() {
        // `harvest: false` disarms the anytime budget entirely: the
        // same 1-pull budget queries complete exactly, no degradation.
        let ds = gaussian_dataset(2000, 64, 23);
        let cfg = CoordinatorConfig { harvest: false, ..Default::default() };
        let c = Coordinator::new(ds.vectors.clone(), cfg).unwrap();
        for i in 0..4 {
            let req =
                QueryRequest::bounded_me(ds.vectors.row(i).to_vec(), 5, 0.05, 0.05)
                    .with_budget_flops(1);
            let resp = c.query_blocking(req).unwrap();
            assert!(!resp.shed && !resp.degraded);
            assert_eq!(resp.epsilon_hat, 0.0);
            assert_eq!(resp.indices.len(), 5);
        }
        let m = c.metrics();
        assert_eq!(m.degraded, 0);
        c.shutdown();
    }

    #[test]
    fn straggler_shard_degrades_with_partial_coverage() {
        // Two shards, one artificially slow past the deadline: the fast
        // shard's partial is harvested into a `degraded` reply with
        // coverage 1/2 — the pre-anytime coordinator shed these.
        let ds = gaussian_dataset(600, 64, 24);
        let cfg = CoordinatorConfig {
            shard: ShardSpec::contiguous(2),
            workers: 2,
            debug_slow_shard: Some((1, Duration::from_millis(300))),
            ..Default::default()
        };
        let c = Coordinator::new(ds.vectors.clone(), cfg).unwrap();
        let req = QueryRequest::bounded_me(ds.vectors.row(0).to_vec(), 5, 0.2, 0.1)
            .with_deadline(Duration::from_millis(60));
        let resp = c.query_blocking(req).unwrap();
        assert!(!resp.shed, "one covered shard must degrade, not shed");
        assert!(resp.degraded);
        assert_eq!(resp.shards, 1, "only the fast shard should be folded");
        assert_eq!(resp.shards_total, 2);
        assert!(!resp.indices.is_empty());
        let m = c.metrics();
        assert_eq!(m.shed, 0);
        assert_eq!(m.degraded, 1);
        c.shutdown();
    }
}
