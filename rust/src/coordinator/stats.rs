//! Coordinator metrics registry — **lock-free**. Every recording path
//! (worker fast-path replies, reactor merges, batcher) touches only
//! `AtomicU64`s with `Relaxed` ordering, so metrics never serialize the
//! serving threads the way the previous `Mutex<Inner>` did: with the
//! S = 1 fast path replying from inside the worker loop, a metrics lock
//! would be the last shared point of contention on the per-request
//! path.
//!
//! # Relaxed-snapshot semantics
//!
//! [`MetricsRegistry::snapshot`] reads each counter independently with
//! `Relaxed` loads. There is no cross-counter atomicity: a snapshot
//! taken while a query is being recorded may see its service-time
//! bucket but not yet its flops (or vice versa), and histogram totals
//! may momentarily disagree with bucket sums by the number of
//! concurrently recording threads. Every counter is monotone, so the
//! skew is bounded by in-flight updates and vanishes at quiesce —
//! "consistent enough" for dashboards, load tests, and the assertions
//! the test batteries make after draining. Nothing in this module is a
//! synchronization point.

use crate::linalg::stats::LogHistogram;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Duration;

/// Lock-free log-bucketed latency histogram: the atomic counterpart of
/// [`LogHistogram`], sharing its bucket layout (via
/// [`LogHistogram::bucket_index`] / [`LogHistogram::bucket_midpoint`])
/// so quantiles from either representation are comparable.
struct AtomicDurHistogram {
    counts: Box<[AtomicU64]>,
    total: AtomicU64,
    sum_nanos: AtomicU64,
}

impl AtomicDurHistogram {
    fn new() -> Self {
        let counts: Vec<AtomicU64> =
            (0..LogHistogram::bucket_count()).map(|_| AtomicU64::new(0)).collect();
        Self {
            counts: counts.into_boxed_slice(),
            total: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
        }
    }

    fn record(&self, d: Duration) {
        let b = LogHistogram::bucket_index(d.as_secs_f64());
        self.counts[b].fetch_add(1, Relaxed);
        self.sum_nanos.fetch_add(d.as_nanos() as u64, Relaxed);
        self.total.fetch_add(1, Relaxed);
    }

    fn mean(&self) -> f64 {
        let n = self.total.load(Relaxed);
        if n == 0 {
            0.0
        } else {
            self.sum_nanos.load(Relaxed) as f64 * 1e-9 / n as f64
        }
    }

    /// Approximate quantile in seconds. Under concurrent recording the
    /// bucket scan may see slightly more observations than `total` did
    /// (relaxed loads) — the returned bucket can shift by the number of
    /// in-flight updates, which is within the sketch's error anyway.
    fn quantile(&self, q: f64) -> f64 {
        let total = self.total.load(Relaxed);
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        let mut last_nonempty = 0usize;
        for (b, c) in self.counts.iter().enumerate() {
            let c = c.load(Relaxed);
            if c > 0 {
                last_nonempty = b;
            }
            seen += c;
            if seen >= target {
                return LogHistogram::bucket_midpoint(b);
            }
        }
        // A racing snapshot can make the scan fall short of `target`;
        // the highest populated bucket is the honest upper estimate.
        LogHistogram::bucket_midpoint(last_nonempty)
    }
}

/// Shared metrics sink for the coordinator threads. All-atomic; see the
/// module docs for the relaxed snapshot contract.
pub struct MetricsRegistry {
    queries: AtomicU64,
    batches: AtomicU64,
    batch_items: AtomicU64,
    flops: AtomicU64,
    shed: AtomicU64,
    hedge_fired: AtomicU64,
    hedge_won: AtomicU64,
    fast_path: AtomicU64,
    mutations: AtomicU64,
    mutation_rows: AtomicU64,
    shed_superseded: AtomicU64,
    queue_wait: AtomicDurHistogram,
    service: AtomicDurHistogram,
}

/// A point-in-time copy of the registry.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Queries served.
    pub queries: u64,
    /// Batches formed.
    pub batches: u64,
    /// Total flops spent on the query path.
    pub flops: u64,
    /// Mean batch size.
    pub mean_batch_size: f64,
    /// Queue-wait quantiles (seconds): (p50, p90, p99).
    pub queue_wait: (f64, f64, f64),
    /// Service-time quantiles (seconds): (p50, p90, p99).
    pub service: (f64, f64, f64),
    /// Mean service seconds.
    pub mean_service: f64,
    /// Requests shed for missing their deadline in queue.
    pub shed: u64,
    /// Straggler hedges dispatched (a shard batch re-sent to the hedge
    /// queue after [`super::CoordinatorConfig::hedge_delay`]).
    pub hedge_fired: u64,
    /// Hedges that finished before the original dispatch (the duplicate
    /// partial from the straggler was dropped).
    pub hedge_won: u64,
    /// Queries answered on the S = 1 fast path (worker → client
    /// directly, no reactor hop, no merge state).
    pub fast_path: u64,
    /// Generation flips applied (non-empty [`super::Coordinator::mutate`]
    /// batches acknowledged by every serving thread).
    pub mutations: u64,
    /// Total delta rows (upserts + appends + deletes) across all flips.
    pub mutation_rows: u64,
    /// Requests shed at shard pickup because their pinned generation had
    /// been superseded by a flip **and** their deadline had expired —
    /// the stale-and-late subset of `shed` (also counted there).
    pub shed_superseded: u64,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRegistry {
    /// Fresh registry.
    pub fn new() -> Self {
        Self {
            queries: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_items: AtomicU64::new(0),
            flops: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            hedge_fired: AtomicU64::new(0),
            hedge_won: AtomicU64::new(0),
            fast_path: AtomicU64::new(0),
            mutations: AtomicU64::new(0),
            mutation_rows: AtomicU64::new(0),
            shed_superseded: AtomicU64::new(0),
            queue_wait: AtomicDurHistogram::new(),
            service: AtomicDurHistogram::new(),
        }
    }

    /// Record one served query.
    pub fn record_query(&self, queue_wait: Duration, service: Duration, flops: u64) {
        self.queue_wait.record(queue_wait);
        self.service.record(service);
        self.queries.fetch_add(1, Relaxed);
        self.flops.fetch_add(flops, Relaxed);
    }

    /// Record a shed (deadline-expired) request.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Relaxed);
    }

    /// Record a formed batch.
    pub fn record_batch(&self, size: usize) {
        self.batch_items.fetch_add(size as u64, Relaxed);
        self.batches.fetch_add(1, Relaxed);
    }

    /// Record a straggler hedge dispatch.
    pub fn record_hedge_fired(&self) {
        self.hedge_fired.fetch_add(1, Relaxed);
    }

    /// Record a hedge completing before its straggling original.
    pub fn record_hedge_won(&self) {
        self.hedge_won.fetch_add(1, Relaxed);
    }

    /// Record a query answered on the S = 1 fast path.
    pub fn record_fast_path(&self) {
        self.fast_path.fetch_add(1, Relaxed);
    }

    /// Record an applied generation flip carrying `delta_rows` deltas.
    pub fn record_mutation(&self, delta_rows: usize) {
        self.mutations.fetch_add(1, Relaxed);
        self.mutation_rows.fetch_add(delta_rows as u64, Relaxed);
    }

    /// Record a shed whose pinned generation was superseded (the request
    /// is *also* recorded via [`Self::record_shed`] by the caller).
    pub fn record_shed_superseded(&self) {
        self.shed_superseded.fetch_add(1, Relaxed);
    }

    /// Copy out a snapshot (relaxed — see module docs).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let batches = self.batches.load(Relaxed);
        let batch_items = self.batch_items.load(Relaxed);
        MetricsSnapshot {
            queries: self.queries.load(Relaxed),
            batches,
            flops: self.flops.load(Relaxed),
            mean_batch_size: if batches == 0 {
                0.0
            } else {
                batch_items as f64 / batches as f64
            },
            queue_wait: (
                self.queue_wait.quantile(0.5),
                self.queue_wait.quantile(0.9),
                self.queue_wait.quantile(0.99),
            ),
            service: (
                self.service.quantile(0.5),
                self.service.quantile(0.9),
                self.service.quantile(0.99),
            ),
            mean_service: self.service.mean(),
            shed: self.shed.load(Relaxed),
            hedge_fired: self.hedge_fired.load(Relaxed),
            hedge_won: self.hedge_won.load(Relaxed),
            fast_path: self.fast_path.load(Relaxed),
            mutations: self.mutations.load(Relaxed),
            mutation_rows: self.mutation_rows.load(Relaxed),
            shed_superseded: self.shed_superseded.load(Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = MetricsRegistry::new();
        m.record_batch(4);
        m.record_batch(8);
        for _ in 0..12 {
            m.record_query(Duration::from_micros(100), Duration::from_millis(1), 500);
        }
        let s = m.snapshot();
        assert_eq!(s.queries, 12);
        assert_eq!(s.batches, 2);
        assert_eq!(s.flops, 6000);
        assert!((s.mean_batch_size - 6.0).abs() < 1e-9);
        assert!(s.service.0 > 0.0);
        assert!(s.queue_wait.2 >= s.queue_wait.0);
        assert_eq!((s.hedge_fired, s.hedge_won, s.fast_path), (0, 0, 0));
    }

    #[test]
    fn atomic_histogram_matches_lock_based_quantiles() {
        // Same bucket layout ⇒ same quantile estimates as LogHistogram
        // (up to one bucket of slack: Duration's nanosecond rounding can
        // nudge a value across a log-bucket boundary).
        let m = MetricsRegistry::new();
        let mut reference = LogHistogram::new();
        for i in 1..=1000u64 {
            let s = i as f64 * 1e-5; // 10µs … 10ms
            m.record_query(Duration::from_secs_f64(s), Duration::from_secs_f64(s), 1);
            reference.record(s);
        }
        let snap = m.snapshot();
        for (got, q) in [(snap.service.0, 0.5), (snap.service.1, 0.9), (snap.service.2, 0.99)] {
            let want = reference.quantile(q);
            assert!(
                (got / want - 1.0).abs() < 0.03,
                "q={q}: atomic {got} vs reference {want}"
            );
        }
        assert!((snap.mean_service - reference.mean()).abs() < 1e-6);
    }

    #[test]
    fn hedge_and_fast_path_counters() {
        let m = MetricsRegistry::new();
        m.record_hedge_fired();
        m.record_hedge_fired();
        m.record_hedge_won();
        m.record_fast_path();
        let s = m.snapshot();
        assert_eq!((s.hedge_fired, s.hedge_won, s.fast_path), (2, 1, 1));
    }

    #[test]
    fn mutation_and_superseded_counters() {
        let m = MetricsRegistry::new();
        m.record_mutation(3);
        m.record_mutation(7);
        m.record_shed();
        m.record_shed_superseded();
        let s = m.snapshot();
        assert_eq!(s.mutations, 2);
        assert_eq!(s.mutation_rows, 10);
        assert_eq!(s.shed, 1);
        assert_eq!(s.shed_superseded, 1);
    }

    #[test]
    fn concurrent_recording_conserves_counts() {
        let m = std::sync::Arc::new(MetricsRegistry::new());
        let mut hs = Vec::new();
        for _ in 0..4 {
            let m = m.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..500 {
                    m.record_query(
                        Duration::from_micros(50),
                        Duration::from_micros(200),
                        3,
                    );
                    m.record_shed();
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let s = m.snapshot();
        assert_eq!(s.queries, 2000);
        assert_eq!(s.shed, 2000);
        assert_eq!(s.flops, 6000);
    }
}
