//! Coordinator metrics registry: latency histograms, batch sizes, flop
//! counters. Lock-based (parking_lot) — updates are off the per-pull hot
//! loop, once per query.

use crate::linalg::stats::{LogHistogram, OnlineMoments};
use std::sync::Mutex;
use std::time::Duration;

/// Shared metrics sink for the coordinator threads.
pub struct MetricsRegistry {
    inner: Mutex<Inner>,
}

struct Inner {
    queue_wait: LogHistogram,
    service: LogHistogram,
    batch_sizes: OnlineMoments,
    queries: u64,
    batches: u64,
    flops: u64,
    shed: u64,
}

/// A point-in-time copy of the registry.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Queries served.
    pub queries: u64,
    /// Batches formed.
    pub batches: u64,
    /// Total flops spent on the query path.
    pub flops: u64,
    /// Mean batch size.
    pub mean_batch_size: f64,
    /// Queue-wait quantiles (seconds): (p50, p90, p99).
    pub queue_wait: (f64, f64, f64),
    /// Service-time quantiles (seconds): (p50, p90, p99).
    pub service: (f64, f64, f64),
    /// Mean service seconds.
    pub mean_service: f64,
    /// Requests shed for missing their deadline in queue.
    pub shed: u64,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRegistry {
    /// Fresh registry.
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner {
                queue_wait: LogHistogram::new(),
                service: LogHistogram::new(),
                batch_sizes: OnlineMoments::new(),
                queries: 0,
                batches: 0,
                flops: 0,
                shed: 0,
            }),
        }
    }

    /// Record one served query.
    pub fn record_query(&self, queue_wait: Duration, service: Duration, flops: u64) {
        let mut g = self.inner.lock().unwrap();
        g.queue_wait.record(queue_wait.as_secs_f64());
        g.service.record(service.as_secs_f64());
        g.queries += 1;
        g.flops += flops;
    }

    /// Record a shed (deadline-expired) request.
    pub fn record_shed(&self) {
        self.inner.lock().unwrap().shed += 1;
    }

    /// Record a formed batch.
    pub fn record_batch(&self, size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batch_sizes.push(size as f64);
        g.batches += 1;
    }

    /// Copy out a snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        MetricsSnapshot {
            queries: g.queries,
            batches: g.batches,
            flops: g.flops,
            mean_batch_size: g.batch_sizes.mean(),
            queue_wait: (
                g.queue_wait.quantile(0.5),
                g.queue_wait.quantile(0.9),
                g.queue_wait.quantile(0.99),
            ),
            service: (
                g.service.quantile(0.5),
                g.service.quantile(0.9),
                g.service.quantile(0.99),
            ),
            mean_service: g.service.mean(),
            shed: g.shed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = MetricsRegistry::new();
        m.record_batch(4);
        m.record_batch(8);
        for _ in 0..12 {
            m.record_query(Duration::from_micros(100), Duration::from_millis(1), 500);
        }
        let s = m.snapshot();
        assert_eq!(s.queries, 12);
        assert_eq!(s.batches, 2);
        assert_eq!(s.flops, 6000);
        assert!((s.mean_batch_size - 6.0).abs() < 1e-9);
        assert!(s.service.0 > 0.0);
        assert!(s.queue_wait.2 >= s.queue_wait.0);
    }
}
