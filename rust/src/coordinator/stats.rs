//! Coordinator metrics registry — **lock-free**. Every recording path
//! (worker fast-path replies, reactor merges, batcher) touches only
//! `AtomicU64`s with `Relaxed` ordering, so metrics never serialize the
//! serving threads the way the previous `Mutex<Inner>` did: with the
//! S = 1 fast path replying from inside the worker loop, a metrics lock
//! would be the last shared point of contention on the per-request
//! path.
//!
//! # Relaxed-snapshot semantics
//!
//! [`MetricsRegistry::snapshot`] reads each counter independently with
//! `Relaxed` loads. There is no cross-counter atomicity: a snapshot
//! taken while a query is being recorded may see its service-time
//! bucket but not yet its flops (or vice versa), and histogram totals
//! may momentarily disagree with bucket sums by the number of
//! concurrently recording threads. Every counter is monotone, so the
//! skew is bounded by in-flight updates and vanishes at quiesce —
//! "consistent enough" for dashboards, load tests, and the assertions
//! the test batteries make after draining. Nothing in this module is a
//! synchronization point.

use crate::linalg::stats::LogHistogram;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Duration;

/// Lock-free log-bucketed latency histogram: the atomic counterpart of
/// [`LogHistogram`], sharing its bucket layout (via
/// [`LogHistogram::bucket_index`] / [`LogHistogram::bucket_midpoint`])
/// so quantiles from either representation are comparable.
struct AtomicDurHistogram {
    counts: Box<[AtomicU64]>,
    total: AtomicU64,
    sum_nanos: AtomicU64,
}

impl AtomicDurHistogram {
    fn new() -> Self {
        let counts: Vec<AtomicU64> =
            (0..LogHistogram::bucket_count()).map(|_| AtomicU64::new(0)).collect();
        Self {
            counts: counts.into_boxed_slice(),
            total: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
        }
    }

    fn record(&self, d: Duration) {
        let b = LogHistogram::bucket_index(d.as_secs_f64());
        self.counts[b].fetch_add(1, Relaxed);
        self.sum_nanos.fetch_add(d.as_nanos() as u64, Relaxed);
        self.total.fetch_add(1, Relaxed);
    }

    fn mean(&self) -> f64 {
        let n = self.total.load(Relaxed);
        if n == 0 {
            0.0
        } else {
            self.sum_nanos.load(Relaxed) as f64 * 1e-9 / n as f64
        }
    }

    /// Approximate quantile in seconds. Under concurrent recording the
    /// bucket scan may see slightly more observations than `total` did
    /// (relaxed loads) — the returned bucket can shift by the number of
    /// in-flight updates, which is within the sketch's error anyway.
    fn quantile(&self, q: f64) -> f64 {
        let total = self.total.load(Relaxed);
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        let mut last_nonempty = 0usize;
        for (b, c) in self.counts.iter().enumerate() {
            let c = c.load(Relaxed);
            if c > 0 {
                last_nonempty = b;
            }
            seen += c;
            if seen >= target {
                return LogHistogram::bucket_midpoint(b);
            }
        }
        // A racing snapshot can make the scan fall short of `target`;
        // the highest populated bucket is the honest upper estimate.
        LogHistogram::bucket_midpoint(last_nonempty)
    }
}

/// Per-shard counters: dispatch / hedge / merge attribution plus the
/// reactor-side backlog gauge, so shard skew (a slow or hot shard) is
/// visible instead of averaged away in the global snapshot.
struct ShardStats {
    dispatches: AtomicU64,
    hedges_fired: AtomicU64,
    hedges_won: AtomicU64,
    merges: AtomicU64,
    merge_nanos: AtomicU64,
    queue_depth: AtomicU64,
}

impl ShardStats {
    fn new() -> Self {
        Self {
            dispatches: AtomicU64::new(0),
            hedges_fired: AtomicU64::new(0),
            hedges_won: AtomicU64::new(0),
            merges: AtomicU64::new(0),
            merge_nanos: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
        }
    }
}

/// Shared metrics sink for the coordinator threads. All-atomic; see the
/// module docs for the relaxed snapshot contract.
pub struct MetricsRegistry {
    queries: AtomicU64,
    submitted: AtomicU64,
    batches: AtomicU64,
    batch_items: AtomicU64,
    flops: AtomicU64,
    shed: AtomicU64,
    degraded: AtomicU64,
    degraded_admitted: AtomicU64,
    hedge_fired: AtomicU64,
    hedge_won: AtomicU64,
    fast_path: AtomicU64,
    mutations: AtomicU64,
    mutation_rows: AtomicU64,
    shed_superseded: AtomicU64,
    wire_json: AtomicU64,
    wire_binary: AtomicU64,
    queue_wait: AtomicDurHistogram,
    service: AtomicDurHistogram,
    shards: Box<[ShardStats]>,
}

/// A point-in-time copy of one shard's counters.
#[derive(Clone, Debug)]
pub struct ShardSnapshot {
    /// Shard index.
    pub shard: usize,
    /// Shard batches dispatched to this shard's workers.
    pub dispatches: u64,
    /// Straggler hedges fired against this shard.
    pub hedges_fired: u64,
    /// Hedges that beat this shard's original dispatch.
    pub hedges_won: u64,
    /// Dispatch completions merged from this shard.
    pub merges: u64,
    /// Mean dispatch→completion latency, seconds.
    pub mean_merge_s: f64,
    /// Reactor backlog depth at snapshot time (gauge).
    pub queue_depth: u64,
}

/// A point-in-time copy of the registry.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Queries served.
    pub queries: u64,
    /// Batches formed.
    pub batches: u64,
    /// Total flops spent on the query path.
    pub flops: u64,
    /// Mean batch size.
    pub mean_batch_size: f64,
    /// Queue-wait quantiles (seconds): (p50, p90, p99).
    pub queue_wait: (f64, f64, f64),
    /// Service-time quantiles (seconds): (p50, p90, p99).
    pub service: (f64, f64, f64),
    /// Mean service seconds.
    pub mean_service: f64,
    /// Requests shed for missing their deadline in queue.
    pub shed: u64,
    /// Requests accepted by [`super::Coordinator::submit`] (the
    /// backlog gauge's numerator; `submitted − queries − shed` is the
    /// in-flight population).
    pub submitted: u64,
    /// Replies that were **degraded** rather than shed or exact:
    /// harvested mid-run checkpoints and/or partial shard coverage.
    /// Together with `shed`, splits terminal outcomes three ways —
    /// `queries − degraded` answered exact-complete, `degraded`
    /// answered with reduced fidelity, `shed` answered empty.
    pub degraded: u64,
    /// Queries admitted with widened ε / clamped k by the
    /// [`super::DegradePolicy`] under sustained backlog (reported
    /// per-reply via `applied_epsilon` / `applied_k`).
    pub degraded_admitted: u64,
    /// Straggler hedges dispatched (a shard batch re-sent to the hedge
    /// queue after [`super::CoordinatorConfig::hedge_delay`]).
    pub hedge_fired: u64,
    /// Hedges that finished before the original dispatch (the duplicate
    /// partial from the straggler was dropped).
    pub hedge_won: u64,
    /// Queries answered on the S = 1 fast path (worker → client
    /// directly, no reactor hop, no merge state).
    pub fast_path: u64,
    /// Generation flips applied (non-empty [`super::Coordinator::mutate`]
    /// batches acknowledged by every serving thread).
    pub mutations: u64,
    /// Total delta rows (upserts + appends + deletes) across all flips.
    pub mutation_rows: u64,
    /// Requests shed at shard pickup because their pinned generation had
    /// been superseded by a flip **and** their deadline had expired —
    /// the stale-and-late subset of `shed` (also counted there).
    pub shed_superseded: u64,
    /// Total items across all formed batches (`mean_batch_size`'s
    /// numerator, exposed so dashboards need no derived math).
    pub batch_items: u64,
    /// Wire requests decoded by the TCP front-end over the line-JSON
    /// codec (one per JSON line or JSON-framed document). Zero for
    /// in-process callers — the coordinator itself never records these.
    pub wire_json: u64,
    /// Wire requests decoded over the binary codec (one per frame; a
    /// batch-query frame carrying B vectors counts once).
    pub wire_binary: u64,
    /// Hedges that fired but lost the race (`hedge_fired − hedge_won`,
    /// saturating): the duplicated work that bought no latency.
    pub hedge_lost: u64,
    /// Per-shard breakdown (one entry per shard; S = 1 deployments have
    /// exactly one, fed by the direct-worker path's shard 0).
    pub shards: Vec<ShardSnapshot>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRegistry {
    /// Fresh registry with one shard slot.
    pub fn new() -> Self {
        Self::with_shards(1)
    }

    /// Fresh registry with `n_shards` per-shard counter slots.
    pub fn with_shards(n_shards: usize) -> Self {
        let shards: Vec<ShardStats> =
            (0..n_shards.max(1)).map(|_| ShardStats::new()).collect();
        Self {
            queries: AtomicU64::new(0),
            submitted: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_items: AtomicU64::new(0),
            flops: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            degraded_admitted: AtomicU64::new(0),
            hedge_fired: AtomicU64::new(0),
            hedge_won: AtomicU64::new(0),
            fast_path: AtomicU64::new(0),
            mutations: AtomicU64::new(0),
            mutation_rows: AtomicU64::new(0),
            shed_superseded: AtomicU64::new(0),
            wire_json: AtomicU64::new(0),
            wire_binary: AtomicU64::new(0),
            queue_wait: AtomicDurHistogram::new(),
            service: AtomicDurHistogram::new(),
            shards: shards.into_boxed_slice(),
        }
    }

    /// Record one served query.
    pub fn record_query(&self, queue_wait: Duration, service: Duration, flops: u64) {
        self.queue_wait.record(queue_wait);
        self.service.record(service);
        self.queries.fetch_add(1, Relaxed);
        self.flops.fetch_add(flops, Relaxed);
    }

    /// Record a shed (deadline-expired) request.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Relaxed);
    }

    /// Record a request accepted into the pipeline (submit time).
    pub fn record_submit(&self) {
        self.submitted.fetch_add(1, Relaxed);
    }

    /// Record a degraded reply (harvested checkpoint and/or partial
    /// shard coverage; the request is *also* recorded via
    /// [`Self::record_query`] by the caller).
    pub fn record_degraded(&self) {
        self.degraded.fetch_add(1, Relaxed);
    }

    /// Record a query admitted with widened ε / clamped k under the
    /// backlog [`super::DegradePolicy`].
    pub fn record_degraded_admit(&self) {
        self.degraded_admitted.fetch_add(1, Relaxed);
    }

    /// In-flight population: requests submitted but not yet terminally
    /// answered or shed. The batcher's [`super::DegradePolicy`] reads
    /// this as its sustained-backlog signal. Relaxed loads: a racing
    /// reply can briefly overstate it by the number of in-flight
    /// updates, which is noise at the thresholds that matter.
    pub fn backlog(&self) -> u64 {
        let submitted = self.submitted.load(Relaxed);
        let done = self.queries.load(Relaxed).saturating_add(self.shed.load(Relaxed));
        submitted.saturating_sub(done)
    }

    /// Record a formed batch.
    pub fn record_batch(&self, size: usize) {
        self.batch_items.fetch_add(size as u64, Relaxed);
        self.batches.fetch_add(1, Relaxed);
    }

    /// Record a straggler hedge dispatch against `shard`.
    pub fn record_hedge_fired(&self, shard: usize) {
        self.hedge_fired.fetch_add(1, Relaxed);
        if let Some(s) = self.shards.get(shard) {
            s.hedges_fired.fetch_add(1, Relaxed);
        }
    }

    /// Record a hedge completing before its straggling original on
    /// `shard`.
    pub fn record_hedge_won(&self, shard: usize) {
        self.hedge_won.fetch_add(1, Relaxed);
        if let Some(s) = self.shards.get(shard) {
            s.hedges_won.fetch_add(1, Relaxed);
        }
    }

    /// Record a shard-batch dispatch to `shard`'s workers.
    pub fn record_dispatch(&self, shard: usize) {
        if let Some(s) = self.shards.get(shard) {
            s.dispatches.fetch_add(1, Relaxed);
        }
    }

    /// Record one dispatch completion merged from `shard`, with its
    /// dispatch→completion latency.
    pub fn record_merge(&self, shard: usize, latency: Duration) {
        if let Some(s) = self.shards.get(shard) {
            s.merges.fetch_add(1, Relaxed);
            s.merge_nanos.fetch_add(latency.as_nanos() as u64, Relaxed);
        }
    }

    /// Set `shard`'s backlog-depth gauge (reactor-side batches waiting
    /// for a worker slot).
    pub fn set_queue_depth(&self, shard: usize, depth: usize) {
        if let Some(s) = self.shards.get(shard) {
            s.queue_depth.store(depth as u64, Relaxed);
        }
    }

    /// Record a query answered on the S = 1 fast path.
    pub fn record_fast_path(&self) {
        self.fast_path.fetch_add(1, Relaxed);
    }

    /// Record an applied generation flip carrying `delta_rows` deltas.
    pub fn record_mutation(&self, delta_rows: usize) {
        self.mutations.fetch_add(1, Relaxed);
        self.mutation_rows.fetch_add(delta_rows as u64, Relaxed);
    }

    /// Record a shed whose pinned generation was superseded (the request
    /// is *also* recorded via [`Self::record_shed`] by the caller).
    pub fn record_shed_superseded(&self) {
        self.shed_superseded.fetch_add(1, Relaxed);
    }

    /// Record one wire request decoded by the TCP front-end against the
    /// codec that carried it (`binary` = length-prefixed frames, else
    /// line-JSON). A binary batch-query frame counts once however many
    /// vectors it carries — the unit is *wire requests*, not queries.
    pub fn record_wire(&self, binary: bool) {
        if binary {
            self.wire_binary.fetch_add(1, Relaxed);
        } else {
            self.wire_json.fetch_add(1, Relaxed);
        }
    }

    /// Copy out a snapshot (relaxed — see module docs).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let batches = self.batches.load(Relaxed);
        let batch_items = self.batch_items.load(Relaxed);
        let hedge_fired = self.hedge_fired.load(Relaxed);
        let hedge_won = self.hedge_won.load(Relaxed);
        let shards = self
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let merges = s.merges.load(Relaxed);
                ShardSnapshot {
                    shard: i,
                    dispatches: s.dispatches.load(Relaxed),
                    hedges_fired: s.hedges_fired.load(Relaxed),
                    hedges_won: s.hedges_won.load(Relaxed),
                    merges,
                    mean_merge_s: if merges == 0 {
                        0.0
                    } else {
                        s.merge_nanos.load(Relaxed) as f64 * 1e-9 / merges as f64
                    },
                    queue_depth: s.queue_depth.load(Relaxed),
                }
            })
            .collect();
        MetricsSnapshot {
            queries: self.queries.load(Relaxed),
            batches,
            flops: self.flops.load(Relaxed),
            mean_batch_size: if batches == 0 {
                0.0
            } else {
                batch_items as f64 / batches as f64
            },
            queue_wait: (
                self.queue_wait.quantile(0.5),
                self.queue_wait.quantile(0.9),
                self.queue_wait.quantile(0.99),
            ),
            service: (
                self.service.quantile(0.5),
                self.service.quantile(0.9),
                self.service.quantile(0.99),
            ),
            mean_service: self.service.mean(),
            shed: self.shed.load(Relaxed),
            submitted: self.submitted.load(Relaxed),
            degraded: self.degraded.load(Relaxed),
            degraded_admitted: self.degraded_admitted.load(Relaxed),
            hedge_fired,
            hedge_won,
            fast_path: self.fast_path.load(Relaxed),
            mutations: self.mutations.load(Relaxed),
            mutation_rows: self.mutation_rows.load(Relaxed),
            shed_superseded: self.shed_superseded.load(Relaxed),
            batch_items,
            wire_json: self.wire_json.load(Relaxed),
            wire_binary: self.wire_binary.load(Relaxed),
            hedge_lost: hedge_fired.saturating_sub(hedge_won),
            shards,
        }
    }
}

impl MetricsSnapshot {
    /// Render the snapshot as Prometheus text exposition (version
    /// 0.0.4): every global counter/gauge plus the per-shard breakdown
    /// as `{shard="i"}`-labeled series. `generation` and
    /// `generations_alive` come from the coordinator (they live outside
    /// the registry).
    pub fn to_prometheus(&self, generation: u64, generations_alive: usize) -> String {
        use crate::metrics::prom::PromWriter;
        let mut w = PromWriter::new();
        let counters: [(&str, &str, u64); 15] = [
            ("pallas_queries_total", "Queries served.", self.queries),
            ("pallas_submitted_total", "Requests accepted by submit().", self.submitted),
            ("pallas_batches_total", "Batches formed.", self.batches),
            ("pallas_batch_items_total", "Items across all formed batches.", self.batch_items),
            ("pallas_flops_total", "Flops spent on the query path.", self.flops),
            ("pallas_shed_total", "Requests shed for missing their deadline.", self.shed),
            (
                "pallas_degraded_total",
                "Degraded replies (harvested checkpoint or partial shard coverage).",
                self.degraded,
            ),
            (
                "pallas_degraded_admitted_total",
                "Queries admitted with widened epsilon or clamped k under backlog.",
                self.degraded_admitted,
            ),
            (
                "pallas_shed_superseded_total",
                "Sheds whose pinned generation was superseded.",
                self.shed_superseded,
            ),
            ("pallas_hedge_fired_total", "Straggler hedges dispatched.", self.hedge_fired),
            ("pallas_hedge_won_total", "Hedges that beat their original.", self.hedge_won),
            (
                "pallas_hedge_lost_total",
                "Hedges that fired but lost the race (duplicated work).",
                self.hedge_lost,
            ),
            ("pallas_fast_path_total", "Queries answered on the S=1 fast path.", self.fast_path),
            ("pallas_mutations_total", "Generation flips applied.", self.mutations),
            ("pallas_mutation_rows_total", "Delta rows across all flips.", self.mutation_rows),
        ];
        for (name, help, v) in counters {
            w.header(name, help, "counter");
            w.sample(name, &[], v as f64);
        }
        w.header(
            "pallas_wire_requests_total",
            "Wire requests decoded by the TCP front-end, per codec.",
            "counter",
        );
        w.sample("pallas_wire_requests_total", &[("codec", "json")], self.wire_json as f64);
        w.sample(
            "pallas_wire_requests_total",
            &[("codec", "binary")],
            self.wire_binary as f64,
        );
        w.header("pallas_generation", "Current dataset generation id.", "gauge");
        w.sample("pallas_generation", &[], generation as f64);
        w.header("pallas_generations_alive", "Dataset generations not yet reclaimed.", "gauge");
        w.sample("pallas_generations_alive", &[], generations_alive as f64);
        w.header("pallas_mean_batch_size", "Mean items per batch.", "gauge");
        w.sample("pallas_mean_batch_size", &[], self.mean_batch_size);
        for (name, help, (p50, p90, p99), mean) in [
            (
                "pallas_service_seconds",
                "Service time quantiles (pickup to reply).",
                self.service,
                Some(self.mean_service),
            ),
            (
                "pallas_queue_wait_seconds",
                "Queue wait quantiles (submit to pickup).",
                self.queue_wait,
                None,
            ),
        ] {
            w.header(name, help, "summary");
            w.sample(name, &[("quantile", "0.5")], p50);
            w.sample(name, &[("quantile", "0.9")], p90);
            w.sample(name, &[("quantile", "0.99")], p99);
            if let Some(mean) = mean {
                let mean_name = format!("{name}_mean");
                w.header(&mean_name, "Mean of the summary above.", "gauge");
                w.sample(&mean_name, &[], mean);
            }
        }
        let shard_counters: [(&str, &str, fn(&ShardSnapshot) -> f64, &str); 6] = [
            (
                "pallas_shard_dispatches_total",
                "Shard batches dispatched, per shard.",
                |s| s.dispatches as f64,
                "counter",
            ),
            (
                "pallas_shard_hedges_fired_total",
                "Straggler hedges fired, per shard.",
                |s| s.hedges_fired as f64,
                "counter",
            ),
            (
                "pallas_shard_hedges_won_total",
                "Hedges that beat the original, per shard.",
                |s| s.hedges_won as f64,
                "counter",
            ),
            (
                "pallas_shard_merges_total",
                "Dispatch completions merged, per shard.",
                |s| s.merges as f64,
                "counter",
            ),
            (
                "pallas_shard_merge_seconds_mean",
                "Mean dispatch-to-completion latency, per shard.",
                |s| s.mean_merge_s,
                "gauge",
            ),
            (
                "pallas_shard_queue_depth",
                "Reactor backlog depth, per shard.",
                |s| s.queue_depth as f64,
                "gauge",
            ),
        ];
        for (name, help, get, kind) in shard_counters {
            w.header(name, help, kind);
            for s in &self.shards {
                let label = s.shard.to_string();
                w.sample(name, &[("shard", &label)], get(s));
            }
        }
        w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = MetricsRegistry::new();
        m.record_batch(4);
        m.record_batch(8);
        for _ in 0..12 {
            m.record_query(Duration::from_micros(100), Duration::from_millis(1), 500);
        }
        let s = m.snapshot();
        assert_eq!(s.queries, 12);
        assert_eq!(s.batches, 2);
        assert_eq!(s.flops, 6000);
        assert!((s.mean_batch_size - 6.0).abs() < 1e-9);
        assert!(s.service.0 > 0.0);
        assert!(s.queue_wait.2 >= s.queue_wait.0);
        assert_eq!((s.hedge_fired, s.hedge_won, s.fast_path), (0, 0, 0));
    }

    #[test]
    fn atomic_histogram_matches_lock_based_quantiles() {
        // Same bucket layout ⇒ same quantile estimates as LogHistogram
        // (up to one bucket of slack: Duration's nanosecond rounding can
        // nudge a value across a log-bucket boundary).
        let m = MetricsRegistry::new();
        let mut reference = LogHistogram::new();
        for i in 1..=1000u64 {
            let s = i as f64 * 1e-5; // 10µs … 10ms
            m.record_query(Duration::from_secs_f64(s), Duration::from_secs_f64(s), 1);
            reference.record(s);
        }
        let snap = m.snapshot();
        for (got, q) in [(snap.service.0, 0.5), (snap.service.1, 0.9), (snap.service.2, 0.99)] {
            let want = reference.quantile(q);
            assert!(
                (got / want - 1.0).abs() < 0.03,
                "q={q}: atomic {got} vs reference {want}"
            );
        }
        assert!((snap.mean_service - reference.mean()).abs() < 1e-6);
    }

    #[test]
    fn hedge_and_fast_path_counters() {
        let m = MetricsRegistry::with_shards(2);
        m.record_hedge_fired(0);
        m.record_hedge_fired(1);
        m.record_hedge_won(1);
        m.record_fast_path();
        let s = m.snapshot();
        assert_eq!((s.hedge_fired, s.hedge_won, s.fast_path), (2, 1, 1));
        assert_eq!(s.hedge_lost, 1);
        assert_eq!(s.shards.len(), 2);
        assert_eq!((s.shards[0].hedges_fired, s.shards[0].hedges_won), (1, 0));
        assert_eq!((s.shards[1].hedges_fired, s.shards[1].hedges_won), (1, 1));
    }

    #[test]
    fn per_shard_dispatch_merge_and_depth() {
        let m = MetricsRegistry::with_shards(3);
        m.record_dispatch(0);
        m.record_dispatch(0);
        m.record_dispatch(2);
        m.record_merge(0, Duration::from_millis(2));
        m.record_merge(0, Duration::from_millis(4));
        m.set_queue_depth(2, 5);
        // Out-of-range shard ids are ignored, not panics (the direct
        // path always records against shard 0).
        m.record_dispatch(99);
        m.record_hedge_fired(99);
        let s = m.snapshot();
        assert_eq!(s.shards[0].dispatches, 2);
        assert_eq!(s.shards[0].merges, 2);
        assert!((s.shards[0].mean_merge_s - 3e-3).abs() < 1e-4);
        assert_eq!(s.shards[1].dispatches, 0);
        assert_eq!(s.shards[2].dispatches, 1);
        assert_eq!(s.shards[2].queue_depth, 5);
        // The global hedge counter still saw the out-of-range fire.
        assert_eq!(s.hedge_fired, 1);
    }

    #[test]
    fn batch_items_exposed() {
        let m = MetricsRegistry::new();
        m.record_batch(4);
        m.record_batch(8);
        let s = m.snapshot();
        assert_eq!(s.batch_items, 12);
        assert_eq!(s.shards.len(), 1);
    }

    #[test]
    fn prometheus_exposition_has_global_and_per_shard_series() {
        let m = MetricsRegistry::with_shards(2);
        m.record_batch(3);
        m.record_query(Duration::from_micros(100), Duration::from_millis(1), 500);
        m.record_dispatch(1);
        m.record_hedge_fired(1);
        m.record_merge(1, Duration::from_millis(2));
        m.set_queue_depth(0, 4);
        let text = m.snapshot().to_prometheus(7, 2);
        for needle in [
            "# TYPE pallas_queries_total counter\n",
            "pallas_queries_total 1\n",
            "pallas_batch_items_total 3\n",
            "pallas_hedge_lost_total 1\n",
            "pallas_generation 7\n",
            "pallas_generations_alive 2\n",
            "pallas_service_seconds{quantile=\"0.99\"}",
            "pallas_shard_dispatches_total{shard=\"0\"} 0\n",
            "pallas_shard_dispatches_total{shard=\"1\"} 1\n",
            "pallas_shard_hedges_fired_total{shard=\"1\"} 1\n",
            "pallas_shard_merges_total{shard=\"1\"} 1\n",
            "pallas_shard_queue_depth{shard=\"0\"} 4\n",
            "pallas_wire_requests_total{codec=\"json\"} 0\n",
            "pallas_wire_requests_total{codec=\"binary\"} 0\n",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }

    #[test]
    fn wire_codec_counters() {
        let m = MetricsRegistry::new();
        m.record_wire(false);
        m.record_wire(false);
        m.record_wire(true);
        let s = m.snapshot();
        assert_eq!((s.wire_json, s.wire_binary), (2, 1));
        let text = s.to_prometheus(0, 1);
        assert!(text.contains("pallas_wire_requests_total{codec=\"json\"} 2\n"));
        assert!(text.contains("pallas_wire_requests_total{codec=\"binary\"} 1\n"));
    }

    #[test]
    fn mutation_and_superseded_counters() {
        let m = MetricsRegistry::new();
        m.record_mutation(3);
        m.record_mutation(7);
        m.record_shed();
        m.record_shed_superseded();
        let s = m.snapshot();
        assert_eq!(s.mutations, 2);
        assert_eq!(s.mutation_rows, 10);
        assert_eq!(s.shed, 1);
        assert_eq!(s.shed_superseded, 1);
    }

    #[test]
    fn degradation_counters_and_backlog() {
        let m = MetricsRegistry::new();
        for _ in 0..5 {
            m.record_submit();
        }
        assert_eq!(m.backlog(), 5);
        m.record_query(Duration::from_micros(10), Duration::from_micros(20), 1);
        m.record_degraded();
        m.record_shed();
        m.record_degraded_admit();
        assert_eq!(m.backlog(), 3); // 5 submitted − 1 served − 1 shed
        let s = m.snapshot();
        assert_eq!(s.submitted, 5);
        assert_eq!(s.degraded, 1);
        assert_eq!(s.degraded_admitted, 1);
        let text = s.to_prometheus(0, 1);
        for needle in [
            "pallas_submitted_total 5\n",
            "pallas_degraded_total 1\n",
            "pallas_degraded_admitted_total 1\n",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // Replies never recorded as submitted can't underflow the gauge.
        let fresh = MetricsRegistry::new();
        fresh.record_query(Duration::ZERO, Duration::ZERO, 0);
        assert_eq!(fresh.backlog(), 0);
    }

    #[test]
    fn concurrent_recording_conserves_counts() {
        let m = std::sync::Arc::new(MetricsRegistry::new());
        let mut hs = Vec::new();
        for _ in 0..4 {
            let m = m.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..500 {
                    m.record_query(
                        Duration::from_micros(50),
                        Duration::from_micros(200),
                        3,
                    );
                    m.record_shed();
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let s = m.snapshot();
        assert_eq!(s.queries, 2000);
        assert_eq!(s.shed, 2000);
        assert_eq!(s.flops, 6000);
    }
}
