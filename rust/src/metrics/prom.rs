//! Minimal Prometheus text-exposition (version 0.0.4) writer — the
//! offline counterpart of a `prometheus` client crate, sized to what
//! the coordinator's `metrics_prom` server op needs: `# HELP`/`# TYPE`
//! headers, unlabeled samples, and label sets (the per-shard
//! breakdown).
//!
//! Values go through `f64`'s `Display`, which prints integral values
//! without a fractional part (`123`, not `123.0`) — both forms are
//! valid exposition floats.

/// Incremental text-exposition builder.
pub struct PromWriter {
    out: String,
}

impl Default for PromWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl PromWriter {
    /// Empty exposition.
    pub fn new() -> Self {
        PromWriter { out: String::new() }
    }

    /// Emit the `# HELP` / `# TYPE` header for a metric family.
    /// `kind` is one of `counter`, `gauge`, `summary`.
    pub fn header(&mut self, name: &str, help: &str, kind: &str) {
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(help);
        self.out.push_str("\n# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(kind);
        self.out.push('\n');
    }

    /// Emit one sample line. `labels` render as
    /// `name{k1="v1",k2="v2"} value`; empty renders `name value`.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push_str("=\"");
                for c in v.chars() {
                    // Label-value escapes per the exposition format.
                    match c {
                        '\\' => self.out.push_str("\\\\"),
                        '"' => self.out.push_str("\\\""),
                        '\n' => self.out.push_str("\\n"),
                        c => self.out.push(c),
                    }
                }
                self.out.push('"');
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&value.to_string());
        self.out.push('\n');
    }

    /// The finished exposition body.
    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_headers_and_samples() {
        let mut w = PromWriter::new();
        w.header("pallas_queries_total", "Queries served.", "counter");
        w.sample("pallas_queries_total", &[], 42.0);
        w.sample("pallas_shard_dispatches_total", &[("shard", "0")], 7.0);
        let text = w.finish();
        assert!(text.contains("# HELP pallas_queries_total Queries served.\n"));
        assert!(text.contains("# TYPE pallas_queries_total counter\n"));
        assert!(text.contains("\npallas_queries_total 42\n"));
        assert!(text.contains("pallas_shard_dispatches_total{shard=\"0\"} 7\n"));
    }

    #[test]
    fn integral_floats_print_clean_and_labels_escape() {
        let mut w = PromWriter::new();
        w.sample("m", &[("q", "0.99")], 0.125);
        w.sample("weird", &[("v", "a\"b\\c\nd")], 1.0);
        let text = w.finish();
        assert!(text.contains("m{q=\"0.99\"} 0.125\n"));
        assert!(text.contains("weird{v=\"a\\\"b\\\\c\\nd\"} 1\n"));
    }
}
