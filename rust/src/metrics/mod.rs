//! Evaluation metrics: precision@K, suboptimality, online speedup.
//!
//! Definitions follow the paper's Experiments section:
//!
//! * **precision** — fraction of the true top-K present in the returned
//!   top-K (set semantics);
//! * **suboptimality** — `p̃(T*) − p̃(T)` where `p̃(S)` is the K-th
//!   highest *true mean* among the arms of `S` (mean-reward units,
//!   i.e. inner products divided by `N`);
//! * **online speedup** — cost(naive) / cost(algo), measured both in
//!   flops (the paper's pull-count currency) and wall-clock.

pub mod prom;

use crate::linalg::{dot, stats::LogHistogram, Matrix};

/// Precision@K: |truth ∩ returned| / |truth|. Returns 1.0 for empty
/// truth (vacuous).
pub fn precision_at_k(truth: &[usize], returned: &[usize]) -> f64 {
    if truth.is_empty() {
        return 1.0;
    }
    let hit = truth.iter().filter(|t| returned.contains(t)).count();
    hit as f64 / truth.len() as f64
}

/// Paper suboptimality of a returned K-set: the K-th best true mean of
/// the optimal set minus the K-th best true mean of the returned set,
/// in `qᵀv/N` units. Non-negative up to floating-point noise.
pub fn suboptimality(data: &Matrix, q: &[f32], truth: &[usize], returned: &[usize]) -> f64 {
    let kth = |set: &[usize]| -> f64 {
        let mut scores: Vec<f64> =
            set.iter().map(|&i| dot(data.row(i), q) as f64).collect();
        scores.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
        let k = truth.len().min(scores.len());
        if k == 0 {
            return 0.0;
        }
        scores[k - 1]
    };
    ((kth(truth) - kth(returned)) / data.cols() as f64).max(0.0)
}

/// Aggregated per-algorithm measurements over a query batch.
#[derive(Clone, Debug, Default)]
pub struct AlgoStats {
    /// Algorithm label.
    pub name: String,
    /// Mean precision@K.
    pub precision_sum: f64,
    /// Total query flops.
    pub flops: u64,
    /// Total naive flops over the same queries (for speedup).
    pub naive_flops: u64,
    /// Wall-clock seconds on the query path.
    pub query_seconds: f64,
    /// Naive wall-clock seconds on the same queries.
    pub naive_seconds: f64,
    /// Number of queries aggregated.
    pub queries: u64,
    /// Latency distribution (seconds).
    pub latency: Option<LogHistogram>,
}

impl AlgoStats {
    /// New empty aggregate for an algorithm.
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), latency: Some(LogHistogram::new()), ..Default::default() }
    }

    /// Record one query's outcome.
    pub fn record(
        &mut self,
        precision: f64,
        flops: u64,
        naive_flops: u64,
        seconds: f64,
        naive_seconds: f64,
    ) {
        self.precision_sum += precision;
        self.flops += flops;
        self.naive_flops += naive_flops;
        self.query_seconds += seconds;
        self.naive_seconds += naive_seconds;
        self.queries += 1;
        if let Some(h) = self.latency.as_mut() {
            h.record(seconds);
        }
    }

    /// Mean precision over recorded queries.
    pub fn precision(&self) -> f64 {
        if self.queries == 0 {
            0.0
        } else {
            self.precision_sum / self.queries as f64
        }
    }

    /// Flop-based online speedup vs naive.
    pub fn speedup_flops(&self) -> f64 {
        if self.flops == 0 {
            f64::INFINITY
        } else {
            self.naive_flops as f64 / self.flops as f64
        }
    }

    /// Wall-clock online speedup vs naive.
    pub fn speedup_wall(&self) -> f64 {
        if self.query_seconds <= 0.0 {
            f64::INFINITY
        } else {
            self.naive_seconds / self.query_seconds
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_basics() {
        assert_eq!(precision_at_k(&[1, 2, 3], &[3, 2, 1]), 1.0);
        assert_eq!(precision_at_k(&[1, 2, 3, 4], &[1, 9, 2, 8]), 0.5);
        assert_eq!(precision_at_k(&[1], &[]), 0.0);
        assert_eq!(precision_at_k(&[], &[1]), 1.0);
    }

    #[test]
    fn suboptimality_zero_for_exact_answer() {
        let data = Matrix::from_rows(&[vec![1.0, 0.0], vec![0.5, 0.0], vec![0.0, 1.0]]);
        let q = [1.0f32, 0.0];
        let s = suboptimality(&data, &q, &[0, 1], &[1, 0]);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn suboptimality_positive_for_worse_set() {
        let data = Matrix::from_rows(&[vec![1.0, 0.0], vec![0.5, 0.0], vec![0.0, 0.0]]);
        let q = [1.0f32, 0.0];
        // truth = {0}, returned = {2}: gap = (1.0 - 0.0)/2 = 0.5
        let s = suboptimality(&data, &q, &[0], &[2]);
        assert!((s - 0.5).abs() < 1e-9);
    }

    #[test]
    fn algo_stats_aggregation() {
        let mut st = AlgoStats::new("X");
        st.record(1.0, 100, 1000, 0.001, 0.01);
        st.record(0.5, 100, 1000, 0.001, 0.01);
        assert_eq!(st.precision(), 0.75);
        assert!((st.speedup_flops() - 10.0).abs() < 1e-9);
        assert!((st.speedup_wall() - 10.0).abs() < 1e-6);
        assert_eq!(st.queries, 2);
    }
}
