//! Zero-allocation batched query execution core.
//!
//! Preprocessing-free MIPS means the per-query hot path *is* the
//! product: there is no index build to hide setup costs behind. Before
//! this module existed, every query re-allocated its coordinate
//! permutation, gathered-query buffer, per-arm bandit state, and
//! scoring slab — and the coordinator's dynamic batcher collected
//! batches only to execute them query-by-query. [`QueryContext`] is the
//! reusable scratch arena that removes those allocations, and
//! [`QueryPlan`] is the small planner that picks an algorithm and a
//! [`PullOrder`] from the request knobs `(k, ε, δ, dim)`.
//!
//! Layering:
//!
//! * [`crate::bandit::PullScratch`] (inside the context) caches the pull
//!   order keyed on `(order, dim, seed)` — every query of a batch shares
//!   one block-shuffled permutation and only re-gathers its own values;
//! * [`crate::bandit::BanditScratch`] reuses the `O(n)` survivor arena
//!   of BOUNDEDME across runs — including the survivor-compacted
//!   [`crate::bandit::PullPanel`] (ping-pong buffers sized by the first
//!   compacting queries, then reused allocation-free; see the
//!   [`crate::bandit::Compaction`] policy);
//! * [`RankScratch`] holds the exact-scoring slab the engines / naive
//!   index write into;
//! * [`crate::algos::MipsIndex::query_with`] /
//!   [`crate::algos::MipsIndex::query_batch`] thread a `&mut
//!   QueryContext` through the algorithm layer, and each coordinator
//!   worker owns one context for its whole lifetime.
//!
//! The `hotpath` bench measures the effect directly: the context-reuse
//! path performs no steady-state heap allocation per query, versus a
//! handful of `O(dim)`/`O(n)` allocations per query on the legacy path.
//!
//! Below this layer sits the runtime-dispatched SIMD kernel table
//! ([`crate::linalg::simd`]): the fused `query_batch` scans and the
//! engines' `score_dataset_batch` run the blocked `dot_rows` kernel
//! tile-by-tile, and BOUNDEDME's per-round pulls run
//! `partial_dot_rows` across the survivor set — so every plan the
//! planner can pick executes on the same hardware-speed kernels.
//!
//! [`shard`] layers sharded execution on top: a batch fans out across
//! dataset row shards (one context per shard), per-shard (ε, δ/S)
//! budgets keep the union guarantee, and partial top-K results merge
//! through [`crate::linalg::TopK`].

pub mod shard;

use crate::bandit::{m_bounded, BanditScratch, PullOrder, PullScratch};
use crate::data::quant::Storage;
use crate::trace::TraceStage;

/// Reusable scoring scratch: the exact-score slab (one `f32` per
/// row × query).
#[derive(Default)]
pub struct RankScratch {
    /// Score slab, query-major (`scores[qi * rows + i]`). Engines and
    /// the naive index write into it via `score_batch_into`/`matvec_into`.
    pub scores: Vec<f32>,
}

impl RankScratch {
    /// Empty arena.
    pub fn new() -> Self {
        Self::default()
    }
}

/// Per-worker (or per-thread) scratch arena threaded through the whole
/// execution path: pull-order state, bandit survivor state, and exact
/// scoring buffers. Create once, pass to every
/// [`crate::algos::MipsIndex::query_with`] /
/// [`crate::algos::MipsIndex::query_batch`] call.
///
/// The fields are public and independently borrowable on purpose: the
/// bandit layer holds `pull` immutably (through
/// [`crate::bandit::MatrixArms::with_scratch`]) while mutating `bandit`,
/// which the borrow checker allows via disjoint field borrows.
#[derive(Default)]
pub struct QueryContext {
    /// Pull-order permutation / run table + gathered query buffer.
    pub pull: PullScratch,
    /// BOUNDEDME survivor arena.
    pub bandit: BanditScratch,
    /// Exact-scoring slab + candidate gather buffer.
    pub rank: RankScratch,
    /// Flight-recorder staging ([`crate::trace::TraceStage`]): while
    /// armed, the BOUNDEDME index stages one
    /// [`crate::trace::QueryExec`] per executed query. Disarmed by
    /// default — one bool check per query, nothing else.
    pub trace: TraceStage,
}

impl QueryContext {
    /// Empty context; buffers grow to steady-state on the first queries
    /// and are then reused allocation-free.
    pub fn new() -> Self {
        Self::default()
    }

    /// Buffer-growth (reallocation) events observed by the pull scratch
    /// since construction — constant in steady state; the `hotpath`
    /// bench asserts on it. (Pull-order buffers only; the survivor
    /// panel is tracked separately by
    /// [`QueryContext::panel_grow_events`], since its high-water size
    /// depends on each query's elimination schedule.)
    pub fn grow_events(&self) -> u64 {
        self.pull.grow_events()
    }

    /// Survivor-panel buffer-growth events (see
    /// [`crate::bandit::BanditScratch::panel_grow_events`]) — reaches a
    /// high-water steady state after the first few compacting queries.
    pub fn panel_grow_events(&self) -> u64 {
        self.bandit.panel_grow_events()
    }
}

/// Which algorithm a [`QueryPlan`] selected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanAlgo {
    /// Exhaustive exact scoring (the bandit cannot win at these knobs).
    Exact,
    /// BOUNDEDME adaptive sampling with the plan's pull order.
    BoundedMe,
}

/// Per-query execution plan derived from `(k, ε, δ, dim)`.
///
/// The decision rule comes from the paper's sample complexity: the
/// first elimination round already needs
/// `t₁ = m((ε/4)/2, ·)` pulls per arm (with range-relative ε, range
/// width 1). If that many pulls per arm is already ≥ `N`, BOUNDEDME
/// degenerates to exhaustive search *plus* bandit bookkeeping — so the
/// plan routes the query to the exact engine instead. Otherwise it
/// picks BOUNDEDME with a block-shuffled pull order whose block width
/// scales with `dim` (dense runs for the vectorized dot kernel, enough
/// blocks for the shuffle to stay statistically near-uniform).
#[derive(Clone, Copy, Debug)]
pub struct QueryPlan {
    /// Selected algorithm.
    pub algo: PlanAlgo,
    /// Pull order a BOUNDEDME execution should use — a block-shuffled
    /// order whose width scales with `dim` (see
    /// [`QueryPlan::block_width`]). The coordinator adopts it when its
    /// config asks for planner-chosen ordering
    /// (`PullOrder::BlockShuffled(0)`, the serving default).
    pub order: PullOrder,
    /// Estimated first-round pulls per arm (diagnostic).
    pub first_round_pulls: usize,
    /// Storage tier the execution should sample from ([`Storage::F32`]
    /// unless overridden via [`QueryPlan::with_storage`]; `Exact` plans
    /// always score on f32 regardless). The coordinator's plan-aware
    /// batcher groups on it so a batch shares one tier's kernels and
    /// panel element type end-to-end.
    pub storage: Storage,
}

impl QueryPlan {
    /// Pick a plan from the request knobs. `dim` is the vector dimension
    /// `N`; `k` currently only guards degenerate requests.
    pub fn pick(k: usize, epsilon: f64, delta: f64, dim: usize) -> Self {
        let order = PullOrder::BlockShuffled(Self::block_width(dim));
        if dim < 64 {
            // Too few coordinates for sampling to amortize its overhead.
            return Self {
                algo: PlanAlgo::Exact,
                order,
                first_round_pulls: dim,
                storage: Storage::F32,
            };
        }
        let eps = epsilon.clamp(f64::MIN_POSITIVE, 1.0);
        let delta = delta.clamp(1e-12, 1.0 - 1e-12);
        // Round-1 budget of Algorithm 1 at range-relative ε: ε₁ = ε/4,
        // tested at radius ε₁/2 with confidence δ₁ = δ/2.
        let first = m_bounded(eps / 8.0, delta / 2.0, dim, 1.0);
        let algo = if first >= dim { PlanAlgo::Exact } else { PlanAlgo::BoundedMe };
        let _ = k;
        Self { algo, order, first_round_pulls: first, storage: Storage::F32 }
    }

    /// Route the plan's sampling step to a compressed storage tier (the
    /// `RUST_PALLAS_FORCE_F32` hatch is applied here, so a plan never
    /// carries a tier the process has disabled).
    pub fn with_storage(mut self, storage: Storage) -> Self {
        self.storage = storage.effective();
        self
    }

    /// Block width for the block-shuffled pull order: dense enough for
    /// the vectorized dot kernel, with ≥ ~32 blocks so the shuffle stays
    /// near-uniform.
    pub fn block_width(dim: usize) -> usize {
        (dim / 32).clamp(16, 256).min(dim.max(1))
    }
}

/// Load-aware admission degradation policy (part of
/// [`crate::coordinator::CoordinatorConfig`]): when the coordinator's
/// queue backlog has stayed at or above `backlog_threshold` items, new
/// BOUNDEDME queries are admitted with a widened ε and a clamped k —
/// trading per-query precision for throughput *before* deadlines start
/// expiring, the admission-side half of harvest-not-shed. Exact-mode
/// queries are never touched (their contract is exactness), and the
/// applied knobs are reported back in
/// [`crate::coordinator::QueryResponse::applied_epsilon`] /
/// [`crate::coordinator::QueryResponse::applied_k`] so clients can see
/// what they actually paid for.
#[derive(Clone, Copy, Debug)]
pub struct DegradePolicy {
    /// Queue backlog (submitted − completed) at or above which
    /// admission degradation kicks in.
    pub backlog_threshold: usize,
    /// Multiplier (> 1 to widen) applied to the requested ε of admitted
    /// BOUNDEDME queries under backlog.
    pub epsilon_widen: f64,
    /// Upper bound applied to the requested k under backlog (0 = leave
    /// k alone).
    pub max_k: usize,
}

impl Default for DegradePolicy {
    fn default() -> Self {
        DegradePolicy { backlog_threshold: 64, epsilon_widen: 2.0, max_k: 0 }
    }
}

impl DegradePolicy {
    /// Apply the policy to a request's `(ε, k)` under backlog: returns
    /// the degraded knobs, or `None` when the policy leaves this
    /// request untouched (ε already wider than the widened value and k
    /// within the clamp).
    pub fn apply(&self, epsilon: f64, k: usize) -> Option<(f64, usize)> {
        let new_eps = (epsilon * self.epsilon_widen.max(1.0)).min(1.0).max(epsilon);
        let new_k = if self.max_k > 0 { k.min(self.max_k) } else { k };
        (new_eps > epsilon || new_k < k).then_some((new_eps, new_k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_dim_plans_exact() {
        let p = QueryPlan::pick(5, 0.1, 0.1, 16);
        assert_eq!(p.algo, PlanAlgo::Exact);
    }

    #[test]
    fn tiny_epsilon_plans_exact() {
        // ε → 0 forces t₁ = N: the bandit cannot beat a scan.
        let p = QueryPlan::pick(5, 1e-12, 0.05, 4096);
        assert_eq!(p.algo, PlanAlgo::Exact);
        assert_eq!(p.first_round_pulls, 4096);
    }

    #[test]
    fn loose_knobs_plan_bandit() {
        let p = QueryPlan::pick(5, 0.3, 0.2, 4096);
        assert_eq!(p.algo, PlanAlgo::BoundedMe);
        assert!(p.first_round_pulls < 4096);
        assert!(matches!(p.order, PullOrder::BlockShuffled(_)));
    }

    #[test]
    fn plan_monotone_in_epsilon() {
        // Tighter ε ⇒ never switches from Exact back to BoundedMe.
        let dim = 2048;
        let mut was_exact = false;
        for eps in [0.5, 0.2, 0.05, 0.01, 1e-3, 1e-6, 1e-12] {
            let p = QueryPlan::pick(1, eps, 0.1, dim);
            if was_exact {
                assert_eq!(p.algo, PlanAlgo::Exact, "eps={eps}");
            }
            was_exact = p.algo == PlanAlgo::Exact;
        }
        assert!(was_exact, "ε=1e-12 should have planned Exact");
    }

    #[test]
    fn block_width_bounds() {
        assert_eq!(QueryPlan::block_width(4096), 128);
        assert_eq!(QueryPlan::block_width(64), 16);
        assert_eq!(QueryPlan::block_width(100_000), 256);
        assert!(QueryPlan::block_width(8) <= 8);
    }

    #[test]
    fn plans_default_to_f32_storage() {
        let p = QueryPlan::pick(5, 0.3, 0.2, 4096);
        assert_eq!(p.storage, Storage::F32);
        let p = p.with_storage(Storage::F16);
        // `with_storage` applies the force-f32 hatch eagerly.
        assert_eq!(p.storage, Storage::F16.effective());
        assert_eq!(p.with_storage(Storage::F32).storage, Storage::F32);
    }

    #[test]
    fn degrade_policy_widens_and_clamps() {
        let p = DegradePolicy { backlog_threshold: 8, epsilon_widen: 2.0, max_k: 5 };
        let (eps, k) = p.apply(0.1, 10).unwrap();
        assert!((eps - 0.2).abs() < 1e-12);
        assert_eq!(k, 5);
        // ε is capped at 1.0 and never shrinks.
        let (eps, _) = p.apply(0.9, 3).unwrap();
        assert_eq!(eps, 1.0);
        // Nothing to degrade: wide ε, small k, no clamp.
        let p = DegradePolicy { backlog_threshold: 8, epsilon_widen: 1.0, max_k: 0 };
        assert!(p.apply(0.5, 3).is_none());
    }

    #[test]
    fn context_starts_empty_and_grows_once() {
        let mut ctx = QueryContext::new();
        assert_eq!(ctx.grow_events(), 0);
        ctx.pull.prepare(PullOrder::BlockShuffled(16), 256, 1);
        let q = vec![0.5f32; 256];
        ctx.pull.gather(&q);
        let warm = ctx.grow_events();
        for _ in 0..20 {
            ctx.pull.prepare(PullOrder::BlockShuffled(16), 256, 1);
            ctx.pull.gather(&q);
        }
        assert_eq!(ctx.grow_events(), warm);
    }
}
