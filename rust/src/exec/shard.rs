//! Sharded query execution: fan a batch out across dataset row shards,
//! split the (ε, δ) budget so the union keeps the paper's guarantee,
//! and merge partial top-K results through [`TopK`].
//!
//! # Accounting: why the union keeps (ε, δ)
//!
//! BOUNDEDME's guarantee is per *instance*: on an `n`-arm instance it
//! returns a K-set that is ε-optimal with probability ≥ 1 − δ. Sharding
//! runs one instance per shard and recombines with a
//! **sample-then-confirm** step (the same decomposition adaptive-
//! sampling MIPS uses at scale, cf. BanditMIPS):
//!
//! 1. **Sample**: shard `s` (with `n_s` rows) runs BOUNDEDME at knobs
//!    `(k_s, ε, δ/S)` where `k_s = min(K, n_s)` — see [`shard_params`].
//!    By a union bound over the `S` shards, *every* shard's returned
//!    set is ε-optimal within its shard with probability ≥ 1 − δ.
//! 2. **Confirm**: each shard exactly rescores its own ≤ `k_s`
//!    candidates (row-local, `k_s · N` flops — negligible next to the
//!    sampling budget) so partials carry true inner products. The
//!    rescore runs the blocked [`crate::linalg::partial_dot_rows`] SIMD
//!    kernel over the scattered candidate rows, and the per-shard exact
//!    scans run blocked [`crate::linalg::dot_rows`] tiles — every
//!    sharded path executes on the dispatched kernel table.
//! 3. **Merge**: the ≤ `S·K` candidates merge through one [`TopK`]
//!    keyed on `(exact score, global id)`.
//!
//! On the 1 − δ event, any true global top-K row `v` living on shard
//! `s` is either returned by shard `s` or displaced by a within-shard
//! candidate whose true mean is within ε of `v`'s (that is what
//! ε-optimality of the shard's set means). Since the merge ranks by
//! *exact* scores, every member of the merged K-set is either a true
//! top-K row or ε-close to the one it displaced — the merged set is
//! ε-optimal. The ε budget is **not** halved per shard and the pull
//! budget per shard covers only that shard's `n_s` arms, so total
//! sample complexity matches the unsharded bound (modulo the δ/S
//! log-factor inside `m(·)`).
//!
//! Exact queries need no accounting: per-shard exact top-K over
//! disjoint row sets merges to exactly the global top-K, byte-identical
//! to the unsharded scan because contiguous shards are views over the
//! same bytes and [`TopK`]'s id tie-break is insertion-order
//! independent.
//!
//! [`ShardedIndex`] is the in-process executor built on these pieces
//! (one [`QueryContext`] per shard, shards served sequentially); the
//! serving coordinator runs the same protocol in parallel behind an
//! event-driven reactor — shard-pinned workers produce
//! [`ShardPartial`]s as completion events, the reactor folds them with
//! exactly the [`merge_partials`] semantics (same [`TopK`] order, same
//! flop accounting), and a straggling shard's batch can be re-executed
//! verbatim by a sibling worker because partials are deterministic
//! functions of (shard data, knobs, seed) (see [`crate::coordinator`]).

use std::sync::Arc;

use crate::algos::{BoundedMeIndex, MipsIndex, MipsParams, MipsResult, NaiveIndex};
use crate::bandit::PullOrder;
use crate::data::generation::{Generation, GenerationBuild};
use crate::data::quant::Storage;
use crate::data::shard::{Shard, ShardSpec, ShardedMatrix};
use crate::exec::{PlanAlgo, QueryContext, QueryPlan};
use crate::linalg::{Matrix, TopK};

/// One shard's contribution to one query: candidate `(score, global
/// row id)` pairs plus work accounting. Produced by the shard-aware
/// batch entry points ([`NaiveIndex::query_batch_shard`],
/// [`BoundedMeIndex::query_batch_shard`]) and consumed by
/// [`merge_partials`].
#[derive(Clone, Debug)]
pub struct ShardPartial {
    /// Candidates as `(score, dataset-global id)`. Exact mode: the
    /// shard's top-k by exact score. BOUNDEDME mode: the shard's
    /// survivors, exactly rescored (the confirm step).
    pub entries: Vec<(f32, usize)>,
    /// Flops this shard spent on the query (pulls + confirm rescore, or
    /// the exact scan).
    pub flops: u64,
    /// Rows this shard exactly ranked (shard rows for exact, confirmed
    /// candidates for BOUNDEDME) — summed into
    /// [`MipsResult::candidates`].
    pub scanned: usize,
}

/// Per-shard knob split preserving the union (ε, δ) guarantee: `k`
/// clamps to the shard's row count (still ≥ 1 — BOUNDEDME wants a
/// non-empty return set), ε passes through unchanged (the confirm
/// rescore is what keeps the merge from compounding estimate error),
/// and δ is divided across the `n_shards` simultaneous runs (union
/// bound). See the module docs for the full argument.
pub fn shard_params(params: &MipsParams, n_shards: usize, shard_rows: usize) -> MipsParams {
    MipsParams {
        k: params.k.min(shard_rows.max(1)).max(1),
        epsilon: params.epsilon,
        delta: (params.delta / n_shards.max(1) as f64).max(f64::MIN_POSITIVE),
        seed: params.seed,
    }
}

/// Merge per-shard partials into the final top-`k`. Deterministic for
/// any arrival order of partials: [`TopK`] keeps the k best under the
/// strict total order (score desc, global id asc), so duplicate scores
/// across shards break toward the lower global id no matter which
/// shard answered first.
pub fn merge_partials(
    k: usize,
    partials: impl IntoIterator<Item = ShardPartial>,
) -> MipsResult {
    let mut top = TopK::new(k);
    let mut flops = 0u64;
    let mut scanned = 0usize;
    for p in partials {
        flops += p.flops;
        scanned += p.scanned;
        for (score, id) in p.entries {
            top.push(score, id);
        }
    }
    let ranked = top.into_sorted();
    MipsResult {
        indices: ranked.iter().map(|&(_, i)| i).collect(),
        scores: ranked.iter().map(|&(s, _)| s).collect(),
        flops,
        candidates: scanned,
    }
}

/// In-process sharded executor: per-shard [`BoundedMeIndex`] +
/// [`NaiveIndex`] pairs with one long-lived [`QueryContext`] per shard
/// (shard-pinned contexts, exactly like the coordinator's shard-pinned
/// workers), serving batches shard-by-shard and merging.
///
/// With a single shard this degenerates to the plain index paths
/// (bit-identical to unsharded execution, no confirm step); with `S ≥
/// 2`, exact batches stay byte-identical to unsharded and BOUNDEDME
/// batches follow the sample-then-confirm protocol above.
pub struct ShardedIndex {
    sharded: ShardedMatrix,
    bme: Vec<BoundedMeIndex>,
    naive: Vec<NaiveIndex>,
    ctxs: Vec<QueryContext>,
}

impl ShardedIndex {
    /// Split `data` per `spec` with the planner-chosen block-shuffled
    /// pull order for this dimension.
    pub fn new(data: Matrix, spec: ShardSpec) -> Self {
        let order = PullOrder::BlockShuffled(QueryPlan::block_width(data.cols()));
        Self::with_order(data, spec, order)
    }

    /// Split `data` per `spec` with an explicit pull order.
    pub fn with_order(data: Matrix, spec: ShardSpec, order: PullOrder) -> Self {
        let sharded = ShardedMatrix::new(data, spec);
        let bme = sharded
            .shards()
            .iter()
            .map(|s| BoundedMeIndex::with_order(s.matrix().clone(), order))
            .collect();
        let naive =
            sharded.shards().iter().map(|s| NaiveIndex::new(s.matrix().clone())).collect();
        let ctxs = (0..sharded.num_shards()).map(|_| QueryContext::new()).collect();
        Self { sharded, bme, naive, ctxs }
    }

    /// Effective shard count.
    pub fn num_shards(&self) -> usize {
        self.sharded.num_shards()
    }

    /// The sharded dataset.
    pub fn sharded(&self) -> &ShardedMatrix {
        &self.sharded
    }

    /// Plan a query against this dataset. Sharding splits rows, never
    /// coordinates, so the plan depends only on `(k, ε, δ, dim)` and is
    /// shard-count invariant; it is made **once per query before
    /// fan-out**, never per shard.
    pub fn plan(&self, k: usize, epsilon: f64, delta: f64) -> QueryPlan {
        QueryPlan::pick(k, epsilon, delta, self.sharded.dim())
    }

    /// Exact sharded batch: per-shard fused scans merged by top-K.
    /// Byte-identical to an unsharded [`NaiveIndex::query_batch`].
    pub fn query_batch_exact(&mut self, queries: &[&[f32]], k: usize) -> Vec<MipsResult> {
        let s_count = self.sharded.num_shards();
        if s_count == 1 {
            return self.naive[0].query_batch(
                queries,
                &MipsParams { k, ..MipsParams::default() },
                &mut self.ctxs[0],
            );
        }
        let mut acc: Vec<Vec<ShardPartial>> =
            queries.iter().map(|_| Vec::with_capacity(s_count)).collect();
        for s in 0..s_count {
            let partials = self.naive[s].query_batch_shard(queries, k, self.sharded.shard(s));
            for (qi, p) in partials.into_iter().enumerate() {
                acc[qi].push(p);
            }
        }
        acc.into_iter().map(|ps| merge_partials(k, ps)).collect()
    }

    /// BOUNDEDME sharded batch: per-shard `(k_s, ε, δ/S)` runs with
    /// shard-pinned contexts, confirm rescore, top-K merge. With one
    /// shard, delegates to the plain fused batch (bit-identical to
    /// unsharded; scores are the bandit's estimates, not rescored).
    pub fn query_batch_bounded_me(
        &mut self,
        queries: &[&[f32]],
        params: &MipsParams,
    ) -> Vec<MipsResult> {
        let s_count = self.sharded.num_shards();
        if s_count == 1 {
            return self.bme[0].query_batch(queries, params, &mut self.ctxs[0]);
        }
        let mut acc: Vec<Vec<ShardPartial>> =
            queries.iter().map(|_| Vec::with_capacity(s_count)).collect();
        for s in 0..s_count {
            let split = shard_params(params, s_count, self.sharded.shard(s).rows());
            let partials = self.bme[s].query_batch_shard(
                queries,
                &split,
                &mut self.ctxs[s],
                self.sharded.shard(s),
            );
            for (qi, p) in partials.into_iter().enumerate() {
                acc[qi].push(p);
            }
        }
        acc.into_iter().map(|ps| merge_partials(params.k.max(1), ps)).collect()
    }

    /// Planner-routed batch: one [`QueryPlan`] decision for the batch's
    /// shared knobs *before* fan-out, then the exact or BOUNDEDME path.
    pub fn query_batch_auto(
        &mut self,
        queries: &[&[f32]],
        params: &MipsParams,
    ) -> Vec<MipsResult> {
        match self.plan(params.k, params.epsilon, params.delta).algo {
            PlanAlgo::Exact => self.query_batch_exact(queries, params.k),
            PlanAlgo::BoundedMe => self.query_batch_bounded_me(queries, params),
        }
    }
}

/// A [`Generation`] pinned to its per-shard serving state: one
/// [`BoundedMeIndex`] (column maxima, quantized codes for compressed
/// tiers) and one [`NaiveIndex`] per shard. This is the
/// generation-pinned sibling of [`ShardedIndex`]: immutable and
/// `Arc`-shared, so a query that captured the set at admission keeps
/// answering from it however many flips happen behind its back —
/// queries pin a `ShardSet`, the coordinator swaps `Arc<ShardSet>`s
/// between batches.
///
/// [`ShardSet::advance`] is the copy-on-write step of the flip: shards
/// the [`GenerationBuild`] marks as reused carry their *derived* state
/// (colmax, `QuantMatrix` incl. per-row error bounds) by `Arc` clone —
/// valid because the reuse contract is byte-identical rows in identical
/// order — while re-materialized shards are indexed from scratch, which
/// is precisely what re-quantizes delta rows with fresh error bounds
/// and keeps the two-tier ε-bias accounting stated against the live
/// bytes.
pub struct ShardSet {
    generation: Arc<Generation>,
    indexes: Vec<Arc<BoundedMeIndex>>,
    naive: Vec<NaiveIndex>,
    order: PullOrder,
    storage: Storage,
}

impl ShardSet {
    /// Index `generation` with the planner-chosen pull order for its
    /// dimension.
    pub fn build(generation: Arc<Generation>, storage: Storage) -> Arc<ShardSet> {
        let order = PullOrder::BlockShuffled(QueryPlan::block_width(generation.dim()));
        Self::with_order(generation, order, storage)
    }

    /// Index `generation` with an explicit pull order (all shards from
    /// scratch — generation 0, or a reference build for equivalence
    /// tests).
    pub fn with_order(
        generation: Arc<Generation>,
        order: PullOrder,
        storage: Storage,
    ) -> Arc<ShardSet> {
        let indexes = generation
            .shards()
            .iter()
            .map(|s| {
                Arc::new(
                    BoundedMeIndex::with_order(s.matrix().clone(), order).with_storage(storage),
                )
            })
            .collect();
        let naive = Self::naive_for(&generation);
        Arc::new(Self { generation, indexes, naive, order, storage })
    }

    /// Flip step: index `built.generation`, reusing the derived state of
    /// every shard `built.reuse` proves untouched and re-indexing (and
    /// re-quantizing) only the re-materialized ones.
    pub fn advance(prev: &ShardSet, built: &GenerationBuild) -> Arc<ShardSet> {
        let generation = Arc::clone(&built.generation);
        debug_assert_eq!(built.reuse.len(), generation.num_shards());
        let indexes = generation
            .shards()
            .iter()
            .zip(&built.reuse)
            .map(|(s, reuse)| match reuse {
                Some(j) => Arc::clone(&prev.indexes[*j]),
                None => Arc::new(
                    BoundedMeIndex::with_order(s.matrix().clone(), prev.order)
                        .with_storage(prev.storage),
                ),
            })
            .collect();
        let naive = Self::naive_for(&generation);
        Arc::new(Self {
            generation,
            indexes,
            naive,
            order: prev.order,
            storage: prev.storage,
        })
    }

    fn naive_for(generation: &Generation) -> Vec<NaiveIndex> {
        // NaiveIndex has no derived state (it is the raw rows), so a
        // fresh wrap per flip is just an `Arc` bump per shard.
        generation.shards().iter().map(|s| NaiveIndex::new(s.matrix().clone())).collect()
    }

    /// The pinned generation.
    pub fn generation(&self) -> &Arc<Generation> {
        &self.generation
    }

    /// Shard count (fixed across the lineage).
    pub fn num_shards(&self) -> usize {
        self.indexes.len()
    }

    /// Shard `s` of the pinned generation.
    pub fn shard(&self, s: usize) -> &Shard {
        self.generation.shard(s)
    }

    /// Shard `s`'s BOUNDEDME index.
    pub fn index(&self, s: usize) -> &Arc<BoundedMeIndex> {
        &self.indexes[s]
    }

    /// The storage tier every shard is indexed with.
    pub fn storage(&self) -> Storage {
        self.storage
    }

    /// Exact batch against the pinned generation: identical protocol to
    /// [`ShardedIndex::query_batch_exact`] (S = 1 delegates to the
    /// plain fused scan; S ≥ 2 merges per-shard partials), with
    /// caller-supplied shard-pinned contexts so the set itself stays
    /// shareable.
    pub fn query_batch_exact(
        &self,
        queries: &[&[f32]],
        k: usize,
        ctxs: &mut [QueryContext],
    ) -> Vec<MipsResult> {
        let s_count = self.num_shards();
        debug_assert_eq!(ctxs.len(), s_count, "one context per shard");
        if s_count == 1 {
            return self.naive[0].query_batch(
                queries,
                &MipsParams { k, ..MipsParams::default() },
                &mut ctxs[0],
            );
        }
        let mut acc: Vec<Vec<ShardPartial>> =
            queries.iter().map(|_| Vec::with_capacity(s_count)).collect();
        for s in 0..s_count {
            let partials = self.naive[s].query_batch_shard(queries, k, self.shard(s));
            for (qi, p) in partials.into_iter().enumerate() {
                acc[qi].push(p);
            }
        }
        acc.into_iter().map(|ps| merge_partials(k, ps)).collect()
    }

    /// BOUNDEDME batch against the pinned generation: identical
    /// protocol to [`ShardedIndex::query_batch_bounded_me`].
    pub fn query_batch_bounded_me(
        &self,
        queries: &[&[f32]],
        params: &MipsParams,
        ctxs: &mut [QueryContext],
    ) -> Vec<MipsResult> {
        let s_count = self.num_shards();
        debug_assert_eq!(ctxs.len(), s_count, "one context per shard");
        if s_count == 1 {
            return self.indexes[0].query_batch(queries, params, &mut ctxs[0]);
        }
        let mut acc: Vec<Vec<ShardPartial>> =
            queries.iter().map(|_| Vec::with_capacity(s_count)).collect();
        for (s, ctx) in ctxs.iter_mut().enumerate() {
            let split = shard_params(params, s_count, self.shard(s).rows());
            let partials =
                self.indexes[s].query_batch_shard(queries, &split, ctx, self.shard(s));
            for (qi, p) in partials.into_iter().enumerate() {
                acc[qi].push(p);
            }
        }
        acc.into_iter().map(|ps| merge_partials(params.k.max(1), ps)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generation::GenerationBuilder;
    use crate::linalg::Rng;
    use crate::sync::EpochGauge;

    fn gaussian(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn shard_params_splits_delta_and_clamps_k() {
        let p = MipsParams { k: 10, epsilon: 0.2, delta: 0.1, seed: 3 };
        let s = shard_params(&p, 4, 100);
        assert_eq!(s.k, 10);
        assert_eq!(s.epsilon, 0.2);
        assert!((s.delta - 0.025).abs() < 1e-15);
        assert_eq!(s.seed, 3);
        // Single-row shard: k clamps to 1 (still a valid BOUNDEDME run).
        assert_eq!(shard_params(&p, 4, 1).k, 1);
        assert_eq!(shard_params(&MipsParams { k: 0, ..p }, 2, 50).k, 1);
    }

    #[test]
    fn merge_is_arrival_order_independent() {
        let a = ShardPartial {
            entries: vec![(1.0, 5), (0.5, 7)],
            flops: 10,
            scanned: 2,
        };
        let b = ShardPartial {
            entries: vec![(1.0, 2), (0.5, 1)],
            flops: 20,
            scanned: 2,
        };
        let ab = merge_partials(3, [a.clone(), b.clone()]);
        let ba = merge_partials(3, [b, a]);
        // Duplicate scores across shards: lower global id wins the tie
        // regardless of which shard's partial arrived first.
        assert_eq!(ab.indices, vec![2, 5, 1]);
        assert_eq!(ab.indices, ba.indices);
        assert_eq!(ab.scores, ba.scores);
        assert_eq!(ab.flops, 30);
        assert_eq!(ab.candidates, 4);
    }

    #[test]
    fn merge_k_zero_and_empty() {
        let p = ShardPartial { entries: vec![(1.0, 0)], flops: 4, scanned: 1 };
        let r = merge_partials(0, [p]);
        assert!(r.indices.is_empty());
        assert_eq!(r.flops, 4);
        let r = merge_partials(3, std::iter::empty());
        assert!(r.indices.is_empty() && r.scores.is_empty());
    }

    #[test]
    fn sharded_exact_matches_unsharded() {
        let data = gaussian(37, 48, 1);
        let naive = NaiveIndex::new(data.clone());
        let queries: Vec<Vec<f32>> = (0..4).map(|i| Rng::new(50 + i).gaussian_vec(48)).collect();
        let refs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        for spec in [ShardSpec::contiguous(3), ShardSpec::round_robin(4)] {
            let mut sx = ShardedIndex::new(data.clone(), spec);
            let got = sx.query_batch_exact(&refs, 5);
            for (qi, q) in queries.iter().enumerate() {
                let want = naive.query(q, &MipsParams { k: 5, ..Default::default() });
                assert_eq!(got[qi].indices, want.indices, "{spec:?} q{qi}");
                for (a, b) in got[qi].scores.iter().zip(&want.scores) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{spec:?} q{qi}");
                }
                assert_eq!(got[qi].flops, want.flops, "{spec:?} q{qi}");
                assert_eq!(got[qi].candidates, 37, "{spec:?} q{qi}");
            }
        }
    }

    #[test]
    fn sharded_bounded_me_exact_at_tiny_epsilon() {
        let data = gaussian(60, 96, 2);
        let q: Vec<f32> = Rng::new(9).gaussian_vec(96);
        let truth = crate::algos::ground_truth(&data, &q, 4);
        let params = MipsParams { k: 4, epsilon: 1e-9, delta: 0.1, seed: 5 };
        for spec in [ShardSpec::contiguous(2), ShardSpec::round_robin(3)] {
            let mut sx = ShardedIndex::new(data.clone(), spec);
            let results = sx.query_batch_bounded_me(&[&q[..]], &params);
            // ε → 0: every shard eliminates on exact means, and the
            // confirm rescore ranks by exact products, so the merged
            // result *is* the exact top-k, in exact order.
            assert_eq!(results[0].indices, truth, "{spec:?}");
        }
    }

    #[test]
    fn shard_set_matches_sharded_index_on_generation_zero() {
        let data = gaussian(41, 96, 11);
        let queries: Vec<Vec<f32>> = (0..5).map(|i| Rng::new(70 + i).gaussian_vec(96)).collect();
        let refs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        for spec in [ShardSpec::contiguous(3), ShardSpec::single(), ShardSpec::round_robin(2)] {
            let g0 = Generation::initial(data.clone(), spec, EpochGauge::new());
            let set = ShardSet::build(Arc::clone(&g0), Storage::F32);
            let mut sx = ShardedIndex::new(data.clone(), spec);
            let mut ctxs: Vec<QueryContext> =
                (0..set.num_shards()).map(|_| QueryContext::new()).collect();
            let a = set.query_batch_exact(&refs, 4, &mut ctxs);
            let b = sx.query_batch_exact(&refs, 4);
            let params = MipsParams { k: 4, epsilon: 0.1, delta: 0.1, seed: 9 };
            let mut ctxs2: Vec<QueryContext> =
                (0..set.num_shards()).map(|_| QueryContext::new()).collect();
            let c = set.query_batch_bounded_me(&refs, &params, &mut ctxs2);
            let d = sx.query_batch_bounded_me(&refs, &params);
            for qi in 0..queries.len() {
                assert_eq!(a[qi].indices, b[qi].indices, "{spec:?} exact q{qi}");
                assert_eq!(a[qi].flops, b[qi].flops, "{spec:?} exact q{qi}");
                for (x, y) in a[qi].scores.iter().zip(&b[qi].scores) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{spec:?} exact q{qi}");
                }
                assert_eq!(c[qi].indices, d[qi].indices, "{spec:?} bme q{qi}");
                assert_eq!(c[qi].flops, d[qi].flops, "{spec:?} bme q{qi}");
                for (x, y) in c[qi].scores.iter().zip(&d[qi].scores) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{spec:?} bme q{qi}");
                }
            }
        }
    }

    #[test]
    fn advanced_shard_set_matches_from_scratch_after_flip() {
        let data = gaussian(36, 64, 21);
        let g0 = Generation::initial(data, ShardSpec::contiguous(3), EpochGauge::new());
        let set0 = ShardSet::build(Arc::clone(&g0), Storage::F32);
        let mut b = GenerationBuilder::new(&g0);
        b.upsert(2, Rng::new(77).gaussian_vec(64)).unwrap();
        b.upsert(30, Rng::new(78).gaussian_vec(64)).unwrap();
        let built = b.build().unwrap();
        assert!(built.reuse.iter().any(Option::is_some), "flip should reuse a shard");
        let pinned = ShardSet::advance(&set0, &built);
        // Reference: index the materialized snapshot from scratch.
        let fresh = ShardSet::build(
            Generation::initial(
                built.generation.materialize(),
                ShardSpec::contiguous(3),
                EpochGauge::new(),
            ),
            Storage::F32,
        );
        let queries: Vec<Vec<f32>> = (0..4).map(|i| Rng::new(90 + i).gaussian_vec(64)).collect();
        let refs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let params = MipsParams { k: 3, epsilon: 0.1, delta: 0.1, seed: 4 };
        let mut ca: Vec<QueryContext> = (0..3).map(|_| QueryContext::new()).collect();
        let mut cb: Vec<QueryContext> = (0..3).map(|_| QueryContext::new()).collect();
        let a = pinned.query_batch_bounded_me(&refs, &params, &mut ca);
        let b = fresh.query_batch_bounded_me(&refs, &params, &mut cb);
        let mut ca2: Vec<QueryContext> = (0..3).map(|_| QueryContext::new()).collect();
        let mut cb2: Vec<QueryContext> = (0..3).map(|_| QueryContext::new()).collect();
        let ea = pinned.query_batch_exact(&refs, 3, &mut ca2);
        let eb = fresh.query_batch_exact(&refs, 3, &mut cb2);
        for qi in 0..queries.len() {
            assert_eq!(a[qi].indices, b[qi].indices, "bme q{qi}");
            assert_eq!(a[qi].flops, b[qi].flops, "bme q{qi}");
            for (x, y) in a[qi].scores.iter().zip(&b[qi].scores) {
                assert_eq!(x.to_bits(), y.to_bits(), "bme q{qi}");
            }
            assert_eq!(ea[qi].indices, eb[qi].indices, "exact q{qi}");
            for (x, y) in ea[qi].scores.iter().zip(&eb[qi].scores) {
                assert_eq!(x.to_bits(), y.to_bits(), "exact q{qi}");
            }
        }
    }

    #[test]
    fn auto_routes_once_for_the_batch() {
        let data = gaussian(30, 32, 3);
        let mut sx = ShardedIndex::new(data.clone(), ShardSpec::contiguous(2));
        // dim 32 < 64 ⇒ plan says Exact no matter the knobs.
        assert_eq!(sx.plan(3, 0.5, 0.5).algo, PlanAlgo::Exact);
        let q: Vec<f32> = Rng::new(4).gaussian_vec(32);
        let res = sx.query_batch_auto(
            &[&q[..]],
            &MipsParams { k: 3, epsilon: 0.5, delta: 0.5, seed: 0 },
        );
        assert_eq!(res[0].indices, crate::algos::ground_truth(&data, &q, 3));
    }
}
