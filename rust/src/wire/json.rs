//! The default codec: newline-delimited JSON, bit-for-bit the protocol
//! the server spoke before codecs existed. Decoding is line splitting
//! only — parsing (and its `bad json` error text) stays inside
//! `coordinator::server::handle_line` so the behavior is provably
//! unchanged; encoding owns the response *shapes* (shared with
//! [`super::BinaryCodec`]'s embedded-JSON path).

use super::{error_json, Codec, FrameError, WireRequest};
use crate::coordinator::QueryResponse;
use crate::jsonlite::Json;

/// Build the line protocol's successful query reply object (the
/// single source for both codecs' JSON paths and `handle_line`).
/// Degradation fields mirror the binary codec's [`super::frame`]
/// response header: `degraded` + `epsilon_hat` + shard coverage, plus
/// the admission-degradation knobs when the coordinator applied any.
pub fn query_response_json(resp: &QueryResponse) -> Json {
    let mut pairs = vec![
        ("ok", Json::Bool(true)),
        ("indices", Json::usizes(&resp.indices)),
        ("scores", Json::f32s(&resp.scores)),
        ("flops", Json::Num(resp.flops as f64)),
        ("service_ms", Json::Num(resp.service.as_secs_f64() * 1e3)),
        ("batch", Json::Num(resp.batch_size as f64)),
        ("storage", Json::Str(resp.storage.label().into())),
        ("generation", Json::Num(resp.generation as f64)),
        ("degraded", Json::Bool(resp.degraded)),
        ("epsilon_hat", Json::Num(resp.epsilon_hat)),
        ("shards", Json::Num(resp.shards as f64)),
        ("shards_total", Json::Num(resp.shards_total as f64)),
    ];
    if let Some(eps) = resp.applied_epsilon {
        pairs.push(("applied_epsilon", Json::Num(eps)));
    }
    if let Some(k) = resp.applied_k {
        pairs.push(("applied_k", Json::Num(k as f64)));
    }
    Json::obj(pairs)
}

/// Newline-delimited JSON codec (the negotiation default).
#[derive(Default)]
pub struct LineJsonCodec {
    buf: Vec<u8>,
    /// Offset of the first byte not yet consumed by a returned line.
    start: usize,
}

impl LineJsonCodec {
    /// Fresh codec with a pre-sized line buffer.
    pub fn new() -> Self {
        LineJsonCodec { buf: Vec::with_capacity(16 * 1024), start: 0 }
    }
}

impl Codec for LineJsonCodec {
    fn name(&self) -> &'static str {
        "json"
    }

    fn feed(&mut self, bytes: &[u8]) {
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        } else if self.start > 0 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    fn try_decode(&mut self) -> Result<Option<WireRequest>, FrameError> {
        // Skip blank lines the way the old read_line loop did.
        loop {
            let Some(nl) = self.buf[self.start..].iter().position(|&b| b == b'\n') else {
                return Ok(None);
            };
            let line = &self.buf[self.start..self.start + nl];
            // Invalid UTF-8 becomes replacement chars and fails in
            // `handle_line` as `bad json` — an application-level reply,
            // never a framing error.
            let text = String::from_utf8_lossy(line).trim().to_string();
            self.start += nl + 1;
            if !text.is_empty() {
                return Ok(Some(WireRequest::Line(text)));
            }
        }
    }

    fn encode_json(&mut self, doc: &Json, out: &mut Vec<u8>) {
        out.extend_from_slice(doc.dump().as_bytes());
        out.push(b'\n');
    }

    fn encode_reply(&mut self, resp: &QueryResponse, out: &mut Vec<u8>) {
        let doc = if resp.shed {
            error_json("deadline exceeded (shed)")
        } else {
            query_response_json(resp)
        };
        self.encode_json(&doc, out);
    }

    fn encode_error(&mut self, msg: &str, out: &mut Vec<u8>) {
        self.encode_json(&error_json(msg), out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_lines_across_arbitrary_feeds() {
        let mut c = LineJsonCodec::new();
        c.feed(b"{\"op\":\"pi");
        assert!(matches!(c.try_decode(), Ok(None)));
        c.feed(b"ng\"}\n\n  \n{\"op\":\"metrics\"}\n");
        let Ok(Some(WireRequest::Line(a))) = c.try_decode() else { panic!() };
        assert_eq!(a, "{\"op\":\"ping\"}");
        // Blank lines are skipped, not surfaced.
        let Ok(Some(WireRequest::Line(b))) = c.try_decode() else { panic!() };
        assert_eq!(b, "{\"op\":\"metrics\"}");
        assert!(matches!(c.try_decode(), Ok(None)));
    }

    #[test]
    fn trims_carriage_returns_and_whitespace() {
        let mut c = LineJsonCodec::new();
        c.feed(b"  {\"op\":\"ping\"}\r\n");
        let Ok(Some(WireRequest::Line(a))) = c.try_decode() else { panic!() };
        assert_eq!(a, "{\"op\":\"ping\"}");
    }

    #[test]
    fn encodes_replies_with_trailing_newline() {
        let mut c = LineJsonCodec::new();
        let mut out = Vec::new();
        c.encode_error("nope", &mut out);
        assert_eq!(out, b"{\"ok\":false,\"error\":\"nope\"}\n");
    }
}
