//! Pluggable wire codecs for the TCP front-end.
//!
//! The paper's serving pitch — no preprocessing, cheap per-query
//! compute — means the *transport* tax can dominate at scale: a d=4096
//! query vector is ~13 ASCII bytes per coordinate as decimal JSON but
//! exactly 4 as a raw little-endian f32. This module makes the protocol
//! a [`Codec`] axis with two implementations:
//!
//! * [`LineJsonCodec`] — today's newline-delimited JSON, bit-for-bit
//!   (requests dispatch through `coordinator::server::handle_line`
//!   unchanged). The default; any JSON-speaking client keeps working.
//! * [`BinaryCodec`] — length-prefixed frames
//!   (see [`frame`] for the layout) carrying either an embedded JSON
//!   document ([`frame::OP_JSON`], so *every* op is reachable over
//!   binary transport) or a binary query batch ([`frame::OP_QUERY`]):
//!   one fixed [`frame::QueryHeader`] with the (k, ε, δ, seed,
//!   deadline, mode, storage) knobs, then B vectors of raw LE f32
//!   coordinates, contiguous, decoded straight off the frame buffer
//!   into the submission path — no intermediate JSON values. The B
//!   requests are submitted before any reply is awaited, so the
//!   coordinator's batcher admits them as one group.
//!
//! # Negotiation
//!
//! Per connection, on the first byte ([`negotiate`]): binary frames
//! lead with [`frame::MAGIC`]'s `b'P'`, which can never start a JSON
//! document, so existing clients need no changes and mixed fleets can
//! share one server port. A connection's codec is fixed once chosen.
//!
//! # Errors
//!
//! Application-level failures (unknown op, dimension mismatch, shed
//! deadline) are ordinary replies in either codec. *Frame*-level
//! violations ([`frame::FrameError`]: bad magic, zero/oversized length
//! prefix, truncated or inconsistent headers) are unrecoverable — the
//! server sends one encoded error and closes, since resync inside a
//! corrupted byte stream is guesswork.

use crate::coordinator::QueryRequest;
use crate::jsonlite::Json;
use std::sync::OnceLock;

pub mod binary;
pub mod frame;
pub mod json;

pub use binary::{BinaryCodec, QueryOpts, QueryReply};
pub use frame::{FrameDecoder, FrameError, FrameRef};
pub use json::LineJsonCodec;

/// Environment pin: `RUST_PALLAS_WIRE=binary` makes
/// [`crate::coordinator::server::Client::connect`] negotiate the binary
/// codec (JSON documents ride [`frame::OP_JSON`] frames transparently),
/// so the whole TCP test battery exercises [`BinaryCodec`] framing on
/// the CI `wire` leg. Any other value stays on line-JSON.
pub const WIRE_ENV: &str = "RUST_PALLAS_WIRE";

/// True when [`WIRE_ENV`] selects the binary codec (read once, cached).
pub fn binary_env_requested() -> bool {
    static PIN: OnceLock<bool> = OnceLock::new();
    *PIN.get_or_init(|| matches!(std::env::var(WIRE_ENV).as_deref(), Ok("binary")))
}

/// One decoded unit of client input, codec-agnostic.
pub enum WireRequest {
    /// A JSON document (from a text line or an [`frame::OP_JSON`]
    /// frame), raw — the server dispatches it through `handle_line`, so
    /// the line protocol's behavior (including its error strings) is
    /// preserved bit-for-bit.
    Line(String),
    /// A decoded binary query batch. The server submits every request
    /// before reaping replies, keeping the batch together through the
    /// coordinator's group-forming batcher.
    Query(Vec<QueryRequest>),
}

/// A wire protocol: buffered streaming decode of requests plus reply
/// encoding. One instance per connection (codecs carry buffer state).
pub trait Codec {
    /// Stable codec label for metrics and bench rows (`"json"` /
    /// `"binary"`).
    fn name(&self) -> &'static str;

    /// Buffer raw socket bytes.
    fn feed(&mut self, bytes: &[u8]);

    /// Decode the next complete request, if buffered bytes hold one.
    /// `Ok(None)` = need more bytes; `Err` = frame-level violation, the
    /// connection must close after one encoded error reply.
    fn try_decode(&mut self) -> Result<Option<WireRequest>, FrameError>;

    /// Encode a JSON reply document (responses to [`WireRequest::Line`]).
    fn encode_json(&mut self, doc: &Json, out: &mut Vec<u8>);

    /// Encode one query reply (responses to [`WireRequest::Query`],
    /// one per submitted request, in order).
    fn encode_reply(&mut self, resp: &crate::coordinator::QueryResponse, out: &mut Vec<u8>);

    /// Encode a terminal error (failed submissions and protocol
    /// violations).
    fn encode_error(&mut self, msg: &str, out: &mut Vec<u8>);
}

/// The line protocol's error shape, shared by both codecs (and by
/// `handle_line` itself).
pub fn error_json(msg: &str) -> Json {
    Json::obj([("ok", Json::Bool(false)), ("error", Json::Str(msg.to_string()))])
}

/// Pick a connection's codec from its first byte: [`frame::MAGIC`]'s
/// leading `b'P'` selects [`BinaryCodec`] (no JSON document can start
/// with `P`), anything else stays on the [`LineJsonCodec`] default —
/// including garbage, which then fails with the line protocol's
/// `bad json` reply exactly as before.
pub fn negotiate(first_byte: u8) -> Box<dyn Codec + Send> {
    if first_byte == frame::MAGIC[0] {
        Box::new(BinaryCodec::new())
    } else {
        Box::new(LineJsonCodec::new())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn negotiation_sniffs_the_first_byte() {
        assert_eq!(negotiate(b'P').name(), "binary");
        assert_eq!(negotiate(b'{').name(), "json");
        assert_eq!(negotiate(b' ').name(), "json");
        assert_eq!(negotiate(0x00).name(), "json");
    }

    #[test]
    fn error_shape_matches_line_protocol() {
        let e = error_json("nope");
        assert_eq!(e.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(e.get("error").unwrap().as_str(), Some("nope"));
    }
}
