//! Length-prefixed binary frames: preamble layout, the incremental
//! [`FrameDecoder`], and the fixed request/response headers.
//!
//! Every frame is
//!
//! ```text
//! ┌────────────┬────┬─────────────┬──────────────┬────────────┐
//! │ "PLW1"     │ op │ reserved    │ body_len     │ body       │
//! │ 4 B magic  │ u8 │ 3 B zeroes  │ u32 LE       │ body_len B │
//! └────────────┴────┴─────────────┴──────────────┴────────────┘
//! ```
//!
//! The magic's last byte is the protocol version: `'1'` is the
//! original layout, `'2'` is a minor revision whose only change is an
//! extra `budget_flops` u64 at the tail of [`QueryHeader`] (encoders
//! emit `'1'` whenever the budget is zero, so v1-only peers never see a
//! v2 frame they didn't ask for). A `body_len` of zero or above
//! [`MAX_BODY`] is rejected as soon as the 12-byte preamble is visible
//! — **before** any buffer is sized to it, so a hostile length prefix
//! cannot make the server allocate.

use std::fmt;

/// Frame magic (version 1); the last byte is the wire-format version.
pub const MAGIC: [u8; 4] = *b"PLW1";
/// Frame magic for the version-2 minor revision ([`QueryHeader`] grows
/// a trailing `budget_flops`; responses are layout-identical to v1).
pub const MAGIC_V2: [u8; 4] = *b"PLW2";
/// Bytes before the body: magic + op + 3 reserved + `body_len` u32.
pub const PREAMBLE_LEN: usize = 12;
/// Upper bound on `body_len` (64 MiB ≈ a 4096-dim f32 batch of 4096
/// vectors — far above any sane request, far below an allocation DoS).
pub const MAX_BODY: usize = 64 << 20;

/// Request op: the body is one JSON document, dispatched exactly like a
/// line of the line-JSON protocol (any op: `ping`, `metrics`, `mutate`,
/// even `query`).
pub const OP_JSON: u8 = 0x00;
/// Request op: a binary query batch ([`QueryHeader`] + raw LE f32
/// vectors). All vectors in one frame are admitted together, so the
/// batcher sees them as one group.
pub const OP_QUERY: u8 = 0x01;
/// Response op: body is one JSON document (the reply to [`OP_JSON`]).
pub const RESP_JSON: u8 = 0x80;
/// Response op: one [`RespHeader`] + indices/scores payload. An
/// [`OP_QUERY`] frame with B vectors is answered by B of these, in
/// request order.
pub const RESP_QUERY: u8 = 0x81;
/// Response op: UTF-8 error message (protocol violations and rejected
/// submissions).
pub const RESP_ERROR: u8 = 0x82;

/// Frame-layer violations. All of these are unrecoverable for the
/// connection: after [`FrameDecoder::try_frame`] returns one, resync
/// inside the byte stream is not attempted — the server replies with a
/// [`RESP_ERROR`] frame and closes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// First four bytes of a frame were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// `body_len` was zero (no op has an empty body).
    EmptyBody,
    /// `body_len` exceeded [`MAX_BODY`].
    Oversized(usize),
    /// A body ended before its fixed header was complete.
    Truncated {
        /// Bytes the header needed.
        need: usize,
        /// Bytes the body actually carried.
        got: usize,
    },
    /// A structurally complete header carried an invalid field.
    BadHeader(&'static str),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            FrameError::EmptyBody => write!(f, "zero-length frame body"),
            FrameError::Oversized(n) => {
                write!(f, "frame body of {n} bytes exceeds the {MAX_BODY}-byte cap")
            }
            FrameError::Truncated { need, got } => {
                write!(f, "truncated frame body: header needs {need} bytes, got {got}")
            }
            FrameError::BadHeader(what) => write!(f, "bad frame header: {what}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// One complete frame, borrowed from the decoder's buffer (zero-copy:
/// the body slice lives until the next `feed`/`try_frame`).
#[derive(Debug)]
pub struct FrameRef<'a> {
    /// The frame's op byte (`OP_*` / `RESP_*`).
    pub op: u8,
    /// Wire-format version the magic carried (`1` or `2`).
    pub version: u8,
    /// The frame body.
    pub body: &'a [u8],
}

/// Incremental frame extractor over a raw byte stream. Feed socket
/// reads in whatever chunks they arrive, pull complete frames out;
/// partial frames stay buffered until their bytes show up. Consumed
/// bytes are compacted away on the next `feed`, so the buffer's high
///-water mark tracks the largest single frame, not the stream length —
/// and a warmed decoder re-uses its buffer allocation-free.
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Offset of the first unconsumed byte in `buf`.
    start: usize,
}

impl FrameDecoder {
    /// Decoder with a pre-sized buffer (one socket read's worth), so
    /// typical control frames never allocate.
    pub fn new() -> Self {
        FrameDecoder { buf: Vec::with_capacity(16 * 1024), start: 0 }
    }

    /// Bytes buffered but not yet consumed by a returned frame.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Append raw stream bytes, compacting consumed ones first.
    pub fn feed(&mut self, bytes: &[u8]) {
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        } else if self.start > 0 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Extract the next complete frame, if the buffer holds one.
    ///
    /// `Ok(None)` means "need more bytes" — including a partial
    /// preamble. Length sanity (zero / oversized) is checked the moment
    /// the preamble is complete, independent of how much of the body has
    /// arrived, so a hostile prefix is rejected without buffering toward
    /// it.
    pub fn try_frame(&mut self) -> Result<Option<FrameRef<'_>>, FrameError> {
        let avail = self.buf.len() - self.start;
        if avail < PREAMBLE_LEN {
            // A wrong magic is detectable from the first divergent byte,
            // but waiting for the full preamble keeps the reject path
            // single: every error is raised from a complete preamble.
            return Ok(None);
        }
        let p = self.start;
        // "PLW" + a version byte we understand ('1' or '2').
        if self.buf[p..p + 3] != MAGIC[..3] || !matches!(self.buf[p + 3], b'1' | b'2') {
            return Err(FrameError::BadMagic([
                self.buf[p],
                self.buf[p + 1],
                self.buf[p + 2],
                self.buf[p + 3],
            ]));
        }
        let version = self.buf[p + 3] - b'0';
        let op = self.buf[p + 4];
        let body_len = u32::from_le_bytes([
            self.buf[p + 8],
            self.buf[p + 9],
            self.buf[p + 10],
            self.buf[p + 11],
        ]) as usize;
        if body_len == 0 {
            return Err(FrameError::EmptyBody);
        }
        if body_len > MAX_BODY {
            return Err(FrameError::Oversized(body_len));
        }
        if avail < PREAMBLE_LEN + body_len {
            return Ok(None);
        }
        let body_start = p + PREAMBLE_LEN;
        let end = body_start + body_len;
        self.start = end;
        Ok(Some(FrameRef { op, version, body: &self.buf[body_start..end] }))
    }
}

/// Append one complete frame (preamble + body) to `out`.
pub fn encode_frame(op: u8, body: &[u8], out: &mut Vec<u8>) {
    let at = begin_frame(op, out);
    out.extend_from_slice(body);
    end_frame(at, out);
}

/// Start a frame whose body is written directly into `out` (avoids a
/// staging buffer for vector payloads); returns the patch cookie for
/// [`end_frame`]. Emits the version-1 magic.
pub fn begin_frame(op: u8, out: &mut Vec<u8>) -> usize {
    begin_frame_v(op, 1, out)
}

/// [`begin_frame`] with an explicit wire-format version (1 or 2).
pub fn begin_frame_v(op: u8, version: u8, out: &mut Vec<u8>) -> usize {
    out.extend_from_slice(if version >= 2 { &MAGIC_V2 } else { &MAGIC });
    out.push(op);
    out.extend_from_slice(&[0u8; 3]);
    let at = out.len();
    out.extend_from_slice(&[0u8; 4]);
    at
}

/// Patch the `body_len` of a frame started with [`begin_frame`] once
/// its body bytes are in place.
pub fn end_frame(at: usize, out: &mut Vec<u8>) {
    let body_len = (out.len() - at - 4) as u32;
    out[at..at + 4].copy_from_slice(&body_len.to_le_bytes());
}

/// Fixed header of an [`OP_QUERY`] body. One header covers the whole
/// batch: `count` vectors of `dim` raw little-endian f32 coordinates
/// follow contiguously, and `body_len` must equal
/// `QUERY_HEADER_LEN + count·dim·4` exactly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QueryHeader {
    /// Top-K per query.
    pub k: u32,
    /// Range-relative ε.
    pub epsilon: f64,
    /// Failure probability δ.
    pub delta: f64,
    /// Pull-order seed shared by the batch.
    pub seed: u64,
    /// Deadline in nanoseconds (0 = none).
    pub deadline_ns: u64,
    /// Query mode (see `mode_to_byte` in [`super::binary`]).
    pub mode: u8,
    /// Storage-tier override (see `storage_to_byte`; 0 = deployment
    /// default).
    pub storage: u8,
    /// Vectors in the batch (≥ 1).
    pub count: u32,
    /// Coordinates per vector (≥ 1).
    pub dim: u32,
    /// Anytime FLOP budget (0 = none). Rides only v2 frames: the
    /// encoder emits the v1 layout whenever this is zero, so a
    /// budget-free stream is byte-identical to the original protocol.
    pub budget_flops: u64,
}

/// Bytes of a serialized version-1 [`QueryHeader`].
pub const QUERY_HEADER_LEN: usize = 48;
/// Bytes of a serialized version-2 [`QueryHeader`] (v1 + `budget_flops`).
pub const QUERY_HEADER_LEN_V2: usize = 56;

impl QueryHeader {
    /// Wire-format version this header needs: v2 iff it carries a
    /// non-zero `budget_flops`.
    pub fn version(&self) -> u8 {
        if self.budget_flops > 0 {
            2
        } else {
            1
        }
    }

    /// Header length for a given wire-format version.
    pub fn len_for(version: u8) -> usize {
        if version >= 2 {
            QUERY_HEADER_LEN_V2
        } else {
            QUERY_HEADER_LEN
        }
    }

    /// Serialize into `out` ([`QUERY_HEADER_LEN`] bytes for v1,
    /// [`QUERY_HEADER_LEN_V2`] for v2 — pick by [`Self::version`]).
    pub fn write(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.k.to_le_bytes());
        out.extend_from_slice(&self.epsilon.to_le_bytes());
        out.extend_from_slice(&self.delta.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&self.deadline_ns.to_le_bytes());
        out.push(self.mode);
        out.push(self.storage);
        out.extend_from_slice(&[0u8; 2]);
        out.extend_from_slice(&self.count.to_le_bytes());
        out.extend_from_slice(&self.dim.to_le_bytes());
        if self.version() >= 2 {
            out.extend_from_slice(&self.budget_flops.to_le_bytes());
        }
    }

    /// Parse from an [`OP_QUERY`] body of the given wire-format
    /// `version`, validating the payload length against `count · dim`
    /// (in u64 so a hostile header cannot overflow the check itself).
    pub fn parse(body: &[u8], version: u8) -> Result<QueryHeader, FrameError> {
        let header_len = Self::len_for(version);
        if body.len() < header_len {
            return Err(FrameError::Truncated { need: header_len, got: body.len() });
        }
        let h = QueryHeader {
            k: u32::from_le_bytes(body[0..4].try_into().unwrap()),
            epsilon: f64::from_le_bytes(body[4..12].try_into().unwrap()),
            delta: f64::from_le_bytes(body[12..20].try_into().unwrap()),
            seed: u64::from_le_bytes(body[20..28].try_into().unwrap()),
            deadline_ns: u64::from_le_bytes(body[28..36].try_into().unwrap()),
            mode: body[36],
            storage: body[37],
            count: u32::from_le_bytes(body[40..44].try_into().unwrap()),
            dim: u32::from_le_bytes(body[44..48].try_into().unwrap()),
            budget_flops: if version >= 2 {
                u64::from_le_bytes(body[48..56].try_into().unwrap())
            } else {
                0
            },
        };
        if h.count == 0 {
            return Err(FrameError::BadHeader("query count must be >= 1"));
        }
        if h.dim == 0 {
            return Err(FrameError::BadHeader("query dim must be >= 1"));
        }
        let want = header_len as u64 + h.count as u64 * h.dim as u64 * 4;
        if body.len() as u64 != want {
            return Err(FrameError::BadHeader("payload length != count * dim * 4"));
        }
        Ok(h)
    }
}

/// Fixed header of a [`RESP_QUERY`] body, followed by `count` u64 LE
/// indices then `count` f32 LE scores. The layout is version-1 stable:
/// the degradation fields live in bytes that were previously reserved
/// zeroes, so an exact-complete reply is byte-identical to the original
/// protocol and v1 peers that ignored the reserved bytes keep working.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RespHeader {
    /// [`FLAG_OK`] / [`FLAG_SHED`] / [`FLAG_DEGRADED`] bits.
    pub flags: u8,
    /// Storage tier the answer sampled on (`storage_to_byte` of a
    /// concrete tier, never 0).
    pub storage: u8,
    /// Shards whose partials the answer folded (equals `shards_total`
    /// for exact-complete replies, 0 for shed ones).
    pub covered: u8,
    /// Shards the deployment serves (0 on pre-degradation replies,
    /// whose reserved byte was always zero).
    pub shards_total: u8,
    /// Result entries in the payload.
    pub count: u32,
    /// Flops the query spent.
    pub flops: u64,
    /// Service time, ns.
    pub service_ns: u64,
    /// Generation the indices refer to.
    pub generation: u64,
    /// Batch size the query rode in.
    pub batch: u32,
    /// Achieved confidence width ε̂ of a degraded reply (0 otherwise).
    pub epsilon_hat: f32,
}

/// Bytes of a serialized [`RespHeader`].
pub const RESP_HEADER_LEN: usize = 40;
/// [`RespHeader::flags`] bit: the query produced results.
pub const FLAG_OK: u8 = 1;
/// [`RespHeader::flags`] bit: the query was shed (deadline exceeded
/// with nothing harvestable; no results).
pub const FLAG_SHED: u8 = 2;
/// [`RespHeader::flags`] bit: the reply is degraded — a mid-run harvest
/// and/or partial shard coverage; results are present and `epsilon_hat`
/// / `covered` report the achieved fidelity. Exact-complete replies set
/// neither [`FLAG_SHED`] nor this bit.
pub const FLAG_DEGRADED: u8 = 4;

impl RespHeader {
    /// Serialize into `out` (exactly [`RESP_HEADER_LEN`] bytes).
    pub fn write(&self, out: &mut Vec<u8>) {
        out.push(self.flags);
        out.push(self.storage);
        out.push(self.covered);
        out.push(self.shards_total);
        out.extend_from_slice(&self.count.to_le_bytes());
        out.extend_from_slice(&self.flops.to_le_bytes());
        out.extend_from_slice(&self.service_ns.to_le_bytes());
        out.extend_from_slice(&self.generation.to_le_bytes());
        out.extend_from_slice(&self.batch.to_le_bytes());
        out.extend_from_slice(&self.epsilon_hat.to_le_bytes());
    }

    /// Parse from a [`RESP_QUERY`] body, validating the payload length
    /// against `count` (12 bytes per entry: u64 index + f32 score).
    pub fn parse(body: &[u8]) -> Result<RespHeader, FrameError> {
        if body.len() < RESP_HEADER_LEN {
            return Err(FrameError::Truncated { need: RESP_HEADER_LEN, got: body.len() });
        }
        let h = RespHeader {
            flags: body[0],
            storage: body[1],
            covered: body[2],
            shards_total: body[3],
            count: u32::from_le_bytes(body[4..8].try_into().unwrap()),
            flops: u64::from_le_bytes(body[8..16].try_into().unwrap()),
            service_ns: u64::from_le_bytes(body[16..24].try_into().unwrap()),
            generation: u64::from_le_bytes(body[24..32].try_into().unwrap()),
            batch: u32::from_le_bytes(body[32..36].try_into().unwrap()),
            epsilon_hat: f32::from_le_bytes(body[36..40].try_into().unwrap()),
        };
        let want = RESP_HEADER_LEN as u64 + h.count as u64 * 12;
        if body.len() as u64 != want {
            return Err(FrameError::BadHeader("payload length != count * 12"));
        }
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_in_one_feed() {
        let mut dec = FrameDecoder::new();
        let mut wire = Vec::new();
        encode_frame(OP_JSON, b"{\"op\":\"ping\"}", &mut wire);
        encode_frame(RESP_ERROR, b"nope", &mut wire);
        dec.feed(&wire);
        let f = dec.try_frame().unwrap().unwrap();
        assert_eq!((f.op, f.body), (OP_JSON, &b"{\"op\":\"ping\"}"[..]));
        let f = dec.try_frame().unwrap().unwrap();
        assert_eq!((f.op, f.body), (RESP_ERROR, &b"nope"[..]));
        assert!(dec.try_frame().unwrap().is_none());
        assert_eq!(dec.buffered(), 0);
    }

    #[test]
    fn partial_reads_at_every_byte_boundary() {
        let mut wire = Vec::new();
        encode_frame(OP_JSON, b"abc", &mut wire);
        for cut in 0..=wire.len() {
            let mut dec = FrameDecoder::new();
            dec.feed(&wire[..cut]);
            if cut < wire.len() {
                assert!(dec.try_frame().unwrap().is_none(), "cut={cut}");
                dec.feed(&wire[cut..]);
            }
            let f = dec.try_frame().unwrap().unwrap();
            assert_eq!((f.op, f.body), (OP_JSON, &b"abc"[..]), "cut={cut}");
        }
    }

    #[test]
    fn zero_and_oversized_lengths_rejected_from_preamble_alone() {
        for (len, want_err) in [
            (0u32, FrameError::EmptyBody),
            ((MAX_BODY + 1) as u32, FrameError::Oversized(MAX_BODY + 1)),
        ] {
            let mut dec = FrameDecoder::new();
            let mut preamble = Vec::new();
            preamble.extend_from_slice(&MAGIC);
            preamble.push(OP_QUERY);
            preamble.extend_from_slice(&[0u8; 3]);
            preamble.extend_from_slice(&len.to_le_bytes());
            dec.feed(&preamble);
            assert_eq!(dec.try_frame().unwrap_err(), want_err);
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let mut dec = FrameDecoder::new();
        dec.feed(b"GET / HTTP/1.1\r\n");
        assert!(matches!(dec.try_frame(), Err(FrameError::BadMagic(_))));
    }

    #[test]
    fn query_header_roundtrip_and_length_check() {
        let h = QueryHeader {
            k: 5,
            epsilon: 0.1,
            delta: 0.05,
            seed: 42,
            deadline_ns: 1_000_000,
            mode: 0,
            storage: 2,
            count: 3,
            dim: 4,
            budget_flops: 0,
        };
        assert_eq!(h.version(), 1);
        let mut body = Vec::new();
        h.write(&mut body);
        assert_eq!(body.len(), QUERY_HEADER_LEN);
        body.extend_from_slice(&[0u8; 3 * 4 * 4]); // count * dim * 4
        assert_eq!(QueryHeader::parse(&body, 1).unwrap(), h);
        // Any other payload length is rejected.
        body.push(0);
        assert!(matches!(QueryHeader::parse(&body, 1), Err(FrameError::BadHeader(_))));
        body.truncate(QUERY_HEADER_LEN - 1);
        assert!(matches!(QueryHeader::parse(&body, 1), Err(FrameError::Truncated { .. })));
    }

    #[test]
    fn query_header_v2_carries_budget() {
        let h = QueryHeader {
            k: 2,
            epsilon: 0.2,
            delta: 0.1,
            seed: 7,
            deadline_ns: 0,
            mode: 0,
            storage: 0,
            count: 1,
            dim: 8,
            budget_flops: 123_456,
        };
        assert_eq!(h.version(), 2);
        let mut body = Vec::new();
        h.write(&mut body);
        assert_eq!(body.len(), QUERY_HEADER_LEN_V2);
        body.extend_from_slice(&[0u8; 8 * 4]); // count * dim * 4
        assert_eq!(QueryHeader::parse(&body, 2).unwrap(), h);
        // A v1 parse of a v2 body fails the length check instead of
        // silently mis-slicing the vector payload.
        assert!(matches!(QueryHeader::parse(&body, 1), Err(FrameError::BadHeader(_))));
        body.truncate(QUERY_HEADER_LEN_V2 - 1);
        assert!(matches!(QueryHeader::parse(&body, 2), Err(FrameError::Truncated { .. })));
    }

    #[test]
    fn v2_magic_negotiated_per_frame() {
        let mut wire = Vec::new();
        let at = begin_frame_v(OP_QUERY, 2, &mut wire);
        wire.extend_from_slice(b"xx");
        end_frame(at, &mut wire);
        encode_frame(OP_JSON, b"{}", &mut wire); // v1 alongside
        let mut dec = FrameDecoder::new();
        dec.feed(&wire);
        let f = dec.try_frame().unwrap().unwrap();
        assert_eq!((f.op, f.version), (OP_QUERY, 2));
        let f = dec.try_frame().unwrap().unwrap();
        assert_eq!((f.op, f.version), (OP_JSON, 1));
        // Unknown versions are rejected as bad magic.
        let mut dec = FrameDecoder::new();
        dec.feed(b"PLW3\x00\x00\x00\x00\x01\x00\x00\x00");
        assert!(matches!(dec.try_frame(), Err(FrameError::BadMagic(_))));
    }

    #[test]
    fn resp_header_roundtrip() {
        let h = RespHeader {
            flags: FLAG_OK,
            storage: 1,
            covered: 0,
            shards_total: 0,
            count: 2,
            flops: 12345,
            service_ns: 67890,
            generation: 3,
            batch: 8,
            epsilon_hat: 0.0,
        };
        let mut body = Vec::new();
        h.write(&mut body);
        assert_eq!(body.len(), RESP_HEADER_LEN);
        body.extend_from_slice(&[0u8; 2 * 12]);
        assert_eq!(RespHeader::parse(&body).unwrap(), h);
        body.pop();
        assert!(matches!(RespHeader::parse(&body), Err(FrameError::BadHeader(_))));
    }

    #[test]
    fn resp_header_degraded_fields_roundtrip() {
        let h = RespHeader {
            flags: FLAG_OK | FLAG_DEGRADED,
            storage: 1,
            covered: 3,
            shards_total: 4,
            count: 0,
            flops: 10,
            service_ns: 20,
            generation: 0,
            batch: 1,
            epsilon_hat: 0.125,
        };
        let mut body = Vec::new();
        h.write(&mut body);
        // count = 0 ⇒ header-only body, still length-checked.
        assert_eq!(RespHeader::parse(&body).unwrap(), h);
        // The degradation fields live where v1 wrote reserved zeroes:
        // an exact-complete reply still zeroes them.
        let plain = RespHeader { flags: FLAG_OK, covered: 0, shards_total: 0, epsilon_hat: 0.0, ..h };
        let mut body = Vec::new();
        plain.write(&mut body);
        assert_eq!(body[2], 0);
        assert_eq!(body[3], 0);
        assert_eq!(&body[36..40], &[0u8; 4]);
    }

    #[test]
    fn decoder_compacts_consumed_bytes() {
        let mut dec = FrameDecoder::new();
        let mut wire = Vec::new();
        encode_frame(OP_JSON, &[7u8; 100], &mut wire);
        for _ in 0..50 {
            dec.feed(&wire);
            assert!(dec.try_frame().unwrap().is_some());
            assert!(dec.try_frame().unwrap().is_none());
        }
        // Fully drained between feeds ⇒ the buffer never grows past one
        // frame's worth.
        assert_eq!(dec.buffered(), 0);
    }
}
