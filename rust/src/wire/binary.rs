//! The binary codec: [`super::frame`] frames over the socket, with the
//! query payload as raw little-endian f32 — one bulk byte-to-float
//! conversion straight off the frame buffer, no JSON values anywhere on
//! the path. A warmed decoder extracts query payloads with zero
//! allocations (gated by the counting allocator in the serving bench).

use super::frame::{
    self, FrameError, QueryHeader, RespHeader, FLAG_DEGRADED, FLAG_OK, FLAG_SHED,
    RESP_HEADER_LEN,
};
use super::{Codec, WireRequest};
use crate::coordinator::{QueryMode, QueryRequest, QueryResponse};
use crate::data::quant::Storage;
use crate::jsonlite::Json;
use std::time::{Duration, Instant};

/// [`QueryHeader::mode`] encoding.
pub fn mode_to_byte(mode: QueryMode) -> u8 {
    match mode {
        QueryMode::BoundedMe => 0,
        QueryMode::Exact => 1,
        QueryMode::Auto => 2,
    }
}

/// Inverse of [`mode_to_byte`].
pub fn mode_from_byte(b: u8) -> Result<QueryMode, FrameError> {
    match b {
        0 => Ok(QueryMode::BoundedMe),
        1 => Ok(QueryMode::Exact),
        2 => Ok(QueryMode::Auto),
        _ => Err(FrameError::BadHeader("unknown query mode byte")),
    }
}

/// [`QueryHeader::storage`] encoding: 0 = no override (deployment
/// default), 1–4 = an explicit tier.
pub fn storage_to_byte(storage: Option<Storage>) -> u8 {
    match storage {
        None => 0,
        Some(Storage::F32) => 1,
        Some(Storage::F16) => 2,
        Some(Storage::Bf16) => 3,
        Some(Storage::Int8) => 4,
    }
}

/// Inverse of [`storage_to_byte`].
pub fn storage_from_byte(b: u8) -> Result<Option<Storage>, FrameError> {
    match b {
        0 => Ok(None),
        1 => Ok(Some(Storage::F32)),
        2 => Ok(Some(Storage::F16)),
        3 => Ok(Some(Storage::Bf16)),
        4 => Ok(Some(Storage::Int8)),
        _ => Err(FrameError::BadHeader("unknown storage byte")),
    }
}

/// Per-batch query knobs for [`encode_query_frame`] /
/// [`crate::coordinator::server::Client::query_binary`]. Defaults
/// mirror the JSON protocol's (k=10, ε=δ=0.1, BOUNDEDME, no deadline,
/// deployment storage).
#[derive(Clone, Debug)]
pub struct QueryOpts {
    /// Top-K per query.
    pub k: usize,
    /// Range-relative ε.
    pub epsilon: f64,
    /// Failure probability δ.
    pub delta: f64,
    /// Pull-order seed shared by the batch.
    pub seed: u64,
    /// Query mode.
    pub mode: QueryMode,
    /// Per-request deadline.
    pub deadline: Option<Duration>,
    /// Anytime FLOP budget. `Some` promotes the frame to the PLW2
    /// layout; `None` keeps it byte-identical to the v1 protocol.
    pub budget_flops: Option<u64>,
    /// Storage-tier override (see
    /// [`crate::coordinator::resolve_storage`]).
    pub storage: Option<Storage>,
}

impl Default for QueryOpts {
    fn default() -> Self {
        QueryOpts {
            k: 10,
            epsilon: 0.1,
            delta: 0.1,
            seed: 0,
            mode: QueryMode::BoundedMe,
            deadline: None,
            budget_flops: None,
            storage: None,
        }
    }
}

/// One decoded [`frame::RESP_QUERY`] (or [`frame::RESP_ERROR`]) reply.
#[derive(Clone, Debug)]
pub struct QueryReply {
    /// The query produced results.
    pub ok: bool,
    /// The query was shed (deadline exceeded with nothing harvestable;
    /// no results).
    pub shed: bool,
    /// The reply is degraded: a mid-run harvest and/or partial shard
    /// coverage. Results are present; `epsilon_hat` and `covered`
    /// report the achieved fidelity. Exactly one of `shed`, `degraded`,
    /// or neither (exact-complete) holds for an ok/shed reply.
    pub degraded: bool,
    /// Achieved confidence width ε̂ of a degraded reply (0 otherwise).
    pub epsilon_hat: f32,
    /// Shards whose partials the answer folded.
    pub covered: u8,
    /// Shards the deployment serves.
    pub shards_total: u8,
    /// Error message when the reply was a [`frame::RESP_ERROR`] frame.
    pub error: Option<String>,
    /// Result row ids, best first.
    pub indices: Vec<u64>,
    /// Result scores, best first (bit-exact f32 off the wire).
    pub scores: Vec<f32>,
    /// Flops the query spent.
    pub flops: u64,
    /// Service time, ns.
    pub service_ns: u64,
    /// Generation the indices refer to.
    pub generation: u64,
    /// Batch size the query rode in.
    pub batch: u32,
    /// Storage tier the sampling step ran on.
    pub storage: Storage,
}

impl QueryReply {
    /// Reply shape of a [`frame::RESP_ERROR`] frame.
    pub fn from_error(msg: String) -> QueryReply {
        QueryReply {
            ok: false,
            shed: false,
            degraded: false,
            epsilon_hat: 0.0,
            covered: 0,
            shards_total: 0,
            error: Some(msg),
            indices: Vec::new(),
            scores: Vec::new(),
            flops: 0,
            service_ns: 0,
            generation: 0,
            batch: 0,
            storage: Storage::F32,
        }
    }
}

/// Encode one [`frame::OP_QUERY`] frame carrying `vectors` as one
/// batch. All vectors must share one nonzero dimension.
pub fn encode_query_frame(
    vectors: &[&[f32]],
    opts: &QueryOpts,
    out: &mut Vec<u8>,
) -> Result<(), FrameError> {
    if vectors.is_empty() {
        return Err(FrameError::BadHeader("query count must be >= 1"));
    }
    let dim = vectors[0].len();
    if dim == 0 || vectors.iter().any(|v| v.len() != dim) {
        return Err(FrameError::BadHeader("vectors must share one nonzero dim"));
    }
    let h = QueryHeader {
        k: opts.k as u32,
        epsilon: opts.epsilon,
        delta: opts.delta,
        seed: opts.seed,
        deadline_ns: opts.deadline.map(|d| d.as_nanos() as u64).unwrap_or(0),
        mode: mode_to_byte(opts.mode),
        storage: storage_to_byte(opts.storage),
        count: vectors.len() as u32,
        dim: dim as u32,
        budget_flops: opts.budget_flops.unwrap_or(0),
    };
    // Budget-free frames stay on the v1 magic + 48-byte header, so an
    // unbudgeted stream is byte-identical to the original protocol.
    let at = frame::begin_frame_v(frame::OP_QUERY, h.version(), out);
    h.write(out);
    out.reserve(vectors.len() * dim * 4);
    for v in vectors {
        for x in *v {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    frame::end_frame(at, out);
    Ok(())
}

/// Decode-only fast path (the serving bench's `wire_binary` rows):
/// parse an [`frame::OP_QUERY`] body's header and bulk-convert every
/// coordinate into `coords`. A warmed `coords` is reused without
/// reallocation, so the steady state is allocation-free.
pub fn decode_query_payload(
    body: &[u8],
    version: u8,
    coords: &mut Vec<f32>,
) -> Result<QueryHeader, FrameError> {
    let h = QueryHeader::parse(body, version)?;
    coords.clear();
    coords.reserve(h.count as usize * h.dim as usize);
    coords.extend(
        body[QueryHeader::len_for(version)..]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
    );
    Ok(h)
}

/// Decode one [`frame::RESP_QUERY`] body.
pub fn decode_reply(body: &[u8]) -> Result<QueryReply, FrameError> {
    let h = RespHeader::parse(body)?;
    let storage = storage_from_byte(h.storage)?
        .ok_or(FrameError::BadHeader("response storage byte must name a tier"))?;
    let n = h.count as usize;
    let mut indices = Vec::with_capacity(n);
    let mut off = RESP_HEADER_LEN;
    for _ in 0..n {
        indices.push(u64::from_le_bytes(body[off..off + 8].try_into().unwrap()));
        off += 8;
    }
    let mut scores = Vec::with_capacity(n);
    for _ in 0..n {
        scores.push(f32::from_le_bytes(body[off..off + 4].try_into().unwrap()));
        off += 4;
    }
    Ok(QueryReply {
        ok: h.flags & FLAG_OK != 0,
        shed: h.flags & FLAG_SHED != 0,
        degraded: h.flags & FLAG_DEGRADED != 0,
        epsilon_hat: h.epsilon_hat,
        covered: h.covered,
        shards_total: h.shards_total,
        error: None,
        indices,
        scores,
        flops: h.flops,
        service_ns: h.service_ns,
        generation: h.generation,
        batch: h.batch,
        storage,
    })
}

/// Length-prefixed binary codec (negotiated by a leading frame magic).
#[derive(Default)]
pub struct BinaryCodec {
    dec: frame::FrameDecoder,
}

impl BinaryCodec {
    /// Fresh codec.
    pub fn new() -> Self {
        BinaryCodec { dec: frame::FrameDecoder::new() }
    }
}

impl Codec for BinaryCodec {
    fn name(&self) -> &'static str {
        "binary"
    }

    fn feed(&mut self, bytes: &[u8]) {
        self.dec.feed(bytes);
    }

    fn try_decode(&mut self) -> Result<Option<WireRequest>, FrameError> {
        let t0 = Instant::now();
        let Some(f) = self.dec.try_frame()? else {
            return Ok(None);
        };
        match f.op {
            frame::OP_JSON => {
                let text = String::from_utf8_lossy(f.body).trim().to_string();
                Ok(Some(WireRequest::Line(text)))
            }
            frame::OP_QUERY => {
                let h = QueryHeader::parse(f.body, f.version)?;
                let mode = mode_from_byte(h.mode)?;
                let storage = storage_from_byte(h.storage)?;
                let deadline =
                    (h.deadline_ns > 0).then(|| Duration::from_nanos(h.deadline_ns));
                let budget_flops = (h.budget_flops > 0).then_some(h.budget_flops);
                let dim = h.dim as usize;
                let mut requests = Vec::with_capacity(h.count as usize);
                let mut off = QueryHeader::len_for(f.version);
                for _ in 0..h.count {
                    // The one unavoidable copy: bulk LE bytes → the
                    // owned coordinate vector the coordinator takes.
                    let mut vector = Vec::with_capacity(dim);
                    vector.extend(
                        f.body[off..off + dim * 4]
                            .chunks_exact(4)
                            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
                    );
                    off += dim * 4;
                    requests.push(QueryRequest {
                        vector,
                        k: h.k as usize,
                        epsilon: h.epsilon,
                        delta: h.delta,
                        mode,
                        seed: h.seed,
                        deadline,
                        budget_flops,
                        storage,
                        decode_ns: 0,
                    });
                }
                // Frame decode happened before submission; the
                // coordinator re-anchors this as a `decode` span.
                let decode_ns = t0.elapsed().as_nanos() as u64;
                for r in &mut requests {
                    r.decode_ns = decode_ns;
                }
                Ok(Some(WireRequest::Query(requests)))
            }
            _ => Err(FrameError::BadHeader("unknown request op")),
        }
    }

    fn encode_json(&mut self, doc: &Json, out: &mut Vec<u8>) {
        frame::encode_frame(frame::RESP_JSON, doc.dump().as_bytes(), out);
    }

    fn encode_reply(&mut self, resp: &QueryResponse, out: &mut Vec<u8>) {
        let at = frame::begin_frame(frame::RESP_QUERY, out);
        // Three-way split on the wire: shed (empty), degraded
        // (harvested / partial coverage), or exact-complete (plain OK).
        let flags = if resp.shed {
            FLAG_SHED
        } else if resp.degraded {
            FLAG_OK | FLAG_DEGRADED
        } else {
            FLAG_OK
        };
        RespHeader {
            flags,
            storage: storage_to_byte(Some(resp.storage)),
            covered: resp.shards.min(u8::MAX as usize) as u8,
            shards_total: resp.shards_total.min(u8::MAX as usize) as u8,
            count: resp.indices.len() as u32,
            flops: resp.flops,
            service_ns: resp.service.as_nanos() as u64,
            generation: resp.generation,
            batch: resp.batch_size as u32,
            epsilon_hat: resp.epsilon_hat as f32,
        }
        .write(out);
        for &i in &resp.indices {
            out.extend_from_slice(&(i as u64).to_le_bytes());
        }
        for &s in &resp.scores {
            out.extend_from_slice(&s.to_le_bytes());
        }
        frame::end_frame(at, out);
    }

    fn encode_error(&mut self, msg: &str, out: &mut Vec<u8>) {
        frame::encode_frame(frame::RESP_ERROR, msg.as_bytes(), out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_maps_roundtrip_and_reject_unknowns() {
        for mode in [QueryMode::BoundedMe, QueryMode::Exact, QueryMode::Auto] {
            assert_eq!(mode_from_byte(mode_to_byte(mode)).unwrap(), mode);
        }
        assert!(mode_from_byte(9).is_err());
        for s in [
            None,
            Some(Storage::F32),
            Some(Storage::F16),
            Some(Storage::Bf16),
            Some(Storage::Int8),
        ] {
            assert_eq!(storage_from_byte(storage_to_byte(s)).unwrap(), s);
        }
        assert!(storage_from_byte(200).is_err());
    }

    #[test]
    fn query_frame_roundtrips_through_the_codec() {
        let v0: Vec<f32> = (0..8).map(|i| i as f32 * 0.5 - 2.0).collect();
        let v1: Vec<f32> = (0..8).map(|i| -(i as f32) * 0.25).collect();
        let opts = QueryOpts {
            k: 3,
            epsilon: 0.07,
            delta: 0.02,
            seed: 99,
            mode: QueryMode::Auto,
            deadline: Some(Duration::from_millis(40)),
            storage: Some(Storage::Int8),
        };
        let mut wire = Vec::new();
        encode_query_frame(&[&v0, &v1], &opts, &mut wire).unwrap();
        let mut codec = BinaryCodec::new();
        codec.feed(&wire);
        let Ok(Some(WireRequest::Query(reqs))) = codec.try_decode() else {
            panic!("expected a query batch");
        };
        assert_eq!(reqs.len(), 2);
        for (req, v) in reqs.iter().zip([&v0, &v1]) {
            assert_eq!(req.k, 3);
            assert_eq!(req.epsilon, 0.07);
            assert_eq!(req.delta, 0.02);
            assert_eq!(req.seed, 99);
            assert_eq!(req.mode, QueryMode::Auto);
            assert_eq!(req.deadline, Some(Duration::from_millis(40)));
            assert_eq!(req.storage, Some(Storage::Int8));
            // Coordinates survive bit-exactly (raw LE f32, no decimal
            // round-trip).
            for (a, b) in req.vector.iter().zip(v.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn reply_roundtrips_bit_exactly() {
        let resp = QueryResponse {
            indices: vec![4, 17, 0],
            scores: vec![3.5, -0.25, f32::MIN_POSITIVE],
            flops: 9876,
            queue_wait: Duration::from_micros(12),
            service: Duration::from_micros(345),
            batch_size: 7,
            worker: 2,
            shed: false,
            degraded: false,
            epsilon_hat: 0.0,
            shards: 1,
            shards_total: 1,
            storage: Storage::Bf16,
            generation: 5,
            applied_epsilon: None,
            applied_k: None,
        };
        let mut codec = BinaryCodec::new();
        let mut wire = Vec::new();
        codec.encode_reply(&resp, &mut wire);
        let mut dec = frame::FrameDecoder::new();
        dec.feed(&wire);
        let f = dec.try_frame().unwrap().unwrap();
        assert_eq!(f.op, frame::RESP_QUERY);
        let reply = decode_reply(f.body).unwrap();
        assert!(reply.ok && !reply.shed);
        assert_eq!(reply.indices, vec![4, 17, 0]);
        for (a, b) in reply.scores.iter().zip(&resp.scores) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(reply.flops, 9876);
        assert_eq!(reply.service_ns, 345_000);
        assert_eq!(reply.generation, 5);
        assert_eq!(reply.batch, 7);
        assert_eq!(reply.storage, Storage::Bf16);
    }

    #[test]
    fn shed_reply_carries_the_flag_and_no_results() {
        let resp = QueryResponse {
            indices: Vec::new(),
            scores: Vec::new(),
            flops: 0,
            queue_wait: Duration::from_micros(900),
            service: Duration::ZERO,
            batch_size: 0,
            worker: usize::MAX,
            shed: true,
            degraded: false,
            epsilon_hat: 0.0,
            shards: 0,
            shards_total: 2,
            storage: Storage::F32,
            generation: 0,
            applied_epsilon: None,
            applied_k: None,
        };
        let mut codec = BinaryCodec::new();
        let mut wire = Vec::new();
        codec.encode_reply(&resp, &mut wire);
        let mut dec = frame::FrameDecoder::new();
        dec.feed(&wire);
        let f = dec.try_frame().unwrap().unwrap();
        let reply = decode_reply(f.body).unwrap();
        assert!(!reply.ok && reply.shed && !reply.degraded);
        assert!(reply.indices.is_empty() && reply.scores.is_empty());
        assert_eq!((reply.covered, reply.shards_total), (0, 2));
    }

    #[test]
    fn degraded_reply_roundtrips_flags_and_epsilon_hat() {
        let resp = QueryResponse {
            indices: vec![3, 8],
            scores: vec![1.5, 0.75],
            flops: 4200,
            queue_wait: Duration::from_micros(5),
            service: Duration::from_micros(80),
            batch_size: 1,
            worker: 0,
            shed: false,
            degraded: true,
            epsilon_hat: 0.0625,
            shards: 3,
            shards_total: 4,
            storage: Storage::F32,
            generation: 2,
            applied_epsilon: None,
            applied_k: None,
        };
        let mut codec = BinaryCodec::new();
        let mut wire = Vec::new();
        codec.encode_reply(&resp, &mut wire);
        let mut dec = frame::FrameDecoder::new();
        dec.feed(&wire);
        let f = dec.try_frame().unwrap().unwrap();
        let reply = decode_reply(f.body).unwrap();
        assert!(reply.ok && !reply.shed && reply.degraded);
        assert_eq!(reply.indices, vec![3, 8]);
        assert_eq!(reply.epsilon_hat, 0.0625);
        assert_eq!((reply.covered, reply.shards_total), (3, 4));
    }

    #[test]
    fn budget_flops_promotes_frame_to_v2_and_roundtrips() {
        let v: Vec<f32> = (0..8).map(|i| i as f32 * 0.125).collect();
        let opts =
            QueryOpts { budget_flops: Some(5_000), ..Default::default() };
        let mut wire = Vec::new();
        encode_query_frame(&[&v], &opts, &mut wire).unwrap();
        assert_eq!(&wire[..4], &frame::MAGIC_V2);
        let mut codec = BinaryCodec::new();
        codec.feed(&wire);
        let Ok(Some(WireRequest::Query(reqs))) = codec.try_decode() else {
            panic!("expected a query batch");
        };
        assert_eq!(reqs[0].budget_flops, Some(5_000));
        for (a, b) in reqs[0].vector.iter().zip(&v) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // No budget ⇒ the frame stays v1, byte-for-byte.
        let mut v1_wire = Vec::new();
        encode_query_frame(&[&v], &QueryOpts::default(), &mut v1_wire).unwrap();
        assert_eq!(&v1_wire[..4], &frame::MAGIC);
        let mut codec = BinaryCodec::new();
        codec.feed(&v1_wire);
        let Ok(Some(WireRequest::Query(reqs))) = codec.try_decode() else {
            panic!("expected a query batch");
        };
        assert_eq!(reqs[0].budget_flops, None);
    }

    #[test]
    fn decode_payload_reuses_its_buffer() {
        let v: Vec<f32> = (0..128).map(|i| (i as f32).sin()).collect();
        let mut wire = Vec::new();
        encode_query_frame(&[&v], &QueryOpts::default(), &mut wire).unwrap();
        let body = &wire[frame::PREAMBLE_LEN..];
        let mut coords = Vec::new();
        for _ in 0..3 {
            let h = decode_query_payload(body, 1, &mut coords).unwrap();
            assert_eq!((h.count, h.dim), (1, 128));
            assert_eq!(coords.len(), 128);
            for (a, b) in coords.iter().zip(&v) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn truncated_payload_rejected() {
        let v = vec![1.0f32; 16];
        let mut wire = Vec::new();
        encode_query_frame(&[&v], &QueryOpts::default(), &mut wire).unwrap();
        // Lie about the body length: shrink the payload but keep the
        // header's count·dim claim.
        let body = &wire[frame::PREAMBLE_LEN..wire.len() - 4];
        assert!(matches!(
            QueryHeader::parse(body, 1),
            Err(FrameError::BadHeader(_))
        ));
    }
}
