//! Streaming statistics: online moments and a fixed-bucket percentile
//! sketch for latency reporting in the coordinator.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineMoments {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineMoments {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add an observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Count of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population variance (0 if < 2 observations).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest observation (`inf` if empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation (`-inf` if empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator into this one (Chan's parallel update).
    pub fn merge(&mut self, other: &OnlineMoments) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Log-bucketed histogram for positive values (latencies in seconds,
/// flop counts, …). 90 buckets per decade over ~12 decades; quantile
/// error is < 3% which is plenty for p50/p99 reporting.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: Vec<u64>,
    total: u64,
    moments: OnlineMoments,
}

const BUCKETS_PER_DECADE: f64 = 90.0;
const MIN_EXP: f64 = -9.0; // 1e-9 lower edge
const NUM_BUCKETS: usize = (12.0 * BUCKETS_PER_DECADE) as usize;

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self { counts: vec![0; NUM_BUCKETS + 2], total: 0, moments: OnlineMoments::new() }
    }

    fn bucket(x: f64) -> usize {
        if x <= 0.0 {
            return 0;
        }
        let b = ((x.log10() - MIN_EXP) * BUCKETS_PER_DECADE).floor();
        (b.max(0.0) as usize + 1).min(NUM_BUCKETS + 1)
    }

    fn bucket_value(b: usize) -> f64 {
        if b == 0 {
            return 0.0;
        }
        10f64.powf(MIN_EXP + (b as f64 - 0.5) / BUCKETS_PER_DECADE)
    }

    /// Bucket index for value `x` — the bucket layout is public so
    /// other sketch representations (the coordinator's lock-free atomic
    /// histogram) can share it and stay comparable.
    pub fn bucket_index(x: f64) -> usize {
        Self::bucket(x)
    }

    /// Representative (log-midpoint) value of bucket `b`.
    pub fn bucket_midpoint(b: usize) -> f64 {
        Self::bucket_value(b)
    }

    /// Total bucket count, including the ≤0 and overflow buckets.
    pub const fn bucket_count() -> usize {
        NUM_BUCKETS + 2
    }

    /// Record an observation.
    pub fn record(&mut self, x: f64) {
        self.counts[Self::bucket(x)] += 1;
        self.total += 1;
        self.moments.push(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Mean of observations.
    pub fn mean(&self) -> f64 {
        self.moments.mean()
    }

    /// Approximate quantile `q` in [0,1]; 0 if empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_value(b);
            }
        }
        self.moments.max()
    }

    /// Merge another histogram.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.moments.merge(&other.moments);
    }

    /// One-line summary string: `n=…, mean=…, p50=…, p99=…, max=…`.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3e} p50={:.3e} p90={:.3e} p99={:.3e} max={:.3e}",
            self.total,
            self.mean(),
            self.quantile(0.5),
            self.quantile(0.9),
            self.quantile(0.99),
            self.moments.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_basic() {
        let mut m = OnlineMoments::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            m.push(x);
        }
        assert_eq!(m.count(), 4);
        assert!((m.mean() - 2.5).abs() < 1e-12);
        assert!((m.variance() - 1.25).abs() < 1e-12);
        assert_eq!(m.min(), 1.0);
        assert_eq!(m.max(), 4.0);
    }

    #[test]
    fn moments_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = OnlineMoments::new();
        xs.iter().for_each(|&x| all.push(x));
        let mut a = OnlineMoments::new();
        let mut b = OnlineMoments::new();
        xs[..37].iter().for_each(|&x| a.push(x));
        xs[37..].iter().for_each(|&x| b.push(x));
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_roughly_correct() {
        let mut h = LogHistogram::new();
        // 1..=1000 microseconds-ish values
        for i in 1..=1000 {
            h.record(i as f64 * 1e-6);
        }
        let p50 = h.quantile(0.5);
        assert!((p50 / 500e-6 - 1.0).abs() < 0.1, "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!((p99 / 990e-6 - 1.0).abs() < 0.1, "p99={p99}");
    }

    #[test]
    fn histogram_empty_and_zero() {
        let mut h = LogHistogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
        h.record(0.0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for i in 1..=100 {
            a.record(i as f64);
            b.record(i as f64 * 10.0);
        }
        let pre = a.count();
        a.merge(&b);
        assert_eq!(a.count(), pre + 100);
        assert!(a.quantile(0.99) > 500.0);
    }
}
