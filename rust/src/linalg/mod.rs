//! Dense linear-algebra substrate.
//!
//! Everything the MIPS algorithms need, implemented from scratch:
//! a row-major [`Matrix`], runtime-dispatched SIMD kernels ([`simd`]:
//! AVX2 / NEON / portable-scalar behind one cached function-pointer
//! table), deterministic RNG ([`rng::Rng`]), power-iteration PCA
//! ([`pca`]), top-K selection ([`topk`]) and streaming moments
//! ([`stats`]).
//!
//! The free functions below ([`dot`], [`partial_dot`], [`axpy`],
//! [`dist_sq`], [`norm_sq`], [`dot_rows`], [`partial_dot_rows`],
//! [`gather_idx`]) are the single compute funnel of the whole system:
//! every exact scan, pull batch, confirm rescore, and panel/query
//! gather goes through them, so the ISA selected by [`simd`] (AVX-512 /
//! AVX2 / NEON / scalar) lifts every layer at once. Set
//! `RUST_PALLAS_FORCE_SCALAR=1` to pin the portable scalar kernels
//! (see [`simd`] for the dispatch and tolerance contract).

pub mod matrix;
pub mod pca;
pub mod rng;
pub mod simd;
pub mod solve;
pub mod stats;
pub mod topk;

pub use matrix::Matrix;
pub use rng::Rng;
pub use topk::TopK;

/// Dot product of two equal-length slices.
///
/// This is the innermost primitive of the whole system: both the naive
/// baseline and the exact re-ranking phases of every approximate index
/// funnel through it. Dispatches to the [`simd`] kernel table (AVX2 /
/// NEON / scalar — selected once per process). We accept float
/// reassociation across ISAs; MIPS scores are compared, not accumulated
/// across queries.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    (simd::kernels().dot)(a, b)
}

/// Partial dot product over the coordinate range `[lo, hi)`.
///
/// One BOUNDEDME "pull batch": multiplying `hi - lo` coordinates of a
/// data vector with the query. Counted as `hi - lo` flops by the cost
/// model in [`crate::metrics`].
#[inline]
pub fn partial_dot(a: &[f32], b: &[f32], lo: usize, hi: usize) -> f32 {
    dot(&a[lo..hi], &b[lo..hi])
}

/// Blocked row scoring: `out[i] = dot(block[i*dim..(i+1)*dim], q)`.
///
/// `block` is `out.len()` contiguous row-major rows (the shape
/// [`Matrix::row_block`] returns). The SIMD backends score several rows
/// per pass sharing each query register load — the kernel behind the
/// Naive fused scan, the engine batch paths, and the sharded confirm
/// rescore. Guaranteed bit-identical per row to [`dot`] on the same
/// slices (see the [`simd`] module contract).
#[inline]
pub fn dot_rows(block: &[f32], dim: usize, q: &[f32], out: &mut [f32]) {
    debug_assert_eq!(q.len(), dim);
    debug_assert_eq!(block.len(), out.len() * dim);
    (simd::kernels().dot_rows)(block, dim, q, out)
}

/// Scattered blocked scoring: `out[i] = dot(rows[i], q)` where every
/// `rows[i]` is a pre-sliced window with `rows[i].len() == q.len()`.
///
/// One pull batch across a BOUNDEDME survivor set: survivors are
/// non-contiguous matrix rows, but each round pulls the same dense
/// coordinate run from all of them, so the kernel shares query register
/// loads across the set. Also bit-identical per row to [`dot`].
#[inline]
pub fn partial_dot_rows(rows: &[&[f32]], q: &[f32], out: &mut [f32]) {
    debug_assert_eq!(rows.len(), out.len());
    (simd::kernels().partial_dot_rows)(rows, q, out)
}

/// Drive [`partial_dot_rows`] over an arbitrarily long scattered row
/// sequence in fixed stack-resident chunks of 8 (no heap staging),
/// calling `sink(index, score)` for each row in sequence order.
///
/// This is the one staging loop shared by every scattered consumer —
/// BOUNDEDME pull batches over survivor sets and the sharded confirm
/// rescore — so the chunk/remainder bookkeeping lives in exactly one
/// place. Per-row scores are bit-identical to [`dot`] regardless of how
/// the sequence length splits into chunks.
pub fn partial_dot_rows_chunked<'a, I, F>(rows: I, q: &[f32], mut sink: F)
where
    I: IntoIterator<Item = &'a [f32]>,
    F: FnMut(usize, f32),
{
    const CHUNK: usize = 8;
    let mut refs: [&[f32]; CHUNK] = [&[]; CHUNK];
    let mut scores = [0f32; CHUNK];
    let mut base = 0usize;
    let mut fill = 0usize;
    for row in rows {
        refs[fill] = row;
        fill += 1;
        if fill == CHUNK {
            partial_dot_rows(&refs, q, &mut scores);
            for (t, &s) in scores.iter().enumerate() {
                sink(base + t, s);
            }
            base += CHUNK;
            fill = 0;
        }
    }
    if fill > 0 {
        partial_dot_rows(&refs[..fill], q, &mut scores[..fill]);
        for (t, &s) in scores[..fill].iter().enumerate() {
            sink(base + t, s);
        }
    }
}

/// Index gather: `out[t] = src[idx[t]]` with `idx.len() == out.len()`
/// and every index within `src`.
///
/// The staging primitive behind the per-query coordinate gather
/// ([`crate::bandit::PullScratch::gather`]) and BOUNDEDME's survivor
/// panel compaction ([`crate::bandit::PullPanel`]). Pure data movement:
/// results are identical on every ISA (x86 backends use the hardware
/// `vgatherdps`), so unlike the dot kernels it carries no
/// float-reassociation caveats.
#[inline]
pub fn gather_idx(src: &[f32], idx: &[u32], out: &mut [f32]) {
    debug_assert_eq!(idx.len(), out.len());
    (simd::kernels().gather)(src, idx, out)
}

/// Squared Euclidean norm.
#[inline]
pub fn norm_sq(a: &[f32]) -> f32 {
    (simd::kernels().norm_sq)(a)
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    norm_sq(a).sqrt()
}

/// Squared Euclidean distance between two vectors.
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    (simd::kernels().dist_sq)(a, b)
}

/// `y += alpha * x` (AXPY).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    (simd::kernels().axpy)(alpha, x, y)
}

/// Scale a vector in place.
#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Normalize a vector in place to unit L2 norm; returns the original norm.
/// Zero vectors are left untouched.
pub fn normalize(x: &mut [f32]) -> f32 {
    let n = norm(x);
    if n > 0.0 {
        scale(1.0 / n, x);
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.5 - 3.0).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32).sin()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    fn dot_empty_is_zero() {
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn dot_short_lengths() {
        for n in 1..20usize {
            let a: Vec<f32> = (0..n).map(|i| i as f32 + 1.0).collect();
            let b = vec![2.0f32; n];
            let expect: f32 = (1..=n).map(|i| 2.0 * i as f32).sum();
            assert!((dot(&a, &b) - expect).abs() < 1e-4, "n={n}");
        }
    }

    #[test]
    fn partial_dot_slices() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [1.0f32, 1.0, 1.0, 1.0];
        assert_eq!(partial_dot(&a, &b, 1, 3), 5.0);
        assert_eq!(partial_dot(&a, &b, 0, 4), 10.0);
        assert_eq!(partial_dot(&a, &b, 2, 2), 0.0);
    }

    #[test]
    fn dot_rows_matches_per_row_dot_bitwise() {
        // The invariant the fused-scan equivalence tests stand on.
        for (rows, dim) in [(1usize, 5usize), (3, 64), (4, 17), (9, 33), (2, 0)] {
            let block: Vec<f32> =
                (0..rows * dim).map(|i| (i as f32 * 0.3).sin()).collect();
            let q: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.7).cos()).collect();
            let mut out = vec![0f32; rows];
            dot_rows(&block, dim, &q, &mut out);
            for r in 0..rows {
                let single = dot(&block[r * dim..(r + 1) * dim], &q);
                assert_eq!(out[r].to_bits(), single.to_bits(), "{rows}x{dim} row {r}");
            }
        }
    }

    #[test]
    fn partial_dot_rows_matches_per_row_dot_bitwise() {
        let dim = 50usize;
        let rows = 7usize;
        let block: Vec<f32> = (0..rows * dim).map(|i| (i as f32 * 0.13).sin()).collect();
        let q: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.21).cos()).collect();
        // Scattered windows [10, 40) of each row (unaligned lo).
        let refs: Vec<&[f32]> =
            (0..rows).map(|r| &block[r * dim + 10..r * dim + 40]).collect();
        let mut out = vec![0f32; rows];
        partial_dot_rows(&refs, &q[10..40], &mut out);
        for r in 0..rows {
            let single = partial_dot(&block[r * dim..(r + 1) * dim], &q, 10, 40);
            assert_eq!(out[r].to_bits(), single.to_bits(), "row {r}");
        }
    }

    #[test]
    fn partial_dot_rows_chunked_covers_all_remainders() {
        // Lengths straddling the chunk width (8): empty, sub-chunk,
        // exact multiples, and ragged tails all visit every row once,
        // in order, with scores bit-identical to per-row dot.
        let dim = 21usize;
        for rows in [0usize, 1, 7, 8, 9, 16, 19] {
            let block: Vec<f32> =
                (0..rows * dim).map(|i| (i as f32 * 0.23).sin()).collect();
            let q: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.41).cos()).collect();
            let mut seen = Vec::new();
            partial_dot_rows_chunked(
                (0..rows).map(|r| &block[r * dim..(r + 1) * dim]),
                &q,
                |i, s| seen.push((i, s)),
            );
            assert_eq!(seen.len(), rows, "rows={rows}");
            for (r, &(i, s)) in seen.iter().enumerate() {
                assert_eq!(i, r, "rows={rows}: order");
                let single = dot(&block[r * dim..(r + 1) * dim], &q);
                assert_eq!(s.to_bits(), single.to_bits(), "rows={rows} row {r}");
            }
        }
    }

    #[test]
    fn gather_idx_matches_index_loop() {
        let src: Vec<f32> = (0..50).map(|i| (i as f32 * 0.7).sin()).collect();
        for n in [0usize, 1, 7, 8, 9, 24] {
            let idx: Vec<u32> = (0..n).map(|t| ((t * 13 + 5) % 50) as u32).collect();
            let mut out = vec![0f32; n];
            gather_idx(&src, &idx, &mut out);
            for t in 0..n {
                assert_eq!(out[t].to_bits(), src[idx[t] as usize].to_bits(), "n={n} t={t}");
            }
        }
    }

    #[test]
    fn norms_and_dist() {
        let a = [3.0f32, 4.0];
        assert_eq!(norm_sq(&a), 25.0);
        assert_eq!(norm(&a), 5.0);
        assert_eq!(dist_sq(&a, &[0.0, 0.0]), 25.0);
    }

    #[test]
    fn dist_sq_matches_naive_long() {
        let a: Vec<f32> = (0..133).map(|i| (i as f32 * 0.17).sin()).collect();
        let b: Vec<f32> = (0..133).map(|i| (i as f32 * 0.31).cos()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!((dist_sq(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    fn axpy_scale_normalize() {
        let x = [1.0f32, 2.0];
        let mut y = [10.0f32, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
        scale(0.5, &mut y);
        assert_eq!(y, [6.0, 12.0]);
        let mut v = [3.0f32, 4.0];
        let n = normalize(&mut v);
        assert_eq!(n, 5.0);
        assert!((norm(&v) - 1.0).abs() < 1e-6);
        let mut z = [0.0f32, 0.0];
        assert_eq!(normalize(&mut z), 0.0);
    }
}
