//! Dense linear-algebra substrate.
//!
//! Everything the MIPS algorithms need, implemented from scratch:
//! a row-major [`Matrix`], blocked dot products, deterministic RNG
//! ([`rng::Rng`]), power-iteration PCA ([`pca`]), top-K selection
//! ([`topk`]) and streaming moments ([`stats`]).

pub mod matrix;
pub mod pca;
pub mod rng;
pub mod solve;
pub mod stats;
pub mod topk;

pub use matrix::Matrix;
pub use rng::Rng;
pub use topk::TopK;

/// Dot product of two equal-length slices, unrolled 4-wide.
///
/// This is the innermost primitive of the whole system: both the naive
/// baseline and the exact re-ranking phases of every approximate index
/// funnel through it. The 4 independent accumulators let LLVM vectorize
/// without `-ffast-math`-style reassociation concerns (we accept the
/// reassociation; MIPS scores are compared, not accumulated across
/// queries).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // Lane-wise accumulators over fixed-size chunks: the form LLVM
    // reliably turns into packed FMAs under `-C target-cpu=native`.
    const LANES: usize = 16;
    let mut acc = [0f32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for i in 0..LANES {
            acc[i] += xa[i] * xb[i];
        }
    }
    let mut tail = 0f32;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    // Pairwise reduction keeps the summation tree balanced.
    let mut width = LANES / 2;
    while width > 0 {
        for i in 0..width {
            acc[i] += acc[i + width];
        }
        width /= 2;
    }
    acc[0] + tail
}

/// Partial dot product over the coordinate range `[lo, hi)`.
///
/// One BOUNDEDME "pull batch": multiplying `hi - lo` coordinates of a
/// data vector with the query. Counted as `hi - lo` flops by the cost
/// model in [`crate::metrics`].
#[inline]
pub fn partial_dot(a: &[f32], b: &[f32], lo: usize, hi: usize) -> f32 {
    dot(&a[lo..hi], &b[lo..hi])
}

/// Squared Euclidean norm.
#[inline]
pub fn norm_sq(a: &[f32]) -> f32 {
    dot(a, a)
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    norm_sq(a).sqrt()
}

/// Squared Euclidean distance between two vectors.
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// `y += alpha * x` (AXPY).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// Scale a vector in place.
#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Normalize a vector in place to unit L2 norm; returns the original norm.
/// Zero vectors are left untouched.
pub fn normalize(x: &mut [f32]) -> f32 {
    let n = norm(x);
    if n > 0.0 {
        scale(1.0 / n, x);
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.5 - 3.0).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32).sin()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    fn dot_empty_is_zero() {
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn dot_short_lengths() {
        for n in 1..20usize {
            let a: Vec<f32> = (0..n).map(|i| i as f32 + 1.0).collect();
            let b = vec![2.0f32; n];
            let expect: f32 = (1..=n).map(|i| 2.0 * i as f32).sum();
            assert!((dot(&a, &b) - expect).abs() < 1e-4, "n={n}");
        }
    }

    #[test]
    fn partial_dot_slices() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [1.0f32, 1.0, 1.0, 1.0];
        assert_eq!(partial_dot(&a, &b, 1, 3), 5.0);
        assert_eq!(partial_dot(&a, &b, 0, 4), 10.0);
        assert_eq!(partial_dot(&a, &b, 2, 2), 0.0);
    }

    #[test]
    fn norms_and_dist() {
        let a = [3.0f32, 4.0];
        assert_eq!(norm_sq(&a), 25.0);
        assert_eq!(norm(&a), 5.0);
        assert_eq!(dist_sq(&a, &[0.0, 0.0]), 25.0);
    }

    #[test]
    fn axpy_scale_normalize() {
        let x = [1.0f32, 2.0];
        let mut y = [10.0f32, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
        scale(0.5, &mut y);
        assert_eq!(y, [6.0, 12.0]);
        let mut v = [3.0f32, 4.0];
        let n = normalize(&mut v);
        assert_eq!(n, 5.0);
        assert!((norm(&v) - 1.0).abs() < 1e-6);
        let mut z = [0.0f32, 0.0];
        assert_eq!(normalize(&mut z), 0.0);
    }
}
