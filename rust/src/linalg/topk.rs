//! Top-K selection utilities.
//!
//! Every MIPS index ends with "return the K items with the largest
//! scores"; [`TopK`] is a bounded min-heap specialized for `(score, id)`
//! pairs with deterministic tie-breaking (lower id wins ties so that
//! precision comparisons across algorithms are stable).

/// Bounded min-heap keeping the `k` largest `(score, id)` pairs.
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    /// Min-heap on (score, Reverse(id)) semantics, stored as a binary heap
    /// in a Vec. heap[0] is the *worst* kept element.
    heap: Vec<(f32, usize)>,
}

impl TopK {
    /// New selector for the `k` largest items. `k = 0` keeps nothing.
    pub fn new(k: usize) -> Self {
        Self { k, heap: Vec::with_capacity(k + 1) }
    }

    /// `a` is strictly worse than `b` (lower score, or equal score with
    /// higher id — so ties prefer smaller ids to stay).
    #[inline]
    fn worse(a: (f32, usize), b: (f32, usize)) -> bool {
        a.0 < b.0 || (a.0 == b.0 && a.1 > b.1)
    }

    /// Offer a candidate.
    #[inline]
    pub fn push(&mut self, score: f32, id: usize) {
        if self.k == 0 {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push((score, id));
            self.sift_up(self.heap.len() - 1);
        } else if Self::worse(self.heap[0], (score, id)) {
            self.heap[0] = (score, id);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if Self::worse(self.heap[i], self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut worst = i;
            if l < n && Self::worse(self.heap[l], self.heap[worst]) {
                worst = l;
            }
            if r < n && Self::worse(self.heap[r], self.heap[worst]) {
                worst = r;
            }
            if worst == i {
                return;
            }
            self.heap.swap(i, worst);
            i = worst;
        }
    }

    /// Current number of kept items.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True if nothing has been kept.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Worst kept score, or `-inf` if fewer than `k` kept so far.
    pub fn threshold(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::NEG_INFINITY
        } else {
            self.heap[0].0
        }
    }

    /// Extract `(score, id)` pairs sorted best-first (descending score,
    /// ascending id on ties).
    pub fn into_sorted(self) -> Vec<(f32, usize)> {
        let mut v = self.heap;
        v.sort_by(|a, b| {
            b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
        });
        v
    }

    /// Extract just the ids, best-first.
    pub fn into_indices(self) -> Vec<usize> {
        self.into_sorted().into_iter().map(|(_, i)| i).collect()
    }
}

/// Exact top-k of a score slice: returns `(score, index)` best-first.
pub fn top_k_of(scores: &[f32], k: usize) -> Vec<(f32, usize)> {
    let mut t = TopK::new(k);
    for (i, &s) in scores.iter().enumerate() {
        t.push(s, i);
    }
    t.into_sorted()
}

/// Exact arg-top-k of a score slice.
pub fn arg_top_k(scores: &[f32], k: usize) -> Vec<usize> {
    top_k_of(scores, k).into_iter().map(|(_, i)| i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_k_largest() {
        let scores = [0.1f32, 5.0, 3.0, 4.0, -1.0, 2.0];
        assert_eq!(arg_top_k(&scores, 3), vec![1, 3, 2]);
    }

    #[test]
    fn fewer_than_k() {
        assert_eq!(arg_top_k(&[2.0, 1.0], 5), vec![0, 1]);
    }

    #[test]
    fn k_zero() {
        let mut t = TopK::new(0);
        t.push(1.0, 0);
        assert!(t.is_empty());
        assert!(t.into_indices().is_empty());
    }

    #[test]
    fn tie_break_prefers_lower_id() {
        let scores = [1.0f32, 1.0, 1.0, 1.0];
        assert_eq!(arg_top_k(&scores, 2), vec![0, 1]);
    }

    #[test]
    fn threshold_tracks_worst() {
        let mut t = TopK::new(2);
        assert_eq!(t.threshold(), f32::NEG_INFINITY);
        t.push(1.0, 0);
        assert_eq!(t.threshold(), f32::NEG_INFINITY);
        t.push(3.0, 1);
        assert_eq!(t.threshold(), 1.0);
        t.push(2.0, 2);
        assert_eq!(t.threshold(), 2.0);
    }

    #[test]
    fn insertion_order_independent_with_duplicate_scores() {
        // The shard merge pushes partials in whatever order shards
        // finish; the kept set and its order must not depend on it.
        // (score, id) pairs with heavy score duplication across "shards":
        let items: Vec<(f32, usize)> =
            (0..24).map(|i| (((i * 7) % 4) as f32, i)).collect();
        let reference: Vec<(f32, usize)> = {
            let mut t = TopK::new(5);
            for &(s, i) in &items {
                t.push(s, i);
            }
            t.into_sorted()
        };
        // Try many deterministic permutations of the arrival order.
        let mut order: Vec<usize> = (0..items.len()).collect();
        let mut rng = crate::linalg::Rng::new(0x0D7E);
        for trial in 0..40 {
            rng.shuffle(&mut order);
            let mut t = TopK::new(5);
            for &pos in &order {
                let (s, i) = items[pos];
                t.push(s, i);
            }
            assert_eq!(t.into_sorted(), reference, "trial {trial}: order-dependent");
        }
        // Ties resolved toward the smaller id: score 3.0 is held by ids
        // 1, 5, 9, 13, 17, 21 — the five kept must be the smallest ids.
        assert!(reference.iter().all(|&(s, _)| s == 3.0));
        assert_eq!(
            reference.iter().map(|&(_, i)| i).collect::<Vec<_>>(),
            vec![1, 5, 9, 13, 17]
        );
    }

    #[test]
    fn k_zero_threshold_and_push_are_inert() {
        let mut t = TopK::new(0);
        for i in 0..10 {
            t.push(i as f32, i);
        }
        assert_eq!(t.len(), 0);
        assert!(t.into_sorted().is_empty());
    }

    #[test]
    fn k_exceeding_input_keeps_everything_sorted() {
        let scores = [1.0f32, 1.0, 3.0, -2.0];
        let got = top_k_of(&scores, 100);
        assert_eq!(got, vec![(3.0, 2), (1.0, 0), (1.0, 1), (-2.0, 3)]);
    }

    #[test]
    fn matches_full_sort_on_random_input() {
        let mut rng = crate::linalg::Rng::new(42);
        for trial in 0..50 {
            let n = 1 + rng.next_below(200);
            let k = 1 + rng.next_below(20);
            let scores: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let got = arg_top_k(&scores, k);
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| {
                scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b))
            });
            idx.truncate(k.min(n));
            assert_eq!(got, idx, "trial {trial}");
        }
    }
}
