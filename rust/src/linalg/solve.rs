//! Small dense solvers: Cholesky for the SPD normal equations of ALS,
//! and Gram–Schmidt orthonormalization for the embedding lift.

use super::{axpy, dot, normalize, Rng};

/// In-place Cholesky factorization of a symmetric positive-definite
/// `n × n` matrix `a` (row-major); lower triangle receives `L` with
/// `A = L Lᵀ`. Returns `false` if the matrix is not SPD.
pub fn cholesky(a: &mut [f64], n: usize) -> bool {
    debug_assert_eq!(a.len(), n * n);
    for j in 0..n {
        let mut d = a[j * n + j];
        for k in 0..j {
            d -= a[j * n + k] * a[j * n + k];
        }
        if d <= 0.0 {
            return false;
        }
        let d = d.sqrt();
        a[j * n + j] = d;
        for i in j + 1..n {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = s / d;
        }
    }
    true
}

/// Solve `A x = b` for SPD `A` via Cholesky; `a` is destroyed, `b` is
/// replaced by the solution. Returns `false` if not SPD.
pub fn cholesky_solve(a: &mut [f64], b: &mut [f64], n: usize) -> bool {
    if !cholesky(a, n) {
        return false;
    }
    // Forward: L y = b.
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= a[i * n + k] * b[k];
        }
        b[i] = s / a[i * n + i];
    }
    // Backward: Lᵀ x = y.
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= a[k * n + i] * b[k];
        }
        b[i] = s / a[i * n + i];
    }
    true
}

/// Generate `k` orthonormal vectors of dimension `dim` (rows of the
/// returned flat `k × dim` buffer) via Gram–Schmidt on Gaussian draws.
/// Panics if `k > dim`.
pub fn random_orthonormal(k: usize, dim: usize, seed: u64) -> Vec<f32> {
    assert!(k <= dim, "cannot build {k} orthonormal vectors in R^{dim}");
    let mut rng = Rng::new(seed);
    let mut rows: Vec<Vec<f32>> = Vec::with_capacity(k);
    while rows.len() < k {
        let mut v = rng.gaussian_vec(dim);
        for r in &rows {
            let p = dot(&v, r);
            axpy(-p, r, &mut v);
        }
        if normalize(&mut v) > 1e-6 {
            rows.push(v);
        }
    }
    rows.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_solves_known_system() {
        // A = [[4,2],[2,3]], b = [10, 9] → x = [1.5, 2.0]
        let mut a = vec![4.0, 2.0, 2.0, 3.0];
        let mut b = vec![10.0, 9.0];
        assert!(cholesky_solve(&mut a, &mut b, 2));
        assert!((b[0] - 1.5).abs() < 1e-12);
        assert!((b[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_random_spd_roundtrip() {
        let mut rng = Rng::new(1);
        let n = 8;
        // A = M Mᵀ + I is SPD.
        let m: Vec<f64> = (0..n * n).map(|_| rng.gaussian()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..n {
                    s += m[i * n + k] * m[j * n + k];
                }
                a[i * n + j] = s;
            }
        }
        let x_true: Vec<f64> = (0..n).map(|i| i as f64 - 3.0).collect();
        let mut b = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                b[i] += a[i * n + j] * x_true[j];
            }
        }
        let mut a_work = a.clone();
        assert!(cholesky_solve(&mut a_work, &mut b, n));
        for i in 0..n {
            assert!((b[i] - x_true[i]).abs() < 1e-8, "x[{i}]");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(!cholesky(&mut a, 2));
    }

    #[test]
    fn orthonormal_rows() {
        let k = 6;
        let dim = 32;
        let e = random_orthonormal(k, dim, 2);
        for i in 0..k {
            let ri = &e[i * dim..(i + 1) * dim];
            assert!((dot(ri, ri) - 1.0).abs() < 1e-5);
            for j in 0..i {
                let rj = &e[j * dim..(j + 1) * dim];
                assert!(dot(ri, rj).abs() < 1e-5, "rows {i},{j}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn orthonormal_rejects_k_gt_dim() {
        random_orthonormal(5, 4, 0);
    }
}
